"""Render EXPERIMENTS.md table sections from the dry-run JSONs.
Usage: PYTHONPATH=src python -m benchmarks.build_experiments
Prints the §Dry-run and §Roofline tables to stdout (pasted into
EXPERIMENTS.md by the build process / maintainer)."""
from __future__ import annotations

import json


def fmt(results, mesh_filter):
    rows = []
    for r in results:
        if r.get("status") == "SKIP":
            continue
        if r.get("status") != "OK":
            rows.append(f"| {r['arch']} | {r['shape']} | - | FAIL: "
                        f"{r.get('error','?')[:40]} | | | | | | |")
            continue
        is_multi = "pod" in r["mesh"]
        if (mesh_filter == "multi") != is_multi:
            continue
        ro, mem = r["roofline"], r["memory"]
        flags = []
        if r.get("fsdp"):
            flags.append("fsdp")
        if r.get("seq_parallel"):
            flags.append("sp")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {'+'.join(flags) or '-'} "
            f"| {ro['compute_s']:.3f} | {ro['memory_s']:.3f} "
            f"| {ro['collective_s']:.3f} | {ro['dominant']} "
            f"| {ro['useful_ratio']:.2f} | {ro['roofline_fraction']:.4f} "
            f"| {mem['peak_gb']:.2f}{'' if mem['fits_16gb'] else ' (!)'} |")
    head = ("| arch | shape | mode | compute_s | memory_s | collective_s "
            "| dominant | useful | frac | peak GB/dev |\n"
            "|---|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    with open("dryrun_results.json") as f:
        results = json.load(f)
    ok = [r for r in results if r.get("status") == "OK"]
    fail = [r for r in results if r.get("status") == "FAIL"]
    skip = [r for r in results if r.get("status") == "SKIP"]
    print(f"<!-- {len(ok)} OK, {len(fail)} FAIL, {len(skip)} SKIP -->\n")
    print("### Single-pod (16x16 = 256 chips)\n")
    print(fmt(results, "single"))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(fmt(results, "multi"))
    print("\n### Skipped cells\n")
    print("| arch | shape | reason |\n|---|---|---|")
    for r in skip:
        print(f"| {r['arch']} | {r['shape']} | {r['reason'][:90]}... |")
    try:
        with open("dryrun_hier.json") as f:
            hier = json.load(f)
        print("\n### HierTrain tiered sync (multi-pod, train_4k)\n")
        print(fmt(hier, "multi"))
        for r in hier:
            if r.get("status") == "OK" and "tiers" in r:
                print(f"\n- {r['arch']}: {r['tiers']}")
    except FileNotFoundError:
        pass


if __name__ == "__main__":
    main()
