"""Shared benchmark plumbing: the paper's testbed profiles + bandwidth
sweeps (§VI-B), the heterogeneous device fleet used by the M-device
benchmark, a tiny CSV/markdown table printer, and the JSON sink the
perf-tracking mode (``benchmarks/run.py --json``) writes through."""
from __future__ import annotations

import json
import platform
import subprocess
import time
from typing import Dict, Iterable, List, Sequence

from repro.core.cost_model import HierProfile, MultiProfile, Network, \
    StarNetwork
from repro.core.fleet import (FLEET_SLOWDOWNS, FLEET_UPLINK_MBPS, MBPS,
                              MOBILE_EDGE_MBPS, TABLE2_TESTBEDS, Fleet)
from repro.models.cnn import alexnet, lenet5

# §VI-D: mobile-edge fixed at 5 Mbps; edge-cloud swept 1.5 -> 5 Mbps.
EDGE_CLOUD_SWEEP_MBPS = (1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 4.5, 5.0)

BATCH = {"lenet5": 128, "alexnet": 64}

# Per-model worker calibration — single-sourced from repro.core.fleet so
# benchmarks and the Fleet constructors can never drift apart.
TESTBEDS = TABLE2_TESTBEDS

_MODELS = {"lenet5": lenet5, "alexnet": alexnet}


def cnn_model(model_name: str):
    return _MODELS[model_name]()


def table2_fleet(model_name: str, edge_cloud_mbps: float, m: int = 1,
                 topology: str = "auto", n_edges: int = 1) -> Fleet:
    """The paper-calibrated testbed as a :class:`Fleet` (the benchmark
    front door; figures plan through ``repro.api`` against it)."""
    return Fleet.from_table2(model=model_name, m=m,
                             edge_cloud_mbps=edge_cloud_mbps,
                             topology=topology, n_edges=n_edges)


def paper_profile(model_name: str) -> HierProfile:
    """The 3-worker analytic profile of the paper's testbed (kept for
    the equivalence suites; figures use :func:`table2_fleet`)."""
    fleet = table2_fleet(model_name, 3.0, topology="triple")
    return fleet.profile_for(cnn_model(model_name))


def network(edge_cloud_mbps: float,
            mobile_edge_mbps: float = MOBILE_EDGE_MBPS) -> Network:
    return Network(bw_de=mobile_edge_mbps * MBPS,
                   bw_ec=edge_cloud_mbps * MBPS)


def fleet_profile(model_name: str, m: int) -> MultiProfile:
    """M-device star profile for the paper-calibrated model testbed."""
    fleet = table2_fleet(model_name, 3.0, m=m, topology="star")
    return fleet.profile_for(cnn_model(model_name))


def star_network(m: int, edge_cloud_mbps: float) -> StarNetwork:
    net = table2_fleet("lenet5", edge_cloud_mbps, m=m,
                       topology="star").network()
    assert isinstance(net, StarNetwork)
    return net


def git_sha() -> str:
    """Commit (short) of the checkout containing this repo — resolved from
    this file's directory, not the process cwd; "unknown" outside git.

    A ``+dirty`` suffix marks a stamp taken with uncommitted changes: the
    artifact describes the commit *being prepared*, not the named SHA
    (the committed ``BENCH_sched.json`` always lags one commit otherwise;
    see EXPERIMENTS.md §Perf-tracking artifacts)."""
    import os
    cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True, cwd=cwd).stdout.strip()
    except Exception:
        return "unknown"
    try:
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10, check=True, cwd=cwd).stdout.strip()
        return f"{sha}+dirty" if dirty else sha
    except Exception:
        return sha


def table(rows: Sequence[Dict], cols: Sequence[str],
          title: str = "") -> str:
    out: List[str] = []
    if title:
        out.append(f"### {title}")
    out.append("| " + " | ".join(cols) + " |")
    out.append("|" + "|".join("---" for _ in cols) + "|")
    for r in rows:
        out.append("| " + " | ".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols) + " |")
    return "\n".join(out)


def write_json(path: str, payload: Dict) -> str:
    """Write a benchmark payload with host/time provenance; returns path.

    ``generated_in_ci`` marks in-CI regeneration (the schedule drift check
    recomputes the deterministic fields there without rewriting the
    committed artifact)."""
    import os
    doc = {
        "generated_unix": time.time(),
        "git_sha": git_sha(),
        "generated_in_ci": bool(os.environ.get("CI")),
        "host": {"machine": platform.machine(),
                 "python": platform.python_version()},
        **payload,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return path
