"""Shared benchmark plumbing: the paper's testbed profiles + bandwidth
sweeps (§VI-B), a tiny CSV/markdown table printer, and the JSON sink the
perf-tracking mode (``benchmarks/run.py --json``) writes through."""
from __future__ import annotations

import json
import platform
import time
from typing import Dict, Iterable, List, Sequence

from repro.core.cost_model import HierProfile, Network
from repro.core.profiler import (ALEXNET_TESTBED, PAPER_TESTBED,
                                 analytic_profile)
from repro.models.cnn import alexnet, lenet5

MBPS = 1e6 / 8.0                      # paper quotes Mbps; model uses B/s

# §VI-D: mobile-edge fixed at 5 Mbps; edge-cloud swept 1.5 -> 5 Mbps.
EDGE_CLOUD_SWEEP_MBPS = (1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 4.5, 5.0)
MOBILE_EDGE_MBPS = 5.0

BATCH = {"lenet5": 128, "alexnet": 64}

# Per-model worker calibration — the paper's profiling stage measures each
# model on each worker, so effective throughput is model-specific.
TESTBEDS = {"lenet5": PAPER_TESTBED, "alexnet": ALEXNET_TESTBED}


def paper_profile(model_name: str) -> HierProfile:
    model = {"lenet5": lenet5, "alexnet": alexnet}[model_name]()
    return analytic_profile(model, TESTBEDS[model_name])


def network(edge_cloud_mbps: float,
            mobile_edge_mbps: float = MOBILE_EDGE_MBPS) -> Network:
    return Network(bw_de=mobile_edge_mbps * MBPS,
                   bw_ec=edge_cloud_mbps * MBPS)


def table(rows: Sequence[Dict], cols: Sequence[str],
          title: str = "") -> str:
    out: List[str] = []
    if title:
        out.append(f"### {title}")
    out.append("| " + " | ".join(cols) + " |")
    out.append("|" + "|".join("---" for _ in cols) + "|")
    for r in rows:
        out.append("| " + " | ".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols) + " |")
    return "\n".join(out)


def write_json(path: str, payload: Dict) -> str:
    """Write a benchmark payload with host/time provenance; returns path."""
    doc = {
        "generated_unix": time.time(),
        "host": {"machine": platform.machine(),
                 "python": platform.python_version()},
        **payload,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return path
