"""Fig. 11: effect of edge-server CPU count on HierTrain, AlexNet.
The paper scales the edge server from 1 to 4 cores (docker-limited);
here the edge worker's throughput scales with core count.  Expected
shape: big win 1->2 cores at low bandwidth, flat at high bandwidth
(optimal policy trains on the cloud).  A custom-spec ``Fleet`` per core
count, planned through ``repro.api``."""
from __future__ import annotations

import dataclasses

from benchmarks.common import BATCH, table
from repro.api import Fleet, plan
from repro.core.profiler import ALEXNET_TESTBED
from repro.models.cnn import alexnet

BWS = (1.0, 1.5, 2.0, 3.0, 4.0)


def run() -> str:
    rows = []
    model = alexnet()
    for cores in (1, 2, 3, 4):
        workers = dict(ALEXNET_TESTBED)
        base = workers["edge"]
        workers["edge"] = dataclasses.replace(
            base, flops_per_sec=base.flops_per_sec * cores)
        row = {"edge_cores": cores}
        for bw in BWS:
            fleet = Fleet(workers=workers, backhaul_mbps=bw,
                          topology="triple")
            row[f"bw{bw}"] = plan(model, fleet, BATCH["alexnet"]).t_total
        rows.append(row)
    return table(rows, ["edge_cores"] + [f"bw{b}" for b in BWS],
                 "Fig.11 — per-iteration time (s) vs edge cores, AlexNet")


if __name__ == "__main__":
    print(run())
