"""Fig. 6: cost-model validity — Eq. (12) analytic latency vs the
discrete-event simulation of the §IV-B procedure, per phase, training
AlexNet under the optimal schedule at several bandwidths.  Planned
through the ``repro.api`` front door (triple-native fleet: the paper's
exact 3-worker stack)."""
from __future__ import annotations

from benchmarks.common import (EDGE_CLOUD_SWEEP_MBPS, cnn_model, table,
                               table2_fleet)
from repro.api import plan


def run() -> str:
    model = cnn_model("alexnet")
    rows = []
    for bw in EDGE_CLOUD_SWEEP_MBPS:
        p = plan(model, table2_fleet("alexnet", bw, topology="triple"),
                 B=64)
        analytic = p.t_total
        simulated = p.simulate()
        rows.append({
            "edge_cloud_mbps": bw,
            "analytic_s": analytic,
            "simulated_s": simulated,
            "rel_err_%": 100.0 * abs(simulated - analytic) /
            max(analytic, 1e-12),
            "schedule": p.schedule.describe(),
        })
    return table(rows, ["edge_cloud_mbps", "analytic_s", "simulated_s",
                        "rel_err_%", "schedule"],
                 "Fig.6 — analytic (Eq.12) vs discrete-event simulation, "
                 "AlexNet B=64")


if __name__ == "__main__":
    print(run())
