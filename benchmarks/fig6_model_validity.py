"""Fig. 6: cost-model validity — Eq. (12) analytic latency vs the
discrete-event simulation of the §IV-B procedure, per phase, training
AlexNet under the optimal schedule at several bandwidths."""
from __future__ import annotations

from benchmarks.common import (EDGE_CLOUD_SWEEP_MBPS, network,
                               paper_profile, table)
from repro.core.cost_model import t_total
from repro.core.scheduler import solve
from repro.core.simulator import simulate_iteration


def run() -> str:
    profile = paper_profile("alexnet")
    rows = []
    for bw in EDGE_CLOUD_SWEEP_MBPS:
        net = network(bw)
        res = solve(profile, net, B=64)
        analytic = t_total(profile, net, res.schedule).total
        simulated = simulate_iteration(profile, net, res.schedule)
        rows.append({
            "edge_cloud_mbps": bw,
            "analytic_s": analytic,
            "simulated_s": simulated,
            "rel_err_%": 100.0 * abs(simulated - analytic) /
            max(analytic, 1e-12),
            "schedule": res.schedule.describe(),
        })
    return table(rows, ["edge_cloud_mbps", "analytic_s", "simulated_s",
                        "rel_err_%", "schedule"],
                 "Fig.6 — analytic (Eq.12) vs discrete-event simulation, "
                 "AlexNet B=64")


if __name__ == "__main__":
    print(run())
