"""Figs. 7/8: per-iteration training time of HierTrain vs All-Edge and
All-Cloud across the edge-cloud bandwidth sweep, for AlexNet (Fig. 7)
and LeNet-5 (Fig. 8).  The paper reports up to 2.3x/4.5x (AlexNet) and
1.7x/6.9x (LeNet-5) speedups over All-Edge/All-Cloud.  Planned through
``repro.api``; the baselines come from ``Plan.baseline``."""
from __future__ import annotations

from benchmarks.common import BATCH, EDGE_CLOUD_SWEEP_MBPS, cnn_model, \
    table, table2_fleet
from repro.api import plan


def run_model(model_name: str) -> tuple:
    model = cnn_model(model_name)
    B = BATCH[model_name]
    rows = []
    best_edge, best_cloud = 0.0, 0.0
    for bw in EDGE_CLOUD_SWEEP_MBPS:
        p = plan(model, table2_fleet(model_name, bw, topology="triple"), B)
        hier = p.t_total
        edge = p.baseline("edge")
        cloud = p.baseline("cloud")
        best_edge = max(best_edge, edge / hier)
        best_cloud = max(best_cloud, cloud / hier)
        rows.append({"edge_cloud_mbps": bw, "hiertrain_s": hier,
                     "all_edge_s": edge, "all_cloud_s": cloud,
                     "speedup_vs_edge": edge / hier,
                     "speedup_vs_cloud": cloud / hier})
    return rows, best_edge, best_cloud


def run() -> str:
    out = []
    for name, fig in (("alexnet", "Fig.7"), ("lenet5", "Fig.8")):
        rows, se, sc = run_model(name)
        out.append(table(
            rows, ["edge_cloud_mbps", "hiertrain_s", "all_edge_s",
                   "all_cloud_s", "speedup_vs_edge", "speedup_vs_cloud"],
            f"{fig} — {name} (B={BATCH[name]}); max speedup "
            f"{se:.1f}x vs All-Edge, {sc:.1f}x vs All-Cloud"))
    return "\n\n".join(out)


if __name__ == "__main__":
    print(run())
