"""Figs. 9/10: HierTrain vs JointDNN, JointDNN+ and JALAD (8-bit
compression) across the bandwidth sweep, AlexNet and LeNet-5.

Expected qualitative shape (paper §VI-D.3): JALAD wins below ~2 Mbps on
AlexNet (compression dominates), HierTrain wins everywhere else; on
LeNet-5 the JALAD/JointDNN+ curves collapse onto All-Edge/All-Cloud.

HierTrain plans through ``repro.api``; the SOTA baselines keep their own
shortest-path schedulers (:mod:`repro.core.baselines`) evaluated on the
plan's profile/network."""
from __future__ import annotations

from benchmarks.common import BATCH, EDGE_CLOUD_SWEEP_MBPS, cnn_model, \
    table, table2_fleet
from repro.api import plan
from repro.core.baselines import jalad, jointdnn, jointdnn_plus


def run_model(model_name: str) -> list:
    model = cnn_model(model_name)
    B = BATCH[model_name]
    rows = []
    for bw in EDGE_CLOUD_SWEEP_MBPS:
        p = plan(model, table2_fleet(model_name, bw, topology="triple"), B)
        rows.append({
            "edge_cloud_mbps": bw,
            "hiertrain_s": p.t_total,
            "jointdnn_s": jointdnn(p.profile, p.network, B).t_total,
            "jointdnn+_s": jointdnn_plus(p.profile, p.network, B).t_total,
            "jalad_s": jalad(p.profile, p.network, B).t_total,
        })
    return rows


def run() -> str:
    out = []
    for name, fig in (("alexnet", "Fig.9"), ("lenet5", "Fig.10")):
        rows = run_model(name)
        out.append(table(rows, ["edge_cloud_mbps", "hiertrain_s",
                                "jointdnn_s", "jointdnn+_s", "jalad_s"],
                         f"{fig} — {name} vs JointDNN/JointDNN+/JALAD"))
    return "\n\n".join(out)


if __name__ == "__main__":
    print(run())
