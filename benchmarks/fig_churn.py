"""Elastic-fleet churn benchmark (beyond the paper — DESIGN.md §10).

The paper schedules a *static* fleet; this benchmark drives the
hierarchical trainer through a deterministic Poisson join/leave/crash/
link-fade trace on the heterogeneous M-device star fleet (M ∈ {2, 4, 8})
and measures what elasticity costs and what the warm-started re-solve
buys:

* **recovery** — simulated seconds lost to crashes (the in-flight fill
  the survivors re-run) plus the wall-clock overhead of the elastic run
  against an *oracle static* fleet that keeps the initial membership and
  never churns,
* **warm vs cold re-solve** — at every membership change the live
  schedule is remapped onto the survivors and fed to the dominance
  prune as a warm incumbent; the same membership is also solved cold,
  checking the schedules are bit-identical (the ``_warm_ok``
  certificate) and recording the measured solver seconds and prune
  counts for both,
* **crash-safe resume** — the elastic run is killed mid-flight via
  ``fail_at`` and resumed from its checkpoint; the resumed tail must be
  bitwise equal to the uninterrupted run (params and history), and the
  measured resume seconds are recorded.

``python -m benchmarks.fig_churn`` prints the tables;
``benchmarks/run.py --json`` folds :func:`run_json` into
``BENCH_sched.json`` under the ``churn`` key (deterministic fields —
traces, schedules, prune counts, simulated walls — are covered by the
``--check-schedules`` CI drift check; measured seconds are not).
"""
from __future__ import annotations

import copy
import tempfile
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import cnn_model, table, table2_fleet
from repro.api import Fleet, plan
from repro.core.churn import (apply_event, poisson_trace, reference_rows,
                              remap_schedule)
from repro.data.pipeline import SyntheticImages

SWEEP_M = (2, 4, 8)
EDGE_CLOUD_MBPS = 3.0
MODEL = "lenet5"
B = 128
STEPS = 30
FAIL_AT = 17
CKPT_EVERY = 5
# Rates tuned so every M sees a handful of events inside STEPS steps.
RATES = dict(join_rate=0.08, leave_rate=0.06, crash_rate=0.05,
             degrade_rate=0.08)


def _star_fleet(m: int) -> Fleet:
    spec = table2_fleet(MODEL, EDGE_CLOUD_MBPS, m=m, topology="star")
    model = cnn_model(MODEL)
    return Fleet.from_profile(spec.profile_for(model), spec.network())


def _replay_resolves(prof, net, trace, sched0) -> List[Dict]:
    """Re-play the trace's membership changes outside the loop, timing
    the warm-started re-solve against a cold solve of the identical
    membership and checking the argmin is bit-identical."""
    from repro.core.scheduler import _solve_multi
    prof = copy.deepcopy(prof)
    base = copy.deepcopy(prof)
    ref = reference_rows(base)
    sched = sched0
    out: List[Dict] = []
    steps = sorted({e.step for e in trace.events})
    for step in steps:
        for ev in trace.events_at(step):
            prof, base, net, _ = apply_event(prof, base, net, ref, ev)
        warm = remap_schedule(sched, prof)
        t0 = time.perf_counter()
        ws = _solve_multi(prof, net, B, warm_start=warm)
        warm_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        cold = _solve_multi(prof, net, B)
        cold_s = time.perf_counter() - t0
        sched = ws.schedule
        out.append({
            "step": step,
            "m": len(prof.worker_names) - 2,
            "warm": warm is not None,
            "candidates": cold.n_candidates,
            "pruned_warm": ws.n_pruned,
            "pruned_cold": cold.n_pruned,
            "equal": bool(ws.schedule == cold.schedule),
            "schedule": ws.schedule.describe(),
            "warm_s": warm_s,
            "cold_s": cold_s,
        })
    return out


def measure() -> Dict[str, List[Dict]]:
    rows: List[Dict] = []
    resume_rows: List[Dict] = []
    model = cnn_model(MODEL)
    for m in SWEEP_M:
        fleet = _star_fleet(m)
        prof, net = fleet.profile_for(model), fleet.network()
        data = SyntheticImages(model.input_shape, model.num_classes, B,
                               seed=0)
        trace = poisson_trace(prof.worker_names[:-2], STEPS, seed=m,
                              **RATES)
        p = plan(model, fleet, B)
        sched0 = p.schedule

        t0 = time.perf_counter()
        elastic = plan(model, fleet, B).train(data, steps=STEPS, seed=0,
                                              churn=trace)
        train_s = time.perf_counter() - t0
        static = plan(model, fleet, B).train(data, steps=STEPS, seed=0)

        resolves = _replay_resolves(prof, net, trace, sched0)
        warm_s = sum(r["warm_s"] for r in resolves)
        cold_s = sum(r["cold_s"] for r in resolves)
        rows.append({
            "M": m,
            "steps": STEPS,
            "n_events": len(trace.events),
            "events": [f"{type(e).__name__}:{e.name}@{e.step}"
                       for e in trace.events],
            "schedule_initial": sched0.describe(),
            "schedule_final": elastic["final_schedule"].describe(),
            "warm_equals_cold": all(r["equal"] for r in resolves),
            "resolves": [{k: r[k] for k in
                          ("step", "m", "warm", "candidates",
                           "pruned_warm", "pruned_cold", "schedule")}
                         for r in resolves],
            "lps_pruned_warm": sum(r["pruned_warm"] for r in resolves),
            "lps_pruned_cold": sum(r["pruned_cold"] for r in resolves),
            # simulated clocks: deterministic, drift-checked
            "wall_elastic": float(elastic["wall"]),
            "wall_static": float(static["wall"]),
            "recovery_s": float(sum(c["lost_s"]
                                    for c in elastic["churn_log"])),
            "loss_elastic": elastic["history"][-1]["loss"],
            "loss_static": static["history"][-1]["loss"],
            # measured seconds: tracked, never drift-checked
            "train_s": train_s,
            "warm_solve_s": warm_s,
            "cold_solve_s": cold_s,
            "warm_speedup": cold_s / warm_s if warm_s > 0 else 1.0,
        })

        # crash-safe resume on the same elastic run
        with tempfile.TemporaryDirectory() as d:
            from repro.train.loop import InjectedFailure
            try:
                plan(model, fleet, B).train(
                    data, steps=STEPS, seed=0, churn=trace, ckpt_dir=d,
                    ckpt_every=CKPT_EVERY, fail_at=FAIL_AT)
                raise AssertionError("fail_at never fired")
            except InjectedFailure:
                pass
            t0 = time.perf_counter()
            resumed = plan(model, fleet, B).train(
                data, steps=STEPS, seed=0, churn=trace, ckpt_dir=d,
                ckpt_every=CKPT_EVERY)
            resume_s = time.perf_counter() - t0
        bitwise = bool(all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(elastic["params"]),
                            jax.tree.leaves(resumed["params"])))
            and resumed["wall"] == elastic["wall"])
        resume_rows.append({
            "M": m,
            "fail_at": FAIL_AT,
            "resumed_from": resumed["resumed_from"],
            "bitwise_equal": bitwise,
            "resume_s": resume_s,
        })
    return {"rows": rows, "resume": resume_rows}


def run() -> str:
    out = measure()
    main = table(
        out["rows"],
        ["M", "n_events", "recovery_s", "wall_elastic", "wall_static",
         "lps_pruned_warm", "lps_pruned_cold", "warm_solve_s",
         "cold_solve_s", "warm_speedup", "warm_equals_cold"],
        f"Elastic-fleet churn — {MODEL}, B={B}, {STEPS} steps, Poisson "
        f"join/leave/crash/fade, heterogeneous fleet")
    res = table(out["resume"],
                ["M", "fail_at", "resumed_from", "bitwise_equal",
                 "resume_s"],
                "Kill/resume from checkpoint (bitwise-equal tail)")
    ev_lines = "\n".join(
        f"  M={r['M']}: {', '.join(r['events'])}" for r in out["rows"])
    return f"{main}\n\ntraces:\n{ev_lines}\n\n{res}"


def run_json() -> Dict[str, List[Dict]]:
    """The ``churn`` section of ``BENCH_sched.json``: ``rows`` (per-M
    elastic runs) and ``resume`` (kill/resume checks)."""
    return measure()


if __name__ == "__main__":
    print(run())
