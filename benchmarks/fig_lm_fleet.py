"""LM-fleet scheduling benchmark (beyond the paper — DESIGN.md §8).

Schedules small-but-shaped-like-the-real-thing LM block stacks (four
families: attention, gla/mamba2, moe, xlstm) across the M-device
mobile-edge-cloud fleet via the LayerStack adapter
(:mod:`repro.models.lm.layerstack`), for M in {1, 2, 4}, under both the
latency and the throughput objective.  Everything here is the *analytic*
path — cut-point meta, Algorithm-1 LPs, closed-form periods, DES
validation — so it is deterministic and tracked by the BENCH_sched.json
drift check.

Activations are bf16 on the wire but gradients return in f32
(``grad_bytes = 2 * act_bytes``): this is the first committed artifact to
exercise the cost model's explicit ``MG`` channel.

Workload model: each sample is a *device-resident raw payload* (audio /
image, ~2 MB) tokenized on-device — the Parallel-Split-Learning regime
(arXiv:2403.15815) where data gravity, not FLOPs alone, drives the cut.
The embed cut-point then acts as a 4x wire compressor (2 MB raw ->
T x D bf16 hidden), which is why latency-optimal schedules ship part of
the batch through an edge-resident embed front-end; the embedding-table
gradient sync (2 x MP[embed] per iteration) is what pins those splits to
the edge rather than the devices and needs a large batch to amortize
(see EXPERIMENTS.md §LM fleet).
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import MBPS, table
from repro.core.cost_model import MultiSchedule, StarNetwork, t_total_multi
from repro.core.profiler import LM_TESTBED, multi_analytic_profile
from repro.core.scheduler import solve_multi
from repro.core.simulator import simulate_iteration_multi
from repro.models.lm.layerstack import lm_layerstack
from repro.models.lm.model import LMConfig
from repro.models.lm.moe import MoEConfig
from repro.models.lm.ssm import SSMConfig
from repro.models.lm.xlstm import XLSTMConfig

SEQ_LEN = 512
BATCH = 64
M_SWEEP = (1, 2, 4)
RAW_SAMPLE_BYTES = 2e6       # on-device raw payload per sequence

# Same deterministic heterogeneity shape as the CNN fleet
# (benchmarks/common.py), on LTE/WiFi-class radios (raw payloads are MBs).
LM_FLEET_SLOWDOWNS = (1.0, 1.4, 1.9, 2.5)
LM_FLEET_UPLINK_MBPS = (50.0, 40.0, 30.0, 25.0)
LM_BACKHAUL_MBPS = 200.0

# ~120M-parameter-class stacks: big enough that cuts are non-trivial,
# small enough that the exhaustive stage-A sweep stays sub-second.
CONFIGS: Dict[str, LMConfig] = {
    "attention": LMConfig(
        name="fleet-attn", family="dense", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=1536, vocab=32_000),
    "gla": LMConfig(
        name="fleet-gla", family="zamba", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=1536, vocab=32_000,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128),
        shared_attn_every=4),
    "moe": LMConfig(
        name="fleet-moe", family="moe", n_layers=10, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=1536, vocab=32_000,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=768)),
    "xlstm": LMConfig(
        name="fleet-xlstm", family="xlstm", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=1536, vocab=32_000,
        xlstm=XLSTMConfig(n_heads=4, expand=2, slstm_every=4, chunk=128)),
}


def lm_star_network(m: int) -> StarNetwork:
    assert 1 <= m <= len(LM_FLEET_UPLINK_MBPS)
    return StarNetwork(
        bw_de=np.array(LM_FLEET_UPLINK_MBPS[:m]) * MBPS,
        bw_ec=LM_BACKHAUL_MBPS * MBPS)


def _single_worker(prof, tier: str) -> MultiSchedule:
    """All-on-one-worker baseline schedule (everything on ``tier``)."""
    m = prof.num_devices
    names = list(prof.worker_names)
    wo = tier if tier != "device" else names[0]
    rest = [w for w in names if w != wo]
    wl = rest[-1]
    return MultiSchedule(worker_o=wo, worker_l=wl,
                         s_workers=tuple(rest[:-1]), m_s=(0,) * m, m_l=0,
                         b_o=BATCH, b_s=(0,) * m, b_l=0)


def _rows() -> List[Dict]:
    rows: List[Dict] = []
    for family, cfg in CONFIGS.items():
        stack = lm_layerstack(cfg, seq_len=SEQ_LEN)
        assert cfg.dtype == jnp.bfloat16  # bf16 fwd / f32 bwd wire (MG)
        for m in M_SWEEP:
            prof = multi_analytic_profile(
                stack, LM_TESTBED, device_slowdowns=LM_FLEET_SLOWDOWNS[:m],
                sample_bytes=RAW_SAMPLE_BYTES)
            net = lm_star_network(m)
            lat = solve_multi(prof, net, BATCH, objective="latency")
            thr = solve_multi(prof, net, BATCH, objective="throughput")
            sim = simulate_iteration_multi(prof, net, lat.schedule)
            t_edge = t_total_multi(prof, net,
                                   _single_worker(prof, "edge")).total
            t_cloud = t_total_multi(prof, net,
                                    _single_worker(prof, "cloud")).total
            rows.append({
                "family": family, "M": m, "layers": prof.num_layers,
                "t_total": lat.t_total,
                "t_sim": sim,
                "sim_rel_err": abs(sim - lat.t_total) / lat.t_total,
                "t_period_lat": lat.t_period,
                "t_period_thr": thr.t_period,
                "period_gain": lat.t_period / thr.t_period,
                "speedup_all_edge": t_edge / lat.t_total,
                "speedup_all_cloud": t_cloud / lat.t_total,
                "lps_solved": lat.n_lp_solved,
                "candidates": lat.n_candidates,
                "pruned": lat.n_pruned,
                "schedule_lat": lat.schedule.describe(),
                "schedule_thr": thr.schedule.describe(),
            })
    return rows


def run() -> str:
    rows = _rows()
    out = [table(rows, ("family", "M", "layers", "t_total", "t_sim",
                        "sim_rel_err", "t_period_lat", "t_period_thr",
                        "period_gain", "speedup_all_edge",
                        "speedup_all_cloud"),
                 title=f"LM fleet (T={SEQ_LEN}, B={BATCH}, "
                       f"{RAW_SAMPLE_BYTES/1e6:.0f}MB raw samples, "
                       f"bf16 fwd / f32 bwd wire)")]
    for r in rows:
        out.append(f"  {r['family']:>9} M={r['M']}: "
                   f"lat [{r['schedule_lat']}]")
        out.append(f"  {'':>9}      thr [{r['schedule_thr']}]")
    return "\n".join(out)


def run_json() -> List[Dict]:
    return _rows()


if __name__ == "__main__":
    print(run())
