"""LM-fleet scheduling benchmark (beyond the paper — DESIGN.md §8).

Schedules small-but-shaped-like-the-real-thing LM block stacks (four
families: attention, gla/mamba2, moe, xlstm) across the M-device
mobile-edge-cloud fleet via the LayerStack adapter
(:mod:`repro.models.lm.layerstack`), for M in {1, 2, 4}, under both the
latency and the throughput objective — one ``repro.api.plan`` call per
(family, M, objective) against ``Fleet.lm_default``.  Everything here is
the *analytic* path — cut-point meta, Algorithm-1 LPs, closed-form
periods, DES validation — so it is deterministic and tracked by the
BENCH_sched.json drift check.

Activations are bf16 on the wire but gradients return in f32
(``grad_bytes = 2 * act_bytes``): this is the first committed artifact to
exercise the cost model's explicit ``MG`` channel.

Workload model: each sample is a *device-resident raw payload* (audio /
image, ~2 MB) tokenized on-device — the Parallel-Split-Learning regime
(arXiv:2403.15815) where data gravity, not FLOPs alone, drives the cut.
The embed cut-point then acts as a 4x wire compressor (2 MB raw ->
T x D bf16 hidden), which is why latency-optimal schedules ship part of
the batch through an edge-resident embed front-end; the embedding-table
gradient sync (2 x MP[embed] per iteration) is what pins those splits to
the edge rather than the devices and needs a large batch to amortize
(see EXPERIMENTS.md §LM fleet).
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp

from benchmarks.common import table
from repro.api import Fleet, plan
from repro.models.lm.layerstack import lm_layerstack
from repro.models.lm.model import LMConfig
from repro.models.lm.moe import MoEConfig
from repro.models.lm.ssm import SSMConfig
from repro.models.lm.xlstm import XLSTMConfig

SEQ_LEN = 512
BATCH = 64
M_SWEEP = (1, 2, 4)

# ~120M-parameter-class stacks: big enough that cuts are non-trivial,
# small enough that the exhaustive stage-A sweep stays sub-second.
CONFIGS: Dict[str, LMConfig] = {
    "attention": LMConfig(
        name="fleet-attn", family="dense", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=1536, vocab=32_000),
    "gla": LMConfig(
        name="fleet-gla", family="zamba", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=1536, vocab=32_000,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128),
        shared_attn_every=4),
    "moe": LMConfig(
        name="fleet-moe", family="moe", n_layers=10, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=1536, vocab=32_000,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=768)),
    "xlstm": LMConfig(
        name="fleet-xlstm", family="xlstm", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=1536, vocab=32_000,
        xlstm=XLSTMConfig(n_heads=4, expand=2, slstm_every=4, chunk=128)),
}


def _rows() -> List[Dict]:
    rows: List[Dict] = []
    for family, cfg in CONFIGS.items():
        stack = lm_layerstack(cfg, seq_len=SEQ_LEN)
        assert cfg.dtype == jnp.bfloat16  # bf16 fwd / f32 bwd wire (MG)
        for m in M_SWEEP:
            fleet = Fleet.lm_default(m=m)
            lat = plan(stack, fleet, BATCH, objective="latency")
            thr = plan(stack, fleet, BATCH, objective="throughput")
            sim = lat.simulate()
            res = lat.result
            rows.append({
                "family": family, "M": m, "layers": lat.profile.num_layers,
                "t_total": lat.t_total,
                "t_sim": sim,
                "sim_rel_err": abs(sim - lat.t_total) / lat.t_total,
                "t_period_lat": lat.t_period,
                "t_period_thr": thr.t_period,
                "period_gain": lat.t_period / thr.t_period,
                "speedup_all_edge": lat.baseline("edge") / lat.t_total,
                "speedup_all_cloud": lat.baseline("cloud") / lat.t_total,
                "lps_solved": res.n_lp_solved,
                "candidates": res.n_candidates,
                "pruned": res.n_pruned,
                "schedule_lat": lat.schedule.describe(),
                "schedule_thr": thr.schedule.describe(),
            })
    return rows


def run() -> str:
    rows = _rows()
    out = [table(rows, ("family", "M", "layers", "t_total", "t_sim",
                        "sim_rel_err", "t_period_lat", "t_period_thr",
                        "period_gain", "speedup_all_edge",
                        "speedup_all_cloud"),
                 title=f"LM fleet (T={SEQ_LEN}, B={BATCH}, "
                       f"2MB raw samples, bf16 fwd / f32 bwd wire)")]
    for r in rows:
        out.append(f"  {r['family']:>9} M={r['M']}: "
                   f"lat [{r['schedule_lat']}]")
        out.append(f"  {'':>9}      thr [{r['schedule_thr']}]")
    return "\n".join(out)


def run_json() -> List[Dict]:
    return _rows()


if __name__ == "__main__":
    print(run())
