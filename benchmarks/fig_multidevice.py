"""M-device hybrid parallelism sweep (beyond the paper — DESIGN.md §6).

The paper fixes the topology at one device + edge + cloud; this benchmark
sweeps M ∈ {1, 2, 4, 8} heterogeneous straggler devices (compute slowdowns
and uplink bandwidths from ``benchmarks.common.FLEET_*``) sharing one edge
and one cloud.  Per M it records:

* generalized Algorithm-1 scheduler runtime (stage-A sweep + per-device
  cut refinement) and LP counts,
* the predicted iteration time ``T_total`` and the DES-simulated makespan
  (model validity must hold at M > 1 too — the Fig.-6 check generalized),
* speedup over the All-Edge / All-Cloud baselines evaluated on the same
  M-device cost model.

``python -m benchmarks.fig_multidevice`` prints the table;
``benchmarks/run.py --json`` folds :func:`run_json` into
``BENCH_sched.json`` with each record stamped with M.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import (BATCH, fleet_profile, star_network, table)
from repro.core.cost_model import (MultiProfile, MultiSchedule, StarNetwork,
                                   t_total_multi)
from repro.core.scheduler import solve_multi
from repro.core.simulator import simulate_iteration_multi

SWEEP_M = (1, 2, 4, 8)
EDGE_CLOUD_MBPS = 3.0
MODEL = "lenet5"


def _all_on(profile: MultiProfile, net: StarNetwork, B: int,
            worker: str) -> float:
    """All-Edge / All-Cloud baseline on the M-device cost model: the whole
    batch uploaded to one worker that trains the full model alone."""
    other = "cloud" if worker == "edge" else "edge"
    sched = MultiSchedule(
        worker_o=worker, worker_l=other, s_workers=profile.device_names,
        m_s=(0,) * profile.num_devices, m_l=0, b_o=B,
        b_s=(0,) * profile.num_devices, b_l=0)
    return t_total_multi(profile, net, sched).total


def measure() -> List[Dict]:
    rows: List[Dict] = []
    B = BATCH[MODEL]
    for m in SWEEP_M:
        profile = fleet_profile(MODEL, m)
        net = star_network(m, EDGE_CLOUD_MBPS)
        t0 = time.perf_counter()
        res = solve_multi(profile, net, B)
        dt = time.perf_counter() - t0
        sim = simulate_iteration_multi(profile, net, res.schedule)
        t_edge = _all_on(profile, net, B, "edge")
        t_cloud = _all_on(profile, net, B, "cloud")
        rows.append({
            "M": m,
            "sched_s": dt,
            "lps_solved": res.n_lp_solved,
            "candidates": res.n_candidates,
            "pruned": res.n_pruned,
            "lps_refine": res.n_lp_refine,
            "refine_rounds": res.refine_rounds,
            "t_total": res.t_total,
            "t_sim": sim,
            "sim_rel_err": abs(sim - res.t_total) / res.t_total,
            "speedup_all_edge": t_edge / res.t_total,
            "speedup_all_cloud": t_cloud / res.t_total,
            "schedule": res.schedule.describe(),
        })
    return rows


def run() -> str:
    rows = measure()
    out = table(rows, ["M", "sched_s", "lps_solved", "pruned",
                       "lps_refine", "refine_rounds", "t_total", "t_sim",
                       "sim_rel_err",
                       "speedup_all_edge", "speedup_all_cloud"],
                f"M-device sweep — {MODEL}, B={BATCH[MODEL]}, "
                f"edge-cloud {EDGE_CLOUD_MBPS} Mbps, heterogeneous fleet")
    sched_lines = "\n".join(f"  M={r['M']}: {r['schedule']}" for r in rows)
    return f"{out}\n\nchosen schedules:\n{sched_lines}"


def run_json() -> List[Dict]:
    """Rows for the ``multidevice`` section of ``BENCH_sched.json``; every
    record carries its device count M (the sweep dimension) and its chosen
    schedule (covered by the CI drift check)."""
    return measure()


if __name__ == "__main__":
    print(run())
