"""M-device hybrid parallelism sweep (beyond the paper — DESIGN.md §6).

The paper fixes the topology at one device + edge + cloud; this benchmark
sweeps M ∈ {1, 2, 4, 8} heterogeneous straggler devices (compute slowdowns
and uplink bandwidths from ``repro.core.fleet.FLEET_*``) sharing one edge
and one cloud.  Per M it records:

* generalized Algorithm-1 scheduler runtime (stage-A sweep + per-device
  cut refinement) and LP counts,
* the predicted iteration time ``T_total`` and the DES-simulated makespan
  (model validity must hold at M > 1 too — the Fig.-6 check generalized),
* speedup over the All-Edge / All-Cloud baselines evaluated on the same
  M-device cost model (``Plan.baseline``).

Planned through ``repro.api`` on star-native fleets
(``topology="star"`` even at M = 1, so the whole sweep runs one stack).

``python -m benchmarks.fig_multidevice`` prints the table;
``benchmarks/run.py --json`` folds :func:`run_json` into
``BENCH_sched.json`` with each record stamped with M.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import BATCH, cnn_model, table, table2_fleet
from repro.api import Fleet, plan

SWEEP_M = (1, 2, 4, 8)
EDGE_CLOUD_MBPS = 3.0
MODEL = "lenet5"


def measure() -> List[Dict]:
    rows: List[Dict] = []
    B = BATCH[MODEL]
    model = cnn_model(MODEL)
    for m in SWEEP_M:
        spec = table2_fleet(MODEL, EDGE_CLOUD_MBPS, m=m, topology="star")
        # Pin the profile outside the timer so sched_s keeps measuring
        # the Algorithm-1 search alone, comparable with prior BENCH
        # records (profiling is not the tracked metric).
        fleet = Fleet.from_profile(spec.profile_for(model), spec.network())
        t0 = time.perf_counter()
        p = plan(model, fleet, B)
        dt = time.perf_counter() - t0
        res = p.result
        sim = p.simulate()
        rows.append({
            "M": m,
            "sched_s": dt,
            "lps_solved": res.n_lp_solved,
            "candidates": res.n_candidates,
            "pruned": res.n_pruned,
            "lps_refine": res.n_lp_refine,
            "refine_rounds": res.refine_rounds,
            "t_total": res.t_total,
            "t_sim": sim,
            "sim_rel_err": abs(sim - res.t_total) / res.t_total,
            "speedup_all_edge": p.baseline("edge") / res.t_total,
            "speedup_all_cloud": p.baseline("cloud") / res.t_total,
            "schedule": res.schedule.describe(),
        })
    return rows


def run() -> str:
    rows = measure()
    out = table(rows, ["M", "sched_s", "lps_solved", "pruned",
                       "lps_refine", "refine_rounds", "t_total", "t_sim",
                       "sim_rel_err",
                       "speedup_all_edge", "speedup_all_cloud"],
                f"M-device sweep — {MODEL}, B={BATCH[MODEL]}, "
                f"edge-cloud {EDGE_CLOUD_MBPS} Mbps, heterogeneous fleet")
    sched_lines = "\n".join(f"  M={r['M']}: {r['schedule']}" for r in rows)
    return f"{out}\n\nchosen schedules:\n{sched_lines}"


def run_json() -> List[Dict]:
    """Rows for the ``multidevice`` section of ``BENCH_sched.json``; every
    record carries its device count M (the sweep dimension) and its chosen
    schedule (covered by the CI drift check)."""
    return measure()


if __name__ == "__main__":
    print(run())
