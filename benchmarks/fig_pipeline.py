"""Pipelined steady-state sweep (beyond the paper — DESIGN.md §7).

The paper (and ``fig7_8_speedup``) scores one iteration in isolation;
this benchmark measures what hybrid parallelism buys once consecutive
minibatches are *pipelined*.  Two sections, both planned through
``repro.api`` (latency- vs throughput-objective plans):

* **Table II profiles** (3-worker, synthetic N-layer networks) — for each
  network, the latency-optimal vs throughput-optimal plan, their
  steady-state periods ``t_period``, the DES-measured period
  (``Plan.simulate(K)`` slope — model validity), and the depth-K
  wall-clock ``T(K)`` speedup of pipelined execution over K barrier
  iterations.  Pinned-profile triple fleets: the paper's exact stack.
* **M-device fleet** (the ``fig_multidevice`` fleet, M ∈ {1, 2, 4, 8}) —
  the same comparison on star fleets, where throughput-optimal schedules
  genuinely diverge from latency-optimal ones (the recurrence bound
  punishes round-trip-heavy cuts).

``python -m benchmarks.fig_pipeline`` prints the tables;
``benchmarks/run.py --json`` folds :func:`run_json` into
``BENCH_sched.json`` (deterministic schedule/period fields are covered by
the CI drift check).
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import BATCH, cnn_model, network, table, \
    table2_fleet
from benchmarks.table2_sched_runtime import synthetic_profile
from repro.api import Fleet, plan
from repro.core.pipeline import t_period_breakdown

NETS = {"lenet5": 5, "alexnet": 8, "vgg16": 16}
SWEEP_M = (1, 2, 4, 8)
SWEEP_K = (1, 2, 4, 8, 16)
EDGE_CLOUD_MBPS = 3.0
MODEL = "lenet5"
K_MEASURE = (32, 64)        # DES period = slope of T(K) between these


def _des_period(p) -> float:
    k0, k1 = K_MEASURE
    return (p.simulate(K=k1) - p.simulate(K=k0)) / (k1 - k0)


def measure_table2() -> List[Dict]:
    rows: List[Dict] = []
    for name, n in NETS.items():
        fleet = Fleet.from_profile(synthetic_profile(n),
                                   network(EDGE_CLOUD_MBPS))
        t0 = time.perf_counter()
        lat = plan(None, fleet, B=64)
        thr = plan(None, fleet, B=64, objective="throughput")
        dt = time.perf_counter() - t0
        des = _des_period(thr)
        k = SWEEP_K[-1]
        barrier_k = k * lat.t_total
        pipe_k = thr.pipeline_time(k)
        rows.append({
            "network": name, "layers": n, "M": 1, "sched_s": dt,
            "pipeline_depth": k,
            "t_total_lat": lat.t_total,
            "t_period_lat": lat.t_period,
            "t_period_thr": thr.t_period,
            "t_period_des": des,
            "period_rel_err": abs(des - thr.t_period) / thr.t_period,
            "bottleneck": t_period_breakdown(thr.profile, thr.network,
                                             thr.schedule)["bottleneck"],
            "speedup_pipelined": barrier_k / pipe_k,
            "schedule_lat": lat.schedule.describe(),
            "schedule_thr": thr.schedule.describe(),
        })
    return rows


def measure_fleet() -> List[Dict]:
    rows: List[Dict] = []
    B = BATCH[MODEL]
    model = cnn_model(MODEL)
    for m in SWEEP_M:
        spec = table2_fleet(MODEL, EDGE_CLOUD_MBPS, m=m, topology="star")
        # profile pinned outside the timer: sched_s tracks the search
        # alone, comparable with prior BENCH records
        fleet = Fleet.from_profile(spec.profile_for(model), spec.network())
        t0 = time.perf_counter()
        lat = plan(model, fleet, B)
        thr = plan(model, fleet, B, objective="throughput")
        dt = time.perf_counter() - t0
        des = _des_period(thr)
        k = SWEEP_K[-1]
        barrier_k = k * lat.t_total
        pipe_k = thr.pipeline_time(k)
        rows.append({
            "M": m, "sched_s": dt,
            "pipeline_depth": k,
            "t_total_lat": lat.t_total,
            "t_period_lat": lat.t_period,
            "t_period_thr": thr.t_period,
            "t_period_des": des,
            "period_rel_err": abs(des - thr.t_period) / thr.t_period,
            "period_gain": lat.t_period / thr.t_period,
            "speedup_pipelined": barrier_k / pipe_k,
            "schedule_lat": lat.schedule.describe(),
            "schedule_thr": thr.schedule.describe(),
            "_plan_thr": thr,               # Plan object, stripped from JSON
        })
    return rows


def run() -> str:
    t2 = measure_table2()
    fl = measure_fleet()
    out = [table(t2, ["network", "layers", "t_total_lat", "t_period_lat",
                      "t_period_thr", "t_period_des", "period_rel_err",
                      "bottleneck", "speedup_pipelined"],
                 f"Pipelined steady state — Table II profiles, B=64, "
                 f"edge-cloud {EDGE_CLOUD_MBPS} Mbps, K={SWEEP_K[-1]}"),
           "",
           table(fl, ["M", "t_total_lat", "t_period_lat", "t_period_thr",
                      "t_period_des", "period_rel_err", "period_gain",
                      "speedup_pipelined"],
                 f"Pipelined steady state — {MODEL} fleet, B={BATCH[MODEL]}, "
                 f"M sweep, K={SWEEP_K[-1]}"),
           "", "throughput-optimal schedules:"]
    out += [f"  {r['network']}: {r['schedule_thr']}" for r in t2]
    out += [f"  M={r['M']}: {r['schedule_thr']}" for r in fl]
    # depth sweep on the largest fleet: model vs simulated wall clock
    # (reuse the plan measure_fleet already solved)
    thr = fl[-1]["_plan_thr"]
    out.append(f"\nT(K) on the M={SWEEP_M[-1]} throughput schedule "
               f"(model | DES):")
    for kk in SWEEP_K:
        out.append(f"  K={kk:>2}: {thr.pipeline_time(kk):.3f}"
                   f" | {thr.simulate(K=kk):.3f}")
    return "\n".join(out)


def run_json() -> Dict[str, List[Dict]]:
    """Rows for the ``pipeline`` section of ``BENCH_sched.json``
    (``_``-prefixed keys hold Plan objects and are stripped)."""
    return {"table2": measure_table2(),
            "fleet": [{k: v for k, v in r.items()
                       if not k.startswith("_")}
                      for r in measure_fleet()]}


if __name__ == "__main__":
    print(run())
