"""Cross-fleet planner throughput (beyond the paper; DESIGN.md §13).

The serving question: a population of ~1000 client fleets — four device
families, each a finite catalog of perturbed device classes
(:mod:`repro.serve.population`) — asks for plans.  Three measurements:

* **plans/sec batched** — one :class:`repro.serve.planner.Planner`
  resolving the whole population: fingerprint cache + shape-bucketed
  ``solve_many`` tableau stacks.
* **plans/sec per-fleet loop** — the pre-planner baseline
  (``api.plan`` per request), timed on a stratified per-family
  subsample and extrapolated to the full population (the full loop is
  minutes; the subsample is documented in the JSON payload).
* **cache-hit latency** — p50/p99 of single-request ``plan_many``
  calls against the warm cache.

Deterministic per-family rows (population composition, class counts,
distinct chosen schedules, modal schedule, cold hit rate) are guarded
by the BENCH drift check; timings ride only on full ``--json`` runs.
"""
from __future__ import annotations

import time
from collections import Counter
from typing import Dict, List

import numpy as np

from benchmarks.common import table

POP_N = 1024          # >= 1000 perturbed fleets (ISSUE 9 acceptance)
POP_SEED = 0
BASELINE_SAMPLE = 10  # per-family api.plan solves for the loop baseline
HIT_SAMPLE = 200      # warm single-request latency probes
MIN_SPEEDUP = 5.0     # acceptance floor: batched vs per-fleet loop


def _family_of(tag: str) -> str:
    return tag.split("/", 1)[0]


def _class_of(tag: str) -> str:
    return tag.split("/")[1]


def measure(include_timing: bool = True) -> Dict:
    from repro.api import plan
    from repro.serve.planner import Planner
    from repro.serve.population import synthetic_population

    reqs = synthetic_population(n=POP_N, seed=POP_SEED)
    planner = Planner()
    t0 = time.perf_counter()
    plans = planner.plan_many(reqs)
    cold_s = time.perf_counter() - t0
    cold_stats = planner.stats()

    # ---- deterministic per-family rows ---------------------------------
    rows: List[Dict] = []
    by_family: "dict[str, list]" = {}
    for r, p in zip(reqs, plans):
        by_family.setdefault(_family_of(r.tag), []).append((r, p))
    for family, pairs in by_family.items():
        classes = len({_class_of(r.tag) for r, _ in pairs})
        scheds = Counter(p.result.schedule.describe() for _, p in pairs)
        prof = pairs[0][1].profile
        rows.append({
            "family": family,
            "n_fleets": len(pairs),
            "M": getattr(prof, "num_devices", 1),
            "E": 1,
            "layers": prof.num_layers,
            "classes": classes,
            "distinct_schedules": len(scheds),
            "schedule_mode": scheds.most_common(1)[0][0],
            # Identical fleets within a class make every non-first
            # request of a class a cache hit on the cold pass.
            "hit_rate_cold": 1.0 - classes / len(pairs),
        })

    payload: Dict = {
        "benchmark": "fig_planner",
        "n_fleets": POP_N,
        "seed": POP_SEED,
        "rows": rows,
        "cache": {"hits": cold_stats["hits"],
                  "misses": cold_stats["misses"],
                  "hit_rate": cold_stats["hit_rate"],
                  "pad_waste": cold_stats["pad_waste"],
                  "lp_calls": cold_stats["lp_calls"]},
    }
    if not include_timing:
        return payload

    # ---- per-fleet loop baseline (stratified subsample, extrapolated) --
    baseline_s = 0.0
    for family, pairs in by_family.items():
        sample = pairs[:BASELINE_SAMPLE]
        t0 = time.perf_counter()
        for r, p in sample:
            ref = plan(r.model, r.fleet, r.B, objective=r.objective)
            assert ref.result.schedule == p.result.schedule, \
                f"planner diverged from api.plan on {r.tag}"
        dt = time.perf_counter() - t0
        baseline_s += dt / len(sample) * len(pairs)

    # ---- warm cache-hit latency ----------------------------------------
    stride = max(1, len(reqs) // HIT_SAMPLE)
    probes = reqs[::stride][:HIT_SAMPLE]
    lat_us = []
    for r in probes:
        t0 = time.perf_counter()
        planner.plan_many([r])
        lat_us.append((time.perf_counter() - t0) * 1e6)
    lat = np.asarray(lat_us)

    speedup = baseline_s / cold_s
    assert speedup >= MIN_SPEEDUP, \
        (f"batched planner only {speedup:.1f}x over the per-fleet loop "
         f"(floor {MIN_SPEEDUP}x)")
    payload.update({
        "cold_s": cold_s,
        "plans_per_s": POP_N / cold_s,
        "baseline_sample_per_family": BASELINE_SAMPLE,
        "baseline_s_extrapolated": baseline_s,
        "speedup_vs_loop": speedup,
        "hit_p50_us": float(np.percentile(lat, 50)),
        "hit_p99_us": float(np.percentile(lat, 99)),
        "hit_probes": len(probes),
    })
    return payload


def run() -> str:
    payload = measure()
    out = table(payload["rows"],
                ["family", "n_fleets", "M", "layers", "classes",
                 "distinct_schedules", "hit_rate_cold"],
                f"Cross-fleet planner — {POP_N} perturbed fleets, "
                f"seed {POP_SEED}")
    c = payload["cache"]
    lines = [
        out, "",
        f"cold pass: {payload['cold_s']:.2f}s "
        f"({payload['plans_per_s']:.0f} plans/s), cache hit rate "
        f"{c['hit_rate']:.3f} ({c['hits']} hits / {c['misses']} misses), "
        f"pad waste {c['pad_waste']:.4f}",
        f"per-fleet loop (extrapolated from {payload['baseline_sample_per_family']}"
        f"/family): {payload['baseline_s_extrapolated']:.1f}s -> "
        f"{payload['speedup_vs_loop']:.1f}x speedup",
        f"cache-hit latency: p50 {payload['hit_p50_us']:.0f}us / "
        f"p99 {payload['hit_p99_us']:.0f}us over "
        f"{payload['hit_probes']} probes",
    ]
    return "\n".join(lines)


def run_json(include_timing: bool = True) -> Dict:
    """Payload for BENCH_sched.json; ``include_timing=False`` keeps only
    the deterministic fields (the CI drift-check mode)."""
    return measure(include_timing=include_timing)


if __name__ == "__main__":
    print(run())
