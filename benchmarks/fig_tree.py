"""Multi-edge tree sweep (beyond the paper — DESIGN.md §12).

The M-device star benchmark (``fig_multidevice``) keeps every device
behind one edge server.  This sweep partitions the same heterogeneous
fleets across E ∈ {1, 2, 4} edge servers, each with its own backhaul to
one cloud, and lets the tree scheduler assign per-edge cuts.  Per
(model, E) it records the generalized Algorithm-1 search cost, the
predicted ``T_total`` against the DES makespan (model validity at
E > 1), and the speedup over the best single-edge star plan of the same
fleet (the E=1 row — partitioning can also *lose* when it pushes
same-cut streams behind foreign backhauls, which the lenet5 rows show
honestly).

Planned through ``repro.api`` on tree-native fleets (``topology="tree"``
even at E = 1, so the whole sweep runs one stack; the E = 1 plan is
bit-identical to the star plan by the nativity-reduction tests).

``python -m benchmarks.fig_tree`` prints the table;
``benchmarks/run.py --json`` folds :func:`run_json` into
``BENCH_sched.json`` with each record stamped with (model, M, E).
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import BATCH, cnn_model, table, table2_fleet
from repro.api import Fleet, plan

SWEEP_E = (1, 2, 4)
# (model, M): lenet5 uses the full 8-straggler fleet, alexnet the first 4.
CONFIGS = (("lenet5", 8), ("alexnet", 4))
EDGE_CLOUD_MBPS = 2.0


def measure() -> List[Dict]:
    rows: List[Dict] = []
    for model_name, m in CONFIGS:
        B = BATCH[model_name]
        model = cnn_model(model_name)
        star_t = None
        for e in SWEEP_E:
            spec = table2_fleet(model_name, EDGE_CLOUD_MBPS, m=m,
                                topology="tree", n_edges=e)
            # Pin the profile outside the timer so sched_s measures the
            # per-edge Algorithm-1 search alone (comparable with the
            # fig_multidevice records; profiling is not tracked).
            fleet = Fleet.from_profile(spec.profile_for(model),
                                       spec.network())
            t0 = time.perf_counter()
            p = plan(model, fleet, B)
            dt = time.perf_counter() - t0
            res = p.result
            sim = p.simulate()
            if e == 1:
                star_t = res.t_total       # the best single-edge star plan
            rows.append({
                "model": model_name,
                "M": m,
                "E": e,
                "sched_s": dt,
                "lps_solved": res.n_lp_solved,
                "candidates": res.n_candidates,
                "pruned": res.n_pruned,
                "t_total": res.t_total,
                "t_sim": sim,
                "sim_rel_err": abs(sim - res.t_total) / res.t_total,
                "speedup_vs_star": star_t / res.t_total,
                "schedule": res.schedule.describe(),
            })
    return rows


def run() -> str:
    rows = measure()
    out = table(rows, ["model", "M", "E", "sched_s", "lps_solved",
                       "pruned", "t_total", "t_sim", "sim_rel_err",
                       "speedup_vs_star"],
                f"multi-edge tree sweep — backhaul {EDGE_CLOUD_MBPS} Mbps "
                f"per edge, heterogeneous fleets")
    sched_lines = "\n".join(
        f"  {r['model']} E={r['E']}: {r['schedule']}" for r in rows)
    return f"{out}\n\nchosen schedules:\n{sched_lines}"


def run_json() -> List[Dict]:
    """Rows for the ``tree`` section of ``BENCH_sched.json``; every record
    carries its fleet (model, M) and edge count E (the sweep dimensions)
    and its chosen schedule (covered by the CI drift check)."""
    return measure()


if __name__ == "__main__":
    print(run())
