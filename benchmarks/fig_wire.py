"""Wire-compression benchmark (beyond the paper — DESIGN.md §11).

Two halves, mirroring :mod:`repro.core.wire` itself:

* **Planning (deterministic, drift-checked)** — for the attention and
  gla LM fleets of :mod:`benchmarks.fig_lm_fleet` at M in {1, 2, 4},
  plan the same workload with ``wire="none"`` and ``wire="int8"`` and
  record how the latency-optimal schedule moves.  An int8 wire shrinks
  the forward (bf16) crossing ~2x and the backward (f32) crossing ~4x,
  so split-point traffic stops dominating and the optimizer pushes the
  cuts deeper / rebalances the batch — the arXiv:2403.15815 effect, now
  visible to Algorithm 1 because ``apply_wire`` rewrites the ``MO``/
  ``MG`` columns every LP reads.

* **Execution (timed, not drift-checked)** — step-time of a tiny
  executable zamba stack (both Pallas kernels on its path) under
  wire x backend, on a fixed offloading schedule.  On CPU CI the Pallas
  path runs in interpret mode, so these timings are shape checks, not
  speedups; the accelerator story is the roofline report's job.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import table
from benchmarks.fig_lm_fleet import BATCH, CONFIGS, M_SWEEP, SEQ_LEN
from repro.api import Fleet, plan
from repro.core.hybrid_step import jitted_hybrid_step, split_batch
from repro.core.cost_model import Schedule
from repro.models.lm.layerstack import lm_layerstack
from repro.models.lm.model import LMConfig
from repro.models.lm.ssm import SSMConfig

FAMILIES = ("attention", "gla")

# Executable stack for the step-time half: zamba so one model exercises
# both kernels (mamba2 -> gla_scan, shared attn -> flash_attention).
EXEC_CFG = LMConfig(
    name="wire-exec", family="zamba", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
    shared_attn_every=1, dtype=jnp.float32)
EXEC_SEQ = 32
EXEC_BATCH = 18
EXEC_STEPS = 3


def _cuts(sched) -> tuple:
    return (tuple(sched.m_s), sched.m_l)


def _rows() -> List[Dict]:
    rows: List[Dict] = []
    for family in FAMILIES:
        stack = lm_layerstack(CONFIGS[family], seq_len=SEQ_LEN)
        for m in M_SWEEP:
            fleet = Fleet.lm_default(m=m)
            p0 = plan(stack, fleet, BATCH, objective="latency")
            p1 = plan(stack, fleet, BATCH, objective="latency",
                      wire="int8")
            rows.append({
                "family": family, "M": m,
                "layers": p0.profile.num_layers,
                "t_total_none": p0.t_total,
                "t_total_int8": p1.t_total,
                "wire_gain": p0.t_total / p1.t_total,
                # embed-cut compression ratios (bf16 fwd / f32 bwd)
                "mo_ratio": float(p1.profile.MO[0] / p0.profile.MO[0]),
                "mg_ratio": float(p1.profile.MG[0] / p0.profile.MG[0]),
                "cut_shifted": _cuts(p1.schedule) != _cuts(p0.schedule),
                "schedule_none": p0.schedule.describe(),
                "schedule_int8": p1.schedule.describe(),
            })
    return rows


def _exec_rows() -> List[Dict]:
    sched = Schedule(worker_o="edge", worker_s="device", worker_l="cloud",
                     m_s=2, m_l=4, b_o=6, b_s=6, b_l=6)
    key = jax.random.PRNGKey(0)
    rows: List[Dict] = []
    for backend in ("ref", "pallas"):
        stack = lm_layerstack(EXEC_CFG, seq_len=EXEC_SEQ, backend=backend)
        x, y = stack.dummy_batch(jax.random.fold_in(key, 1), EXEC_BATCH)
        batches = split_batch(x, y, sched)
        for wire in ("none", "int8"):
            step = jitted_hybrid_step(stack, sched.m_s, sched.m_l, 0.05,
                                      wire=wire)
            params = stack.init(key)
            params, loss = step(params, batches)      # compile + warm
            jax.block_until_ready(loss)
            t0 = time.perf_counter()
            for _ in range(EXEC_STEPS):
                params, loss = step(params, batches)
            jax.block_until_ready(loss)
            rows.append({
                "backend": backend, "wire": wire,
                "step_ms": (time.perf_counter() - t0) / EXEC_STEPS * 1e3,
                "final_loss": float(loss),
            })
    return rows


def run() -> str:
    rows = _rows()
    out = [table(rows, ("family", "M", "layers", "t_total_none",
                        "t_total_int8", "wire_gain", "mo_ratio",
                        "mg_ratio", "cut_shifted"),
                 title=f"Wire compression: int8 cut-point transfers "
                       f"(T={SEQ_LEN}, B={BATCH})")]
    for r in rows:
        out.append(f"  {r['family']:>9} M={r['M']}: "
                   f"none [{r['schedule_none']}]")
        out.append(f"  {'':>9}      int8 [{r['schedule_int8']}]")
    ex = _exec_rows()
    out.append(table(ex, ("backend", "wire", "step_ms", "final_loss"),
                     title=f"Executable zamba step (T={EXEC_SEQ}, "
                           f"B={EXEC_BATCH}; CPU interpret mode — "
                           f"shape check, not a speedup claim)"))
    return "\n".join(out)


def run_json(include_exec: bool = True) -> Dict[str, List[Dict]]:
    payload: Dict[str, List[Dict]] = {"rows": _rows()}
    if include_exec:
        payload["exec"] = _exec_rows()
    return payload


if __name__ == "__main__":
    print(run())
