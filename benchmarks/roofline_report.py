"""§Roofline report: renders the dry-run JSON (written by
``repro.launch.dryrun --out``) as the per-(arch x shape) roofline table
for EXPERIMENTS.md.  Pure post-processing — no jax device state, so it
can run inside the normal 1-device benchmark process."""
from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import table

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")


def run(path: str = RESULTS, mesh_filter: str = "single") -> str:
    if not os.path.exists(path):
        return ("(dry-run results not found — run `python -m "
                "repro.launch.dryrun --mesh both --out "
                "dryrun_results.json` first)")
    with open(path) as f:
        results = json.load(f)
    rows: List[dict] = []
    skips: List[dict] = []
    for r in results:
        if r.get("status") == "SKIP":
            skips.append({"arch": r["arch"], "shape": r["shape"],
                          "reason": r["reason"][:60] + "..."})
            continue
        if r.get("status") != "OK":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": "?", "dominant": "FAIL",
                         "compute_s": float("nan"),
                         "memory_s": float("nan"),
                         "collective_s": float("nan"),
                         "useful": float("nan"), "peak_gb": float("nan"),
                         "frac": float("nan")})
            continue
        is_multi = "pod" in r["mesh"]
        if mesh_filter == "single" and is_multi:
            continue
        if mesh_filter == "multi" and not is_multi:
            continue
        roof = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "mesh": "x".join(str(v) for v in r["mesh"].values()),
            "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
            "collective_s": roof["collective_s"],
            "dominant": roof["dominant"],
            "useful": roof["useful_ratio"],
            "frac": roof["roofline_fraction"],
            "peak_gb": r["memory"]["peak_gb"],
        })
    out = [table(rows, ["arch", "shape", "mesh", "compute_s", "memory_s",
                        "collective_s", "dominant", "useful", "frac",
                        "peak_gb"],
                 f"Roofline terms per (arch x shape), {mesh_filter}-pod "
                 "mesh")]
    if skips:
        out.append(table(skips, ["arch", "shape", "reason"],
                         "Skipped cells"))
    return "\n\n".join(out)


if __name__ == "__main__":
    print(run())
