"""Benchmark orchestrator: one section per paper table/figure, plus the
roofline report if dry-run results exist.  ``python -m benchmarks.run``."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig6_model_validity, fig7_8_speedup,
                            fig9_10_sota, fig11_edge_cpu, roofline_report,
                            table2_sched_runtime)
    sections = [
        ("Fig.6 model validity", fig6_model_validity.run),
        ("Fig.7/8 vs All-Edge/All-Cloud", fig7_8_speedup.run),
        ("Fig.9/10 vs JointDNN/JointDNN+/JALAD", fig9_10_sota.run),
        ("Fig.11 edge CPU scaling", fig11_edge_cpu.run),
        ("Table II scheduler runtime", table2_sched_runtime.run),
        ("Roofline report (from dry-run)", roofline_report.run),
    ]
    failures = 0
    for name, fn in sections:
        t0 = time.perf_counter()
        print(f"\n{'='*72}\n== {name}\n{'='*72}")
        try:
            print(fn())
            print(f"-- done in {time.perf_counter() - t0:.1f}s")
        except Exception as e:                      # pragma: no cover
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"-- FAILED: {e}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
