"""Benchmark orchestrator: one section per paper table/figure, plus the
roofline report if dry-run results exist.  ``python -m benchmarks.run``.

``--json [PATH]`` switches to perf-tracking mode: instead of printing every
section it re-times the Table II scheduler search with both backends
(reference scalar simplex vs batched engine) plus the M-device sweep
(``benchmarks/fig_multidevice``) and writes the runtimes and speedups to
``BENCH_sched.json`` (or PATH), so the scheduler-engine perf trajectory is
tracked across PRs.  Every record is stamped with the git SHA and its
device count M.
"""
from __future__ import annotations

import argparse
import sys
import time


def run_sections() -> int:
    from benchmarks import (fig6_model_validity, fig7_8_speedup,
                            fig9_10_sota, fig11_edge_cpu, fig_multidevice,
                            roofline_report, table2_sched_runtime)
    sections = [
        ("Fig.6 model validity", fig6_model_validity.run),
        ("Fig.7/8 vs All-Edge/All-Cloud", fig7_8_speedup.run),
        ("Fig.9/10 vs JointDNN/JointDNN+/JALAD", fig9_10_sota.run),
        ("Fig.11 edge CPU scaling", fig11_edge_cpu.run),
        ("Table II scheduler runtime", table2_sched_runtime.run),
        ("M-device sweep (beyond the paper)", fig_multidevice.run),
        ("Roofline report (from dry-run)", roofline_report.run),
    ]
    failures = 0
    for name, fn in sections:
        t0 = time.perf_counter()
        print(f"\n{'='*72}\n== {name}\n{'='*72}")
        try:
            print(fn())
            print(f"-- done in {time.perf_counter() - t0:.1f}s")
        except Exception as e:                      # pragma: no cover
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"-- FAILED: {e}")
    return 1 if failures else 0


def run_sched_json(path: str) -> int:
    from benchmarks import fig_multidevice, table2_sched_runtime
    from benchmarks.common import write_json
    payload = table2_sched_runtime.run_json()
    payload["multidevice"] = fig_multidevice.run_json()
    write_json(path, payload)
    rows = payload["rows"]
    print(f"wrote {path}")
    for r in rows:
        print(f"  {r['network']:>10} (N={r['layers']:>2}): "
              f"reference {r['reference_s']:.3f}s -> "
              f"batched {r['batched_s']:.3f}s "
              f"({r['speedup']:.1f}x, {r['pruned']} of "
              f"{r['candidates']} LPs pruned)")
    print(f"  min speedup for N >= 16: "
          f"{payload['min_speedup_n_ge_16']:.1f}x")
    for r in payload["multidevice"]:
        print(f"  M={r['M']}: sched {r['sched_s']*1e3:.0f}ms "
              f"T_total {r['t_total']:.3f}s sim {r['t_sim']:.3f}s "
              f"(rel err {r['sim_rel_err']:.1%}) "
              f"speedup vs all-edge {r['speedup_all_edge']:.2f}x "
              f"/ all-cloud {r['speedup_all_cloud']:.2f}x")
    return 0


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_sched.json",
                        default=None, metavar="PATH",
                        help="write reference-vs-batched Table II scheduler "
                             "runtimes to PATH (default BENCH_sched.json) "
                             "instead of running every section")
    args = parser.parse_args()
    if args.json is not None:
        sys.exit(run_sched_json(args.json))
    sys.exit(run_sections())


if __name__ == "__main__":
    main()
