"""Benchmark orchestrator: one section per paper table/figure, plus the
roofline report if dry-run results exist.  ``python -m benchmarks.run``.

``--section NAME`` restricts any mode to one section (see ``--help`` for
the section names): alone it runs just that section's report; with
``--json`` it recomputes only that section's subtree and merges it into
the existing artifact; with ``--check-schedules`` it drift-checks only
that section's deterministic fields.

``--json [PATH]`` switches to perf-tracking mode: instead of printing every
section it re-times the Table II scheduler search with both backends
(reference scalar simplex vs batched engine) plus the M-device sweep
(``benchmarks/fig_multidevice``), the multi-edge tree sweep
(``benchmarks/fig_tree``), the pipelined steady-state sweep
(``benchmarks/fig_pipeline``), the LM-fleet LayerStack sweep
(``benchmarks/fig_lm_fleet``), the elastic-fleet churn benchmark
(``benchmarks/fig_churn``), the wire-compression sweep
(``benchmarks/fig_wire``) and the cross-fleet planner benchmark
(``benchmarks/fig_planner``), and writes runtimes, speedups, periods and
the chosen schedules to ``BENCH_sched.json`` (or PATH), so the
scheduler-engine perf trajectory is tracked across PRs.  Every record is
stamped with the git SHA (``+dirty`` when regenerated before the commit it
describes) and its device count M.

``--check-schedules [PATH]`` recomputes only the *deterministic* fields
(schedules, exact costs, LP/prune counts — never timings) and fails when
they drift from the committed artifact: CI runs this so a scheduler-
behavior change can't land without regenerating ``BENCH_sched.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

# Deterministic (timing-free) fields per BENCH_sched.json section: the
# surface the drift check guards.
_DET_KEYS = {
    "rows": ("network", "layers", "M", "lps_solved", "candidates",
             "pruned", "t_total", "schedule"),
    "multidevice": ("M", "lps_solved", "candidates", "pruned",
                    "lps_refine", "refine_rounds", "t_total", "t_sim",
                    "sim_rel_err", "speedup_all_edge", "speedup_all_cloud",
                    "schedule"),
    "tree.rows": ("model", "M", "E", "lps_solved", "candidates", "pruned",
                  "t_total", "t_sim", "sim_rel_err", "speedup_vs_star",
                  "schedule"),
    "pipeline.table2": ("network", "layers", "M", "pipeline_depth",
                        "t_total_lat", "t_period_lat", "t_period_thr",
                        "t_period_des", "period_rel_err", "bottleneck",
                        "speedup_pipelined", "schedule_lat",
                        "schedule_thr"),
    "pipeline.fleet": ("M", "pipeline_depth", "t_total_lat",
                       "t_period_lat", "t_period_thr", "t_period_des",
                       "period_rel_err", "period_gain",
                       "speedup_pipelined", "schedule_lat",
                       "schedule_thr"),
    "lm_fleet": ("family", "M", "layers", "t_total", "t_sim",
                 "sim_rel_err", "t_period_lat", "t_period_thr",
                 "period_gain", "speedup_all_edge", "speedup_all_cloud",
                 "lps_solved", "candidates", "pruned", "schedule_lat",
                 "schedule_thr"),
    "wire.rows": ("family", "M", "layers", "t_total_none", "t_total_int8",
                  "wire_gain", "mo_ratio", "mg_ratio", "cut_shifted",
                  "schedule_none", "schedule_int8"),
    "churn.rows": ("M", "steps", "n_events", "events",
                   "schedule_initial", "schedule_final",
                   "warm_equals_cold", "resolves", "lps_pruned_warm",
                   "lps_pruned_cold", "wall_elastic", "wall_static",
                   "recovery_s", "loss_elastic", "loss_static"),
    "churn.resume": ("M", "fail_at", "resumed_from", "bitwise_equal"),
    "planner.rows": ("family", "n_fleets", "M", "E", "layers", "classes",
                     "distinct_schedules", "schedule_mode",
                     "hit_rate_cold"),
}

# Section registry: key -> (title, module name, BENCH_sched.json subtree
# key or None, det-check section names).  ``"."`` as subtree key means
# the section's run_json() produces the payload's top level (Table II).
_SECTIONS = {
    "fig6": ("Fig.6 model validity", "fig6_model_validity", None, ()),
    "speedup": ("Fig.7/8 vs All-Edge/All-Cloud", "fig7_8_speedup",
                None, ()),
    "sota": ("Fig.9/10 vs JointDNN/JointDNN+/JALAD", "fig9_10_sota",
             None, ()),
    "edge_cpu": ("Fig.11 edge CPU scaling", "fig11_edge_cpu", None, ()),
    "table2": ("Table II scheduler runtime", "table2_sched_runtime",
               ".", ("rows",)),
    "multidevice": ("M-device sweep (beyond the paper)",
                    "fig_multidevice", "multidevice", ("multidevice",)),
    "tree": ("Multi-edge tree sweep (beyond the paper)", "fig_tree",
             "tree", ("tree.rows",)),
    "pipeline": ("Pipelined steady state (T_period)", "fig_pipeline",
                 "pipeline", ("pipeline.table2", "pipeline.fleet")),
    "lm_fleet": ("LM fleet via LayerStack (beyond the paper)",
                 "fig_lm_fleet", "lm_fleet", ("lm_fleet",)),
    "churn": ("Elastic fleet churn (beyond the paper)", "fig_churn",
              "churn", ("churn.rows", "churn.resume")),
    "wire": ("Wire compression (beyond the paper)", "fig_wire",
             "wire", ("wire.rows",)),
    "planner": ("Cross-fleet planner (beyond the paper)", "fig_planner",
                "planner", ("planner.rows",)),
    "roofline": ("Roofline report (from dry-run)", "roofline_report",
                 None, ()),
}

# Path of each det-check section inside the committed JSON payload.
_DET_PATHS = {
    "rows": ("rows",),
    "multidevice": ("multidevice",),
    "tree.rows": ("tree", "rows"),
    "pipeline.table2": ("pipeline", "table2"),
    "pipeline.fleet": ("pipeline", "fleet"),
    "lm_fleet": ("lm_fleet",),
    "wire.rows": ("wire", "rows"),
    "churn.rows": ("churn", "rows"),
    "churn.resume": ("churn", "resume"),
    "planner.rows": ("planner", "rows"),
}


def _module(name: str):
    import importlib
    return importlib.import_module(f"benchmarks.{name}")


def validate_section(only: str) -> str:
    """The section name, or ValueError naming the valid choices —
    shared by every ``only=`` entry point so a typo'd programmatic call
    fails the same helpful way the CLI does (not a bare KeyError)."""
    if only not in _SECTIONS:
        raise ValueError(
            f"unknown section {only!r}; valid sections: "
            f"{', '.join(sorted(_SECTIONS))}")
    return only


def run_sections(only: str = None) -> int:
    keys = [validate_section(only)] if only else list(_SECTIONS)
    failures = 0
    for key in keys:
        title, mod_name, _, _ = _SECTIONS[key]
        t0 = time.perf_counter()
        print(f"\n{'='*72}\n== {title}\n{'='*72}")
        try:
            print(_module(mod_name).run())
            print(f"-- done in {time.perf_counter() - t0:.1f}s")
        except Exception as e:                      # pragma: no cover
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"-- FAILED: {e}")
    return 1 if failures else 0


def _json_value(key: str, include_reference: bool):
    """One section's BENCH_sched.json subtree, freshly recomputed."""
    mod = _module(_SECTIONS[key][1])
    if key == "table2":
        return mod.run_json(include_reference)
    if key == "tree":
        return {"rows": mod.run_json()}
    if key == "wire":
        # exec timings ride only on full --json runs; the drift check
        # needs just the deterministic planning rows
        return mod.run_json(include_exec=include_reference)
    if key == "planner":
        return mod.run_json(include_timing=include_reference)
    return mod.run_json()


def _json_keys(only: str = None) -> list:
    keys = [validate_section(only)] if only else list(_SECTIONS)
    return [k for k in keys if _SECTIONS[k][2] is not None]


def _build_payload(include_reference: bool = True, only: str = None,
                   base: dict = None) -> dict:
    payload = dict(base or {})
    for key in _json_keys(only):
        subtree = _SECTIONS[key][2]
        value = _json_value(key, include_reference)
        if subtree == ".":
            payload.update(value)
        else:
            payload[subtree] = value
    return payload


def _print_json_summary(payload: dict, keys: list) -> None:
    if "table2" in keys:
        for r in payload["rows"]:
            print(f"  {r['network']:>10} (N={r['layers']:>2}): "
                  f"reference {r['reference_s']:.3f}s -> "
                  f"batched {r['batched_s']:.3f}s "
                  f"({r['speedup']:.1f}x, {r['pruned']} of "
                  f"{r['candidates']} LPs pruned)")
        if "min_speedup_n_ge_16" in payload:
            print(f"  min speedup for N >= 16: "
                  f"{payload['min_speedup_n_ge_16']:.1f}x")
    if "multidevice" in keys:
        for r in payload["multidevice"]:
            print(f"  M={r['M']}: sched {r['sched_s']*1e3:.0f}ms "
                  f"T_total {r['t_total']:.3f}s sim {r['t_sim']:.3f}s "
                  f"(rel err {r['sim_rel_err']:.1%}) "
                  f"speedup vs all-edge {r['speedup_all_edge']:.2f}x "
                  f"/ all-cloud {r['speedup_all_cloud']:.2f}x")
    if "tree" in keys:
        for r in payload["tree"]["rows"]:
            print(f"  tree {r['model']:>7} E={r['E']}: sched "
                  f"{r['sched_s']*1e3:.0f}ms T_total {r['t_total']:.3f}s "
                  f"sim {r['t_sim']:.3f}s (rel err {r['sim_rel_err']:.1%}) "
                  f"speedup vs star {r['speedup_vs_star']:.2f}x")
    if "pipeline" in keys:
        for r in payload["pipeline"]["fleet"]:
            print(f"  pipeline M={r['M']}: T_period latency-opt "
                  f"{r['t_period_lat']:.3f}s -> throughput-opt "
                  f"{r['t_period_thr']:.3f}s ({r['period_gain']:.2f}x)")
    if "lm_fleet" in keys:
        for r in payload["lm_fleet"]:
            print(f"  lm {r['family']:>9} M={r['M']}: T_total "
                  f"{r['t_total']:.2f}s (sim err {r['sim_rel_err']:.1%}) "
                  f"vs all-edge {r['speedup_all_edge']:.2f}x / all-cloud "
                  f"{r['speedup_all_cloud']:.2f}x")
    if "wire" in keys:
        for r in payload["wire"]["rows"]:
            print(f"  wire {r['family']:>9} M={r['M']}: T_total "
                  f"{r['t_total_none']:.2f}s -> int8 "
                  f"{r['t_total_int8']:.2f}s ({r['wire_gain']:.2f}x), "
                  f"cut shifted {r['cut_shifted']}")
    if "churn" in keys:
        for r in payload["churn"]["rows"]:
            print(f"  churn M={r['M']}: {r['n_events']} events, recovery "
                  f"{r['recovery_s']:.2f}s, warm/cold prune "
                  f"{r['lps_pruned_warm']}/{r['lps_pruned_cold']}, "
                  f"warm==cold {r['warm_equals_cold']}")
        for r in payload["churn"]["resume"]:
            print(f"  resume M={r['M']}: from step {r['resumed_from']}, "
                  f"bitwise {r['bitwise_equal']} "
                  f"({r['resume_s']:.1f}s)")
    if "planner" in keys:
        p = payload["planner"]
        c = p["cache"]
        print(f"  planner: {p['n_fleets']} fleets, cold "
              f"{p['cold_s']:.2f}s ({p['plans_per_s']:.0f} plans/s, "
              f"{p['speedup_vs_loop']:.1f}x vs per-fleet loop), hit rate "
              f"{c['hit_rate']:.3f}, hit p50/p99 "
              f"{p['hit_p50_us']:.0f}/{p['hit_p99_us']:.0f}us")


def run_sched_json(path: str, only: str = None) -> int:
    from benchmarks.common import write_json
    base = None
    if only:
        # --section merge mode: recompute one subtree in place.
        with open(path) as f:
            base = json.load(f)
    payload = _build_payload(only=only, base=base)
    write_json(path, payload)
    print(f"wrote {path}" + (f" (section {only})" if only else ""))
    _print_json_summary(payload, _json_keys(only))
    return 0


_MISSING = "<missing field>"


def _det_view(section: str, rows: list) -> list:
    # A key absent on either side surfaces as drift (never None == None).
    keys = _DET_KEYS[section]
    return [{k: r.get(k, _MISSING) for k in keys} for r in rows]


def _close(a, b) -> bool:
    if isinstance(a, float) and isinstance(b, (int, float)):
        return abs(a - b) <= 1e-6 * max(abs(a), abs(b)) + 1e-12
    return a == b


def _lookup(payload: dict, det_section: str) -> list:
    node = payload
    for part in _DET_PATHS[det_section]:
        node = node.get(part, {}) if isinstance(node, dict) else {}
    return node if isinstance(node, list) else []


def check_schedules(path: str, only: str = None) -> int:
    """Recompute deterministic schedule fields; fail on drift from
    ``path`` (the committed artifact)."""
    with open(path) as f:
        committed = json.load(f)
    fresh = _build_payload(include_reference=False, only=only)
    det_sections = [s for k in _json_keys(only) for s in _SECTIONS[k][3]]
    drift = 0
    for name in det_sections:
        old = _lookup(committed, name)
        new = _lookup(fresh, name)
        old_v, new_v = _det_view(name, old), _det_view(name, new)
        # A guarded key missing from the *recomputed* rows means _DET_KEYS
        # went stale against the benchmark code — fail loudly instead of
        # silently comparing nothing.
        for i, n in enumerate(new_v):
            for k, v in n.items():
                if v is _MISSING:
                    print(f"CONFIG {name}[{i}].{k}: not produced by the "
                          f"benchmark — update _DET_KEYS in benchmarks/"
                          f"run.py")
                    drift += 1
        if len(old_v) != len(new_v):
            print(f"DRIFT {name}: {len(old_v)} committed rows vs "
                  f"{len(new_v)} recomputed")
            drift += 1
            continue
        for i, (o, n) in enumerate(zip(old_v, new_v)):
            for k in _DET_KEYS[name]:
                if not _close(o[k], n[k]):
                    print(f"DRIFT {name}[{i}].{k}: committed {o[k]!r} "
                          f"!= recomputed {n[k]!r}")
                    drift += 1
    if drift:
        print(f"\n{drift} drifted field(s) — regenerate with "
              f"`python -m benchmarks.run --json` and commit the result.")
        return 1
    print(f"schedules in {path} match the recomputed search "
          f"(timings ignored).")
    return 0


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_sched.json",
                        default=None, metavar="PATH",
                        help="write reference-vs-batched Table II scheduler "
                             "runtimes to PATH (default BENCH_sched.json) "
                             "instead of running every section")
    parser.add_argument("--check-schedules", nargs="?",
                        const="BENCH_sched.json", default=None,
                        metavar="PATH",
                        help="recompute the deterministic schedule fields "
                             "and exit non-zero if they drift from PATH")
    parser.add_argument("--section", default=None, metavar="NAME",
                        help="restrict to one section: report mode runs "
                             "just it; --json merges only its subtree "
                             "into the existing artifact; "
                             "--check-schedules drift-checks only it "
                             f"(sections: {', '.join(sorted(_SECTIONS))})")
    args = parser.parse_args()
    if args.section is not None:
        try:
            validate_section(args.section)
        except ValueError as e:
            parser.error(str(e))
    if args.check_schedules is not None:
        sys.exit(check_schedules(args.check_schedules, only=args.section))
    if args.json is not None:
        sys.exit(run_sched_json(args.json, only=args.section))
    sys.exit(run_sections(only=args.section))


if __name__ == "__main__":
    main()
