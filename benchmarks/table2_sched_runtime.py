"""Table II: Algorithm-1 scheduler runtime per network size.  The paper
reports 0.52 s (LeNet) .. 12 s (ResNet-34) on an i7-6700 with CPLEX; our
scalar two-phase simplex on synthetic N-layer profiles lands in the same
order of magnitude and scales ~N^2 in the cut enumeration.  The batched
engine (one stacked simplex over all candidate LPs + dominance pruning)
solves the same search 10-50x faster with identical answers; both are
timed here and the speedup is the tracked perf metric (BENCH_sched.json).

Plans through ``repro.api`` against a pinned-profile triple fleet; the
``backend`` knob selects the stacked vs scalar simplex.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import network, table
from repro.api import Fleet, plan
from repro.core.cost_model import HierProfile

NETS = {"lenet5": 5, "alexnet": 8, "vgg16": 16, "vgg19": 19,
        "googlenet": 22, "resnet34": 34}


def synthetic_profile(n: int) -> HierProfile:
    rng = np.random.default_rng(0)
    speed = np.array([[1.0], [0.12], [0.01]])
    base = rng.uniform(5e-3, 5e-2, (1, n))
    return HierProfile(
        layer_names=tuple(f"l{i}" for i in range(n)),
        L_f=base * speed, L_b=2 * base * speed, L_u=0.5 * base * speed,
        MP=rng.uniform(1e5, 5e7, n), MO=rng.uniform(1e4, 2e6, n),
        sample_bytes=3073.0)


def measure(include_reference: bool = True) -> List[Dict]:
    """Time both backends per network; assert they agree on the answer."""
    rows: List[Dict] = []
    for name, n in NETS.items():
        fleet = Fleet.from_profile(synthetic_profile(n), network(3.0))
        t0 = time.perf_counter()
        res_b = plan(None, fleet, B=64).result
        dt_b = time.perf_counter() - t0
        row = {"network": name, "layers": n, "M": 1,
               "batched_s": dt_b, "lps_solved": res_b.n_lp_solved,
               "candidates": res_b.n_candidates,
               "pruned": res_b.n_pruned,
               "t_total": res_b.t_total,
               "schedule": res_b.schedule.describe()}
        if include_reference:
            t0 = time.perf_counter()
            res_r = plan(None, fleet, B=64, backend="reference").result
            dt_r = time.perf_counter() - t0
            assert res_r.t_total == res_b.t_total, \
                f"{name}: backends disagree ({res_r.t_total} vs {res_b.t_total})"
            row["reference_s"] = dt_r
            row["speedup"] = dt_r / dt_b
        rows.append(row)
    return rows


def run() -> str:
    rows = measure()
    return table(rows, ["network", "layers", "reference_s", "batched_s",
                        "speedup", "lps_solved", "pruned"],
                 "Table II — Algorithm 1 runtime (reference two-phase "
                 "simplex vs batched engine, this host)")


def run_json(include_reference: bool = True) -> Dict:
    """Payload for BENCH_sched.json (benchmarks/run.py --json).

    ``include_reference=False`` skips timing the scalar oracle (and the
    speedup summary) — the deterministic-fields mode the CI schedule
    drift check runs."""
    rows = measure(include_reference=include_reference)
    payload = {
        "benchmark": "table2_sched_runtime",
        "batch": 64,
        "edge_cloud_mbps": 3.0,
        "rows": rows,
    }
    if include_reference:
        payload["min_speedup_n_ge_16"] = min(
            r["speedup"] for r in rows if r["layers"] >= 16)
    return payload


if __name__ == "__main__":
    print(run())
