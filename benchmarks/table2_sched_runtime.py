"""Table II: Algorithm-1 scheduler runtime per network size.  The paper
reports 0.52 s (LeNet) .. 12 s (ResNet-34) on an i7-6700 with CPLEX; our
two-phase simplex on synthetic N-layer profiles should land in the same
order of magnitude and scale ~N^2 in the cut enumeration."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import network, table
from repro.core.cost_model import HierProfile
from repro.core.scheduler import solve

NETS = {"lenet5": 5, "alexnet": 8, "vgg16": 16, "vgg19": 19,
        "googlenet": 22, "resnet34": 34}


def synthetic_profile(n: int) -> HierProfile:
    rng = np.random.default_rng(0)
    speed = np.array([[1.0], [0.12], [0.01]])
    base = rng.uniform(5e-3, 5e-2, (1, n))
    return HierProfile(
        layer_names=tuple(f"l{i}" for i in range(n)),
        L_f=base * speed, L_b=2 * base * speed, L_u=0.5 * base * speed,
        MP=rng.uniform(1e5, 5e7, n), MO=rng.uniform(1e4, 2e6, n),
        sample_bytes=3073.0)


def run() -> str:
    rows = []
    for name, n in NETS.items():
        profile = synthetic_profile(n)
        t0 = time.perf_counter()
        res = solve(profile, network(3.0), B=64)
        dt = time.perf_counter() - t0
        rows.append({"network": name, "layers": n, "runtime_s": dt,
                     "lps_solved": res.n_lp_solved})
    return table(rows, ["network", "layers", "runtime_s", "lps_solved"],
                 "Table II — Algorithm 1 runtime (two-phase simplex, "
                 "this host)")


if __name__ == "__main__":
    print(run())
