"""Elastic fleet + crash-safe resume, end-to-end through ``repro.api``
(DESIGN.md §10).

A heterogeneous M-device star fleet trains a small CNN while a
deterministic Poisson churn trace joins, removes, crashes, and fades
devices mid-run; every membership change remaps the live schedule onto
the survivors and warm-starts the re-solve.  The run is then killed
mid-flight with an injected failure and resumed from its atomic
checkpoint — and the resumed run must be *bitwise* equal to the
uninterrupted one (final params, history tail, simulated wall clock).

    PYTHONPATH=src python examples/churn_resume.py [--steps 24] [--m 3] \
        [--fail-at 14] [--ckpt-dir DIR]
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.api import Fleet, plan
from repro.core.churn import poisson_trace
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import lenet5
from repro.train.loop import InjectedFailure


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--m", type=int, default=3,
                    help="initial number of devices (star topology)")
    ap.add_argument("--fail-at", type=int, default=14)
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint store (default: a fresh tmpdir)")
    args = ap.parse_args()

    model = lenet5()
    spec = Fleet.from_table2(model="lenet5", m=args.m, topology="star")
    fleet = Fleet.from_profile(spec.profile_for(model), spec.network())
    prof = fleet.profile_for(model)
    data = SyntheticImages(model.input_shape, model.num_classes,
                           args.batch, seed=0)
    trace = poisson_trace(prof.worker_names[:-2], args.steps, seed=2,
                          join_rate=0.1, leave_rate=0.08,
                          crash_rate=0.06, degrade_rate=0.1)
    print(f"fleet: {fleet.describe()}")
    print("churn trace:")
    for e in trace.events:
        print(f"  step {e.step:>3}: {type(e).__name__} {e.name}")

    # --- uninterrupted reference run (no checkpointing) -----------------
    ref = plan(model, fleet, args.batch).train(data, steps=args.steps,
                                               churn=trace, seed=0)
    for c in ref["churn_log"]:
        print(f"  step {c['step']:>3}: {','.join(c['events'])} -> M={c['m']}"
              f" re-solved in {c['resolve_s']*1e3:.0f}ms "
              f"({c['n_pruned']}/{c['n_candidates']} lanes pruned, "
              f"warm={c['warm']})")

    # --- kill mid-run, then resume from the checkpoint ------------------
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="hiertrain_ckpt_")
    kw = dict(steps=args.steps, churn=trace, seed=0, ckpt_dir=ckpt_dir,
              ckpt_every=args.ckpt_every)
    try:
        plan(model, fleet, args.batch).train(data, fail_at=args.fail_at,
                                             **kw)
        raise SystemExit("injected failure never fired — check --fail-at")
    except InjectedFailure as e:
        print(f"\nkilled: {e}")
    resumed = plan(model, fleet, args.batch).train(data, **kw)
    print(f"resumed from step {resumed['resumed_from']} "
          f"(checkpoints in {ckpt_dir})")

    # --- the resumed run must be bitwise equal --------------------------
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert resumed["wall"] == ref["wall"], (resumed["wall"], ref["wall"])
    tail = [h for h in ref["history"] if h["step"] > resumed["resumed_from"]]
    assert [h["loss"] for h in tail] == \
        [h["loss"] for h in resumed["history"]]
    print(f"bitwise resume OK: loss {ref['history'][-1]['loss']:.4f}, "
          f"simulated wall {ref['wall']:.2f}s, "
          f"{len(ref['churn_log'])} churn re-solves")


if __name__ == "__main__":
    main()
