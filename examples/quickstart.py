"""Quickstart: HierTrain end-to-end through the one front door,
``repro.api``.

LeNet-5-style CNN + synthetic CIFAR-shaped data on the paper's
mobile-edge-cloud testbed: build a ``Fleet``, ``plan()`` the Algorithm-1
schedule, read the ``Plan.explain()`` breakdown, then train with the
plan's jitted hybrid-SGD step — whose update must match vanilla SGD
bit-for-bit (exact batch-B semantics).

    PYTHONPATH=src python examples/quickstart.py [--steps 40] [--m 2]
"""
import argparse

import jax
import numpy as np

from repro.api import Fleet, plan
from repro.core.hybrid_step import reference_sgd_step
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import lenet5


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--edge-cloud-mbps", type=float, default=3.0)
    ap.add_argument("--m", type=int, default=1,
                    help="number of devices (1 = the paper's triple)")
    args = ap.parse_args()

    model = lenet5()
    fleet = Fleet.from_table2(model="lenet5", m=args.m,
                              edge_cloud_mbps=args.edge_cloud_mbps)

    # --- optimization stage (Algorithm 1) -------------------------------
    p = plan(model, fleet, args.batch)
    print(p.explain())
    print(f"simulated iteration (DES): {p.simulate():.3f}s")

    # --- hierarchical training stage ------------------------------------
    data = SyntheticImages(model.input_shape, model.num_classes,
                           args.batch, seed=0)
    step = p.step_fn(lr=0.05)
    params = p.init_params(jax.random.PRNGKey(0))
    # the jitted step donates its params; the reference copy needs its
    # own buffers
    ref_params = jax.tree.map(jax.numpy.array, params)
    for i in range(args.steps):
        b = data.batch(i)
        x, y = b["x"], b["labels"]
        params, loss = step(params, x, y)
        ref_params, _ = reference_sgd_step(model, ref_params,
                                           jax.numpy.asarray(x),
                                           jax.numpy.asarray(y), 0.05)
        if (i + 1) % 10 == 0 or i + 1 == args.steps:
            # hybrid parallelism must match vanilla SGD
            drift = max(float(np.abs(np.asarray(a - b)).max())
                        for a, b in zip(jax.tree.leaves(params),
                                        jax.tree.leaves(ref_params)))
            print(f"step {i+1:3d}: loss={float(loss):.4f} "
                  f"(max drift vs vanilla SGD: {drift:.2e})")
    print("done — hybrid parallelism preserved SGD semantics.")


if __name__ == "__main__":
    main()
