"""Quickstart: HierTrain end-to-end on the paper's own setting.

LeNet-5-style CNN + synthetic CIFAR-shaped data on the mobile-edge-cloud
testbed: profile -> Algorithm 1 schedule -> hybrid-parallel training with
exact SGD semantics -> per-iteration time vs All-Edge / All-Cloud.

    PYTHONPATH=src python examples/quickstart.py [--steps 40]
"""
import argparse

import jax
import numpy as np

from repro.core.baselines import all_on_one
from repro.core.cost_model import Network
from repro.core.hybrid_step import (hybrid_step_from_schedule,
                                    reference_sgd_step, split_batch)
from repro.core.profiler import PAPER_TESTBED, analytic_profile
from repro.core.scheduler import solve
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import lenet5

MBPS = 1e6 / 8.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--edge-cloud-mbps", type=float, default=3.0)
    args = ap.parse_args()

    model = lenet5()
    profile = analytic_profile(model, PAPER_TESTBED)
    net = Network(bw_de=5.0 * MBPS, bw_ec=args.edge_cloud_mbps * MBPS)

    # --- optimization stage (Algorithm 1) -------------------------------
    res = solve(profile, net, args.batch)
    sched = res.schedule
    print(f"schedule: {sched.describe()}")
    print(f"predicted iteration: {res.t_total:.3f}s "
          f"(all-edge {all_on_one(profile, net, args.batch, 'edge').t_total:.3f}s, "
          f"all-cloud {all_on_one(profile, net, args.batch, 'cloud').t_total:.3f}s)")

    # --- hierarchical training stage ------------------------------------
    data = SyntheticImages(model.input_shape, model.num_classes,
                           args.batch, seed=0)
    params = model.init(jax.random.PRNGKey(0))
    ref_params = params
    for step in range(args.steps):
        b = data.batch(step)
        x, y = jax.numpy.asarray(b["x"]), jax.numpy.asarray(b["labels"])
        params, loss = hybrid_step_from_schedule(model, params, x, y,
                                                 sched, lr=0.05)
        if (step + 1) % 10 == 0:
            # hybrid parallelism must match vanilla SGD bit-for-bit
            ref_params, ref_loss = reference_sgd_step(model, ref_params,
                                                      x, y, 0.05)
            drift = max(float(np.abs(np.asarray(a - b)).max())
                        for a, b in zip(jax.tree.leaves(params),
                                        jax.tree.leaves(ref_params)))
            print(f"step {step+1:3d}: loss={float(loss):.4f} "
                  f"(max drift vs vanilla SGD: {drift:.2e})")
        else:
            ref_params, _ = reference_sgd_step(model, ref_params, x, y,
                                               0.05)
    print("done — hybrid parallelism preserved SGD semantics.")


if __name__ == "__main__":
    main()
