"""Batched serving example: prefill a prompt batch, decode new tokens
with the KV cache, report per-phase throughput.  ``--arch`` selects any
assigned architecture's *smoke* config (same code path as the full
configs; the 32k/500k cells run via the dry-run).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2.5-3b --new 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.lm.model import build_model
from repro.serve.engine import generate

ARGS = None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B, T = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, T, cfg.d_model),
                                            jnp.float32)
    elif cfg.n_frontend_tokens > 0:
        P = cfg.n_frontend_tokens
        batch["tokens"] = batch["tokens"][:, :T - P]
        batch["embeds"] = jax.random.normal(key, (B, P, cfg.d_model),
                                            jnp.float32)

    t0 = time.perf_counter()
    out = generate(model, params, batch, max_len=T + args.new,
                   n_new=args.new, key=key, temperature=args.temperature)
    out.tokens.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} (smoke config, family={cfg.family})")
    print(f"generated {B}x{args.new} tokens in {dt:.2f}s "
          f"({B*args.new/dt:.1f} tok/s incl. prefill+compile)")
    print("sample token ids:", out.tokens[0, :16].tolist())


if __name__ == "__main__":
    main()
