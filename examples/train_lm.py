"""End-to-end LM training driver: ~100M-class dense transformer on the
synthetic token stream, with checkpoint/restart and (optional) failure
injection.

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --steps 60 --fail-at 30
    PYTHONPATH=src python examples/train_lm.py --steps 60 --resume

``--hier`` instead trains the same config *hierarchically* across the LM
mobile-edge-cloud fleet through the ``repro.api`` front door: plan the
Algorithm-1 cut/split, print the breakdown, run the straggler-aware
hybrid-SGD loop:

    PYTHONPATH=src python examples/train_lm.py --hier --steps 20 --devices 2

~100M params needs --size full (slow on CPU); the default "small" config
(~20M) runs a few hundred steps in minutes and exercises the same code.
"""
import argparse

import jax

from repro.configs.base import ShapeSpec
from repro.data.pipeline import make_lm_batch_fn
from repro.models.lm.model import LMConfig, build_model
from repro.optim import get_optimizer
from repro.train.loop import LoopConfig, run_train_loop
from repro.train.step import init_state, make_train_step

SIZES = {
    "small": LMConfig("lm-20m", "dense", n_layers=4, d_model=256,
                      n_heads=4, n_kv_heads=2, d_ff=1024, vocab=32_000,
                      dtype=jax.numpy.float32),
    "full": LMConfig("lm-110m", "dense", n_layers=10, d_model=640,
                     n_heads=10, n_kv_heads=5, d_ff=2560, vocab=32_000,
                     dtype=jax.numpy.float32),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--size", choices=SIZES, default="small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--resume", action="store_true",
                    help="(restart picks up the latest checkpoint "
                    "automatically; flag is informational)")
    ap.add_argument("--hier", action="store_true",
                    help="train hierarchically across the LM fleet via "
                    "repro.api instead of the single-host loop")
    ap.add_argument("--devices", type=int, default=1,
                    help="fleet device count for --hier")
    args = ap.parse_args()

    cfg = SIZES[args.size]
    if args.hier:
        return hier_main(cfg, args)
    model = build_model(cfg)
    opt = get_optimizer("adamw", lr=3e-4, weight_decay=0.0)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree.leaves(state["params"]))
    print(f"model {cfg.name}: {n/1e6:.1f}M params")

    shape = ShapeSpec("example", args.seq, args.batch, "train")
    batch_fn = make_lm_batch_fn(cfg, shape, seed=0)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))

    out = run_train_loop(
        LoopConfig(total_steps=args.steps, ckpt_every=20,
                   ckpt_dir=args.ckpt_dir, log_every=10,
                   fail_at=args.fail_at),
        state, step, batch_fn)
    if out["resumed_from"] is not None:
        print(f"(resumed from checkpoint at step {out['resumed_from']})")
    hist = out["history"]
    if len(hist) >= 2:
        print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


def hier_main(cfg, args) -> None:
    """Plan and run hierarchical LM training through repro.api."""
    from repro.api import Fleet, plan
    from repro.models.lm.layerstack import lm_layerstack

    stack = lm_layerstack(cfg, seq_len=args.seq)
    fleet = Fleet.lm_default(m=args.devices)
    p = plan(stack, fleet, args.batch)
    print(p.explain())

    class TokenData:
        """Stateless batch source in the loop's {"x", "labels"} shape."""

        def batch(self, step):
            key = jax.random.fold_in(jax.random.PRNGKey(0), step)
            x, labels = stack.dummy_batch(key, args.batch)
            return {"x": x, "labels": labels}

    out = p.train(TokenData(), steps=args.steps, lr=0.05,
                  log=lambda s: print(s))
    hist = out["history"]
    print(f"hier loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"(modeled fleet wall clock {out['wall']:.1f}s, final schedule "
          f"{out['final_schedule'].describe()})")


if __name__ == "__main__":
    main()
