"""HierTrain reproduction — public surface (DESIGN.md §9).

The supported API is the ``Fleet``/``Plan`` front door:

* :class:`repro.api.Fleet` — M heterogeneous devices + edge + cloud
  (the paper's triple is ``M = 1``), with ``from_table2()`` /
  ``lm_default()`` / ``from_profile()`` constructors.
* :func:`repro.api.plan` — Algorithm 1 over a (model, fleet, B) triple.
* :class:`repro.api.Plan` — the decision: schedule, predicted
  ``t_total``/``t_period``, ``.simulate()``, ``.step_fn()``,
  ``.train()``, ``.explain()``.
* :func:`repro.core.layerstack.as_layerstack` — the model adapter seam.

Everything else under ``repro.*`` is internal: stable enough to read,
not a compatibility surface.  The pre-facade entry points (``solve``,
``t_total*``, ``simulate_iteration*``, ``run_*_hier_loop``) are
deprecation shims over the facade.

Exports resolve lazily so ``import repro`` stays cheap (no jax import
until the facade is touched).
"""
from __future__ import annotations

__all__ = ["Fleet", "Plan", "plan", "plan_many", "as_layerstack"]


def __getattr__(name):
    if name in ("Fleet", "Plan", "plan", "plan_many"):
        from repro import api
        return getattr(api, name)
    if name == "as_layerstack":
        from repro.core.layerstack import as_layerstack
        return as_layerstack
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + __all__)
