"""repro.analysis — repo-native static analysis (DESIGN.md §14).

Five AST checkers, each pinned to a bug class this codebase has
actually shipped and fixed, plus a baseline/ratchet runner wired into
CI as a tier-1 gate.  Run ``python -m repro.analysis.lint`` from the
repo root; ``--list-checks`` prints the finding-code catalog.
"""
from repro.analysis.base import CODES, Finding, SourceFile

__all__ = ["CODES", "Finding", "SourceFile", "lint_file", "lint_paths",
           "run"]


def __getattr__(name):
    # Lazy: importing the runner here would shadow the
    # ``python -m repro.analysis.lint`` entry point (runpy warning).
    if name in ("lint_file", "lint_paths", "run"):
        from repro.analysis import lint
        return getattr(lint, name)
    raise AttributeError(name)
