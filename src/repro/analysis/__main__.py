"""``python -m repro.analysis`` == ``python -m repro.analysis.lint``."""
import sys

from repro.analysis.lint import main

sys.exit(main())
