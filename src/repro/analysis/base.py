"""Shared infrastructure for the repo-native static-analysis pass.

The analysis package (DESIGN.md §14) is a small AST toolkit with five
repo-specific checkers, each targeting a bug class that has actually
shipped (and been fixed) in this codebase: per-call re-jit, unbounded
``id()``-keyed caches, donated-buffer reuse, bytes-vs-elems unit mixes
in the wire cost model, deprecated-shim calls, and Pallas grid/BlockSpec
mismatches.  This module holds what every checker shares:

* :class:`Finding` — one diagnostic, with a *stable* identity key
  ``(code, path, message)`` (no line numbers, so the committed baseline
  survives unrelated edits).
* :class:`SourceFile` — parsed source plus the inline
  ``# repro-lint: disable=CODE <reason>`` escape-hatch map (built from
  real COMMENT tokens, so string literals can never fake a disable).
* :class:`Imports` — per-file import resolution so checkers can decide
  whether ``jit(...)`` means ``jax.jit`` and which module an attribute
  call lands in.
* ``const_int`` / ``dotted_name`` — tiny resolution helpers.

Checkers are plain objects with ``code_prefix``, ``name`` and
``check(SourceFile) -> list[Finding]``; path scoping lives in the runner
(:mod:`repro.analysis.lint`), keeping checkers directly callable on
fixture snippets in tests.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Set, Tuple

# Catalog of finding codes (DESIGN.md §14).  One-line summaries; the
# finding message carries the site-specific detail.
CODES: Dict[str, str] = {
    "RA000": "file does not parse (checkers skipped)",
    "RA001": "repro-lint disable comment without a reason or with an "
             "unknown code",
    "RA101": "jax.jit called inside a loop body (re-traces per "
             "iteration)",
    "RA102": "jax.jit(...) immediately called (re-traces on every "
             "invocation of the enclosing function)",
    "RA103": "unbounded plain-dict cache keyed by id(...)",
    "RA104": "Python-side nondeterminism (time.*, random.*, set "
             "iteration) reachable from a jitted function",
    "RA105": "unhashable literal passed in a static argument position",
    "RA201": "array read after being passed in a donated argument "
             "position",
    "RA301": "arithmetic mixes unit families (bytes/elems/mb/mbps) "
             "without an explicit conversion",
    "RA302": "value of one unit family bound to a name of another "
             "(assignment, keyword, parameter, or return)",
    "RA401": "call or import of a deprecated pre-Fleet/Plan shim from "
             "in-repo code (static deprecation firewall)",
    "RA501": "pallas_call grid arity does not match a BlockSpec "
             "index_map signature",
    "RA502": "BlockSpec block shape inconsistent with index_map return "
             "arity or not dividing the declared array dim",
    "RA503": "matmul in a Pallas kernel may accumulate in low "
             "precision (no f32 cast / preferred_element_type)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    path: str          # repo-relative posix path
    line: int
    col: int
    message: str       # stable: must not embed line/col numbers

    @property
    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: survives line-number churn."""
        return (self.code, self.path, self.message)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} " \
               f"{self.message}"

    def to_json(self) -> Dict:
        return {"code": self.code, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


# ``# repro-lint: disable=RA101,RA102 <reason>`` on the flagged line, or
# ``disable-next=...`` on the line above it.
_DISABLE_RE = re.compile(
    r"repro-lint:\s*(disable|disable-next)=([A-Za-z0-9,]+)\s*(.*)$")


class SourceFile:
    """One parsed source file plus its disable-comment map."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.parse_error: Optional[str] = None
        try:
            self.tree: ast.AST = ast.parse(text)
        except SyntaxError as e:  # surfaced as RA000 by the runner
            self.parse_error = str(e)
            self.tree = ast.Module(body=[], type_ignores=[])
        # line -> set of codes disabled on that line
        self.disables: Dict[int, Set[str]] = {}
        # meta-findings about the disable comments themselves (RA001)
        self.disable_findings: List[Finding] = []
        self._scan_comments()

    def _scan_comments(self) -> None:
        try:
            tokens = tokenize.generate_tokens(
                io.StringIO(self.text).readline)
            comments = [(t.start[0], t.string) for t in tokens
                        if t.type == tokenize.COMMENT]
        except (tokenize.TokenError, IndentationError, SyntaxError):
            comments = []
        for line, comment in comments:
            m = _DISABLE_RE.search(comment)
            if not m:
                continue
            kind, codes_s, reason = m.groups()
            codes = {c.strip() for c in codes_s.split(",") if c.strip()}
            target = line + 1 if kind == "disable-next" else line
            unknown = sorted(c for c in codes if c not in CODES)
            if unknown:
                self.disable_findings.append(Finding(
                    "RA001", self.path, line, 0,
                    f"disable comment names unknown code(s) "
                    f"{', '.join(unknown)}"))
            if not reason.strip(" -:;"):
                self.disable_findings.append(Finding(
                    "RA001", self.path, line, 0,
                    f"disable={','.join(sorted(codes))} has no reason — "
                    f"every suppression must say why"))
            self.disables.setdefault(target, set()).update(codes)

    def disabled(self, finding: Finding) -> bool:
        return finding.code in self.disables.get(finding.line, set())


class Imports:
    """Per-file import map: resolve local names to dotted module paths.

    ``modules`` maps a bound name to the module it denotes
    (``import a.b as c`` -> ``c: a.b``; ``import a.b`` -> ``a: a`` with
    the full path reachable through attribute chains).  ``names`` maps a
    bound name from ``from M import n [as k]`` to ``(M, n)``.
    """

    def __init__(self, tree: ast.AST):
        self.modules: Dict[str, str] = {}
        self.names: Dict[str, Tuple[str, str]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.asname:
                        self.modules[alias.asname] = alias.name
                    else:
                        root = alias.name.split(".")[0]
                        self.modules[root] = root
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for alias in node.names:
                    bound = alias.asname or alias.name
                    self.names[bound] = (node.module, alias.name)

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Fully-qualified dotted path of a Name/Attribute chain, or
        ``None`` when the root is not an import binding."""
        parts = dotted_name(node)
        if not parts:
            return None
        root, rest = parts[0], parts[1:]
        if root in self.names:
            mod, orig = self.names[root]
            return ".".join([mod, orig] + rest)
        if root in self.modules:
            return ".".join([self.modules[root]] + rest)
        return None


def dotted_name(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` attribute chain as ``["a", "b", "c"]`` (Name roots
    only)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return None


def call_path(imports: Imports, call: ast.Call) -> Optional[str]:
    """Resolved dotted path of a call's callee (``jax.jit``,
    ``repro.core.scheduler.solve``, ...), or the raw dotted text when
    the root is a local binding rather than an import."""
    resolved = imports.resolve(call.func)
    if resolved:
        return resolved
    parts = dotted_name(call.func)
    return ".".join(parts) if parts else None


def const_int(node: ast.AST, env: Dict[str, int]) -> Optional[int]:
    """Resolve a node to a compile-time int: literals, names bound in
    ``env``, and unary minus."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand, env)
        return -v if v is not None else None
    return None


def int_env(body: Iterable[ast.stmt]) -> Dict[str, int]:
    """Names bound exactly once to int literals in a statement list —
    the module/function-level tile constants (``LANES = 128``)."""
    env: Dict[str, int] = {}
    seen: Set[str] = set()
    for stmt in body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            name = stmt.targets[0].id
            v = const_int(stmt.value, {})
            if name in seen:
                env.pop(name, None)      # rebound: not a constant
            elif v is not None:
                env[name] = v
            seen.add(name)
    return env


def walk_functions(tree: ast.AST):
    """Every FunctionDef/AsyncFunctionDef in the file, including nested
    ones."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def enclosing_loops(tree: ast.AST) -> Dict[int, bool]:
    """Map ``id(node) -> True`` for nodes lexically inside a for/while
    body (used by the re-jit checker).  Loop iter/condition expressions
    do not count as "inside"."""
    inside: Dict[int, bool] = {}

    def mark(node: ast.AST, flag: bool) -> None:
        inside[id(node)] = flag
        if isinstance(node, (ast.For, ast.AsyncFor)):
            for child in [node.target, node.iter]:
                mark(child, flag)
            for child in node.body + node.orelse:
                mark(child, True)
            return
        if isinstance(node, ast.While):
            mark(node.test, flag)
            for child in node.body + node.orelse:
                mark(child, True)
            return
        # A nested function body is a fresh call frame: being *defined*
        # inside a loop does not mean each call re-enters the loop.
        flag = flag and not isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
        for child in ast.iter_child_nodes(node):
            mark(child, flag)

    mark(tree, False)
    return inside
