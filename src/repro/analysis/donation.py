"""Donation-safety checker (RA201, DESIGN.md §14).

``jax.jit(f, donate_argnums=0)`` lets XLA reuse the input buffer for
the output — which is exactly what the cached hybrid steps do with
``params`` (PR 1) — but makes any later read of the donated array
undefined behaviour: jax raises on CPU, and on accelerators the buffer
may silently alias the new values.  PR 5's quickstart fix
(``ref_params = jax.tree.map(jnp.array, params)`` *before* the donating
step) is the canonical repair.

The checker does a statement-order dataflow walk per function body:

* A name passed in a donated position of a call to a known-donating
  callable becomes *tainted* at that call.
* A later ``Load`` of the tainted name is RA201.
* Rebinding the name (assignment target, including the common
  ``params, loss = step(params, ...)`` self-rebind) clears the taint —
  the read inside the donating call itself is the donation, not a
  violation.

Donating callables are resolved intra-module: ``jax.jit(f,
donate_argnums=...)`` / ``donate_argnames=...`` bound to a name or
used as a decorator.  Cross-module donation (e.g. a ``Plan.step_fn``
consumer) is out of static reach — the runtime error path covers it.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Sequence, Set, Tuple

from repro.analysis.base import (Finding, Imports, SourceFile,
                                 dotted_name, walk_functions)
from repro.analysis.jit_hygiene import (_is_jit_call, _jit_kwarg)


def _donating_node(imports: Imports, node: ast.AST) -> "ast.Call | None":
    """The Call whose keywords carry donate_arg* for a jit expression:
    ``jax.jit(...)`` itself, or ``functools.partial(jax.jit, ...)``
    (the canonical decorator spelling)."""
    if _is_jit_call(imports, node):
        return node
    if isinstance(node, ast.Call):
        parts = dotted_name(node.func)
        if parts and parts[-1] == "partial" and node.args:
            inner = dotted_name(node.args[0])
            if inner and inner[-1] in ("jit", "pjit"):
                return node
    return None


def _donated_positions(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    nums: Set[int] = set()
    names: Set[str] = set()
    v = _jit_kwarg(call, "donate_argnums")
    if isinstance(v, ast.Constant) and isinstance(v.value, int):
        nums.add(v.value)
    elif isinstance(v, (ast.Tuple, ast.List)):
        for e in v.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                nums.add(e.value)
    v = _jit_kwarg(call, "donate_argnames")
    if isinstance(v, ast.Constant) and isinstance(v.value, str):
        names.add(v.value)
    elif isinstance(v, (ast.Tuple, ast.List)):
        for e in v.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                names.add(e.value)
    return nums, names


class DonationChecker:
    code_prefix = "RA2"
    name = "donation"

    def check(self, src: SourceFile) -> List[Finding]:
        imports = Imports(src.tree)
        # name -> (donated positions, donated kwarg names)
        donators: Dict[str, Tuple[Set[int], Set[str]]] = {}
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and _is_jit_call(imports, node.value):
                nums, names = _donated_positions(node.value)
                if nums or names:
                    donators[node.targets[0].id] = (nums, names)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    host = _donating_node(imports, dec)
                    if host is not None:
                        nums, names = _donated_positions(host)
                        if nums or names:
                            donators[node.name] = (nums, names)

        out: List[Finding] = []
        for fn in walk_functions(src.tree):
            out += self._walk_body(src, fn.body, donators, imports)
        out += self._walk_body(
            src,
            [s for s in getattr(src.tree, "body", [])
             if not isinstance(s, (ast.FunctionDef,
                                   ast.AsyncFunctionDef, ast.ClassDef))],
            donators, imports)
        return out

    def _walk_body(self, src: SourceFile, body: Sequence[ast.stmt],
                   donators, imports: Imports) -> List[Finding]:
        out: List[Finding] = []
        tainted: Dict[str, int] = {}     # name -> donation line

        def expr_reads(node: ast.AST) -> None:
            for n in ast.walk(node):
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                        and n.id in tainted:
                    out.append(Finding(
                        "RA201", src.path, n.lineno, n.col_offset,
                        f"{n.id!r} is read after being donated to a "
                        f"jitted call (donate_argnums) — the buffer may "
                        f"already be reused; copy it first "
                        f"(jax.tree.map(jnp.array, ...)) or rebind the "
                        f"result"))

        def handle_call(call: ast.Call) -> None:
            if not (isinstance(call.func, ast.Name)
                    and call.func.id in donators):
                # also catch the immediate form jax.jit(f, donate...)(x)
                if isinstance(call.func, ast.Call) \
                        and _is_jit_call(imports, call.func):
                    nums, names = _donated_positions(call.func)
                else:
                    return
            else:
                nums, names = donators[call.func.id]
            for i, arg in enumerate(call.args):
                if i in nums and isinstance(arg, ast.Name):
                    tainted[arg.id] = call.lineno
            for kw in call.keywords:
                if kw.arg in names and isinstance(kw.value, ast.Name):
                    tainted[kw.value.id] = call.lineno

        def clear_targets(target: ast.AST) -> None:
            for n in ast.walk(target):
                if isinstance(n, ast.Name):
                    tainted.pop(n.id, None)

        def walk_stmt(stmt: ast.stmt) -> None:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                return               # separate frame, walked on its own
            if isinstance(stmt, ast.Assign):
                expr_reads(stmt.value)
                for n in ast.walk(stmt.value):
                    if isinstance(n, ast.Call):
                        handle_call(n)
                for t in stmt.targets:
                    clear_targets(t)
                return
            if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                if stmt.value is not None:
                    expr_reads(stmt.value)
                    for n in ast.walk(stmt.value):
                        if isinstance(n, ast.Call):
                            handle_call(n)
                clear_targets(stmt.target)
                return
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                expr_reads(stmt.iter)
                clear_targets(stmt.target)
                for s in stmt.body + stmt.orelse:
                    walk_stmt(s)
                return
            if isinstance(stmt, (ast.If, ast.While)):
                expr_reads(stmt.test)
                for s in stmt.body + stmt.orelse:
                    walk_stmt(s)
                return
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    expr_reads(item.context_expr)
                for s in stmt.body:
                    walk_stmt(s)
                return
            if isinstance(stmt, ast.Try):
                for s in stmt.body + stmt.orelse + stmt.finalbody:
                    walk_stmt(s)
                for h in stmt.handlers:
                    for s in h.body:
                        walk_stmt(s)
                return
            # expression statements, return, etc.: reads then calls
            expr_reads(stmt)
            for n in ast.walk(stmt):
                if isinstance(n, ast.Call):
                    handle_call(n)

        for stmt in body:
            walk_stmt(stmt)
        return out
