"""jit-hygiene checker (RA101–RA105, DESIGN.md §14).

Every sub-check maps to a bug this repo has shipped and later fixed:

* **RA101 / RA102 — per-call re-jit.**  ``jax.jit`` inside a loop body
  (RA101) or a ``jax.jit(...)``\\ (...) immediate call inside a function
  body (RA102) builds a *new* traced executable on every pass — the
  exact shape of the seed's ``jax.jit(make_decode_step(model))`` inside
  ``generate()`` (fixed in PR 9 with a bounded per-model cache) and the
  per-step re-jit the PR 1 cached hybrid steps removed (~17x/step).

* **RA103 — unbounded id()-keyed caches.**  A plain dict keyed by
  ``id(obj)`` grows forever *and* is unsound once the object is
  collected and its id recycled (PR 4 replaced the grow-forever
  ``_JIT_CACHE`` dict with the pinning ``_JitStepCache`` LRU).  The
  checker flags subscript stores whose key expression contains an
  ``id(...)`` call when the target resolves to a bare ``{}``/``dict()``
  binding; bounded cache objects (anything with an eviction method) do
  not match because their stores go through method calls.

* **RA104 — nondeterminism reachable from jitted code.**  ``time.*``
  and ``random.*`` calls and iteration over set displays execute at
  *trace* time inside a jitted function: the compiled executable bakes
  in whatever value the tracer saw, silently breaking the repo's
  bitwise invariants (warm==cold, kill/resume equality).  Reachability
  is the intra-module call graph seeded from functions that are jitted
  (decorator or ``jax.jit(f)`` by name).

* **RA105 — unhashable static args.**  A list/dict/set literal passed
  in a ``static_argnums``/``static_argnames`` position raises at call
  time (or, worse, at first call on a rarely-taken path).  Checked at
  call sites of jit results built in the same module.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import (Finding, Imports, SourceFile, call_path,
                                 dotted_name, enclosing_loops,
                                 walk_functions)

_JIT_PATHS = {"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"}

# stdlib modules whose calls are Python-side nondeterminism when they
# execute at trace time.
_NONDET_MODULES = {"time", "random", "datetime"}

_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.DictComp, ast.ListComp,
               ast.SetComp)


def _is_jit_call(imports: Imports, node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    path = call_path(imports, node)
    if path in _JIT_PATHS:
        return True
    # ``from jax import jit`` / bare ``jit`` bound by the file itself
    parts = dotted_name(node.func)
    return bool(parts) and parts[-1] == "jit" and (
        path is None or path.endswith(".jit"))


def _jit_kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _static_positions(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    nums: Set[int] = set()
    names: Set[str] = set()
    v = _jit_kwarg(call, "static_argnums")
    if isinstance(v, ast.Constant) and isinstance(v.value, int):
        nums.add(v.value)
    elif isinstance(v, (ast.Tuple, ast.List)):
        for e in v.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                nums.add(e.value)
    v = _jit_kwarg(call, "static_argnames")
    if isinstance(v, ast.Constant) and isinstance(v.value, str):
        names.add(v.value)
    elif isinstance(v, (ast.Tuple, ast.List)):
        for e in v.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                names.add(e.value)
    return nums, names


class JitHygieneChecker:
    code_prefix = "RA1"
    name = "jit-hygiene"

    def check(self, src: SourceFile) -> List[Finding]:
        imports = Imports(src.tree)
        out: List[Finding] = []
        out += self._re_jit(src, imports)
        out += self._id_caches(src, imports)
        out += self._nondeterminism(src, imports)
        out += self._static_args(src, imports)
        return out

    # -- RA101 / RA102 ---------------------------------------------------
    def _re_jit(self, src: SourceFile, imports: Imports) -> List[Finding]:
        out = []
        in_loop = enclosing_loops(src.tree)
        in_function: Set[int] = set()
        for fn in walk_functions(src.tree):
            for node in ast.walk(fn):
                in_function.add(id(node))
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            if _is_jit_call(imports, node):
                if in_loop.get(id(node)):
                    out.append(Finding(
                        "RA101", src.path, node.lineno, node.col_offset,
                        "jax.jit called inside a loop body — each "
                        "iteration re-traces and re-compiles; hoist the "
                        "jit out of the loop or cache the compiled "
                        "function"))
            elif isinstance(node.func, ast.Call) \
                    and _is_jit_call(imports, node.func) \
                    and id(node) in in_function:
                # jax.jit(f)(args): the executable is rebuilt on every
                # call of the enclosing function.
                out.append(Finding(
                    "RA102", src.path, node.lineno, node.col_offset,
                    "jax.jit(...) immediately called — the compiled "
                    "function is rebuilt on every invocation; bind the "
                    "jitted function once (module level or a bounded "
                    "cache) and reuse it"))
        return out

    # -- RA103 -----------------------------------------------------------
    def _id_caches(self, src: SourceFile, imports: Imports
                   ) -> List[Finding]:
        # Names bound to a bare dict at module or class level.
        plain_dicts: Set[str] = set()
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                v = node.value
                is_dict = isinstance(v, ast.Dict) or (
                    isinstance(v, ast.Call)
                    and isinstance(v.func, ast.Name)
                    and v.func.id == "dict" and not v.args)
                if is_dict:
                    plain_dicts.add(node.targets[0].id)

        def key_uses_id(expr: ast.AST) -> bool:
            return any(isinstance(n, ast.Call)
                       and isinstance(n.func, ast.Name)
                       and n.func.id == "id" for n in ast.walk(expr))

        out = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            else:
                continue
            for t in targets:
                if isinstance(t, ast.Subscript) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id in plain_dicts \
                        and key_uses_id(t.slice):
                    out.append(Finding(
                        "RA103", src.path, t.lineno, t.col_offset,
                        f"store into plain dict {t.value.id!r} keyed by "
                        f"id(...) — the dict grows without bound and a "
                        f"recycled id aliases a dead entry; use a "
                        f"bounded LRU that pins the keyed object "
                        f"(see hybrid_step._JitStepCache)"))
        return out

    # -- RA104 -----------------------------------------------------------
    def _nondeterminism(self, src: SourceFile, imports: Imports
                        ) -> List[Finding]:
        # Functions (by name) defined anywhere in the file.
        fns: Dict[str, ast.FunctionDef] = {}
        for fn in walk_functions(src.tree):
            fns.setdefault(fn.name, fn)

        def is_jitted(fn: ast.FunctionDef) -> bool:
            for dec in fn.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                parts = dotted_name(target) or []
                if parts and parts[-1] in ("jit", "pjit"):
                    return True
            return False

        jitted: Set[str] = {n for n, f in fns.items() if is_jitted(f)}
        # ...plus functions passed by name to jax.jit(...) in this file.
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call) and _is_jit_call(imports, node) \
                    and node.args and isinstance(node.args[0], ast.Name):
                jitted.add(node.args[0].id)

        # Intra-module call graph, propagated to a fixed point.
        calls: Dict[str, Set[str]] = {}
        for name, fn in fns.items():
            callees = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name) \
                        and node.func.id in fns:
                    callees.add(node.func.id)
            calls[name] = callees
        reach = set(jitted)
        frontier = list(jitted & set(fns))
        while frontier:
            name = frontier.pop()
            for callee in calls.get(name, ()):
                if callee not in reach:
                    reach.add(callee)
                    frontier.append(callee)

        out = []
        for name in sorted(reach & set(fns)):
            fn = fns[name]
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    parts = dotted_name(node.func)
                    if parts and len(parts) >= 2 \
                            and parts[0] in _NONDET_MODULES \
                            and imports.resolve(node.func):
                        out.append(Finding(
                            "RA104", src.path, node.lineno,
                            node.col_offset,
                            f"{'.'.join(parts)}() inside jit-reachable "
                            f"function {name!r} runs at trace time — "
                            f"the compiled step bakes in one stale "
                            f"value and breaks bitwise replay"))
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    it = node.iter
                    is_set = isinstance(it, (ast.Set, ast.SetComp)) or (
                        isinstance(it, ast.Call)
                        and isinstance(it.func, ast.Name)
                        and it.func.id in ("set", "frozenset"))
                    if is_set:
                        out.append(Finding(
                            "RA104", src.path, node.lineno,
                            node.col_offset,
                            f"iteration over an unordered set inside "
                            f"jit-reachable function {name!r} — trace "
                            f"order (and therefore the compiled "
                            f"program) varies across runs; sort first"))
        return out

    # -- RA105 -----------------------------------------------------------
    def _static_args(self, src: SourceFile, imports: Imports
                     ) -> List[Finding]:
        # jitted-name -> (static positions, static names)
        jitted: Dict[str, Tuple[Set[int], Set[str]]] = {}
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and _is_jit_call(imports, node.value):
                nums, names = _static_positions(node.value)
                if nums or names:
                    jitted[node.targets[0].id] = (nums, names)

        out = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            # immediate form: jax.jit(f, static_argnums=...)(args)
            if isinstance(node.func, ast.Call) \
                    and _is_jit_call(imports, node.func):
                nums, names = _static_positions(node.func)
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in jitted:
                nums, names = jitted[node.func.id]
            else:
                continue
            for i, arg in enumerate(node.args):
                if i in nums and isinstance(arg, _UNHASHABLE):
                    out.append(Finding(
                        "RA105", src.path, arg.lineno, arg.col_offset,
                        f"unhashable literal in static position {i} — "
                        f"jit static args must be hashable; pass a "
                        f"tuple"))
            for kw in node.keywords:
                if kw.arg in names and isinstance(kw.value, _UNHASHABLE):
                    out.append(Finding(
                        "RA105", src.path, kw.value.lineno,
                        kw.value.col_offset,
                        f"unhashable literal for static argument "
                        f"{kw.arg!r} — jit static args must be "
                        f"hashable; pass a tuple"))
        return out
