"""Runner for the repo-native static-analysis pass (DESIGN.md §14).

Usage (from the repo root)::

    python -m repro.analysis.lint [paths...] [--json [PATH]]
        [--baseline analysis/baseline.json] [--check-baseline]
        [--list-checks]

With no paths, lints every *tracked* ``*.py`` file under ``src/``,
``benchmarks/`` and ``examples/`` (``git ls-files``; untracked scratch
files and ``__pycache__`` never slow the gate).  Checkers are scoped
(see ``_SCOPES``): units lint runs only on the wire/cost-model modules
it is calibrated for, the shim firewall on ``src/repro`` +
``benchmarks`` (tests stay free to call shims), Pallas checks on
``kernels/``.

Suppression has exactly two forms, both audited:

* inline ``# repro-lint: disable=CODE <reason>`` (or ``disable-next=``)
  on the flagged line — a missing reason is itself a finding (RA001);
* a committed **baseline** (``analysis/baseline.json``) entry with a
  mandatory ``reason``, matched on the stable finding key
  ``(code, path, message)`` with an explicit ``count``.

``--check-baseline`` is the CI gate and ratchet: it fails on any new
finding *and* on any stale baseline entry (the linter no longer reports
it), so the accepted-finding count can only go down.  Exit codes:
0 clean, 1 findings/stale entries, 2 bad invocation.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.base import CODES, Finding, SourceFile
from repro.analysis.donation import DonationChecker
from repro.analysis.jit_hygiene import JitHygieneChecker
from repro.analysis.pallas_checks import PallasChecker
from repro.analysis.shims import ShimFirewallChecker
from repro.analysis.units import UnitsChecker

DEFAULT_ROOTS = ("src", "benchmarks", "examples")
DEFAULT_BASELINE = os.path.join("analysis", "baseline.json")

# The units lint is calibrated for the modules whose identifiers carry
# unit suffixes by convention (DESIGN.md §14); new modules opt in here.
UNITS_SCOPE = (
    "src/repro/core/cost_model.py",
    "src/repro/core/wire.py",
    "src/repro/core/pipeline.py",
    "src/repro/distrib/tiered_sync.py",
)
SHIM_SCOPE = ("src/repro/", "benchmarks/")
KERNEL_SCOPE = ("src/repro/kernels/",)

_CHECKERS = (JitHygieneChecker(), DonationChecker(), UnitsChecker(),
             ShimFirewallChecker(), PallasChecker())


def _in_scope(checker, path: str) -> bool:
    if isinstance(checker, UnitsChecker):
        return path in UNITS_SCOPE
    if isinstance(checker, ShimFirewallChecker):
        return any(path.startswith(p) for p in SHIM_SCOPE)
    if isinstance(checker, PallasChecker):
        return any(path.startswith(p) for p in KERNEL_SCOPE)
    return True           # jit-hygiene + donation run everywhere


def discover_files(root: str, paths: Sequence[str] = ()) -> List[str]:
    """Repo-relative posix paths of the ``*.py`` files to lint."""
    if paths:
        out = []
        for p in paths:
            ap = os.path.abspath(p)
            if os.path.isdir(ap):
                for dirpath, dirnames, filenames in os.walk(ap):
                    dirnames[:] = [d for d in dirnames
                                   if d != "__pycache__"
                                   and not d.startswith(".")]
                    out += [os.path.join(dirpath, f) for f in filenames
                            if f.endswith(".py")]
            elif ap.endswith(".py"):
                out.append(ap)
        return sorted(os.path.relpath(p, root).replace(os.sep, "/")
                      for p in out)
    try:
        ls = subprocess.run(
            ["git", "ls-files", "--"] +
            [f"{r}/**/*.py" for r in DEFAULT_ROOTS] +
            [f"{r}/*.py" for r in DEFAULT_ROOTS],
            cwd=root, capture_output=True, text=True, check=True,
            timeout=30).stdout.split()
        if ls:
            return sorted(set(ls))
    except (OSError, subprocess.SubprocessError):
        pass
    # not a git checkout: fall back to walking the default roots
    return discover_files(root, [os.path.join(root, r)
                                 for r in DEFAULT_ROOTS
                                 if os.path.isdir(os.path.join(root, r))])


def lint_file(src: SourceFile) -> Tuple[List[Finding], List[Finding]]:
    """(active findings, disabled findings) for one parsed file."""
    if src.parse_error is not None:
        return [Finding("RA000", src.path, 1, 0,
                        f"file does not parse: {src.parse_error}")], []
    findings: List[Finding] = list(src.disable_findings)
    for checker in _CHECKERS:
        if _in_scope(checker, src.path):
            findings += checker.check(src)
    active = [f for f in findings if not src.disabled(f)]
    disabled = [f for f in findings if src.disabled(f)]
    active.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return active, disabled


def lint_paths(root: str, paths: Sequence[str] = ()
               ) -> Tuple[List[Finding], List[Finding]]:
    active: List[Finding] = []
    disabled: List[Finding] = []
    for rel in discover_files(root, paths):
        full = os.path.join(root, rel)
        try:
            with open(full, encoding="utf-8") as f:
                text = f.read()
        except OSError as e:
            active.append(Finding("RA000", rel, 1, 0,
                                  f"unreadable: {e}"))
            continue
        a, d = lint_file(SourceFile(rel, text))
        active += a
        disabled += d
    return active, disabled


# ---------------------------------------------------------------------------
# Baseline: accepted findings, keyed stably, each with a mandatory reason.
# ---------------------------------------------------------------------------

class BaselineError(ValueError):
    pass


def load_baseline(path: str) -> List[Dict]:
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    entries = data.get("entries", []) if isinstance(data, dict) else data
    for i, e in enumerate(entries):
        for field in ("code", "path", "message", "reason"):
            if not str(e.get(field, "")).strip():
                raise BaselineError(
                    f"baseline entry {i} is missing {field!r} — every "
                    f"accepted finding needs a stable key and a reason")
        e.setdefault("count", 1)
        if not (isinstance(e["count"], int) and e["count"] >= 1):
            raise BaselineError(f"baseline entry {i}: count must be a "
                                f"positive int")
    return entries


def apply_baseline(findings: List[Finding], entries: List[Dict]
                   ) -> Tuple[List[Finding], List[Finding], List[Dict]]:
    """Split findings into (new, baselined) and return stale entries.

    An entry absorbs up to ``count`` findings with its exact
    ``(code, path, message)`` key; leftovers are new findings, and an
    entry that absorbs nothing is stale (the ratchet: prune it)."""
    budget: Dict[Tuple[str, str, str], int] = {}
    used: Dict[Tuple[str, str, str], int] = {}
    for e in entries:
        key = (e["code"], e["path"], e["message"])
        budget[key] = budget.get(key, 0) + e["count"]
        used.setdefault(key, 0)
    new: List[Finding] = []
    baselined: List[Finding] = []
    for f in findings:
        if used.get(f.key, 0) < budget.get(f.key, -1):
            used[f.key] += 1
            baselined.append(f)
        else:
            new.append(f)
    stale = [e for e in entries
             if used.get((e["code"], e["path"], e["message"]), 0) == 0]
    # a key covered by several entries: mark extras stale only if the
    # whole key went unused (individual-entry attribution is ambiguous)
    return new, baselined, stale


def run(root: str, paths: Sequence[str] = (),
        baseline_path: Optional[str] = None,
        check_baseline: bool = False) -> Dict:
    """Full lint pass as a JSON-ready report dict (CLI-independent so
    tests and CI drive it directly)."""
    active, disabled = lint_paths(root, paths)
    entries: List[Dict] = []
    baseline_missing = False
    if baseline_path:
        full = baseline_path if os.path.isabs(baseline_path) \
            else os.path.join(root, baseline_path)
        if os.path.exists(full):
            entries = load_baseline(full)
        else:
            baseline_missing = check_baseline
    new, baselined, stale = apply_baseline(active, entries)
    per_code: Dict[str, int] = {}
    for f in active:
        per_code[f.code] = per_code.get(f.code, 0) + 1
    ok = not new and not (check_baseline and (stale or baseline_missing))
    return {
        "ok": ok,
        "summary": {
            "files": len(set(f.path for f in active + disabled))
            or None,
            "new": len(new), "baselined": len(baselined),
            "disabled": len(disabled), "stale_baseline": len(stale),
            "per_code": dict(sorted(per_code.items())),
        },
        "new": [f.to_json() for f in new],
        "baselined": [f.to_json() for f in baselined],
        "disabled": [f.to_json() for f in disabled],
        "stale_baseline": stale,
        "baseline_missing": baseline_missing,
    }


def _print_report(report: Dict, check_baseline: bool) -> None:
    for f in report["new"]:
        print(f"{f['path']}:{f['line']}:{f['col']}: {f['code']} "
              f"{f['message']}")
    if check_baseline:
        for e in report["stale_baseline"]:
            print(f"STALE baseline entry: {e['code']} {e['path']} — "
                  f"{e['message']!r} is no longer reported; prune it "
                  f"(the ratchet only goes down)")
        if report["baseline_missing"]:
            print("baseline file not found — run without "
                  "--check-baseline and commit analysis/baseline.json")
    s = report["summary"]
    print(f"repro-lint: {s['new']} new, {s['baselined']} baselined, "
          f"{s['disabled']} inline-disabled"
          + (f", {s['stale_baseline']} stale baseline entr"
             f"{'ies' if s['stale_baseline'] != 1 else 'y'}"
             if check_baseline else ""))


def find_root(start: Optional[str] = None) -> str:
    """Repo root: nearest ancestor with .git or analysis/, else cwd."""
    d = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.isdir(os.path.join(d, ".git")) \
                or os.path.isdir(os.path.join(d, "analysis")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start or os.getcwd())
        d = parent


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: tracked "
                         "*.py under src/, benchmarks/, examples/)")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="write the full report as JSON to PATH "
                         "(default stdout)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    metavar="PATH",
                    help=f"baseline file (default {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report every finding)")
    ap.add_argument("--check-baseline", action="store_true",
                    help="CI gate: fail on new findings AND on stale "
                         "baseline entries (the ratchet)")
    ap.add_argument("--list-checks", action="store_true",
                    help="print the finding-code catalog and exit")
    args = ap.parse_args(argv)

    if args.list_checks:
        for code, desc in sorted(CODES.items()):
            print(f"{code}  {desc}")
        return 0

    root = find_root()
    try:
        report = run(root, args.paths,
                     baseline_path=None if args.no_baseline
                     else args.baseline,
                     check_baseline=args.check_baseline)
    except BaselineError as e:
        print(f"repro-lint: {e}", file=sys.stderr)
        return 2

    if args.json is not None:
        payload = json.dumps(report, indent=2, sort_keys=True)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as f:
                f.write(payload + "\n")
            print(f"wrote {args.json}")
    if args.json != "-":
        _print_report(report, args.check_baseline)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
