"""Pallas kernel checks (RA501–RA503, DESIGN.md §14).

The three kernels under ``kernels/`` are proven against ``kernels/
ref.py`` by the PR 7 exactness-oracle suite — at *runtime*, on the
shapes the suite draws.  These checks pin the structural contracts
statically, so a grid/BlockSpec drift is caught before any oracle run:

* **RA501** — every ``BlockSpec`` ``index_map`` of a ``pallas_call``
  must take exactly ``len(grid)`` parameters.  A missing grid axis
  silently broadcasts the block over the dropped axis.
* **RA502** — the ``index_map`` must return one coordinate per block
  dimension, and where both a block dim and the matching
  ``out_shape`` dim resolve to compile-time ints (literals or tile
  constants like ``LANES = 128``), the block dim must divide the
  array dim — the static half of the ``T % bq == 0`` runtime asserts.
* **RA503** — matmuls inside kernel bodies must accumulate in f32:
  every ``dot``/``dot_general``/``einsum``/``@`` either passes
  ``preferred_element_type`` or takes operands visibly cast via
  ``.astype(jnp.float32)``.  Reading a ``*_ref`` input raw into a
  matmul is flagged — on bf16 inputs the MXU would accumulate in bf16
  and the PR 7 ULP budgets no longer hold.  Kernel bodies are
  functions named ``*_kernel`` or passed (possibly via
  ``functools.partial``) as the first argument of a ``pallas_call``.

Resolution is best-effort and conservative: dims or maps the checker
cannot resolve statically are skipped, never guessed.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.base import (Finding, SourceFile, const_int,
                                 dotted_name, int_env, walk_functions)

_DOT_CALLS = {"dot", "dot_general", "einsum", "matmul"}


def _callee(node: ast.Call) -> Optional[str]:
    parts = dotted_name(node.func)
    return parts[-1] if parts else None


def _is_pallas_call(node: ast.Call) -> bool:
    parts = dotted_name(node.func)
    return bool(parts) and parts[-1] == "pallas_call"


def _is_blockspec(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and _callee(node) == "BlockSpec"


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _specs(node: Optional[ast.expr]) -> List[ast.Call]:
    """BlockSpec calls inside an in_specs/out_specs expression."""
    if node is None:
        return []
    if _is_blockspec(node):
        return [node]
    if isinstance(node, (ast.List, ast.Tuple)):
        return [e for e in node.elts if _is_blockspec(e)]
    return []


def _spec_shape(spec: ast.Call) -> Optional[ast.expr]:
    shape = _kwarg(spec, "block_shape")
    if shape is None and spec.args:
        shape = spec.args[0]
    return shape if isinstance(shape, (ast.Tuple, ast.List)) else None


def _spec_index_map(spec: ast.Call) -> Optional[ast.Lambda]:
    im = _kwarg(spec, "index_map")
    if im is None and len(spec.args) >= 2:
        im = spec.args[1]
    return im if isinstance(im, ast.Lambda) else None


def _grid_arity(call: ast.Call, env: Dict[str, int]) -> Optional[int]:
    grid = _kwarg(call, "grid")
    if grid is None:
        return None
    if isinstance(grid, (ast.Tuple, ast.List)):
        return len(grid.elts)
    v = const_int(grid, env)
    return 1 if v is not None else None


class PallasChecker:
    code_prefix = "RA5"
    name = "pallas"

    def check(self, src: SourceFile) -> List[Finding]:
        out: List[Finding] = []
        env = int_env(getattr(src.tree, "body", []))
        kernel_names: Set[str] = {
            fn.name for fn in walk_functions(src.tree)
            if fn.name.endswith("_kernel")}

        for fn in walk_functions(src.tree):
            # function-local tile constants extend the module ones
            local_env = dict(env)
            local_env.update(int_env(fn.body))
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and _is_pallas_call(node):
                    kernel_names.add(self._kernel_name(node))
                    out += self._check_call(src, node, local_env)

        out += self._check_accumulation(src, kernel_names, env)
        return out

    @staticmethod
    def _kernel_name(call: ast.Call) -> str:
        """Name of the kernel function handed to pallas_call (unwraps
        functools.partial and local bindings by name only)."""
        if not call.args:
            return ""
        k = call.args[0]
        if isinstance(k, ast.Call) and _callee(k) == "partial" and k.args:
            k = k.args[0]
        return k.id if isinstance(k, ast.Name) else ""

    # -- RA501 / RA502 ----------------------------------------------------
    def _check_call(self, src: SourceFile, call: ast.Call,
                    env: Dict[str, int]) -> List[Finding]:
        out: List[Finding] = []
        # grid_spec=pl.GridSpec(grid=..., in_specs=..., out_specs=...)
        host = call
        gs = _kwarg(call, "grid_spec")
        if isinstance(gs, ast.Call) and _callee(gs) in ("GridSpec",
                                                        "PrefetchScalarGridSpec"):
            host = gs
        arity = _grid_arity(host, env)
        specs = []
        for role in ("in_specs", "out_specs"):
            for i, spec in enumerate(_specs(_kwarg(host, role))):
                specs.append((role, i, spec))

        out_shapes = self._out_shapes(call, env)

        for role, i, spec in specs:
            im = _spec_index_map(spec)
            shape = _spec_shape(spec)
            where = f"{role}[{i}]"
            if im is not None and arity is not None:
                n_params = len(im.args.posonlyargs) + len(im.args.args)
                if im.args.vararg is None and n_params != arity:
                    out.append(Finding(
                        "RA501", src.path, spec.lineno, spec.col_offset,
                        f"{where} index_map takes {n_params} parameter"
                        f"{'s' if n_params != 1 else ''} but the grid "
                        f"has {arity} ax{'es' if arity != 1 else 'is'}"))
            if im is not None and shape is not None:
                ret = im.body
                ret_len = len(ret.elts) if isinstance(
                    ret, (ast.Tuple, ast.List)) else 1
                if ret_len != len(shape.elts):
                    out.append(Finding(
                        "RA502", src.path, spec.lineno, spec.col_offset,
                        f"{where} block shape has {len(shape.elts)} "
                        f"dims but index_map returns {ret_len} "
                        f"coordinate{'s' if ret_len != 1 else ''}"))
            # static divisibility against the matching out_shape
            if role == "out_specs" and shape is not None \
                    and i < len(out_shapes) and out_shapes[i] is not None:
                arr = out_shapes[i]
                if len(arr.elts) == len(shape.elts):
                    for d, (b_e, a_e) in enumerate(
                            zip(shape.elts, arr.elts)):
                        b, a = const_int(b_e, env), const_int(a_e, env)
                        if b and a and b > 0 and a % b:
                            out.append(Finding(
                                "RA502", src.path, spec.lineno,
                                spec.col_offset,
                                f"{where} block dim {d} is {b} but the "
                                f"output array dim is {a} — blocks "
                                f"must tile the array exactly"))
        return out

    @staticmethod
    def _out_shapes(call: ast.Call, env: Dict[str, int]
                    ) -> List[Optional[ast.expr]]:
        """Shape tuples of the out_shape ShapeDtypeStructs (None where
        unresolvable)."""
        node = _kwarg(call, "out_shape")
        if node is None:
            return []
        structs = node.elts if isinstance(node, (ast.List, ast.Tuple)) \
            else [node]
        shapes: List[Optional[ast.expr]] = []
        for s in structs:
            if isinstance(s, ast.Call) \
                    and _callee(s) == "ShapeDtypeStruct" and s.args \
                    and isinstance(s.args[0], (ast.Tuple, ast.List)):
                shapes.append(s.args[0])
            else:
                shapes.append(None)
        return shapes

    # -- RA503 ------------------------------------------------------------
    def _check_accumulation(self, src: SourceFile,
                            kernel_names: Set[str],
                            env: Dict[str, int]) -> List[Finding]:
        out: List[Finding] = []
        for fn in walk_functions(src.tree):
            if fn.name not in kernel_names:
                continue
            ref_params = {a.arg for a in fn.args.args
                          if a.arg.endswith("_ref")}
            # one-hop local bindings: name -> RHS expression
            bindings: Dict[str, ast.expr] = {}
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) \
                        and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    bindings[node.targets[0].id] = node.value

            def low_precision(expr: ast.AST, hop: int = 0) -> bool:
                """Operand visibly at the input dtype: a raw *_ref read
                (no astype(f32) on the path) or an explicit cast to
                bf16/f16.  Unknown derivations are NOT flagged."""
                for n in ast.walk(expr):
                    if isinstance(n, ast.Call) \
                            and isinstance(n.func, ast.Attribute) \
                            and n.func.attr == "astype":
                        parts = dotted_name(n.args[0]) if n.args else None
                        if parts and parts[-1] in ("bfloat16", "float16"):
                            return True
                        # astype(float32) launders the whole expression
                        if parts and parts[-1] in ("float32", "float64"):
                            return False
                for n in ast.walk(expr):
                    if isinstance(n, ast.Subscript) \
                            and isinstance(n.value, ast.Name) \
                            and n.value.id in ref_params:
                        return True
                if isinstance(expr, ast.Name) and hop == 0 \
                        and expr.id in bindings:
                    return low_precision(bindings[expr.id], hop=1)
                return False

            for node in ast.walk(fn):
                operands = None
                if isinstance(node, ast.Call) \
                        and _callee(node) in _DOT_CALLS:
                    if _kwarg(node, "preferred_element_type") is not None:
                        continue
                    operands = [a for a in node.args
                                if not (isinstance(a, ast.Constant)
                                        and isinstance(a.value, str))]
                    # dot_general's dimension_numbers tuple isn't data
                    if _callee(node) == "dot_general":
                        operands = operands[:2]
                elif isinstance(node, ast.BinOp) \
                        and isinstance(node.op, ast.MatMult):
                    operands = [node.left, node.right]
                if not operands:
                    continue
                if any(low_precision(op) for op in operands):
                    out.append(Finding(
                        "RA503", src.path, node.lineno, node.col_offset,
                        f"matmul in kernel {fn.name!r} consumes a raw "
                        f"input-dtype operand with no "
                        f"preferred_element_type — on bf16 inputs the "
                        f"MXU accumulates in bf16; cast with "
                        f".astype(jnp.float32) or set "
                        f"preferred_element_type=jnp.float32"))
        return out
