"""Static deprecation firewall (RA401, DESIGN.md §14).

DESIGN.md §9 retired ten pre-``Fleet``/``Plan`` entry points as
warn-once shims, and ``pytest.ini`` turns their DeprecationWarnings into
tier-1 errors — but only on paths a test actually executes.  This
checker enforces the same contract *statically*: no module under
``src/repro/`` or ``benchmarks/`` may import or call a shim, whether or
not any test reaches the line.

Flagged forms (resolved through the file's import map):

* ``from repro.core.scheduler import solve`` — the import itself;
* ``scheduler.solve(...)`` / ``repro.core.cost_model.t_total(...)`` —
  attribute calls landing in a shim module;
* bare ``solve(...)`` after a flagged ``from``-import (reported once,
  at the import).

The modules that *define* the shims are exempt for their own
definitions (a ``def`` is not a call); their internal delegation goes
through the ``_``-prefixed canonical engines, so a hit inside them is
still a real violation.  Tests are outside the lint scope on purpose:
they assert on shim behaviour and stay free to call them.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.base import Finding, Imports, SourceFile

# module -> deprecated names (DESIGN.md §9's ten legacy entry points).
SHIMS: Dict[str, Set[str]] = {
    "repro.core.scheduler": {"solve", "solve_multi"},
    "repro.core.cost_model": {"t_total", "t_total_batch",
                              "t_total_multi", "t_total_multi_batch"},
    "repro.core.simulator": {"simulate_iteration",
                             "simulate_iteration_multi"},
    "repro.train.loop": {"run_hier_loop", "run_multi_hier_loop"},
}

_REPLACEMENT = "repro.api.plan()/Fleet (see DESIGN.md §9)"


def _is_shim(path: str) -> bool:
    mod, _, attr = path.rpartition(".")
    return attr in SHIMS.get(mod, set())


class ShimFirewallChecker:
    code_prefix = "RA4"
    name = "shim-firewall"

    def check(self, src: SourceFile) -> List[Finding]:
        imports = Imports(src.tree)
        out: List[Finding] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for alias in node.names:
                    if alias.name in SHIMS.get(node.module, set()):
                        out.append(Finding(
                            "RA401", src.path, node.lineno,
                            node.col_offset,
                            f"import of deprecated shim "
                            f"{node.module}.{alias.name} — use "
                            f"{_REPLACEMENT}"))
            elif isinstance(node, ast.Call):
                path = imports.resolve(node.func)
                if path and _is_shim(path):
                    out.append(Finding(
                        "RA401", src.path, node.lineno, node.col_offset,
                        f"call to deprecated shim {path} — use "
                        f"{_REPLACEMENT}"))
        return out
