"""Units lint (RA301/RA302, DESIGN.md §14): suffix-driven dimensional
analysis over the wire/cost-model modules.

PR 7's symmetric-dtype bug was a *units* bug: a byte count flowed into
arithmetic that assumed element counts, silently moving every optimal
cut the ``fig_wire`` benchmark later measured.  The identifiers in the
cost model already carry their units as suffixes (``act_bytes``,
``resolved_grad_elems``, ``uplink_mbps``) — this checker makes those
suffixes load-bearing.

Unit families (suffix match on the last identifier segment, or the
bare word): ``bytes``, ``elems``, ``mb``/``kb``/``gb``, ``mbps``.
Rules, deliberately conservative (unknown never flags):

* **RA301** — ``+``, ``-``, ``*`` or a comparison whose two operands
  have *known, different* families mixes units.  Division is the
  canonical conversion (``x_mb / bw_mbps`` is seconds, ``bytes / 4``
  is elements) and never flags; its result is unknown.  A function
  call is a conversion boundary: its result takes the unit of the
  *callee's* suffix (``int8_wire_bytes(...)`` is bytes), never its
  arguments'.
* **RA302** — a value of one family bound to a name of another:
  assignment targets, keyword arguments, positional arguments matched
  against same-module parameter names, and ``return`` against the
  enclosing function's name suffix.  This is the PR 7 shape —
  ``f(act_elems=x_bytes)`` — caught at the call site.

Identifiers containing ``_per_`` are rates and read as unknown.  The
intended escape hatch at a real conversion point (int8: one byte per
element) is an inline ``# repro-lint: disable=RA301 <why>``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.base import Finding, SourceFile, walk_functions

# suffix -> family.  mb/kb/gb are one family (decimal data sizes) but
# distinct from raw bytes: mixing them without a conversion is exactly
# the 1e6-factor bug class.
_FAMILY = {
    "bytes": "bytes",
    "elems": "elems",
    "mb": "mb", "kb": "mb", "gb": "mb",
    "mbps": "mbps",
}


def unit_of_name(identifier: str) -> Optional[str]:
    """Unit family of an identifier, by suffix (``act_bytes``) or bare
    word (``elems``).  ``_per_`` names are rates: unknown."""
    low = identifier.lower()
    if "_per_" in low:
        return None
    for suffix, family in _FAMILY.items():
        if low == suffix or low.endswith("_" + suffix):
            return family
    return None


class _Units(ast.NodeVisitor):
    def __init__(self, src: SourceFile, param_units: Dict[str, Dict]):
        self.src = src
        self.param_units = param_units     # fn name -> pos -> family
        self.findings: List[Finding] = []
        self._fn_stack: List[str] = []

    # -- expression unit inference --------------------------------------
    def unit(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            return unit_of_name(node.id)
        if isinstance(node, ast.Attribute):
            return unit_of_name(node.attr)
        if isinstance(node, ast.Call):
            # conversion boundary: result unit = callee suffix
            f = node.func
            callee = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None)
            return unit_of_name(callee) if callee else None
        if isinstance(node, ast.BinOp):
            lu, ru = self.unit(node.left), self.unit(node.right)
            if isinstance(node.op, (ast.Add, ast.Sub)):
                return lu or ru
            if isinstance(node.op, ast.Mult):
                # rate * count converts (bytes_per_elem * elems is
                # bytes, not elems): result unknown, never flagged.
                if self._is_rate(node.left) or self._is_rate(node.right):
                    return None
                # unit * dimensionless keeps the unit
                if lu and ru is None:
                    return lu
                if ru and lu is None:
                    return ru
                return None
            return None                     # division etc.: converted
        if isinstance(node, ast.UnaryOp):
            return self.unit(node.operand)
        if isinstance(node, ast.IfExp):
            return self.unit(node.body) or self.unit(node.orelse)
        return None

    @staticmethod
    def _is_rate(node: ast.AST) -> bool:
        ident = None
        if isinstance(node, ast.Name):
            ident = node.id
        elif isinstance(node, ast.Attribute):
            ident = node.attr
        return bool(ident) and "_per_" in ident.lower()

    def _flag_mix(self, node: ast.AST, lu: str, ru: str,
                  what: str) -> None:
        self.findings.append(Finding(
            "RA301", self.src.path, node.lineno, node.col_offset,
            f"{what} mixes unit families {lu!r} and {ru!r} without an "
            f"explicit conversion — route one side through a "
            f"conversion call or divide by the unit factor"))

    # -- RA301 -----------------------------------------------------------
    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub, ast.Mult, ast.Mod)):
            lu, ru = self.unit(node.left), self.unit(node.right)
            if lu and ru and lu != ru:
                op = type(node.op).__name__.lower()
                self._flag_mix(node, lu, ru, f"'{op}' arithmetic")
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        lu = self.unit(node.left)
        for comp in node.comparators:
            ru = self.unit(comp)
            if lu and ru and lu != ru:
                self._flag_mix(node, lu, ru, "comparison")
        self.generic_visit(node)

    # -- RA302 -----------------------------------------------------------
    def visit_Assign(self, node: ast.Assign) -> None:
        vu = self.unit(node.value)
        if vu:
            for t in node.targets:
                tu = None
                if isinstance(t, ast.Name):
                    tu = unit_of_name(t.id)
                elif isinstance(t, ast.Attribute):
                    tu = unit_of_name(t.attr)
                if tu and tu != vu:
                    self.findings.append(Finding(
                        "RA302", self.src.path, node.lineno,
                        node.col_offset,
                        f"a {vu!r} value is assigned to "
                        f"{self._tname(t)!r} ({tu}) — convert "
                        f"explicitly or rename"))
        self.generic_visit(node)

    @staticmethod
    def _tname(t: ast.AST) -> str:
        if isinstance(t, ast.Name):
            return t.id
        if isinstance(t, ast.Attribute):
            return t.attr
        return "<target>"

    def visit_Call(self, node: ast.Call) -> None:
        for kw in node.keywords:
            if kw.arg is None:
                continue
            tu = unit_of_name(kw.arg)
            vu = self.unit(kw.value)
            if tu and vu and tu != vu:
                self.findings.append(Finding(
                    "RA302", self.src.path, kw.value.lineno,
                    kw.value.col_offset,
                    f"a {vu!r} value is passed for keyword "
                    f"{kw.arg!r} ({tu}) — the callee expects {tu}, "
                    f"convert at the call site"))
        # positional args against same-module parameter names
        f = node.func
        fname = f.id if isinstance(f, ast.Name) else None
        pmap = self.param_units.get(fname or "", {})
        for i, arg in enumerate(node.args):
            tu = pmap.get(i)
            vu = self.unit(arg)
            if tu and vu and tu != vu:
                self.findings.append(Finding(
                    "RA302", self.src.path, arg.lineno, arg.col_offset,
                    f"a {vu!r} value is passed to parameter "
                    f"{pmap.get(('name', i), i)!r} ({tu}) of "
                    f"{fname}() — convert at the call site"))
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._fn_stack.append(node.name)
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Return(self, node: ast.Return) -> None:
        if node.value is not None and self._fn_stack:
            fu = unit_of_name(self._fn_stack[-1])
            vu = self.unit(node.value)
            if fu and vu and fu != vu:
                self.findings.append(Finding(
                    "RA302", self.src.path, node.lineno, node.col_offset,
                    f"{self._fn_stack[-1]}() is named as {fu!r} but "
                    f"returns a {vu!r} value — convert before "
                    f"returning"))
        self.generic_visit(node)


class UnitsChecker:
    code_prefix = "RA3"
    name = "units"

    def check(self, src: SourceFile) -> List[Finding]:
        # parameter units of same-module functions, for positional RA302
        param_units: Dict[str, Dict] = {}
        for fn in walk_functions(src.tree):
            args = fn.args.posonlyargs + fn.args.args
            pmap: Dict = {}
            for i, a in enumerate(args):
                u = unit_of_name(a.arg)
                if u:
                    pmap[i] = u
                    pmap[("name", i)] = a.arg
            if pmap:
                param_units.setdefault(fn.name, pmap)
        v = _Units(src, param_units)
        v.visit(src.tree)
        return v.findings
