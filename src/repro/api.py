"""One front door: ``Fleet`` → :func:`plan` → :class:`Plan`
(DESIGN.md §9).

HierTrain's value is one decision — where to cut layers and how to split
samples across an M-device/edge/cloud fleet (Algorithm 1).  This module
is the single entry point to that decision and everything downstream of
it:

    from repro.api import Fleet, plan

    fleet = Fleet.from_table2(model="lenet5")          # paper testbed
    p = plan(lenet5(), fleet, B=64)                    # Algorithm 1
    print(p.explain())                                 # cut/split/cost map
    p.simulate()                                       # DES validation
    step = p.step_fn(lr=0.05)                          # jitted hybrid SGD
    out = p.train(data, steps=100)                     # straggler-aware loop

The classic (device, edge, cloud) triple is exactly a :class:`Fleet` at
``M = 1``; a heterogeneous M-device star is the same call with ``m >= 2``
(or any custom :class:`Fleet`).  ``plan`` resolves to the topology-native
engine — bit-for-bit identical across topologies at M = 1 for the
latency objective — and the returned :class:`Plan` carries the chosen
schedule, the predicted ``t_total``/``t_period``, and executable methods.

Every pre-facade entry point (``solve``/``solve_multi``, ``t_total*``,
``simulate_iteration*``, ``run_*_hier_loop``) survives as a thin
deprecation shim over this module and returns bit-identical results
(``tests/test_api.py`` asserts it).

CLI smoke: ``python -m repro.api --explain lenet5 [--m 2] [--batch 64]``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Union

import numpy as np

from repro.core import pipeline as _pipeline
from repro.core import scheduler as _scheduler
from repro.core import simulator as _simulator
from repro.core.cost_model import (Breakdown, MultiSchedule, Schedule,
                                   _t_total_multi)
from repro.core.fleet import STAR, TREE, TRIPLE, Fleet
from repro.core.layerstack import LayerStack, as_layerstack

__all__ = ["Fleet", "Plan", "plan", "as_layerstack"]

OBJECTIVES = _scheduler.OBJECTIVES


@dataclasses.dataclass
class Plan:
    """The resolved HierTrain decision for one (model, fleet, B) triple.

    ``schedule`` is the topology-native object (a ``Schedule`` on the
    classic triple, a ``MultiSchedule`` on a star) — use
    :attr:`multi_schedule` for the unified view.  ``result`` is the full
    native scheduler result (LP/prune counters, search log).
    """
    fleet: Fleet
    B: int
    objective: str
    pipeline_depth: int
    backend: str
    profile: Any                  # HierProfile | MultiProfile (native;
    #                               wire-compressed MO/MG when wire != none)
    network: Any                  # Network | StarNetwork (native)
    result: Any                   # SchedulerResult | MultiSchedulerResult
    wire: str = "none"            # cut-point transfer codec (core/wire.py)
    model: Optional[LayerStack] = None

    # ---- the decision ---------------------------------------------------

    @property
    def schedule(self) -> Union[Schedule, MultiSchedule]:
        return self.result.schedule

    @property
    def multi_schedule(self) -> MultiSchedule:
        """The schedule in the unified M-device representation."""
        s = self.schedule
        return s if isinstance(s, MultiSchedule) \
            else MultiSchedule.from_schedule(s)

    @property
    def breakdown(self) -> Breakdown:
        """Exact per-phase Eq.-12 latencies of the chosen schedule."""
        return self.result.breakdown

    @property
    def t_total(self) -> float:
        """Predicted single-iteration (barrier) latency, seconds."""
        return self.result.t_total

    @property
    def t_period(self) -> float:
        """Predicted pipelined steady-state period (DESIGN.md §7)."""
        return self.result.t_period

    def pipeline_time(self, K: Optional[int] = None) -> float:
        """Model wall-clock of a depth-K pipelined run:
        ``T(K) = T_fill + (K - 1) * T_period``.  ``K`` defaults to the
        plan's ``pipeline_depth``."""
        K = self.pipeline_depth if K is None else K
        return _pipeline.t_pipeline(self.profile, self.network,
                                    self.schedule, K)

    # ---- validation -----------------------------------------------------

    def simulate(self, K: int = 1) -> float:
        """Discrete-event-simulated makespan of ``K`` pipelined
        iterations (``K = 1``: one barrier iteration).  Runs the
        topology-native DES, so triple fleets reproduce the paper's
        three-worker simulation exactly."""
        if K == 1:
            if self.fleet.topology == TRIPLE:
                return _simulator._simulate_iteration(
                    self.profile, self.network, self.schedule)
            return _simulator._simulate_iteration_multi(
                self.profile, self.network, self.schedule)
        return _simulator.simulate_pipeline(self.profile, self.network,
                                            self.schedule, K)

    def baseline(self, tier: str) -> float:
        """Exact ``T_total`` of the all-on-one-worker baseline schedule
        (``tier`` in ``"device" | "edge" | "cloud"``) on this fleet's
        cost model — the paper's All-Edge/All-Cloud comparison points."""
        if tier not in ("device", "edge", "cloud"):
            raise ValueError(f"unknown baseline tier: {tier!r} "
                             f"(pick 'device', 'edge' or 'cloud')")
        if self.fleet.topology == TRIPLE:
            from repro.core.baselines import all_on_one
            return all_on_one(self.profile, self.network, self.B,
                              tier).t_total
        prof = self.profile
        names = prof.worker_names
        S = prof.num_streams
        wo = tier if tier in ("edge", "cloud") else names[0]
        if wo == "edge" and wo not in names:    # tree: edge_0.. at E >= 2
            wo = names[prof.num_devices]
        rest = [w for w in names if w != wo]
        sched = MultiSchedule(worker_o=wo, worker_l=rest[-1],
                              s_workers=tuple(rest[:-1]), m_s=(0,) * S,
                              m_l=0, b_o=self.B, b_s=(0,) * S, b_l=0)
        return _t_total_multi(prof, self.network, sched).total

    # ---- execution ------------------------------------------------------

    def _require_model(self) -> LayerStack:
        if self.model is None:
            raise ValueError(
                "this Plan was built without a model (profile-only "
                "fleet); pass a model/LayerStack to plan() to execute")
        return self.model

    def stream_edges(self) -> tuple:
        """Per-TASK-S-stream hosting edge (tree fleets): a device stream
        sits under its radio's edge, an edge's own stream under itself,
        and a cloud-hosted stream merges with the front group (index 0 —
        on an E=1 tree every stream maps to edge 0, which is what keeps
        the traced step identical to the star's)."""
        from repro.core.hybrid_step import tree_stream_edges
        return tree_stream_edges(self.profile, self.network,
                                 self.multi_schedule)

    def step_fn(self, lr: float = 0.05, cloud_mesh=None) -> Callable:
        """A compiled ``(params, x, y) -> (new_params, loss)`` hybrid-SGD
        step for the chosen schedule (exact batch-B SGD semantics;
        ``params`` donated, executables cached per cut tuple).

        ``cloud_mesh`` (tree fleets only) runs the cloud tail segment
        data-parallel over the mesh's dp axes via ``shard_map``
        (DESIGN.md §12); the batch must divide by the dp shard count."""
        import jax.numpy as jnp

        stack = self._require_model()
        sched = self.schedule
        if cloud_mesh is not None and self.fleet.topology != TREE:
            raise ValueError("cloud_mesh is a tree-topology option; this "
                             f"plan's fleet is {self.fleet.topology!r}")
        if self.fleet.topology == TRIPLE:
            from repro.core.hybrid_step import (jitted_hybrid_step,
                                                split_batch)
            fn = jitted_hybrid_step(stack, sched.m_s, sched.m_l, lr,
                                    wire=self.wire)

            def step(params, x, y):
                return fn(params, split_batch(jnp.asarray(x),
                                              jnp.asarray(y), sched))
        elif self.fleet.topology == TREE:
            from repro.core.hybrid_step import (jitted_tree_hybrid_step,
                                                multi_split_batch)
            fn = jitted_tree_hybrid_step(stack, sched.m_s, sched.m_l, lr,
                                         wire=self.wire,
                                         stream_edge=self.stream_edges(),
                                         cloud_mesh=cloud_mesh)

            def step(params, x, y):
                return fn(params, multi_split_batch(jnp.asarray(x),
                                                    jnp.asarray(y), sched))
        else:
            from repro.core.hybrid_step import (jitted_multi_hybrid_step,
                                                multi_split_batch)
            fn = jitted_multi_hybrid_step(stack, sched.m_s, sched.m_l, lr,
                                          wire=self.wire)

            def step(params, x, y):
                return fn(params, multi_split_batch(jnp.asarray(x),
                                                    jnp.asarray(y), sched))
        return step

    def init_params(self, key) -> Any:
        """Consensus initial weights (one pytree per cut-point)."""
        return self._require_model().init(key)

    def train(self, data, steps: int, lr: float = 0.05,
              resched_every: int = 20, ema: float = 0.3, seed: int = 0,
              worker_slowdown: Optional[Callable[[int], Dict[str, float]]]
              = None,
              log: Optional[Callable[[str], None]] = None, *,
              churn=None, ckpt_dir: Optional[str] = None,
              ckpt_every: int = 50, keep: int = 3,
              fail_at: Optional[int] = None) -> Dict[str, Any]:
        """Straggler-aware HierTrain loop: real hybrid JAX steps for the
        numerics, the calibrated cost model for the wall clock, online
        EMA re-profiling + re-scheduling every ``resched_every`` steps,
        and pipelined fill+period accounting when the plan was built with
        ``pipeline_depth > 1``.  Returns ``{params, history, wall,
        final_schedule, resumed_from, churn_log}``.

        ``churn`` — a :class:`repro.core.churn.ChurnTrace` of membership
        events for elastic star fleets (DESIGN.md §10); raises
        ``NotImplementedError`` naming the topology on any other fleet.  ``ckpt_dir``/``ckpt_every``/``keep``
        enable atomic keep-N checkpointing and crash-safe resume: rerun
        the same call after a crash and the loop restores the newest
        checkpoint and continues, bitwise equal to an uninterrupted run.
        ``fail_at`` injects a failure after that step (testing).  All
        four default off — the loop is then bit-identical to its
        pre-elastic behaviour."""
        from repro.train.loop import HierLoopConfig, _run_loop
        if churn is not None and self.fleet.topology != STAR:
            raise NotImplementedError(
                "churn (elastic membership) is only implemented for the "
                f"star topology; this plan's fleet is "
                f"topology={self.fleet.topology!r}")
        cfg = HierLoopConfig(
            total_steps=steps, batch=self.B, lr=lr,
            resched_every=resched_every, ema=ema, seed=seed,
            pipeline_depth=self.pipeline_depth, objective=self.objective,
            wire=self.wire, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
            keep=keep, fail_at=fail_at)
        return _run_loop(cfg, self._require_model(), self.profile,
                         self.network, data, worker_slowdown, log,
                         topology=self.fleet.topology,
                         initial_schedule=self.schedule, churn=churn)

    # ---- reporting ------------------------------------------------------

    def explain(self) -> str:
        """Human-readable cut/split/cost breakdown of the decision."""
        bd = self.breakdown
        s = self.schedule
        res = self.result
        name = self.model.name if self.model is not None else "(profile)"
        ms = s.m_s if isinstance(s.m_s, int) else \
            "/".join(str(m) for m in s.m_s)
        t_edge, t_cloud = self.baseline("edge"), self.baseline("cloud")
        lines = [
            f"HierTrain plan — model={name}  fleet[{self.fleet.describe()}]",
            f"  batch B={self.B}  objective={self.objective}  "
            f"backend={self.backend}  wire={self.wire}",
            f"  schedule: {s.describe()}",
            f"  cuts: m_s={ms}  m_l={s.m_l}  of N={self.profile.num_layers}"
            f" layers",
            f"  predicted: T_total={bd.total:.6g}s  "
            f"T_period={self.t_period:.6g}s",
            f"  phases (s): f1={bd.t_f1:.4g} b1={bd.t_b1:.4g} "
            f"f2={bd.t_f2:.4g} b2={bd.t_b2:.4g} f3={bd.t_f3:.4g} "
            f"b3={bd.t_b3:.4g} update={bd.t_update:.4g}",
            f"  comm (s): input={bd.comm_input:.4g} "
            f"activation={bd.comm_activation:.4g} "
            f"weight-sync={bd.comm_weightgrad:.4g}",
            f"  baselines: all-edge={t_edge:.6g}s "
            f"({t_edge / bd.total:.2f}x)  all-cloud={t_cloud:.6g}s "
            f"({t_cloud / bd.total:.2f}x)",
        ]
        if self.pipeline_depth > 1:
            K = self.pipeline_depth
            tk = self.pipeline_time(K)
            lines.append(
                f"  pipelined: T(K={K})={tk:.6g}s vs barrier "
                f"{K * bd.total:.6g}s ({K * bd.total / tk:.2f}x)")
        search = (f"  search: {res.n_candidates} candidates, "
                  f"{res.n_pruned} pruned, {res.n_lp_solved} LPs")
        if getattr(res, "n_lp_refine", 0):
            search += (f" (+{res.n_lp_refine} refine LPs, "
                       f"{res.refine_rounds} rounds)")
        lines.append(search)
        return "\n".join(lines)


def _prepare(model, fleet: Fleet, wire: Optional[str]):
    """Shared plan-request prep: resolve the wire codec, adapt the model
    to a :class:`LayerStack`, build the wire-adjusted profile and the
    native network.  Used by :func:`plan` and by the cross-fleet planner
    (``repro.serve.planner``), so both see identical solver inputs."""
    from repro.core.wire import apply_wire, validate_wire
    wire = fleet.wire if wire is None else validate_wire(wire)
    stack = as_layerstack(model) if model is not None else None
    profile = apply_wire(fleet.profile_for(stack), stack, wire)
    net = fleet.network()
    return stack, profile, net, wire


def plan_many(requests, **kwargs):
    """Batch front door: plan many fleets in shared tableau stacks with a
    fingerprinted plan cache (``repro.serve.planner``, DESIGN.md §13).
    Takes :class:`repro.serve.planner.PlanRequest` items (or anything the
    planner coerces); returns plans in request order."""
    from repro.serve import planner as _planner
    return _planner.plan_many(requests, **kwargs)


def plan(model, fleet: Fleet, B: int, *, objective: str = "latency",
         pipeline_depth: int = 1, backend: str = "batched",
         wire: Optional[str] = None,
         prune: bool = True, refine_passes: int = 4,
         keep_log: bool = False,
         warm_start: Optional[Union[Schedule, MultiSchedule]] = None
         ) -> Plan:
    """Solve Algorithm 1 for ``(model, fleet, B)`` and return a
    :class:`Plan`.

    ``model`` is anything :func:`repro.core.layerstack.as_layerstack`
    accepts (a layered CNN, an LM model-zoo adapter, any ``LayerStack``),
    or ``None`` for pinned-profile fleets used purely for scheduling.
    ``objective`` is ``"latency"`` (Eq.-12 ``T_total``) or
    ``"throughput"`` (steady-state period, DESIGN.md §7);
    ``pipeline_depth`` records how many minibatches ``Plan.train`` keeps
    in flight.  ``backend``/``prune``/``refine_passes``/``keep_log`` are
    forwarded to the topology-native engine.  ``warm_start`` (a feasible
    topology-native schedule, e.g. the live one before a fleet change)
    tightens the dominance prune without changing the result
    (DESIGN.md §10).

    ``wire`` selects the cut-point transfer codec (DESIGN.md §11):
    ``None`` inherits ``fleet.wire``; ``"int8"`` both *plans with* the
    compressed ``MO``/``MG`` wire sizes (so Algorithm 1 sees the
    compressed split-point traffic — optimal cuts legitimately move)
    and *executes* the matching quantize→dequantize codec in
    :meth:`Plan.step_fn` / :meth:`Plan.train`.
    """
    if pipeline_depth < 1:
        raise ValueError("pipeline_depth must be >= 1")
    stack, profile, net, wire = _prepare(model, fleet, wire)
    if fleet.topology == TRIPLE:
        result = _scheduler._solve_3w(
            profile, net, B, keep_log=keep_log, backend=backend,
            prune=prune, objective=objective, warm_start=warm_start)
    else:
        result = _scheduler._solve_multi(
            profile, net, B, keep_log=keep_log, backend=backend,
            prune=prune, refine_passes=refine_passes, objective=objective,
            warm_start=warm_start)
    return Plan(fleet=fleet, B=B, objective=objective,
                pipeline_depth=pipeline_depth, backend=backend,
                profile=profile, network=net, result=result, wire=wire,
                model=stack)


# ---------------------------------------------------------------------------
# CLI: python -m repro.api --explain <config>
# ---------------------------------------------------------------------------

_CLI_CONFIGS = ("lenet5", "alexnet", "lm")


def _cli_model_and_fleet(config: str, m: int, edge_cloud_mbps, topology,
                         n_edges: int = 1):
    if config in ("lenet5", "alexnet"):
        from repro.models import cnn
        model = getattr(cnn, config)()
        return model, Fleet.from_table2(
            model=config, m=m,
            edge_cloud_mbps=3.0 if edge_cloud_mbps is None
            else edge_cloud_mbps,
            topology=topology, n_edges=n_edges)
    if config == "lm":
        if topology == TRIPLE:
            raise SystemExit("the lm fleet is star-native; drop "
                             "--topology triple")
        from repro.core.fleet import LM_BACKHAUL_MBPS
        from repro.models.lm.layerstack import lm_layerstack
        from repro.models.lm.model import LMConfig
        cfg = LMConfig(name="api-lm", family="dense", n_layers=6,
                       d_model=256, n_heads=4, n_kv_heads=2, d_ff=768,
                       vocab=32_000)
        fleet = Fleet.lm_default(
            m=m, backhaul_mbps=LM_BACKHAUL_MBPS if edge_cloud_mbps is None
            else edge_cloud_mbps)
        return lm_layerstack(cfg, seq_len=256), fleet
    raise SystemExit(f"unknown config {config!r}; pick one of "
                     f"{_CLI_CONFIGS}")


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Plan a HierTrain schedule and explain it.")
    ap.add_argument("--explain", metavar="CONFIG", required=True,
                    help=f"one of {', '.join(_CLI_CONFIGS)}")
    ap.add_argument("--m", type=int, default=1,
                    help="number of devices in the fleet")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--edge-cloud-mbps", type=float, default=None,
                    help="edge-cloud backhaul (default: 3 Mbps for the "
                         "CNN testbeds, 200 Mbps for the lm fleet)")
    ap.add_argument("--objective", choices=OBJECTIVES, default="latency")
    ap.add_argument("--pipeline-depth", type=int, default=1)
    ap.add_argument("--topology", choices=("auto", TRIPLE, STAR, TREE),
                    default="auto")
    ap.add_argument("--edges", type=int, default=1,
                    help="edge-server count (tree topology; devices are "
                         "partitioned contiguously)")
    ap.add_argument("--wire", choices=("none", "int8"), default="none",
                    help="cut-point transfer codec: int8 plans with and "
                         "executes compressed activation/gradient wires")
    args = ap.parse_args(argv)
    model, fleet = _cli_model_and_fleet(args.explain, args.m,
                                        args.edge_cloud_mbps, args.topology,
                                        n_edges=args.edges)
    p = plan(model, fleet, args.batch, objective=args.objective,
             pipeline_depth=args.pipeline_depth, wire=args.wire)
    print(p.explain())
    print(f"  simulated (DES): {p.simulate():.6g}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
