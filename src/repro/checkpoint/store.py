"""Fault-tolerant checkpointing: npz shards + a JSON manifest.

Design points (the ones that matter at 1000-node scale, implemented
single-host here with the same protocol):

* **Atomicity** — writes go to ``step_<k>.tmp/`` and are ``os.rename``d
  into place only after every array and the manifest have been fsynced;
  a crash mid-write can never produce a half-checkpoint that
  ``latest_step`` would pick up.
* **Elastic reshard-on-load** — arrays are stored unsharded (this is a
  single-host container); ``load_checkpoint`` takes an optional target
  sharding tree and uses ``jax.device_put`` leaf-wise, so a checkpoint
  written under one mesh restores cleanly under another (different pod
  count / axis sizes) — the restore path of elastic scaling.
* **Keep-N retention** with the manifest updated last, so garbage
  collection of an old step can never race a reader of the newest one.
* **Self-describing manifest** — tree structure, dtypes, shapes, step,
  and a payload checksum; loads verify structure before touching the
  model.
* **Stray-entry tolerance** — only names matching ``step_\\d{8}`` are
  checkpoints; lock files, notes, or foreign directories in the store
  are ignored by :func:`latest_step` and the keep-N GC instead of
  crashing the run.
* **Corrupt-newest fallback** — :meth:`CheckpointManager.restore_latest`
  skips an unreadable newest step (torn payload, missing manifest) with
  a warning and restores the previous one; a restart after a crash that
  damaged the newest checkpoint still comes back up.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import shutil
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple
from zipfile import BadZipFile as zipfile_BadZipFile

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

# Checkpoint dirs are exactly ``step_<8+ digits>``; anything else in the
# store (lock files, ``step_notes.txt``, foreign dirs) is not ours.
_STEP_RE = re.compile(r"step_(\d{8,})")


def _step_of(name: str) -> Optional[int]:
    m = _STEP_RE.fullmatch(name)
    return int(m.group(1)) if m else None


def _list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = [_step_of(d) for d in os.listdir(directory)]
    return sorted(s for s in steps if s is not None)


def _fsync_dir(path: str) -> None:
    """fsync a directory so its entries (renames, new files) are durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree: Params):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree: Params) -> List[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in kp))
    return paths


def save_checkpoint(directory: str, step: int, tree: Params,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically write ``tree`` (params/opt state/metadata) at ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten(tree)
    paths = _tree_paths(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    # bf16 has no numpy dtype: store as uint16 view + dtype tag.
    dtypes = {}
    for name in list(arrays):
        arr = arrays[name]
        if arr.dtype == jnp.bfloat16:
            arrays[name] = arr.view(np.uint16)
            dtypes[name] = "bfloat16"
        else:
            dtypes[name] = str(arr.dtype)
    payload = os.path.join(tmp, "arrays.npz")
    with open(payload, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    with open(payload, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()

    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": dtypes,
        "shapes": {f"a{i}": list(np.asarray(l).shape)
                   for i, l in enumerate(leaves)},
        "sha256": digest,
        "extra": extra or {},
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    # Durability order: payload + manifest fsynced above, then the tmp
    # dir (so both entries survive), then the rename, then the parent
    # dir (so the rename itself survives).
    _fsync_dir(tmp)
    os.rename(tmp, final)
    _fsync_dir(directory)
    return final


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return steps[-1] if steps else None


def read_extra(directory: str, step: int) -> Dict[str, Any]:
    """Read only the manifest's ``extra`` dict (cheap, no arrays).

    Two-phase restore: the extra carries JSON metadata (fleet
    membership, schedule, RNG seed, ...) that callers may need to
    reconstruct the ``like`` tree before loading the arrays."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("extra", {})


def load_checkpoint(directory: str, step: int, like: Params,
                    shardings: Optional[Params] = None,
                    verify: bool = True) -> Params:
    """Restore into the structure of ``like``; optionally device_put each
    leaf to ``shardings`` (elastic reshard: target mesh may differ from
    the writer's)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    want_paths = _tree_paths(like)
    if manifest["paths"] != want_paths:
        missing = set(want_paths) - set(manifest["paths"])
        extra = set(manifest["paths"]) - set(want_paths)
        raise ValueError(f"checkpoint/model structure mismatch: "
                         f"missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    payload = os.path.join(path, "arrays.npz")
    if verify:
        with open(payload, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {path} payload corrupt")
    data = np.load(payload)
    leaves, treedef = _flatten(like)
    out = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    for i, (leaf, shard) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"a{i}"]
        if manifest["dtypes"][f"a{i}"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            x = jnp.asarray(arr)
            # With x64 disabled jnp silently downcasts f64/i64 leaves; a
            # checkpoint must restore exactly what was saved (the hier
            # loop's profile rows are float64), so keep such leaves as
            # host numpy arrays.
            out.append(arr if x.dtype != arr.dtype else x)
    return jax.tree.unflatten(treedef, out)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def save(self, step: int, tree: Params,
             extra: Optional[Dict[str, Any]] = None) -> str:
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def restore_latest(self, like: Params, shardings: Optional[Params] = None
                       ):
        return self.restore_latest_with(lambda step, extra: like,
                                        shardings)[:2]

    def restore_latest_with(self, like_fn: Callable[[int, Dict[str, Any]],
                                                    Params],
                            shardings: Optional[Params] = None,
                            ) -> Tuple[Optional[int], Optional[Params],
                                       Optional[Dict[str, Any]]]:
        """Restore the newest readable step, building the target tree
        from its manifest extra via ``like_fn(step, extra)``.

        A corrupt or torn newest step (crash while the durability
        protocol was mid-flight on a non-ordering filesystem, disk
        damage, ...) is skipped with a warning and the previous step is
        tried; the last error is raised only if *every* step is
        unreadable."""
        steps = _list_steps(self.directory)
        last_err: Optional[BaseException] = None
        for step in reversed(steps):
            try:
                extra = read_extra(self.directory, step)
                like = like_fn(step, extra)
                tree = load_checkpoint(self.directory, step, like, shardings)
                return step, tree, extra
            except (OSError, ValueError, KeyError, zipfile_BadZipFile) as e:
                warnings.warn(
                    f"checkpoint step {step} in {self.directory} is "
                    f"unreadable ({type(e).__name__}: {e}); falling back "
                    f"to the previous step", RuntimeWarning, stacklevel=2)
                last_err = e
        if last_err is not None:
            raise last_err
        return None, None, None

    def _gc(self) -> None:
        for s in _list_steps(self.directory)[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))
