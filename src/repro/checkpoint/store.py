"""Fault-tolerant checkpointing: npz shards + a JSON manifest.

Design points (the ones that matter at 1000-node scale, implemented
single-host here with the same protocol):

* **Atomicity** — writes go to ``step_<k>.tmp/`` and are ``os.rename``d
  into place only after every array and the manifest have been fsynced;
  a crash mid-write can never produce a half-checkpoint that
  ``latest_step`` would pick up.
* **Elastic reshard-on-load** — arrays are stored unsharded (this is a
  single-host container); ``load_checkpoint`` takes an optional target
  sharding tree and uses ``jax.device_put`` leaf-wise, so a checkpoint
  written under one mesh restores cleanly under another (different pod
  count / axis sizes) — the restore path of elastic scaling.
* **Keep-N retention** with the manifest updated last, so garbage
  collection of an old step can never race a reader of the newest one.
* **Self-describing manifest** — tree structure, dtypes, shapes, step,
  and a payload checksum; loads verify structure before touching the
  model.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _flatten(tree: Params):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree: Params) -> List[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in kp))
    return paths


def save_checkpoint(directory: str, step: int, tree: Params,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically write ``tree`` (params/opt state/metadata) at ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten(tree)
    paths = _tree_paths(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    # bf16 has no numpy dtype: store as uint16 view + dtype tag.
    dtypes = {}
    for name in list(arrays):
        arr = arrays[name]
        if arr.dtype == jnp.bfloat16:
            arrays[name] = arr.view(np.uint16)
            dtypes[name] = "bfloat16"
        else:
            dtypes[name] = str(arr.dtype)
    payload = os.path.join(tmp, "arrays.npz")
    np.savez(payload, **arrays)
    with open(payload, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()

    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": dtypes,
        "shapes": {f"a{i}": list(np.asarray(l).shape)
                   for i, l in enumerate(leaves)},
        "sha256": digest,
        "extra": extra or {},
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like: Params,
                    shardings: Optional[Params] = None,
                    verify: bool = True) -> Params:
    """Restore into the structure of ``like``; optionally device_put each
    leaf to ``shardings`` (elastic reshard: target mesh may differ from
    the writer's)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    want_paths = _tree_paths(like)
    if manifest["paths"] != want_paths:
        missing = set(want_paths) - set(manifest["paths"])
        extra = set(manifest["paths"]) - set(want_paths)
        raise ValueError(f"checkpoint/model structure mismatch: "
                         f"missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    payload = os.path.join(path, "arrays.npz")
    if verify:
        with open(payload, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {path} payload corrupt")
    data = np.load(payload)
    leaves, treedef = _flatten(like)
    out = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    for i, (leaf, shard) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"a{i}"]
        if manifest["dtypes"][f"a{i}"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def save(self, step: int, tree: Params,
             extra: Optional[Dict[str, Any]] = None) -> str:
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def restore_latest(self, like: Params, shardings: Optional[Params] = None
                       ):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, load_checkpoint(self.directory, step, like, shardings)

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))
