"""Architecture registry: ``--arch <id>`` lookup for every assigned
architecture plus the paper's own CNNs (lenet5 / alexnet, which run on
the HierTrain mobile-edge-cloud scheduler rather than the LM runtime).
"""
from __future__ import annotations

from typing import Dict

from repro.configs import (gemma3_12b, granite_20b, grok1_314b,
                           phi3_medium_14b, pixtral_12b, qwen2_5_3b,
                           qwen2_moe_a2_7b, whisper_base, xlstm_350m,
                           zamba2_7b)
from repro.configs.base import (SHAPES, ArchSpec, ShapeSpec,
                                decode_token_spec, input_specs)

ARCHS: Dict[str, ArchSpec] = {
    spec.arch_id: spec
    for spec in (
        whisper_base.SPEC, pixtral_12b.SPEC, grok1_314b.SPEC,
        qwen2_moe_a2_7b.SPEC, zamba2_7b.SPEC, xlstm_350m.SPEC,
        phi3_medium_14b.SPEC, gemma3_12b.SPEC, qwen2_5_3b.SPEC,
        granite_20b.SPEC,
    )
}

# The paper's own evaluation models (layered CNNs on the MECC hierarchy).
CNN_ARCHS = ("lenet5", "alexnet")


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}"
                       f" + CNNs {CNN_ARCHS}")
    return ARCHS[arch_id]


__all__ = ["ARCHS", "CNN_ARCHS", "SHAPES", "ArchSpec", "ShapeSpec",
           "get_arch", "input_specs", "decode_token_spec"]
