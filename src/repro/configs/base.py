"""Shared architecture-spec plumbing: shape catalogue + input specs.

Every assigned architecture module exports an :class:`ArchSpec` with the
exact published full config, a reduced smoke config of the same family,
and the shape cells it runs (`long_500k` only for sub-quadratic archs —
skips are recorded with reasons and surface in the dry-run matrix).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.model import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

FULL_ATTENTION_SKIP = ("full-attention arch: 524k-token KV would be a "
                       "quadratic-prefill / full-cache cost; long_500k is "
                       "reserved for sub-quadratic (SSM/hybrid) archs per "
                       "the assignment")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    lm: LMConfig                      # the exact published configuration
    smoke: LMConfig                   # reduced same-family config for CPU
    optimizer: str = "adamw"          # adamw | sgdm (giant models)
    microbatches: int = 8             # train_4k grad-accumulation factor
    smoke_seq: int = 64
    smoke_batch: int = 2
    notes: str = ""

    @property
    def shapes(self) -> Tuple[str, ...]:
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.lm.sub_quadratic:
            names.append("long_500k")
        return tuple(names)

    @property
    def skips(self) -> Dict[str, str]:
        if self.lm.sub_quadratic:
            return {}
        return {"long_500k": FULL_ATTENTION_SKIP}


def _token_specs(B: int, T: int, targets: bool) -> Dict[str, object]:
    s = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
    if targets:
        s["targets"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    return s


def input_specs(cfg: LMConfig, shape: ShapeSpec,
                smoke: bool = False) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for one batch of the given shape cell.

    For ``decode`` cells this is the *prompt-side* spec; the serve-step
    cache spec comes from ``jax.eval_shape`` on ``model.init_cache``.
    """
    B, T = shape.global_batch, shape.seq_len
    want_targets = shape.kind == "train"
    if cfg.family == "encdec":
        s = _token_specs(B, T, want_targets)
        s["frames"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
        return s
    if cfg.n_frontend_tokens > 0:
        P = min(cfg.n_frontend_tokens, T // 2)
        s = _token_specs(B, T - P, want_targets)
        s["embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), jnp.bfloat16)
        return s
    return _token_specs(B, T, want_targets)


def decode_token_spec(shape: ShapeSpec) -> Dict[str, object]:
    B = shape.global_batch
    return {"tok": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
