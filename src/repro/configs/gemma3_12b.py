"""gemma3-12b [dense]: 5 local : 1 global attention, 262k vocab, GeGLU.
[hf:google/gemma-3]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig

FULL = LMConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3_840, n_heads=16, n_kv_heads=8,
    d_ff=15_360, vocab=262_144, head_dim=256,
    sliding_window=1_024, global_every=6, mlp="geglu",
)

SMOKE = LMConfig(
    name="gemma3-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    sliding_window=16, global_every=3, mlp="geglu", dtype=jnp.float32,
)

SPEC = ArchSpec(
    arch_id="gemma3-12b", lm=FULL, smoke=SMOKE,
    notes=("head_dim=256 per the released model (d_model/n_heads would "
           "give 240; 256 is also MXU-aligned).  5:1 pattern realized as "
           "grouped scans with static windows: 8 groups of [5 local + 1 "
           "global].  long_500k skipped: the global layers keep full "
           "attention, so a 524k KV cache is a full-attention cost."),
)
