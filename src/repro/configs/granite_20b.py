"""granite-20b [dense]: llama-arch code model with MQA (kv=1).
[arXiv:2405.04324]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig

FULL = LMConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6_144, n_heads=48, n_kv_heads=1,
    d_ff=24_576, vocab=49_152, head_dim=128, mlp="gelu",
)

SMOKE = LMConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=128,
    dtype=jnp.float32,
)

SPEC = ArchSpec(
    arch_id="granite-20b", lm=FULL, smoke=SMOKE,
    notes=("MQA: the single KV head cannot shard over the model axis; "
           "decode shards the KV-cache sequence dim instead (LSE-combined "
           "distributed decode attention).  Non-gated GELU MLP "
           "(d_ff = 4*d_model, GPT-bigcode lineage) — a gated MLP at this "
           "d_ff would be a 28B model, not 20B."),
)
