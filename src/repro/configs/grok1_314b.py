"""grok-1-314b [moe]: 8-expert top-2 MoE decoder.  [hf:xai-org/grok-1]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig
from repro.models.lm.moe import MoEConfig

FULL = LMConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6_144, n_heads=48, n_kv_heads=8,
    d_ff=32_768, vocab=131_072, head_dim=128,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32_768),
)

SMOKE = LMConfig(
    name="grok-1-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
    # generous capacity so smoke tests see no token dropping (capacity
    # dropping makes prefill/decode batch-context-dependent by design)
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                  capacity_factor=8.0),
    dtype=jnp.float32,
)

SPEC = ArchSpec(
    arch_id="grok-1-314b", lm=FULL, smoke=SMOKE, optimizer="sgdm",
    notes=("~86% of parameters live in experts — the strongest case for "
           "HierTrain tiered sync (expert tier crosses the pod axis "
           "int8-quantized).  SGD+momentum optimizer: AdamW f32 state for "
           "314B params would not fit 256x16GB HBM."),
)
