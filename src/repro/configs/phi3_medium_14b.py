"""phi3-medium-14b [dense]: RoPE + SwiGLU + GQA.  [arXiv:2404.14219]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig

FULL = LMConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5_120, n_heads=40, n_kv_heads=10,
    d_ff=17_920, vocab=100_352, head_dim=128,
)

SMOKE = LMConfig(
    name="phi3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192, vocab=128,
    dtype=jnp.float32,
)

SPEC = ArchSpec(arch_id="phi3-medium-14b", lm=FULL, smoke=SMOKE)
