"""pixtral-12b [vlm]: mistral-nemo decoder backbone; pixtral-ViT patch
frontend STUB (precomputed patch embeddings).  [hf:mistralai/Pixtral-12B]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig

FULL = LMConfig(
    name="pixtral-12b", family="dense",
    n_layers=40, d_model=5_120, n_heads=32, n_kv_heads=8,
    d_ff=14_336, vocab=131_072, head_dim=128,
    n_frontend_tokens=1_024, rope_theta=1e6,
)

SMOKE = LMConfig(
    name="pixtral-12b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    n_frontend_tokens=8, dtype=jnp.float32,
)

SPEC = ArchSpec(
    arch_id="pixtral-12b", lm=FULL, smoke=SMOKE,
    notes=("ViT frontend is a stub: input_specs supplies [B, 1024, d_model] "
           "patch embeddings prepended to the token sequence; prefix "
           "positions carry no LM loss."),
)
