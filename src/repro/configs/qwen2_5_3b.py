"""qwen2.5-3b [dense]: GQA kv=2, QKV bias.  [hf:Qwen/Qwen2.5]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig

FULL = LMConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2_048, n_heads=16, n_kv_heads=2,
    d_ff=11_008, vocab=151_936, qkv_bias=True,
)

SMOKE = LMConfig(
    name="qwen2.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    qkv_bias=True, dtype=jnp.float32,
)

SPEC = ArchSpec(arch_id="qwen2.5-3b", lm=FULL, smoke=SMOKE)
