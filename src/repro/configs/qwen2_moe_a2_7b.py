"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig
from repro.models.lm.moe import MoEConfig

FULL = LMConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2_048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=151_936, qkv_bias=True,
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1_408,
                  n_shared=4, d_ff_shared=5_632),
)

SMOKE = LMConfig(
    name="qwen2-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab=128,
    qkv_bias=True,
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=32,
                  n_shared=2, d_ff_shared=64, capacity_factor=8.0),
    dtype=jnp.float32,
)

SPEC = ArchSpec(arch_id="qwen2-moe-a2.7b", lm=FULL, smoke=SMOKE)
