"""whisper-base [audio]: enc-dec transformer backbone, conv frontend STUB
(precomputed frame embeddings are inputs).  [arXiv:2212.04356]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig

FULL = LMConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51_865,
    encoder_layers=6, norm="layer", mlp="gelu", rope_theta=0.0,
)

SMOKE = LMConfig(
    name="whisper-base-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    encoder_layers=2, norm="layer", mlp="gelu", rope_theta=0.0,
    dtype=jnp.float32,
)

SPEC = ArchSpec(
    arch_id="whisper-base", lm=FULL, smoke=SMOKE,
    notes=("audio frontend (2x conv) is a stub per the assignment: "
           "input_specs supplies [B, T, d_model] frame embeddings. "
           "Sinusoidal positions on both encoder and decoder."),
)
