"""xlstm-350m [ssm]: mLSTM blocks with an sLSTM block every 8 (7:1).
Sub-quadratic => long_500k runs.  [arXiv:2405.04517]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig
from repro.models.lm.xlstm import XLSTMConfig

FULL = LMConfig(
    name="xlstm-350m", family="xlstm",
    n_layers=24, d_model=1_024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50_304, rope_theta=0.0,
    xlstm=XLSTMConfig(n_heads=4, expand=2, d_conv=4, slstm_every=8,
                      chunk=256),
    sub_quadratic=True,
)

SMOKE = LMConfig(
    name="xlstm-smoke", family="xlstm",
    n_layers=6, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0, vocab=128,
    rope_theta=0.0,
    xlstm=XLSTMConfig(n_heads=2, slstm_every=3, chunk=32),
    sub_quadratic=True, dtype=jnp.float32,
)

SPEC = ArchSpec(
    arch_id="xlstm-350m", lm=FULL, smoke=SMOKE,
    notes="d_ff=0: xLSTM blocks carry their own up/down projections.",
)
