"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block every 6
layers.  Sub-quadratic => long_500k runs.  [arXiv:2411.15242]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.models.lm.model import LMConfig
from repro.models.lm.ssm import SSMConfig

FULL = LMConfig(
    name="zamba2-7b", family="zamba",
    n_layers=81, d_model=3_584, n_heads=32, n_kv_heads=32,
    d_ff=14_336, vocab=32_000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, chunk=256),
    shared_attn_every=6, sub_quadratic=True,
)

SMOKE = LMConfig(
    name="zamba2-smoke", family="zamba",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    ssm=SSMConfig(d_state=16, head_dim=16, chunk=32),
    shared_attn_every=3, sub_quadratic=True, dtype=jnp.float32,
)

SPEC = ArchSpec(
    arch_id="zamba2-7b", lm=FULL, smoke=SMOKE,
    notes=("One shared attention+MLP block (the paper interleaves two); "
           "81 = 13 groups of 6 + 3 trailing mamba layers.  long_500k "
           "decode state is O(1) in sequence length for the mamba layers; "
           "the 13 shared-attention applications keep per-application KV "
           "caches."),
)
