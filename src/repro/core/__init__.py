"""HierTrain core — cost model, scheduler, execution engine, DES.

Public surface (re-exported here and from ``repro``): ``Fleet``,
``Plan``, ``plan``, ``plan_many``, ``as_layerstack`` — see DESIGN.md §9
for the API map.  The submodules are internal: the canonical engines live under
private names (``scheduler._solve_3w`` / ``_solve_multi``,
``cost_model._t_total*``, ``simulator._simulate_iteration*``) and the
historical public names are deprecation shims over the facade.
"""
from __future__ import annotations

__all__ = ["Fleet", "Plan", "plan", "plan_many", "as_layerstack"]


def __getattr__(name):
    if name == "Fleet":
        from repro.core.fleet import Fleet
        return Fleet
    if name in ("Plan", "plan", "plan_many"):
        from repro import api
        return getattr(api, name)
    if name == "as_layerstack":
        from repro.core.layerstack import as_layerstack
        return as_layerstack
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + __all__)
