"""Deprecation plumbing for the legacy pre-``Fleet``/``Plan`` surface.

Every legacy entry point listed in DESIGN.md §9 calls
:func:`warn_deprecated` exactly once per call site before delegating to
the facade.  Messages always start with the fully-qualified old name
(``repro.…``) so the tier-1 warning filter (``pytest.ini``) can turn
*in-repo* uses of a deprecated path into hard errors without touching
third-party DeprecationWarnings.
"""
from __future__ import annotations

import warnings


def warn_deprecated(old: str, new: str) -> None:
    """Emit one DeprecationWarning naming the exact replacement call.

    ``stacklevel=3`` attributes the warning to the *caller of the shim*
    (helper → shim → caller), which is what the scoped ``error::``
    filter in ``pytest.ini`` matches on.
    """
    warnings.warn(
        f"{old} is deprecated; use {new} instead (see DESIGN.md §9).",
        DeprecationWarning, stacklevel=3)
