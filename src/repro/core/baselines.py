"""Baseline schedulers from the paper's evaluation (§VI-C).

* **All-Edge / All-Cloud** — ship all ``B`` samples to one worker which trains
  the full model.  Expressed as degenerate HierTrain schedules
  (``m_s = m_l = 0``) and evaluated with the exact cost model.
* **JointDNN** [8] — device+cloud layer-granularity partition, whole batch,
  no sample parallelism.  The scheduling is a shortest path over a chain
  graph: state = (layer, location); switching location between consecutive
  layers pays the activation transfer forward *and* the gradient-activation
  transfer backward (both of size ``B * MO_i``).
* **JointDNN+** — our 3-location extension (device/edge/cloud) of the same
  shortest-path scheduling, as described in the paper.
* **JALAD** [13] — edge+cloud partition with the boundary activations
  compressed from 32-bit floats to ``c`` bits (paper uses ``c = 8``), i.e. a
  4x reduction on the *edge-cloud* link only.  Weights/gradients of disjoint
  layer sets never cross links.

All of these train the *full* batch on the chosen location(s): per-layer time
is ``B * (L^f + L^b)`` plus per-layer update time on the owning location.
The data originates at the device; if the first layer set does not run on the
device, the raw samples (``B * Q``) must first be shipped there.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.cost_model import (WIDX, Breakdown, HierProfile, Network,
                                   Schedule, _t_total)


@dataclasses.dataclass
class BaselineResult:
    name: str
    t_total: float
    placement: List[str]  # per-layer location
    detail: Dict[str, float]


def all_on_one(profile: HierProfile, net: Network, B: int, worker: str,
               origin: str = "device") -> BaselineResult:
    """All-Edge / All-Cloud / device-only: one worker trains everything."""
    sched = Schedule(worker_o=worker, worker_s=worker, worker_l=worker,
                     m_s=0, m_l=0, b_o=B, b_s=0, b_l=0)
    bd = _t_total(profile, net, sched, origin)
    return BaselineResult(
        name=f"all-{worker}", t_total=bd.total,
        placement=[worker] * profile.num_layers,
        detail={"input_comm": bd.comm_input, "compute": bd.total -
                bd.comm_input})


def _partition_shortest_path(profile: HierProfile, net: Network, B: int,
                             locations: Sequence[str],
                             origin: str = "device",
                             act_compress: Dict[Tuple[str, str], float] | None
                             = None) -> Tuple[float, List[str]]:
    """Min-cost per-layer placement over a chain DNN (JointDNN's graph model).

    ``act_compress[(a, b)]`` scales activation bytes on link ``a-b``
    (JALAD's 8-bit compression => 0.25 on edge-cloud).
    """
    N = profile.num_layers
    act_compress = act_compress or {}

    def link_scale(a: str, b: str) -> float:
        return act_compress.get((a, b), act_compress.get((b, a), 1.0))

    def xfer(a: str, b: str, nbytes: float) -> float:
        if a == b or nbytes == 0.0:
            return 0.0
        return nbytes * link_scale(a, b) / net.bw(a, b)

    # Node cost: fwd + bwd + update of layer i at location j, full batch.
    # Edge cost between layer i and i+1 at (a -> b): activation fwd +
    # grad-activation bwd, both B * MO_i.
    INF = float("inf")
    dist = {}
    prev: Dict[Tuple[int, str], Tuple[int, str]] = {}
    for j in locations:
        inp = 0.0 if j == origin else B * profile.sample_bytes / \
            net.bw(origin, j)
        node = B * (profile.L_f[WIDX[j], 0] + profile.L_b[WIDX[j], 0]) + \
            profile.L_u[WIDX[j], 0]
        dist[(0, j)] = inp + node
    for i in range(1, N):
        for j in locations:
            node = B * (profile.L_f[WIDX[j], i] + profile.L_b[WIDX[j], i]) + \
                profile.L_u[WIDX[j], i]
            best, barg = INF, None
            for k in locations:
                edge = 2.0 * xfer(k, j, B * profile.MO[i - 1])
                cand = dist[(i - 1, k)] + edge
                if cand < best:
                    best, barg = cand, k
            dist[(i, j)] = best + node
            prev[(i, j)] = (i - 1, barg)
    end = min(((dist[(N - 1, j)], j) for j in locations))
    # Recover placement.
    placement = [""] * N
    cur = (N - 1, end[1])
    while True:
        placement[cur[0]] = cur[1]
        if cur[0] == 0:
            break
        cur = prev[cur]
    return end[0], placement


def jointdnn(profile: HierProfile, net: Network, B: int,
             origin: str = "device") -> BaselineResult:
    t, placement = _partition_shortest_path(
        profile, net, B, locations=("device", "cloud"), origin=origin)
    return BaselineResult("jointdnn", t, placement, {})


def jointdnn_plus(profile: HierProfile, net: Network, B: int,
                  origin: str = "device") -> BaselineResult:
    t, placement = _partition_shortest_path(
        profile, net, B, locations=("device", "edge", "cloud"),
        origin=origin)
    return BaselineResult("jointdnn+", t, placement, {})


def jalad(profile: HierProfile, net: Network, B: int, origin: str = "device",
          compress_bits: int = 8) -> BaselineResult:
    scale = compress_bits / 32.0
    t, placement = _partition_shortest_path(
        profile, net, B, locations=("edge", "cloud"), origin=origin,
        act_compress={("edge", "cloud"): scale})
    return BaselineResult("jalad", t, placement,
                          {"compress_bits": float(compress_bits)})


def run_all(profile: HierProfile, net: Network, B: int,
            origin: str = "device") -> Dict[str, BaselineResult]:
    return {
        "all-edge": all_on_one(profile, net, B, "edge", origin),
        "all-cloud": all_on_one(profile, net, B, "cloud", origin),
        "jointdnn": jointdnn(profile, net, B, origin),
        "jointdnn+": jointdnn_plus(profile, net, B, origin),
        "jalad": jalad(profile, net, B, origin),
    }
