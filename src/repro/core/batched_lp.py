"""Batched dense two-phase simplex: pivot a *stack* of LPs simultaneously.

Algorithm 1 solves ~``6 * (N+1)(N+2)/2`` structurally identical small LPs
(one per worker mapping x cut pair).  The scalar solver in
:mod:`repro.core.lp` walks them one at a time with per-element Python
loops; here the whole stack shares every pivot step:

* one ``(K, m+1, cols+1)`` tableau tensor holds all K problems,
* the entering column is chosen per batch element with Bland's rule
  (first negative reduced cost) via a vectorized ``argmax`` over a mask,
* the leaving row comes from a masked ratio test (non-positive column
  entries are excluded with ``inf`` ratios; ties break on the smallest
  basis index, mirroring the scalar solver's anti-cycling tie-break),
* batch elements that reach optimality/unboundedness are *frozen*: their
  lanes are masked out of subsequent pivots so their tableaus stay intact
  while the rest of the stack keeps iterating.

The arithmetic of each pivot mirrors :func:`repro.core.lp._pivot`
operation-for-operation (same normalization, same ``|factor| > eps`` skip
rule), so a batched lane follows the exact pivot path the scalar solver
takes on the same problem — the two backends agree to the last bit on
non-degenerate instances and to tolerance on degenerate ties.

Fleet axis (DESIGN.md §13): every pivot above is *per-lane* — no
arithmetic ever mixes two lanes — so stacks from **different problems**
(different fleets' candidate grids) can share one tableau tensor as long
as their shapes match.  :func:`pad_lp_stack` embeds a smaller stack into
a larger ``(n_vars, m_ub, m_eq)`` shape with provably inert zero
rows/columns, and :func:`linprog_batch_many` pads a list of
heterogeneous stacks to their common maximum shape, solves the flattened
``(fleet, lane)`` stack in ONE :func:`linprog_batch` call, and splits
the answers back — bit-identical, lane for lane, to solving each stack
on its own (the padding proof lives on :func:`pad_lp_stack`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lp import EPS

# Per-lane status codes.
RUNNING = 0
OPTIMAL = 1
INFEASIBLE = 2
UNBOUNDED = 3
ITERATION_LIMIT = 4

STATUS_NAMES = {
    OPTIMAL: "optimal",
    INFEASIBLE: "infeasible",
    UNBOUNDED: "unbounded",
    ITERATION_LIMIT: "iteration_limit",
}


@dataclasses.dataclass
class BatchLPResult:
    """Vectorized analogue of :class:`repro.core.lp.LPResult`.

    ``x`` rows of failed lanes are zero; check ``success`` before use.
    """
    x: np.ndarray        # [K, n]
    fun: np.ndarray      # [K]
    success: np.ndarray  # [K] bool
    status: np.ndarray   # [K] int (see STATUS_* / STATUS_NAMES)


def _pivot_masked(T: np.ndarray, basis: np.ndarray, row: np.ndarray,
                  col: np.ndarray, mask: np.ndarray) -> None:
    """Pivot lane ``k`` of ``T`` at ``(row[k], col[k])`` where ``mask[k]``.

    Mirrors the scalar ``_pivot``: normalize the pivot row, then subtract
    ``factor * pivot_row`` from every other row whose pivot-column entry
    exceeds ``EPS`` in magnitude (identical op order => identical floats).
    Lanes with ``mask == False`` are left untouched.
    """
    K = T.shape[0]
    ar = np.arange(K)
    piv_rows = np.where(mask[:, None], T[ar, row, :], 0.0)
    piv_vals = np.where(mask, T[ar, row][ar, col], 1.0)[:, None]
    norm = piv_rows / piv_vals                       # [K, cols]
    factor = T[ar, :, col]                           # [K, rows]
    factor[ar, row] = 0.0                            # pivot row: replaced below
    factor = np.where(np.abs(factor) > EPS, factor, 0.0)
    factor = np.where(mask[:, None], factor, 0.0)
    T -= factor[:, :, None] * norm[:, None, :]
    T[ar, row, :] = np.where(mask[:, None], norm, T[ar, row, :])
    basis[ar, row] = np.where(mask, col, basis[ar, row])


def _simplex_batch(T: np.ndarray, basis: np.ndarray, n_vars: int,
                   active: np.ndarray, status: np.ndarray,
                   max_iter: int = 10_000) -> None:
    """Primal simplex over the stack; updates ``status`` / ``active`` in
    place.  On return every initially-active lane is marked OPTIMAL,
    UNBOUNDED or ITERATION_LIMIT.

    The loop runs on a *compacted* working copy: whenever fewer than
    half the working lanes are still running, finished lanes are written
    back to ``T``/``basis`` and dropped, so late pivots (only a few
    slow-converging lanes) stop paying for the whole stack.  Compaction
    is pure gather/scatter — no lane's tableau or pivot order changes —
    so results are bit-identical to the uncompacted loop.
    """
    idx = np.flatnonzero(active)           # original indices of working lanes
    if idx.size == 0:
        return
    Tw, bw = T[idx], basis[idx]            # fancy indexing => private copies
    act = np.ones(idx.size, bool)
    m = T.shape[1] - 1

    def finish(lanes: np.ndarray, code: int) -> None:
        status[idx[lanes]] = code
        active[idx[lanes]] = False

    def flush() -> None:
        """Write finished lanes back and shrink the working stack."""
        nonlocal idx, Tw, bw, act
        done = ~act
        T[idx[done]] = Tw[done]
        basis[idx[done]] = bw[done]
        idx, Tw, bw, act = idx[act], Tw[act], bw[act], act[act]

    for _ in range(max_iter):
        K = idx.size
        ar = np.arange(K)
        # Entering column (Bland): first negative reduced cost per lane.
        neg = Tw[:, -1, :n_vars] < -EPS              # [K, n_vars]
        has_neg = neg.any(axis=1)
        finish(act & ~has_neg, OPTIMAL)
        act &= has_neg
        if not act.any():
            flush()
            return
        col = np.argmax(neg, axis=1)                 # first True; garbage if
        col = np.where(act, col, 0)                  # inactive (masked later)
        # Ratio test over body rows.
        body = Tw[ar, :, col][:, :m]                 # [K, m]
        pos = body > EPS
        unbounded = act & ~pos.any(axis=1)
        finish(unbounded, UNBOUNDED)
        act &= ~unbounded
        if not act.any():
            flush()
            return
        rhs = Tw[:, :m, -1]
        ratio = np.where(pos, rhs / np.where(pos, body, 1.0), np.inf)
        # Leaving row: replay the scalar solver's *incremental* scan
        # (lp._simplex) exactly — a fresh "ratio < best - EPS" beats the
        # incumbent, an EPS-tie goes to the smaller basis index and then
        # RESETS the band at the new ratio (ties chain transitively).  A
        # one-shot "ratio <= min + EPS" band is not equivalent on
        # near-degenerate chains, and pivot-path identity with the
        # reference backend is what the equivalence suite asserts.
        best_ratio = np.full(K, np.inf)
        best_basis = np.zeros(K, np.int64)
        row = np.full(K, -1)
        with np.errstate(invalid="ignore"):
            for i in range(m):
                ri, bi = ratio[:, i], bw[:, i]
                take = (ri < best_ratio - EPS) | (
                    (np.abs(ri - best_ratio) <= EPS) &
                    ((row < 0) | (bi < best_basis)))
                best_ratio = np.where(take, ri, best_ratio)
                best_basis = np.where(take, bi, best_basis)
                row = np.where(take, i, row)
        row = np.maximum(row, 0)  # inactive lanes: any valid index
        _pivot_masked(Tw, bw, row, col, act)
        if act.sum() * 2 <= K and K >= 16:
            flush()
    finish(act, ITERATION_LIMIT)
    act &= False
    flush()


def pad_lp_stack(c: np.ndarray,
                 A_ub: np.ndarray, b_ub: np.ndarray,
                 A_eq: np.ndarray, b_eq: np.ndarray,
                 n_pad: int, m_ub_pad: int, m_eq_pad: int):
    """Embed a ``(n, m_ub, m_eq)``-shaped LP stack into the larger
    ``(n_pad, m_ub_pad, m_eq_pad)`` shape with *inert* padding.

    Pad variables get all-zero columns (zero objective, zero rows); pad
    rows are all-zero with zero rhs.  The padded stack pivots
    **bit-identically** to the native one inside :func:`linprog_batch`:

    * a pad *column* is zero in every row and in the objective; pivoting
      adds ``factor * pivot_row`` to rows, and the pivot row's pad entry
      is zero, so pad columns stay exactly ``0.0`` forever — their
      reduced cost is never ``< -EPS`` and Bland's rule never enters
      them;
    * a pad *row* starts as ``[0 … 0 | artificial 1 | rhs 0]``; its
      entry in any entering column is zero, so the ratio test excludes
      it (never a leaving row) and ``factor = 0`` leaves it untouched;
      its phase-1 price-out subtracts exact zeros from every real
      column, and its artificial's reduced cost prices out to exactly
      ``0.0`` (never entering);
    * the native→padded index map (variables ``i → i``, slacks
      ``n + j → n_pad + j``, artificials shifted by the pad row counts)
      is strictly increasing, so Bland's first-negative scan and the
      smallest-basis-index tie-break make the same choices in the same
      order.

    Hence every pivot touches the same entries with the same floats as
    the native solve — ``tests/test_planner.py`` asserts the bitwise
    equality on random stacks.
    """
    A_ub = np.asarray(A_ub, np.float64)
    A_eq = np.asarray(A_eq, np.float64)
    K, m_ub, n = A_ub.shape
    m_eq = A_eq.shape[1]
    assert n_pad >= n and m_ub_pad >= m_ub and m_eq_pad >= m_eq
    c2 = np.zeros((K, n_pad))
    c2[:, :n] = np.broadcast_to(np.asarray(c, np.float64), (K, n))
    A_ub2 = np.zeros((K, m_ub_pad, n_pad))
    A_ub2[:, :m_ub, :n] = A_ub
    b_ub2 = np.zeros((K, m_ub_pad))
    b_ub2[:, :m_ub] = b_ub
    A_eq2 = np.zeros((K, m_eq_pad, n_pad))
    A_eq2[:, :m_eq, :n] = A_eq
    b_eq2 = np.zeros((K, m_eq_pad))
    b_eq2[:, :m_eq] = b_eq
    return c2, A_ub2, b_ub2, A_eq2, b_eq2


def linprog_batch_many(stacks) -> list:
    """Solve several heterogeneous-shape LP stacks as ONE flattened
    ``(fleet, lane)`` simplex stack (the cross-fleet fleet axis).

    Parameters
    ----------
    stacks : sequence of ``(c, A_ub, b_ub, A_eq, b_eq)`` tuples, each a
        valid :func:`linprog_batch` input of its own shape.

    Returns a list of :class:`BatchLPResult`, one per input stack, with
    ``x`` truncated back to each stack's native variable count.  Every
    lane is bit-identical to what a per-stack :func:`linprog_batch`
    call returns (padding is inert — see :func:`pad_lp_stack` — and no
    pivot arithmetic mixes lanes).
    """
    if not stacks:
        return []
    shapes = []
    for c, A_ub, b_ub, A_eq, b_eq in stacks:
        K, m_ub, n = np.asarray(A_ub).shape
        shapes.append((K, n, m_ub, np.asarray(A_eq).shape[1]))
    n_pad = max(s[1] for s in shapes)
    m_ub_pad = max(s[2] for s in shapes)
    m_eq_pad = max(s[3] for s in shapes)
    padded = [pad_lp_stack(c, A_ub, b_ub, A_eq, b_eq,
                           n_pad, m_ub_pad, m_eq_pad)
              for (c, A_ub, b_ub, A_eq, b_eq) in stacks]
    res = linprog_batch(
        np.concatenate([p[0] for p in padded], axis=0),
        np.concatenate([p[1] for p in padded], axis=0),
        np.concatenate([p[2] for p in padded], axis=0),
        np.concatenate([p[3] for p in padded], axis=0),
        np.concatenate([p[4] for p in padded], axis=0))
    out = []
    k0 = 0
    for K, n, _, _ in shapes:
        sl = slice(k0, k0 + K)
        out.append(BatchLPResult(x=res.x[sl, :n], fun=res.fun[sl],
                                 success=res.success[sl],
                                 status=res.status[sl]))
        k0 += K
    return out


def pad_cells(stacks) -> tuple:
    """``(native_cells, padded_cells)`` tableau-cell counts for a
    :func:`linprog_batch_many` call — the padding-waste telemetry the
    planner logs (waste = ``1 - native/padded``)."""
    shapes = [(np.asarray(A_ub).shape, np.asarray(A_eq).shape[1])
              for (_, A_ub, _, A_eq, _) in stacks]
    if not shapes:
        return 0, 0
    n_pad = max(s[0][2] for s in shapes)
    m_pad = max(s[0][1] for s in shapes) + max(s[1] for s in shapes)
    native = sum(K * (mu + me) * n for ((K, mu, n), me) in shapes)
    padded = sum(K * m_pad * n_pad for ((K, _, _), _) in shapes)
    return native, padded


def linprog_batch(c: np.ndarray,
                  A_ub: np.ndarray, b_ub: np.ndarray,
                  A_eq: np.ndarray, b_eq: np.ndarray) -> BatchLPResult:
    """Two-phase simplex over a stack of K LPs of identical shape.

    Parameters
    ----------
    c : ``[n]`` or ``[K, n]`` objective (minimized; ``x >= 0`` implicit).
    A_ub, b_ub : ``[K, m_ub, n]`` / ``[K, m_ub]`` inequality stack.
    A_eq, b_eq : ``[K, m_eq, n]`` / ``[K, m_eq]`` equality stack.
    """
    A_ub = np.asarray(A_ub, np.float64)
    b_ub = np.asarray(b_ub, np.float64)
    A_eq = np.asarray(A_eq, np.float64)
    b_eq = np.asarray(b_eq, np.float64)
    K, m_ub, n = A_ub.shape
    m_eq = A_eq.shape[1]
    m = m_ub + m_eq
    c = np.broadcast_to(np.asarray(c, np.float64), (K, n))

    # Standard form with slacks; flip negative-rhs rows (scalar parity).
    n_total = n + m_ub
    A = np.zeros((K, m, n_total))
    A[:, :m_ub, :n] = A_ub
    A[:, :m_ub, n:] = np.eye(m_ub)
    A[:, m_ub:, :n] = A_eq
    b = np.concatenate([b_ub, b_eq], axis=1)
    negrow = b < 0.0
    A = np.where(negrow[:, :, None], -A, A)
    b = np.abs(b)

    # Phase 1: artificials on every row, minimize their sum.
    T = np.zeros((K, m + 1, n_total + m + 1))
    T[:, :m, :n_total] = A
    T[:, :m, n_total:n_total + m] = np.eye(m)
    T[:, :m, -1] = b
    T[:, -1, n_total:n_total + m] = 1.0
    basis = np.tile(np.arange(n_total, n_total + m), (K, 1))
    for i in range(m):  # price out artificials (sequential: scalar parity)
        T[:, -1, :] -= T[:, i, :]

    status = np.full(K, RUNNING, np.int64)
    active = np.ones(K, bool)
    _simplex_batch(T, basis, n_total + m, active, status)
    feasible = (status == OPTIMAL) & (T[:, -1, -1] >= -1e-7)
    status[(status == OPTIMAL) & ~feasible] = INFEASIBLE

    # Drive leftover artificials out of the basis where possible.
    ar = np.arange(K)
    for i in range(m):
        need = feasible & (basis[:, i] >= n_total)
        if not need.any():
            continue
        entry = np.abs(T[:, i, :n_total]) > EPS      # [K, n_total]
        col = np.argmax(entry, axis=1)               # first usable column
        do = need & entry.any(axis=1)
        _pivot_masked(T, basis, np.full(K, i), col, do)

    # Phase 2: real objective over the phase-1 basis (artificials dropped).
    T2 = np.zeros((K, m + 1, n_total + 1))
    T2[:, :m, :n_total] = T[:, :m, :n_total]
    T2[:, :m, -1] = T[:, :m, -1]
    T2[:, -1, :n] = c
    for i in range(m):
        bi = basis[:, i]
        coef = T2[ar, -1, np.minimum(bi, n_total - 1)]
        do = feasible & (bi < n_total) & (np.abs(coef) > EPS)
        T2[:, -1, :] -= np.where(do, coef, 0.0)[:, None] * T2[:, i, :]

    status2 = status.copy()
    status2[feasible] = RUNNING
    active = feasible.copy()
    _simplex_batch(T2, basis, n_total, active, status2)

    # Extract the solution (scatter via a dummy column so lanes whose row i
    # holds an artificial cannot clobber variable 0).
    success = status2 == OPTIMAL
    x_ext = np.zeros((K, n_total + 1))
    in_vars = basis < n_total
    target = np.where(in_vars, basis, n_total)
    vals = np.where(in_vars & success[:, None], T2[:, :m, -1], 0.0)
    np.put_along_axis(x_ext, target, vals, axis=1)
    x = x_ext[:, :n]
    fun = np.einsum("kn,kn->k", c, x)
    fun = np.where(success, fun,
                   np.where(status2 == UNBOUNDED, -np.inf, np.inf))
    return BatchLPResult(x=x, fun=fun, success=success, status=status2)
