"""Elastic-fleet churn: typed membership events, deterministic Poisson
traces, and schedule remapping (DESIGN.md §10).

HierTrain's scheduler assumes a static device/edge/cloud fleet, but the
MECC deployments the paper targets are *mobile* fleets: devices join,
leave, die, and see their radios fade mid-training.  This module is the
event layer the hierarchical training loop
(:func:`repro.train.loop._run_loop` via ``Plan.train(churn=...)``)
consumes:

* **Typed events** — :class:`DeviceJoin`, :class:`DeviceLeave`,
  :class:`DeviceCrash`, :class:`LinkDegrade` — each pinned to the train
  step *before* which it takes effect.  Events only ever target devices;
  the edge and cloud are infrastructure.
* **Deterministic traces** — :func:`poisson_trace` draws per-step event
  counts from independent Poisson processes using a counter-based
  Philox generator, so a trace is a pure function of its seed (same
  property the synthetic data pipeline relies on for crash-safe resume).
* **Membership edits** — :func:`apply_event` maps an event onto the
  ``(EMA'd profile, baseline profile, network)`` triple using the
  membership primitives on :class:`~repro.core.cost_model.MultiProfile`
  / :class:`~repro.core.cost_model.StarNetwork`.  Survivor rows are
  byte-identical to the pre-churn rows, which is what makes the
  post-churn re-solve bit-equal to a cold solve on a fresh fleet of the
  survivors.
* **Schedule remap** — :func:`remap_schedule` projects the in-flight
  schedule onto the new membership (a departed TASK-S worker's samples
  fold into TASK O's sub-batch, joiners enter idle), giving the warm
  incumbent the re-solve feeds into the dominance prune.

Churn is native to the star topology: membership is a property of the
M-device star, and the paper's fixed three-worker triple has no notion
of it (``Plan.train(churn=...)`` raises on ``topology="triple"``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cost_model import MultiProfile, MultiSchedule, StarNetwork
from repro.core.fleet import MBPS


@dataclasses.dataclass(frozen=True)
class DeviceJoin:
    """Device ``name`` joins before step ``step``.

    ``slowdown`` seeds the joiner's compute rows from the fleet's
    reference device tier (the initial baseline profile's first device
    row at slowdown 1.0) — i.e. the joiner's
    :class:`~repro.core.profiler.WorkerSpec` tier expressed the same way
    ``Fleet.device_slowdowns`` expresses heterogeneity.  The online EMA
    refines the seed as soon as the straggler monitor reports the
    device.  ``uplink_mbps`` is its radio.
    """
    step: int
    name: str
    slowdown: float = 1.0
    uplink_mbps: float = 5.0


@dataclasses.dataclass(frozen=True)
class DeviceLeave:
    """Device ``name`` departs gracefully before step ``step``."""
    step: int
    name: str


@dataclasses.dataclass(frozen=True)
class DeviceCrash:
    """Device ``name`` dies mid-step: same membership edit as a leave,
    but the step in flight is lost and must be re-run by the survivors
    (the loop charges the lost fill latency as recovery time)."""
    step: int
    name: str


@dataclasses.dataclass(frozen=True)
class LinkDegrade:
    """Device ``name``'s uplink is multiplied by ``factor`` before step
    ``step`` (``factor < 1`` fades, ``factor > 1`` heals).  Membership is
    unchanged; only the network edits."""
    step: int
    name: str
    factor: float


ChurnEvent = Union[DeviceJoin, DeviceLeave, DeviceCrash, LinkDegrade]


@dataclasses.dataclass(frozen=True)
class ChurnTrace:
    """An ordered stream of churn events.

    Events with ``step == s`` take effect at the *top* of train step
    ``s``, before its schedule is (re-)solved and before its batch is
    split — so step ``s`` itself already runs on the post-churn fleet.
    """
    events: Tuple[ChurnEvent, ...]

    def __post_init__(self) -> None:
        steps = [e.step for e in self.events]
        assert steps == sorted(steps), "trace events must be step-ordered"

    def events_at(self, step: int) -> Tuple[ChurnEvent, ...]:
        return tuple(e for e in self.events if e.step == step)

    def since(self, step: int) -> "ChurnTrace":
        """The sub-trace from ``step`` onward — what a run resumed at
        ``step`` still has to apply (earlier events are already baked
        into the checkpointed membership)."""
        return ChurnTrace(tuple(e for e in self.events if e.step >= step))

    @property
    def max_step(self) -> int:
        return max((e.step for e in self.events), default=-1)


def poisson_trace(device_names: Sequence[str], total_steps: int, *,
                  join_rate: float = 0.02, leave_rate: float = 0.02,
                  crash_rate: float = 0.01, degrade_rate: float = 0.02,
                  seed: int = 0, min_devices: int = 1,
                  max_devices: Optional[int] = None,
                  slowdown_range: Tuple[float, float] = (1.0, 3.0),
                  uplink_mbps_range: Tuple[float, float] = (3.0, 5.0),
                  degrade_factor_range: Tuple[float, float] = (0.25, 0.75),
                  first_step: int = 1) -> ChurnTrace:
    """Deterministic Poisson churn trace over ``total_steps`` train steps.

    Per step and per event type, the event count is drawn from an
    independent Poisson process with the given per-step rate; targets
    and magnitudes are drawn uniformly.  The generator is a
    counter-based Philox keyed on ``seed``, so the trace is a pure
    function of its arguments — two runs (or a killed run and its
    resume) see the identical stream.

    Membership is tracked while generating: leaves/crashes never shrink
    the fleet below ``min_devices``, joins never grow it past
    ``max_devices``, and joiner names (``dev_j0``, ``dev_j1``, ...) never
    collide with a live or past member.
    """
    assert min_devices >= 1 and first_step >= 1
    rng = np.random.Generator(np.random.Philox(key=seed))
    live = list(device_names)
    used = set(live)
    events: list = []
    next_id = 0
    for step in range(first_step, total_steps):
        for kind, rate in (("leave", leave_rate), ("crash", crash_rate),
                           ("degrade", degrade_rate), ("join", join_rate)):
            for _ in range(int(rng.poisson(rate))):
                if kind in ("leave", "crash"):
                    if len(live) <= min_devices:
                        continue
                    name = live.pop(int(rng.integers(len(live))))
                    cls = DeviceLeave if kind == "leave" else DeviceCrash
                    events.append(cls(step, name))
                elif kind == "degrade":
                    name = live[int(rng.integers(len(live)))]
                    factor = float(rng.uniform(*degrade_factor_range))
                    events.append(LinkDegrade(step, name, factor))
                else:
                    if max_devices is not None and len(live) >= max_devices:
                        continue
                    while f"dev_j{next_id}" in used:
                        next_id += 1
                    name = f"dev_j{next_id}"
                    next_id += 1
                    slow = float(rng.uniform(*slowdown_range))
                    up = float(rng.uniform(*uplink_mbps_range))
                    events.append(DeviceJoin(step, name, slow, up))
                    live.append(name)
                    used.add(name)
    return ChurnTrace(tuple(events))


RefRows = Tuple[np.ndarray, np.ndarray, np.ndarray]


def reference_rows(base: MultiProfile) -> RefRows:
    """The fleet's reference device tier — per-layer ``(L_f, L_b, L_u)``
    of the baseline profile's first device row — against which
    :class:`DeviceJoin` slowdowns are expressed.  Captured once at loop
    start (and checkpointed) so joins are reproducible across resume
    even after the first device itself has churned out."""
    return (base.L_f[0].copy(), base.L_b[0].copy(), base.L_u[0].copy())


def apply_event(prof: MultiProfile, base: MultiProfile, net: StarNetwork,
                ref: RefRows, event: ChurnEvent
                ) -> Tuple[MultiProfile, MultiProfile, StarNetwork, bool]:
    """Apply one event to the ``(EMA'd profile, baseline profile,
    network)`` triple; returns the edited triple plus whether fleet
    *membership* changed (joins/leaves/crashes — the cases that force a
    schedule re-solve and a batch remap; a pure link fade keeps the
    schedule feasible and only re-scores it)."""
    if isinstance(event, DeviceJoin):
        lf, lb, lu = ref
        s = float(event.slowdown)
        if s <= 0:
            raise ValueError("join slowdown must be positive")
        prof = prof.add_device(event.name, lf * s, lb * s, lu * s)
        base = base.add_device(event.name, lf * s, lb * s, lu * s)
        net = net.add_device(event.uplink_mbps * MBPS)
        return prof, base, net, True
    if isinstance(event, (DeviceLeave, DeviceCrash)):
        i = prof.device_index(event.name)
        return (prof.drop_device(event.name), base.drop_device(event.name),
                net.drop_device(i), True)
    if isinstance(event, LinkDegrade):
        i = prof.device_index(event.name)
        return prof, base, net.scale_uplink(i, event.factor), False
    raise TypeError(f"unknown churn event: {event!r}")


def remap_schedule(sched: MultiSchedule, profile: MultiProfile
                   ) -> Optional[MultiSchedule]:
    """Project a live schedule onto a new fleet membership.

    A departed TASK-S worker's samples fold into TASK O's sub-batch
    (TASK O runs the full model, so it can absorb any front-end stream
    without violating the cut constraints — exact batch-B SGD is
    preserved because the *set* of samples in the step is unchanged);
    joiners enter with an idle TASK-S slot (``m_s = 0``, ``b_s = 0``)
    until the next re-solve assigns them work.  Returns ``None`` when
    the departed worker held TASK O or TASK L — the cut structure
    itself is gone and only a cold solve can rebuild it.

    The remapped schedule is feasible on the new fleet, so its exact
    cost is a valid incumbent for the warm-started re-solve.
    """
    names = set(profile.worker_names)
    if sched.worker_o not in names or sched.worker_l not in names:
        return None
    kept = [(w, m, b) for w, m, b in
            zip(sched.s_workers, sched.m_s, sched.b_s) if w in names]
    lost = sum(b for w, _, b in
               zip(sched.s_workers, sched.m_s, sched.b_s) if w not in names)
    taken = {sched.worker_o, sched.worker_l, *(w for w, _, _ in kept)}
    joiners = [w for w in profile.worker_names if w not in taken]
    s_workers = tuple(w for w, _, _ in kept) + tuple(joiners)
    m_s = tuple(m for _, m, _ in kept) + (0,) * len(joiners)
    b_s = tuple(b for _, _, b in kept) + (0,) * len(joiners)
    return MultiSchedule(worker_o=sched.worker_o, worker_l=sched.worker_l,
                         s_workers=s_workers, m_s=m_s, m_l=sched.m_l,
                         b_o=sched.b_o + lost, b_s=b_s, b_l=sched.b_l)
