"""HierTrain per-iteration training-time cost model — Eqs. (1)-(12) of the
paper, plus the M-device generalization (DESIGN.md §6).

Conventions
-----------
* The paper's topology has exactly three physical workers — ``"device"``,
  ``"edge"``, ``"cloud"`` (indices 0/1/2) — captured by
  :class:`HierProfile` / :class:`Network` / :class:`Schedule` and scored by
  :func:`t_total` / :func:`t_total_batch`.
* The generalized topology has ``M`` heterogeneous devices in a star around
  one edge server, which uplinks to one cloud — captured by
  :class:`MultiProfile` / :class:`StarNetwork` / :class:`MultiSchedule` and
  scored by :func:`t_total_multi` / :func:`t_total_multi_batch`.  With
  ``M = 1`` the generalized model evaluates to the three-worker model
  bit-for-bit (the M=1 equivalence suite asserts it).
* Roles are ``o`` (TASK O, full model, owner), ``s`` (TASK S, layers
  ``1..m_s`` — one such task per non-``o``/non-``l`` worker in the
  generalized model, each with its own cut ``m_s[i]``), ``l`` (TASK L,
  layers ``1..m_l``), with ``0 <= m_s[i] <= m_l <= N``.
* Layers are 1-indexed in the paper; arrays here are 0-indexed, so layer ``i``
  lives at index ``i-1``.  ``MO[i-1]`` is the forward output size (bytes per
  sample) of layer ``i``; ``MP[i-1]`` its parameter bytes.
* All times in seconds, sizes in bytes, bandwidths in bytes/second.

Any path between workers without a direct physical link is the series
composition of the links through the edge (data is relayed — Fig. 1(c)
topology); the paper's Algorithm 1 only takes ``BW_de`` and ``BW_ec`` as
inputs, the star network takes one uplink bandwidth per device.

Everything here scores ONE iteration in isolation (barrier execution).
The steady-state cost of *pipelined* consecutive minibatches —
``t_period`` and friends — lives in :mod:`repro.core.pipeline`
(DESIGN.md §7) and consumes the same profile/network/schedule types.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

WORKERS: Tuple[str, str, str] = ("device", "edge", "cloud")
WIDX: Dict[str, int] = {w: i for i, w in enumerate(WORKERS)}


@dataclasses.dataclass
class HierProfile:
    """Profiling-stage output (§III, profiling stage).

    Attributes
    ----------
    L_f, L_b : ``[3, N]`` — forward/backward seconds *per sample* per layer
        per worker (``L^f_{j,i}``, ``L^b_{j,i}``).
    L_u : ``[3, N]`` — weight-update seconds per layer per worker
        (``L^u_{j,i}``; batch-size independent).
    MP : ``[N]`` — parameter bytes per layer (``MP_i``).
    MO : ``[N]`` — forward-output bytes per *sample* per layer (``MO_i``).
    MG : ``[N]`` — backward wire bytes per *sample* at each cut (the
        activation *gradient* shipped from worker_o back to a TASK-S/L
        worker).  ``None`` (the default) means "equal to ``MO``" — the
        paper's §IV-C assumption, under which every cost is bitwise
        identical to the historical MO-only model.  LM profiles set it
        explicitly (bf16 activations forward, f32 gradients back).
    sample_bytes : ``Q`` — bytes of one training sample (input + label).
    """
    layer_names: Tuple[str, ...]
    L_f: np.ndarray
    L_b: np.ndarray
    L_u: np.ndarray
    MP: np.ndarray
    MO: np.ndarray
    sample_bytes: float
    MG: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        self.L_f = np.asarray(self.L_f, np.float64)
        self.L_b = np.asarray(self.L_b, np.float64)
        self.L_u = np.asarray(self.L_u, np.float64)
        self.MP = np.asarray(self.MP, np.float64)
        self.MO = np.asarray(self.MO, np.float64)
        self.MG = self.MO if self.MG is None \
            else np.asarray(self.MG, np.float64)
        n = self.num_layers
        assert self.L_f.shape == (3, n) and self.L_b.shape == (3, n)
        assert self.L_u.shape == (3, n) and self.MP.shape == (n,)
        assert self.MO.shape == (n,) and self.MG.shape == (n,)

    @property
    def num_layers(self) -> int:
        return len(self.layer_names)

    # Prefix sums (index k => layers 1..k inclusive) used all over the
    # scheduler; computed lazily and cached.
    def prefix(self) -> Dict[str, np.ndarray]:
        if not hasattr(self, "_prefix"):
            z = np.zeros((3, 1))
            zl = np.zeros(1)
            self._prefix = {
                "F": np.concatenate([z, np.cumsum(self.L_f, axis=1)], axis=1),
                "Bk": np.concatenate([z, np.cumsum(self.L_b, axis=1)], axis=1),
                "U": np.concatenate([z, np.cumsum(self.L_u, axis=1)], axis=1),
                "MP": np.concatenate([zl, np.cumsum(self.MP)]),
            }
        return self._prefix


@dataclasses.dataclass
class Network:
    """Bandwidths (bytes/s). ``bw_de``: device↔edge; ``bw_ec``: edge↔cloud."""
    bw_de: float
    bw_ec: float

    def bw(self, a: str, b: str) -> float:
        if a == b:
            return np.inf
        pair = frozenset((a, b))
        if pair == frozenset(("device", "edge")):
            return self.bw_de
        if pair == frozenset(("edge", "cloud")):
            return self.bw_ec
        # device <-> cloud: store-and-forward through the edge.
        return 1.0 / (1.0 / self.bw_de + 1.0 / self.bw_ec)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A full HierTrain scheduling decision (mapping + cuts + batch split)."""
    worker_o: str
    worker_s: str
    worker_l: str
    m_s: int
    m_l: int
    b_o: int
    b_s: int
    b_l: int

    @property
    def batch(self) -> int:
        return self.b_o + self.b_s + self.b_l

    def role_of(self, worker: str) -> Optional[str]:
        for role, w in (("o", self.worker_o), ("s", self.worker_s),
                        ("l", self.worker_l)):
            if w == worker:
                return role
        return None

    def describe(self) -> str:
        return (f"o={self.worker_o}(b={self.b_o}) "
                f"s={self.worker_s}(m={self.m_s},b={self.b_s}) "
                f"l={self.worker_l}(m={self.m_l},b={self.b_l})")


@dataclasses.dataclass
class Breakdown:
    """Per-phase latencies of one training iteration — Eq. (12) terms."""
    t_f1: float
    t_b1: float
    t_f2: float
    t_b2: float
    t_f3: float
    t_b3: float
    t_update: float
    # Diagnostics (not part of T_total; already contained in the above):
    comm_input: float = 0.0
    comm_activation: float = 0.0
    comm_weightgrad: float = 0.0

    @property
    def total(self) -> float:
        return (self.t_f1 + self.t_b1 + self.t_f2 + self.t_b2 +
                self.t_f3 + self.t_b3 + self.t_update)


def bw_matrix(net: Network) -> np.ndarray:
    """``[3, 3]`` pairwise bandwidth table over :data:`WORKERS` (diagonal is
    ``inf``: a worker talking to itself is free)."""
    return np.array([[net.bw(a, b) for b in WORKERS] for a in WORKERS],
                    np.float64)


def _t_total_batch(profile: HierProfile, net: Network,
                   o_idx: np.ndarray, s_idx: np.ndarray, l_idx: np.ndarray,
                   ms: np.ndarray, ml: np.ndarray, b: np.ndarray,
                   origin: str = "device") -> np.ndarray:
    """Vectorized :func:`_t_total` over K candidate schedules.

    Parameters
    ----------
    o_idx, s_idx, l_idx : ``[K]`` int — :data:`WIDX` indices of the workers
        holding TASK O / S / L.
    ms, ml : ``[K]`` int — cut points (``0 <= ms <= ml <= N``).
    b : ``[K, 3]`` — integer batch split ``(b_o, b_s, b_l)``.
    origin : worker the training data starts on.

    Returns ``[K]`` exact ``T_total`` values.  Every arithmetic expression
    mirrors the scalar :func:`t_total` term-for-term (same operation
    order), so a lane equals the scalar evaluation of the same schedule
    bit-for-bit — the batched scheduler's argmin agrees with the
    reference scheduler's sequential min.
    """
    N = profile.num_layers
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    bwm = bw_matrix(net)
    oi = WIDX[origin]
    Q = profile.sample_bytes
    bo = np.asarray(b[:, 0], np.float64)
    bs = np.asarray(b[:, 1], np.float64)
    bl = np.asarray(b[:, 2], np.float64)

    bw_os = bwm[o_idx, s_idx]
    bw_ol = bwm[o_idx, l_idx]

    # --- communication pieces -------------------------------------------
    def t_in(w_idx: np.ndarray, bb: np.ndarray) -> np.ndarray:
        return np.where((bb == 0) | (w_idx == oi), 0.0,
                        bb * Q / bwm[oi, w_idx])

    t_in_o, t_in_s, t_in_l = t_in(o_idx, bo), t_in(s_idx, bs), t_in(l_idx, bl)
    mo_s = profile.MO[np.maximum(ms, 1) - 1]   # MO_{m_s} (junk at ms == 0)
    mo_l = profile.MO[np.maximum(ml, 1) - 1]
    mg_s = profile.MG[np.maximum(ms, 1) - 1]   # backward wire bytes
    mg_l = profile.MG[np.maximum(ml, 1) - 1]
    t_s_out = np.where((ms > 0) & (bs > 0), bs * mo_s / bw_os, 0.0)
    t_l_out = np.where((ml > 0) & (bl > 0), bl * mo_l / bw_ol, 0.0)
    t_s_gout = np.where((ms > 0) & (bs > 0), bs * mg_s / bw_os, 0.0)
    t_l_gout = np.where((ml > 0) & (bl > 0), bl * mg_l / bw_ol, 0.0)

    # --- Eq. (5)/(6): layers 1..m_s on all three workers ----------------
    t_f1 = np.maximum(np.maximum(t_in_o + bo * F[o_idx, ms],
                                 t_in_s + bs * F[s_idx, ms] + t_s_out),
                      t_in_l + bl * F[l_idx, ms])
    t_b1 = np.maximum(np.maximum(bo * Bk[o_idx, ms],
                                 bs * Bk[s_idx, ms] + t_s_gout),
                      bl * Bk[l_idx, ms])

    # --- Eq. (7)/(8): layers m_s+1..m_l ---------------------------------
    t_f2 = np.maximum((bo + bs) * (F[o_idx, ml] - F[o_idx, ms]),
                      bl * (F[l_idx, ml] - F[l_idx, ms]) + t_l_out)
    t_b2 = np.maximum((bo + bs) * (Bk[o_idx, ml] - Bk[o_idx, ms]),
                      bl * (Bk[l_idx, ml] - Bk[l_idx, ms]) + t_l_gout)

    # --- Eq. (9)/(10): layers m_l+1..N with the full batch --------------
    B = bo + bs + bl
    t_f3 = B * (F[o_idx, N] - F[o_idx, ml])
    t_b3 = B * (Bk[o_idx, N] - Bk[o_idx, ml])

    # --- Eq. (11): weight update ----------------------------------------
    t_upd_o = U[o_idx, N]
    t_upd_s = np.where(bs > 0, U[s_idx, ms], 0.0)
    t_upd_l = np.where(bl > 0, U[l_idx, ml], 0.0)
    t_wg_s = np.where(bs > 0, 2.0 * MPc[ms] / bw_os, 0.0)
    t_wg_l = np.where(bl > 0, 2.0 * MPc[ml] / bw_ol, 0.0)
    t_update = np.maximum(np.maximum(t_upd_o, t_upd_s), t_upd_l) + \
        np.maximum(t_wg_s, t_wg_l)

    return t_f1 + t_b1 + t_f2 + t_b2 + t_f3 + t_b3 + t_update


# ---------------------------------------------------------------------------
# M-device generalization (DESIGN.md §6).
#
# Topology: M heterogeneous devices, each with its own uplink to one edge
# server; the edge uplinks to one cloud.  Training data lives on the devices
# (device-resident tasks read local samples for free; edge/cloud-resident
# tasks ingest their sub-batch uploaded evenly, in parallel, from all M
# devices).  One worker holds TASK O (full model), one holds TASK L (layers
# 1..m_l); every remaining worker holds a TASK-S instance with its own cut
# m_s[i] <= m_l.  With M = 1 this is exactly the paper's three-worker model
# (same six role mappings, same Eq. 12 — bit-for-bit; the equivalence suite
# asserts it).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiProfile:
    """Profiling-stage output for the M-device star topology.

    Same per-layer quantities as :class:`HierProfile` (including the
    optional backward wire bytes ``MG``, defaulting to ``MO``), but with
    one row per worker in ``worker_names`` order: ``M`` device rows first,
    then ``"edge"``, then ``"cloud"`` (so ``L_f`` is ``[M+2, N]``).
    """
    layer_names: Tuple[str, ...]
    worker_names: Tuple[str, ...]
    L_f: np.ndarray
    L_b: np.ndarray
    L_u: np.ndarray
    MP: np.ndarray
    MO: np.ndarray
    sample_bytes: float
    MG: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        self.L_f = np.asarray(self.L_f, np.float64)
        self.L_b = np.asarray(self.L_b, np.float64)
        self.L_u = np.asarray(self.L_u, np.float64)
        self.MP = np.asarray(self.MP, np.float64)
        self.MO = np.asarray(self.MO, np.float64)
        self.MG = self.MO if self.MG is None \
            else np.asarray(self.MG, np.float64)
        n, w = self.num_layers, self.num_workers
        assert w >= 3 and self.worker_names[-2:] == ("edge", "cloud")
        assert len(set(self.worker_names)) == w, "duplicate worker name"
        assert self.L_f.shape == (w, n) and self.L_b.shape == (w, n)
        assert self.L_u.shape == (w, n) and self.MP.shape == (n,)
        assert self.MO.shape == (n,) and self.MG.shape == (n,)

    @property
    def num_layers(self) -> int:
        return len(self.layer_names)

    @property
    def num_workers(self) -> int:
        return len(self.worker_names)

    @property
    def num_devices(self) -> int:
        return self.num_workers - 2

    @property
    def device_names(self) -> Tuple[str, ...]:
        return self.worker_names[:-2]

    @property
    def widx(self) -> Dict[str, int]:
        return {w: i for i, w in enumerate(self.worker_names)}

    def prefix(self) -> Dict[str, np.ndarray]:
        if not hasattr(self, "_prefix"):
            z = np.zeros((self.num_workers, 1))
            zl = np.zeros(1)
            self._prefix = {
                "F": np.concatenate([z, np.cumsum(self.L_f, axis=1)], axis=1),
                "Bk": np.concatenate([z, np.cumsum(self.L_b, axis=1)],
                                     axis=1),
                "U": np.concatenate([z, np.cumsum(self.L_u, axis=1)], axis=1),
                "MP": np.concatenate([zl, np.cumsum(self.MP)]),
            }
        return self._prefix

    @classmethod
    def from_hier(cls, profile: HierProfile,
                  device_scales: Sequence[float] = (1.0,)) -> "MultiProfile":
        """Lift a 3-worker profile to M devices.

        ``device_scales[i]`` is device *i*'s slowdown relative to the
        profiled device row (1.0 = identical, 2.0 = half speed).  With a
        single scale of 1.0 the result is the numerically identical M=1
        profile (``x * 1.0`` is exact).
        """
        scales = np.asarray(tuple(device_scales), np.float64)
        assert scales.ndim == 1 and scales.size >= 1 and (scales > 0).all()
        m = scales.size
        names = (("device",) if m == 1 else
                 tuple(f"device_{i}" for i in range(m))) + ("edge", "cloud")

        def lift(a: np.ndarray) -> np.ndarray:
            return np.concatenate([a[0][None, :] * scales[:, None], a[1:]],
                                  axis=0)

        return cls(layer_names=profile.layer_names, worker_names=names,
                   L_f=lift(profile.L_f), L_b=lift(profile.L_b),
                   L_u=lift(profile.L_u), MP=profile.MP, MO=profile.MO,
                   sample_bytes=profile.sample_bytes, MG=profile.MG)

    def three_worker(self) -> HierProfile:
        """The exact 3-worker profile (requires ``M == 1``)."""
        assert self.num_devices == 1, "only an M=1 profile reduces"
        return HierProfile(layer_names=self.layer_names, L_f=self.L_f,
                           L_b=self.L_b, L_u=self.L_u, MP=self.MP,
                           MO=self.MO, sample_bytes=self.sample_bytes,
                           MG=self.MG)

    # ---- membership edits (elastic fleets, DESIGN.md §10) ---------------
    # All return NEW profiles (rows are copied, the per-layer columns are
    # shared); the prefix cache is never inherited, so downstream costs
    # always see the edited membership.

    def device_index(self, name: str) -> int:
        """Index of device ``name`` (raises on edge/cloud or unknown)."""
        if name not in self.device_names:
            raise ValueError(f"{name!r} is not a device of this fleet "
                             f"(devices: {self.device_names})")
        return self.widx[name]

    def drop_device(self, name: str) -> "MultiProfile":
        """Membership edit: remove device ``name`` (a leave or crash).

        The surviving rows are byte-identical to the original profile's,
        so every cost of the edited fleet equals a fresh fleet built from
        the survivors bit-for-bit."""
        i = self.device_index(name)
        if self.num_devices < 2:
            raise ValueError("cannot drop the last device of the fleet")
        keep = [j for j in range(self.num_workers) if j != i]
        return MultiProfile(
            layer_names=self.layer_names,
            worker_names=tuple(self.worker_names[j] for j in keep),
            L_f=self.L_f[keep].copy(), L_b=self.L_b[keep].copy(),
            L_u=self.L_u[keep].copy(), MP=self.MP, MO=self.MO,
            sample_bytes=self.sample_bytes, MG=self.MG)

    def add_device(self, name: str, L_f_row, L_b_row,
                   L_u_row) -> "MultiProfile":
        """Membership edit: append device ``name`` after the existing
        devices with the given per-layer second rows (seeded from the
        joiner's :class:`~repro.core.profiler.WorkerSpec` tier by
        :func:`repro.core.churn.apply_event`; the online EMA refines it
        from the first straggler report onward)."""
        if name in self.worker_names:
            raise ValueError(f"worker {name!r} already in the fleet")
        m = self.num_devices

        def ins(a: np.ndarray, row) -> np.ndarray:
            row = np.asarray(row, np.float64).reshape(1, -1)
            assert row.shape[1] == self.num_layers
            return np.concatenate([a[:m], row, a[m:]], axis=0)

        return MultiProfile(
            layer_names=self.layer_names,
            worker_names=self.worker_names[:m] + (name,) +
            self.worker_names[m:],
            L_f=ins(self.L_f, L_f_row), L_b=ins(self.L_b, L_b_row),
            L_u=ins(self.L_u, L_u_row), MP=self.MP, MO=self.MO,
            sample_bytes=self.sample_bytes, MG=self.MG)


@dataclasses.dataclass
class StarNetwork:
    """Star topology: per-device uplinks ``bw_de[i]`` (device_i↔edge) and one
    backhaul ``bw_ec`` (edge↔cloud), all in bytes/s.  Paths without a direct
    link (device↔cloud, device↔device) are the series composition of their
    hops through the edge, matching :meth:`Network.bw`."""
    bw_de: np.ndarray
    bw_ec: float

    def __post_init__(self) -> None:
        self.bw_de = np.atleast_1d(np.asarray(self.bw_de, np.float64))
        assert (self.bw_de > 0).all() and self.bw_ec > 0

    @property
    def num_devices(self) -> int:
        return int(self.bw_de.size)

    @classmethod
    def from_network(cls, net: Network, num_devices: int = 1
                     ) -> "StarNetwork":
        return cls(bw_de=np.full(num_devices, net.bw_de), bw_ec=net.bw_ec)

    def three_worker(self) -> Network:
        assert self.num_devices == 1
        return Network(bw_de=float(self.bw_de[0]), bw_ec=self.bw_ec)

    def bw_matrix(self) -> np.ndarray:
        """``[M+2, M+2]`` pairwise bandwidths in worker order (devices...,
        edge, cloud); diagonal ``inf``.  ``[i, j]`` for two devices is the
        relayed series path through the edge."""
        m = self.num_devices
        w = m + 2
        bwm = np.full((w, w), np.inf)
        de, ec = self.bw_de, self.bw_ec
        bwm[:m, m] = bwm[m, :m] = de                     # device_i <-> edge
        bwm[m, m + 1] = bwm[m + 1, m] = ec               # edge <-> cloud
        dc = 1.0 / (1.0 / de + 1.0 / ec)                 # relayed, Fig. 1(c)
        bwm[:m, m + 1] = bwm[m + 1, :m] = dc
        dd = 1.0 / (1.0 / de[:, None] + 1.0 / de[None, :])
        dd[np.diag_indices(m)] = np.inf
        bwm[:m, :m] = dd
        return bwm

    # ---- membership edits (elastic fleets, DESIGN.md §10) ---------------

    def drop_device(self, i: int) -> "StarNetwork":
        """Remove device ``i``'s uplink (paired with
        :meth:`MultiProfile.drop_device`)."""
        if not 0 <= i < self.num_devices:
            raise ValueError(f"no device {i} in a {self.num_devices}-device "
                             "star")
        if self.num_devices < 2:
            raise ValueError("cannot drop the last device of the fleet")
        return StarNetwork(bw_de=np.delete(self.bw_de, i), bw_ec=self.bw_ec)

    def add_device(self, bw: float) -> "StarNetwork":
        """Append a device uplink of ``bw`` bytes/s."""
        return StarNetwork(bw_de=np.concatenate([self.bw_de, [bw]]),
                           bw_ec=self.bw_ec)

    def scale_uplink(self, i: int, factor: float) -> "StarNetwork":
        """Multiply device ``i``'s uplink by ``factor`` (a
        :class:`~repro.core.churn.LinkDegrade`; ``factor > 1`` heals)."""
        if not 0 <= i < self.num_devices:
            raise ValueError(f"no device {i} in a {self.num_devices}-device "
                             "star")
        if factor <= 0:
            raise ValueError("uplink scale factor must be positive")
        bw = self.bw_de.copy()
        bw[i] *= factor
        return StarNetwork(bw_de=bw, bw_ec=self.bw_ec)

    def upload_bw(self) -> np.ndarray:
        """``[M+2]`` effective ingest bandwidth for a worker receiving its
        sub-batch uploaded *evenly in parallel* from all M devices: the
        slowest uplink carries ``1/M`` of the bytes, so the edge ingests at
        ``M * min(bw_de)`` and the cloud at the series composition of that
        with the backhaul.  Devices read local samples (``inf``)."""
        m = self.num_devices
        up = np.full(m + 2, np.inf)
        radio = m * self.bw_de.min()
        up[m] = radio
        up[m + 1] = 1.0 / (1.0 / radio + 1.0 / self.bw_ec)
        return up


@dataclasses.dataclass(frozen=True)
class MultiSchedule:
    """An M-device HierTrain scheduling decision.

    ``s_workers[i]`` runs a TASK-S instance over layers ``1..m_s[i]`` on its
    ``b_s[i]`` samples; ``worker_o``/``worker_l`` are as in :class:`Schedule`.
    ``len(s_workers) == M`` always (the non-o, non-l workers)."""
    worker_o: str
    worker_l: str
    s_workers: Tuple[str, ...]
    m_s: Tuple[int, ...]
    m_l: int
    b_o: int
    b_s: Tuple[int, ...]
    b_l: int

    @property
    def batch(self) -> int:
        return self.b_o + sum(self.b_s) + self.b_l

    def describe(self) -> str:
        s = " ".join(f"s={w}(m={m},b={b})" for w, m, b in
                     zip(self.s_workers, self.m_s, self.b_s))
        return (f"o={self.worker_o}(b={self.b_o}) {s} "
                f"l={self.worker_l}(m={self.m_l},b={self.b_l})")

    @classmethod
    def from_schedule(cls, sched: Schedule) -> "MultiSchedule":
        return cls(worker_o=sched.worker_o, worker_l=sched.worker_l,
                   s_workers=(sched.worker_s,), m_s=(sched.m_s,),
                   m_l=sched.m_l, b_o=sched.b_o, b_s=(sched.b_s,),
                   b_l=sched.b_l)

    def to_schedule(self) -> Schedule:
        assert len(self.s_workers) == 1, "only an M=1 schedule reduces"
        return Schedule(worker_o=self.worker_o, worker_s=self.s_workers[0],
                        worker_l=self.worker_l, m_s=self.m_s[0],
                        m_l=self.m_l, b_o=self.b_o, b_s=self.b_s[0],
                        b_l=self.b_l)


def _validate_multi(profile: MultiProfile, sched: MultiSchedule) -> None:
    N = profile.num_layers
    M = profile.num_devices
    assert len(sched.s_workers) == len(sched.m_s) == len(sched.b_s) == M
    assert 0 <= sched.m_l <= N
    for m_i, b_i in zip(sched.m_s, sched.b_s):
        assert 0 <= m_i <= sched.m_l, "need 0 <= m_s[i] <= m_l <= N"
        if m_i == 0:
            assert b_i == 0, "m_s[i] = 0 forces b_s[i] = 0"
    if sched.m_l == 0:
        assert sched.b_l == 0, "m_l = 0 forces b_l = 0"
    widx = profile.widx
    seen = {sched.worker_o, sched.worker_l, *sched.s_workers}
    assert len(seen) == M + 2 and all(w in widx for w in seen), \
        "schedule must name every worker exactly once"


def _t_total_multi(profile: MultiProfile, net: StarNetwork,
                   sched: MultiSchedule) -> Breakdown:
    """Exact generalized Eq. (12) for an integer M-device schedule.

    Phase structure (DESIGN.md §6): phase 1 runs every TASK-S front-end in
    parallel up to its own cut; worker_o's catch-up of stream *i* from
    ``m_s[i]`` to ``max_i m_s[i]`` is charged to phase 2 alongside the
    common ``max_i m_s[i] .. m_l`` block.  With ``M = 1`` every term reduces
    to the three-worker expression bit-for-bit.
    """
    _validate_multi(profile, sched)
    N = profile.num_layers
    M = profile.num_devices
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    widx = profile.widx
    o, l = widx[sched.worker_o], widx[sched.worker_l]
    s = [widx[w] for w in sched.s_workers]
    ml = sched.m_l
    bo, bl = sched.b_o, sched.b_l
    bs = sched.b_s
    msmax = max(sched.m_s)
    bwm = net.bw_matrix()
    up = net.upload_bw()
    Q = profile.sample_bytes

    def t_in(w: int, b: int) -> float:
        if b == 0 or w < M:          # device-resident: local data
            return 0.0
        return b * Q / up[w]

    t_in_o, t_in_l = t_in(o, bo), t_in(l, bl)
    t_in_s = [t_in(si, bi) for si, bi in zip(s, bs)]
    t_s_out = [bi * profile.MO[mi - 1] / bwm[o, si]
               if (mi > 0 and bi > 0) else 0.0
               for si, mi, bi in zip(s, sched.m_s, bs)]
    t_l_out = bl * profile.MO[ml - 1] / bwm[o, l] \
        if (ml > 0 and bl > 0) else 0.0
    t_s_gout = [bi * profile.MG[mi - 1] / bwm[o, si]
                if (mi > 0 and bi > 0) else 0.0
                for si, mi, bi in zip(s, sched.m_s, bs)]
    t_l_gout = bl * profile.MG[ml - 1] / bwm[o, l] \
        if (ml > 0 and bl > 0) else 0.0

    # --- phase 1: every front-end in parallel up to its own cut ----------
    t_f1 = max(t_in_o + bo * F[o, msmax],
               *[ti + bi * F[si, mi] + to for ti, si, mi, bi, to in
                 zip(t_in_s, s, sched.m_s, bs, t_s_out)],
               t_in_l + bl * F[l, msmax])
    t_b1 = max(bo * Bk[o, msmax],
               *[bi * Bk[si, mi] + to for si, mi, bi, to in
                 zip(s, sched.m_s, bs, t_s_gout)],
               bl * Bk[l, msmax])

    # --- phase 2: worker_o catches every stream up, then the common block -
    bs_sum = sum(bs)
    catch_f = sum(bi * (F[o, msmax] - F[o, mi])
                  for mi, bi in zip(sched.m_s, bs))
    catch_b = sum(bi * (Bk[o, msmax] - Bk[o, mi])
                  for mi, bi in zip(sched.m_s, bs))
    t_f2 = max((bo + bs_sum) * (F[o, ml] - F[o, msmax]) + catch_f,
               bl * (F[l, ml] - F[l, msmax]) + t_l_out)
    t_b2 = max((bo + bs_sum) * (Bk[o, ml] - Bk[o, msmax]) + catch_b,
               bl * (Bk[l, ml] - Bk[l, msmax]) + t_l_gout)

    # --- phase 3 + weight update (as in the three-worker model) ----------
    B = bo + bs_sum + bl
    t_f3 = B * (F[o, N] - F[o, ml])
    t_b3 = B * (Bk[o, N] - Bk[o, ml])
    t_upd_o = U[o, N]
    t_upd_s = [U[si, mi] if bi > 0 else 0.0
               for si, mi, bi in zip(s, sched.m_s, bs)]
    t_upd_l = U[l, ml] if bl > 0 else 0.0
    t_wg_s = [2.0 * MPc[mi] / bwm[o, si] if bi > 0 else 0.0
              for si, mi, bi in zip(s, sched.m_s, bs)]
    t_wg_l = 2.0 * MPc[ml] / bwm[o, l] if bl > 0 else 0.0
    t_update = max(t_upd_o, *t_upd_s, t_upd_l) + max(*t_wg_s, t_wg_l)

    return Breakdown(
        t_f1=t_f1, t_b1=t_b1, t_f2=t_f2, t_b2=t_b2, t_f3=t_f3, t_b3=t_b3,
        t_update=t_update,
        comm_input=t_in_o + sum(t_in_s) + t_in_l,
        comm_activation=(sum(t_s_out) + t_l_out) +
                        (sum(t_s_gout) + t_l_gout),
        comm_weightgrad=max(*t_wg_s, t_wg_l),
    )


def _t_total_multi_batch(profile: MultiProfile, net: StarNetwork,
                         o_idx: np.ndarray, s_idx: np.ndarray,
                         l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                         b: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_t_total_multi` over K candidate schedules.

    ``o_idx, l_idx, ml``: ``[K]``; ``s_idx, ms``: ``[K, M]``;
    ``b``: ``[K, M+2]`` split ``(b_o, b_s[0..M-1], b_l)``.  Every arithmetic
    expression mirrors the scalar evaluation term-for-term, and with
    ``M = 1`` also mirrors :func:`t_total_batch` — a lane is bit-identical
    to both.
    """
    N = profile.num_layers
    M = profile.num_devices
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    bwm = net.bw_matrix()
    up = net.upload_bw()
    Q = profile.sample_bytes
    bo = np.asarray(b[:, 0], np.float64)
    bs = np.asarray(b[:, 1:1 + M], np.float64)
    bl = np.asarray(b[:, 1 + M], np.float64)
    o2 = o_idx[:, None]
    msmax = ms.max(axis=1)

    bw_os = bwm[o_idx[:, None], s_idx]        # [K, M]
    bw_ol = bwm[o_idx, l_idx]

    def t_in(w_idx: np.ndarray, bb: np.ndarray) -> np.ndarray:
        return np.where((bb == 0) | (w_idx < M), 0.0, bb * Q / up[w_idx])

    t_in_o, t_in_s, t_in_l = t_in(o_idx, bo), t_in(s_idx, bs), t_in(l_idx, bl)
    mo_s = profile.MO[np.maximum(ms, 1) - 1]
    mo_l = profile.MO[np.maximum(ml, 1) - 1]
    mg_s = profile.MG[np.maximum(ms, 1) - 1]
    mg_l = profile.MG[np.maximum(ml, 1) - 1]
    t_s_out = np.where((ms > 0) & (bs > 0), bs * mo_s / bw_os, 0.0)
    t_l_out = np.where((ml > 0) & (bl > 0), bl * mo_l / bw_ol, 0.0)
    t_s_gout = np.where((ms > 0) & (bs > 0), bs * mg_s / bw_os, 0.0)
    t_l_gout = np.where((ml > 0) & (bl > 0), bl * mg_l / bw_ol, 0.0)

    # --- phase 1 ---------------------------------------------------------
    t_f1 = np.maximum(np.maximum(t_in_o + bo * F[o_idx, msmax],
                                 (t_in_s + bs * F[s_idx, ms] +
                                  t_s_out).max(axis=1)),
                      t_in_l + bl * F[l_idx, msmax])
    t_b1 = np.maximum(np.maximum(bo * Bk[o_idx, msmax],
                                 (bs * Bk[s_idx, ms] +
                                  t_s_gout).max(axis=1)),
                      bl * Bk[l_idx, msmax])

    # --- phase 2 (catch-up + common block) -------------------------------
    bs_sum = bs.sum(axis=1)
    catch_f = (bs * (F[o2, msmax[:, None]] - F[o2, ms])).sum(axis=1)
    catch_b = (bs * (Bk[o2, msmax[:, None]] - Bk[o2, ms])).sum(axis=1)
    t_f2 = np.maximum(
        (bo + bs_sum) * (F[o_idx, ml] - F[o_idx, msmax]) + catch_f,
        bl * (F[l_idx, ml] - F[l_idx, msmax]) + t_l_out)
    t_b2 = np.maximum(
        (bo + bs_sum) * (Bk[o_idx, ml] - Bk[o_idx, msmax]) + catch_b,
        bl * (Bk[l_idx, ml] - Bk[l_idx, msmax]) + t_l_gout)

    # --- phase 3 + update ------------------------------------------------
    B = bo + bs_sum + bl
    t_f3 = B * (F[o_idx, N] - F[o_idx, ml])
    t_b3 = B * (Bk[o_idx, N] - Bk[o_idx, ml])
    t_upd_o = U[o_idx, N]
    t_upd_s = np.where(bs > 0, U[s_idx, ms], 0.0).max(axis=1)
    t_upd_l = np.where(bl > 0, U[l_idx, ml], 0.0)
    t_wg_s = np.where(bs > 0, 2.0 * MPc[ms] / bw_os, 0.0).max(axis=1)
    t_wg_l = np.where(bl > 0, 2.0 * MPc[ml] / bw_ol, 0.0)
    t_update = np.maximum(np.maximum(t_upd_o, t_upd_s), t_upd_l) + \
        np.maximum(t_wg_s, t_wg_l)

    return t_f1 + t_b1 + t_f2 + t_b2 + t_f3 + t_b3 + t_update


def t_input(profile: HierProfile, net: Network, worker: str, b: int,
            origin: str = "device") -> float:
    """``T_{j,input}``: latency for worker *j* to receive its ``b`` samples."""
    if b == 0 or worker == origin:
        return 0.0
    return b * profile.sample_bytes / net.bw(origin, worker)


def _t_total(profile: HierProfile, net: Network, sched: Schedule,
             origin: str = "device") -> Breakdown:
    """Exact Eq. (12) evaluation for an (integer) schedule.

    This is the canonical *three-worker* evaluation — the correctness
    oracle the M=1 equivalence suite compares the star model against,
    and the only path that supports ``origin != "device"`` or
    degenerate schedules that repeat a worker across roles (the
    all-on-one baselines)."""
    N = profile.num_layers
    assert 0 <= sched.m_s <= sched.m_l <= N, "need 0 <= m_s <= m_l <= N"
    if sched.m_s == 0:
        assert sched.b_s == 0, "m_s = 0 forces b_s = 0 (constraint (14))"
    if sched.m_l == 0:
        assert sched.b_l == 0, "m_l = 0 forces b_l = 0 (constraint (15))"
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    o, s, l = WIDX[sched.worker_o], WIDX[sched.worker_s], WIDX[sched.worker_l]
    ms, ml = sched.m_s, sched.m_l
    bo, bs, bl = sched.b_o, sched.b_s, sched.b_l

    bw_os = net.bw(sched.worker_o, sched.worker_s)
    bw_ol = net.bw(sched.worker_o, sched.worker_l)

    # --- communication pieces -------------------------------------------
    t_in_o = t_input(profile, net, sched.worker_o, bo, origin)
    t_in_s = t_input(profile, net, sched.worker_s, bs, origin)
    t_in_l = t_input(profile, net, sched.worker_l, bl, origin)
    # T_{s,output} = b_s * MO_{m_s} / B_{o,s}  (§IV-C); T_{s,grad} uses the
    # backward wire bytes MG_{m_s} (== MO by default, LM profiles differ).
    t_s_out = bs * profile.MO[ms - 1] / bw_os if (ms > 0 and bs > 0) else 0.0
    t_l_out = bl * profile.MO[ml - 1] / bw_ol if (ml > 0 and bl > 0) else 0.0
    t_s_gout = bs * profile.MG[ms - 1] / bw_os if (ms > 0 and bs > 0) else 0.0
    t_l_gout = bl * profile.MG[ml - 1] / bw_ol if (ml > 0 and bl > 0) else 0.0

    # --- Eq. (5)/(6): layers 1..m_s on all three workers ----------------
    t_f1 = max(t_in_o + bo * F[o, ms],
               t_in_s + bs * F[s, ms] + t_s_out,
               t_in_l + bl * F[l, ms])
    t_b1 = max(bo * Bk[o, ms],
               bs * Bk[s, ms] + t_s_gout,
               bl * Bk[l, ms])

    # --- Eq. (7)/(8): layers m_s+1..m_l on worker_o (b_o+b_s) & worker_l -
    t_f2 = max((bo + bs) * (F[o, ml] - F[o, ms]),
               bl * (F[l, ml] - F[l, ms]) + t_l_out)
    t_b2 = max((bo + bs) * (Bk[o, ml] - Bk[o, ms]),
               bl * (Bk[l, ml] - Bk[l, ms]) + t_l_gout)

    # --- Eq. (9)/(10): layers m_l+1..N on worker_o with the full batch ---
    B = bo + bs + bl
    t_f3 = B * (F[o, N] - F[o, ml])
    t_b3 = B * (Bk[o, N] - Bk[o, ml])

    # --- Eq. (11): weight update -----------------------------------------
    # worker_o updates all N layers (TASK O is the full model); worker_s
    # updates 1..m_s; worker_l updates 1..m_l.  Gradient exchange covers the
    # *shared* (frontend) layers only: 2 * sum MP_i (push grads + pull avg).
    t_upd_o = U[o, N]
    t_upd_s = U[s, ms] if bs > 0 else 0.0
    t_upd_l = U[l, ml] if bl > 0 else 0.0
    t_wg_s = 2.0 * MPc[ms] / bw_os if bs > 0 else 0.0
    t_wg_l = 2.0 * MPc[ml] / bw_ol if bl > 0 else 0.0
    t_update = max(t_upd_o, t_upd_s, t_upd_l) + max(t_wg_s, t_wg_l)

    return Breakdown(
        t_f1=t_f1, t_b1=t_b1, t_f2=t_f2, t_b2=t_b2, t_f3=t_f3, t_b3=t_b3,
        t_update=t_update,
        comm_input=t_in_o + t_in_s + t_in_l,
        comm_activation=(t_s_out + t_l_out) + (t_s_gout + t_l_gout),
        comm_weightgrad=max(t_wg_s, t_wg_l),
    )


# ---------------------------------------------------------------------------
# Deprecated public surface (DESIGN.md §9).  The forked t_total* pairs are
# shims over the unified model: the 3-worker entry points lift their
# arguments onto the star types and evaluate the M-device model, which is
# bit-for-bit identical at M = 1 (the equivalence suite asserts it).
# Non-collapsible corners — ``origin != "device"`` and degenerate
# schedules that repeat a worker (the all-on-one baselines) — fall back
# to the retained 3-worker oracle.
# ---------------------------------------------------------------------------


def t_total(profile: HierProfile, net: Network, sched: Schedule,
            origin: str = "device") -> Breakdown:
    """Deprecated: use ``repro.api.plan(...).breakdown`` (Plan carries the
    exact Eq.-12 evaluation of its chosen schedule)."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.cost_model.t_total()",
                    "repro.api.plan(model, fleet, B).breakdown")
    distinct = len({sched.worker_o, sched.worker_s, sched.worker_l}) == 3
    if origin == "device" and distinct:
        return _t_total_multi(MultiProfile.from_hier(profile),
                              StarNetwork.from_network(net),
                              MultiSchedule.from_schedule(sched))
    return _t_total(profile, net, sched, origin)


def t_total_batch(profile: HierProfile, net: Network,
                  o_idx: np.ndarray, s_idx: np.ndarray, l_idx: np.ndarray,
                  ms: np.ndarray, ml: np.ndarray, b: np.ndarray,
                  origin: str = "device") -> np.ndarray:
    """Deprecated: the batched kernels are internal to the facade — use
    ``repro.api.plan`` (the scheduler scores candidates itself)."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.cost_model.t_total_batch()",
                    "repro.api.plan(model, fleet, B)")
    if origin == "device":
        return _t_total_multi_batch(
            MultiProfile.from_hier(profile), StarNetwork.from_network(net),
            np.asarray(o_idx), np.asarray(s_idx)[:, None],
            np.asarray(l_idx), np.asarray(ms)[:, None], np.asarray(ml),
            np.asarray(b))
    return _t_total_batch(profile, net, o_idx, s_idx, l_idx, ms, ml, b,
                          origin)


def t_total_multi(profile: MultiProfile, net: StarNetwork,
                  sched: MultiSchedule) -> Breakdown:
    """Deprecated: use ``repro.api.plan(...).breakdown``."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.cost_model.t_total_multi()",
                    "repro.api.plan(model, fleet, B).breakdown")
    return _t_total_multi(profile, net, sched)


def t_total_multi_batch(profile: MultiProfile, net: StarNetwork,
                        o_idx: np.ndarray, s_idx: np.ndarray,
                        l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                        b: np.ndarray) -> np.ndarray:
    """Deprecated: use ``repro.api.plan`` (internal scoring kernel)."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.cost_model.t_total_multi_batch()",
                    "repro.api.plan(model, fleet, B)")
    return _t_total_multi_batch(profile, net, o_idx, s_idx, l_idx, ms, ml,
                                b)
