"""HierTrain per-iteration training-time cost model — Eqs. (1)-(12) of the paper.

Conventions
-----------
* Physical workers are ``"device"``, ``"edge"``, ``"cloud"`` (indices 0/1/2).
* Roles are ``o`` (TASK O, full model, owner), ``s`` (TASK S, layers 1..m_s),
  ``l`` (TASK L, layers 1..m_l), with ``0 <= m_s <= m_l <= N``.
* Layers are 1-indexed in the paper; arrays here are 0-indexed, so layer ``i``
  lives at index ``i-1``.  ``MO[i-1]`` is the forward output size (bytes per
  sample) of layer ``i``; ``MP[i-1]`` its parameter bytes.
* All times in seconds, sizes in bytes, bandwidths in bytes/second.

The device↔cloud path is the series composition of the device↔edge and
edge↔cloud links (data is relayed through the edge — Fig. 1(c) topology); the
paper's Algorithm 1 only takes ``BW_de`` and ``BW_ec`` as inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

WORKERS: Tuple[str, str, str] = ("device", "edge", "cloud")
WIDX: Dict[str, int] = {w: i for i, w in enumerate(WORKERS)}


@dataclasses.dataclass
class HierProfile:
    """Profiling-stage output (§III, profiling stage).

    Attributes
    ----------
    L_f, L_b : ``[3, N]`` — forward/backward seconds *per sample* per layer
        per worker (``L^f_{j,i}``, ``L^b_{j,i}``).
    L_u : ``[3, N]`` — weight-update seconds per layer per worker
        (``L^u_{j,i}``; batch-size independent).
    MP : ``[N]`` — parameter bytes per layer (``MP_i``).
    MO : ``[N]`` — forward-output bytes per *sample* per layer (``MO_i``).
    sample_bytes : ``Q`` — bytes of one training sample (input + label).
    """
    layer_names: Tuple[str, ...]
    L_f: np.ndarray
    L_b: np.ndarray
    L_u: np.ndarray
    MP: np.ndarray
    MO: np.ndarray
    sample_bytes: float

    def __post_init__(self) -> None:
        self.L_f = np.asarray(self.L_f, np.float64)
        self.L_b = np.asarray(self.L_b, np.float64)
        self.L_u = np.asarray(self.L_u, np.float64)
        self.MP = np.asarray(self.MP, np.float64)
        self.MO = np.asarray(self.MO, np.float64)
        n = self.num_layers
        assert self.L_f.shape == (3, n) and self.L_b.shape == (3, n)
        assert self.L_u.shape == (3, n) and self.MP.shape == (n,)
        assert self.MO.shape == (n,)

    @property
    def num_layers(self) -> int:
        return len(self.layer_names)

    # Prefix sums (index k => layers 1..k inclusive) used all over the
    # scheduler; computed lazily and cached.
    def prefix(self) -> Dict[str, np.ndarray]:
        if not hasattr(self, "_prefix"):
            z = np.zeros((3, 1))
            zl = np.zeros(1)
            self._prefix = {
                "F": np.concatenate([z, np.cumsum(self.L_f, axis=1)], axis=1),
                "Bk": np.concatenate([z, np.cumsum(self.L_b, axis=1)], axis=1),
                "U": np.concatenate([z, np.cumsum(self.L_u, axis=1)], axis=1),
                "MP": np.concatenate([zl, np.cumsum(self.MP)]),
            }
        return self._prefix


@dataclasses.dataclass
class Network:
    """Bandwidths (bytes/s). ``bw_de``: device↔edge; ``bw_ec``: edge↔cloud."""
    bw_de: float
    bw_ec: float

    def bw(self, a: str, b: str) -> float:
        if a == b:
            return np.inf
        pair = frozenset((a, b))
        if pair == frozenset(("device", "edge")):
            return self.bw_de
        if pair == frozenset(("edge", "cloud")):
            return self.bw_ec
        # device <-> cloud: store-and-forward through the edge.
        return 1.0 / (1.0 / self.bw_de + 1.0 / self.bw_ec)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A full HierTrain scheduling decision (mapping + cuts + batch split)."""
    worker_o: str
    worker_s: str
    worker_l: str
    m_s: int
    m_l: int
    b_o: int
    b_s: int
    b_l: int

    @property
    def batch(self) -> int:
        return self.b_o + self.b_s + self.b_l

    def role_of(self, worker: str) -> Optional[str]:
        for role, w in (("o", self.worker_o), ("s", self.worker_s),
                        ("l", self.worker_l)):
            if w == worker:
                return role
        return None

    def describe(self) -> str:
        return (f"o={self.worker_o}(b={self.b_o}) "
                f"s={self.worker_s}(m={self.m_s},b={self.b_s}) "
                f"l={self.worker_l}(m={self.m_l},b={self.b_l})")


@dataclasses.dataclass
class Breakdown:
    """Per-phase latencies of one training iteration — Eq. (12) terms."""
    t_f1: float
    t_b1: float
    t_f2: float
    t_b2: float
    t_f3: float
    t_b3: float
    t_update: float
    # Diagnostics (not part of T_total; already contained in the above):
    comm_input: float = 0.0
    comm_activation: float = 0.0
    comm_weightgrad: float = 0.0

    @property
    def total(self) -> float:
        return (self.t_f1 + self.t_b1 + self.t_f2 + self.t_b2 +
                self.t_f3 + self.t_b3 + self.t_update)


def bw_matrix(net: Network) -> np.ndarray:
    """``[3, 3]`` pairwise bandwidth table over :data:`WORKERS` (diagonal is
    ``inf``: a worker talking to itself is free)."""
    return np.array([[net.bw(a, b) for b in WORKERS] for a in WORKERS],
                    np.float64)


def t_total_batch(profile: HierProfile, net: Network,
                  o_idx: np.ndarray, s_idx: np.ndarray, l_idx: np.ndarray,
                  ms: np.ndarray, ml: np.ndarray, b: np.ndarray,
                  origin: str = "device") -> np.ndarray:
    """Vectorized :func:`t_total` over K candidate schedules.

    Parameters
    ----------
    o_idx, s_idx, l_idx : ``[K]`` int — :data:`WIDX` indices of the workers
        holding TASK O / S / L.
    ms, ml : ``[K]`` int — cut points (``0 <= ms <= ml <= N``).
    b : ``[K, 3]`` — integer batch split ``(b_o, b_s, b_l)``.
    origin : worker the training data starts on.

    Returns ``[K]`` exact ``T_total`` values.  Every arithmetic expression
    mirrors the scalar :func:`t_total` term-for-term (same operation
    order), so a lane equals the scalar evaluation of the same schedule
    bit-for-bit — the batched scheduler's argmin agrees with the
    reference scheduler's sequential min.
    """
    N = profile.num_layers
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    bwm = bw_matrix(net)
    oi = WIDX[origin]
    Q = profile.sample_bytes
    bo = np.asarray(b[:, 0], np.float64)
    bs = np.asarray(b[:, 1], np.float64)
    bl = np.asarray(b[:, 2], np.float64)

    bw_os = bwm[o_idx, s_idx]
    bw_ol = bwm[o_idx, l_idx]

    # --- communication pieces -------------------------------------------
    def t_in(w_idx: np.ndarray, bb: np.ndarray) -> np.ndarray:
        return np.where((bb == 0) | (w_idx == oi), 0.0,
                        bb * Q / bwm[oi, w_idx])

    t_in_o, t_in_s, t_in_l = t_in(o_idx, bo), t_in(s_idx, bs), t_in(l_idx, bl)
    mo_s = profile.MO[np.maximum(ms, 1) - 1]   # MO_{m_s} (junk at ms == 0)
    mo_l = profile.MO[np.maximum(ml, 1) - 1]
    t_s_out = np.where((ms > 0) & (bs > 0), bs * mo_s / bw_os, 0.0)
    t_l_out = np.where((ml > 0) & (bl > 0), bl * mo_l / bw_ol, 0.0)

    # --- Eq. (5)/(6): layers 1..m_s on all three workers ----------------
    t_f1 = np.maximum(np.maximum(t_in_o + bo * F[o_idx, ms],
                                 t_in_s + bs * F[s_idx, ms] + t_s_out),
                      t_in_l + bl * F[l_idx, ms])
    t_b1 = np.maximum(np.maximum(bo * Bk[o_idx, ms],
                                 bs * Bk[s_idx, ms] + t_s_out),
                      bl * Bk[l_idx, ms])

    # --- Eq. (7)/(8): layers m_s+1..m_l ---------------------------------
    t_f2 = np.maximum((bo + bs) * (F[o_idx, ml] - F[o_idx, ms]),
                      bl * (F[l_idx, ml] - F[l_idx, ms]) + t_l_out)
    t_b2 = np.maximum((bo + bs) * (Bk[o_idx, ml] - Bk[o_idx, ms]),
                      bl * (Bk[l_idx, ml] - Bk[l_idx, ms]) + t_l_out)

    # --- Eq. (9)/(10): layers m_l+1..N with the full batch --------------
    B = bo + bs + bl
    t_f3 = B * (F[o_idx, N] - F[o_idx, ml])
    t_b3 = B * (Bk[o_idx, N] - Bk[o_idx, ml])

    # --- Eq. (11): weight update ----------------------------------------
    t_upd_o = U[o_idx, N]
    t_upd_s = np.where(bs > 0, U[s_idx, ms], 0.0)
    t_upd_l = np.where(bl > 0, U[l_idx, ml], 0.0)
    t_wg_s = np.where(bs > 0, 2.0 * MPc[ms] / bw_os, 0.0)
    t_wg_l = np.where(bl > 0, 2.0 * MPc[ml] / bw_ol, 0.0)
    t_update = np.maximum(np.maximum(t_upd_o, t_upd_s), t_upd_l) + \
        np.maximum(t_wg_s, t_wg_l)

    return t_f1 + t_b1 + t_f2 + t_b2 + t_f3 + t_b3 + t_update


def t_input(profile: HierProfile, net: Network, worker: str, b: int,
            origin: str = "device") -> float:
    """``T_{j,input}``: latency for worker *j* to receive its ``b`` samples."""
    if b == 0 or worker == origin:
        return 0.0
    return b * profile.sample_bytes / net.bw(origin, worker)


def t_total(profile: HierProfile, net: Network, sched: Schedule,
            origin: str = "device") -> Breakdown:
    """Exact Eq. (12) evaluation for an (integer) schedule."""
    N = profile.num_layers
    assert 0 <= sched.m_s <= sched.m_l <= N, "need 0 <= m_s <= m_l <= N"
    if sched.m_s == 0:
        assert sched.b_s == 0, "m_s = 0 forces b_s = 0 (constraint (14))"
    if sched.m_l == 0:
        assert sched.b_l == 0, "m_l = 0 forces b_l = 0 (constraint (15))"
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    o, s, l = WIDX[sched.worker_o], WIDX[sched.worker_s], WIDX[sched.worker_l]
    ms, ml = sched.m_s, sched.m_l
    bo, bs, bl = sched.b_o, sched.b_s, sched.b_l

    bw_os = net.bw(sched.worker_o, sched.worker_s)
    bw_ol = net.bw(sched.worker_o, sched.worker_l)

    # --- communication pieces -------------------------------------------
    t_in_o = t_input(profile, net, sched.worker_o, bo, origin)
    t_in_s = t_input(profile, net, sched.worker_s, bs, origin)
    t_in_l = t_input(profile, net, sched.worker_l, bl, origin)
    # T_{s,output} = b_s * MO_{m_s} / B_{o,s}; T_{s,grad} equals it.  (§IV-C)
    t_s_out = bs * profile.MO[ms - 1] / bw_os if (ms > 0 and bs > 0) else 0.0
    t_l_out = bl * profile.MO[ml - 1] / bw_ol if (ml > 0 and bl > 0) else 0.0

    # --- Eq. (5)/(6): layers 1..m_s on all three workers ----------------
    t_f1 = max(t_in_o + bo * F[o, ms],
               t_in_s + bs * F[s, ms] + t_s_out,
               t_in_l + bl * F[l, ms])
    t_b1 = max(bo * Bk[o, ms],
               bs * Bk[s, ms] + t_s_out,
               bl * Bk[l, ms])

    # --- Eq. (7)/(8): layers m_s+1..m_l on worker_o (b_o+b_s) & worker_l -
    t_f2 = max((bo + bs) * (F[o, ml] - F[o, ms]),
               bl * (F[l, ml] - F[l, ms]) + t_l_out)
    t_b2 = max((bo + bs) * (Bk[o, ml] - Bk[o, ms]),
               bl * (Bk[l, ml] - Bk[l, ms]) + t_l_out)

    # --- Eq. (9)/(10): layers m_l+1..N on worker_o with the full batch ---
    B = bo + bs + bl
    t_f3 = B * (F[o, N] - F[o, ml])
    t_b3 = B * (Bk[o, N] - Bk[o, ml])

    # --- Eq. (11): weight update -----------------------------------------
    # worker_o updates all N layers (TASK O is the full model); worker_s
    # updates 1..m_s; worker_l updates 1..m_l.  Gradient exchange covers the
    # *shared* (frontend) layers only: 2 * sum MP_i (push grads + pull avg).
    t_upd_o = U[o, N]
    t_upd_s = U[s, ms] if bs > 0 else 0.0
    t_upd_l = U[l, ml] if bl > 0 else 0.0
    t_wg_s = 2.0 * MPc[ms] / bw_os if bs > 0 else 0.0
    t_wg_l = 2.0 * MPc[ml] / bw_ol if bl > 0 else 0.0
    t_update = max(t_upd_o, t_upd_s, t_upd_l) + max(t_wg_s, t_wg_l)

    return Breakdown(
        t_f1=t_f1, t_b1=t_b1, t_f2=t_f2, t_b2=t_b2, t_f3=t_f3, t_b3=t_b3,
        t_update=t_update,
        comm_input=t_in_o + t_in_s + t_in_l,
        comm_activation=2.0 * (t_s_out + t_l_out),
        comm_weightgrad=max(t_wg_s, t_wg_l),
    )
