"""HierTrain per-iteration training-time cost model — Eqs. (1)-(12) of the
paper, plus the M-device generalization (DESIGN.md §6).

Conventions
-----------
* The paper's topology has exactly three physical workers — ``"device"``,
  ``"edge"``, ``"cloud"`` (indices 0/1/2) — captured by
  :class:`HierProfile` / :class:`Network` / :class:`Schedule` and scored by
  :func:`t_total` / :func:`t_total_batch`.
* The generalized topology has ``M`` heterogeneous devices in a star around
  one edge server, which uplinks to one cloud — captured by
  :class:`MultiProfile` / :class:`StarNetwork` / :class:`MultiSchedule` and
  scored by :func:`t_total_multi` / :func:`t_total_multi_batch`.  With
  ``M = 1`` the generalized model evaluates to the three-worker model
  bit-for-bit (the M=1 equivalence suite asserts it).
* Roles are ``o`` (TASK O, full model, owner), ``s`` (TASK S, layers
  ``1..m_s`` — one such task per non-``o``/non-``l`` worker in the
  generalized model, each with its own cut ``m_s[i]``), ``l`` (TASK L,
  layers ``1..m_l``), with ``0 <= m_s[i] <= m_l <= N``.
* Layers are 1-indexed in the paper; arrays here are 0-indexed, so layer ``i``
  lives at index ``i-1``.  ``MO[i-1]`` is the forward output size (bytes per
  sample) of layer ``i``; ``MP[i-1]`` its parameter bytes.
* All times in seconds, sizes in bytes, bandwidths in bytes/second.

Any path between workers without a direct physical link is the series
composition of the links through the edge (data is relayed — Fig. 1(c)
topology); the paper's Algorithm 1 only takes ``BW_de`` and ``BW_ec`` as
inputs, the star network takes one uplink bandwidth per device.

Everything here scores ONE iteration in isolation (barrier execution).
The steady-state cost of *pipelined* consecutive minibatches —
``t_period`` and friends — lives in :mod:`repro.core.pipeline`
(DESIGN.md §7) and consumes the same profile/network/schedule types.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

WORKERS: Tuple[str, str, str] = ("device", "edge", "cloud")
WIDX: Dict[str, int] = {w: i for i, w in enumerate(WORKERS)}


@dataclasses.dataclass
class HierProfile:
    """Profiling-stage output (§III, profiling stage).

    Attributes
    ----------
    L_f, L_b : ``[3, N]`` — forward/backward seconds *per sample* per layer
        per worker (``L^f_{j,i}``, ``L^b_{j,i}``).
    L_u : ``[3, N]`` — weight-update seconds per layer per worker
        (``L^u_{j,i}``; batch-size independent).
    MP : ``[N]`` — parameter bytes per layer (``MP_i``).
    MO : ``[N]`` — forward-output bytes per *sample* per layer (``MO_i``).
    MG : ``[N]`` — backward wire bytes per *sample* at each cut (the
        activation *gradient* shipped from worker_o back to a TASK-S/L
        worker).  ``None`` (the default) means "equal to ``MO``" — the
        paper's §IV-C assumption, under which every cost is bitwise
        identical to the historical MO-only model.  LM profiles set it
        explicitly (bf16 activations forward, f32 gradients back).
    sample_bytes : ``Q`` — bytes of one training sample (input + label).
    """
    layer_names: Tuple[str, ...]
    L_f: np.ndarray
    L_b: np.ndarray
    L_u: np.ndarray
    MP: np.ndarray
    MO: np.ndarray
    sample_bytes: float
    MG: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        self.L_f = np.asarray(self.L_f, np.float64)
        self.L_b = np.asarray(self.L_b, np.float64)
        self.L_u = np.asarray(self.L_u, np.float64)
        self.MP = np.asarray(self.MP, np.float64)
        self.MO = np.asarray(self.MO, np.float64)
        self.MG = self.MO if self.MG is None \
            else np.asarray(self.MG, np.float64)
        n = self.num_layers
        assert self.L_f.shape == (3, n) and self.L_b.shape == (3, n)
        assert self.L_u.shape == (3, n) and self.MP.shape == (n,)
        assert self.MO.shape == (n,) and self.MG.shape == (n,)

    @property
    def num_layers(self) -> int:
        return len(self.layer_names)

    # Prefix sums (index k => layers 1..k inclusive) used all over the
    # scheduler; computed lazily and cached.
    def prefix(self) -> Dict[str, np.ndarray]:
        if not hasattr(self, "_prefix"):
            z = np.zeros((3, 1))
            zl = np.zeros(1)
            self._prefix = {
                "F": np.concatenate([z, np.cumsum(self.L_f, axis=1)], axis=1),
                "Bk": np.concatenate([z, np.cumsum(self.L_b, axis=1)], axis=1),
                "U": np.concatenate([z, np.cumsum(self.L_u, axis=1)], axis=1),
                "MP": np.concatenate([zl, np.cumsum(self.MP)]),
            }
        return self._prefix


@dataclasses.dataclass
class Network:
    """Bandwidths (bytes/s). ``bw_de``: device↔edge; ``bw_ec``: edge↔cloud."""
    bw_de: float
    bw_ec: float

    def bw(self, a: str, b: str) -> float:
        if a == b:
            return np.inf
        pair = frozenset((a, b))
        if pair == frozenset(("device", "edge")):
            return self.bw_de
        if pair == frozenset(("edge", "cloud")):
            return self.bw_ec
        # device <-> cloud: store-and-forward through the edge.
        return 1.0 / (1.0 / self.bw_de + 1.0 / self.bw_ec)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A full HierTrain scheduling decision (mapping + cuts + batch split)."""
    worker_o: str
    worker_s: str
    worker_l: str
    m_s: int
    m_l: int
    b_o: int
    b_s: int
    b_l: int

    @property
    def batch(self) -> int:
        return self.b_o + self.b_s + self.b_l

    def role_of(self, worker: str) -> Optional[str]:
        for role, w in (("o", self.worker_o), ("s", self.worker_s),
                        ("l", self.worker_l)):
            if w == worker:
                return role
        return None

    def describe(self) -> str:
        return (f"o={self.worker_o}(b={self.b_o}) "
                f"s={self.worker_s}(m={self.m_s},b={self.b_s}) "
                f"l={self.worker_l}(m={self.m_l},b={self.b_l})")


@dataclasses.dataclass
class Breakdown:
    """Per-phase latencies of one training iteration — Eq. (12) terms."""
    t_f1: float
    t_b1: float
    t_f2: float
    t_b2: float
    t_f3: float
    t_b3: float
    t_update: float
    # Diagnostics (not part of T_total; already contained in the above):
    comm_input: float = 0.0
    comm_activation: float = 0.0
    comm_weightgrad: float = 0.0

    @property
    def total(self) -> float:
        return (self.t_f1 + self.t_b1 + self.t_f2 + self.t_b2 +
                self.t_f3 + self.t_b3 + self.t_update)


def bw_matrix(net: Network) -> np.ndarray:
    """``[3, 3]`` pairwise bandwidth table over :data:`WORKERS` (diagonal is
    ``inf``: a worker talking to itself is free)."""
    return np.array([[net.bw(a, b) for b in WORKERS] for a in WORKERS],
                    np.float64)


def _t_total_batch(profile: HierProfile, net: Network,
                   o_idx: np.ndarray, s_idx: np.ndarray, l_idx: np.ndarray,
                   ms: np.ndarray, ml: np.ndarray, b: np.ndarray,
                   origin: str = "device") -> np.ndarray:
    """Vectorized :func:`_t_total` over K candidate schedules.

    Parameters
    ----------
    o_idx, s_idx, l_idx : ``[K]`` int — :data:`WIDX` indices of the workers
        holding TASK O / S / L.
    ms, ml : ``[K]`` int — cut points (``0 <= ms <= ml <= N``).
    b : ``[K, 3]`` — integer batch split ``(b_o, b_s, b_l)``.
    origin : worker the training data starts on.

    Returns ``[K]`` exact ``T_total`` values.  Every arithmetic expression
    mirrors the scalar :func:`t_total` term-for-term (same operation
    order), so a lane equals the scalar evaluation of the same schedule
    bit-for-bit — the batched scheduler's argmin agrees with the
    reference scheduler's sequential min.
    """
    N = profile.num_layers
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    bwm = bw_matrix(net)
    oi = WIDX[origin]
    Q = profile.sample_bytes
    bo = np.asarray(b[:, 0], np.float64)
    bs = np.asarray(b[:, 1], np.float64)
    bl = np.asarray(b[:, 2], np.float64)

    bw_os = bwm[o_idx, s_idx]
    bw_ol = bwm[o_idx, l_idx]

    # --- communication pieces -------------------------------------------
    def t_in(w_idx: np.ndarray, bb: np.ndarray) -> np.ndarray:
        return np.where((bb == 0) | (w_idx == oi), 0.0,
                        bb * Q / bwm[oi, w_idx])

    t_in_o, t_in_s, t_in_l = t_in(o_idx, bo), t_in(s_idx, bs), t_in(l_idx, bl)
    mo_s = profile.MO[np.maximum(ms, 1) - 1]   # MO_{m_s} (junk at ms == 0)
    mo_l = profile.MO[np.maximum(ml, 1) - 1]
    mg_s = profile.MG[np.maximum(ms, 1) - 1]   # backward wire bytes
    mg_l = profile.MG[np.maximum(ml, 1) - 1]
    t_s_out = np.where((ms > 0) & (bs > 0), bs * mo_s / bw_os, 0.0)
    t_l_out = np.where((ml > 0) & (bl > 0), bl * mo_l / bw_ol, 0.0)
    t_s_gout = np.where((ms > 0) & (bs > 0), bs * mg_s / bw_os, 0.0)
    t_l_gout = np.where((ml > 0) & (bl > 0), bl * mg_l / bw_ol, 0.0)

    # --- Eq. (5)/(6): layers 1..m_s on all three workers ----------------
    t_f1 = np.maximum(np.maximum(t_in_o + bo * F[o_idx, ms],
                                 t_in_s + bs * F[s_idx, ms] + t_s_out),
                      t_in_l + bl * F[l_idx, ms])
    t_b1 = np.maximum(np.maximum(bo * Bk[o_idx, ms],
                                 bs * Bk[s_idx, ms] + t_s_gout),
                      bl * Bk[l_idx, ms])

    # --- Eq. (7)/(8): layers m_s+1..m_l ---------------------------------
    t_f2 = np.maximum((bo + bs) * (F[o_idx, ml] - F[o_idx, ms]),
                      bl * (F[l_idx, ml] - F[l_idx, ms]) + t_l_out)
    t_b2 = np.maximum((bo + bs) * (Bk[o_idx, ml] - Bk[o_idx, ms]),
                      bl * (Bk[l_idx, ml] - Bk[l_idx, ms]) + t_l_gout)

    # --- Eq. (9)/(10): layers m_l+1..N with the full batch --------------
    B = bo + bs + bl
    t_f3 = B * (F[o_idx, N] - F[o_idx, ml])
    t_b3 = B * (Bk[o_idx, N] - Bk[o_idx, ml])

    # --- Eq. (11): weight update ----------------------------------------
    t_upd_o = U[o_idx, N]
    t_upd_s = np.where(bs > 0, U[s_idx, ms], 0.0)
    t_upd_l = np.where(bl > 0, U[l_idx, ml], 0.0)
    t_wg_s = np.where(bs > 0, 2.0 * MPc[ms] / bw_os, 0.0)
    t_wg_l = np.where(bl > 0, 2.0 * MPc[ml] / bw_ol, 0.0)
    t_update = np.maximum(np.maximum(t_upd_o, t_upd_s), t_upd_l) + \
        np.maximum(t_wg_s, t_wg_l)

    return t_f1 + t_b1 + t_f2 + t_b2 + t_f3 + t_b3 + t_update


# ---------------------------------------------------------------------------
# M-device generalization (DESIGN.md §6).
#
# Topology: M heterogeneous devices, each with its own uplink to one edge
# server; the edge uplinks to one cloud.  Training data lives on the devices
# (device-resident tasks read local samples for free; edge/cloud-resident
# tasks ingest their sub-batch uploaded evenly, in parallel, from all M
# devices).  One worker holds TASK O (full model), one holds TASK L (layers
# 1..m_l); every remaining worker holds a TASK-S instance with its own cut
# m_s[i] <= m_l.  With M = 1 this is exactly the paper's three-worker model
# (same six role mappings, same Eq. 12 — bit-for-bit; the equivalence suite
# asserts it).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiProfile:
    """Profiling-stage output for the M-device star topology.

    Same per-layer quantities as :class:`HierProfile` (including the
    optional backward wire bytes ``MG``, defaulting to ``MO``), but with
    one row per worker in ``worker_names`` order: ``M`` device rows first,
    then ``"edge"``, then ``"cloud"`` (so ``L_f`` is ``[M+2, N]``).
    """
    layer_names: Tuple[str, ...]
    worker_names: Tuple[str, ...]
    L_f: np.ndarray
    L_b: np.ndarray
    L_u: np.ndarray
    MP: np.ndarray
    MO: np.ndarray
    sample_bytes: float
    MG: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        self.L_f = np.asarray(self.L_f, np.float64)
        self.L_b = np.asarray(self.L_b, np.float64)
        self.L_u = np.asarray(self.L_u, np.float64)
        self.MP = np.asarray(self.MP, np.float64)
        self.MO = np.asarray(self.MO, np.float64)
        self.MG = self.MO if self.MG is None \
            else np.asarray(self.MG, np.float64)
        n, w = self.num_layers, self.num_workers
        if len(set(self.worker_names)) != w:
            dupes = sorted({x for x in self.worker_names
                            if self.worker_names.count(x) > 1})
            raise ValueError(f"duplicate worker names in fleet: {dupes}")
        self._check_names()
        assert self.L_f.shape == (w, n) and self.L_b.shape == (w, n)
        assert self.L_u.shape == (w, n) and self.MP.shape == (n,)
        assert self.MO.shape == (n,) and self.MG.shape == (n,)

    def _check_names(self) -> None:
        assert self.num_workers >= 3 and \
            self.worker_names[-2:] == ("edge", "cloud")

    @property
    def num_layers(self) -> int:
        return len(self.layer_names)

    @property
    def num_workers(self) -> int:
        return len(self.worker_names)

    @property
    def num_devices(self) -> int:
        return self.num_workers - 2

    @property
    def num_streams(self) -> int:
        """How many TASK-S streams a schedule on this profile carries:
        every worker that is neither ``worker_o`` nor ``worker_l``.  On a
        star this equals ``num_devices``; on a tree it is ``M + E - 1``
        (idle edges still hold a — possibly empty — stream slot)."""
        return self.num_workers - 2

    @property
    def device_names(self) -> Tuple[str, ...]:
        return self.worker_names[:-2]

    @property
    def widx(self) -> Dict[str, int]:
        return {w: i for i, w in enumerate(self.worker_names)}

    def prefix(self) -> Dict[str, np.ndarray]:
        if not hasattr(self, "_prefix"):
            z = np.zeros((self.num_workers, 1))
            zl = np.zeros(1)
            self._prefix = {
                "F": np.concatenate([z, np.cumsum(self.L_f, axis=1)], axis=1),
                "Bk": np.concatenate([z, np.cumsum(self.L_b, axis=1)],
                                     axis=1),
                "U": np.concatenate([z, np.cumsum(self.L_u, axis=1)], axis=1),
                "MP": np.concatenate([zl, np.cumsum(self.MP)]),
            }
        return self._prefix

    @classmethod
    def from_hier(cls, profile: HierProfile,
                  device_scales: Sequence[float] = (1.0,)) -> "MultiProfile":
        """Lift a 3-worker profile to M devices.

        ``device_scales[i]`` is device *i*'s slowdown relative to the
        profiled device row (1.0 = identical, 2.0 = half speed).  With a
        single scale of 1.0 the result is the numerically identical M=1
        profile (``x * 1.0`` is exact).
        """
        scales = np.asarray(tuple(device_scales), np.float64)
        assert scales.ndim == 1 and scales.size >= 1 and (scales > 0).all()
        m = scales.size
        names = (("device",) if m == 1 else
                 tuple(f"device_{i}" for i in range(m))) + ("edge", "cloud")

        def lift(a: np.ndarray) -> np.ndarray:
            return np.concatenate([a[0][None, :] * scales[:, None], a[1:]],
                                  axis=0)

        return cls(layer_names=profile.layer_names, worker_names=names,
                   L_f=lift(profile.L_f), L_b=lift(profile.L_b),
                   L_u=lift(profile.L_u), MP=profile.MP, MO=profile.MO,
                   sample_bytes=profile.sample_bytes, MG=profile.MG)

    def three_worker(self) -> HierProfile:
        """The exact 3-worker profile (requires ``M == 1``)."""
        assert self.num_devices == 1, "only an M=1 profile reduces"
        return HierProfile(layer_names=self.layer_names, L_f=self.L_f,
                           L_b=self.L_b, L_u=self.L_u, MP=self.MP,
                           MO=self.MO, sample_bytes=self.sample_bytes,
                           MG=self.MG)

    # ---- membership edits (elastic fleets, DESIGN.md §10) ---------------
    # All return NEW profiles (rows are copied, the per-layer columns are
    # shared); the prefix cache is never inherited, so downstream costs
    # always see the edited membership.

    def device_index(self, name: str) -> int:
        """Index of device ``name`` (raises on edge/cloud or unknown)."""
        if name not in self.device_names:
            raise ValueError(f"{name!r} is not a device of this fleet "
                             f"(devices: {self.device_names})")
        return self.widx[name]

    def drop_device(self, name: str) -> "MultiProfile":
        """Membership edit: remove device ``name`` (a leave or crash).

        The surviving rows are byte-identical to the original profile's,
        so every cost of the edited fleet equals a fresh fleet built from
        the survivors bit-for-bit."""
        i = self.device_index(name)
        if self.num_devices < 2:
            raise ValueError("cannot drop the last device of the fleet")
        keep = [j for j in range(self.num_workers) if j != i]
        return MultiProfile(
            layer_names=self.layer_names,
            worker_names=tuple(self.worker_names[j] for j in keep),
            L_f=self.L_f[keep].copy(), L_b=self.L_b[keep].copy(),
            L_u=self.L_u[keep].copy(), MP=self.MP, MO=self.MO,
            sample_bytes=self.sample_bytes, MG=self.MG)

    def add_device(self, name: str, L_f_row, L_b_row,
                   L_u_row) -> "MultiProfile":
        """Membership edit: append device ``name`` after the existing
        devices with the given per-layer second rows (seeded from the
        joiner's :class:`~repro.core.profiler.WorkerSpec` tier by
        :func:`repro.core.churn.apply_event`; the online EMA refines it
        from the first straggler report onward)."""
        if name in self.worker_names:
            raise ValueError(f"worker {name!r} already in the fleet")
        m = self.num_devices

        def ins(a: np.ndarray, row) -> np.ndarray:
            row = np.asarray(row, np.float64).reshape(1, -1)
            assert row.shape[1] == self.num_layers
            return np.concatenate([a[:m], row, a[m:]], axis=0)

        return MultiProfile(
            layer_names=self.layer_names,
            worker_names=self.worker_names[:m] + (name,) +
            self.worker_names[m:],
            L_f=ins(self.L_f, L_f_row), L_b=ins(self.L_b, L_b_row),
            L_u=ins(self.L_u, L_u_row), MP=self.MP, MO=self.MO,
            sample_bytes=self.sample_bytes, MG=self.MG)


@dataclasses.dataclass
class TreeProfile(MultiProfile):
    """Profiling-stage output for the two-level tree topology
    (DESIGN.md §12): ``M`` device rows, then ``n_edges`` edge rows, then
    one ``"cloud"`` row.

    The single edge is named ``"edge"`` at ``E = 1`` — the exact star
    naming, which is what makes E=1 tree DES traces (whose pipe names
    embed worker names) bit-identical to the star's — and
    ``edge_0..edge_{E-1}`` otherwise.  ``cloud_speedup`` records the
    data-parallel speedup baked into the cloud row by
    :meth:`from_multi` (a ``cloud_speedup``-way sharded cloud tier runs
    its segment that much faster); at the default 1.0 the row is
    bit-identical to the star's.
    """
    n_edges: int = 1
    cloud_speedup: float = 1.0

    def _check_names(self) -> None:
        assert self.n_edges >= 1 and \
            self.num_workers >= self.n_edges + 2, "need >= 1 device"
        assert self.worker_names[-1] == "cloud"
        want = ("edge",) if self.n_edges == 1 else \
            tuple(f"edge_{i}" for i in range(self.n_edges))
        assert self.worker_names[-1 - self.n_edges:-1] == want, \
            f"edge rows must be named {want}"

    @property
    def num_devices(self) -> int:
        return self.num_workers - self.n_edges - 1

    @property
    def device_names(self) -> Tuple[str, ...]:
        return self.worker_names[:self.num_devices]

    @property
    def edge_names(self) -> Tuple[str, ...]:
        return self.worker_names[self.num_devices:-1]

    @classmethod
    def from_multi(cls, profile: MultiProfile, n_edges: int = 1,
                   edge_scales: Optional[Sequence[float]] = None,
                   cloud_speedup: float = 1.0) -> "TreeProfile":
        """Lift a star profile to ``n_edges`` edge servers.

        ``edge_scales[e]`` is edge ``e``'s slowdown relative to the star's
        edge row; the cloud row is divided by ``cloud_speedup``.  With one
        edge at scale 1.0 and speedup 1.0 every row is numerically
        identical to the star profile (``x * 1.0`` and ``x / 1.0`` are
        exact), so the E=1 tree is the bit-exact star."""
        scales = np.ones(n_edges) if edge_scales is None else \
            np.asarray(tuple(edge_scales), np.float64)
        assert scales.shape == (n_edges,) and (scales > 0).all()
        assert cloud_speedup > 0
        m = profile.num_devices
        names = profile.worker_names[:m] + \
            (("edge",) if n_edges == 1 else
             tuple(f"edge_{i}" for i in range(n_edges))) + ("cloud",)

        def lift(a: np.ndarray) -> np.ndarray:
            return np.concatenate(
                [a[:m], a[m][None, :] * scales[:, None],
                 a[m + 1][None, :] / cloud_speedup], axis=0)

        return cls(layer_names=profile.layer_names, worker_names=names,
                   L_f=lift(profile.L_f), L_b=lift(profile.L_b),
                   L_u=lift(profile.L_u), MP=profile.MP, MO=profile.MO,
                   sample_bytes=profile.sample_bytes, MG=profile.MG,
                   n_edges=n_edges, cloud_speedup=cloud_speedup)

    def to_multi(self) -> MultiProfile:
        """The exact star profile (requires ``E == 1``)."""
        assert self.n_edges == 1, "only an E=1 profile reduces to a star"
        return MultiProfile(
            layer_names=self.layer_names, worker_names=self.worker_names,
            L_f=self.L_f, L_b=self.L_b, L_u=self.L_u, MP=self.MP,
            MO=self.MO, sample_bytes=self.sample_bytes, MG=self.MG)


@dataclasses.dataclass
class StarNetwork:
    """Star topology: per-device uplinks ``bw_de[i]`` (device_i↔edge) and one
    backhaul ``bw_ec`` (edge↔cloud), all in bytes/s.  Paths without a direct
    link (device↔cloud, device↔device) are the series composition of their
    hops through the edge, matching :meth:`Network.bw`."""
    bw_de: np.ndarray
    bw_ec: float

    def __post_init__(self) -> None:
        self.bw_de = np.atleast_1d(np.asarray(self.bw_de, np.float64))
        assert (self.bw_de > 0).all() and self.bw_ec > 0

    @property
    def num_devices(self) -> int:
        return int(self.bw_de.size)

    # Tree-compat view (a star is the one-edge tree): generic code paths
    # read ``num_edges``/``edge_of``/``backhaul`` off either network type.
    @property
    def num_edges(self) -> int:
        return 1

    @property
    def edge_of(self) -> Tuple[int, ...]:
        return (0,) * self.num_devices

    @property
    def backhaul(self) -> np.ndarray:
        return np.array([self.bw_ec], np.float64)

    @classmethod
    def from_network(cls, net: Network, num_devices: int = 1
                     ) -> "StarNetwork":
        return cls(bw_de=np.full(num_devices, net.bw_de), bw_ec=net.bw_ec)

    def three_worker(self) -> Network:
        assert self.num_devices == 1
        return Network(bw_de=float(self.bw_de[0]), bw_ec=self.bw_ec)

    def bw_matrix(self) -> np.ndarray:
        """``[M+2, M+2]`` pairwise bandwidths in worker order (devices...,
        edge, cloud); diagonal ``inf``.  ``[i, j]`` for two devices is the
        relayed series path through the edge."""
        m = self.num_devices
        w = m + 2
        bwm = np.full((w, w), np.inf)
        de, ec = self.bw_de, self.bw_ec
        bwm[:m, m] = bwm[m, :m] = de                     # device_i <-> edge
        bwm[m, m + 1] = bwm[m + 1, m] = ec               # edge <-> cloud
        dc = 1.0 / (1.0 / de + 1.0 / ec)                 # relayed, Fig. 1(c)
        bwm[:m, m + 1] = bwm[m + 1, :m] = dc
        dd = 1.0 / (1.0 / de[:, None] + 1.0 / de[None, :])
        dd[np.diag_indices(m)] = np.inf
        bwm[:m, :m] = dd
        return bwm

    # ---- membership edits (elastic fleets, DESIGN.md §10) ---------------

    def drop_device(self, i: int) -> "StarNetwork":
        """Remove device ``i``'s uplink (paired with
        :meth:`MultiProfile.drop_device`)."""
        if not 0 <= i < self.num_devices:
            raise ValueError(f"no device {i} in a {self.num_devices}-device "
                             "star")
        if self.num_devices < 2:
            raise ValueError("cannot drop the last device of the fleet")
        return StarNetwork(bw_de=np.delete(self.bw_de, i), bw_ec=self.bw_ec)

    def add_device(self, bw: float) -> "StarNetwork":
        """Append a device uplink of ``bw`` bytes/s."""
        return StarNetwork(bw_de=np.concatenate([self.bw_de, [bw]]),
                           bw_ec=self.bw_ec)

    def scale_uplink(self, i: int, factor: float) -> "StarNetwork":
        """Multiply device ``i``'s uplink by ``factor`` (a
        :class:`~repro.core.churn.LinkDegrade`; ``factor > 1`` heals)."""
        if not 0 <= i < self.num_devices:
            raise ValueError(f"no device {i} in a {self.num_devices}-device "
                             "star")
        if factor <= 0:
            raise ValueError("uplink scale factor must be positive")
        bw = self.bw_de.copy()
        bw[i] *= factor
        return StarNetwork(bw_de=bw, bw_ec=self.bw_ec)

    def upload_bw(self) -> np.ndarray:
        """``[M+2]`` effective ingest bandwidth for a worker receiving its
        sub-batch uploaded *evenly in parallel* from all M devices: the
        slowest uplink carries ``1/M`` of the bytes, so the edge ingests at
        ``M * min(bw_de)`` and the cloud at the series composition of that
        with the backhaul.  Devices read local samples (``inf``)."""
        m = self.num_devices
        up = np.full(m + 2, np.inf)
        radio = m * self.bw_de.min()
        up[m] = radio
        up[m + 1] = 1.0 / (1.0 / radio + 1.0 / self.bw_ec)
        return up


@dataclasses.dataclass
class TreeNetwork:
    """Two-level tree: device ``i`` reaches its edge ``edge_of[i]`` over
    radio ``bw_de[i]``; edge ``e`` reaches the cloud over its own backhaul
    ``bw_ec[e]`` (all bytes/s).  Paths without a direct link are series
    compositions of their hops — device↔cloud through the device's edge,
    edge↔edge and device↔foreign-edge through the cloud.  With one edge
    every pairwise path reduces to the :class:`StarNetwork` expression
    bit-for-bit (series terms enter as exact ``+ 0.0``)."""
    bw_de: np.ndarray
    bw_ec: np.ndarray
    edge_of: Tuple[int, ...] = (0,)

    def __post_init__(self) -> None:
        self.bw_de = np.atleast_1d(np.asarray(self.bw_de, np.float64))
        self.bw_ec = np.atleast_1d(np.asarray(self.bw_ec, np.float64))
        self.edge_of = tuple(int(e) for e in self.edge_of)
        assert (self.bw_de > 0).all() and (self.bw_ec > 0).all()
        assert len(self.edge_of) == self.num_devices
        counts = np.bincount(self.edge_of, minlength=self.num_edges)
        assert counts.size == self.num_edges and (counts > 0).all(), \
            "every edge needs at least one device"

    @property
    def num_devices(self) -> int:
        return int(self.bw_de.size)

    @property
    def num_edges(self) -> int:
        return int(self.bw_ec.size)

    @property
    def backhaul(self) -> np.ndarray:
        return self.bw_ec

    @classmethod
    def from_star(cls, net: StarNetwork) -> "TreeNetwork":
        return cls(bw_de=net.bw_de, bw_ec=np.array([net.bw_ec]),
                   edge_of=(0,) * net.num_devices)

    def to_star(self) -> StarNetwork:
        assert self.num_edges == 1, "only an E=1 tree reduces to a star"
        return StarNetwork(bw_de=self.bw_de, bw_ec=float(self.bw_ec[0]))

    def bw_matrix(self) -> np.ndarray:
        """``[M+E+1, M+E+1]`` pairwise bandwidths in worker order
        (devices..., edges..., cloud); diagonal ``inf``."""
        m, e = self.num_devices, self.num_edges
        w = m + e + 1
        eo = np.asarray(self.edge_of)
        de, ec = self.bw_de, self.bw_ec
        inv_bh = 1.0 / ec[eo]                        # [M] own-backhaul term
        bwm = np.full((w, w), np.inf)
        # device_i <-> edge_k: direct radio to its own edge, relayed via
        # its own backhaul + the foreign edge's backhaul otherwise.
        same = eo[:, None] == np.arange(e)[None, :]          # [M, E]
        d_edge = np.where(
            same, de[:, None],
            1.0 / (1.0 / de[:, None] + inv_bh[:, None] + 1.0 / ec[None, :]))
        bwm[:m, m:m + e] = d_edge
        bwm[m:m + e, :m] = d_edge.T
        # edge_k <-> cloud: its own backhaul.
        bwm[m:m + e, m + e] = bwm[m + e, m:m + e] = ec
        # device_i <-> cloud: radio in series with its edge's backhaul —
        # the star's relayed Fig. 1(c) path, per-edge.
        dc = 1.0 / (1.0 / de + inv_bh)
        bwm[:m, m + e] = bwm[m + e, :m] = dc
        # device_i <-> device_j: series through the shared edge, plus both
        # backhauls when the devices sit under different edges.  The
        # same-edge term adds an exact 0.0, so at E=1 this is the star's
        # ``dd`` expression bit-for-bit.
        cross = 1.0 / de[:, None] + 1.0 / de[None, :] + np.where(
            eo[:, None] == eo[None, :], 0.0,
            inv_bh[:, None] + inv_bh[None, :])
        dd = 1.0 / cross
        dd[np.diag_indices(m)] = np.inf
        bwm[:m, :m] = dd
        # edge_a <-> edge_b: series through the cloud.
        ee = 1.0 / (1.0 / ec[:, None] + 1.0 / ec[None, :])
        ee[np.diag_indices(e)] = np.inf
        bwm[m:m + e, m:m + e] = ee
        return bwm

    def upload_bw(self) -> np.ndarray:
        """``[M+E+1]`` effective ingest bandwidth under the even-upload
        model (every device ships ``b/M`` samples to the destination).
        An all-local edge ingests at ``M * min_j path(j, dst)`` — the
        star expression bit-for-bit, so E=1 always takes that branch.
        Chunks that cross a backhaul serialize per shaped pipe (matching
        the simulator's input classes): the cloud composes the bottleneck
        radio aggregate with the bottleneck per-edge uplink share
        ``min_e bw_ec[e] / (M_e / M)``; a foreign-edge destination adds
        the worst foreign uplink (``M_e`` chunks over ``bw_ec[e]``) and
        its own downlink (``M - M_k`` foreign chunks over ``bw_ec[k]``)
        in series with the radio stage."""
        m, e = self.num_devices, self.num_edges
        up = np.full(m + e + 1, np.inf)
        bwm = self.bw_matrix()
        counts = np.bincount(self.edge_of, minlength=e)
        for k in range(e):
            if counts[k] == m:            # all devices local (always at E=1)
                up[m + k] = m * bwm[:m, m + k].min()
            else:
                inv = (1.0 / self.bw_de.min() +
                       max(counts[e2] / self.bw_ec[e2]
                           for e2 in range(e) if e2 != k) +
                       (m - counts[k]) / self.bw_ec[k])
                up[m + k] = m / inv
        radio = m * self.bw_de.min()
        bh = (self.bw_ec / (counts / m)).min()
        up[m + e] = 1.0 / (1.0 / radio + 1.0 / bh)
        return up


@dataclasses.dataclass(frozen=True)
class MultiSchedule:
    """An M-device HierTrain scheduling decision.

    ``s_workers[i]`` runs a TASK-S instance over layers ``1..m_s[i]`` on its
    ``b_s[i]`` samples; ``worker_o``/``worker_l`` are as in :class:`Schedule`.
    ``len(s_workers) == M`` always (the non-o, non-l workers)."""
    worker_o: str
    worker_l: str
    s_workers: Tuple[str, ...]
    m_s: Tuple[int, ...]
    m_l: int
    b_o: int
    b_s: Tuple[int, ...]
    b_l: int

    @property
    def batch(self) -> int:
        return self.b_o + sum(self.b_s) + self.b_l

    def describe(self) -> str:
        s = " ".join(f"s={w}(m={m},b={b})" for w, m, b in
                     zip(self.s_workers, self.m_s, self.b_s))
        return (f"o={self.worker_o}(b={self.b_o}) {s} "
                f"l={self.worker_l}(m={self.m_l},b={self.b_l})")

    @classmethod
    def from_schedule(cls, sched: Schedule) -> "MultiSchedule":
        return cls(worker_o=sched.worker_o, worker_l=sched.worker_l,
                   s_workers=(sched.worker_s,), m_s=(sched.m_s,),
                   m_l=sched.m_l, b_o=sched.b_o, b_s=(sched.b_s,),
                   b_l=sched.b_l)

    def to_schedule(self) -> Schedule:
        assert len(self.s_workers) == 1, "only an M=1 schedule reduces"
        return Schedule(worker_o=self.worker_o, worker_s=self.s_workers[0],
                        worker_l=self.worker_l, m_s=self.m_s[0],
                        m_l=self.m_l, b_o=self.b_o, b_s=self.b_s[0],
                        b_l=self.b_l)


def _validate_multi(profile: MultiProfile, sched: MultiSchedule) -> None:
    N = profile.num_layers
    S = profile.num_streams
    assert len(sched.s_workers) == len(sched.m_s) == len(sched.b_s) == S
    assert 0 <= sched.m_l <= N
    for m_i, b_i in zip(sched.m_s, sched.b_s):
        assert 0 <= m_i <= sched.m_l, "need 0 <= m_s[i] <= m_l <= N"
        if m_i == 0:
            assert b_i == 0, "m_s[i] = 0 forces b_s[i] = 0"
    if sched.m_l == 0:
        assert sched.b_l == 0, "m_l = 0 forces b_l = 0"
    widx = profile.widx
    seen = {sched.worker_o, sched.worker_l, *sched.s_workers}
    assert len(seen) == S + 2 and all(w in widx for w in seen), \
        "schedule must name every worker exactly once"


def _t_total_multi(profile: MultiProfile, net: StarNetwork,
                   sched: MultiSchedule) -> Breakdown:
    """Exact generalized Eq. (12) for an integer M-device schedule.

    Phase structure (DESIGN.md §6): phase 1 runs every TASK-S front-end in
    parallel up to its own cut; worker_o's catch-up of stream *i* from
    ``m_s[i]`` to ``max_i m_s[i]`` is charged to phase 2 alongside the
    common ``max_i m_s[i] .. m_l`` block.  With ``M = 1`` every term reduces
    to the three-worker expression bit-for-bit.
    """
    _validate_multi(profile, sched)
    N = profile.num_layers
    D = profile.num_devices       # data holders (locality), not streams
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    widx = profile.widx
    o, l = widx[sched.worker_o], widx[sched.worker_l]
    s = [widx[w] for w in sched.s_workers]
    ml = sched.m_l
    bo, bl = sched.b_o, sched.b_l
    bs = sched.b_s
    msmax = max(sched.m_s)
    bwm = net.bw_matrix()
    up = net.upload_bw()
    Q = profile.sample_bytes

    def t_in(w: int, b: int) -> float:
        if b == 0 or w < D:          # device-resident: local data
            return 0.0
        return b * Q / up[w]

    t_in_o, t_in_l = t_in(o, bo), t_in(l, bl)
    t_in_s = [t_in(si, bi) for si, bi in zip(s, bs)]
    t_s_out = [bi * profile.MO[mi - 1] / bwm[o, si]
               if (mi > 0 and bi > 0) else 0.0
               for si, mi, bi in zip(s, sched.m_s, bs)]
    t_l_out = bl * profile.MO[ml - 1] / bwm[o, l] \
        if (ml > 0 and bl > 0) else 0.0
    t_s_gout = [bi * profile.MG[mi - 1] / bwm[o, si]
                if (mi > 0 and bi > 0) else 0.0
                for si, mi, bi in zip(s, sched.m_s, bs)]
    t_l_gout = bl * profile.MG[ml - 1] / bwm[o, l] \
        if (ml > 0 and bl > 0) else 0.0

    # --- phase 1: every front-end in parallel up to its own cut ----------
    t_f1 = max(t_in_o + bo * F[o, msmax],
               *[ti + bi * F[si, mi] + to for ti, si, mi, bi, to in
                 zip(t_in_s, s, sched.m_s, bs, t_s_out)],
               t_in_l + bl * F[l, msmax])
    t_b1 = max(bo * Bk[o, msmax],
               *[bi * Bk[si, mi] + to for si, mi, bi, to in
                 zip(s, sched.m_s, bs, t_s_gout)],
               bl * Bk[l, msmax])

    # --- phase 2: worker_o catches every stream up, then the common block -
    bs_sum = sum(bs)
    catch_f = sum(bi * (F[o, msmax] - F[o, mi])
                  for mi, bi in zip(sched.m_s, bs))
    catch_b = sum(bi * (Bk[o, msmax] - Bk[o, mi])
                  for mi, bi in zip(sched.m_s, bs))
    t_f2 = max((bo + bs_sum) * (F[o, ml] - F[o, msmax]) + catch_f,
               bl * (F[l, ml] - F[l, msmax]) + t_l_out)
    t_b2 = max((bo + bs_sum) * (Bk[o, ml] - Bk[o, msmax]) + catch_b,
               bl * (Bk[l, ml] - Bk[l, msmax]) + t_l_gout)

    # --- phase 3 + weight update (as in the three-worker model) ----------
    B = bo + bs_sum + bl
    t_f3 = B * (F[o, N] - F[o, ml])
    t_b3 = B * (Bk[o, N] - Bk[o, ml])
    t_upd_o = U[o, N]
    t_upd_s = [U[si, mi] if bi > 0 else 0.0
               for si, mi, bi in zip(s, sched.m_s, bs)]
    t_upd_l = U[l, ml] if bl > 0 else 0.0
    t_wg_s = [2.0 * MPc[mi] / bwm[o, si] if bi > 0 else 0.0
              for si, mi, bi in zip(s, sched.m_s, bs)]
    t_wg_l = 2.0 * MPc[ml] / bwm[o, l] if bl > 0 else 0.0
    t_update = max(t_upd_o, *t_upd_s, t_upd_l) + max(*t_wg_s, t_wg_l)

    return Breakdown(
        t_f1=t_f1, t_b1=t_b1, t_f2=t_f2, t_b2=t_b2, t_f3=t_f3, t_b3=t_b3,
        t_update=t_update,
        comm_input=t_in_o + sum(t_in_s) + t_in_l,
        comm_activation=(sum(t_s_out) + t_l_out) +
                        (sum(t_s_gout) + t_l_gout),
        comm_weightgrad=max(*t_wg_s, t_wg_l),
    )


def _t_total_multi_batch(profile: MultiProfile, net: StarNetwork,
                         o_idx: np.ndarray, s_idx: np.ndarray,
                         l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                         b: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_t_total_multi` over K candidate schedules.

    ``o_idx, l_idx, ml``: ``[K]``; ``s_idx, ms``: ``[K, S]``;
    ``b``: ``[K, S+2]`` split ``(b_o, b_s[0..S-1], b_l)`` where ``S`` is
    ``profile.num_streams`` (``M`` on a star, ``M + E - 1`` on a tree).
    Every arithmetic expression mirrors the scalar evaluation
    term-for-term, and with ``M = 1`` also mirrors :func:`t_total_batch`
    — a lane is bit-identical to both.
    """
    N = profile.num_layers
    D = profile.num_devices       # data holders (locality), not streams
    S = profile.num_streams
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    bwm = net.bw_matrix()
    up = net.upload_bw()
    Q = profile.sample_bytes
    bo = np.asarray(b[:, 0], np.float64)
    bs = np.asarray(b[:, 1:1 + S], np.float64)
    bl = np.asarray(b[:, 1 + S], np.float64)
    o2 = o_idx[:, None]
    msmax = ms.max(axis=1)

    bw_os = bwm[o_idx[:, None], s_idx]        # [K, S]
    bw_ol = bwm[o_idx, l_idx]

    def t_in(w_idx: np.ndarray, bb: np.ndarray) -> np.ndarray:
        return np.where((bb == 0) | (w_idx < D), 0.0, bb * Q / up[w_idx])

    t_in_o, t_in_s, t_in_l = t_in(o_idx, bo), t_in(s_idx, bs), t_in(l_idx, bl)
    mo_s = profile.MO[np.maximum(ms, 1) - 1]
    mo_l = profile.MO[np.maximum(ml, 1) - 1]
    mg_s = profile.MG[np.maximum(ms, 1) - 1]
    mg_l = profile.MG[np.maximum(ml, 1) - 1]
    t_s_out = np.where((ms > 0) & (bs > 0), bs * mo_s / bw_os, 0.0)
    t_l_out = np.where((ml > 0) & (bl > 0), bl * mo_l / bw_ol, 0.0)
    t_s_gout = np.where((ms > 0) & (bs > 0), bs * mg_s / bw_os, 0.0)
    t_l_gout = np.where((ml > 0) & (bl > 0), bl * mg_l / bw_ol, 0.0)

    # --- phase 1 ---------------------------------------------------------
    t_f1 = np.maximum(np.maximum(t_in_o + bo * F[o_idx, msmax],
                                 (t_in_s + bs * F[s_idx, ms] +
                                  t_s_out).max(axis=1)),
                      t_in_l + bl * F[l_idx, msmax])
    t_b1 = np.maximum(np.maximum(bo * Bk[o_idx, msmax],
                                 (bs * Bk[s_idx, ms] +
                                  t_s_gout).max(axis=1)),
                      bl * Bk[l_idx, msmax])

    # --- phase 2 (catch-up + common block) -------------------------------
    bs_sum = bs.sum(axis=1)
    catch_f = (bs * (F[o2, msmax[:, None]] - F[o2, ms])).sum(axis=1)
    catch_b = (bs * (Bk[o2, msmax[:, None]] - Bk[o2, ms])).sum(axis=1)
    t_f2 = np.maximum(
        (bo + bs_sum) * (F[o_idx, ml] - F[o_idx, msmax]) + catch_f,
        bl * (F[l_idx, ml] - F[l_idx, msmax]) + t_l_out)
    t_b2 = np.maximum(
        (bo + bs_sum) * (Bk[o_idx, ml] - Bk[o_idx, msmax]) + catch_b,
        bl * (Bk[l_idx, ml] - Bk[l_idx, msmax]) + t_l_gout)

    # --- phase 3 + update ------------------------------------------------
    B = bo + bs_sum + bl
    t_f3 = B * (F[o_idx, N] - F[o_idx, ml])
    t_b3 = B * (Bk[o_idx, N] - Bk[o_idx, ml])
    t_upd_o = U[o_idx, N]
    t_upd_s = np.where(bs > 0, U[s_idx, ms], 0.0).max(axis=1)
    t_upd_l = np.where(bl > 0, U[l_idx, ml], 0.0)
    t_wg_s = np.where(bs > 0, 2.0 * MPc[ms] / bw_os, 0.0).max(axis=1)
    t_wg_l = np.where(bl > 0, 2.0 * MPc[ml] / bw_ol, 0.0)
    t_update = np.maximum(np.maximum(t_upd_o, t_upd_s), t_upd_l) + \
        np.maximum(t_wg_s, t_wg_l)

    return t_f1 + t_b1 + t_f2 + t_b2 + t_f3 + t_b3 + t_update


def t_input(profile: HierProfile, net: Network, worker: str, b: int,
            origin: str = "device") -> float:
    """``T_{j,input}``: latency for worker *j* to receive its ``b`` samples."""
    if b == 0 or worker == origin:
        return 0.0
    return b * profile.sample_bytes / net.bw(origin, worker)


def _t_total(profile: HierProfile, net: Network, sched: Schedule,
             origin: str = "device") -> Breakdown:
    """Exact Eq. (12) evaluation for an (integer) schedule.

    This is the canonical *three-worker* evaluation — the correctness
    oracle the M=1 equivalence suite compares the star model against,
    and the only path that supports ``origin != "device"`` or
    degenerate schedules that repeat a worker across roles (the
    all-on-one baselines)."""
    N = profile.num_layers
    assert 0 <= sched.m_s <= sched.m_l <= N, "need 0 <= m_s <= m_l <= N"
    if sched.m_s == 0:
        assert sched.b_s == 0, "m_s = 0 forces b_s = 0 (constraint (14))"
    if sched.m_l == 0:
        assert sched.b_l == 0, "m_l = 0 forces b_l = 0 (constraint (15))"
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    o, s, l = WIDX[sched.worker_o], WIDX[sched.worker_s], WIDX[sched.worker_l]
    ms, ml = sched.m_s, sched.m_l
    bo, bs, bl = sched.b_o, sched.b_s, sched.b_l

    bw_os = net.bw(sched.worker_o, sched.worker_s)
    bw_ol = net.bw(sched.worker_o, sched.worker_l)

    # --- communication pieces -------------------------------------------
    t_in_o = t_input(profile, net, sched.worker_o, bo, origin)
    t_in_s = t_input(profile, net, sched.worker_s, bs, origin)
    t_in_l = t_input(profile, net, sched.worker_l, bl, origin)
    # T_{s,output} = b_s * MO_{m_s} / B_{o,s}  (§IV-C); T_{s,grad} uses the
    # backward wire bytes MG_{m_s} (== MO by default, LM profiles differ).
    t_s_out = bs * profile.MO[ms - 1] / bw_os if (ms > 0 and bs > 0) else 0.0
    t_l_out = bl * profile.MO[ml - 1] / bw_ol if (ml > 0 and bl > 0) else 0.0
    t_s_gout = bs * profile.MG[ms - 1] / bw_os if (ms > 0 and bs > 0) else 0.0
    t_l_gout = bl * profile.MG[ml - 1] / bw_ol if (ml > 0 and bl > 0) else 0.0

    # --- Eq. (5)/(6): layers 1..m_s on all three workers ----------------
    t_f1 = max(t_in_o + bo * F[o, ms],
               t_in_s + bs * F[s, ms] + t_s_out,
               t_in_l + bl * F[l, ms])
    t_b1 = max(bo * Bk[o, ms],
               bs * Bk[s, ms] + t_s_gout,
               bl * Bk[l, ms])

    # --- Eq. (7)/(8): layers m_s+1..m_l on worker_o (b_o+b_s) & worker_l -
    t_f2 = max((bo + bs) * (F[o, ml] - F[o, ms]),
               bl * (F[l, ml] - F[l, ms]) + t_l_out)
    t_b2 = max((bo + bs) * (Bk[o, ml] - Bk[o, ms]),
               bl * (Bk[l, ml] - Bk[l, ms]) + t_l_gout)

    # --- Eq. (9)/(10): layers m_l+1..N on worker_o with the full batch ---
    B = bo + bs + bl
    t_f3 = B * (F[o, N] - F[o, ml])
    t_b3 = B * (Bk[o, N] - Bk[o, ml])

    # --- Eq. (11): weight update -----------------------------------------
    # worker_o updates all N layers (TASK O is the full model); worker_s
    # updates 1..m_s; worker_l updates 1..m_l.  Gradient exchange covers the
    # *shared* (frontend) layers only: 2 * sum MP_i (push grads + pull avg).
    t_upd_o = U[o, N]
    t_upd_s = U[s, ms] if bs > 0 else 0.0
    t_upd_l = U[l, ml] if bl > 0 else 0.0
    t_wg_s = 2.0 * MPc[ms] / bw_os if bs > 0 else 0.0
    t_wg_l = 2.0 * MPc[ml] / bw_ol if bl > 0 else 0.0
    t_update = max(t_upd_o, t_upd_s, t_upd_l) + max(t_wg_s, t_wg_l)

    return Breakdown(
        t_f1=t_f1, t_b1=t_b1, t_f2=t_f2, t_b2=t_b2, t_f3=t_f3, t_b3=t_b3,
        t_update=t_update,
        comm_input=t_in_o + t_in_s + t_in_l,
        comm_activation=(t_s_out + t_l_out) + (t_s_gout + t_l_gout),
        comm_weightgrad=max(t_wg_s, t_wg_l),
    )


# ---------------------------------------------------------------------------
# Deprecated public surface (DESIGN.md §9).  The forked t_total* pairs are
# shims over the unified model: the 3-worker entry points lift their
# arguments onto the star types and evaluate the M-device model, which is
# bit-for-bit identical at M = 1 (the equivalence suite asserts it).
# Non-collapsible corners — ``origin != "device"`` and degenerate
# schedules that repeat a worker (the all-on-one baselines) — fall back
# to the retained 3-worker oracle.
# ---------------------------------------------------------------------------


def t_total(profile: HierProfile, net: Network, sched: Schedule,
            origin: str = "device") -> Breakdown:
    """Deprecated: use ``repro.api.plan(...).breakdown`` (Plan carries the
    exact Eq.-12 evaluation of its chosen schedule)."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.cost_model.t_total()",
                    "repro.api.plan(model, fleet, B).breakdown")
    distinct = len({sched.worker_o, sched.worker_s, sched.worker_l}) == 3
    if origin == "device" and distinct:
        return _t_total_multi(MultiProfile.from_hier(profile),
                              StarNetwork.from_network(net),
                              MultiSchedule.from_schedule(sched))
    return _t_total(profile, net, sched, origin)


def t_total_batch(profile: HierProfile, net: Network,
                  o_idx: np.ndarray, s_idx: np.ndarray, l_idx: np.ndarray,
                  ms: np.ndarray, ml: np.ndarray, b: np.ndarray,
                  origin: str = "device") -> np.ndarray:
    """Deprecated: the batched kernels are internal to the facade — use
    ``repro.api.plan`` (the scheduler scores candidates itself)."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.cost_model.t_total_batch()",
                    "repro.api.plan(model, fleet, B)")
    if origin == "device":
        return _t_total_multi_batch(
            MultiProfile.from_hier(profile), StarNetwork.from_network(net),
            np.asarray(o_idx), np.asarray(s_idx)[:, None],
            np.asarray(l_idx), np.asarray(ms)[:, None], np.asarray(ml),
            np.asarray(b))
    return _t_total_batch(profile, net, o_idx, s_idx, l_idx, ms, ml, b,
                          origin)


def t_total_multi(profile: MultiProfile, net: StarNetwork,
                  sched: MultiSchedule) -> Breakdown:
    """Deprecated: use ``repro.api.plan(...).breakdown``."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.cost_model.t_total_multi()",
                    "repro.api.plan(model, fleet, B).breakdown")
    return _t_total_multi(profile, net, sched)


def t_total_multi_batch(profile: MultiProfile, net: StarNetwork,
                        o_idx: np.ndarray, s_idx: np.ndarray,
                        l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                        b: np.ndarray) -> np.ndarray:
    """Deprecated: use ``repro.api.plan`` (internal scoring kernel)."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.cost_model.t_total_multi_batch()",
                    "repro.api.plan(model, fleet, B)")
    return _t_total_multi_batch(profile, net, o_idx, s_idx, l_idx, ms, ml,
                                b)


# ---------------------------------------------------------------------------
# Tree topology entry points (DESIGN.md §12).  The generalized multi
# evaluators above are stream-generic — a tree schedule carries
# S = M + E - 1 TASK-S streams and the per-edge structure lives in
# TreeProfile/TreeNetwork — so these are thin, *supported* (not
# deprecated) wrappers with tree-typed signatures.
# ---------------------------------------------------------------------------


def t_total_tree(profile: TreeProfile, net: TreeNetwork,
                 sched: MultiSchedule) -> Breakdown:
    """Exact generalized Eq. (12) for an integer tree schedule.  At
    ``E = 1`` every term is the star's :func:`_t_total_multi` expression
    bit-for-bit (the equivalence suite asserts it)."""
    return _t_total_multi(profile, net, sched)


def t_total_tree_batch(profile: TreeProfile, net: TreeNetwork,
                       o_idx: np.ndarray, s_idx: np.ndarray,
                       l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                       b: np.ndarray) -> np.ndarray:
    """Vectorized :func:`t_total_tree` over K candidate lanes
    (``s_idx``/``ms``: ``[K, S]``, ``b``: ``[K, S+2]``)."""
    return _t_total_multi_batch(profile, net, o_idx, s_idx, l_idx, ms, ml,
                                b)
