"""``Fleet`` — the unified topology primitive behind ``repro.api``
(DESIGN.md §9).

A fleet is *M heterogeneous devices + one edge server + one cloud*; the
paper's classic (device, edge, cloud) triple is exactly a fleet at
``M = 1``.  A :class:`Fleet` carries everything the scheduler needs that
is **hardware**, not workload: per-tier compute specs, per-device compute
slowdowns, per-device uplinks and the edge→cloud backhaul — or, in
*pinned-profile* mode, an already-built profile/network pair (used by the
synthetic Table-II benchmarks and by the legacy shims).

Topology nativity
-----------------
``topology`` records which concrete stack a fleet resolves to:

* ``"triple"`` — the paper's 3-worker types (:class:`HierProfile` /
  :class:`Network` / ``Schedule``) and their scheduler/DES.  Only valid
  at ``M = 1``.
* ``"star"`` — the M-device generalization (:class:`MultiProfile` /
  :class:`StarNetwork` / ``MultiSchedule``).
* ``"tree"`` — the two-level generalization: M devices partitioned
  across E edge servers, each with its own backhaul pipe
  (:class:`TreeProfile` / :class:`TreeNetwork`, still ``MultiSchedule``
  — idle edges hold empty stream slots).  At ``E = 1`` the tree stack
  is bit-identical to the star (DESIGN.md §12), the same way the star
  at ``M = 1`` is bit-identical to the triple.

For the **latency** objective the two stacks are bit-for-bit equivalent
at ``M = 1`` (the equivalence suite asserts it), so the choice is
invisible.  The discrete-event simulators and the steady-state period
model, however, shape network pipes differently (per-destination TC
input classes on the star — see EXPERIMENTS.md §Fig.6), so DES traces
and throughput-objective scores agree only on schedules without input
uploads.  ``topology="auto"`` therefore resolves to ``"triple"`` at
``M = 1`` (the exact paper stack) and ``"star"`` otherwise; benchmarks
that sweep M pass ``topology="star"`` so the M=1 row stays comparable to
the rest of the sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.core.cost_model import (HierProfile, MultiProfile, Network,
                                   StarNetwork, TreeNetwork, TreeProfile)
from repro.core.profiler import (ALEXNET_TESTBED, LM_TESTBED, PAPER_TESTBED,
                                 WorkerSpec, analytic_profile,
                                 multi_analytic_profile)

MBPS = 1e6 / 8.0                      # paper quotes Mbps; model uses B/s

TRIPLE = "triple"
STAR = "star"
TREE = "tree"

# The paper's §VI-B testbed radios: mobile-edge fixed at 5 Mbps.
MOBILE_EDGE_MBPS = 5.0

# Heterogeneous CNN device fleet (deterministic so BENCH records stay
# comparable across PRs): per-device compute slowdown vs the paper's
# reference device, and per-device uplink Mbps.  The first device is the
# paper's testbed device exactly (slowdown 1.0, 5 Mbps).
FLEET_SLOWDOWNS = (1.0, 1.4, 1.9, 2.5, 1.2, 1.6, 2.2, 3.0)
FLEET_UPLINK_MBPS = (5.0, 4.5, 4.0, 3.5, 5.0, 4.2, 3.8, 3.2)

# LM fleet: same heterogeneity shape on LTE/WiFi-class radios (raw
# payloads are MBs), device-resident ~2 MB raw samples tokenized
# on-device (see benchmarks/fig_lm_fleet.py for the workload story).
LM_FLEET_SLOWDOWNS = (1.0, 1.4, 1.9, 2.5)
LM_FLEET_UPLINK_MBPS = (50.0, 40.0, 30.0, 25.0)
LM_BACKHAUL_MBPS = 200.0
LM_RAW_SAMPLE_BYTES = 2e6

# Per-model worker calibration — the paper's profiling stage measures
# each model on each worker, so effective throughput is model-specific.
TABLE2_TESTBEDS: Dict[str, Dict[str, WorkerSpec]] = {
    "lenet5": PAPER_TESTBED,
    "alexnet": ALEXNET_TESTBED,
}


@dataclasses.dataclass
class Fleet:
    """M devices + edge + cloud, in spec mode or pinned-profile mode.

    Spec mode (the default constructors): ``workers`` maps the three
    tiers (``device``/``edge``/``cloud``) to :class:`WorkerSpec`;
    ``device_slowdowns[i]`` scales the device tier for device *i*;
    ``uplink_mbps[i]`` is device *i*'s radio; ``backhaul_mbps`` the
    edge↔cloud link; ``sample_bytes`` optionally overrides the model's
    per-sample wire size (the LM fleet's raw-payload regime).

    Pinned-profile mode (:meth:`from_profile`): ``_profile``/``_network``
    hold a prebuilt profile/network pair and the spec fields are unused.

    ``wire`` is the fleet's default cut-point transfer codec
    (``"none"`` | ``"int8"``, see :mod:`repro.core.wire`): a property of
    the deployment's links, not the workload, so it lives here and
    :func:`repro.api.plan` picks it up (overridable per plan).
    """
    workers: Optional[Dict[str, WorkerSpec]] = None
    device_slowdowns: Tuple[float, ...] = (1.0,)
    uplink_mbps: Tuple[float, ...] = (MOBILE_EDGE_MBPS,)
    backhaul_mbps: float = 3.0
    sample_bytes: Optional[float] = None
    topology: str = "auto"
    wire: str = "none"
    # -- tree-topology spec fields (ignored on triple/star fleets) --------
    edge_of: Optional[Tuple[int, ...]] = None
    edge_backhaul_mbps: Optional[Tuple[float, ...]] = None
    edge_scales: Optional[Tuple[float, ...]] = None
    cloud_speedup: float = 1.0
    _profile: Optional[Union[HierProfile, MultiProfile]] = None
    _network: Optional[Union[Network, StarNetwork, TreeNetwork]] = None

    def __post_init__(self) -> None:
        from repro.core.wire import validate_wire
        validate_wire(self.wire)
        if self.topology == "auto":
            self.topology = TRIPLE if self.num_devices == 1 else STAR
        if self.topology not in (TRIPLE, STAR, TREE):
            raise ValueError(f"unknown fleet topology: {self.topology!r}")
        if self.topology == TRIPLE and self.num_devices != 1:
            raise ValueError("the classic triple has exactly one device; "
                             "use topology='star' for M >= 2")
        if self._profile is not None:
            names = getattr(self._profile, "worker_names",
                            ("device", "edge", "cloud"))
            if len(set(names)) != len(names):
                dupes = sorted({n for n in names if names.count(n) > 1})
                raise ValueError(
                    f"duplicate worker names in fleet: {dupes}")
        if self.topology == TREE:
            if self._profile is None:
                if self.edge_of is None:
                    raise ValueError("a tree fleet needs edge_of — the "
                                     "device→edge assignment")
                self.edge_of = tuple(int(e) for e in self.edge_of)
                if len(self.edge_of) != self.num_devices:
                    raise ValueError("edge_of needs one entry per device")
                e = self.num_edges
                if sorted(set(self.edge_of)) != list(range(e)):
                    raise ValueError("edge_of must use contiguous edge "
                                     f"indices 0..{e - 1} with every edge "
                                     "non-empty")
                if self.edge_backhaul_mbps is None:
                    self.edge_backhaul_mbps = (self.backhaul_mbps,) * e
                self.edge_backhaul_mbps = tuple(
                    float(b) for b in self.edge_backhaul_mbps)
                if len(self.edge_backhaul_mbps) != e:
                    raise ValueError("need one backhaul per edge")
                if self.edge_scales is not None and \
                        len(self.edge_scales) != e:
                    raise ValueError("need one edge_scale per edge")
        if self._profile is None:
            assert len(self.device_slowdowns) == len(self.uplink_mbps), \
                "need one uplink per device"

    # ---- constructors ---------------------------------------------------

    @classmethod
    def from_profile(cls, profile: Union[HierProfile, MultiProfile],
                     net: Union[Network, StarNetwork],
                     topology: str = "auto", wire: str = "none") -> "Fleet":
        """Wrap an existing profile/network pair (synthetic benchmarks,
        measured profiles, legacy shims).  A :class:`HierProfile` +
        :class:`Network` pair is triple-native; a :class:`MultiProfile` +
        :class:`StarNetwork` pair is star-native (even at M = 1); a
        :class:`TreeProfile` + :class:`TreeNetwork` pair is tree-native
        (even at E = 1)."""
        if isinstance(profile, TreeProfile):
            assert isinstance(net, TreeNetwork), \
                "a TreeProfile needs a TreeNetwork"
            assert profile.num_devices == net.num_devices and \
                profile.n_edges == net.num_edges
            if topology == "auto":
                topology = TREE
            if topology != TREE:
                raise ValueError(
                    "a TreeProfile/TreeNetwork pair is tree-native; reduce "
                    "with profile.to_multi() / net.to_star() for a star "
                    "fleet")
            m = profile.num_devices
            return cls(device_slowdowns=(1.0,) * m,
                       uplink_mbps=(0.0,) * m, topology=topology,
                       wire=wire, edge_of=net.edge_of, _profile=profile,
                       _network=net)
        if isinstance(profile, MultiProfile):
            assert isinstance(net, StarNetwork), \
                "a MultiProfile needs a StarNetwork"
            assert profile.num_devices == net.num_devices
            if topology == "auto":
                topology = STAR
            if topology != STAR:
                raise ValueError(
                    "a MultiProfile/StarNetwork pair is star-native; "
                    "reduce with profile.three_worker() for a triple fleet")
            m = profile.num_devices
        else:
            assert isinstance(profile, HierProfile) and \
                isinstance(net, Network), \
                "a HierProfile needs a Network"
            if topology == "auto":
                topology = TRIPLE
            if topology != TRIPLE:
                raise ValueError(
                    "a HierProfile/Network pair is triple-native; lift "
                    "with MultiProfile.from_hier / StarNetwork."
                    "from_network for a star fleet")
            m = 1
        return cls(device_slowdowns=(1.0,) * m, uplink_mbps=(0.0,) * m,
                   topology=topology, wire=wire, _profile=profile,
                   _network=net)

    @classmethod
    def from_table2(cls, model: str = "lenet5", m: int = 1,
                    edge_cloud_mbps: float = 3.0,
                    topology: str = "auto", wire: str = "none",
                    n_edges: int = 1,
                    cloud_speedup: float = 1.0) -> "Fleet":
        """The paper-calibrated CNN testbed (§VI-B) extended to the
        deterministic heterogeneous device fleet of the M-sweeps.
        ``model`` picks the per-model worker calibration
        (``lenet5`` / ``alexnet``); ``m = 1`` is the paper's exact
        testbed (slowdown 1.0, 5 Mbps uplink).  ``n_edges > 1`` (or
        ``topology="tree"``) partitions the devices contiguously across
        ``n_edges`` edge servers, each with its own ``edge_cloud_mbps``
        backhaul pipe."""
        assert 1 <= m <= len(FLEET_SLOWDOWNS)
        if n_edges > 1 and topology in ("auto", TREE):
            topology = TREE
        if topology == TREE:
            assert 1 <= n_edges <= m
            edge_of = tuple(i * n_edges // m for i in range(m))
            return cls(workers=TABLE2_TESTBEDS[model],
                       device_slowdowns=FLEET_SLOWDOWNS[:m],
                       uplink_mbps=FLEET_UPLINK_MBPS[:m],
                       backhaul_mbps=edge_cloud_mbps, topology=TREE,
                       wire=wire, edge_of=edge_of,
                       cloud_speedup=cloud_speedup)
        return cls(workers=TABLE2_TESTBEDS[model],
                   device_slowdowns=FLEET_SLOWDOWNS[:m],
                   uplink_mbps=FLEET_UPLINK_MBPS[:m],
                   backhaul_mbps=edge_cloud_mbps, topology=topology,
                   wire=wire)

    @classmethod
    def lm_default(cls, m: int = 1,
                   backhaul_mbps: float = LM_BACKHAUL_MBPS,
                   sample_bytes: float = LM_RAW_SAMPLE_BYTES,
                   wire: str = "none") -> "Fleet":
        """The LM fleet (DESIGN.md §8): mobile-NPU/edge-GPU/cloud tiers,
        LTE/WiFi-class radios, device-resident ~2 MB raw samples.
        Star-native at every M so sweeps stay internally comparable."""
        assert 1 <= m <= len(LM_FLEET_SLOWDOWNS)
        return cls(workers=LM_TESTBED,
                   device_slowdowns=LM_FLEET_SLOWDOWNS[:m],
                   uplink_mbps=LM_FLEET_UPLINK_MBPS[:m],
                   backhaul_mbps=backhaul_mbps, sample_bytes=sample_bytes,
                   topology=STAR, wire=wire)

    # ---- views ----------------------------------------------------------

    @property
    def num_devices(self) -> int:
        if self._profile is not None:
            return self._profile.num_devices \
                if isinstance(self._profile, MultiProfile) else 1
        return len(self.device_slowdowns)

    M = num_devices

    @property
    def num_edges(self) -> int:
        """Edge-server count: 1 on triple/star, ``E`` on a tree."""
        if isinstance(self._profile, TreeProfile):
            return self._profile.n_edges
        if self.topology == TREE and self.edge_of is not None:
            return max(self.edge_of) + 1
        return 1

    @property
    def pinned(self) -> bool:
        return self._profile is not None

    def profile_for(self, model=None
                    ) -> Union[HierProfile, MultiProfile]:
        """The native profile: pinned, or built from the model via the
        analytic profiler (triple → :class:`HierProfile`, star →
        :class:`MultiProfile`)."""
        if self._profile is not None:
            return self._profile
        if model is None:
            raise ValueError(
                "this Fleet carries worker specs, not a profile — pass a "
                "model to plan()/profile_for(), or build the Fleet with "
                "Fleet.from_profile(profile, net)")
        if self.topology == TRIPLE:
            return analytic_profile(model, self.workers,
                                    sample_bytes=self.sample_bytes)
        star = multi_analytic_profile(model, self.workers,
                                      device_slowdowns=self.device_slowdowns,
                                      sample_bytes=self.sample_bytes)
        if self.topology == TREE:
            return TreeProfile.from_multi(star, n_edges=self.num_edges,
                                          edge_scales=self.edge_scales,
                                          cloud_speedup=self.cloud_speedup)
        return star

    def network(self) -> Union[Network, StarNetwork, TreeNetwork]:
        """The native network (triple → :class:`Network`, star →
        :class:`StarNetwork`, tree → :class:`TreeNetwork`)."""
        if self._network is not None:
            return self._network
        if self.topology == TRIPLE:
            return Network(bw_de=self.uplink_mbps[0] * MBPS,
                           bw_ec=self.backhaul_mbps * MBPS)
        if self.topology == TREE:
            return TreeNetwork(
                bw_de=np.array(self.uplink_mbps) * MBPS,
                bw_ec=np.array(self.edge_backhaul_mbps) * MBPS,
                edge_of=self.edge_of)
        return StarNetwork(bw_de=np.array(self.uplink_mbps) * MBPS,
                           bw_ec=self.backhaul_mbps * MBPS)

    def describe(self) -> str:
        m = self.num_devices
        tree = f", E={self.num_edges}" if self.topology == TREE else ""
        wire = f", wire={self.wire}" if self.wire != "none" else ""
        if self.pinned:
            return (f"M={m} ({self.topology}{tree}; pinned "
                    f"profile/network{wire})")
        ups = "/".join(f"{u:g}" for u in self.uplink_mbps)
        if self.topology == TREE:
            bhs = "/".join(f"{b:g}" for b in self.edge_backhaul_mbps)
            return (f"M={m} ({self.topology}{tree}; uplinks {ups} Mbps, "
                    f"backhauls {bhs} Mbps{wire})")
        return (f"M={m} ({self.topology}; uplinks {ups} Mbps, "
                f"backhaul {self.backhaul_mbps:g} Mbps{wire})")
