"""``Fleet`` — the unified topology primitive behind ``repro.api``
(DESIGN.md §9).

A fleet is *M heterogeneous devices + one edge server + one cloud*; the
paper's classic (device, edge, cloud) triple is exactly a fleet at
``M = 1``.  A :class:`Fleet` carries everything the scheduler needs that
is **hardware**, not workload: per-tier compute specs, per-device compute
slowdowns, per-device uplinks and the edge→cloud backhaul — or, in
*pinned-profile* mode, an already-built profile/network pair (used by the
synthetic Table-II benchmarks and by the legacy shims).

Topology nativity
-----------------
``topology`` records which concrete stack a fleet resolves to:

* ``"triple"`` — the paper's 3-worker types (:class:`HierProfile` /
  :class:`Network` / ``Schedule``) and their scheduler/DES.  Only valid
  at ``M = 1``.
* ``"star"`` — the M-device generalization (:class:`MultiProfile` /
  :class:`StarNetwork` / ``MultiSchedule``).

For the **latency** objective the two stacks are bit-for-bit equivalent
at ``M = 1`` (the equivalence suite asserts it), so the choice is
invisible.  The discrete-event simulators and the steady-state period
model, however, shape network pipes differently (per-destination TC
input classes on the star — see EXPERIMENTS.md §Fig.6), so DES traces
and throughput-objective scores agree only on schedules without input
uploads.  ``topology="auto"`` therefore resolves to ``"triple"`` at
``M = 1`` (the exact paper stack) and ``"star"`` otherwise; benchmarks
that sweep M pass ``topology="star"`` so the M=1 row stays comparable to
the rest of the sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.core.cost_model import (HierProfile, MultiProfile, Network,
                                   StarNetwork)
from repro.core.profiler import (ALEXNET_TESTBED, LM_TESTBED, PAPER_TESTBED,
                                 WorkerSpec, analytic_profile,
                                 multi_analytic_profile)

MBPS = 1e6 / 8.0                      # paper quotes Mbps; model uses B/s

TRIPLE = "triple"
STAR = "star"

# The paper's §VI-B testbed radios: mobile-edge fixed at 5 Mbps.
MOBILE_EDGE_MBPS = 5.0

# Heterogeneous CNN device fleet (deterministic so BENCH records stay
# comparable across PRs): per-device compute slowdown vs the paper's
# reference device, and per-device uplink Mbps.  The first device is the
# paper's testbed device exactly (slowdown 1.0, 5 Mbps).
FLEET_SLOWDOWNS = (1.0, 1.4, 1.9, 2.5, 1.2, 1.6, 2.2, 3.0)
FLEET_UPLINK_MBPS = (5.0, 4.5, 4.0, 3.5, 5.0, 4.2, 3.8, 3.2)

# LM fleet: same heterogeneity shape on LTE/WiFi-class radios (raw
# payloads are MBs), device-resident ~2 MB raw samples tokenized
# on-device (see benchmarks/fig_lm_fleet.py for the workload story).
LM_FLEET_SLOWDOWNS = (1.0, 1.4, 1.9, 2.5)
LM_FLEET_UPLINK_MBPS = (50.0, 40.0, 30.0, 25.0)
LM_BACKHAUL_MBPS = 200.0
LM_RAW_SAMPLE_BYTES = 2e6

# Per-model worker calibration — the paper's profiling stage measures
# each model on each worker, so effective throughput is model-specific.
TABLE2_TESTBEDS: Dict[str, Dict[str, WorkerSpec]] = {
    "lenet5": PAPER_TESTBED,
    "alexnet": ALEXNET_TESTBED,
}


@dataclasses.dataclass
class Fleet:
    """M devices + edge + cloud, in spec mode or pinned-profile mode.

    Spec mode (the default constructors): ``workers`` maps the three
    tiers (``device``/``edge``/``cloud``) to :class:`WorkerSpec`;
    ``device_slowdowns[i]`` scales the device tier for device *i*;
    ``uplink_mbps[i]`` is device *i*'s radio; ``backhaul_mbps`` the
    edge↔cloud link; ``sample_bytes`` optionally overrides the model's
    per-sample wire size (the LM fleet's raw-payload regime).

    Pinned-profile mode (:meth:`from_profile`): ``_profile``/``_network``
    hold a prebuilt profile/network pair and the spec fields are unused.

    ``wire`` is the fleet's default cut-point transfer codec
    (``"none"`` | ``"int8"``, see :mod:`repro.core.wire`): a property of
    the deployment's links, not the workload, so it lives here and
    :func:`repro.api.plan` picks it up (overridable per plan).
    """
    workers: Optional[Dict[str, WorkerSpec]] = None
    device_slowdowns: Tuple[float, ...] = (1.0,)
    uplink_mbps: Tuple[float, ...] = (MOBILE_EDGE_MBPS,)
    backhaul_mbps: float = 3.0
    sample_bytes: Optional[float] = None
    topology: str = "auto"
    wire: str = "none"
    _profile: Optional[Union[HierProfile, MultiProfile]] = None
    _network: Optional[Union[Network, StarNetwork]] = None

    def __post_init__(self) -> None:
        from repro.core.wire import validate_wire
        validate_wire(self.wire)
        if self.topology == "auto":
            self.topology = TRIPLE if self.num_devices == 1 else STAR
        if self.topology not in (TRIPLE, STAR):
            raise ValueError(f"unknown fleet topology: {self.topology!r}")
        if self.topology == TRIPLE and self.num_devices != 1:
            raise ValueError("the classic triple has exactly one device; "
                             "use topology='star' for M >= 2")
        if self._profile is None:
            assert len(self.device_slowdowns) == len(self.uplink_mbps), \
                "need one uplink per device"

    # ---- constructors ---------------------------------------------------

    @classmethod
    def from_profile(cls, profile: Union[HierProfile, MultiProfile],
                     net: Union[Network, StarNetwork],
                     topology: str = "auto", wire: str = "none") -> "Fleet":
        """Wrap an existing profile/network pair (synthetic benchmarks,
        measured profiles, legacy shims).  A :class:`HierProfile` +
        :class:`Network` pair is triple-native; a :class:`MultiProfile` +
        :class:`StarNetwork` pair is star-native (even at M = 1)."""
        if isinstance(profile, MultiProfile):
            assert isinstance(net, StarNetwork), \
                "a MultiProfile needs a StarNetwork"
            assert profile.num_devices == net.num_devices
            if topology == "auto":
                topology = STAR
            if topology != STAR:
                raise ValueError(
                    "a MultiProfile/StarNetwork pair is star-native; "
                    "reduce with profile.three_worker() for a triple fleet")
            m = profile.num_devices
        else:
            assert isinstance(profile, HierProfile) and \
                isinstance(net, Network), \
                "a HierProfile needs a Network"
            if topology == "auto":
                topology = TRIPLE
            if topology != TRIPLE:
                raise ValueError(
                    "a HierProfile/Network pair is triple-native; lift "
                    "with MultiProfile.from_hier / StarNetwork."
                    "from_network for a star fleet")
            m = 1
        return cls(device_slowdowns=(1.0,) * m, uplink_mbps=(0.0,) * m,
                   topology=topology, wire=wire, _profile=profile,
                   _network=net)

    @classmethod
    def from_table2(cls, model: str = "lenet5", m: int = 1,
                    edge_cloud_mbps: float = 3.0,
                    topology: str = "auto", wire: str = "none") -> "Fleet":
        """The paper-calibrated CNN testbed (§VI-B) extended to the
        deterministic heterogeneous device fleet of the M-sweeps.
        ``model`` picks the per-model worker calibration
        (``lenet5`` / ``alexnet``); ``m = 1`` is the paper's exact
        testbed (slowdown 1.0, 5 Mbps uplink)."""
        assert 1 <= m <= len(FLEET_SLOWDOWNS)
        return cls(workers=TABLE2_TESTBEDS[model],
                   device_slowdowns=FLEET_SLOWDOWNS[:m],
                   uplink_mbps=FLEET_UPLINK_MBPS[:m],
                   backhaul_mbps=edge_cloud_mbps, topology=topology,
                   wire=wire)

    @classmethod
    def lm_default(cls, m: int = 1,
                   backhaul_mbps: float = LM_BACKHAUL_MBPS,
                   sample_bytes: float = LM_RAW_SAMPLE_BYTES,
                   wire: str = "none") -> "Fleet":
        """The LM fleet (DESIGN.md §8): mobile-NPU/edge-GPU/cloud tiers,
        LTE/WiFi-class radios, device-resident ~2 MB raw samples.
        Star-native at every M so sweeps stay internally comparable."""
        assert 1 <= m <= len(LM_FLEET_SLOWDOWNS)
        return cls(workers=LM_TESTBED,
                   device_slowdowns=LM_FLEET_SLOWDOWNS[:m],
                   uplink_mbps=LM_FLEET_UPLINK_MBPS[:m],
                   backhaul_mbps=backhaul_mbps, sample_bytes=sample_bytes,
                   topology=STAR, wire=wire)

    # ---- views ----------------------------------------------------------

    @property
    def num_devices(self) -> int:
        if self._profile is not None:
            return self._profile.num_devices \
                if isinstance(self._profile, MultiProfile) else 1
        return len(self.device_slowdowns)

    M = num_devices

    @property
    def pinned(self) -> bool:
        return self._profile is not None

    def profile_for(self, model=None
                    ) -> Union[HierProfile, MultiProfile]:
        """The native profile: pinned, or built from the model via the
        analytic profiler (triple → :class:`HierProfile`, star →
        :class:`MultiProfile`)."""
        if self._profile is not None:
            return self._profile
        if model is None:
            raise ValueError(
                "this Fleet carries worker specs, not a profile — pass a "
                "model to plan()/profile_for(), or build the Fleet with "
                "Fleet.from_profile(profile, net)")
        if self.topology == TRIPLE:
            return analytic_profile(model, self.workers,
                                    sample_bytes=self.sample_bytes)
        return multi_analytic_profile(model, self.workers,
                                      device_slowdowns=self.device_slowdowns,
                                      sample_bytes=self.sample_bytes)

    def network(self) -> Union[Network, StarNetwork]:
        """The native network (triple → :class:`Network`, star →
        :class:`StarNetwork`)."""
        if self._network is not None:
            return self._network
        if self.topology == TRIPLE:
            return Network(bw_de=self.uplink_mbps[0] * MBPS,
                           bw_ec=self.backhaul_mbps * MBPS)
        return StarNetwork(bw_de=np.array(self.uplink_mbps) * MBPS,
                           bw_ec=self.backhaul_mbps * MBPS)

    def describe(self) -> str:
        m = self.num_devices
        wire = f", wire={self.wire}" if self.wire != "none" else ""
        if self.pinned:
            return f"M={m} ({self.topology}; pinned profile/network{wire})"
        ups = "/".join(f"{u:g}" for u in self.uplink_mbps)
        return (f"M={m} ({self.topology}; uplinks {ups} Mbps, "
                f"backhaul {self.backhaul_mbps:g} Mbps{wire})")
