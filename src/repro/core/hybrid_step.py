"""Hybrid-parallelism execution engine (§IV-B) with exact SGD semantics.

Executes one HierTrain iteration the way the paper describes it — workers
holding *separate copies* of their assigned layers, activations crossing at
the cut points, and only frontend gradients being exchanged — and produces
the *same* update as vanilla SGD over the full batch ``B`` (sample-weighted
gradient averaging; see DESIGN.md §3 for why weighting is required for
exactness).  Two entry points:

* :func:`hybrid_sgd_step` — the paper's three-worker topology (one TASK S,
  one TASK L, one TASK O).
* :func:`multi_hybrid_sgd_step` — M TASK-S streams with per-stream cuts
  ``m_s[i]`` (DESIGN.md §6); worker_o picks each arriving stream up at its
  own cut, in ascending-cut order.  With ``M = 1`` the traced program is
  identical to :func:`hybrid_sgd_step`.

The three-worker forward routing (Fig. 4):

* ``worker_s``: layers ``1..m_s`` on its ``b_s`` samples -> ships ``h_s``.
* ``worker_l``: layers ``1..m_l`` on its ``b_l`` samples -> ships ``h_l``.
* ``worker_o``: layers ``1..m_s`` on ``b_o``; layers ``m_s+1..m_l`` on its own
  activations *plus the arrived* ``h_s``; layers ``m_l+1..N`` on everything.

The backward pass retraces this routing (handled by AD through the composed
function — gradients w.r.t. ``params_s`` are exactly what worker_s computes
after receiving the intermediate result at layer ``m_s+1``).  Weight update:
per-layer gradient exchange over the *shared* frontend only.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cost_model import MultiSchedule, Schedule
from repro.models.cnn import LayeredModel

Params = List[Dict[str, jax.Array]]


def _sum_nll(model: LayeredModel, logits: jax.Array,
             labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def reference_sgd_step(model: LayeredModel, params: Params, x: jax.Array,
                       y: jax.Array, lr: float) -> Tuple[Params, jax.Array]:
    """Vanilla full-batch SGD step: the ground truth the hybrid step must
    reproduce."""
    def loss_fn(p):
        return _sum_nll(model, model.apply(p, x), y) / x.shape[0]
    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return new, loss


def split_batch(x: jax.Array, y: jax.Array, sched: Schedule
                ) -> Dict[str, Tuple[jax.Array, jax.Array]]:
    """Assign the first b_o samples to o, next b_s to s, rest to l."""
    bo, bs, bl = sched.b_o, sched.b_s, sched.b_l
    assert bo + bs + bl == x.shape[0]
    return {
        "o": (x[:bo], y[:bo]),
        "s": (x[bo:bo + bs], y[bo:bo + bs]),
        "l": (x[bo + bs:], y[bo + bs:]),
    }


def hybrid_sgd_step(model: LayeredModel, params: Params,
                    batches: Dict[str, Tuple[jax.Array, jax.Array]],
                    m_s: int, m_l: int, lr: float
                    ) -> Tuple[Params, jax.Array]:
    """One HierTrain iteration.  Returns (updated params, mean loss).

    ``params`` plays the role of the consensus weights each worker starts
    the iteration with (they are equal after every weight-update phase).
    """
    N = model.num_layers
    assert 0 <= m_s <= m_l <= N
    x_o, y_o = batches["o"]
    x_s, y_s = batches["s"]
    x_l, y_l = batches["l"]
    b_o, b_s, b_l = x_o.shape[0], x_s.shape[0], x_l.shape[0]
    B = b_o + b_s + b_l

    # Worker-local copies: p_s = frontend 1..m_s, p_l = 1..m_l, p_o = all.
    p_o = params
    p_s = params[:m_s]
    p_l = params[:m_l]

    def iteration_loss(p_o: Params, p_s: Params, p_l: Params) -> jax.Array:
        # --- forward phase (Fig. 4 routing) ---
        h_s = model.apply_segment(p_s, x_s, 0, m_s) if b_s else None
        h_l = model.apply_segment(p_l, x_l, 0, m_l) if b_l else None
        a_o = model.apply_segment(p_o, x_o, 0, m_s)
        # worker_o continues its own + s's samples through m_s+1..m_l.
        mid_in = a_o if h_s is None else jnp.concatenate([a_o, h_s], axis=0)
        mid = model.apply_segment(p_o, mid_in, m_s, m_l)
        tail_in = mid if h_l is None else jnp.concatenate([mid, h_l], axis=0)
        logits = model.apply_segment(p_o, tail_in, m_l, N)
        labels = jnp.concatenate([y_o, y_s, y_l], axis=0)
        return _sum_nll(model, logits, labels)

    total_loss, (g_o, g_s, g_l) = jax.value_and_grad(
        iteration_loss, argnums=(0, 1, 2))(p_o, p_s, p_l)

    # --- weight-update phase: layer-wise gradient exchange ---------------
    # Workers hold per-sample-sum gradients; worker_o aggregates the shared
    # frontend layers and every worker scales by 1/B (exact batch-B SGD).
    new_params: Params = []
    for i in range(N):
        g = g_o[i]
        if i < m_s and b_s:
            g = jax.tree.map(jnp.add, g, g_s[i])
        if i < m_l and b_l:
            g = jax.tree.map(jnp.add, g, g_l[i])
        new_params.append(jax.tree.map(
            lambda p, gg: p - lr * (gg / B), params[i], g))
    return new_params, total_loss / B


def hybrid_step_from_schedule(model: LayeredModel, params: Params,
                              x: jax.Array, y: jax.Array, sched: Schedule,
                              lr: float) -> Tuple[Params, jax.Array]:
    return hybrid_sgd_step(model, params, split_batch(x, y, sched),
                           sched.m_s, sched.m_l, lr)


# ---------------------------------------------------------------------------
# M-stream generalization (DESIGN.md §6): one TASK-S instance per non-o,
# non-l worker, each with its own cut.  worker_o merges stream i into its
# running activation batch at layer m_s[i] (ascending-cut order, stream
# index breaking ties), then TASK L's stream at m_l, exactly mirroring the
# generalized cost model's routing.
# ---------------------------------------------------------------------------


def multi_split_batch(x: jax.Array, y: jax.Array, sched: MultiSchedule
                      ) -> Dict[str, object]:
    """Assign the first ``b_o`` samples to o, the next ``b_s[i]`` to each
    TASK-S stream in ``s_workers`` order, and the remainder to l."""
    bo, bl = sched.b_o, sched.b_l
    assert bo + sum(sched.b_s) + bl == x.shape[0]
    out: Dict[str, object] = {"o": (x[:bo], y[:bo])}
    streams = []
    at = bo
    for bi in sched.b_s:
        streams.append((x[at:at + bi], y[at:at + bi]))
        at += bi
    out["s"] = tuple(streams)
    out["l"] = (x[at:], y[at:])
    return out


def multi_hybrid_sgd_step(model: LayeredModel, params: Params,
                          batches: Dict[str, object],
                          m_s: Sequence[int], m_l: int, lr: float
                          ) -> Tuple[Params, jax.Array]:
    """One M-stream HierTrain iteration.  Returns (updated params, mean
    loss).  Exact batch-``B`` SGD semantics: per-stream gradients are
    per-sample sums, aggregated over every copy of each frontend layer and
    scaled once by ``1/B``.  With ``M = 1`` and the same schedule this
    traces the identical program to :func:`hybrid_sgd_step`.
    """
    N = model.num_layers
    m_s = tuple(int(m) for m in m_s)
    M = len(m_s)
    x_o, y_o = batches["o"]
    s_streams = batches["s"]
    x_l, y_l = batches["l"]
    assert len(s_streams) == M
    assert all(0 <= m <= m_l for m in m_s) and m_l <= N
    b_s = [sx.shape[0] for sx, _ in s_streams]
    b_o, b_l = x_o.shape[0], x_l.shape[0]
    B = b_o + sum(b_s) + b_l
    # Streams join worker_o's batch in ascending-cut order (stream index
    # breaks ties) — the labels must concatenate in the same order.
    join_order = sorted((i for i in range(M) if b_s[i]),
                        key=lambda i: (m_s[i], i))

    p_o = params
    p_s = [params[:m] for m in m_s]
    p_l = params[:m_l]

    def iteration_loss(p_o: Params, p_s: List[Params], p_l: Params
                       ) -> jax.Array:
        # --- forward: every front-end up to its own cut ---
        h = [model.apply_segment(p_s[i], s_streams[i][0], 0, m_s[i])
             if b_s[i] else None for i in range(M)]
        h_l = model.apply_segment(p_l, x_l, 0, m_l) if b_l else None
        # worker_o walks its segment list, merging arrivals at their cuts.
        cur = x_o
        prev = 0
        for i in join_order:
            if m_s[i] != prev:
                cur = model.apply_segment(p_o, cur, prev, m_s[i])
                prev = m_s[i]
            cur = jnp.concatenate([cur, h[i]], axis=0)
        cur = model.apply_segment(p_o, cur, prev, m_l)
        if h_l is not None:
            cur = jnp.concatenate([cur, h_l], axis=0)
        logits = model.apply_segment(p_o, cur, m_l, N)
        labels = jnp.concatenate(
            [y_o] + [s_streams[i][1] for i in join_order] + [y_l], axis=0)
        return _sum_nll(model, logits, labels)

    total_loss, (g_o, g_s, g_l) = jax.value_and_grad(
        iteration_loss, argnums=(0, 1, 2))(p_o, p_s, p_l)

    # --- weight-update phase: layer-wise gradient exchange ---------------
    new_params: Params = []
    for i in range(N):
        g = g_o[i]
        for d in range(M):
            if i < m_s[d] and b_s[d]:
                g = jax.tree.map(jnp.add, g, g_s[d][i])
        if i < m_l and b_l:
            g = jax.tree.map(jnp.add, g, g_l[i])
        new_params.append(jax.tree.map(
            lambda p, gg: p - lr * (gg / B), params[i], g))
    return new_params, total_loss / B


def multi_hybrid_step_from_schedule(model: LayeredModel, params: Params,
                                    x: jax.Array, y: jax.Array,
                                    sched: MultiSchedule, lr: float
                                    ) -> Tuple[Params, jax.Array]:
    return multi_hybrid_sgd_step(model, params, multi_split_batch(x, y,
                                                                  sched),
                                 sched.m_s, sched.m_l, lr)


# ---------------------------------------------------------------------------
# Compiled fast path.  The cuts and learning rate are static (they select
# the program structure), the params are donated (the step consumes the old
# consensus weights and returns the new ones), and compiled steps are cached
# so a training loop that re-solves its schedule only pays retracing when
# the cuts actually change.  The cache holds a strong reference to each
# model (the closures need it), which is fine at "handful of CNNs" scale.
# ---------------------------------------------------------------------------

_JIT_CACHE: Dict[Tuple, Callable] = {}


def jitted_hybrid_step(model: LayeredModel, m_s: int, m_l: int,
                       lr: float) -> Callable:
    """A compiled ``(params, batches) -> (new_params, loss)`` hybrid step
    with static ``(m_s, m_l, lr)`` and donated ``params``.  jax.jit still
    specializes on the batch-split shapes at first call, so one compiled
    step serves every iteration with the same schedule."""
    key = ("hybrid", id(model), int(m_s), int(m_l), float(lr))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        def step(params: Params, batches):
            return hybrid_sgd_step(model, params, batches, m_s, m_l, lr)
        fn = jax.jit(step, donate_argnums=0)
        _JIT_CACHE[key] = fn
        _JIT_CACHE[key + ("model",)] = model  # keep id(model) valid
    return fn


def jitted_multi_hybrid_step(model: LayeredModel, m_s: Sequence[int],
                             m_l: int, lr: float) -> Callable:
    """Compiled ``(params, batches) -> (new_params, loss)`` M-stream hybrid
    step; the cut tuple ``(m_s, m_l)`` and ``lr`` are static, ``params`` is
    donated, and executables are cached per cut tuple like
    :func:`jitted_hybrid_step`."""
    cuts = tuple(int(m) for m in m_s)
    key = ("multi", id(model), cuts, int(m_l), float(lr))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        def step(params: Params, batches):
            return multi_hybrid_sgd_step(model, params, batches, cuts,
                                         m_l, lr)
        fn = jax.jit(step, donate_argnums=0)
        _JIT_CACHE[key] = fn
        _JIT_CACHE[key + ("model",)] = model
    return fn


def jitted_reference_step(model: LayeredModel, lr: float) -> Callable:
    """Compiled ``(params, x, y) -> (new_params, loss)`` vanilla SGD step
    (static ``lr``, donated ``params``)."""
    key = ("reference", id(model), float(lr))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        def step(params: Params, x: jax.Array, y: jax.Array):
            return reference_sgd_step(model, params, x, y, lr)
        fn = jax.jit(step, donate_argnums=0)
        _JIT_CACHE[key] = fn
        _JIT_CACHE[key + ("model",)] = model
    return fn


# ---------------------------------------------------------------------------
# Communication accounting: bytes each phase moves across worker boundaries.
# Used by integration tests to confirm the hybrid step's traffic equals the
# cost model's DataSize terms (the other half of model validity).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrafficReport:
    input_bytes: float
    activation_bytes: float   # forward handoff + backward intermediate
    weightgrad_bytes: float   # frontend grads up + averaged grads down

    @property
    def total(self) -> float:
        return self.input_bytes + self.activation_bytes + \
            self.weightgrad_bytes


def traffic(model: LayeredModel, sched: Schedule, sample_bytes: float,
            origin: str = "device") -> TrafficReport:
    metas = model.layer_meta()
    inp = sum(b * sample_bytes for b, w in
              ((sched.b_o, sched.worker_o), (sched.b_s, sched.worker_s),
               (sched.b_l, sched.worker_l)) if w != origin)
    act = 0.0
    if sched.m_s > 0 and sched.b_s > 0 and sched.worker_s != sched.worker_o:
        act += 2.0 * sched.b_s * metas[sched.m_s - 1].out_bytes
    if sched.m_l > 0 and sched.b_l > 0 and sched.worker_l != sched.worker_o:
        act += 2.0 * sched.b_l * metas[sched.m_l - 1].out_bytes
    wg = 0.0
    if sched.b_s > 0 and sched.worker_s != sched.worker_o:
        wg += 2.0 * sum(m.param_bytes for m in metas[:sched.m_s])
    if sched.b_l > 0 and sched.worker_l != sched.worker_o:
        wg += 2.0 * sum(m.param_bytes for m in metas[:sched.m_l])
    return TrafficReport(inp, act, wg)
