"""Hybrid-parallelism execution engine (§IV-B) with exact SGD semantics.

Executes one HierTrain iteration the way the paper describes it — workers
holding *separate copies* of their assigned layers, activations crossing at
the cut points, and only frontend gradients being exchanged — and produces
the *same* update as vanilla SGD over the full batch ``B`` (sample-weighted
gradient averaging; see DESIGN.md §3 for why weighting is required for
exactness).  Two entry points:

* :func:`hybrid_sgd_step` — the paper's three-worker topology (one TASK S,
  one TASK L, one TASK O).
* :func:`multi_hybrid_sgd_step` — M TASK-S streams with per-stream cuts
  ``m_s[i]`` (DESIGN.md §6); worker_o picks each arriving stream up at its
  own cut, in ascending-cut order.  With ``M = 1`` the traced program is
  identical to :func:`hybrid_sgd_step`.

Every entry point is model-agnostic (DESIGN.md §8): it takes anything
:func:`repro.core.layerstack.as_layerstack` accepts — a bare
:class:`repro.models.cnn.LayeredModel` (traced bit-identically to the
pre-adapter code) or an adapter such as the LM model-zoo stack.  The stack
contract is what makes the routing generic: ``params`` is a list with one
pytree per cut-point, ``apply_segment`` runs a contiguous cut range, and
``sum_loss`` is the per-sample-*sum* objective (so one division by ``B``
yields exact batch-B SGD).

The three-worker forward routing (Fig. 4):

* ``worker_s``: layers ``1..m_s`` on its ``b_s`` samples -> ships ``h_s``.
* ``worker_l``: layers ``1..m_l`` on its ``b_l`` samples -> ships ``h_l``.
* ``worker_o``: layers ``1..m_s`` on ``b_o``; layers ``m_s+1..m_l`` on its own
  activations *plus the arrived* ``h_s``; layers ``m_l+1..N`` on everything.

The backward pass retraces this routing (handled by AD through the composed
function — gradients w.r.t. ``params_s`` are exactly what worker_s computes
after receiving the intermediate result at layer ``m_s+1``).  Weight update:
per-layer gradient exchange over the *shared* frontend only.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cost_model import MultiSchedule, Schedule
from repro.core.layerstack import as_layerstack
from repro.core.wire import wire_act_bytes, wire_codec, wire_grad_bytes

Params = List[Any]


def reference_sgd_step(model, params: Params, x: jax.Array,
                       y: jax.Array, lr: float) -> Tuple[Params, jax.Array]:
    """Vanilla full-batch SGD step: the ground truth the hybrid step must
    reproduce."""
    stack = as_layerstack(model)
    N = stack.num_layers

    def loss_fn(p):
        return stack.sum_loss(stack.apply_segment(p, x, 0, N), y) / \
            x.shape[0]
    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return new, loss


def split_batch(x: jax.Array, y: jax.Array, sched: Schedule
                ) -> Dict[str, Tuple[jax.Array, jax.Array]]:
    """Assign the first b_o samples to o, next b_s to s, rest to l."""
    bo, bs, bl = sched.b_o, sched.b_s, sched.b_l
    assert bo + bs + bl == x.shape[0]
    return {
        "o": (x[:bo], y[:bo]),
        "s": (x[bo:bo + bs], y[bo:bo + bs]),
        "l": (x[bo + bs:], y[bo + bs:]),
    }


def hybrid_sgd_step(model, params: Params,
                    batches: Dict[str, Tuple[jax.Array, jax.Array]],
                    m_s: int, m_l: int, lr: float, wire: str = "none"
                    ) -> Tuple[Params, jax.Array]:
    """One HierTrain iteration.  Returns (updated params, mean loss).

    ``params`` plays the role of the consensus weights each worker starts
    the iteration with (they are equal after every weight-update phase).

    ``wire`` selects the cut-point transfer codec (``repro.core.wire``):
    ``"int8"`` quantizes the shipped activations forward and — via the
    codec's custom VJP — the returning activation-gradients backward;
    ``"none"`` leaves the traced program bit-identical to the seed.  A
    cut at 0 is a raw-input upload (the ``sample_bytes`` channel), so
    the codec only touches crossings with ``m > 0``.
    """
    stack = as_layerstack(model)
    N = stack.num_layers
    assert 0 <= m_s <= m_l <= N
    codec = wire_codec(wire)
    x_o, y_o = batches["o"]
    x_s, y_s = batches["s"]
    x_l, y_l = batches["l"]
    b_o, b_s, b_l = x_o.shape[0], x_s.shape[0], x_l.shape[0]
    B = b_o + b_s + b_l

    # Worker-local copies: p_s = frontend 1..m_s, p_l = 1..m_l, p_o = all.
    p_o = params
    p_s = params[:m_s]
    p_l = params[:m_l]

    def iteration_loss(p_o: Params, p_s: Params, p_l: Params) -> jax.Array:
        # --- forward phase (Fig. 4 routing) ---
        h_s = stack.apply_segment(p_s, x_s, 0, m_s) if b_s else None
        h_l = stack.apply_segment(p_l, x_l, 0, m_l) if b_l else None
        if codec is not None and h_s is not None and m_s > 0:
            h_s = codec(h_s)
        if codec is not None and h_l is not None and m_l > 0:
            h_l = codec(h_l)
        a_o = stack.apply_segment(p_o, x_o, 0, m_s)
        # worker_o continues its own + s's samples through m_s+1..m_l.
        mid_in = a_o if h_s is None else jnp.concatenate([a_o, h_s], axis=0)
        mid = stack.apply_segment(p_o, mid_in, m_s, m_l)
        tail_in = mid if h_l is None else jnp.concatenate([mid, h_l], axis=0)
        logits = stack.apply_segment(p_o, tail_in, m_l, N)
        labels = jnp.concatenate([y_o, y_s, y_l], axis=0)
        return stack.sum_loss(logits, labels)

    total_loss, (g_o, g_s, g_l) = jax.value_and_grad(
        iteration_loss, argnums=(0, 1, 2))(p_o, p_s, p_l)

    # --- weight-update phase: layer-wise gradient exchange ---------------
    # Workers hold per-sample-sum gradients; worker_o aggregates the shared
    # frontend layers and every worker scales by 1/B (exact batch-B SGD).
    new_params: Params = []
    for i in range(N):
        g = g_o[i]
        if i < m_s and b_s:
            g = jax.tree.map(jnp.add, g, g_s[i])
        if i < m_l and b_l:
            g = jax.tree.map(jnp.add, g, g_l[i])
        new_params.append(jax.tree.map(
            lambda p, gg: p - lr * (gg / B), params[i], g))
    return new_params, total_loss / B


def hybrid_step_from_schedule(model, params: Params,
                              x: jax.Array, y: jax.Array, sched: Schedule,
                              lr: float, wire: str = "none"
                              ) -> Tuple[Params, jax.Array]:
    return hybrid_sgd_step(model, params, split_batch(x, y, sched),
                           sched.m_s, sched.m_l, lr, wire=wire)


# ---------------------------------------------------------------------------
# M-stream generalization (DESIGN.md §6): one TASK-S instance per non-o,
# non-l worker, each with its own cut.  worker_o merges stream i into its
# running activation batch at layer m_s[i] (ascending-cut order, stream
# index breaking ties), then TASK L's stream at m_l, exactly mirroring the
# generalized cost model's routing.
# ---------------------------------------------------------------------------


def multi_split_batch(x: jax.Array, y: jax.Array, sched: MultiSchedule
                      ) -> Dict[str, object]:
    """Assign the first ``b_o`` samples to o, the next ``b_s[i]`` to each
    TASK-S stream in ``s_workers`` order, and the remainder to l."""
    bo, bl = sched.b_o, sched.b_l
    assert bo + sum(sched.b_s) + bl == x.shape[0]
    out: Dict[str, object] = {"o": (x[:bo], y[:bo])}
    streams = []
    at = bo
    for bi in sched.b_s:
        streams.append((x[at:at + bi], y[at:at + bi]))
        at += bi
    out["s"] = tuple(streams)
    out["l"] = (x[at:], y[at:])
    return out


def multi_hybrid_sgd_step(model, params: Params,
                          batches: Dict[str, object],
                          m_s: Sequence[int], m_l: int, lr: float,
                          wire: str = "none"
                          ) -> Tuple[Params, jax.Array]:
    """One M-stream HierTrain iteration.  Returns (updated params, mean
    loss).  Exact batch-``B`` SGD semantics: per-stream gradients are
    per-sample sums, aggregated over every copy of each frontend layer and
    scaled once by ``1/B``.  With ``M = 1`` and the same schedule this
    traces the identical program to :func:`hybrid_sgd_step` (including
    the ``wire`` codec, applied per arriving stream at its cut).
    """
    stack = as_layerstack(model)
    N = stack.num_layers
    codec = wire_codec(wire)
    m_s = tuple(int(m) for m in m_s)
    M = len(m_s)
    x_o, y_o = batches["o"]
    s_streams = batches["s"]
    x_l, y_l = batches["l"]
    assert len(s_streams) == M
    assert all(0 <= m <= m_l for m in m_s) and m_l <= N
    b_s = [sx.shape[0] for sx, _ in s_streams]
    b_o, b_l = x_o.shape[0], x_l.shape[0]
    B = b_o + sum(b_s) + b_l
    # Streams join worker_o's batch in ascending-cut order (stream index
    # breaks ties) — the labels must concatenate in the same order.
    join_order = sorted((i for i in range(M) if b_s[i]),
                        key=lambda i: (m_s[i], i))

    p_o = params
    p_s = [params[:m] for m in m_s]
    p_l = params[:m_l]

    def iteration_loss(p_o: Params, p_s: List[Params], p_l: Params
                       ) -> jax.Array:
        # --- forward: every front-end up to its own cut ---
        h = [stack.apply_segment(p_s[i], s_streams[i][0], 0, m_s[i])
             if b_s[i] else None for i in range(M)]
        h_l = stack.apply_segment(p_l, x_l, 0, m_l) if b_l else None
        if codec is not None:
            h = [codec(h[i]) if h[i] is not None and m_s[i] > 0 else h[i]
                 for i in range(M)]
            if h_l is not None and m_l > 0:
                h_l = codec(h_l)
        # worker_o walks its segment list, merging arrivals at their cuts.
        cur = x_o
        prev = 0
        for i in join_order:
            if m_s[i] != prev:
                cur = stack.apply_segment(p_o, cur, prev, m_s[i])
                prev = m_s[i]
            cur = jnp.concatenate([cur, h[i]], axis=0)
        cur = stack.apply_segment(p_o, cur, prev, m_l)
        if h_l is not None:
            cur = jnp.concatenate([cur, h_l], axis=0)
        logits = stack.apply_segment(p_o, cur, m_l, N)
        labels = jnp.concatenate(
            [y_o] + [s_streams[i][1] for i in join_order] + [y_l], axis=0)
        return stack.sum_loss(logits, labels)

    total_loss, (g_o, g_s, g_l) = jax.value_and_grad(
        iteration_loss, argnums=(0, 1, 2))(p_o, p_s, p_l)

    # --- weight-update phase: layer-wise gradient exchange ---------------
    new_params: Params = []
    for i in range(N):
        g = g_o[i]
        for d in range(M):
            if i < m_s[d] and b_s[d]:
                g = jax.tree.map(jnp.add, g, g_s[d][i])
        if i < m_l and b_l:
            g = jax.tree.map(jnp.add, g, g_l[i])
        new_params.append(jax.tree.map(
            lambda p, gg: p - lr * (gg / B), params[i], g))
    return new_params, total_loss / B


def multi_hybrid_step_from_schedule(model, params: Params,
                                    x: jax.Array, y: jax.Array,
                                    sched: MultiSchedule, lr: float,
                                    wire: str = "none"
                                    ) -> Tuple[Params, jax.Array]:
    return multi_hybrid_sgd_step(model, params, multi_split_batch(x, y,
                                                                  sched),
                                 sched.m_s, sched.m_l, lr, wire=wire)


# ---------------------------------------------------------------------------
# Two-level tree generalization: streams live under E edge servers; each
# edge pre-merges the activations of its resident same-cut streams before
# the cloud-side walk, and the cloud tail (layers m_l..N) can optionally
# run data-parallel under shard_map on a device mesh.  Activation
# concatenation is arithmetic-free, so with every stream on one edge
# (E = 1) the produced params and loss are bit-identical to
# :func:`multi_hybrid_sgd_step` — the sample order, every matmul batch
# and the loss-sum reduction order coincide.
# ---------------------------------------------------------------------------


def tree_hybrid_sgd_step(model, params: Params,
                         batches: Dict[str, object],
                         m_s: Sequence[int], m_l: int, lr: float,
                         wire: str = "none",
                         stream_edge: Sequence[int] | None = None,
                         cloud_mesh=None) -> Tuple[Params, jax.Array]:
    """One tree HierTrain iteration.  Returns (updated params, mean loss).

    ``stream_edge[i]`` names the edge hosting TASK-S stream ``i`` (device
    streams sit under their radio's edge; an edge's own stream under
    itself).  Streams sharing ``(cut, edge)`` are concatenated *on the
    edge* into one activation block before joining worker_o's
    ascending-cut walk — E merge points feeding the cloud merge, exactly
    the two-level aggregation the topology describes.  ``cloud_mesh``
    (optional) runs the cloud-resident tail segment ``m_l..N``
    data-parallel over the mesh's dp axes via ``shard_map`` (two-stage
    VJP: the front is differentiated with ``jax.vjp``, the tail's
    value-and-grad runs *inside* the mapped body with ``psum``-reduced
    parameter grads and loss); the default ``None`` keeps the single
    ``value_and_grad`` program whose results are bit-identical to the
    star path at E=1.
    """
    stack = as_layerstack(model)
    N = stack.num_layers
    codec = wire_codec(wire)
    m_s = tuple(int(m) for m in m_s)
    M = len(m_s)
    eo = tuple(int(e) for e in stream_edge) if stream_edge is not None \
        else (0,) * M
    assert len(eo) == M
    x_o, y_o = batches["o"]
    s_streams = batches["s"]
    x_l, y_l = batches["l"]
    assert len(s_streams) == M
    assert all(0 <= m <= m_l for m in m_s) and m_l <= N
    b_s = [sx.shape[0] for sx, _ in s_streams]
    b_o, b_l = x_o.shape[0], x_l.shape[0]
    B = b_o + sum(b_s) + b_l
    # Ascending-cut order with the hosting edge (then stream index)
    # breaking ties; maximal runs of equal (cut, edge) are one edge-side
    # merge each.  With every stream on edge 0 this is exactly the star
    # join order.
    join_order = sorted((i for i in range(M) if b_s[i]),
                        key=lambda i: (m_s[i], eo[i], i))
    groups: List[Tuple[int, List[int]]] = []
    for i in join_order:
        if groups and groups[-1][0] == m_s[i] and eo[groups[-1][1][-1]] == \
                eo[i]:
            groups[-1][1].append(i)
        else:
            groups.append((m_s[i], [i]))

    p_o = params
    p_s = [params[:m] for m in m_s]
    p_l = params[:m_l]

    def front(p_o: Params, p_s: List[Params], p_l: Params) -> jax.Array:
        """Everything up to the cloud boundary ``m_l``: per-stream
        frontends, per-edge merges, worker_o's walk, TASK L's arrival."""
        h = [stack.apply_segment(p_s[i], s_streams[i][0], 0, m_s[i])
             if b_s[i] else None for i in range(M)]
        h_l = stack.apply_segment(p_l, x_l, 0, m_l) if b_l else None
        if codec is not None:
            h = [codec(h[i]) if h[i] is not None and m_s[i] > 0 else h[i]
                 for i in range(M)]
            if h_l is not None and m_l > 0:
                h_l = codec(h_l)
        cur = x_o
        prev = 0
        for cut, members in groups:
            if cut != prev:
                cur = stack.apply_segment(p_o, cur, prev, cut)
                prev = cut
            blk = h[members[0]] if len(members) == 1 else \
                jnp.concatenate([h[i] for i in members], axis=0)
            cur = jnp.concatenate([cur, blk], axis=0)
        cur = stack.apply_segment(p_o, cur, prev, m_l)
        if h_l is not None:
            cur = jnp.concatenate([cur, h_l], axis=0)
        return cur

    labels = jnp.concatenate(
        [y_o] + [s_streams[i][1] for i in join_order] + [y_l], axis=0)

    if cloud_mesh is None:
        def iteration_loss(p_o: Params, p_s: List[Params], p_l: Params
                           ) -> jax.Array:
            logits = stack.apply_segment(p_o, front(p_o, p_s, p_l), m_l, N)
            return stack.sum_loss(logits, labels)

        total_loss, (g_o, g_s, g_l) = jax.value_and_grad(
            iteration_loss, argnums=(0, 1, 2))(p_o, p_s, p_l)
    else:
        total_loss, g_o, g_s, g_l = _sharded_tail_grads(
            stack, front, labels, p_o, p_s, p_l, m_l, N, B, cloud_mesh)

    new_params: Params = []
    for i in range(N):
        g = g_o[i]
        for d in range(M):
            if i < m_s[d] and b_s[d]:
                g = jax.tree.map(jnp.add, g, g_s[d][i])
        if i < m_l and b_l:
            g = jax.tree.map(jnp.add, g, g_l[i])
        new_params.append(jax.tree.map(
            lambda p, gg: p - lr * (gg / B), params[i], g))
    return new_params, total_loss / B


def _sharded_tail_grads(stack, front, labels, p_o: Params,
                        p_s: List[Params], p_l: Params, m_l: int, N: int,
                        B: int, mesh):
    """Loss + grads with the cloud tail ``m_l..N`` data-parallel under
    ``shard_map``.  Two-stage composition: ``jax.vjp`` through the front,
    then the tail's ``value_and_grad`` *inside* the mapped body — param
    grads and the per-sample-sum loss are ``psum``-reduced over the dp
    axes while the activation cotangent stays batch-sharded and flows
    back through the front's VJP."""
    from jax.sharding import PartitionSpec as P

    from repro.distrib import compat, sharding

    dp = sharding.dp_axes(mesh)
    if not dp:
        raise ValueError("cloud_mesh has no data-parallel axes "
                         "('pod'/'data'); got axes "
                         f"{tuple(mesh.axis_names)}")
    n_shards = 1
    for a in dp:
        n_shards *= mesh.shape[a]
    if B % n_shards != 0:
        raise ValueError(
            f"global batch {B} is not divisible by the cloud mesh's "
            f"{n_shards} data-parallel shards; pick a schedule whose "
            "batch split is a multiple of the dp size")

    cur, front_vjp = jax.vjp(front, p_o, p_s, p_l)

    def tail_loss(p_o: Params, cur: jax.Array, lab: jax.Array) -> jax.Array:
        return stack.sum_loss(stack.apply_segment(p_o, cur, m_l, N), lab)

    def body(p_o: Params, cur_l: jax.Array, lab_l: jax.Array):
        loss_l, (gp_l, gc_l) = jax.value_and_grad(
            tail_loss, argnums=(0, 1))(p_o, cur_l, lab_l)
        gp = jax.tree.map(lambda t: jax.lax.psum(t, dp), gp_l)
        return jax.lax.psum(loss_l, dp), gp, gc_l

    spec_cur = P(dp, *([None] * (cur.ndim - 1)))
    spec_lab = P(dp, *([None] * (labels.ndim - 1)))
    sharded = compat.shard_map(
        body, in_specs=(P(), spec_cur, spec_lab),
        out_specs=(P(), P(), spec_cur), axis_names=set(dp),
        check_vma=False, mesh=mesh)
    total_loss, g_o_tail, g_cur = sharded(p_o, cur, labels)
    g_o_front, g_s, g_l = front_vjp(g_cur)
    g_o = jax.tree.map(jnp.add, g_o_front, g_o_tail)
    return total_loss, g_o, g_s, g_l


def tree_stream_edges(profile, net, sched: MultiSchedule) -> Tuple[int, ...]:
    """Per-TASK-S-stream hosting edge for a tree schedule: a device
    stream sits under its radio's edge, an edge's own stream under
    itself, and a cloud-hosted stream merges with the front group
    (index 0).  On an E=1 tree every stream maps to edge 0, which is
    what keeps the traced step identical to the star's."""
    D = profile.num_devices
    E = net.num_edges
    eo = net.edge_of
    out = []
    for w in sched.s_workers:
        i = profile.widx[w]
        if i < D:
            out.append(eo[i])
        else:
            j = i - D
            out.append(j if j < E else 0)
    return tuple(out)


def tree_hybrid_step_from_schedule(model, params: Params,
                                   x: jax.Array, y: jax.Array,
                                   sched: MultiSchedule, lr: float,
                                   wire: str = "none",
                                   stream_edge: Sequence[int] | None = None,
                                   cloud_mesh=None
                                   ) -> Tuple[Params, jax.Array]:
    return tree_hybrid_sgd_step(model, params, multi_split_batch(x, y,
                                                                 sched),
                                sched.m_s, sched.m_l, lr, wire=wire,
                                stream_edge=stream_edge,
                                cloud_mesh=cloud_mesh)


# ---------------------------------------------------------------------------
# Compiled fast path.  The cuts and learning rate are static (they select
# the program structure), the params are donated (the step consumes the old
# consensus weights and returns the new ones), and compiled steps live in a
# *bounded LRU*: with the LM config zoo reachable through the LayerStack
# adapter, the seed's grow-forever dict (which pinned every model through
# the compiled closures) would leak models and executables across a long
# session.  Keys use an id-based weak model handle; the cache entry pins
# the model only while cached — the id can therefore never be recycled
# while its entry is live, and eviction (or :func:`clear_jit_cache`)
# releases both the executable and the model.
# ---------------------------------------------------------------------------

JIT_CACHE_SIZE = 32


class _JitStepCache:
    """Bounded LRU of compiled step functions.

    ``key`` is ``(kind, id(model), *static_args)``.  The value stores the
    compiled function *and* the model it closed over: the pin is what makes
    the id-keyed handle sound (a live key's id cannot be reused by a new
    model), and dropping the entry releases the model for GC — the seed
    cache held every model forever.
    """

    def __init__(self, maxsize: int = JIT_CACHE_SIZE) -> None:
        assert maxsize >= 1
        self.maxsize = maxsize
        self._entries: "OrderedDict[Tuple, Tuple[Callable, Any]]" = \
            OrderedDict()

    def get(self, key: Tuple) -> Callable | None:
        entry = self._entries.get(key)
        if entry is None:
            return None
        self._entries.move_to_end(key)
        return entry[0]

    def put(self, key: Tuple, fn: Callable, model: Any) -> None:
        self._entries[key] = (fn, model)
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple) -> bool:
        return key in self._entries

    def clear(self) -> None:
        self._entries.clear()


_JIT_CACHE = _JitStepCache()


def clear_jit_cache() -> None:
    """Drop every cached compiled step (releases the pinned models)."""
    _JIT_CACHE.clear()


def _cached_step(key: Tuple, model, make: Callable[[], Callable]
                 ) -> Callable:
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = make()
        _JIT_CACHE.put(key, fn, model)
    return fn


def jitted_hybrid_step(model, m_s: int, m_l: int, lr: float,
                       wire: str = "none") -> Callable:
    """A compiled ``(params, batches) -> (new_params, loss)`` hybrid step
    with static ``(m_s, m_l, lr, wire)`` and donated ``params``.  jax.jit
    still specializes on the batch-split shapes at first call, so one
    compiled step serves every iteration with the same schedule."""
    key = ("hybrid", id(model), int(m_s), int(m_l), float(lr), str(wire))

    def make():
        def step(params: Params, batches):
            return hybrid_sgd_step(model, params, batches, m_s, m_l, lr,
                                   wire=wire)
        return jax.jit(step, donate_argnums=0)
    return _cached_step(key, model, make)


def jitted_multi_hybrid_step(model, m_s: Sequence[int],
                             m_l: int, lr: float,
                             wire: str = "none") -> Callable:
    """Compiled ``(params, batches) -> (new_params, loss)`` M-stream hybrid
    step; the cut tuple ``(m_s, m_l)``, ``lr`` and ``wire`` are static,
    ``params`` is donated, and executables are cached per cut tuple like
    :func:`jitted_hybrid_step`."""
    cuts = tuple(int(m) for m in m_s)
    key = ("multi", id(model), cuts, int(m_l), float(lr), str(wire))

    def make():
        def step(params: Params, batches):
            return multi_hybrid_sgd_step(model, params, batches, cuts,
                                         m_l, lr, wire=wire)
        return jax.jit(step, donate_argnums=0)
    return _cached_step(key, model, make)


def jitted_tree_hybrid_step(model, m_s: Sequence[int], m_l: int, lr: float,
                            wire: str = "none",
                            stream_edge: Sequence[int] | None = None,
                            cloud_mesh=None) -> Callable:
    """Compiled tree-step variant of :func:`jitted_multi_hybrid_step`;
    the stream→edge map and the (optional) cloud mesh join the static
    cache key — a mesh swap recompiles rather than reusing a program
    lowered for the old device set."""
    cuts = tuple(int(m) for m in m_s)
    edges = tuple(int(e) for e in stream_edge) if stream_edge is not None \
        else (0,) * len(cuts)
    key = ("tree", id(model), cuts, int(m_l), float(lr), str(wire), edges,
           None if cloud_mesh is None else id(cloud_mesh))

    def make():
        def step(params: Params, batches):
            return tree_hybrid_sgd_step(model, params, batches, cuts,
                                        m_l, lr, wire=wire,
                                        stream_edge=edges,
                                        cloud_mesh=cloud_mesh)
        return jax.jit(step, donate_argnums=0)
    return _cached_step(key, model, make)


def jitted_reference_step(model, lr: float) -> Callable:
    """Compiled ``(params, x, y) -> (new_params, loss)`` vanilla SGD step
    (static ``lr``, donated ``params``)."""
    key = ("reference", id(model), float(lr))

    def make():
        def step(params: Params, x: jax.Array, y: jax.Array):
            return reference_sgd_step(model, params, x, y, lr)
        return jax.jit(step, donate_argnums=0)
    return _cached_step(key, model, make)


# ---------------------------------------------------------------------------
# Communication accounting: bytes each phase moves across worker boundaries.
# Used by integration tests to confirm the hybrid step's traffic equals the
# cost model's DataSize terms (the other half of model validity).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrafficReport:
    input_bytes: float
    activation_bytes: float   # forward handoff + backward intermediate
    weightgrad_bytes: float   # frontend grads up + averaged grads down

    @property
    def total(self) -> float:
        return self.input_bytes + self.activation_bytes + \
            self.weightgrad_bytes


def traffic(model, sched: Schedule, sample_bytes: float,
            origin: str = "device", wire: str = "none") -> TrafficReport:
    """Bytes one iteration moves across worker boundaries.  The
    activation channel is wire-aware and honors asymmetric fwd/bwd
    dtypes: forward bytes come from ``act_bytes``/``act_elems`` and
    backward bytes from ``grad_bytes``/``grad_elems`` independently, so
    a bf16-fwd/f32-bwd cut is never double-counted at a shared width —
    matching the DES transfer sizes (``MO``/``MG``) term for term."""
    stack = as_layerstack(model)
    metas = stack.cut_meta()
    inp = sum(b * sample_bytes for b, w in
              ((sched.b_o, sched.worker_o), (sched.b_s, sched.worker_s),
               (sched.b_l, sched.worker_l)) if w != origin)
    act = 0.0
    if sched.m_s > 0 and sched.b_s > 0 and sched.worker_s != sched.worker_o:
        m = metas[sched.m_s - 1]
        act += sched.b_s * (wire_act_bytes(m, wire) +
                            wire_grad_bytes(m, wire))
    if sched.m_l > 0 and sched.b_l > 0 and sched.worker_l != sched.worker_o:
        m = metas[sched.m_l - 1]
        act += sched.b_l * (wire_act_bytes(m, wire) +
                            wire_grad_bytes(m, wire))
    wg = 0.0
    if sched.b_s > 0 and sched.worker_s != sched.worker_o:
        wg += 2.0 * sum(m.resolved_param_bytes for m in metas[:sched.m_s])
    if sched.b_l > 0 and sched.worker_l != sched.worker_o:
        wg += 2.0 * sum(m.resolved_param_bytes for m in metas[:sched.m_l])
    return TrafficReport(inp, act, wg)
