"""Model-agnostic ``LayerStack`` adapter protocol (DESIGN.md §8).

The HierTrain pipeline — profiling stage, Algorithm-1 scheduler, hybrid
execution engine, DES and train loops — schedules a *generic* ordered chain
of cut-points, but the seed implementation was hard-wired to
:class:`repro.models.cnn.LayeredModel`.  This module is the seam that opens
the core to any layered model:

* :class:`CutMeta` — the per-cut-point quantities the profiling stage needs
  (``flops_fwd`` / ``flops_bwd`` / ``param_count`` / ``param_bytes`` /
  ``act_bytes`` / ``grad_bytes``, all *per sample* where applicable).
* :class:`LayerStack` — the execution + metadata protocol: ``init`` /
  ``apply_segment`` / ``sum_loss`` over a params *list with one entry per
  cut-point* (slicing ``params[:m]`` is what hands a TASK-S/L worker its
  frontend copy).
* :class:`CnnLayerStack` — the CNN adapter.  It delegates every operation
  to the wrapped :class:`~repro.models.cnn.LayeredModel` unchanged, so the
  traced programs, profiles and schedules of the legacy path are preserved
  **bit-for-bit** (the adapter-equivalence suite asserts ``==``).
* :func:`as_layerstack` — coercion used at every core entry point, so
  existing call sites that pass a bare ``LayeredModel`` keep working.

The second implementation — the LM model-zoo adapter over
``build_model(LMConfig)`` block stacks — lives in
:mod:`repro.models.lm.layerstack`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import LayeredModel

Params = List[Any]   # one pytree per cut-point


@dataclasses.dataclass(frozen=True)
class CutMeta:
    """Profiling-stage metadata of one cut-point (paper §III).

    ``flops_fwd`` and the wire sizes are per *sample*; ``param_count`` /
    ``param_bytes`` are absolute.  Two fields are optional with
    model-family defaults:

    * ``flops_bwd`` — ``None`` means "derive from the profiler's
      ``bwd_fwd_ratio``" (the seed CNN behaviour, kept so CNN profiles stay
      bitwise identical: the profiler then evaluates the exact historical
      expression ``ratio * flops_fwd / flops_per_sec + overhead``).
    * ``grad_bytes`` — backward wire bytes at this cut (the activation
      gradient shipped from worker_o back to a TASK-S/L worker).  ``None``
      means "equal to ``act_bytes``", the paper's §IV-C assumption.  LM
      stacks override it: bf16 activations go forward but f32 gradients
      come back.

    ``act_elems`` / ``grad_elems`` are the per-sample *element counts*
    of the two crossing tensors — what wire compression operates on
    (``repro.core.wire``): an int8 wire ships ``elems + 4`` bytes/sample
    regardless of the source dtype, so the fwd and bwd directions must
    be counted from their own dtypes, not a shared one.  ``None`` means
    "f32 payload" (the seed CNN behaviour): ``bytes / 4``.
    """
    name: str
    param_count: int
    flops_fwd: float
    act_bytes: float
    flops_bwd: Optional[float] = None
    param_bytes: Optional[float] = None
    grad_bytes: Optional[float] = None
    act_elems: Optional[float] = None
    grad_elems: Optional[float] = None

    @property
    def resolved_param_bytes(self) -> float:
        return 4.0 * self.param_count if self.param_bytes is None \
            else float(self.param_bytes)

    @property
    def resolved_grad_bytes(self) -> float:
        return float(self.act_bytes) if self.grad_bytes is None \
            else float(self.grad_bytes)

    @property
    def resolved_act_elems(self) -> float:
        return float(self.act_bytes) / 4.0 if self.act_elems is None \
            else float(self.act_elems)

    @property
    def resolved_grad_elems(self) -> float:
        return self.resolved_grad_bytes / 4.0 if self.grad_elems is None \
            else float(self.grad_elems)


class LayerStack:
    """Protocol every schedulable model adapter implements.

    A stack is an ordered chain of ``num_layers`` cut-points.  ``params``
    is always a Python list with exactly one (arbitrary pytree) entry per
    cut-point, so the hybrid engine can slice frontend copies
    (``params[:m_s]``) and aggregate per-cut gradients.

    Subclasses must provide:

    * ``name`` — attribute or property; used in profiles and logs.
    * :meth:`cut_meta` — one :class:`CutMeta` per cut-point.
    * :meth:`init` — ``key -> params`` list.
    * :meth:`apply_segment` — run cut-points ``start..stop-1`` on batch
      ``x`` (``params`` is the *full* list, indexed absolutely).
    * :meth:`sum_loss` — per-sample-**sum** training loss of the final
      segment output (the hybrid engine divides by the global batch once,
      which is what makes the distributed update exactly batch-B SGD).
    * :meth:`default_sample_bytes` — bytes of one training sample
      (input + label), the profile's ``Q``.
    * :meth:`dummy_batch` — a ``(x, labels)`` batch for measurement /
      smoke paths.
    """

    name: str = "layerstack"

    @property
    def num_layers(self) -> int:
        return len(self.cut_meta())

    def cut_meta(self) -> List[CutMeta]:
        raise NotImplementedError

    def default_sample_bytes(self) -> float:
        raise NotImplementedError

    def init(self, key: jax.Array) -> Params:
        raise NotImplementedError

    def apply_segment(self, params: Params, x: jax.Array, start: int,
                      stop: int) -> jax.Array:
        raise NotImplementedError

    def sum_loss(self, out: jax.Array, labels: jax.Array) -> jax.Array:
        raise NotImplementedError

    def dummy_batch(self, key: jax.Array, batch: int
                    ) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    # ---- conveniences shared by every adapter --------------------------

    def apply(self, params: Params, x: jax.Array) -> jax.Array:
        return self.apply_segment(params, x, 0, self.num_layers)

    def meta_arrays(self) -> dict:
        """``{names, MP, MO, MG}`` profile columns from :meth:`cut_meta`."""
        metas = self.cut_meta()
        return {
            "names": tuple(m.name for m in metas),
            "MP": np.array([m.resolved_param_bytes for m in metas],
                           np.float64),
            "MO": np.array([float(m.act_bytes) for m in metas], np.float64),
            "MG": np.array([m.resolved_grad_bytes for m in metas],
                           np.float64),
        }


@dataclasses.dataclass
class CnnLayerStack(LayerStack):
    """The paper's layered CNNs behind the :class:`LayerStack` protocol.

    Every method delegates to the wrapped :class:`LayeredModel`, producing
    the identical traced program / metadata the pre-adapter code produced
    (``grad_bytes`` defaults to ``act_bytes`` and ``flops_bwd`` to the
    profiler ratio, so profiles are bitwise unchanged).
    """
    model: LayeredModel

    @property
    def name(self) -> str:                        # type: ignore[override]
        return self.model.name

    @property
    def num_layers(self) -> int:
        return self.model.num_layers

    def cut_meta(self) -> List[CutMeta]:
        return [CutMeta(name=m.name, param_count=m.param_count,
                        flops_fwd=float(m.flops_fwd),
                        act_bytes=float(m.out_bytes),
                        param_bytes=float(m.param_bytes))
                for m in self.model.layer_meta()]

    def default_sample_bytes(self) -> float:
        # raw uint8 image + int label (the seed profiler's default)
        return float(np.prod(self.model.input_shape)) + 4.0

    def init(self, key: jax.Array) -> Params:
        return self.model.init(key)

    def apply_segment(self, params: Params, x: jax.Array, start: int,
                      stop: int) -> jax.Array:
        return self.model.apply_segment(params, x, start, stop)

    def sum_loss(self, logits: jax.Array, labels: jax.Array) -> jax.Array:
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.sum(jnp.take_along_axis(logp, labels[:, None], axis=-1))

    def dummy_batch(self, key: jax.Array, batch: int
                    ) -> Tuple[jax.Array, jax.Array]:
        kx, ky = jax.random.split(key)
        x = jax.random.normal(kx, (batch,) + self.model.input_shape,
                              jnp.float32)
        y = jax.random.randint(ky, (batch,), 0, self.model.num_classes)
        return x, y


def as_layerstack(model: Any) -> LayerStack:
    """Coerce a model to the :class:`LayerStack` protocol.

    Accepts an adapter as-is, wraps a bare :class:`LayeredModel` (so legacy
    call sites keep working), and rejects anything else loudly.
    """
    if isinstance(model, LayerStack):
        return model
    if isinstance(model, LayeredModel):
        return CnnLayerStack(model)
    raise TypeError(
        f"{type(model).__name__} does not implement the LayerStack "
        f"protocol (and is not a LayeredModel); see repro/core/layerstack.py")
