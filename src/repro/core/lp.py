"""Dense two-phase simplex LP solver (no scipy in this environment).

Solves::

    minimize    c @ x
    subject to  A_ub @ x <= b_ub
                A_eq @ x == b_eq
                x >= 0

Sizes here are tiny (HierTrain's per-cut LP has ~7 variables and ~12
constraints), so a dense tableau simplex with Bland's anti-cycling rule is
plenty. Exposed as :func:`linprog` with a scipy-like result object.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

# Shared pivot/feasibility tolerance.  The batched engine
# (:mod:`repro.core.batched_lp`) imports this so both backends make
# identical accept/reject decisions at every pivot.
EPS = 1e-9
_EPS = EPS


@dataclasses.dataclass
class LPResult:
    x: Optional[np.ndarray]
    fun: float
    success: bool
    status: str  # "optimal" | "infeasible" | "unbounded" | "iteration_limit"


def _pivot(T: np.ndarray, basis: np.ndarray, row: int, col: int) -> None:
    T[row] /= T[row, col]
    for r in range(T.shape[0]):
        if r != row and abs(T[r, col]) > _EPS:
            T[r] -= T[r, col] * T[row]
    basis[row] = col


def _simplex(T: np.ndarray, basis: np.ndarray, n_vars: int,
             max_iter: int = 10_000) -> str:
    """Run primal simplex on tableau ``T`` (last row = objective, last col = rhs).

    Bland's rule: entering = lowest-index negative reduced cost; leaving =
    lowest-index argmin ratio. Guarantees termination.
    """
    m = T.shape[0] - 1
    for _ in range(max_iter):
        # Entering variable (Bland): first column with negative reduced cost.
        col = -1
        for j in range(n_vars):
            if T[-1, j] < -_EPS:
                col = j
                break
        if col < 0:
            return "optimal"
        # Leaving variable: min ratio test.
        best_ratio, row = np.inf, -1
        for i in range(m):
            if T[i, col] > _EPS:
                ratio = T[i, -1] / T[i, col]
                if ratio < best_ratio - _EPS or (
                        abs(ratio - best_ratio) <= _EPS and
                        (row < 0 or basis[i] < basis[row])):
                    best_ratio, row = ratio, i
        if row < 0:
            return "unbounded"
        _pivot(T, basis, row, col)
    return "iteration_limit"


def linprog(c: np.ndarray,
            A_ub: Optional[np.ndarray] = None,
            b_ub: Optional[np.ndarray] = None,
            A_eq: Optional[np.ndarray] = None,
            b_eq: Optional[np.ndarray] = None) -> LPResult:
    """Two-phase simplex. All variables are implicitly >= 0."""
    c = np.asarray(c, dtype=np.float64)
    n = c.shape[0]
    A_ub = np.zeros((0, n)) if A_ub is None else np.asarray(A_ub, np.float64)
    b_ub = np.zeros((0,)) if b_ub is None else np.asarray(b_ub, np.float64)
    A_eq = np.zeros((0, n)) if A_eq is None else np.asarray(A_eq, np.float64)
    b_eq = np.zeros((0,)) if b_eq is None else np.asarray(b_eq, np.float64)

    m_ub, m_eq = A_ub.shape[0], A_eq.shape[0]
    m = m_ub + m_eq

    # Standard form: [A_ub | I_slack] x = b_ub ; A_eq x = b_eq; rhs >= 0.
    A = np.zeros((m, n + m_ub))
    b = np.concatenate([b_ub, b_eq])
    A[:m_ub, :n] = A_ub
    A[:m_ub, n:n + m_ub] = np.eye(m_ub)
    A[m_ub:, :n] = A_eq
    # Flip rows with negative rhs so artificials can start feasible.
    neg = b < 0
    A[neg] *= -1.0
    b = np.abs(b)

    n_total = n + m_ub
    # Phase 1: add artificial variables for every row, minimize their sum.
    T = np.zeros((m + 1, n_total + m + 1))
    T[:m, :n_total] = A
    T[:m, n_total:n_total + m] = np.eye(m)
    T[:m, -1] = b
    T[-1, n_total:n_total + m] = 1.0
    basis = np.arange(n_total, n_total + m)
    # Price out artificials.
    for i in range(m):
        T[-1] -= T[i]
    status = _simplex(T, basis, n_total + m)
    if status != "optimal" or T[-1, -1] < -1e-7:
        return LPResult(None, np.inf, False,
                        "infeasible" if status == "optimal" else status)

    # Drive remaining artificials out of the basis if possible.
    for i in range(m):
        if basis[i] >= n_total:
            for j in range(n_total):
                if abs(T[i, j]) > _EPS:
                    _pivot(T, basis, i, j)
                    break

    # Phase 2: restore the real objective over the phase-1 optimal basis.
    T2 = np.zeros((m + 1, n_total + 1))
    T2[:m, :n_total] = T[:m, :n_total]
    T2[:m, -1] = T[:m, -1]
    T2[-1, :n] = c
    for i in range(m):
        if basis[i] < n_total and abs(T2[-1, basis[i]]) > _EPS:
            T2[-1] -= T2[-1, basis[i]] * T2[i]
    status = _simplex(T2, basis, n_total)
    if status != "optimal":
        return LPResult(None, -np.inf if status == "unbounded" else np.inf,
                        False, status)

    x = np.zeros(n_total)
    for i in range(m):
        if basis[i] < n_total:
            x[basis[i]] = T2[i, -1]
    return LPResult(x[:n], float(c @ x[:n]), True, "optimal")


def solve_many(c: np.ndarray,
               A_ub: np.ndarray, b_ub: np.ndarray,
               A_eq: np.ndarray, b_eq: np.ndarray) -> List[LPResult]:
    """Solve a stack of identically-shaped LPs one by one.

    Same call signature as :func:`repro.core.batched_lp.linprog_batch`
    (``A_ub``: ``[K, m_ub, n]`` etc., ``c`` shared or ``[K, n]``); used as
    the reference oracle in equivalence tests and benchmarks.
    """
    A_ub = np.asarray(A_ub, np.float64)
    K = A_ub.shape[0]
    c = np.broadcast_to(np.asarray(c, np.float64), (K, A_ub.shape[2]))
    return [linprog(c[k], A_ub[k], b_ub[k], A_eq[k], b_eq[k])
            for k in range(K)]
