"""Steady-state pipelined-execution cost model — ``T_period`` (DESIGN.md §7).

The per-iteration model (Eq. 12, :mod:`repro.core.cost_model`) scores one
minibatch in isolation.  When consecutive minibatches are pipelined
(:func:`repro.core.simulator.simulate_pipeline`), the wall-clock of a
depth-K run is ``T(K) = T_fill + (K - 1) * T_period``: after the first
iteration fills the pipe, every further iteration costs one steady-state
*period*.  The period is the max of two families of lower bounds, both of
which the DES empirically attains:

* **Busy-time arms** — each worker CPU and each directed link pipe (plus,
  on the star topology, the per-device TC input-class pipes and the shared
  input backhaul) executes its per-iteration workload once per period, so
  per-resource busy time bounds the period (the classic pipeline
  bottleneck bound).
* **Recurrence bound** — synchronous SGD adds one lag edge per worker:
  iteration-k forwards wait on that worker's iteration-(k-1) weight
  update.  The per-iteration task DAG plus these lag edges is a marked
  event graph whose steady-state period is its maximum cycle mean — the
  max-plus eigenvalue of the iteration-to-iteration completion-time
  recurrence.  We estimate it by vectorized power iteration over the
  fixed task topology (the graph is tiny — ~20 nodes — so the transient
  dies out in a handful of steps): exact whenever the critical cycle's
  cyclicity divides the averaging window (every divisor of ``_WINDOW``;
  always observed on measured schedules) and within
  ``O(intra-cycle variation / _WINDOW)`` otherwise.  On round-trip-heavy
  schedules this bound, not any single resource, sets the period — which
  is exactly why throughput-optimal schedules cut differently than
  latency-optimal ones (DESIGN.md §7).

Input transfers are prefetchable (no lag edges), so they appear in the
busy arms but not in the recurrence.

Scalar entry points evaluate the batched kernels at K = 1, so scalar and
batched results are bit-identical by construction and the throughput
scheduler's batched argmin reproduces the reference scheduler's
sequential min exactly.  The M-device forms mirror the three-worker forms
operation-for-operation (catch-up terms are exactly ``+0.0`` at M = 1).
"""
from __future__ import annotations

from typing import Dict, Tuple, Union

import numpy as np

from repro.core.cost_model import (WIDX, WORKERS, HierProfile, MultiProfile,
                                   MultiSchedule, Network, Schedule,
                                   StarNetwork, _t_total, _t_total_multi,
                                   bw_matrix)

# Power-iteration horizon for the max-plus eigenvalue: ``_UNFOLD`` steps,
# slope averaged over the last ``_WINDOW``.  The estimate is exact when
# the critical cycle's cyclicity (its number of lag edges — up to M + 2
# on the star graph) divides the window; 60's divisors cover 1-6, 10,
# 12, 15, 20, 30, 60, and any other cyclicity leaves a residual bounded
# by (intra-cycle variation) / 60.
_UNFOLD = 128
_WINDOW = 60


def _maxplus_period_3w(d: Dict[str, np.ndarray]) -> np.ndarray:
    """Max cycle mean of the 3-worker iteration graph, per lane.

    ``d`` maps task name -> per-lane duration ``[K]``.  Runs the
    completion-time recurrence (one lag edge per worker: ``u_* -> f_*``)
    and returns the asymptotic slope of the makespan.
    """
    z = np.zeros_like(d["f_o1"])
    u_o, u_s, u_l = z, z, z
    m_hist = []
    for _ in range(_UNFOLD):
        f_s = u_s + d["f_s"]
        act_s = f_s + d["act_s"]
        f_l = u_l + d["f_l"]
        act_l = f_l + d["act_l"]
        f_o1 = u_o + d["f_o1"]
        f_o2 = np.maximum(f_o1, act_s) + d["f_o2"]
        f_o3 = np.maximum(f_o2, act_l) + d["f_o3"]
        b_o3 = f_o3 + d["b_o3"]
        gact_l = b_o3 + d["gact_l"]
        b_l = gact_l + d["b_l"]
        b_o2 = b_o3 + d["b_o2"]
        gact_s = b_o2 + d["gact_s"]
        b_s = gact_s + d["b_s"]
        b_o1 = b_o2 + d["b_o1"]
        wg_s_up = b_s + d["wg_s"]
        wg_l_up = b_l + d["wg_l"]
        wg_s_down = np.maximum(wg_s_up, b_o1) + d["wg_s"]
        wg_l_down = np.maximum(wg_l_up, b_o1) + d["wg_l"]
        u_o = np.maximum(np.maximum(b_o1, wg_s_up), wg_l_up) + d["u_o"]
        u_s = wg_s_down + d["u_s"]
        u_l = wg_l_down + d["u_l"]
        m_hist.append(np.maximum(np.maximum(u_o, u_s), u_l))
    return (m_hist[-1] - m_hist[-1 - _WINDOW]) / _WINDOW


def _period_parts(profile: HierProfile, net: Network, o_idx: np.ndarray,
                  s_idx: np.ndarray, l_idx: np.ndarray, ms: np.ndarray,
                  ml: np.ndarray, b: np.ndarray, origin: str
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-lane ``(cpu busy [K,3], link busy [K,3,3], recurrence [K])``."""
    N = profile.num_layers
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    bwm = bw_matrix(net)
    oi = WIDX[origin]
    Q = profile.sample_bytes
    K = o_idx.shape[0]
    ar = np.arange(K)
    bo = np.asarray(b[:, 0], np.float64)
    bs = np.asarray(b[:, 1], np.float64)
    bl = np.asarray(b[:, 2], np.float64)
    B = bo + bs + bl

    bw_os = bwm[o_idx, s_idx]
    bw_ol = bwm[o_idx, l_idx]

    def t_in(w_idx: np.ndarray, bb: np.ndarray) -> np.ndarray:
        return np.where((bb == 0) | (w_idx == oi), 0.0,
                        bb * Q / bwm[oi, w_idx])

    in_o, in_s, in_l = t_in(o_idx, bo), t_in(s_idx, bs), t_in(l_idx, bl)
    mo_s = profile.MO[np.maximum(ms, 1) - 1]
    mo_l = profile.MO[np.maximum(ml, 1) - 1]
    mg_s = profile.MG[np.maximum(ms, 1) - 1]
    mg_l = profile.MG[np.maximum(ml, 1) - 1]
    d = {
        "act_s": np.where((ms > 0) & (bs > 0), bs * mo_s / bw_os, 0.0),
        "act_l": np.where((ml > 0) & (bl > 0), bl * mo_l / bw_ol, 0.0),
        "gact_s": np.where((ms > 0) & (bs > 0), bs * mg_s / bw_os, 0.0),
        "gact_l": np.where((ml > 0) & (bl > 0), bl * mg_l / bw_ol, 0.0),
        "wg_s": np.where(bs > 0, MPc[ms] / bw_os, 0.0),   # one-way leg
        "wg_l": np.where(bl > 0, MPc[ml] / bw_ol, 0.0),
        "f_s": bs * F[s_idx, ms],
        "b_s": bs * Bk[s_idx, ms],
        "u_s": np.where(bs > 0, U[s_idx, ms], 0.0),
        "f_l": bl * F[l_idx, ml],
        "b_l": bl * Bk[l_idx, ml],
        "u_l": np.where(bl > 0, U[l_idx, ml], 0.0),
        "f_o1": bo * F[o_idx, ms],
        "f_o2": (bo + bs) * (F[o_idx, ml] - F[o_idx, ms]),
        "f_o3": B * (F[o_idx, N] - F[o_idx, ml]),
        "b_o3": B * (Bk[o_idx, N] - Bk[o_idx, ml]),
        "b_o2": (bo + bs) * (Bk[o_idx, ml] - Bk[o_idx, ms]),
        "b_o1": bo * Bk[o_idx, ms],
        "u_o": np.broadcast_to(U[o_idx, N], (K,)).astype(np.float64),
    }

    cpu = np.zeros((K, 3))
    np.add.at(cpu, (ar, o_idx), d["f_o1"] + d["f_o2"] + d["f_o3"] +
              d["b_o3"] + d["b_o2"] + d["b_o1"] + d["u_o"])
    np.add.at(cpu, (ar, s_idx), d["f_s"] + d["b_s"] + d["u_s"])
    np.add.at(cpu, (ar, l_idx), d["f_l"] + d["b_l"] + d["u_l"])
    link = np.zeros((K, 3, 3))
    np.add.at(link, (ar, oi, o_idx), in_o)
    np.add.at(link, (ar, oi, s_idx), in_s)
    np.add.at(link, (ar, oi, l_idx), in_l)
    np.add.at(link, (ar, s_idx, o_idx), d["act_s"] + d["wg_s"])
    np.add.at(link, (ar, o_idx, s_idx), d["gact_s"] + d["wg_s"])
    np.add.at(link, (ar, l_idx, o_idx), d["act_l"] + d["wg_l"])
    np.add.at(link, (ar, o_idx, l_idx), d["gact_l"] + d["wg_l"])
    return cpu, link, _maxplus_period_3w(d)


def t_period_batch(profile: HierProfile, net: Network,
                   o_idx: np.ndarray, s_idx: np.ndarray, l_idx: np.ndarray,
                   ms: np.ndarray, ml: np.ndarray, b: np.ndarray,
                   origin: str = "device") -> np.ndarray:
    """Vectorized steady-state period over K candidate schedules (same
    index conventions as :func:`repro.core.cost_model.t_total_batch`)."""
    cpu, link, rec = _period_parts(profile, net, o_idx, s_idx, l_idx, ms,
                                   ml, b, origin)
    return np.maximum(np.maximum(cpu.max(axis=1), link.max(axis=(1, 2))),
                      rec)


def _lane(sched: Schedule) -> Tuple[np.ndarray, ...]:
    return (np.array([WIDX[sched.worker_o]]),
            np.array([WIDX[sched.worker_s]]),
            np.array([WIDX[sched.worker_l]]),
            np.array([sched.m_s]), np.array([sched.m_l]),
            np.array([[sched.b_o, sched.b_s, sched.b_l]]))


def t_period(profile: HierProfile, net: Network, sched: Schedule,
             origin: str = "device") -> float:
    """Steady-state seconds per iteration of the pipelined schedule."""
    o_idx, s_idx, l_idx, ms, ml, b = _lane(sched)
    return float(t_period_batch(profile, net, o_idx, s_idx, l_idx, ms, ml,
                                b, origin)[0])


def t_period_breakdown(profile: HierProfile, net: Network, sched: Schedule,
                       origin: str = "device") -> Dict[str, object]:
    """Diagnostics: every period arm plus the binding one."""
    o_idx, s_idx, l_idx, ms, ml, b = _lane(sched)
    cpu, link, rec = _period_parts(profile, net, o_idx, s_idx, l_idx, ms,
                                   ml, b, origin)
    arms = {f"cpu:{WORKERS[i]}": float(cpu[0, i]) for i in range(3)
            if cpu[0, i] > 0.0}
    for a in range(3):
        for c in range(3):
            if link[0, a, c] > 0.0:
                arms[f"link:{WORKERS[a]}->{WORKERS[c]}"] = \
                    float(link[0, a, c])
    arms["recurrence"] = float(rec[0])
    period = max(arms.values())
    bottleneck = max(arms, key=lambda k: arms[k])
    return {"period": period, "bottleneck": bottleneck, "arms": arms}


# ---------------------------------------------------------------------------
# M-device star topology (DESIGN.md §6 + §7).
# ---------------------------------------------------------------------------


def _maxplus_period_multi(d: Dict[str, np.ndarray]) -> np.ndarray:
    """Max cycle mean of the M-device iteration graph, per lane.

    Stream-indexed durations (``f_s``, ``act_s``, ``b_s``, ``u_s``,
    ``wg_s``) are ``[K, M]``; the rest ``[K]``.  At M = 1 the recurrence
    is the three-worker one operation-for-operation.
    """
    z = np.zeros_like(d["f_o1"])
    u_o, u_l = z, z
    u_s = np.zeros_like(d["f_s"])
    m_hist = []
    for _ in range(_UNFOLD):
        f_s = u_s + d["f_s"]
        act_s = f_s + d["act_s"]
        f_l = u_l + d["f_l"]
        act_l = f_l + d["act_l"]
        f_o1 = u_o + d["f_o1"]
        f_o2 = np.maximum(f_o1, act_s.max(axis=1)) + d["f_o2"]
        f_o3 = np.maximum(f_o2, act_l) + d["f_o3"]
        b_o3 = f_o3 + d["b_o3"]
        gact_l = b_o3 + d["gact_l"]
        b_l = gact_l + d["b_l"]
        b_o2 = b_o3 + d["b_o2"]
        gact_s = b_o2[:, None] + d["gact_s"]
        b_s = gact_s + d["b_s"]
        b_o1 = b_o2 + d["b_o1"]
        wg_s_up = b_s + d["wg_s"]
        wg_l_up = b_l + d["wg_l"]
        wg_s_down = np.maximum(wg_s_up, b_o1[:, None]) + d["wg_s"]
        wg_l_down = np.maximum(wg_l_up, b_o1) + d["wg_l"]
        u_o = np.maximum(np.maximum(b_o1, wg_s_up.max(axis=1)),
                         wg_l_up) + d["u_o"]
        u_s = wg_s_down + d["u_s"]
        u_l = wg_l_down + d["u_l"]
        m_hist.append(np.maximum(np.maximum(u_o, u_s.max(axis=1)), u_l))
    return (m_hist[-1] - m_hist[-1 - _WINDOW]) / _WINDOW


def _period_parts_multi(profile: MultiProfile, net: StarNetwork,
                        o_idx: np.ndarray, s_idx: np.ndarray,
                        l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                        b: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray, np.ndarray]:
    """Per-lane ``(cpu [K,W], link [K,W,W], in_de [K,M,E+1],
    in_ec [K,E], in_fx [K,E,E], in_cd [K,E], recurrence [K])`` for the
    star/tree topologies: ``in_de`` is the per-device radio busy time
    per input class (one per destination edge plus ``->cloud``);
    ``in_ec`` the per-edge uplink cloud classes (``edge_e->cloud``);
    ``in_fx`` the per-edge uplink foreign-relay classes
    (``edge_e->cloud:edge_k``) and ``in_cd`` the cloud downlink classes
    (``cloud->edge_e``), both only used by foreign-edge relays and
    identically zero at E=1 where ``in_ec[:, 0]`` is the star's shared
    input backhaul."""
    N = profile.num_layers
    M = profile.num_devices       # data holders (locality), not streams
    S = profile.num_streams
    W = profile.num_workers
    E = net.num_edges
    edge_of = np.asarray(net.edge_of)
    backhaul = net.backhaul
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    bwm = net.bw_matrix()
    Q = profile.sample_bytes
    K = o_idx.shape[0]
    ar = np.arange(K)
    bo = np.asarray(b[:, 0], np.float64)
    bs = np.asarray(b[:, 1:1 + S], np.float64)
    bl = np.asarray(b[:, 1 + S], np.float64)
    o2 = o_idx[:, None]
    msmax = ms.max(axis=1)

    bw_os = bwm[o2, s_idx]                                # [K, S]
    bw_ol = bwm[o_idx, l_idx]
    mo_s = profile.MO[np.maximum(ms, 1) - 1]
    mo_l = profile.MO[np.maximum(ml, 1) - 1]
    mg_s = profile.MG[np.maximum(ms, 1) - 1]
    mg_l = profile.MG[np.maximum(ml, 1) - 1]
    bs_sum = bs.sum(axis=1)
    B = bo + bs_sum + bl
    catch_f = (bs * (F[o2, msmax[:, None]] - F[o2, ms])).sum(axis=1)
    catch_b = (bs * (Bk[o2, msmax[:, None]] - Bk[o2, ms])).sum(axis=1)
    d = {
        "act_s": np.where((ms > 0) & (bs > 0), bs * mo_s / bw_os, 0.0),
        "act_l": np.where((ml > 0) & (bl > 0), bl * mo_l / bw_ol, 0.0),
        "gact_s": np.where((ms > 0) & (bs > 0), bs * mg_s / bw_os, 0.0),
        "gact_l": np.where((ml > 0) & (bl > 0), bl * mg_l / bw_ol, 0.0),
        "wg_s": np.where(bs > 0, MPc[ms] / bw_os, 0.0),   # one-way leg
        "wg_l": np.where(bl > 0, MPc[ml] / bw_ol, 0.0),
        "f_s": bs * F[s_idx, ms],
        "b_s": bs * Bk[s_idx, ms],
        "u_s": np.where(bs > 0, U[s_idx, ms], 0.0),
        "f_l": bl * F[l_idx, ml],
        "b_l": bl * Bk[l_idx, ml],
        "u_l": np.where(bl > 0, U[l_idx, ml], 0.0),
        "f_o1": bo * F[o_idx, msmax],
        "f_o2": (bo + bs_sum) * (F[o_idx, ml] - F[o_idx, msmax]) + catch_f,
        "f_o3": B * (F[o_idx, N] - F[o_idx, ml]),
        "b_o3": B * (Bk[o_idx, N] - Bk[o_idx, ml]),
        "b_o2": (bo + bs_sum) * (Bk[o_idx, ml] - Bk[o_idx, msmax]) +
                catch_b,
        "b_o1": bo * Bk[o_idx, msmax],
        "u_o": np.broadcast_to(U[o_idx, N], (K,)).astype(np.float64),
    }

    cpu = np.zeros((K, W))
    np.add.at(cpu, (ar, o_idx), d["f_o1"] + d["f_o2"] + d["f_o3"] +
              d["b_o3"] + d["b_o2"] + d["b_o1"] + d["u_o"])
    for i in range(S):
        np.add.at(cpu, (ar, s_idx[:, i]),
                  d["f_s"][:, i] + d["b_s"][:, i] + d["u_s"][:, i])
    np.add.at(cpu, (ar, l_idx), d["f_l"] + d["b_l"] + d["u_l"])
    link = np.zeros((K, W, W))
    for i in range(S):
        np.add.at(link, (ar, s_idx[:, i], o_idx),
                  d["act_s"][:, i] + d["wg_s"][:, i])
        np.add.at(link, (ar, o_idx, s_idx[:, i]),
                  d["gact_s"][:, i] + d["wg_s"][:, i])
    np.add.at(link, (ar, l_idx, o_idx), d["act_l"] + d["wg_l"])
    np.add.at(link, (ar, o_idx, l_idx), d["gact_l"] + d["wg_l"])

    # TC input-class pipes: device j's radio carries a ``b/M`` chunk of
    # every edge- or cloud-resident task's sub-batch, one shaped class per
    # (device, destination) pair — matching the simulator; cloud- and
    # foreign-edge-bound chunks then serialize on the sender's edge
    # uplink backhaul (upload order o, s_i..., l — matching the
    # simulator's task-add order), and foreign-edge chunks additionally
    # on the destination edge's cloud downlink.
    in_de = np.zeros((K, M, E + 1))    # [..., e] ->edge_e, [..., E] ->cloud
    in_ec = np.zeros((K, E))           # uplink class edge_e -> cloud
    in_fx = np.zeros((K, E, E))        # uplink class edge_e -> foreign edge
    in_cd = np.zeros((K, E))           # downlink cloud -> edge_e
    counts = np.bincount(edge_of, minlength=E).astype(np.float64)

    def ingest(w_idx: np.ndarray, bb: np.ndarray) -> None:
        chunk = np.where((w_idx < M) | (bb == 0), 0.0, bb * Q / M)
        cloud_c = np.where(w_idx == W - 1, chunk, 0.0)
        edge_c = [np.where(w_idx == M + e, chunk, 0.0) for e in range(E)]
        for j in range(M):
            for e in range(E):
                in_de[:, j, e] += edge_c[e] / net.bw_de[j]
            in_de[:, j, E] += cloud_c / net.bw_de[j]
        for e in range(E):
            # edge e's devices relay cloud-bound chunks over edge e's
            # uplink cloud class; at E=1 this is the star's
            # ``M * (cloud_c / bw_ec)`` term bit-for-bit.  Foreign-edge
            # chunks ride their own per-destination uplink class and the
            # destination's downlink class (both absent at E=1),
            # matching the simulator's shaped pipes.
            in_ec[:, e] += counts[e] * (cloud_c / backhaul[e])
            for e2 in range(E):
                if e2 != e:
                    in_fx[:, e, e2] += counts[e] * (edge_c[e2] /
                                                    backhaul[e])
            if M - counts[e] > 0:
                in_cd[:, e] += (M - counts[e]) * (edge_c[e] / backhaul[e])

    ingest(o_idx, bo)
    for i in range(S):
        ingest(s_idx[:, i], bs[:, i])
    ingest(l_idx, bl)

    return cpu, link, in_de, in_ec, in_fx, in_cd, _maxplus_period_multi(d)


def t_period_multi_batch(profile: MultiProfile, net: StarNetwork,
                         o_idx: np.ndarray, s_idx: np.ndarray,
                         l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                         b: np.ndarray) -> np.ndarray:
    """Vectorized M-device steady-state period over K candidates (same
    index conventions as
    :func:`repro.core.cost_model.t_total_multi_batch`)."""
    cpu, link, in_de, in_ec, in_fx, in_cd, rec = _period_parts_multi(
        profile, net, o_idx, s_idx, l_idx, ms, ml, b)
    busy = np.maximum(np.maximum(cpu.max(axis=1), link.max(axis=(1, 2))),
                      np.maximum(in_de.max(axis=(1, 2)),
                                 np.maximum(in_ec.max(axis=1),
                                            np.maximum(in_fx.max(axis=(1, 2)),
                                                       in_cd.max(axis=1)))))
    return np.maximum(busy, rec)


def _lane_multi(profile: MultiProfile,
                sched: MultiSchedule) -> Tuple[np.ndarray, ...]:
    widx = profile.widx
    return (np.array([widx[sched.worker_o]]),
            np.array([[widx[w] for w in sched.s_workers]]),
            np.array([widx[sched.worker_l]]),
            np.array([list(sched.m_s)]), np.array([sched.m_l]),
            np.array([[sched.b_o, *sched.b_s, sched.b_l]]))


def t_period_multi(profile: MultiProfile, net: StarNetwork,
                   sched: MultiSchedule) -> float:
    """Steady-state period of an M-device pipelined schedule."""
    o_idx, s_idx, l_idx, ms, ml, b = _lane_multi(profile, sched)
    return float(t_period_multi_batch(profile, net, o_idx, s_idx, l_idx,
                                      ms, ml, b)[0])


def t_period_tree(profile: MultiProfile, net: StarNetwork,
                  sched: MultiSchedule) -> float:
    """Steady-state period of a two-level tree pipelined schedule.

    Accepts a :class:`TreeProfile`/:class:`TreeNetwork` pair (the
    star-shaped arguments also work — a star is the E=1 tree); at E=1
    the result is bit-identical to :func:`t_period_multi`."""
    return t_period_multi(profile, net, sched)


# ---------------------------------------------------------------------------
# Depth-K wall clock.
# ---------------------------------------------------------------------------


def t_pipeline(profile: Union[HierProfile, MultiProfile],
               net: Union[Network, StarNetwork],
               sched: Union[Schedule, MultiSchedule], K: int,
               origin: str = "device") -> float:
    """Model wall-clock of a depth-K pipelined run:
    ``T(K) = T_fill + (K - 1) * T_period`` with the Eq.-12 single-iteration
    latency as the fill term (DESIGN.md §7)."""
    assert K >= 1
    if isinstance(sched, MultiSchedule):
        fill = _t_total_multi(profile, net, sched).total
        return fill + (K - 1) * t_period_multi(profile, net, sched)
    fill = _t_total(profile, net, sched, origin).total
    return fill + (K - 1) * t_period(profile, net, sched, origin)
