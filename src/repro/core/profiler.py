"""HierTrain profiling stage (§III): produce ``HierProfile`` objects.

Model-agnostic since the :class:`~repro.core.layerstack.LayerStack`
refactor (DESIGN.md §8): every entry point takes *any* layer stack —
a bare :class:`repro.models.cnn.LayeredModel` (coerced through the CNN
adapter, bit-for-bit identical profiles) or an adapter such as the LM
model-zoo stack (:mod:`repro.models.lm.layerstack`).

Two profiling modes:

* :func:`analytic_profile` — derive per-layer per-worker times from the
  stack's FLOP metadata and per-worker effective throughput.  Deterministic;
  used by tests and the figure-reproduction benchmarks.
* :func:`measure_profile` — *measure* per-cut forward/backward wall time of
  the real JAX model on this host (jit + warm-up + repeat, mean of runs — the
  paper's run-time profiling), then scale to each worker by its relative
  speed.  Used by the profiling-stage benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import WORKERS, HierProfile, MultiProfile
from repro.core.layerstack import LayerStack, as_layerstack


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Effective capability of one worker tier.

    ``flops_per_sec`` — sustained throughput on this model family.
    ``overhead`` — fixed per-layer dispatch overhead (seconds).
    ``update_flops_per_param`` — optimizer cost model (SGD+momentum ~ 4).
    """
    name: str
    flops_per_sec: float
    overhead: float = 0.0
    update_flops_per_param: float = 4.0


# Defaults calibrated to the paper's §VI-B testbed: Raspberry Pi 3 (device),
# one core of an Intel NUC i3-7100U (edge), Dell T5820 + GTX 1080 Ti (cloud).
# Effective (not peak) throughputs are per-model in reality — the paper's
# profiling stage measures each model on each worker — so the benchmark
# suite carries per-model calibrations (benchmarks/common.py); this generic
# set is calibrated on LeNet-5 and reproduces the paper's headline
# 1.7x / 6.9x speedups (we measure 1.76x / 7.2x).
PAPER_TESTBED: Dict[str, WorkerSpec] = {
    "device": WorkerSpec("device", flops_per_sec=2e9, overhead=1e-4),
    "edge": WorkerSpec("edge", flops_per_sec=2e10, overhead=1e-5),
    "cloud": WorkerSpec("cloud", flops_per_sec=2e11, overhead=5e-6),
}

# AlexNet's big 11x11/5x5 convs run at lower effective FLOP/s on the
# Pi/NUC than LeNet's tiny stacks (Chainer-era im2col); calibrated so the
# HierTrain-vs-All-Edge gap matches the paper's 2.3x.
ALEXNET_TESTBED: Dict[str, WorkerSpec] = {
    "device": WorkerSpec("device", flops_per_sec=4e8, overhead=1e-4),
    "edge": WorkerSpec("edge", flops_per_sec=6e9, overhead=1e-5),
    "cloud": WorkerSpec("cloud", flops_per_sec=2e11, overhead=5e-6),
}

# Transformer blocks are MXU/NEON-friendly dense matmuls: phones and edge
# boxes sustain a far larger fraction of peak than on branchy CNN stacks.
# Calibrated for the LM-fleet benchmark: mobile NPU device tier (~0.2
# effective bf16 TFLOP/s), edge GPU box (~1), cloud accelerator (~5).
LM_TESTBED: Dict[str, WorkerSpec] = {
    "device": WorkerSpec("device", flops_per_sec=2e11, overhead=2e-4),
    "edge": WorkerSpec("edge", flops_per_sec=1e12, overhead=5e-5),
    "cloud": WorkerSpec("cloud", flops_per_sec=5e12, overhead=2e-5),
}


def analytic_profile(model, workers: Dict[str, WorkerSpec] | None = None,
                     sample_bytes: float | None = None,
                     bwd_fwd_ratio: float = 2.0) -> HierProfile:
    """Analytic profile of any :class:`LayerStack` (or ``LayeredModel``).

    Cut-points that expose an explicit ``flops_bwd`` use it; the rest fall
    back to ``bwd_fwd_ratio * flops_fwd`` evaluated in the seed's exact
    operation order, so CNN profiles stay bitwise identical.  ``MG`` comes
    from the cut-points' ``grad_bytes`` (``== act_bytes`` by default).
    """
    stack = as_layerstack(model)
    workers = workers or PAPER_TESTBED
    metas = stack.cut_meta()
    n = len(metas)
    L_f = np.zeros((3, n))
    L_b = np.zeros((3, n))
    L_u = np.zeros((3, n))
    for j, wname in enumerate(WORKERS):
        w = workers[wname]
        for i, m in enumerate(metas):
            L_f[j, i] = m.flops_fwd / w.flops_per_sec + w.overhead
            if m.flops_bwd is None:
                L_b[j, i] = bwd_fwd_ratio * m.flops_fwd / w.flops_per_sec \
                    + w.overhead
            else:
                L_b[j, i] = m.flops_bwd / w.flops_per_sec + w.overhead
            L_u[j, i] = m.param_count * w.update_flops_per_param / \
                w.flops_per_sec + w.overhead
    if sample_bytes is None:
        sample_bytes = stack.default_sample_bytes()
    cols = stack.meta_arrays()
    return HierProfile(
        layer_names=cols["names"],
        L_f=L_f, L_b=L_b, L_u=L_u,
        MP=cols["MP"], MO=cols["MO"], MG=cols["MG"],
        sample_bytes=sample_bytes,
    )


def multi_analytic_profile(model,
                           workers: Dict[str, WorkerSpec] | None = None,
                           device_slowdowns=(1.0,),
                           sample_bytes: float | None = None,
                           bwd_fwd_ratio: float = 2.0) -> MultiProfile:
    """Analytic profile for the M-device star (DESIGN.md §6).

    ``device_slowdowns[i]`` scales the profiled device tier for device *i*
    (1.0 = the testbed's reference device, 2.0 = half its speed) — the
    straggler heterogeneity knob used by ``benchmarks/fig_multidevice``.
    With the default single 1.0 entry this is exactly
    :func:`analytic_profile` lifted to the M=1 star.
    """
    return MultiProfile.from_hier(
        analytic_profile(model, workers, sample_bytes, bwd_fwd_ratio),
        device_slowdowns)


def measure_profile(model, rel_speed: Dict[str, float] | None = None,
                    batch: int = 8, repeats: int = 3,
                    sample_bytes: float | None = None) -> HierProfile:
    """Measure real per-cut fwd/bwd times on this host, scale per worker.

    ``rel_speed[worker]`` divides the measured host time (2.0 => 2x faster
    than this host).  Default calibrates this CPU as the "edge" tier.
    """
    stack = as_layerstack(model)
    rel_speed = rel_speed or {"device": 1 / 13.0, "edge": 1.0, "cloud": 11.0}
    metas = stack.cut_meta()
    key = jax.random.PRNGKey(0)
    params = stack.init(key)
    n = stack.num_layers
    host_f = np.zeros(n)
    host_b = np.zeros(n)
    x, _ = stack.dummy_batch(key, batch)
    # One-shot measurement probes: each cut's fwd/vjp is traced once,
    # timed, then dropped — re-jit per iteration is the point, not a bug.
    for i in range(n):
        xi = x if i == 0 else _segment_input(stack, params, x, i)
        fwd = jax.jit(lambda p, v, i=i: _seg_apply(stack, params, p, v, i))  # repro-lint: disable=RA101 one-shot timing probe, traced once per cut
        # Backward timing covers what a mid-stack worker computes: the
        # cotangent w.r.t. this cut's params AND its input activations.
        # Integer segment inputs (the LM embed cut's token ids) have no
        # tangent, so there the params cotangent is the whole backward.
        if jnp.issubdtype(xi.dtype, jnp.floating):
            vjp = jax.jit(lambda p, v, i=i: jax.vjp(  # repro-lint: disable=RA101 one-shot timing probe, traced once per cut
                lambda pp, vv: _seg_sq(stack, params, pp, vv, i),
                p, v)[1](1.0))
        else:
            vjp = jax.jit(lambda p, v, i=i: jax.vjp(  # repro-lint: disable=RA101 one-shot timing probe, traced once per cut
                lambda pp: _seg_sq(stack, params, pp, v, i), p)[1](1.0))
        fwd(params[i], xi).block_until_ready()  # compile
        jax.block_until_ready(vjp(params[i], xi))
        tf, tb = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fwd(params[i], xi).block_until_ready()
            tf.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(vjp(params[i], xi))
            tb.append(time.perf_counter() - t0)
        host_f[i] = float(np.mean(tf)) / batch
        host_b[i] = float(np.mean(tb)) / batch
    L_f = np.zeros((3, n))
    L_b = np.zeros((3, n))
    L_u = np.zeros((3, n))
    for j, wname in enumerate(WORKERS):
        s = rel_speed[wname]
        L_f[j] = host_f / s
        L_b[j] = host_b / s
        L_u[j] = np.array([m.param_count * 4.0 for m in metas]) / \
            (s * 8e9)  # SGD update flops over scaled host throughput
    if sample_bytes is None:
        sample_bytes = stack.default_sample_bytes()
    cols = stack.meta_arrays()
    return HierProfile(
        layer_names=cols["names"],
        L_f=L_f, L_b=L_b, L_u=L_u,
        MP=cols["MP"], MO=cols["MO"], MG=cols["MG"],
        sample_bytes=sample_bytes,
    )


def _seg_apply(stack: LayerStack, params, p_i, x: jax.Array,
               i: int) -> jax.Array:
    """Run cut ``i`` with slot ``i`` of ``params`` swapped for ``p_i`` —
    the segment touches only that slot, so tracing differentiates (and
    transfers) nothing else."""
    ps = list(params)
    ps[i] = p_i
    return stack.apply_segment(ps, x, i, i + 1)


def _seg_sq(stack: LayerStack, params, p_i, x: jax.Array,
            i: int) -> jax.Array:
    y = _seg_apply(stack, params, p_i, x, i)
    return (y.astype(np.float32) ** 2).sum()


def _segment_input(stack: LayerStack, params, x: jax.Array,
                   i: int) -> jax.Array:
    # repro-lint: disable-next=RA102 runs once per cut to build the timing input
    return jax.jit(lambda p, v: stack.apply_segment(p, v, 0, i))(params, x)
