"""HierTrain profiling stage (§III): produce ``HierProfile`` objects.

Two profiling modes:

* :func:`analytic_profile` — derive per-layer per-worker times from the
  model's FLOP metadata and per-worker effective throughput.  Deterministic;
  used by tests and the figure-reproduction benchmarks.
* :func:`measure_profile` — *measure* per-layer forward/backward wall time of
  the real JAX model on this host (jit + warm-up + repeat, mean of runs — the
  paper's run-time profiling), then scale to each worker by its relative
  speed.  Used by the profiling-stage benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import WORKERS, HierProfile, MultiProfile
from repro.models.cnn import LayeredModel


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Effective capability of one worker tier.

    ``flops_per_sec`` — sustained throughput on this model family.
    ``overhead`` — fixed per-layer dispatch overhead (seconds).
    ``update_flops_per_param`` — optimizer cost model (SGD+momentum ~ 4).
    """
    name: str
    flops_per_sec: float
    overhead: float = 0.0
    update_flops_per_param: float = 4.0


# Defaults calibrated to the paper's §VI-B testbed: Raspberry Pi 3 (device),
# one core of an Intel NUC i3-7100U (edge), Dell T5820 + GTX 1080 Ti (cloud).
# Effective (not peak) throughputs are per-model in reality — the paper's
# profiling stage measures each model on each worker — so the benchmark
# suite carries per-model calibrations (benchmarks/common.py); this generic
# set is calibrated on LeNet-5 and reproduces the paper's headline
# 1.7x / 6.9x speedups (we measure 1.76x / 7.2x).
PAPER_TESTBED: Dict[str, WorkerSpec] = {
    "device": WorkerSpec("device", flops_per_sec=2e9, overhead=1e-4),
    "edge": WorkerSpec("edge", flops_per_sec=2e10, overhead=1e-5),
    "cloud": WorkerSpec("cloud", flops_per_sec=2e11, overhead=5e-6),
}

# AlexNet's big 11x11/5x5 convs run at lower effective FLOP/s on the
# Pi/NUC than LeNet's tiny stacks (Chainer-era im2col); calibrated so the
# HierTrain-vs-All-Edge gap matches the paper's 2.3x.
ALEXNET_TESTBED: Dict[str, WorkerSpec] = {
    "device": WorkerSpec("device", flops_per_sec=4e8, overhead=1e-4),
    "edge": WorkerSpec("edge", flops_per_sec=6e9, overhead=1e-5),
    "cloud": WorkerSpec("cloud", flops_per_sec=2e11, overhead=5e-6),
}


def analytic_profile(model: LayeredModel,
                     workers: Dict[str, WorkerSpec] | None = None,
                     sample_bytes: float | None = None,
                     bwd_fwd_ratio: float = 2.0) -> HierProfile:
    workers = workers or PAPER_TESTBED
    metas = model.layer_meta()
    n = len(metas)
    L_f = np.zeros((3, n))
    L_b = np.zeros((3, n))
    L_u = np.zeros((3, n))
    for j, wname in enumerate(WORKERS):
        w = workers[wname]
        for i, m in enumerate(metas):
            L_f[j, i] = m.flops_fwd / w.flops_per_sec + w.overhead
            L_b[j, i] = bwd_fwd_ratio * m.flops_fwd / w.flops_per_sec \
                + w.overhead
            L_u[j, i] = m.param_count * w.update_flops_per_param / \
                w.flops_per_sec + w.overhead
    if sample_bytes is None:
        # raw uint8 image + int label
        sample_bytes = float(np.prod(model.input_shape)) + 4.0
    return HierProfile(
        layer_names=tuple(m.name for m in metas),
        L_f=L_f, L_b=L_b, L_u=L_u,
        MP=np.array([m.param_bytes for m in metas], np.float64),
        MO=np.array([m.out_bytes for m in metas], np.float64),
        sample_bytes=sample_bytes,
    )


def multi_analytic_profile(model: LayeredModel,
                           workers: Dict[str, WorkerSpec] | None = None,
                           device_slowdowns: Sequence[float] = (1.0,),
                           sample_bytes: float | None = None,
                           bwd_fwd_ratio: float = 2.0) -> MultiProfile:
    """Analytic profile for the M-device star (DESIGN.md §6).

    ``device_slowdowns[i]`` scales the profiled device tier for device *i*
    (1.0 = the testbed's reference device, 2.0 = half its speed) — the
    straggler heterogeneity knob used by ``benchmarks/fig_multidevice``.
    With the default single 1.0 entry this is exactly
    :func:`analytic_profile` lifted to the M=1 star.
    """
    return MultiProfile.from_hier(
        analytic_profile(model, workers, sample_bytes, bwd_fwd_ratio),
        device_slowdowns)


def measure_profile(model: LayeredModel,
                    rel_speed: Dict[str, float] | None = None,
                    batch: int = 8, repeats: int = 3,
                    sample_bytes: float | None = None) -> HierProfile:
    """Measure real per-layer fwd/bwd times on this host, scale per worker.

    ``rel_speed[worker]`` divides the measured host time (2.0 => 2x faster
    than this host).  Default calibrates this CPU as the "edge" tier.
    """
    rel_speed = rel_speed or {"device": 1 / 13.0, "edge": 1.0, "cloud": 11.0}
    metas = model.layer_meta()
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = model.num_layers
    host_f = np.zeros(n)
    host_b = np.zeros(n)
    shape = (batch,) + model.input_shape
    x = jax.random.normal(key, shape, jnp.float32)
    for i in range(n):
        xi = x if i == 0 else _layer_input(model, params, x, i)
        fwd = jax.jit(lambda p, v, i=i: model.apply_layer(p, v, i))
        vjp = jax.jit(lambda p, v, i=i: jax.vjp(
            lambda pp, vv: jnp.sum(model.apply_layer(pp, vv, i) ** 2),
            p, v)[1](1.0))
        fwd(params[i], xi).block_until_ready()  # compile
        jax.block_until_ready(vjp(params[i], xi))
        tf, tb = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fwd(params[i], xi).block_until_ready()
            tf.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(vjp(params[i], xi))
            tb.append(time.perf_counter() - t0)
        host_f[i] = float(np.mean(tf)) / batch
        host_b[i] = float(np.mean(tb)) / batch
    L_f = np.zeros((3, n))
    L_b = np.zeros((3, n))
    L_u = np.zeros((3, n))
    for j, wname in enumerate(WORKERS):
        s = rel_speed[wname]
        L_f[j] = host_f / s
        L_b[j] = host_b / s
        L_u[j] = np.array([m.param_count * 4.0 for m in metas]) / \
            (s * 8e9)  # SGD update flops over scaled host throughput
    if sample_bytes is None:
        sample_bytes = float(np.prod(model.input_shape)) + 4.0
    return HierProfile(
        layer_names=tuple(m.name for m in metas),
        L_f=L_f, L_b=L_b, L_u=L_u,
        MP=np.array([m.param_bytes for m in metas], np.float64),
        MO=np.array([m.out_bytes for m in metas], np.float64),
        sample_bytes=sample_bytes,
    )


def _layer_input(model: LayeredModel, params: Sequence, x: jax.Array,
                 i: int) -> jax.Array:
    return jax.jit(lambda p, v: model.apply_segment(p, v, 0, i))(params, x)
