"""Algorithm 1 of the paper: optimal HierTrain scheduling policy.

For every one of the 6 worker-role mappings and every cut pair
``(m_s, m_l)`` with ``0 <= m_s <= m_l <= N``, problem P1 (Eqs. 16-19) with the
cuts fixed is an ILP.  Per §V we relax it to an LP in epigraph form (one
epigraph variable per max-term of Eq. 12), solve, round with the paper's
largest-fraction rule, and keep the schedule with the smallest *exact*
integer-evaluated ``T_total``.

Two backends (DESIGN.md §Scheduler-engine):

* ``backend="batched"`` (default) — builds the constraint tensors for *all*
  ``(mapping, m_s, m_l)`` candidates in one shot from the profile's prefix
  arrays, prunes candidates whose cut-constant lower bound (the ``T^3`` +
  ``T_update`` terms, which the LP cannot change) already exceeds an
  incumbent, solves the survivors as ONE stacked simplex call
  (:mod:`repro.core.batched_lp`), rounds every batch split vectorized, and
  evaluates the exact integer ``T_total`` of all survivors with
  :func:`repro.core.cost_model.t_total_batch` before the argmin.
* ``backend="reference"`` — the original sequential loop over scalar
  two-phase-simplex calls.  Kept as the correctness oracle; the equivalence
  suite asserts both backends return schedules with identical ``T_total``.

:func:`solve_multi` generalizes the search to M heterogeneous devices
around one edge and one cloud (DESIGN.md §6): an exhaustive stage over
every (worker_o, worker_l) mapping and shared-cut pair — bit-identical to
:func:`solve` at M = 1 — followed by batched coordinate descent on the
per-device cuts for M >= 2.

Both solvers take ``objective="latency"`` (default, Eq. 12 ``T_total``)
or ``objective="throughput"``, which reuses the same LP stack and
dominance prune but scores candidates with the pipelined steady-state
period (:mod:`repro.core.pipeline`, DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

import numpy as np

from repro.core import batched_lp
from repro.core import lp as lp_mod
from repro.core import pipeline as pipeline_mod
from repro.core._deprecation import warn_deprecated
from repro.core.cost_model import (WIDX, WORKERS, Breakdown, HierProfile,
                                   MultiProfile, MultiSchedule, Network,
                                   Schedule, StarNetwork, _t_total,
                                   _t_total_batch, _t_total_multi,
                                   _t_total_multi_batch, bw_matrix)

OBJECTIVES = ("latency", "throughput")

_LP_NUM_VARS = 7          # [b_o, b_s, b_l, t1, t2, t3, t4]
_LP_NUM_UB = 12           # 10 epigraph arms + constraints (14)/(15)
_LP_COST = np.array([0, 0, 0, 1, 1, 1, 1], np.float64)


@dataclasses.dataclass
class SchedulerResult:
    schedule: Schedule
    breakdown: Breakdown
    t_total: float
    n_lp_solved: int
    search_log: List[Tuple[Schedule, float]]
    n_candidates: int = 0
    n_pruned: int = 0
    objective: str = "latency"
    t_period: Optional[float] = None   # steady-state period of the winner


def _round_batch_split(b_real: np.ndarray, B: int,
                       allowed: np.ndarray) -> np.ndarray:
    """Paper §V rounding: floor everything, then hand the missing units to
    the entries with the largest fractional parts.  Entries with
    ``allowed == False`` (their ``m`` is 0) are forced to exactly 0 — they
    may neither keep an integer part nor receive extra units.  Any residue
    the largest-fraction pass cannot place lands on ``b_o`` (always
    allowed); a floor *overshoot* (LP numerics handing out more than ``B``
    units) is stripped from the largest entries without driving any entry
    below zero, so the result always satisfies ``sum == B`` and ``>= 0``.
    """
    b_real = np.clip(np.asarray(b_real, np.float64), 0.0, None)
    allowed = np.asarray(allowed, bool)
    b_real = np.where(allowed, b_real, 0.0)
    ints = np.floor(b_real + 1e-9).astype(np.int64)
    fracs = np.where(allowed, b_real - ints, -1.0)
    deficit = int(B - ints.sum())
    out = ints.copy()
    for idx in np.argsort(-fracs, kind="stable"):
        if deficit <= 0:
            break
        if not allowed[idx]:
            continue
        out[idx] += 1
        deficit -= 1
    if deficit > 0:  # more missing units than entries: dump on b_o
        out[0] += deficit
        deficit = 0
    while deficit < 0:  # overshoot: strip from the largest entries
        idx = int(np.argmax(out))
        if out[idx] <= 0:
            break
        out[idx] -= 1
        deficit += 1
    return out


def _round_batch_split_batch(b_real: np.ndarray, B: int,
                             allowed: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_round_batch_split` over ``[K, 3]`` splits.
    Semantics match the scalar rule exactly (same stable largest-fraction
    order, same residue handling), so both backends round identically."""
    K = b_real.shape[0]
    ar = np.arange(K)
    b = np.clip(np.asarray(b_real, np.float64), 0.0, None)
    b = np.where(allowed, b, 0.0)
    ints = np.floor(b + 1e-9).astype(np.int64)
    fracs = np.where(allowed, b - ints, -1.0)
    deficit = B - ints.sum(axis=1)
    out = ints.copy()
    order = np.argsort(-fracs, axis=1, kind="stable")
    for j in range(order.shape[1]):  # one potential +1 per entry, like scalar
        idx = order[:, j]
        bump = allowed[ar, idx] & (deficit > 0)
        out[ar, idx] += bump
        deficit -= bump
    out[:, 0] += np.maximum(deficit, 0)
    deficit = np.minimum(deficit, 0)
    while (deficit < 0).any():
        idx = np.argmax(out, axis=1)
        strip = (deficit < 0) & (out[ar, idx] > 0)
        if not strip.any():
            break
        out[ar, idx] -= strip
        deficit += strip
    return out


# ---------------------------------------------------------------------------
# Reference backend: sequential scalar LPs (the seed implementation).
# ---------------------------------------------------------------------------

def _solve_cut_lp(profile: HierProfile, net: Network, wo: str, ws: str,
                  wl: str, m_s: int, m_l: int, B: int,
                  origin: str) -> Optional[np.ndarray]:
    """LP relaxation of P1 for a fixed mapping and fixed cuts.

    Variables ``x = [b_o, b_s, b_l, t1, t2, t3, t4] >= 0`` where
    ``t1 >= T^1_fwd``-terms, ``t2 >= T^1_bwd``, ``t3 >= T^2_fwd``,
    ``t4 >= T^2_bwd``.  ``T^3`` and ``T_update`` are constant once the cuts
    are fixed (they involve the full batch ``B`` / only prefix parameter
    sums), so they do not enter the LP objective.
    """
    p = profile.prefix()
    F, Bk = p["F"], p["Bk"]
    o, s, l = WIDX[wo], WIDX[ws], WIDX[wl]
    Q = profile.sample_bytes
    bw_os, bw_ol = net.bw(wo, ws), net.bw(wo, wl)
    in_o = 0.0 if wo == origin else Q / net.bw(origin, wo)
    in_s = 0.0 if ws == origin else Q / net.bw(origin, ws)
    in_l = 0.0 if wl == origin else Q / net.bw(origin, wl)
    mo_s = profile.MO[m_s - 1] / bw_os if m_s > 0 else 0.0
    mo_l = profile.MO[m_l - 1] / bw_ol if m_l > 0 else 0.0
    mg_s = profile.MG[m_s - 1] / bw_os if m_s > 0 else 0.0
    mg_l = profile.MG[m_l - 1] / bw_ol if m_l > 0 else 0.0

    nv = _LP_NUM_VARS
    A_ub, b_ub = [], []

    def ub(coef_b, t_idx):  # coef_b @ [b_o,b_s,b_l] - t <= 0
        row = np.zeros(nv)
        row[:3] = coef_b
        row[3 + t_idx] = -1.0
        A_ub.append(row)
        b_ub.append(0.0)

    # t1 >= each arm of Eq. (5); t2 >= each arm of Eq. (6) (backward arms
    # ship the activation *gradient*: MG-based wire terms).
    ub([in_o + F[o, m_s], 0, 0], 0)
    ub([0, in_s + F[s, m_s] + mo_s, 0], 0)
    ub([0, 0, in_l + F[l, m_s]], 0)
    ub([Bk[o, m_s], 0, 0], 1)
    ub([0, Bk[s, m_s] + mg_s, 0], 1)
    ub([0, 0, Bk[l, m_s]], 1)
    # t3 >= each arm of Eq. (7); t4 >= each arm of Eq. (8).
    ub([F[o, m_l] - F[o, m_s], F[o, m_l] - F[o, m_s], 0], 2)
    ub([0, 0, (F[l, m_l] - F[l, m_s]) + mo_l], 2)
    ub([Bk[o, m_l] - Bk[o, m_s], Bk[o, m_l] - Bk[o, m_s], 0], 3)
    ub([0, 0, (Bk[l, m_l] - Bk[l, m_s]) + mg_l], 3)
    # Constraints (14)/(15): b_s <= m_s*B, b_l <= m_l*B.
    row = np.zeros(nv); row[1] = 1.0
    A_ub.append(row); b_ub.append(float(m_s) * B)
    row = np.zeros(nv); row[2] = 1.0
    A_ub.append(row); b_ub.append(float(m_l) * B)
    # Constraint (17): b_o + b_s + b_l = B.
    A_eq = np.zeros((1, nv)); A_eq[0, :3] = 1.0
    b_eq = np.array([float(B)])

    res = lp_mod.linprog(_LP_COST, np.array(A_ub), np.array(b_ub), A_eq, b_eq)
    if not res.success:
        return None
    return res.x[:3]


def _solve_reference(profile: HierProfile, net: Network, B: int,
                     origin: str, workers: Tuple[str, ...],
                     keep_log: bool,
                     objective: str = "latency") -> SchedulerResult:
    """Algorithm 1, one scalar LP at a time (the correctness oracle).

    ``objective="throughput"`` keeps the same LP relaxation (splits are
    still balanced for latency) but scores every rounded candidate with
    the steady-state period instead of ``T_total`` (DESIGN.md §7).
    """
    N = profile.num_layers
    best: Optional[Tuple[Schedule, Breakdown]] = None
    best_score = np.inf
    n_lp = 0
    log: List[Tuple[Schedule, float]] = []
    for wo, ws, wl in itertools.permutations(workers, 3):
        for m_s in range(0, N + 1):
            for m_l in range(m_s, N + 1):
                n_lp += 1
                b = _solve_cut_lp(profile, net, wo, ws, wl, m_s, m_l, B,
                                  origin)
                if b is None:
                    continue
                allowed = np.array([True, m_s > 0, m_l > 0])
                b_int = _round_batch_split(b, B, allowed)
                sched = Schedule(wo, ws, wl, m_s, m_l,
                                 int(b_int[0]), int(b_int[1]), int(b_int[2]))
                bd = _t_total(profile, net, sched, origin)
                score = bd.total if objective == "latency" else \
                    pipeline_mod.t_period(profile, net, sched, origin)
                if keep_log:
                    log.append((sched, score))
                if best is None or score < best_score:
                    best = (sched, bd)
                    best_score = score
    assert best is not None
    return SchedulerResult(
        schedule=best[0], breakdown=best[1], t_total=best[1].total,
        n_lp_solved=n_lp, search_log=log, n_candidates=n_lp, n_pruned=0,
        objective=objective,
        t_period=pipeline_mod.t_period(profile, net, best[0], origin))


# ---------------------------------------------------------------------------
# Batched backend: one stacked LP over all surviving candidates.
# ---------------------------------------------------------------------------

def _candidate_grid(N: int, workers: Tuple[str, ...]
                    ) -> Tuple[np.ndarray, ...]:
    """All ``(mapping, m_s, m_l)`` candidates in the reference backend's
    enumeration order, as flat index arrays."""
    maps = list(itertools.permutations(workers, 3))
    ms_g, ml_g = np.triu_indices(N + 1)       # row-major == m_s outer loop
    P = ms_g.shape[0]
    o_idx = np.repeat([WIDX[m[0]] for m in maps], P)
    s_idx = np.repeat([WIDX[m[1]] for m in maps], P)
    l_idx = np.repeat([WIDX[m[2]] for m in maps], P)
    ms = np.tile(ms_g, len(maps))
    ml = np.tile(ml_g, len(maps))
    return o_idx, s_idx, l_idx, ms, ml


def _build_lp_stack(profile: HierProfile, net: Network, o_idx: np.ndarray,
                    s_idx: np.ndarray, l_idx: np.ndarray, ms: np.ndarray,
                    ml: np.ndarray, B: int, origin: str
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
    """Constraint tensors of the per-cut LP for all K candidates at once.

    Row layout matches :func:`_solve_cut_lp` one-to-one.
    """
    p = profile.prefix()
    F, Bk = p["F"], p["Bk"]
    K = o_idx.shape[0]
    Q = profile.sample_bytes
    bwm = bw_matrix(net)
    oi = WIDX[origin]
    bw_os = bwm[o_idx, s_idx]
    bw_ol = bwm[o_idx, l_idx]
    in_o = np.where(o_idx == oi, 0.0, Q / bwm[oi, o_idx])
    in_s = np.where(s_idx == oi, 0.0, Q / bwm[oi, s_idx])
    in_l = np.where(l_idx == oi, 0.0, Q / bwm[oi, l_idx])
    mo_s = np.where(ms > 0, profile.MO[np.maximum(ms, 1) - 1] / bw_os, 0.0)
    mo_l = np.where(ml > 0, profile.MO[np.maximum(ml, 1) - 1] / bw_ol, 0.0)
    mg_s = np.where(ms > 0, profile.MG[np.maximum(ms, 1) - 1] / bw_os, 0.0)
    mg_l = np.where(ml > 0, profile.MG[np.maximum(ml, 1) - 1] / bw_ol, 0.0)

    A_ub = np.zeros((K, _LP_NUM_UB, _LP_NUM_VARS))
    b_ub = np.zeros((K, _LP_NUM_UB))
    # t1 >= each arm of Eq. (5); t2 >= each arm of Eq. (6) (backward arms
    # use the MG-based gradient wire terms).
    A_ub[:, 0, 0] = in_o + F[o_idx, ms]
    A_ub[:, 1, 1] = in_s + F[s_idx, ms] + mo_s
    A_ub[:, 2, 2] = in_l + F[l_idx, ms]
    A_ub[:, 3, 0] = Bk[o_idx, ms]
    A_ub[:, 4, 1] = Bk[s_idx, ms] + mg_s
    A_ub[:, 5, 2] = Bk[l_idx, ms]
    A_ub[:, :3, 3] = -1.0
    A_ub[:, 3:6, 4] = -1.0
    # t3 >= each arm of Eq. (7); t4 >= each arm of Eq. (8).
    dF_o = F[o_idx, ml] - F[o_idx, ms]
    dBk_o = Bk[o_idx, ml] - Bk[o_idx, ms]
    A_ub[:, 6, 0] = dF_o
    A_ub[:, 6, 1] = dF_o
    A_ub[:, 7, 2] = (F[l_idx, ml] - F[l_idx, ms]) + mo_l
    A_ub[:, 8, 0] = dBk_o
    A_ub[:, 8, 1] = dBk_o
    A_ub[:, 9, 2] = (Bk[l_idx, ml] - Bk[l_idx, ms]) + mg_l
    A_ub[:, 6:8, 5] = -1.0
    A_ub[:, 8:10, 6] = -1.0
    # Constraints (14)/(15): b_s <= m_s*B, b_l <= m_l*B.
    A_ub[:, 10, 1] = 1.0
    b_ub[:, 10] = ms.astype(np.float64) * B
    A_ub[:, 11, 2] = 1.0
    b_ub[:, 11] = ml.astype(np.float64) * B
    # Constraint (17): b_o + b_s + b_l = B.
    A_eq = np.zeros((K, 1, _LP_NUM_VARS))
    A_eq[:, 0, :3] = 1.0
    b_eq = np.full((K, 1), float(B))
    return A_ub, b_ub, A_eq, b_eq


def _warm_ok(totals_win: float, incumbent: float) -> bool:
    """Soundness certificate for a warm-started prune (DESIGN.md §10).

    The prune drops lanes with ``const_lb > incumbent``.  If the best
    *surviving* exact score is ``<= incumbent``, then (a) every pruned
    lane scores strictly above it (``score >= const_lb > incumbent``),
    so the cold argmin lane survived, and (b) the order-preserving mask
    kept it the first minimum — the warm result is bit-identical to the
    cold one.  If instead every survivor scores above the incumbent (the
    warm schedule beat the whole surviving grid), a pruned lane could
    have been the cold winner and the caller must re-solve cold.
    """
    return totals_win <= incumbent


def _solve_batched(profile: HierProfile, net: Network, B: int, origin: str,
                   workers: Tuple[str, ...], keep_log: bool,
                   prune: bool, objective: str = "latency",
                   warm_start: Optional[Schedule] = None) -> SchedulerResult:
    N = profile.num_layers
    p = profile.prefix()
    F, Bk, U = p["F"], p["Bk"], p["U"]
    o_idx, s_idx, l_idx, ms, ml = _candidate_grid(N, workers)
    K = o_idx.shape[0]

    def score_batch(o, s, l, mss, mll, bb):
        if objective == "latency":
            return _t_total_batch(profile, net, o, s, l, mss, mll,
                                  bb, origin)
        return pipeline_mod.t_period_batch(profile, net, o, s, l, mss, mll,
                                           bb, origin)

    # Dominance pruning: the T^3 + T_update terms of Eq. (12) do not depend
    # on the batch split, so  B*(F_o[N]-F_o[ml]) + B*(Bk_o[N]-Bk_o[ml]) +
    # U_o[N]  lower-bounds any schedule with these cuts.  Candidates whose
    # bound already exceeds the best ``(m_s = m_l = 0)`` schedule (whose LP
    # is trivial: everything on worker_o) cannot win — skip their LPs.
    # The same constants sit inside worker_o's CPU busy time, so the bound
    # also lower-bounds the steady-state period and the prune stays valid
    # under objective="throughput" (scored against the period incumbent).
    keep = np.ones(K, bool)
    n_pruned = 0
    incumbent = np.inf
    if prune:
        Bf = float(B)
        const_lb = Bf * (F[o_idx, N] - F[o_idx, ml]) + \
            Bf * (Bk[o_idx, N] - Bk[o_idx, ml]) + U[o_idx, N]
        trivial = (ms == 0) & (ml == 0)
        b_triv = np.zeros((int(trivial.sum()), 3), np.int64)
        b_triv[:, 0] = B
        incumbent = score_batch(o_idx[trivial], s_idx[trivial],
                                l_idx[trivial], ms[trivial], ml[trivial],
                                b_triv).min()
        if warm_start is not None:
            # Warm incumbent: the live schedule's exact cost on this
            # fleet (the incremental re-solve of DESIGN.md §10).
            if warm_start.batch != B:
                raise ValueError(
                    f"warm_start batch {warm_start.batch} != B {B}")
            ws_score = _t_total(profile, net, warm_start, origin).total \
                if objective == "latency" else \
                pipeline_mod.t_period(profile, net, warm_start, origin)
            incumbent = min(incumbent, ws_score)
        keep = ~(const_lb > incumbent)
        n_pruned = int(K - keep.sum())

    ko, ks, kl = o_idx[keep], s_idx[keep], l_idx[keep]
    kms, kml = ms[keep], ml[keep]
    A_ub, b_ub, A_eq, b_eq = _build_lp_stack(profile, net, ko, ks, kl,
                                             kms, kml, B, origin)
    res = batched_lp.linprog_batch(_LP_COST, A_ub, b_ub, A_eq, b_eq)

    ok = res.success
    allowed = np.stack([np.ones_like(kms, bool), kms > 0, kml > 0], axis=1)
    b_int = _round_batch_split_batch(res.x[:, :3], B, allowed)
    totals = score_batch(ko, ks, kl, kms, kml, b_int)
    totals = np.where(ok, totals, np.inf)
    if prune and warm_start is not None and \
            not (ok.any() and _warm_ok(float(totals.min()), incumbent)):
        # The warm incumbent over-pruned (the live schedule beat every
        # surviving lane) — bit-identity over speed: re-solve cold.
        return _solve_batched(profile, net, B, origin, workers, keep_log,
                              prune, objective, warm_start=None)
    assert ok.any(), "every per-cut LP failed — inconsistent profile?"
    win = int(np.argmin(totals))  # first min == reference's sequential <

    inv = {i: w for w, i in WIDX.items()}
    sched = Schedule(inv[int(ko[win])], inv[int(ks[win])], inv[int(kl[win])],
                     int(kms[win]), int(kml[win]),
                     int(b_int[win, 0]), int(b_int[win, 1]),
                     int(b_int[win, 2]))
    bd = _t_total(profile, net, sched, origin)
    log: List[Tuple[Schedule, float]] = []
    if keep_log:
        for k in np.nonzero(ok)[0]:
            log.append((Schedule(
                inv[int(ko[k])], inv[int(ks[k])], inv[int(kl[k])],
                int(kms[k]), int(kml[k]), int(b_int[k, 0]),
                int(b_int[k, 1]), int(b_int[k, 2])), float(totals[k])))
    return SchedulerResult(schedule=sched, breakdown=bd, t_total=bd.total,
                           n_lp_solved=int(keep.sum()), search_log=log,
                           n_candidates=K, n_pruned=n_pruned,
                           objective=objective,
                           t_period=pipeline_mod.t_period(profile, net,
                                                          sched, origin))


def _solve_3w(profile: HierProfile, net: Network, B: int,
              origin: str = "device",
              workers: Tuple[str, ...] = WORKERS,
              keep_log: bool = False,
              backend: str = "batched",
              prune: bool = True,
              objective: str = "latency",
              warm_start: Optional[Schedule] = None) -> SchedulerResult:
    """Algorithm 1: enumerate mappings x cuts, LP + round, return the best.

    This is the canonical *three-worker* engine — the facade
    (``repro.api.plan``) runs it for triple-native fleets, and it doubles
    as the correctness oracle the M=1 equivalence suite compares the
    generalized engine against.  ``backend="batched"`` (default) solves
    all candidate LPs as one stacked simplex; ``backend="reference"`` is
    the sequential scalar oracle.  ``prune`` toggles the cut-constant
    dominance bound (batched only).  ``objective="latency"`` (default)
    minimizes the per-iteration ``T_total`` of Eq. 12;
    ``objective="throughput"`` reuses the same LP stack and pruning but
    picks the candidate with the smallest steady-state pipelined period
    ``t_period`` (DESIGN.md §7).  ``warm_start`` feeds a live schedule's
    exact cost into the dominance prune as an extra incumbent — an
    incremental re-solve that returns bit-identical results to a cold
    solve (DESIGN.md §10) while skipping more of the candidate grid.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown scheduler objective: {objective!r}")
    if backend == "reference":
        # The oracle has no prune, so a warm incumbent cannot change it.
        return _solve_reference(profile, net, B, origin, workers, keep_log,
                                objective)
    if backend != "batched":
        raise ValueError(f"unknown scheduler backend: {backend!r}")
    return _solve_batched(profile, net, B, origin, workers, keep_log, prune,
                          objective, warm_start)


def solve(profile: HierProfile, net: Network, B: int,
          origin: str = "device",
          workers: Tuple[str, ...] = WORKERS,
          keep_log: bool = False,
          backend: str = "batched",
          prune: bool = True,
          objective: str = "latency",
          warm_start: Optional[Schedule] = None) -> SchedulerResult:
    """Deprecated shim over the facade (DESIGN.md §9): build a triple
    fleet from the profile/network pair and plan through ``repro.api``.
    Results are bit-identical to the historical solver.  Exotic
    arguments the facade does not model (``origin != "device"``, custom
    ``workers`` subsets) fall back to the retained 3-worker engine."""
    warn_deprecated(
        "repro.core.scheduler.solve()",
        "repro.api.plan(model, Fleet.from_profile(profile, net), B, ...)")
    if origin == "device" and tuple(workers) == WORKERS:
        from repro import api
        return api.plan(None, api.Fleet.from_profile(profile, net), B,
                        objective=objective, backend=backend, prune=prune,
                        keep_log=keep_log, warm_start=warm_start).result
    return _solve_3w(profile, net, B, origin, workers, keep_log, backend,
                     prune, objective, warm_start)


# ---------------------------------------------------------------------------
# M-device scheduler (DESIGN.md §6).
#
# Stage A enumerates every (worker_o, worker_l) mapping x every *shared*
# cut pair (all TASK-S instances at the same m_s) — with M = 1 that IS the
# paper's Algorithm 1 search space in the reference enumeration order, so
# the M=1 result is bit-identical to solve().  Stage B (M >= 2 only)
# coordinate-descends the per-device cuts: every single-cut move is scored
# by one more stacked LP pass, and only strict improvements are accepted.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiSchedulerResult:
    schedule: MultiSchedule
    breakdown: Breakdown
    t_total: float
    n_lp_solved: int          # stage-A LPs: n_candidates - n_pruned
    search_log: List[Tuple[MultiSchedule, float]]
    n_candidates: int = 0
    n_pruned: int = 0
    refine_rounds: int = 0
    n_lp_refine: int = 0      # stage-B LPs, counted separately
    objective: str = "latency"
    t_period: Optional[float] = None   # steady-state period of the winner


def _multi_candidate_grid(N: int, worker_names: Tuple[str, ...]
                          ) -> Tuple[np.ndarray, ...]:
    """All (mapping, shared m_s, m_l) candidates.

    Mapping order — ``worker_o`` outer, ``worker_l`` over the *reversed*
    remaining workers — reproduces the 3-worker ``itertools.permutations``
    (o, s, l) order at M = 1, so first-min tie-breaks match the reference
    scheduler exactly.
    """
    W = len(worker_names)
    M = W - 2
    widx = {w: i for i, w in enumerate(worker_names)}
    maps = []
    for wo in worker_names:
        rest = [w for w in worker_names if w != wo]
        for wl in reversed(rest):
            s_set = tuple(w for w in rest if w != wl)
            maps.append((widx[wo], widx[wl],
                         tuple(widx[w] for w in s_set)))
    ms_g, ml_g = np.triu_indices(N + 1)       # row-major == m_s outer loop
    P = ms_g.shape[0]
    o_idx = np.repeat([m[0] for m in maps], P)
    l_idx = np.repeat([m[1] for m in maps], P)
    s_idx = np.repeat(np.array([m[2] for m in maps], np.int64), P, axis=0)
    ms = np.tile(ms_g, len(maps))[:, None] * np.ones((1, M), np.int64)
    ml = np.tile(ml_g, len(maps))
    return o_idx, s_idx, l_idx, ms, ml


def _build_multi_lp_stack(profile: MultiProfile, net: StarNetwork,
                          o_idx: np.ndarray, s_idx: np.ndarray,
                          l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                          B: int) -> Tuple[np.ndarray, np.ndarray,
                                           np.ndarray, np.ndarray]:
    """Constraint tensors of the per-cut LP for all K candidates.

    Variables ``x = [b_o, b_s[0..M-1], b_l, t1, t2, t3, t4] >= 0``
    (``M + 6`` of them); ``3M + 9`` inequality rows laid out exactly like
    :func:`_build_lp_stack` at M = 1 (same rows, same order, same
    coefficients), so the stacked simplex walks the same pivot path.
    """
    p = profile.prefix()
    F, Bk = p["F"], p["Bk"]
    D = profile.num_devices       # data holders (locality), not streams
    M = profile.num_streams       # stream count: the LP's variable layout
    K = o_idx.shape[0]
    nv = M + 6
    t1, t2, t3, t4 = M + 2, M + 3, M + 4, M + 5
    Q = profile.sample_bytes
    bwm = net.bw_matrix()
    up = net.upload_bw()
    msmax = ms.max(axis=1)
    o2 = o_idx[:, None]

    bw_os = bwm[o2, s_idx]                                  # [K, M]
    bw_ol = bwm[o_idx, l_idx]
    in_o = np.where(o_idx < D, 0.0, Q / up[o_idx])
    in_s = np.where(s_idx < D, 0.0, Q / up[s_idx])
    in_l = np.where(l_idx < D, 0.0, Q / up[l_idx])
    mo_s = np.where(ms > 0, profile.MO[np.maximum(ms, 1) - 1] / bw_os, 0.0)
    mo_l = np.where(ml > 0, profile.MO[np.maximum(ml, 1) - 1] / bw_ol, 0.0)
    mg_s = np.where(ms > 0, profile.MG[np.maximum(ms, 1) - 1] / bw_os, 0.0)
    mg_l = np.where(ml > 0, profile.MG[np.maximum(ml, 1) - 1] / bw_ol, 0.0)

    A_ub = np.zeros((K, 3 * M + 9, nv))
    b_ub = np.zeros((K, 3 * M + 9))
    # t1 >= each phase-1 forward arm; t2 >= each phase-1 backward arm
    # (backward arms use the MG-based gradient wire terms).
    A_ub[:, 0, 0] = in_o + F[o_idx, msmax]
    for i in range(M):
        A_ub[:, 1 + i, 1 + i] = in_s[:, i] + F[s_idx[:, i], ms[:, i]] + \
            mo_s[:, i]
    A_ub[:, M + 1, M + 1] = in_l + F[l_idx, msmax]
    A_ub[:, M + 2, 0] = Bk[o_idx, msmax]
    for i in range(M):
        A_ub[:, M + 3 + i, 1 + i] = Bk[s_idx[:, i], ms[:, i]] + mg_s[:, i]
    A_ub[:, 2 * M + 3, M + 1] = Bk[l_idx, msmax]
    A_ub[:, :M + 2, t1] = -1.0
    A_ub[:, M + 2:2 * M + 4, t2] = -1.0
    # t3/t4 >= the phase-2 arms: worker_o pays the common msmax..m_l block
    # for every stream plus the per-stream catch-up m_s[i]..msmax.
    dF_o = F[o_idx, ml] - F[o_idx, msmax]
    dBk_o = Bk[o_idx, ml] - Bk[o_idx, msmax]
    A_ub[:, 2 * M + 4, 0] = dF_o
    A_ub[:, 2 * M + 6, 0] = dBk_o
    for i in range(M):
        A_ub[:, 2 * M + 4, 1 + i] = dF_o + (F[o_idx, msmax] -
                                            F[o_idx, ms[:, i]])
        A_ub[:, 2 * M + 6, 1 + i] = dBk_o + (Bk[o_idx, msmax] -
                                             Bk[o_idx, ms[:, i]])
    A_ub[:, 2 * M + 5, M + 1] = (F[l_idx, ml] - F[l_idx, msmax]) + mo_l
    A_ub[:, 2 * M + 7, M + 1] = (Bk[l_idx, ml] - Bk[l_idx, msmax]) + mg_l
    A_ub[:, 2 * M + 4:2 * M + 6, t3] = -1.0
    A_ub[:, 2 * M + 6:2 * M + 8, t4] = -1.0
    # Constraints (14)/(15): b_s[i] <= m_s[i]*B, b_l <= m_l*B.
    for i in range(M):
        A_ub[:, 2 * M + 8 + i, 1 + i] = 1.0
        b_ub[:, 2 * M + 8 + i] = ms[:, i].astype(np.float64) * B
    A_ub[:, 3 * M + 8, M + 1] = 1.0
    b_ub[:, 3 * M + 8] = ml.astype(np.float64) * B
    # Constraint (17): b_o + sum b_s + b_l = B.
    A_eq = np.zeros((K, 1, nv))
    A_eq[:, 0, :M + 2] = 1.0
    b_eq = np.full((K, 1), float(B))
    return A_ub, b_ub, A_eq, b_eq


def _solve_multi_lps(cost: np.ndarray, A_ub: np.ndarray, b_ub: np.ndarray,
                     A_eq: np.ndarray, b_eq: np.ndarray,
                     backend: str) -> Tuple[np.ndarray, np.ndarray]:
    """Solve a stack of LPs: one stacked simplex call (batched) or a scalar
    loop over the very same tensors (reference oracle)."""
    if backend == "batched":
        res = batched_lp.linprog_batch(cost, A_ub, b_ub, A_eq, b_eq)
        return res.x, res.success
    K, _, nv = A_ub.shape
    x = np.zeros((K, nv))
    ok = np.zeros(K, bool)
    for k in range(K):
        r = lp_mod.linprog(cost, A_ub[k], b_ub[k], A_eq[k], b_eq[k])
        if r.success:
            x[k], ok[k] = r.x, True
    return x, ok


def _multi_schedule_from_lane(profile: MultiProfile, o_idx, s_idx, l_idx,
                              ms, ml, b_int, k: int) -> MultiSchedule:
    names = profile.worker_names
    M = profile.num_streams
    return MultiSchedule(
        worker_o=names[int(o_idx[k])], worker_l=names[int(l_idx[k])],
        s_workers=tuple(names[int(j)] for j in s_idx[k]),
        m_s=tuple(int(v) for v in ms[k]), m_l=int(ml[k]),
        b_o=int(b_int[k, 0]),
        b_s=tuple(int(v) for v in b_int[k, 1:1 + M]),
        b_l=int(b_int[k, 1 + M]))


def solve_multi(profile: MultiProfile, net: StarNetwork, B: int,
                keep_log: bool = False, backend: str = "batched",
                prune: bool = True,
                refine_passes: int = 4,
                objective: str = "latency",
                warm_start: Optional[MultiSchedule] = None
                ) -> MultiSchedulerResult:
    """Deprecated shim over the facade (DESIGN.md §9): build a star fleet
    from the profile/network pair and plan through ``repro.api``."""
    warn_deprecated(
        "repro.core.scheduler.solve_multi()",
        "repro.api.plan(model, Fleet.from_profile(profile, net), B, ...)")
    from repro import api
    return api.plan(None, api.Fleet.from_profile(profile, net), B,
                    objective=objective, backend=backend, prune=prune,
                    refine_passes=refine_passes, keep_log=keep_log,
                    warm_start=warm_start).result


def _solve_multi(profile: MultiProfile, net: StarNetwork, B: int,
                 keep_log: bool = False, backend: str = "batched",
                 prune: bool = True,
                 refine_passes: int = 4,
                 objective: str = "latency",
                 warm_start: Optional[MultiSchedule] = None
                 ) -> MultiSchedulerResult:
    """Generalized Algorithm 1 over M devices + edge + cloud — the
    canonical engine behind ``repro.api.plan`` for star fleets.

    Stage A: exhaustive (mapping, shared-cut) sweep — with ``M == 1`` this
    is exactly :func:`solve` (same candidates, same order, same LPs) and the
    result is bit-identical.  Stage B (``M >= 2``): coordinate descent on
    the per-device cuts ``m_s[i]``, one stacked LP per pass, accepting only
    strict improvements, until a pass yields none or ``refine_passes`` is
    exhausted.  ``backend="reference"`` solves every lane with the scalar
    simplex instead of the stacked one (the correctness oracle).
    ``objective="throughput"`` scores both stages with the steady-state
    period ``t_period_multi`` instead of ``T_total`` (DESIGN.md §7).
    ``warm_start`` feeds a live schedule's exact cost into the dominance
    prune as an extra incumbent — the incremental re-solve of
    DESIGN.md §10, bit-identical to a cold solve (certified per call by
    :func:`_warm_ok`, with a cold re-solve when the certificate fails).
    """
    if backend not in ("batched", "reference"):
        raise ValueError(f"unknown scheduler backend: {backend!r}")
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown scheduler objective: {objective!r}")
    N = profile.num_layers
    M = profile.num_streams       # per-candidate stream count (slots for
    #                               every non-o/non-l worker: devices on a
    #                               star, devices + idle edges on a tree)
    p = profile.prefix()
    F, Bk, U = p["F"], p["Bk"], p["U"]
    cost = np.concatenate([np.zeros(M + 2), np.ones(4)])
    o_idx, s_idx, l_idx, ms, ml = _multi_candidate_grid(
        N, profile.worker_names)
    K = o_idx.shape[0]
    msmax = ms.max(axis=1)

    def score_batch(o, s, l, mss, mll, bb):
        if objective == "latency":
            return _t_total_multi_batch(profile, net, o, s, l, mss, mll,
                                        bb)
        return pipeline_mod.t_period_multi_batch(profile, net, o, s, l,
                                                 mss, mll, bb)

    keep = np.ones(K, bool)
    n_pruned = 0
    incumbent = np.inf
    if prune:
        # Same dominance rule as the 3-worker engine: the T^3 + T_update
        # cut-constants lower-bound T_total for any split — and worker_o's
        # CPU busy time, hence the period, so the prune is valid under
        # either objective (scored against the matching incumbent).
        Bf = float(B)
        const_lb = Bf * (F[o_idx, N] - F[o_idx, ml]) + \
            Bf * (Bk[o_idx, N] - Bk[o_idx, ml]) + U[o_idx, N]
        trivial = (msmax == 0) & (ml == 0)
        b_triv = np.zeros((int(trivial.sum()), M + 2), np.int64)
        b_triv[:, 0] = B
        incumbent = score_batch(o_idx[trivial], s_idx[trivial],
                                l_idx[trivial], ms[trivial], ml[trivial],
                                b_triv).min()
        if warm_start is not None:
            # Warm incumbent: the live schedule's exact cost on this
            # fleet (the incremental re-solve of DESIGN.md §10).
            if warm_start.batch != B:
                raise ValueError(
                    f"warm_start batch {warm_start.batch} != B {B}")
            ws_score = _t_total_multi(profile, net, warm_start).total \
                if objective == "latency" else \
                pipeline_mod.t_period_multi(profile, net, warm_start)
            incumbent = min(incumbent, ws_score)
        keep = ~(const_lb > incumbent)
        n_pruned = int(K - keep.sum())

    ko, kl = o_idx[keep], l_idx[keep]
    ks, kms, kml = s_idx[keep], ms[keep], ml[keep]
    A_ub, b_ub, A_eq, b_eq = _build_multi_lp_stack(profile, net, ko, ks, kl,
                                                   kms, kml, B)
    x, ok = _solve_multi_lps(cost, A_ub, b_ub, A_eq, b_eq, backend)
    n_lp = int(keep.sum())

    allowed = np.concatenate([np.ones((kms.shape[0], 1), bool), kms > 0,
                              (kml > 0)[:, None]], axis=1)
    b_int = _round_batch_split_batch(x[:, :M + 2], B, allowed)
    totals = score_batch(ko, ks, kl, kms, kml, b_int)
    totals = np.where(ok, totals, np.inf)
    if prune and warm_start is not None and \
            not (ok.any() and _warm_ok(float(totals.min()), incumbent)):
        # The warm incumbent over-pruned (the live schedule beat every
        # surviving lane) — bit-identity over speed: re-solve cold.
        return _solve_multi(profile, net, B, keep_log, backend, prune,
                            refine_passes, objective, warm_start=None)
    assert ok.any(), "every per-cut LP failed — inconsistent profile?"
    win = int(np.argmin(totals))  # first min == reference's sequential <

    log: List[Tuple[MultiSchedule, float]] = []
    if keep_log:
        for k in np.nonzero(ok)[0]:
            log.append((_multi_schedule_from_lane(profile, ko, ks, kl, kms,
                                                  kml, b_int, k),
                        float(totals[k])))

    best_sched = _multi_schedule_from_lane(profile, ko, ks, kl, kms, kml,
                                           b_int, win)
    best_score = float(totals[win])   # objective value (latency or period)

    # ---- Stage B: per-device cut refinement (no-op at M == 1, where the
    # stage-A sweep is already exhaustive). ------------------------------
    rounds = 0
    n_lp_refine = 0
    if M >= 2 and refine_passes > 0:
        cur_ms = np.array(best_sched.m_s, np.int64)
        ml0 = int(best_sched.m_l)
        ro = np.full(1, ko[win])
        rs = ks[win][None, :]
        rl = np.full(1, kl[win])
        for _ in range(refine_passes):
            cand = []
            for i in range(M):
                for c in range(ml0 + 1):
                    if c != cur_ms[i]:
                        row = cur_ms.copy()
                        row[i] = c
                        cand.append(row)
            if not cand:
                break
            cms = np.stack(cand)
            Kr = cms.shape[0]
            ro_r, rl_r = np.repeat(ro, Kr), np.repeat(rl, Kr)
            rs_r = np.repeat(rs, Kr, axis=0)
            ml_r = np.full(Kr, ml0)
            A_ub, b_ub, A_eq, b_eq = _build_multi_lp_stack(
                profile, net, ro_r, rs_r, rl_r, cms, ml_r, B)
            x, ok = _solve_multi_lps(cost, A_ub, b_ub, A_eq, b_eq, backend)
            n_lp_refine += Kr
            allowed = np.concatenate(
                [np.ones((Kr, 1), bool), cms > 0,
                 np.full((Kr, 1), ml0 > 0)], axis=1)
            b_int = _round_batch_split_batch(x[:, :M + 2], B, allowed)
            tot = score_batch(ro_r, rs_r, rl_r, cms, ml_r, b_int)
            tot = np.where(ok, tot, np.inf)
            k = int(np.argmin(tot))
            rounds += 1
            if not (tot[k] < best_score):     # strict improvement only
                break
            best_score = float(tot[k])
            best_sched = _multi_schedule_from_lane(
                profile, ro_r, rs_r, rl_r, cms, ml_r, b_int, k)
            cur_ms = np.array(best_sched.m_s, np.int64)
            if keep_log:
                log.append((best_sched, best_score))

    bd = _t_total_multi(profile, net, best_sched)
    return MultiSchedulerResult(schedule=best_sched, breakdown=bd,
                                t_total=bd.total, n_lp_solved=n_lp,
                                search_log=log, n_candidates=K,
                                n_pruned=n_pruned, refine_rounds=rounds,
                                n_lp_refine=n_lp_refine,
                                objective=objective,
                                t_period=pipeline_mod.t_period_multi(
                                    profile, net, best_sched))
