"""Algorithm 1 of the paper: optimal HierTrain scheduling policy.

For every one of the 6 worker-role mappings and every cut pair
``(m_s, m_l)`` with ``0 <= m_s <= m_l <= N``, problem P1 (Eqs. 16-19) with the
cuts fixed is an ILP.  Per §V we relax it to an LP in epigraph form (one
epigraph variable per max-term of Eq. 12), solve, round with the paper's
largest-fraction rule, and keep the schedule with the smallest *exact*
integer-evaluated ``T_total``.

Two backends (DESIGN.md §Scheduler-engine):

* ``backend="batched"`` (default) — builds the constraint tensors for *all*
  ``(mapping, m_s, m_l)`` candidates in one shot from the profile's prefix
  arrays, prunes candidates whose cut-constant lower bound (the ``T^3`` +
  ``T_update`` terms, which the LP cannot change) already exceeds an
  incumbent, solves the survivors as ONE stacked simplex call
  (:mod:`repro.core.batched_lp`), rounds every batch split vectorized, and
  evaluates the exact integer ``T_total`` of all survivors with
  :func:`repro.core.cost_model.t_total_batch` before the argmin.
* ``backend="reference"`` — the original sequential loop over scalar
  two-phase-simplex calls.  Kept as the correctness oracle; the equivalence
  suite asserts both backends return schedules with identical ``T_total``.

:func:`solve_multi` generalizes the search to M heterogeneous devices
around one edge and one cloud (DESIGN.md §6): an exhaustive stage over
every (worker_o, worker_l) mapping and shared-cut pair — bit-identical to
:func:`solve` at M = 1 — followed by batched coordinate descent on the
per-device cuts for M >= 2.

Both solvers take ``objective="latency"`` (default, Eq. 12 ``T_total``)
or ``objective="throughput"``, which reuses the same LP stack and
dominance prune but scores candidates with the pipelined steady-state
period (:mod:`repro.core.pipeline`, DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import batched_lp
from repro.core import lp as lp_mod
from repro.core import pipeline as pipeline_mod
from repro.core._deprecation import warn_deprecated
from repro.core.cost_model import (WIDX, WORKERS, Breakdown, HierProfile,
                                   MultiProfile, MultiSchedule, Network,
                                   Schedule, StarNetwork, _t_total,
                                   _t_total_batch, _t_total_multi,
                                   _t_total_multi_batch, bw_matrix)

OBJECTIVES = ("latency", "throughput")

_LP_NUM_VARS = 7          # [b_o, b_s, b_l, t1, t2, t3, t4]
_LP_NUM_UB = 12           # 10 epigraph arms + constraints (14)/(15)
_LP_COST = np.array([0, 0, 0, 1, 1, 1, 1], np.float64)


@dataclasses.dataclass
class SchedulerResult:
    schedule: Schedule
    breakdown: Breakdown
    t_total: float
    n_lp_solved: int
    search_log: List[Tuple[Schedule, float]]
    n_candidates: int = 0
    n_pruned: int = 0
    objective: str = "latency"
    t_period: Optional[float] = None   # steady-state period of the winner


def _round_batch_split(b_real: np.ndarray, B: int,
                       allowed: np.ndarray) -> np.ndarray:
    """Paper §V rounding: floor everything, then hand the missing units to
    the entries with the largest fractional parts.  Entries with
    ``allowed == False`` (their ``m`` is 0) are forced to exactly 0 — they
    may neither keep an integer part nor receive extra units.  Any residue
    the largest-fraction pass cannot place lands on ``b_o`` (always
    allowed); a floor *overshoot* (LP numerics handing out more than ``B``
    units) is stripped from the largest entries without driving any entry
    below zero, so the result always satisfies ``sum == B`` and ``>= 0``.

    Entries are clamped to ``[0, B]`` up front: every feasible LP point
    satisfies that bound already (Eq. 17 plus nonnegativity), so real
    solutions are untouched, while a failed lane's garbage ``x`` (e.g. a
    phase-2 ray) can no longer make the one-unit strip loop crawl for
    millions of iterations — such lanes are discarded by the caller's
    success mask anyway, but they must still round in bounded time.
    """
    b_real = np.clip(np.asarray(b_real, np.float64), 0.0, float(B))
    allowed = np.asarray(allowed, bool)
    b_real = np.where(allowed, b_real, 0.0)
    ints = np.floor(b_real + 1e-9).astype(np.int64)
    fracs = np.where(allowed, b_real - ints, -1.0)
    deficit = int(B - ints.sum())
    out = ints.copy()
    for idx in np.argsort(-fracs, kind="stable"):
        if deficit <= 0:
            break
        if not allowed[idx]:
            continue
        out[idx] += 1
        deficit -= 1
    if deficit > 0:  # more missing units than entries: dump on b_o
        out[0] += deficit
        deficit = 0
    while deficit < 0:  # overshoot: strip from the largest entries
        idx = int(np.argmax(out))
        if out[idx] <= 0:
            break
        out[idx] -= 1
        deficit += 1
    return out


def _round_batch_split_batch(b_real: np.ndarray, B: int,
                             allowed: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_round_batch_split` over ``[K, 3]`` splits.
    Semantics match the scalar rule exactly (same stable largest-fraction
    order, same residue handling, same ``[0, B]`` clamp), so both
    backends round identically."""
    K = b_real.shape[0]
    ar = np.arange(K)
    b = np.clip(np.asarray(b_real, np.float64), 0.0, float(B))
    b = np.where(allowed, b, 0.0)
    ints = np.floor(b + 1e-9).astype(np.int64)
    fracs = np.where(allowed, b - ints, -1.0)
    deficit = B - ints.sum(axis=1)
    out = ints.copy()
    order = np.argsort(-fracs, axis=1, kind="stable")
    for j in range(order.shape[1]):  # one potential +1 per entry, like scalar
        idx = order[:, j]
        bump = allowed[ar, idx] & (deficit > 0)
        out[ar, idx] += bump
        deficit -= bump
    out[:, 0] += np.maximum(deficit, 0)
    deficit = np.minimum(deficit, 0)
    while (deficit < 0).any():
        idx = np.argmax(out, axis=1)
        strip = (deficit < 0) & (out[ar, idx] > 0)
        if not strip.any():
            break
        out[ar, idx] -= strip
        deficit += strip
    return out


# ---------------------------------------------------------------------------
# Reference backend: sequential scalar LPs (the seed implementation).
# ---------------------------------------------------------------------------

def _solve_cut_lp(profile: HierProfile, net: Network, wo: str, ws: str,
                  wl: str, m_s: int, m_l: int, B: int,
                  origin: str) -> Optional[np.ndarray]:
    """LP relaxation of P1 for a fixed mapping and fixed cuts.

    Variables ``x = [b_o, b_s, b_l, t1, t2, t3, t4] >= 0`` where
    ``t1 >= T^1_fwd``-terms, ``t2 >= T^1_bwd``, ``t3 >= T^2_fwd``,
    ``t4 >= T^2_bwd``.  ``T^3`` and ``T_update`` are constant once the cuts
    are fixed (they involve the full batch ``B`` / only prefix parameter
    sums), so they do not enter the LP objective.
    """
    p = profile.prefix()
    F, Bk = p["F"], p["Bk"]
    o, s, l = WIDX[wo], WIDX[ws], WIDX[wl]
    Q = profile.sample_bytes
    bw_os, bw_ol = net.bw(wo, ws), net.bw(wo, wl)
    in_o = 0.0 if wo == origin else Q / net.bw(origin, wo)
    in_s = 0.0 if ws == origin else Q / net.bw(origin, ws)
    in_l = 0.0 if wl == origin else Q / net.bw(origin, wl)
    mo_s = profile.MO[m_s - 1] / bw_os if m_s > 0 else 0.0
    mo_l = profile.MO[m_l - 1] / bw_ol if m_l > 0 else 0.0
    mg_s = profile.MG[m_s - 1] / bw_os if m_s > 0 else 0.0
    mg_l = profile.MG[m_l - 1] / bw_ol if m_l > 0 else 0.0

    nv = _LP_NUM_VARS
    A_ub, b_ub = [], []

    def ub(coef_b, t_idx):  # coef_b @ [b_o,b_s,b_l] - t <= 0
        row = np.zeros(nv)
        row[:3] = coef_b
        row[3 + t_idx] = -1.0
        A_ub.append(row)
        b_ub.append(0.0)

    # t1 >= each arm of Eq. (5); t2 >= each arm of Eq. (6) (backward arms
    # ship the activation *gradient*: MG-based wire terms).
    ub([in_o + F[o, m_s], 0, 0], 0)
    ub([0, in_s + F[s, m_s] + mo_s, 0], 0)
    ub([0, 0, in_l + F[l, m_s]], 0)
    ub([Bk[o, m_s], 0, 0], 1)
    ub([0, Bk[s, m_s] + mg_s, 0], 1)
    ub([0, 0, Bk[l, m_s]], 1)
    # t3 >= each arm of Eq. (7); t4 >= each arm of Eq. (8).
    ub([F[o, m_l] - F[o, m_s], F[o, m_l] - F[o, m_s], 0], 2)
    ub([0, 0, (F[l, m_l] - F[l, m_s]) + mo_l], 2)
    ub([Bk[o, m_l] - Bk[o, m_s], Bk[o, m_l] - Bk[o, m_s], 0], 3)
    ub([0, 0, (Bk[l, m_l] - Bk[l, m_s]) + mg_l], 3)
    # Constraints (14)/(15): b_s <= m_s*B, b_l <= m_l*B.
    row = np.zeros(nv); row[1] = 1.0
    A_ub.append(row); b_ub.append(float(m_s) * B)
    row = np.zeros(nv); row[2] = 1.0
    A_ub.append(row); b_ub.append(float(m_l) * B)
    # Constraint (17): b_o + b_s + b_l = B.
    A_eq = np.zeros((1, nv)); A_eq[0, :3] = 1.0
    b_eq = np.array([float(B)])

    res = lp_mod.linprog(_LP_COST, np.array(A_ub), np.array(b_ub), A_eq, b_eq)
    if not res.success:
        return None
    return res.x[:3]


def _solve_reference(profile: HierProfile, net: Network, B: int,
                     origin: str, workers: Tuple[str, ...],
                     keep_log: bool,
                     objective: str = "latency") -> SchedulerResult:
    """Algorithm 1, one scalar LP at a time (the correctness oracle).

    ``objective="throughput"`` keeps the same LP relaxation (splits are
    still balanced for latency) but scores every rounded candidate with
    the steady-state period instead of ``T_total`` (DESIGN.md §7).
    """
    N = profile.num_layers
    best: Optional[Tuple[Schedule, Breakdown]] = None
    best_score = np.inf
    n_lp = 0
    log: List[Tuple[Schedule, float]] = []
    for wo, ws, wl in itertools.permutations(workers, 3):
        for m_s in range(0, N + 1):
            for m_l in range(m_s, N + 1):
                n_lp += 1
                b = _solve_cut_lp(profile, net, wo, ws, wl, m_s, m_l, B,
                                  origin)
                if b is None:
                    continue
                allowed = np.array([True, m_s > 0, m_l > 0])
                b_int = _round_batch_split(b, B, allowed)
                sched = Schedule(wo, ws, wl, m_s, m_l,
                                 int(b_int[0]), int(b_int[1]), int(b_int[2]))
                bd = _t_total(profile, net, sched, origin)
                score = bd.total if objective == "latency" else \
                    pipeline_mod.t_period(profile, net, sched, origin)
                if keep_log:
                    log.append((sched, score))
                if best is None or score < best_score:
                    best = (sched, bd)
                    best_score = score
    assert best is not None
    return SchedulerResult(
        schedule=best[0], breakdown=best[1], t_total=best[1].total,
        n_lp_solved=n_lp, search_log=log, n_candidates=n_lp, n_pruned=0,
        objective=objective,
        t_period=pipeline_mod.t_period(profile, net, best[0], origin))


# ---------------------------------------------------------------------------
# Batched backend: one stacked LP over all surviving candidates.
# ---------------------------------------------------------------------------

def _candidate_grid(N: int, workers: Tuple[str, ...]
                    ) -> Tuple[np.ndarray, ...]:
    """All ``(mapping, m_s, m_l)`` candidates in the reference backend's
    enumeration order, as flat index arrays."""
    maps = list(itertools.permutations(workers, 3))
    ms_g, ml_g = np.triu_indices(N + 1)       # row-major == m_s outer loop
    P = ms_g.shape[0]
    o_idx = np.repeat([WIDX[m[0]] for m in maps], P)
    s_idx = np.repeat([WIDX[m[1]] for m in maps], P)
    l_idx = np.repeat([WIDX[m[2]] for m in maps], P)
    ms = np.tile(ms_g, len(maps))
    ml = np.tile(ml_g, len(maps))
    return o_idx, s_idx, l_idx, ms, ml


def _build_lp_stack(profile: HierProfile, net: Network, o_idx: np.ndarray,
                    s_idx: np.ndarray, l_idx: np.ndarray, ms: np.ndarray,
                    ml: np.ndarray, B: int, origin: str
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
    """Constraint tensors of the per-cut LP for all K candidates at once.

    Row layout matches :func:`_solve_cut_lp` one-to-one.
    """
    p = profile.prefix()
    F, Bk = p["F"], p["Bk"]
    K = o_idx.shape[0]
    Q = profile.sample_bytes
    bwm = bw_matrix(net)
    oi = WIDX[origin]
    bw_os = bwm[o_idx, s_idx]
    bw_ol = bwm[o_idx, l_idx]
    in_o = np.where(o_idx == oi, 0.0, Q / bwm[oi, o_idx])
    in_s = np.where(s_idx == oi, 0.0, Q / bwm[oi, s_idx])
    in_l = np.where(l_idx == oi, 0.0, Q / bwm[oi, l_idx])
    mo_s = np.where(ms > 0, profile.MO[np.maximum(ms, 1) - 1] / bw_os, 0.0)
    mo_l = np.where(ml > 0, profile.MO[np.maximum(ml, 1) - 1] / bw_ol, 0.0)
    mg_s = np.where(ms > 0, profile.MG[np.maximum(ms, 1) - 1] / bw_os, 0.0)
    mg_l = np.where(ml > 0, profile.MG[np.maximum(ml, 1) - 1] / bw_ol, 0.0)

    A_ub = np.zeros((K, _LP_NUM_UB, _LP_NUM_VARS))
    b_ub = np.zeros((K, _LP_NUM_UB))
    # t1 >= each arm of Eq. (5); t2 >= each arm of Eq. (6) (backward arms
    # use the MG-based gradient wire terms).
    A_ub[:, 0, 0] = in_o + F[o_idx, ms]
    A_ub[:, 1, 1] = in_s + F[s_idx, ms] + mo_s
    A_ub[:, 2, 2] = in_l + F[l_idx, ms]
    A_ub[:, 3, 0] = Bk[o_idx, ms]
    A_ub[:, 4, 1] = Bk[s_idx, ms] + mg_s
    A_ub[:, 5, 2] = Bk[l_idx, ms]
    A_ub[:, :3, 3] = -1.0
    A_ub[:, 3:6, 4] = -1.0
    # t3 >= each arm of Eq. (7); t4 >= each arm of Eq. (8).
    dF_o = F[o_idx, ml] - F[o_idx, ms]
    dBk_o = Bk[o_idx, ml] - Bk[o_idx, ms]
    A_ub[:, 6, 0] = dF_o
    A_ub[:, 6, 1] = dF_o
    A_ub[:, 7, 2] = (F[l_idx, ml] - F[l_idx, ms]) + mo_l
    A_ub[:, 8, 0] = dBk_o
    A_ub[:, 8, 1] = dBk_o
    A_ub[:, 9, 2] = (Bk[l_idx, ml] - Bk[l_idx, ms]) + mg_l
    A_ub[:, 6:8, 5] = -1.0
    A_ub[:, 8:10, 6] = -1.0
    # Constraints (14)/(15): b_s <= m_s*B, b_l <= m_l*B.
    A_ub[:, 10, 1] = 1.0
    b_ub[:, 10] = ms.astype(np.float64) * B
    A_ub[:, 11, 2] = 1.0
    b_ub[:, 11] = ml.astype(np.float64) * B
    # Constraint (17): b_o + b_s + b_l = B.
    A_eq = np.zeros((K, 1, _LP_NUM_VARS))
    A_eq[:, 0, :3] = 1.0
    b_eq = np.full((K, 1), float(B))
    return A_ub, b_ub, A_eq, b_eq


def _warm_ok(totals_win: float, incumbent: float) -> bool:
    """Soundness certificate for a warm-started prune (DESIGN.md §10).

    The prune drops lanes with ``const_lb > incumbent``.  If the best
    *surviving* exact score is ``<= incumbent``, then (a) every pruned
    lane scores strictly above it (``score >= const_lb > incumbent``),
    so the cold argmin lane survived, and (b) the order-preserving mask
    kept it the first minimum — the warm result is bit-identical to the
    cold one.  If instead every survivor scores above the incumbent (the
    warm schedule beat the whole surviving grid), a pruned lane could
    have been the cold winner and the caller must re-solve cold.
    """
    return totals_win <= incumbent


def _score_3w(profile: HierProfile, net: Network, objective: str,
              origin: str, o, s, l, mss, mll, bb) -> np.ndarray:
    """Objective scores of K rounded 3-worker candidates (exact eval)."""
    if objective == "latency":
        return _t_total_batch(profile, net, o, s, l, mss, mll, bb, origin)
    return pipeline_mod.t_period_batch(profile, net, o, s, l, mss, mll,
                                       bb, origin)


@dataclasses.dataclass
class _StageA3W:
    """One 3-worker fleet's pruned stage-A candidate lanes + LP stack.

    Built by :func:`_stage_a_3w`, consumed by :func:`_finish_3w`; the
    cross-fleet engine (:func:`solve_many`) concatenates many fleets'
    ``stack`` tensors into one padded simplex call.
    """
    profile: HierProfile
    net: Network
    B: int
    origin: str
    objective: str
    warm: bool                 # prune ran with a warm incumbent
    ko: np.ndarray
    ks: np.ndarray
    kl: np.ndarray
    kms: np.ndarray
    kml: np.ndarray
    K: int
    n_pruned: int
    incumbent: float
    stack: Tuple[np.ndarray, ...]   # (cost, A_ub, b_ub, A_eq, b_eq)


def _stage_a_3w(profile: HierProfile, net: Network, B: int, origin: str,
                workers: Tuple[str, ...], prune: bool, objective: str,
                warm_start: Optional[Schedule]) -> _StageA3W:
    N = profile.num_layers
    p = profile.prefix()
    F, Bk, U = p["F"], p["Bk"], p["U"]
    o_idx, s_idx, l_idx, ms, ml = _candidate_grid(N, workers)
    K = o_idx.shape[0]

    # Dominance pruning: the T^3 + T_update terms of Eq. (12) do not depend
    # on the batch split, so  B*(F_o[N]-F_o[ml]) + B*(Bk_o[N]-Bk_o[ml]) +
    # U_o[N]  lower-bounds any schedule with these cuts.  Candidates whose
    # bound already exceeds the best ``(m_s = m_l = 0)`` schedule (whose LP
    # is trivial: everything on worker_o) cannot win — skip their LPs.
    # The same constants sit inside worker_o's CPU busy time, so the bound
    # also lower-bounds the steady-state period and the prune stays valid
    # under objective="throughput" (scored against the period incumbent).
    keep = np.ones(K, bool)
    n_pruned = 0
    incumbent = np.inf
    if prune:
        Bf = float(B)
        const_lb = Bf * (F[o_idx, N] - F[o_idx, ml]) + \
            Bf * (Bk[o_idx, N] - Bk[o_idx, ml]) + U[o_idx, N]
        trivial = (ms == 0) & (ml == 0)
        b_triv = np.zeros((int(trivial.sum()), 3), np.int64)
        b_triv[:, 0] = B
        incumbent = _score_3w(profile, net, objective, origin,
                              o_idx[trivial], s_idx[trivial],
                              l_idx[trivial], ms[trivial], ml[trivial],
                              b_triv).min()
        if warm_start is not None:
            # Warm incumbent: the live schedule's exact cost on this
            # fleet (the incremental re-solve of DESIGN.md §10).
            if warm_start.batch != B:
                raise ValueError(
                    f"warm_start batch {warm_start.batch} != B {B}")
            ws_score = _t_total(profile, net, warm_start, origin).total \
                if objective == "latency" else \
                pipeline_mod.t_period(profile, net, warm_start, origin)
            incumbent = min(incumbent, ws_score)
        keep = ~(const_lb > incumbent)
        n_pruned = int(K - keep.sum())

    ko, ks, kl = o_idx[keep], s_idx[keep], l_idx[keep]
    kms, kml = ms[keep], ml[keep]
    A_ub, b_ub, A_eq, b_eq = _build_lp_stack(profile, net, ko, ks, kl,
                                             kms, kml, B, origin)
    return _StageA3W(profile=profile, net=net, B=B, origin=origin,
                     objective=objective,
                     warm=prune and warm_start is not None,
                     ko=ko, ks=ks, kl=kl, kms=kms, kml=kml, K=K,
                     n_pruned=n_pruned, incumbent=incumbent,
                     stack=(_LP_COST, A_ub, b_ub, A_eq, b_eq))


def _finish_3w(st: _StageA3W, x: np.ndarray, ok: np.ndarray,
               keep_log: bool) -> Optional[SchedulerResult]:
    """Round, score and argmin one fleet's solved stage-A lanes.

    Returns ``None`` when a warm incumbent over-pruned (the caller must
    re-solve cold — bit-identity over speed, DESIGN.md §10).
    """
    profile, net, B, origin = st.profile, st.net, st.B, st.origin
    ko, ks, kl, kms, kml = st.ko, st.ks, st.kl, st.kms, st.kml
    allowed = np.stack([np.ones_like(kms, bool), kms > 0, kml > 0], axis=1)
    b_int = _round_batch_split_batch(x[:, :3], B, allowed)
    totals = _score_3w(profile, net, st.objective, origin,
                       ko, ks, kl, kms, kml, b_int)
    totals = np.where(ok, totals, np.inf)
    if st.warm and not (ok.any() and
                        _warm_ok(float(totals.min()), st.incumbent)):
        # The warm incumbent over-pruned (the live schedule beat every
        # surviving lane) — bit-identity over speed: re-solve cold.
        return None
    assert ok.any(), "every per-cut LP failed — inconsistent profile?"
    win = int(np.argmin(totals))  # first min == reference's sequential <

    inv = {i: w for w, i in WIDX.items()}
    sched = Schedule(inv[int(ko[win])], inv[int(ks[win])], inv[int(kl[win])],
                     int(kms[win]), int(kml[win]),
                     int(b_int[win, 0]), int(b_int[win, 1]),
                     int(b_int[win, 2]))
    bd = _t_total(profile, net, sched, origin)
    log: List[Tuple[Schedule, float]] = []
    if keep_log:
        for k in np.nonzero(ok)[0]:
            log.append((Schedule(
                inv[int(ko[k])], inv[int(ks[k])], inv[int(kl[k])],
                int(kms[k]), int(kml[k]), int(b_int[k, 0]),
                int(b_int[k, 1]), int(b_int[k, 2])), float(totals[k])))
    return SchedulerResult(schedule=sched, breakdown=bd, t_total=bd.total,
                           n_lp_solved=int(ko.shape[0]), search_log=log,
                           n_candidates=st.K, n_pruned=st.n_pruned,
                           objective=st.objective,
                           t_period=pipeline_mod.t_period(profile, net,
                                                          sched, origin))


def _solve_batched(profile: HierProfile, net: Network, B: int, origin: str,
                   workers: Tuple[str, ...], keep_log: bool,
                   prune: bool, objective: str = "latency",
                   warm_start: Optional[Schedule] = None) -> SchedulerResult:
    st = _stage_a_3w(profile, net, B, origin, workers, prune, objective,
                     warm_start)
    res = batched_lp.linprog_batch(*st.stack)
    out = _finish_3w(st, res.x, res.success, keep_log)
    if out is None:
        return _solve_batched(profile, net, B, origin, workers, keep_log,
                              prune, objective, warm_start=None)
    return out


def _solve_3w(profile: HierProfile, net: Network, B: int,
              origin: str = "device",
              workers: Tuple[str, ...] = WORKERS,
              keep_log: bool = False,
              backend: str = "batched",
              prune: bool = True,
              objective: str = "latency",
              warm_start: Optional[Schedule] = None) -> SchedulerResult:
    """Algorithm 1: enumerate mappings x cuts, LP + round, return the best.

    This is the canonical *three-worker* engine — the facade
    (``repro.api.plan``) runs it for triple-native fleets, and it doubles
    as the correctness oracle the M=1 equivalence suite compares the
    generalized engine against.  ``backend="batched"`` (default) solves
    all candidate LPs as one stacked simplex; ``backend="reference"`` is
    the sequential scalar oracle.  ``prune`` toggles the cut-constant
    dominance bound (batched only).  ``objective="latency"`` (default)
    minimizes the per-iteration ``T_total`` of Eq. 12;
    ``objective="throughput"`` reuses the same LP stack and pruning but
    picks the candidate with the smallest steady-state pipelined period
    ``t_period`` (DESIGN.md §7).  ``warm_start`` feeds a live schedule's
    exact cost into the dominance prune as an extra incumbent — an
    incremental re-solve that returns bit-identical results to a cold
    solve (DESIGN.md §10) while skipping more of the candidate grid.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown scheduler objective: {objective!r}")
    if backend == "reference":
        # The oracle has no prune, so a warm incumbent cannot change it.
        return _solve_reference(profile, net, B, origin, workers, keep_log,
                                objective)
    if backend != "batched":
        raise ValueError(f"unknown scheduler backend: {backend!r}")
    return _solve_batched(profile, net, B, origin, workers, keep_log, prune,
                          objective, warm_start)


def solve(profile: HierProfile, net: Network, B: int,
          origin: str = "device",
          workers: Tuple[str, ...] = WORKERS,
          keep_log: bool = False,
          backend: str = "batched",
          prune: bool = True,
          objective: str = "latency",
          warm_start: Optional[Schedule] = None) -> SchedulerResult:
    """Deprecated shim over the facade (DESIGN.md §9): build a triple
    fleet from the profile/network pair and plan through ``repro.api``.
    Results are bit-identical to the historical solver.  Exotic
    arguments the facade does not model (``origin != "device"``, custom
    ``workers`` subsets) fall back to the retained 3-worker engine."""
    warn_deprecated(
        "repro.core.scheduler.solve()",
        "repro.api.plan(model, Fleet.from_profile(profile, net), B, ...)")
    if origin == "device" and tuple(workers) == WORKERS:
        from repro import api
        return api.plan(None, api.Fleet.from_profile(profile, net), B,
                        objective=objective, backend=backend, prune=prune,
                        keep_log=keep_log, warm_start=warm_start).result
    return _solve_3w(profile, net, B, origin, workers, keep_log, backend,
                     prune, objective, warm_start)


# ---------------------------------------------------------------------------
# M-device scheduler (DESIGN.md §6).
#
# Stage A enumerates every (worker_o, worker_l) mapping x every *shared*
# cut pair (all TASK-S instances at the same m_s) — with M = 1 that IS the
# paper's Algorithm 1 search space in the reference enumeration order, so
# the M=1 result is bit-identical to solve().  Stage B (M >= 2 only)
# coordinate-descends the per-device cuts: every single-cut move is scored
# by one more stacked LP pass, and only strict improvements are accepted.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiSchedulerResult:
    schedule: MultiSchedule
    breakdown: Breakdown
    t_total: float
    n_lp_solved: int          # stage-A LPs: n_candidates - n_pruned
    search_log: List[Tuple[MultiSchedule, float]]
    n_candidates: int = 0
    n_pruned: int = 0
    refine_rounds: int = 0
    n_lp_refine: int = 0      # stage-B LPs, counted separately
    objective: str = "latency"
    t_period: Optional[float] = None   # steady-state period of the winner


def _multi_candidate_grid(N: int, worker_names: Tuple[str, ...]
                          ) -> Tuple[np.ndarray, ...]:
    """All (mapping, shared m_s, m_l) candidates.

    Mapping order — ``worker_o`` outer, ``worker_l`` over the *reversed*
    remaining workers — reproduces the 3-worker ``itertools.permutations``
    (o, s, l) order at M = 1, so first-min tie-breaks match the reference
    scheduler exactly.
    """
    W = len(worker_names)
    M = W - 2
    widx = {w: i for i, w in enumerate(worker_names)}
    maps = []
    for wo in worker_names:
        rest = [w for w in worker_names if w != wo]
        for wl in reversed(rest):
            s_set = tuple(w for w in rest if w != wl)
            maps.append((widx[wo], widx[wl],
                         tuple(widx[w] for w in s_set)))
    ms_g, ml_g = np.triu_indices(N + 1)       # row-major == m_s outer loop
    P = ms_g.shape[0]
    o_idx = np.repeat([m[0] for m in maps], P)
    l_idx = np.repeat([m[1] for m in maps], P)
    s_idx = np.repeat(np.array([m[2] for m in maps], np.int64), P, axis=0)
    ms = np.tile(ms_g, len(maps))[:, None] * np.ones((1, M), np.int64)
    ml = np.tile(ml_g, len(maps))
    return o_idx, s_idx, l_idx, ms, ml


def _build_multi_lp_stack(profile: MultiProfile, net: StarNetwork,
                          o_idx: np.ndarray, s_idx: np.ndarray,
                          l_idx: np.ndarray, ms: np.ndarray, ml: np.ndarray,
                          B: int) -> Tuple[np.ndarray, np.ndarray,
                                           np.ndarray, np.ndarray]:
    """Constraint tensors of the per-cut LP for all K candidates.

    Variables ``x = [b_o, b_s[0..M-1], b_l, t1, t2, t3, t4] >= 0``
    (``M + 6`` of them); ``3M + 9`` inequality rows laid out exactly like
    :func:`_build_lp_stack` at M = 1 (same rows, same order, same
    coefficients), so the stacked simplex walks the same pivot path.
    """
    p = profile.prefix()
    F, Bk = p["F"], p["Bk"]
    D = profile.num_devices       # data holders (locality), not streams
    M = profile.num_streams       # stream count: the LP's variable layout
    K = o_idx.shape[0]
    nv = M + 6
    t1, t2, t3, t4 = M + 2, M + 3, M + 4, M + 5
    Q = profile.sample_bytes
    bwm = net.bw_matrix()
    up = net.upload_bw()
    msmax = ms.max(axis=1)
    o2 = o_idx[:, None]

    bw_os = bwm[o2, s_idx]                                  # [K, M]
    bw_ol = bwm[o_idx, l_idx]
    in_o = np.where(o_idx < D, 0.0, Q / up[o_idx])
    in_s = np.where(s_idx < D, 0.0, Q / up[s_idx])
    in_l = np.where(l_idx < D, 0.0, Q / up[l_idx])
    mo_s = np.where(ms > 0, profile.MO[np.maximum(ms, 1) - 1] / bw_os, 0.0)
    mo_l = np.where(ml > 0, profile.MO[np.maximum(ml, 1) - 1] / bw_ol, 0.0)
    mg_s = np.where(ms > 0, profile.MG[np.maximum(ms, 1) - 1] / bw_os, 0.0)
    mg_l = np.where(ml > 0, profile.MG[np.maximum(ml, 1) - 1] / bw_ol, 0.0)

    A_ub = np.zeros((K, 3 * M + 9, nv))
    b_ub = np.zeros((K, 3 * M + 9))
    # t1 >= each phase-1 forward arm; t2 >= each phase-1 backward arm
    # (backward arms use the MG-based gradient wire terms).
    A_ub[:, 0, 0] = in_o + F[o_idx, msmax]
    for i in range(M):
        A_ub[:, 1 + i, 1 + i] = in_s[:, i] + F[s_idx[:, i], ms[:, i]] + \
            mo_s[:, i]
    A_ub[:, M + 1, M + 1] = in_l + F[l_idx, msmax]
    A_ub[:, M + 2, 0] = Bk[o_idx, msmax]
    for i in range(M):
        A_ub[:, M + 3 + i, 1 + i] = Bk[s_idx[:, i], ms[:, i]] + mg_s[:, i]
    A_ub[:, 2 * M + 3, M + 1] = Bk[l_idx, msmax]
    A_ub[:, :M + 2, t1] = -1.0
    A_ub[:, M + 2:2 * M + 4, t2] = -1.0
    # t3/t4 >= the phase-2 arms: worker_o pays the common msmax..m_l block
    # for every stream plus the per-stream catch-up m_s[i]..msmax.
    dF_o = F[o_idx, ml] - F[o_idx, msmax]
    dBk_o = Bk[o_idx, ml] - Bk[o_idx, msmax]
    A_ub[:, 2 * M + 4, 0] = dF_o
    A_ub[:, 2 * M + 6, 0] = dBk_o
    for i in range(M):
        A_ub[:, 2 * M + 4, 1 + i] = dF_o + (F[o_idx, msmax] -
                                            F[o_idx, ms[:, i]])
        A_ub[:, 2 * M + 6, 1 + i] = dBk_o + (Bk[o_idx, msmax] -
                                             Bk[o_idx, ms[:, i]])
    A_ub[:, 2 * M + 5, M + 1] = (F[l_idx, ml] - F[l_idx, msmax]) + mo_l
    A_ub[:, 2 * M + 7, M + 1] = (Bk[l_idx, ml] - Bk[l_idx, msmax]) + mg_l
    A_ub[:, 2 * M + 4:2 * M + 6, t3] = -1.0
    A_ub[:, 2 * M + 6:2 * M + 8, t4] = -1.0
    # Constraints (14)/(15): b_s[i] <= m_s[i]*B, b_l <= m_l*B.
    for i in range(M):
        A_ub[:, 2 * M + 8 + i, 1 + i] = 1.0
        b_ub[:, 2 * M + 8 + i] = ms[:, i].astype(np.float64) * B
    A_ub[:, 3 * M + 8, M + 1] = 1.0
    b_ub[:, 3 * M + 8] = ml.astype(np.float64) * B
    # Constraint (17): b_o + sum b_s + b_l = B.
    A_eq = np.zeros((K, 1, nv))
    A_eq[:, 0, :M + 2] = 1.0
    b_eq = np.full((K, 1), float(B))
    return A_ub, b_ub, A_eq, b_eq


def _solve_multi_lps(cost: np.ndarray, A_ub: np.ndarray, b_ub: np.ndarray,
                     A_eq: np.ndarray, b_eq: np.ndarray,
                     backend: str) -> Tuple[np.ndarray, np.ndarray]:
    """Solve a stack of LPs: one stacked simplex call (batched) or a scalar
    loop over the very same tensors (reference oracle)."""
    if backend == "batched":
        res = batched_lp.linprog_batch(cost, A_ub, b_ub, A_eq, b_eq)
        return res.x, res.success
    K, _, nv = A_ub.shape
    x = np.zeros((K, nv))
    ok = np.zeros(K, bool)
    for k in range(K):
        r = lp_mod.linprog(cost, A_ub[k], b_ub[k], A_eq[k], b_eq[k])
        if r.success:
            x[k], ok[k] = r.x, True
    return x, ok


def _multi_schedule_from_lane(profile: MultiProfile, o_idx, s_idx, l_idx,
                              ms, ml, b_int, k: int) -> MultiSchedule:
    names = profile.worker_names
    M = profile.num_streams
    return MultiSchedule(
        worker_o=names[int(o_idx[k])], worker_l=names[int(l_idx[k])],
        s_workers=tuple(names[int(j)] for j in s_idx[k]),
        m_s=tuple(int(v) for v in ms[k]), m_l=int(ml[k]),
        b_o=int(b_int[k, 0]),
        b_s=tuple(int(v) for v in b_int[k, 1:1 + M]),
        b_l=int(b_int[k, 1 + M]))


def solve_multi(profile: MultiProfile, net: StarNetwork, B: int,
                keep_log: bool = False, backend: str = "batched",
                prune: bool = True,
                refine_passes: int = 4,
                objective: str = "latency",
                warm_start: Optional[MultiSchedule] = None
                ) -> MultiSchedulerResult:
    """Deprecated shim over the facade (DESIGN.md §9): build a star fleet
    from the profile/network pair and plan through ``repro.api``."""
    warn_deprecated(
        "repro.core.scheduler.solve_multi()",
        "repro.api.plan(model, Fleet.from_profile(profile, net), B, ...)")
    from repro import api
    return api.plan(None, api.Fleet.from_profile(profile, net), B,
                    objective=objective, backend=backend, prune=prune,
                    refine_passes=refine_passes, keep_log=keep_log,
                    warm_start=warm_start).result


def _solve_multi(profile: MultiProfile, net: StarNetwork, B: int,
                 keep_log: bool = False, backend: str = "batched",
                 prune: bool = True,
                 refine_passes: int = 4,
                 objective: str = "latency",
                 warm_start: Optional[MultiSchedule] = None
                 ) -> MultiSchedulerResult:
    """Generalized Algorithm 1 over M devices + edge + cloud — the
    canonical engine behind ``repro.api.plan`` for star fleets.

    Stage A: exhaustive (mapping, shared-cut) sweep — with ``M == 1`` this
    is exactly :func:`solve` (same candidates, same order, same LPs) and the
    result is bit-identical.  Stage B (``M >= 2``): coordinate descent on
    the per-device cuts ``m_s[i]``, one stacked LP per pass, accepting only
    strict improvements, until a pass yields none or ``refine_passes`` is
    exhausted.  ``backend="reference"`` solves every lane with the scalar
    simplex instead of the stacked one (the correctness oracle).
    ``objective="throughput"`` scores both stages with the steady-state
    period ``t_period_multi`` instead of ``T_total`` (DESIGN.md §7).
    ``warm_start`` feeds a live schedule's exact cost into the dominance
    prune as an extra incumbent — the incremental re-solve of
    DESIGN.md §10, bit-identical to a cold solve (certified per call by
    :func:`_warm_ok`, with a cold re-solve when the certificate fails).
    """
    if backend not in ("batched", "reference"):
        raise ValueError(f"unknown scheduler backend: {backend!r}")
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown scheduler objective: {objective!r}")
    st = _stage_a_multi(profile, net, B, prune, objective, warm_start)
    x, ok = _solve_multi_lps(*st.stack, backend)
    search = _finish_multi(st, x, ok, keep_log, refine_passes)
    if search is None:
        # The warm incumbent over-pruned (the live schedule beat every
        # surviving lane) — bit-identity over speed: re-solve cold.
        return _solve_multi(profile, net, B, keep_log, backend, prune,
                            refine_passes, objective, warm_start=None)
    while True:
        stack = search.next_stack()
        if stack is None:
            break
        x, ok = _solve_multi_lps(*stack, backend)
        search.step(x, ok)
    return search.result()


def _score_multi(profile: MultiProfile, net: StarNetwork, objective: str,
                 o, s, l, mss, mll, bb) -> np.ndarray:
    """Objective scores of K rounded multi-device candidates (exact eval)."""
    if objective == "latency":
        return _t_total_multi_batch(profile, net, o, s, l, mss, mll, bb)
    return pipeline_mod.t_period_multi_batch(profile, net, o, s, l,
                                             mss, mll, bb)


@dataclasses.dataclass
class _StageAMulti:
    """One star/tree fleet's pruned stage-A lanes + LP stack (multi analog
    of :class:`_StageA3W`, consumed by :func:`_finish_multi`)."""
    profile: MultiProfile
    net: StarNetwork
    B: int
    objective: str
    warm: bool
    cost: np.ndarray
    ko: np.ndarray
    ks: np.ndarray
    kl: np.ndarray
    kms: np.ndarray
    kml: np.ndarray
    K: int
    n_pruned: int
    n_lp: int
    incumbent: float
    stack: Tuple[np.ndarray, ...]   # (cost, A_ub, b_ub, A_eq, b_eq)


def _stage_a_multi(profile: MultiProfile, net: StarNetwork, B: int,
                   prune: bool, objective: str,
                   warm_start: Optional[MultiSchedule]) -> _StageAMulti:
    N = profile.num_layers
    M = profile.num_streams       # per-candidate stream count (slots for
    #                               every non-o/non-l worker: devices on a
    #                               star, devices + idle edges on a tree)
    p = profile.prefix()
    F, Bk, U = p["F"], p["Bk"], p["U"]
    cost = np.concatenate([np.zeros(M + 2), np.ones(4)])
    o_idx, s_idx, l_idx, ms, ml = _multi_candidate_grid(
        N, profile.worker_names)
    K = o_idx.shape[0]
    msmax = ms.max(axis=1)

    keep = np.ones(K, bool)
    n_pruned = 0
    incumbent = np.inf
    if prune:
        # Same dominance rule as the 3-worker engine: the T^3 + T_update
        # cut-constants lower-bound T_total for any split — and worker_o's
        # CPU busy time, hence the period, so the prune is valid under
        # either objective (scored against the matching incumbent).
        Bf = float(B)
        const_lb = Bf * (F[o_idx, N] - F[o_idx, ml]) + \
            Bf * (Bk[o_idx, N] - Bk[o_idx, ml]) + U[o_idx, N]
        trivial = (msmax == 0) & (ml == 0)
        b_triv = np.zeros((int(trivial.sum()), M + 2), np.int64)
        b_triv[:, 0] = B
        incumbent = _score_multi(profile, net, objective,
                                 o_idx[trivial], s_idx[trivial],
                                 l_idx[trivial], ms[trivial], ml[trivial],
                                 b_triv).min()
        if warm_start is not None:
            # Warm incumbent: the live schedule's exact cost on this
            # fleet (the incremental re-solve of DESIGN.md §10).
            if warm_start.batch != B:
                raise ValueError(
                    f"warm_start batch {warm_start.batch} != B {B}")
            ws_score = _t_total_multi(profile, net, warm_start).total \
                if objective == "latency" else \
                pipeline_mod.t_period_multi(profile, net, warm_start)
            incumbent = min(incumbent, ws_score)
        keep = ~(const_lb > incumbent)
        n_pruned = int(K - keep.sum())

    ko, kl = o_idx[keep], l_idx[keep]
    ks, kms, kml = s_idx[keep], ms[keep], ml[keep]
    A_ub, b_ub, A_eq, b_eq = _build_multi_lp_stack(profile, net, ko, ks, kl,
                                                   kms, kml, B)
    return _StageAMulti(profile=profile, net=net, B=B, objective=objective,
                        warm=prune and warm_start is not None, cost=cost,
                        ko=ko, ks=ks, kl=kl, kms=kms, kml=kml, K=K,
                        n_pruned=n_pruned, n_lp=int(keep.sum()),
                        incumbent=incumbent,
                        stack=(cost, A_ub, b_ub, A_eq, b_eq))


class _MultiRefine:
    """Stage-B coordinate descent as an explicit (build, solve, step) state
    machine, so the per-fleet loop in :func:`_solve_multi` and the
    cross-fleet lockstep loop in :func:`solve_many` share one code path —
    the per-pass operations are identical, hence results stay bit-identical.
    """

    def __init__(self, st: _StageAMulti, win: int,
                 best_sched: MultiSchedule, best_score: float,
                 log: List[Tuple[MultiSchedule, float]], keep_log: bool,
                 refine_passes: int):
        self.st = st
        self.best_sched = best_sched
        self.best_score = best_score   # objective value (latency or period)
        self.log = log
        self.keep_log = keep_log
        self.rounds = 0
        self.n_lp_refine = 0
        M = st.profile.num_streams
        # Stage B is a no-op at M == 1, where stage A is already exhaustive.
        self._active = M >= 2 and refine_passes > 0
        self._passes_left = refine_passes
        if self._active:
            self._cur_ms = np.array(best_sched.m_s, np.int64)
            self._ml0 = int(best_sched.m_l)
            self._ro = np.full(1, st.ko[win])
            self._rs = st.ks[win][None, :]
            self._rl = np.full(1, st.kl[win])

    def next_stack(self) -> Optional[Tuple[np.ndarray, ...]]:
        """Build the next pass's single-cut-move LP stack, or ``None`` when
        refinement has converged / exhausted its pass budget."""
        if not self._active or self._passes_left <= 0:
            return None
        M = self.st.profile.num_streams
        cand = []
        for i in range(M):
            for c in range(self._ml0 + 1):
                if c != self._cur_ms[i]:
                    row = self._cur_ms.copy()
                    row[i] = c
                    cand.append(row)
        if not cand:
            self._active = False
            return None
        cms = np.stack(cand)
        Kr = cms.shape[0]
        self._cms = cms
        self._ro_r, self._rl_r = np.repeat(self._ro, Kr), \
            np.repeat(self._rl, Kr)
        self._rs_r = np.repeat(self._rs, Kr, axis=0)
        self._ml_r = np.full(Kr, self._ml0)
        A_ub, b_ub, A_eq, b_eq = _build_multi_lp_stack(
            self.st.profile, self.st.net, self._ro_r, self._rs_r,
            self._rl_r, cms, self._ml_r, self.st.B)
        return (self.st.cost, A_ub, b_ub, A_eq, b_eq)

    def step(self, x: np.ndarray, ok: np.ndarray) -> None:
        """Score the solved pass; accept a strict improvement or converge."""
        st = self.st
        cms, ml0 = self._cms, self._ml0
        Kr = cms.shape[0]
        M = st.profile.num_streams
        self.n_lp_refine += Kr
        self._passes_left -= 1
        allowed = np.concatenate(
            [np.ones((Kr, 1), bool), cms > 0,
             np.full((Kr, 1), ml0 > 0)], axis=1)
        b_int = _round_batch_split_batch(x[:, :M + 2], st.B, allowed)
        tot = _score_multi(st.profile, st.net, st.objective, self._ro_r,
                           self._rs_r, self._rl_r, cms, self._ml_r, b_int)
        tot = np.where(ok, tot, np.inf)
        k = int(np.argmin(tot))
        self.rounds += 1
        if not (tot[k] < self.best_score):     # strict improvement only
            self._active = False
            return
        self.best_score = float(tot[k])
        self.best_sched = _multi_schedule_from_lane(
            st.profile, self._ro_r, self._rs_r, self._rl_r, cms, self._ml_r,
            b_int, k)
        self._cur_ms = np.array(self.best_sched.m_s, np.int64)
        if self.keep_log:
            self.log.append((self.best_sched, self.best_score))

    def result(self) -> MultiSchedulerResult:
        st = self.st
        bd = _t_total_multi(st.profile, st.net, self.best_sched)
        return MultiSchedulerResult(
            schedule=self.best_sched, breakdown=bd, t_total=bd.total,
            n_lp_solved=st.n_lp, search_log=self.log, n_candidates=st.K,
            n_pruned=st.n_pruned, refine_rounds=self.rounds,
            n_lp_refine=self.n_lp_refine, objective=st.objective,
            t_period=pipeline_mod.t_period_multi(st.profile, st.net,
                                                 self.best_sched))


def _finish_multi(st: _StageAMulti, x: np.ndarray, ok: np.ndarray,
                  keep_log: bool, refine_passes: int
                  ) -> Optional[_MultiRefine]:
    """Round/score/argmin one fleet's stage-A lanes; hand off to stage B.

    Returns ``None`` when a warm incumbent over-pruned (caller re-solves
    cold), else a :class:`_MultiRefine` primed with the stage-A winner.
    """
    profile, net, B = st.profile, st.net, st.B
    ko, ks, kl, kms, kml = st.ko, st.ks, st.kl, st.kms, st.kml
    M = profile.num_streams
    allowed = np.concatenate([np.ones((kms.shape[0], 1), bool), kms > 0,
                              (kml > 0)[:, None]], axis=1)
    b_int = _round_batch_split_batch(x[:, :M + 2], B, allowed)
    totals = _score_multi(profile, net, st.objective,
                          ko, ks, kl, kms, kml, b_int)
    totals = np.where(ok, totals, np.inf)
    if st.warm and not (ok.any() and
                        _warm_ok(float(totals.min()), st.incumbent)):
        return None
    assert ok.any(), "every per-cut LP failed — inconsistent profile?"
    win = int(np.argmin(totals))  # first min == reference's sequential <

    log: List[Tuple[MultiSchedule, float]] = []
    if keep_log:
        for k in np.nonzero(ok)[0]:
            log.append((_multi_schedule_from_lane(profile, ko, ks, kl, kms,
                                                  kml, b_int, k),
                        float(totals[k])))
    best_sched = _multi_schedule_from_lane(profile, ko, ks, kl, kms, kml,
                                           b_int, win)
    return _MultiRefine(st, win, best_sched, float(totals[win]), log,
                        keep_log, refine_passes)


# ---------------------------------------------------------------------------
# Cross-fleet batched solve (DESIGN.md §13).  Many fleets' stage-A stacks —
# heterogeneous in (n_layers, M, topology) — are zero-padded to one common
# tableau shape and solved as a single flattened (fleet, lane) simplex call;
# stage-B refinement then runs in lockstep across the still-active fleets.
# Lanes never mix arithmetically inside the stacked simplex and the padding
# is provably inert (see batched_lp.pad_lp_stack), so every fleet's answer
# is bit-identical to its own _solve_3w / _solve_multi call.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One fleet's scheduling problem, as consumed by :func:`solve_many`.

    ``profile`` dispatches the engine: a :class:`HierProfile` runs the
    3-worker search (``origin="device"``), a :class:`MultiProfile` /
    ``TreeProfile`` runs the multi-device search.
    """
    profile: Union[HierProfile, MultiProfile]
    net: Union[Network, StarNetwork]
    B: int
    objective: str = "latency"


@dataclasses.dataclass
class SolveManyStats:
    """Padding/batching telemetry accumulated by :func:`solve_many`."""
    n_fleets: int = 0
    lanes: int = 0            # stage-A lanes solved (post-prune), all fleets
    lp_calls: int = 0         # stacked-simplex invocations (stage A + B)
    refine_rounds: int = 0    # lockstep stage-B rounds
    cells_native: int = 0     # tableau cells before padding
    cells_padded: int = 0     # tableau cells actually solved

    @property
    def pad_waste(self) -> float:
        """Fraction of solved tableau cells that were padding."""
        if self.cells_padded == 0:
            return 0.0
        return 1.0 - self.cells_native / self.cells_padded


def _stage_a_any(r: SolveRequest, prune: bool
                 ) -> Union[_StageA3W, _StageAMulti]:
    if r.objective not in OBJECTIVES:
        raise ValueError(f"unknown scheduler objective: {r.objective!r}")
    if isinstance(r.profile, MultiProfile):
        return _stage_a_multi(r.profile, r.net, r.B, prune, r.objective,
                              None)
    return _stage_a_3w(r.profile, r.net, r.B, "device", WORKERS, prune,
                       r.objective, None)


def solve_many(requests: Sequence[SolveRequest], *,
               backend: str = "batched", prune: bool = True,
               refine_passes: int = 4, keep_log: bool = False,
               stats: Optional[SolveManyStats] = None
               ) -> List[Union[SchedulerResult, MultiSchedulerResult]]:
    """Solve many fleets' Algorithm-1 searches in shared tableau stacks.

    Results are returned in request order and are bit-identical to calling
    the per-fleet engine on each request (asserted by the tier-1 planner
    suite): stage A concatenates every fleet's candidate stack into one
    :func:`batched_lp.linprog_batch_many` call, then stage-B coordinate
    descent runs in lockstep — each round solves all still-active fleets'
    single-cut-move stacks as one padded call.  Per-fleet state never
    mixes: the stacked simplex pivots lanes independently and the padding
    is inert (:func:`batched_lp.pad_lp_stack`).

    ``backend="reference"`` loops per-fleet through the scalar engines
    (the correctness oracle).  ``stats``, when given, is accumulated in
    place with lane counts and padding-waste telemetry; callers that care
    about padding (the planner admission loop) bucket requests by
    ``(kind, n_layers, M)`` before calling, keeping ``pad_waste`` near 0.
    """
    reqs = list(requests)
    if backend not in ("batched", "reference"):
        raise ValueError(f"unknown scheduler backend: {backend!r}")
    if backend == "reference":
        out: List[Union[SchedulerResult, MultiSchedulerResult]] = []
        for r in reqs:
            if isinstance(r.profile, MultiProfile):
                out.append(_solve_multi(r.profile, r.net, r.B, keep_log,
                                        backend, prune, refine_passes,
                                        r.objective))
            else:
                out.append(_solve_3w(r.profile, r.net, r.B,
                                     keep_log=keep_log, backend=backend,
                                     prune=prune, objective=r.objective))
        return out

    sts = [_stage_a_any(r, prune) for r in reqs]
    stacks = [st.stack for st in sts]
    if stats is not None:
        stats.n_fleets += len(reqs)
        stats.lanes += sum(st.stack[1].shape[0] for st in sts)
        native, padded = batched_lp.pad_cells(stacks)
        stats.cells_native += native
        stats.cells_padded += padded
        stats.lp_calls += 1
    lps = batched_lp.linprog_batch_many(stacks)

    results: List[Optional[Union[SchedulerResult, MultiSchedulerResult]]] \
        = [None] * len(reqs)
    searches: List[Tuple[int, _MultiRefine]] = []
    for i, (st, lp) in enumerate(zip(sts, lps)):
        if isinstance(st, _StageAMulti):
            search = _finish_multi(st, lp.x, lp.success, keep_log,
                                   refine_passes)
            assert search is not None   # no warm starts in solve_many
            searches.append((i, search))
        else:
            res3 = _finish_3w(st, lp.x, lp.success, keep_log)
            assert res3 is not None     # no warm starts in solve_many
            results[i] = res3

    # Lockstep stage B: one padded call per round over every fleet that
    # still has single-cut moves to score.  Fleets converge (and drop out)
    # independently — exactly the per-fleet refinement loop, interleaved.
    active = searches
    while True:
        round_stacks = []
        holders = []
        for i, s in active:
            stack = s.next_stack()
            if stack is not None:
                round_stacks.append(stack)
                holders.append((i, s))
        if not round_stacks:
            break
        if stats is not None:
            native, padded = batched_lp.pad_cells(round_stacks)
            stats.cells_native += native
            stats.cells_padded += padded
            stats.lp_calls += 1
            stats.refine_rounds += 1
        for (i, s), lp in zip(holders,
                              batched_lp.linprog_batch_many(round_stacks)):
            s.step(lp.x, lp.success)
        active = holders

    for i, s in searches:
        results[i] = s.result()
    return results   # type: ignore[return-value]
