"""Algorithm 1 of the paper: optimal HierTrain scheduling policy.

For every one of the 6 worker-role mappings and every cut pair
``(m_s, m_l)`` with ``0 <= m_s <= m_l <= N``, problem P1 (Eqs. 16-19) with the
cuts fixed is an ILP.  Per §V we relax it to an LP in epigraph form (one
epigraph variable per max-term of Eq. 12), solve with the two-phase simplex in
:mod:`repro.core.lp`, round with the paper's largest-fraction rule, and keep
the schedule with the smallest *exact* integer-evaluated ``T_total``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

import numpy as np

from repro.core import lp as lp_mod
from repro.core.cost_model import (WIDX, WORKERS, Breakdown, HierProfile,
                                   Network, Schedule, t_total)


@dataclasses.dataclass
class SchedulerResult:
    schedule: Schedule
    breakdown: Breakdown
    t_total: float
    n_lp_solved: int
    search_log: List[Tuple[Schedule, float]]


def _round_batch_split(b_real: np.ndarray, B: int,
                       allowed: np.ndarray) -> np.ndarray:
    """Paper §V rounding: floor everything, then hand the missing units to the
    entries with the largest fractional parts (at most two steps).  Entries
    with ``allowed == False`` (their ``m`` is 0) never receive extra units.
    """
    b_real = np.clip(np.asarray(b_real, np.float64), 0.0, None)
    ints = np.floor(b_real + 1e-9).astype(np.int64)
    fracs = b_real - ints
    fracs = np.where(allowed, fracs, -1.0)  # never bump disallowed entries
    deficit = int(B - ints.sum())
    order = np.argsort(-fracs)
    out = ints.copy()
    for j in range(len(out)):
        if deficit <= 0:
            break
        idx = order[j]
        if not allowed[idx] and idx != 0:
            continue
        out[idx] += 1
        deficit -= 1
    # Degenerate LP numerics: dump any remainder on b_o (always allowed).
    if deficit > 0:
        out[0] += deficit
    if deficit < 0:  # floor overshoot cannot happen, but stay safe
        out[0] += deficit
    return out


def _solve_cut_lp(profile: HierProfile, net: Network, wo: str, ws: str,
                  wl: str, m_s: int, m_l: int, B: int,
                  origin: str) -> Optional[np.ndarray]:
    """LP relaxation of P1 for a fixed mapping and fixed cuts.

    Variables ``x = [b_o, b_s, b_l, t1, t2, t3, t4] >= 0`` where
    ``t1 >= T^1_fwd``-terms, ``t2 >= T^1_bwd``, ``t3 >= T^2_fwd``,
    ``t4 >= T^2_bwd``.  ``T^3`` and ``T_update`` are constant once the cuts
    are fixed (they involve the full batch ``B`` / only prefix parameter
    sums), so they do not enter the LP objective.
    """
    p = profile.prefix()
    F, Bk = p["F"], p["Bk"]
    o, s, l = WIDX[wo], WIDX[ws], WIDX[wl]
    Q = profile.sample_bytes
    bw_os, bw_ol = net.bw(wo, ws), net.bw(wo, wl)
    in_o = 0.0 if wo == origin else Q / net.bw(origin, wo)
    in_s = 0.0 if ws == origin else Q / net.bw(origin, ws)
    in_l = 0.0 if wl == origin else Q / net.bw(origin, wl)
    mo_s = profile.MO[m_s - 1] / bw_os if m_s > 0 else 0.0
    mo_l = profile.MO[m_l - 1] / bw_ol if m_l > 0 else 0.0

    nv = 7
    c = np.array([0, 0, 0, 1, 1, 1, 1], np.float64)
    A_ub, b_ub = [], []

    def ub(coef_b, t_idx):  # coef_b @ [b_o,b_s,b_l] - t <= 0
        row = np.zeros(nv)
        row[:3] = coef_b
        row[3 + t_idx] = -1.0
        A_ub.append(row)
        b_ub.append(0.0)

    # t1 >= each arm of Eq. (5); t2 >= each arm of Eq. (6).
    ub([in_o + F[o, m_s], 0, 0], 0)
    ub([0, in_s + F[s, m_s] + mo_s, 0], 0)
    ub([0, 0, in_l + F[l, m_s]], 0)
    ub([Bk[o, m_s], 0, 0], 1)
    ub([0, Bk[s, m_s] + mo_s, 0], 1)
    ub([0, 0, Bk[l, m_s]], 1)
    # t3 >= each arm of Eq. (7); t4 >= each arm of Eq. (8).
    ub([F[o, m_l] - F[o, m_s], F[o, m_l] - F[o, m_s], 0], 2)
    ub([0, 0, (F[l, m_l] - F[l, m_s]) + mo_l], 2)
    ub([Bk[o, m_l] - Bk[o, m_s], Bk[o, m_l] - Bk[o, m_s], 0], 3)
    ub([0, 0, (Bk[l, m_l] - Bk[l, m_s]) + mo_l], 3)
    # Constraints (14)/(15): b_s <= m_s*B, b_l <= m_l*B.
    row = np.zeros(nv); row[1] = 1.0
    A_ub.append(row); b_ub.append(float(m_s) * B)
    row = np.zeros(nv); row[2] = 1.0
    A_ub.append(row); b_ub.append(float(m_l) * B)
    # Constraint (17): b_o + b_s + b_l = B.
    A_eq = np.zeros((1, nv)); A_eq[0, :3] = 1.0
    b_eq = np.array([float(B)])

    res = lp_mod.linprog(c, np.array(A_ub), np.array(b_ub), A_eq, b_eq)
    if not res.success:
        return None
    return res.x[:3]


def solve(profile: HierProfile, net: Network, B: int,
          origin: str = "device",
          workers: Tuple[str, ...] = WORKERS,
          keep_log: bool = False) -> SchedulerResult:
    """Algorithm 1: enumerate mappings x cuts, LP + round, return the best."""
    N = profile.num_layers
    best: Optional[Tuple[Schedule, Breakdown]] = None
    n_lp = 0
    log: List[Tuple[Schedule, float]] = []
    for wo, ws, wl in itertools.permutations(workers, 3):
        for m_s in range(0, N + 1):
            for m_l in range(m_s, N + 1):
                n_lp += 1
                b = _solve_cut_lp(profile, net, wo, ws, wl, m_s, m_l, B,
                                  origin)
                if b is None:
                    continue
                allowed = np.array([True, m_s > 0, m_l > 0])
                b_int = _round_batch_split(b, B, allowed)
                sched = Schedule(wo, ws, wl, m_s, m_l,
                                 int(b_int[0]), int(b_int[1]), int(b_int[2]))
                bd = t_total(profile, net, sched, origin)
                if keep_log:
                    log.append((sched, bd.total))
                if best is None or bd.total < best[1].total:
                    best = (sched, bd)
    assert best is not None
    return SchedulerResult(schedule=best[0], breakdown=best[1],
                           t_total=best[1].total, n_lp_solved=n_lp,
                           search_log=log)
