"""Discrete-event simulator of HierTrain iterations.

The analytic cost model (Eq. 12 and its M-device generalization) assumes
clean phase barriers.  This simulator executes the *procedure of §IV-B* —
segment-level compute jobs and link transfers with FIFO resource contention
— and measures the makespan.  :func:`simulate_iteration` covers the paper's
3-tier testbed; :func:`simulate_iteration_multi` covers the M-device star
(per-device compute resources, per-device radio links, shared backhaul);
:func:`simulate_pipeline` runs K consecutive iterations as a pipeline with
synchronous-SGD cross-iteration dependencies (DESIGN.md §7), validating
the closed-form steady-state period of :mod:`repro.core.pipeline`.
Benchmarks ``fig6_model_validity``, ``fig_multidevice`` and
``fig_pipeline`` compare simulated against analytic makespans (the
paper's Fig. 6 shows "real and theoretical latencies highly match");
tests assert a tight bound.

Resources:
* one compute resource per physical worker (sequential execution),
* one resource per *directed* worker-pair pipe (full duplex).  Pairs
  without a physical link (device<->cloud, device<->device) get their own
  shaped pipe at the series bandwidth of the relayed route, matching the
  paper's Linux-TC emulation (see ``_route``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cost_model import (WIDX, HierProfile, MultiProfile,
                                   MultiSchedule, Network, Schedule,
                                   StarNetwork)


@dataclasses.dataclass
class _Task:
    name: str
    resources: Tuple[str, ...]   # sequence of resources (links in a route)
    durations: Tuple[float, ...]  # one duration per resource hop
    deps: Tuple[str, ...] = ()
    start: float = 0.0
    end: float = 0.0


class Des:
    """Tiny FIFO discrete-event executor over a task DAG."""

    def __init__(self) -> None:
        self.tasks: Dict[str, _Task] = {}
        self.res_free: Dict[str, float] = {}

    def add(self, name: str, resources: Sequence[str],
            durations: Sequence[float], deps: Sequence[str] = ()) -> None:
        assert name not in self.tasks, name
        for d in deps:
            assert d in self.tasks, f"unknown dep {d} of {name}"
        self.tasks[name] = _Task(name, tuple(resources), tuple(durations),
                                 tuple(deps))

    def run(self) -> float:
        # Dep-count + ready-heap dispatcher.  A task enters the heap the
        # moment its last dependency has been dispatched, keyed by
        # ``(max dep end, name)`` — the exact tuple the previous
        # rescan-every-dispatch implementation sorted the ready set by, so
        # the dispatch order (and therefore every FIFO resource queue) is
        # preserved while the per-dispatch cost drops from O(n) to O(log n).
        dependents: Dict[str, List[str]] = {n: [] for n in self.tasks}
        counts: Dict[str, int] = {}
        heap: List[Tuple[float, str]] = []
        for name, t in self.tasks.items():
            deps = set(t.deps)
            counts[name] = len(deps)
            for d in deps:
                dependents[d].append(name)
            if not deps:
                heap.append((0.0, name))
        heapq.heapify(heap)
        makespan = 0.0
        n_done = 0
        while heap:
            clock, name = heapq.heappop(heap)
            t = self.tasks[name]
            t.start = clock
            for res, dur in zip(t.resources, t.durations):
                free = self.res_free.get(res, 0.0)
                begin = max(clock, free)
                clock = begin + dur
                self.res_free[res] = clock
            t.end = clock
            n_done += 1
            if clock > makespan:
                makespan = clock
            for succ in dependents[name]:
                counts[succ] -= 1
                if counts[succ] == 0:
                    st = self.tasks[succ]
                    ready = max((self.tasks[d].end for d in st.deps),
                                default=0.0)
                    heapq.heappush(heap, (ready, succ))
        assert n_done == len(self.tasks), "dependency cycle in task graph"
        return makespan


def _route(net: Network, a: str, b: str) -> List[Tuple[str, float]]:
    """Directed link hops (resource name, bandwidth) from a to b.

    Each worker pair is an independent shaped pipe — matching the
    paper's Linux-TC emulation (§VI-B), where device->cloud traffic is
    throttled on its own class rather than contending with device->edge
    on a shared radio.  (With a physically-relayed route the DES diverges
    from Eq. 12 by up to ~38% on shipping-heavy schedules; see
    EXPERIMENTS.md §Fig.6 note.)"""
    if a == b:
        return []
    return [(f"link:{a}->{b}", net.bw(a, b))]


def _add_iteration(des: Des, profile: HierProfile, net: Network,
                   sched: Schedule, origin: str, tag: str = "",
                   prev: Optional[str] = None) -> None:
    """Add one iteration's task DAG to ``des``.

    ``tag`` prefixes every task name (the first iteration uses ``""`` so a
    depth-1 pipeline is *literally* the single-iteration DAG — same names,
    same dispatch order, bit-identical makespan).  ``prev`` is the previous
    iteration's tag (``None`` for the first): it adds the cross-iteration
    dependencies of §7 — each worker's forward task waits on its *own*
    previous-iteration weight update (synchronous SGD semantics), while
    links stay FIFO through the shared pipe resources.
    """
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    N = profile.num_layers
    wo, ws, wl = sched.worker_o, sched.worker_s, sched.worker_l
    o, s, l = WIDX[wo], WIDX[ws], WIDX[wl]
    ms, ml = sched.m_s, sched.m_l
    bo, bs, bl = sched.b_o, sched.b_s, sched.b_l
    Q = profile.sample_bytes

    def nm(base: str) -> str:
        return tag + base

    def lag(base: str) -> List[str]:
        return [prev + base] if prev is not None else []

    def xfer(name: str, a: str, b: str, nbytes: float,
             deps: Sequence[str] = ()) -> str:
        hops = _route(net, a, b)
        if not hops or nbytes <= 0.0:
            des.add(name, (), (), deps)
            return name
        des.add(name, tuple(h[0] for h in hops),
                tuple(nbytes / h[1] for h in hops), deps)
        return name

    def compute(name: str, worker: str, seconds: float,
                deps: Sequence[str] = ()) -> str:
        des.add(name, (f"cpu:{worker}",), (max(seconds, 0.0),), deps)
        return name

    # --- input distribution ---------------------------------------------
    xfer(nm("in_o"), origin, wo, bo * Q if wo != origin else 0.0)
    xfer(nm("in_s"), origin, ws, bs * Q if ws != origin else 0.0)
    xfer(nm("in_l"), origin, wl, bl * Q if wl != origin else 0.0)

    # --- forward ----------------------------------------------------------
    compute(nm("f_s"), ws, bs * F[s, ms], [nm("in_s")] + lag("u_s"))
    xfer(nm("act_s"), ws, wo, bs * profile.MO[ms - 1] if ms > 0 and bs > 0
         else 0.0, [nm("f_s")])
    compute(nm("f_l"), wl, bl * F[l, ml], [nm("in_l")] + lag("u_l"))
    xfer(nm("act_l"), wl, wo, bl * profile.MO[ml - 1] if ml > 0 and bl > 0
         else 0.0, [nm("f_l")])
    compute(nm("f_o1"), wo, bo * F[o, ms], [nm("in_o")] + lag("u_o"))
    compute(nm("f_o2"), wo, (bo + bs) * (F[o, ml] - F[o, ms]),
            [nm("f_o1"), nm("act_s")])
    compute(nm("f_o3"), wo, (bo + bs + bl) * (F[o, N] - F[o, ml]),
            [nm("f_o2"), nm("act_l")])

    # --- backward ---------------------------------------------------------
    compute(nm("b_o3"), wo, (bo + bs + bl) * (Bk[o, N] - Bk[o, ml]),
            [nm("f_o3")])
    xfer(nm("gact_l"), wo, wl, bl * profile.MG[ml - 1] if ml > 0 and bl > 0
         else 0.0, [nm("b_o3")])
    compute(nm("b_l"), wl, bl * Bk[l, ml], [nm("gact_l")])
    compute(nm("b_o2"), wo, (bo + bs) * (Bk[o, ml] - Bk[o, ms]),
            [nm("b_o3")])
    xfer(nm("gact_s"), wo, ws, bs * profile.MG[ms - 1] if ms > 0 and bs > 0
         else 0.0, [nm("b_o2")])
    compute(nm("b_s"), ws, bs * Bk[s, ms], [nm("gact_s")])
    compute(nm("b_o1"), wo, bo * Bk[o, ms], [nm("b_o2")])

    # --- weight update ----------------------------------------------------
    xfer(nm("wg_s_up"), ws, wo, MPc[ms] if bs > 0 else 0.0, [nm("b_s")])
    xfer(nm("wg_l_up"), wl, wo, MPc[ml] if bl > 0 else 0.0, [nm("b_l")])
    xfer(nm("wg_s_down"), wo, ws, MPc[ms] if bs > 0 else 0.0,
         [nm("wg_s_up"), nm("b_o1")])
    xfer(nm("wg_l_down"), wo, wl, MPc[ml] if bl > 0 else 0.0,
         [nm("wg_l_up"), nm("b_o1")])
    compute(nm("u_o"), wo, U[o, N], [nm("b_o1"), nm("wg_s_up"),
                                     nm("wg_l_up")])
    compute(nm("u_s"), ws, U[s, ms] if bs > 0 else 0.0, [nm("wg_s_down")])
    compute(nm("u_l"), wl, U[l, ml] if bl > 0 else 0.0, [nm("wg_l_down")])


def _simulate_iteration(profile: HierProfile, net: Network, sched: Schedule,
                        origin: str = "device") -> float:
    """Makespan (seconds) of one training iteration under `sched` on the
    canonical three-worker DES (``Plan.simulate`` for triple fleets)."""
    des = Des()
    _add_iteration(des, profile, net, sched, origin)
    return des.run()


def simulate_iteration(profile: HierProfile, net: Network, sched: Schedule,
                       origin: str = "device") -> float:
    """Deprecated: use ``repro.api.plan(...).simulate()`` (same DES)."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.simulator.simulate_iteration()",
                    "repro.api.plan(model, fleet, B).simulate()")
    return _simulate_iteration(profile, net, sched, origin)


def simulate_iteration_multi(profile: MultiProfile, net: StarNetwork,
                             sched: MultiSchedule) -> float:
    """Deprecated: use ``repro.api.plan(...).simulate()`` (same DES)."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated("repro.core.simulator.simulate_iteration_multi()",
                    "repro.api.plan(model, fleet, B).simulate()")
    return _simulate_iteration_multi(profile, net, sched)


def _simulate_iteration_multi(profile: MultiProfile, net: StarNetwork,
                              sched: MultiSchedule) -> float:
    """Makespan (seconds) of one M-device iteration under ``sched`` on the
    star DES (``Plan.simulate`` for star fleets).

    Mirrors :func:`_simulate_iteration` on the star topology: one compute
    resource per worker, one shaped pipe per worker pair (each device's
    radio is its own resource, so M uploads to the edge genuinely overlap),
    and edge/cloud-resident tasks ingest their sub-batch as M parallel
    transfers of ``b/M`` samples — one per device — matching the cost
    model's even-upload assumption.  Following the paper's §VI-B Linux-TC
    emulation one class further, the input-distribution flow gets its own
    shaped pipe per (device, worker) pair instead of contending with that
    device's activation flow: with a physically shared radio the DES
    diverges from the generalized Eq. 12 by up to ~26% on upload-heavy
    schedules (same family as the relayed-route divergence recorded in
    EXPERIMENTS.md §Fig.6).
    """
    des = Des()
    _add_iteration_multi(des, profile, net, sched)
    return des.run()


def _add_iteration_multi(des: Des, profile: MultiProfile, net: StarNetwork,
                         sched: MultiSchedule, tag: str = "",
                         prev: Optional[str] = None) -> None:
    """M-device counterpart of :func:`_add_iteration` (same tag/prev
    contract): one iteration's star-topology task DAG, with the §7
    cross-iteration update->forward dependencies when ``prev`` is given."""
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    N = profile.num_layers
    M = profile.num_devices       # data holders; streams come from sched
    W = profile.num_workers
    edge_of = net.edge_of         # device -> edge index ((0,)*M on a star)
    backhaul = net.backhaul       # per-edge backhaul ([bw_ec] on a star)
    names = profile.worker_names
    widx = profile.widx
    o, l = widx[sched.worker_o], widx[sched.worker_l]
    s = [widx[w] for w in sched.s_workers]
    ml = sched.m_l
    bo, bl = sched.b_o, sched.b_l
    bs = list(sched.b_s)
    msmax = max(sched.m_s)
    bwm = net.bw_matrix()
    Q = profile.sample_bytes

    def nm(base: str) -> str:
        return tag + base

    def lag(base: str) -> List[str]:
        return [prev + base] if prev is not None else []

    def xfer(name: str, a: int, b: int, nbytes: float,
             deps: Sequence[str] = ()) -> str:
        if a == b or nbytes <= 0.0:
            des.add(name, (), (), deps)
            return name
        des.add(name, (f"link:{names[a]}->{names[b]}",),
                (nbytes / bwm[a, b],), deps)
        return name

    def compute(name: str, w: int, seconds: float,
                deps: Sequence[str] = ()) -> str:
        des.add(name, (f"cpu:{names[w]}",), (max(seconds, 0.0),), deps)
        return name

    def ingest(base: str, w: int, b: int) -> List[str]:
        """Input distribution for a task on worker ``w``: local (free) on a
        device, else ``b/M`` samples uploaded from every device at once,
        each on its own TC-shaped input-class radio pipe (see docstring).
        Relayed uploads cross one shaped input-class pipe per (shared
        hop, destination) pair, so same-destination flows serialize
        there — matching ``upload_bw``'s series composition instead of
        overbooking a backhaul M-fold: cloud-bound chunks cross the
        sender's per-edge backhaul pipe (``link:in:edge->cloud`` at E=1,
        the star's literal pipe name); chunks bound for a *foreign* edge
        cross their own uplink class (``...->cloud:{dst}``, keeping them
        off the cloud-bound class) plus that edge's downlink class."""
        if w < M or b == 0:
            des.add(nm(base), (), (), ())
            return [nm(base)]
        out = []
        chunk = b * Q / M
        for j in range(M):
            name = f"{nm(base)}_{j}"
            own = M + edge_of[j]         # device_j's aggregation edge
            radio = (f"link:in:{names[j]}->{names[w]}",
                     chunk / net.bw_de[j])
            bh_up = (f"link:in:{names[own]}->cloud",
                     chunk / backhaul[edge_of[j]])
            if w == W - 1:               # device_j -> its edge -> cloud
                # the radio hop is the (device, cloud) input class — its
                # own TC pipe, NOT shared with the (device, edge) class
                # (LM-fleet ingest is MBs per sample; sharing the first
                # hop diverged from upload_bw by ~50% there)
                hops = (radio, bh_up)
            elif w == own:               # direct radio hop to its edge
                hops = ((radio[0], chunk / bwm[j, w]),)
            else:                        # foreign edge: relay via cloud
                hops = (radio,
                        (f"{bh_up[0]}:{names[w]}", bh_up[1]),
                        (f"link:in:cloud->{names[w]}",
                         chunk / backhaul[w - M]))
            des.add(name, tuple(h[0] for h in hops),
                    tuple(h[1] for h in hops), ())
            out.append(name)
        return out

    # --- input distribution ---------------------------------------------
    in_o = ingest("in_o", o, bo)
    in_l = ingest("in_l", l, bl)

    # --- forward ----------------------------------------------------------
    acts: List[str] = []
    for i, si in enumerate(s):
        in_i = ingest(f"in_s{i}", si, bs[i])
        compute(nm(f"f_s{i}"), si, bs[i] * F[si, sched.m_s[i]],
                in_i + lag(f"u_s{i}"))
        acts.append(xfer(
            nm(f"act_s{i}"), si, o,
            bs[i] * profile.MO[sched.m_s[i] - 1]
            if sched.m_s[i] > 0 and bs[i] > 0 else 0.0, [nm(f"f_s{i}")]))
    compute(nm("f_l"), l, bl * F[l, ml], in_l + lag("u_l"))
    xfer(nm("act_l"), l, o, bl * profile.MO[ml - 1] if ml > 0 and bl > 0
         else 0.0, [nm("f_l")])
    bs_sum = sum(bs)
    catch_f = sum(bs[i] * (F[o, msmax] - F[o, sched.m_s[i]])
                  for i in range(len(s)))
    catch_b = sum(bs[i] * (Bk[o, msmax] - Bk[o, sched.m_s[i]])
                  for i in range(len(s)))
    compute(nm("f_o1"), o, bo * F[o, msmax], in_o + lag("u_o"))
    compute(nm("f_o2"), o,
            (bo + bs_sum) * (F[o, ml] - F[o, msmax]) + catch_f,
            [nm("f_o1")] + acts)
    compute(nm("f_o3"), o, (bo + bs_sum + bl) * (F[o, N] - F[o, ml]),
            [nm("f_o2"), nm("act_l")])

    # --- backward ---------------------------------------------------------
    compute(nm("b_o3"), o, (bo + bs_sum + bl) * (Bk[o, N] - Bk[o, ml]),
            [nm("f_o3")])
    xfer(nm("gact_l"), o, l, bl * profile.MG[ml - 1] if ml > 0 and bl > 0
         else 0.0, [nm("b_o3")])
    compute(nm("b_l"), l, bl * Bk[l, ml], [nm("gact_l")])
    compute(nm("b_o2"), o,
            (bo + bs_sum) * (Bk[o, ml] - Bk[o, msmax]) + catch_b,
            [nm("b_o3")])
    for i, si in enumerate(s):
        xfer(nm(f"gact_s{i}"), o, si,
             bs[i] * profile.MG[sched.m_s[i] - 1]
             if sched.m_s[i] > 0 and bs[i] > 0 else 0.0, [nm("b_o2")])
        compute(nm(f"b_s{i}"), si, bs[i] * Bk[si, sched.m_s[i]],
                [nm(f"gact_s{i}")])
    compute(nm("b_o1"), o, bo * Bk[o, msmax], [nm("b_o2")])

    # --- weight update ----------------------------------------------------
    wg_ups: List[str] = []
    for i, si in enumerate(s):
        wg_ups.append(xfer(nm(f"wg_s{i}_up"), si, o,
                           MPc[sched.m_s[i]] if bs[i] > 0 else 0.0,
                           [nm(f"b_s{i}")]))
        xfer(nm(f"wg_s{i}_down"), o, si,
             MPc[sched.m_s[i]] if bs[i] > 0 else 0.0,
             [nm(f"wg_s{i}_up"), nm("b_o1")])
        compute(nm(f"u_s{i}"), si,
                U[si, sched.m_s[i]] if bs[i] > 0 else 0.0,
                [nm(f"wg_s{i}_down")])
    xfer(nm("wg_l_up"), l, o, MPc[ml] if bl > 0 else 0.0, [nm("b_l")])
    xfer(nm("wg_l_down"), o, l, MPc[ml] if bl > 0 else 0.0,
         [nm("wg_l_up"), nm("b_o1")])
    compute(nm("u_o"), o, U[o, N], [nm("b_o1"), nm("wg_l_up")] + wg_ups)
    compute(nm("u_l"), l, U[l, ml] if bl > 0 else 0.0, [nm("wg_l_down")])


def simulate_pipeline(profile: Union[HierProfile, MultiProfile],
                      net: Union[Network, StarNetwork],
                      sched: Union[Schedule, MultiSchedule], K: int,
                      origin: str = "device") -> float:
    """Makespan of ``K`` consecutive iterations executed as a pipeline.

    Instantiates K copies of the single-iteration task DAG
    (:func:`_add_iteration` / :func:`_add_iteration_multi`) with the
    cross-iteration dependencies of DESIGN.md §7: each worker's iteration-k
    forward waits on that worker's iteration-(k-1) weight update
    (synchronous SGD), and every link/CPU stays a FIFO resource, so
    consecutive minibatches overlap wherever the dependency structure
    allows.  ``K = 1`` is bit-identical to :func:`simulate_iteration` /
    :func:`simulate_iteration_multi` (same task names, same DAG, same
    dispatch order).  The closed-form model (:mod:`repro.core.pipeline`)
    predicts the asymptotic slope ``t_period``; the property suite asserts
    the measured DES period converges to it.
    """
    assert K >= 1
    multi = isinstance(sched, MultiSchedule)
    des = Des()
    prev: Optional[str] = None
    for k in range(K):
        # Equal-ready tie-breaks are by name, so all K prefetchable input
        # transfers (ready at t = 0) enter each FIFO pipe in *name* order.
        # Iteration tags are zero-padded *prefixes* built on "~" (which
        # sorts after every identifier character), so dispatch ties order
        # iteration-major: every bare first-iteration task first, then
        # "~000001...", "~000002", ... — a pipe never serves iteration
        # k+1's flow ahead of iteration k's.
        tag = "" if k == 0 else f"~{k:06d}"
        if multi:
            _add_iteration_multi(des, profile, net, sched, tag, prev)
        else:
            _add_iteration(des, profile, net, sched, origin, tag, prev)
        prev = tag
    return des.run()
