"""Discrete-event simulator of one HierTrain iteration on the 3-tier testbed.

The analytic cost model (Eq. 12) assumes clean phase barriers.  This
simulator executes the *procedure of §IV-B* — segment-level compute jobs and
link transfers with FIFO resource contention — and measures the makespan.
Benchmark ``fig6_model_validity`` compares the two (the paper's Fig. 6 shows
"real and theoretical latencies highly match"); tests assert a tight bound.

Resources:
* one compute resource per physical worker (sequential execution),
* one resource per *directed* physical link (full duplex).  device<->cloud
  transfers are relayed through the edge: two sequential link jobs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import (WIDX, HierProfile, Network, Schedule)


@dataclasses.dataclass
class _Task:
    name: str
    resources: Tuple[str, ...]   # sequence of resources (links in a route)
    durations: Tuple[float, ...]  # one duration per resource hop
    deps: Tuple[str, ...] = ()
    start: float = 0.0
    end: float = 0.0
    done: bool = False


class Des:
    """Tiny FIFO discrete-event executor over a task DAG."""

    def __init__(self) -> None:
        self.tasks: Dict[str, _Task] = {}
        self.res_free: Dict[str, float] = {}

    def add(self, name: str, resources: Sequence[str],
            durations: Sequence[float], deps: Sequence[str] = ()) -> None:
        assert name not in self.tasks, name
        for d in deps:
            assert d in self.tasks, f"unknown dep {d} of {name}"
        self.tasks[name] = _Task(name, tuple(resources), tuple(durations),
                                 tuple(deps))

    def run(self) -> float:
        pending = dict(self.tasks)
        while pending:
            # Earliest-ready-first FIFO dispatch.
            ready = [(max((self.tasks[d].end for d in t.deps), default=0.0),
                      name)
                     for name, t in pending.items()
                     if all(self.tasks[d].done for d in t.deps)]
            assert ready, "dependency cycle in task graph"
            ready.sort()
            _, name = ready[0]
            t = pending.pop(name)
            clock = max((self.tasks[d].end for d in t.deps), default=0.0)
            t.start = clock
            for res, dur in zip(t.resources, t.durations):
                free = self.res_free.get(res, 0.0)
                begin = max(clock, free)
                clock = begin + dur
                self.res_free[res] = clock
            t.end = clock
            t.done = True
        return max(t.end for t in self.tasks.values())


def _route(net: Network, a: str, b: str) -> List[Tuple[str, float]]:
    """Directed link hops (resource name, bandwidth) from a to b.

    Each worker pair is an independent shaped pipe — matching the
    paper's Linux-TC emulation (§VI-B), where device->cloud traffic is
    throttled on its own class rather than contending with device->edge
    on a shared radio.  (With a physically-relayed route the DES diverges
    from Eq. 12 by up to ~38% on shipping-heavy schedules; see
    EXPERIMENTS.md §Fig.6 note.)"""
    if a == b:
        return []
    return [(f"link:{a}->{b}", net.bw(a, b))]


def simulate_iteration(profile: HierProfile, net: Network, sched: Schedule,
                       origin: str = "device") -> float:
    """Makespan (seconds) of one training iteration under `sched`."""
    p = profile.prefix()
    F, Bk, U, MPc = p["F"], p["Bk"], p["U"], p["MP"]
    N = profile.num_layers
    wo, ws, wl = sched.worker_o, sched.worker_s, sched.worker_l
    o, s, l = WIDX[wo], WIDX[ws], WIDX[wl]
    ms, ml = sched.m_s, sched.m_l
    bo, bs, bl = sched.b_o, sched.b_s, sched.b_l
    Q = profile.sample_bytes

    des = Des()

    def xfer(name: str, a: str, b: str, nbytes: float,
             deps: Sequence[str] = ()) -> str:
        hops = _route(net, a, b)
        if not hops or nbytes <= 0.0:
            des.add(name, (), (), deps)
            return name
        des.add(name, tuple(h[0] for h in hops),
                tuple(nbytes / h[1] for h in hops), deps)
        return name

    def compute(name: str, worker: str, seconds: float,
                deps: Sequence[str] = ()) -> str:
        des.add(name, (f"cpu:{worker}",), (max(seconds, 0.0),), deps)
        return name

    # --- input distribution ---------------------------------------------
    xfer("in_o", origin, wo, bo * Q if wo != origin else 0.0)
    xfer("in_s", origin, ws, bs * Q if ws != origin else 0.0)
    xfer("in_l", origin, wl, bl * Q if wl != origin else 0.0)

    # --- forward ----------------------------------------------------------
    compute("f_s", ws, bs * F[s, ms], ["in_s"])
    xfer("act_s", ws, wo, bs * profile.MO[ms - 1] if ms > 0 and bs > 0
         else 0.0, ["f_s"])
    compute("f_l", wl, bl * F[l, ml], ["in_l"])
    xfer("act_l", wl, wo, bl * profile.MO[ml - 1] if ml > 0 and bl > 0
         else 0.0, ["f_l"])
    compute("f_o1", wo, bo * F[o, ms], ["in_o"])
    compute("f_o2", wo, (bo + bs) * (F[o, ml] - F[o, ms]),
            ["f_o1", "act_s"])
    compute("f_o3", wo, (bo + bs + bl) * (F[o, N] - F[o, ml]),
            ["f_o2", "act_l"])

    # --- backward ---------------------------------------------------------
    compute("b_o3", wo, (bo + bs + bl) * (Bk[o, N] - Bk[o, ml]), ["f_o3"])
    xfer("gact_l", wo, wl, bl * profile.MO[ml - 1] if ml > 0 and bl > 0
         else 0.0, ["b_o3"])
    compute("b_l", wl, bl * Bk[l, ml], ["gact_l"])
    compute("b_o2", wo, (bo + bs) * (Bk[o, ml] - Bk[o, ms]), ["b_o3"])
    xfer("gact_s", wo, ws, bs * profile.MO[ms - 1] if ms > 0 and bs > 0
         else 0.0, ["b_o2"])
    compute("b_s", ws, bs * Bk[s, ms], ["gact_s"])
    compute("b_o1", wo, bo * Bk[o, ms], ["b_o2"])

    # --- weight update ----------------------------------------------------
    xfer("wg_s_up", ws, wo, MPc[ms] if bs > 0 else 0.0, ["b_s"])
    xfer("wg_l_up", wl, wo, MPc[ml] if bl > 0 else 0.0, ["b_l"])
    xfer("wg_s_down", wo, ws, MPc[ms] if bs > 0 else 0.0,
         ["wg_s_up", "b_o1"])
    xfer("wg_l_down", wo, wl, MPc[ml] if bl > 0 else 0.0,
         ["wg_l_up", "b_o1"])
    compute("u_o", wo, U[o, N], ["b_o1", "wg_s_up", "wg_l_up"])
    compute("u_s", ws, U[s, ms] if bs > 0 else 0.0, ["wg_s_down"])
    compute("u_l", wl, U[l, ml] if bl > 0 else 0.0, ["wg_l_down"])

    return des.run()
