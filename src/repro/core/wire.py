"""Wire compression for cut-point transfers (DESIGN.md §11).

HierTrain's bottleneck is the device uplink: what crosses the
mobile→edge→cloud wire at a cut is the per-sample activation (forward)
and activation-gradient (backward) tensor.  This module makes that wire
compressible — ``wire="int8"`` ships both directions int8-quantized via
the :mod:`repro.kernels.int8_quant` Pallas kernel — and, critically,
makes the *cost model see it*: compressed split-point traffic changes
the optimal cut (arXiv:2403.15815), so the scheduler must plan with the
compressed ``MO``/``MG`` columns, not just apply the codec at runtime.

Two halves, kept consistent by construction:

* **Accounting** — :func:`apply_wire` rewrites a profile's ``MO``/``MG``
  columns to the compressed wire sizes.  One int8 payload byte per
  tensor element plus one f32 row scale per *sample* (the codec
  quantizes per-sample rows), so::

      bytes/sample = elems/sample + 4

  Element counts come from :class:`~repro.core.layerstack.CutMeta`
  (``resolved_act_elems`` / ``resolved_grad_elems``), which is what
  makes the accounting honor *asymmetric* fwd/bwd dtypes: an LM cut
  ships bf16 forward (ratio ≈ 1/2) but f32 backward (ratio ≈ 1/4), and
  both compress to the *same* byte count — the historical symmetric-
  dtype assumption baked into the uncompressed wire sizes drops out.
  Every downstream scorer — ``t_total(_multi)(_batch)``, the three LP
  builders, ``t_period`` and the DES transfer sizes — reads
  ``profile.MO``/``profile.MG``, so this one transform flows through
  all of them in the identical operation order.

* **Execution** — :func:`wire_codec` returns the quantize→dequantize
  round trip the hybrid step applies at each crossing.  Forward it
  compresses the shipped activation; backward (via ``custom_vjp``) it
  compresses the returning cotangent — the MG channel.  Rounding is
  deterministic (round-to-nearest, i.e. the kernel's stochastic-
  rounding noise pinned at 0.5) so compiled steps stay pure functions
  of their inputs and the bounded jit cache needs no PRNG plumbing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

WIRE_MODES = ("none", "int8")

# One f32 absmax scale per quantized row; the codec flattens each
# crossing tensor to one row per sample.
SCALE_BYTES = 4.0


def validate_wire(wire: str) -> str:
    if wire not in WIRE_MODES:
        raise ValueError(f"unknown wire mode {wire!r}; pick one of "
                         f"{WIRE_MODES}")
    return wire


def int8_wire_bytes(elems):
    """Compressed bytes/sample of an ``elems``-element crossing tensor
    (scalar or ndarray): one int8 byte per element + the row scale."""
    return np.asarray(elems, np.float64) * 1.0 + SCALE_BYTES


def int8_leaf_bytes(shape) -> float:
    """Compressed wire bytes of one whole tensor of ``shape``: one int8
    byte per element plus one f32 absmax scale per quantized row, under
    the codec's rowing rule (``ndim >= 2`` flattens to
    ``prod(shape[:-1])`` rows of ``shape[-1]``; anything smaller is a
    single row — :func:`repro.distrib.tiered_sync._as_2d`).  Single
    source for the predicted DCN sync bytes
    (:func:`~repro.distrib.tiered_sync.choose_tiers` /
    :func:`~repro.distrib.tiered_sync.dcn_bytes_per_step`) and the
    payload+scale bytes the int8 all-gather actually ships."""
    shape = tuple(int(d) for d in shape)
    elems = float(np.prod(shape, dtype=np.float64))
    rows = float(np.prod(shape[:-1], dtype=np.float64)) \
        if len(shape) >= 2 else 1.0
    return elems * 1.0 + SCALE_BYTES * rows  # repro-lint: disable=RA301,RA302 int8 codec conversion point: exactly 1 byte per element


def wire_act_bytes(meta, wire: str) -> float:
    """Forward wire bytes/sample at one cut under ``wire``."""
    validate_wire(wire)
    if wire == "none":
        return float(meta.act_bytes)
    return float(int8_wire_bytes(meta.resolved_act_elems))


def wire_grad_bytes(meta, wire: str) -> float:
    """Backward wire bytes/sample at one cut under ``wire``."""
    validate_wire(wire)
    if wire == "none":
        return float(meta.resolved_grad_bytes)
    return float(int8_wire_bytes(meta.resolved_grad_elems))


def apply_wire(profile, stack, wire: str):
    """A copy of ``profile`` whose ``MO``/``MG`` columns are the
    compressed wire sizes (``wire="none"`` returns ``profile``
    unchanged — bit-identical to the historical path).

    With a ``stack`` the element counts come from its cut meta, so the
    fwd/bwd directions compress from their *own* dtypes.  Pinned
    profiles (no model) carry bytes only; their payloads are f32 (the
    CNN testbeds), so elements are ``bytes / 4``.
    """
    validate_wire(wire)
    if wire == "none":
        return profile
    if stack is not None:
        from repro.core.layerstack import as_layerstack
        metas = as_layerstack(stack).cut_meta()
        assert len(metas) == profile.num_layers, \
            "stack cut-points do not match the profile"
        MO = np.array([wire_act_bytes(m, wire) for m in metas], np.float64)
        MG = np.array([wire_grad_bytes(m, wire) for m in metas], np.float64)
    else:
        MO = int8_wire_bytes(np.asarray(profile.MO, np.float64) / 4.0)
        MG = int8_wire_bytes(np.asarray(profile.MG, np.float64) / 4.0)
    return dataclasses.replace(profile, MO=MO, MG=MG)


# ---------------------------------------------------------------------------
# Execution codec.  Built lazily so importing the accounting half never
# pulls in jax/kernels (the scheduler-only paths stay import-light).
# ---------------------------------------------------------------------------

_INT8_CODEC: Optional[Any] = None


def _build_int8_codec():
    import jax

    from repro.kernels import ops as kops

    @jax.custom_vjp
    def int8_wire(x):
        return kops.wire_qdq_int8(x)

    def fwd(x):
        return kops.wire_qdq_int8(x), None

    def bwd(_, g):
        # The returning activation-gradient crosses the same wire — the
        # cost model's MG channel — so it pays the same codec.
        return (kops.wire_qdq_int8(g),)

    int8_wire.defvjp(fwd, bwd)
    return int8_wire


def wire_codec(wire: str) -> Optional[Any]:
    """The crossing transform for ``wire``: ``None`` for the identity
    wire (so the uncompressed trace is untouched), else a jit-safe
    ``x -> dequantize(quantize(x))`` with matching custom VJP."""
    validate_wire(wire)
    if wire == "none":
        return None
    global _INT8_CODEC
    if _INT8_CODEC is None:
        _INT8_CODEC = _build_int8_codec()
    return _INT8_CODEC
