from repro.data.pipeline import (SyntheticImages, SyntheticTokens,
                                 make_lm_batch_fn)

__all__ = ["SyntheticImages", "SyntheticTokens", "make_lm_batch_fn"]
