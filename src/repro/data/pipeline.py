"""Deterministic synthetic data pipelines.

Properties required by the fault-tolerant trainer:

* **Stateless indexing** — batch ``i`` is a pure function of ``(seed, i)``
  (counter-based PRNG), so restart-after-failure resumes at step ``k`` by
  simply asking for batch ``k``: no pipeline state to checkpoint, no
  skip-ahead replay cost (the "deterministic data skip-ahead" trick).
* **Shardable** — batches are produced host-locally per data shard:
  ``batch(i, shard, num_shards)`` returns that shard's rows only, and
  rows are assigned shard-major so the global batch is independent of
  the shard count (elastic rescaling keeps the data order).

The LM stream synthesizes token sequences from a mixture of Zipf-like
unigram draws and periodic motifs, so cross-entropy decreases during the
example runs (there is structure to learn) while everything stays
offline and reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _fold(seed: int, *idx: int) -> np.random.Generator:
    counter = (list(idx) + [0, 0, 0, 0])[:4]
    return np.random.Generator(np.random.Philox(key=seed, counter=counter))


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    """Batch i, shard s: tokens/targets [rows, seq_len] int32."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 64

    def _motifs(self) -> np.ndarray:
        rng = _fold(self.seed, 0xA0)
        return rng.integers(0, self.vocab, (self.n_motifs, self.motif_len),
                            dtype=np.int64)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        assert self.global_batch % num_shards == 0
        rows = self.global_batch // num_shards
        motifs = self._motifs()
        out = np.empty((rows, self.seq_len + 1), np.int64)
        for r in range(rows):
            grow = shard * rows + r
            rng = _fold(self.seed, 1, step, grow)
            # zipf-ish unigram noise
            u = rng.random(self.seq_len + 1)
            noise = (self.vocab * u ** 3).astype(np.int64)
            seq = noise
            # paste periodic motifs (learnable structure)
            m = motifs[rng.integers(0, self.n_motifs)]
            period = self.motif_len * 2
            for start in range(rng.integers(0, period),
                               self.seq_len + 1 - self.motif_len, period):
                seq[start:start + self.motif_len] = m
            out[r] = seq
        return {"tokens": out[:, :-1].astype(np.int32),
                "targets": out[:, 1:].astype(np.int32)}


@dataclasses.dataclass(frozen=True)
class SyntheticImages:
    """Class-conditional Gaussian blobs: learnable image classification.

    Used by the HierTrain CNN examples (LeNet-5 / AlexNet stand-ins for
    CIFAR-10 / tiny-ImageNet).  Batch ``i`` is pure in ``(seed, i)``.
    """
    input_shape: Tuple[int, int, int]
    num_classes: int
    global_batch: int
    seed: int = 0
    noise: float = 0.6

    def _prototypes(self) -> np.ndarray:
        rng = _fold(self.seed, 2)
        return rng.normal(0.0, 1.0, (self.num_classes,) + self.input_shape
                          ).astype(np.float32)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        assert self.global_batch % num_shards == 0
        rows = self.global_batch // num_shards
        protos = self._prototypes()
        rng = _fold(self.seed, 3, step, shard)
        labels = rng.integers(0, self.num_classes, rows)
        x = protos[labels] + rng.normal(
            0.0, self.noise, (rows,) + self.input_shape).astype(np.float32)
        return {"x": x.astype(np.float32),
                "labels": labels.astype(np.int32)}


def make_lm_batch_fn(cfg, shape, seed: int = 0):
    """Batch function for an LM arch config + ShapeSpec (adds the stub
    frontend inputs for vlm/encdec families)."""
    B, T = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        stream = SyntheticTokens(cfg.vocab, T, B, seed)

        def fn(step, shard=0, num_shards=1):
            b = stream.batch(step, shard, num_shards)
            rows = b["tokens"].shape[0]
            rng = _fold(seed, 4, step, shard)
            b["frames"] = rng.normal(0, 1, (rows, T, cfg.d_model)).astype(
                np.float32)
            return b
        return fn
    if cfg.n_frontend_tokens > 0:
        P = min(cfg.n_frontend_tokens, T // 2)
        stream = SyntheticTokens(cfg.vocab, T - P, B, seed)

        def fn(step, shard=0, num_shards=1):
            b = stream.batch(step, shard, num_shards)
            rows = b["tokens"].shape[0]
            rng = _fold(seed, 5, step, shard)
            b["embeds"] = rng.normal(0, 1, (rows, P, cfg.d_model)).astype(
                np.float32)
            return b
        return fn
    stream = SyntheticTokens(cfg.vocab, T, B, seed)
    return stream.batch
