from repro.distrib.sharding import (batch_shardings, batch_spec,
                                    cache_shardings, cache_spec, dp_axes,
                                    opt_state_shardings, param_shardings,
                                    param_spec, replicated)
from repro.distrib.tiered_sync import (TierAssignment, choose_tiers,
                                       dcn_bytes_per_step, tiered_grad_sync)

__all__ = ["batch_shardings", "batch_spec", "cache_shardings", "cache_spec",
           "dp_axes", "opt_state_shardings", "param_shardings", "param_spec",
           "replicated", "TierAssignment", "choose_tiers",
           "dcn_bytes_per_step", "tiered_grad_sync"]
