"""jax version compatibility: ambient mesh context + shard_map.

The distributed runtime is written against the modern jax surface
(``jax.set_mesh`` ambient-mesh context, ``jax.shard_map`` with
``axis_names``/``check_vma``).  Older releases (e.g. 0.4.x, the pinned
container toolchain) have neither: the context manager does not exist
and shard_map lives in ``jax.experimental.shard_map`` with an explicit
``mesh`` argument, ``check_rep`` instead of ``check_vma``, and an
``auto`` set instead of ``axis_names``.  This module folds both surfaces
into one:

* :func:`set_mesh` — delegates to ``jax.set_mesh`` when present;
  otherwise maintains a module-level mesh stack that :func:`shard_map`
  consults, so ``with set_mesh(mesh): jit(step)(...)`` works on both.
* :func:`shard_map` — new-API keyword shape; on old jax it resolves the
  mesh (argument or ambient stack), maps ``check_vma -> check_rep`` and
  ``axis_names -> auto`` (the complement: axes *not* named manual stay
  automatic).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

_MESH_STACK = []


def current_mesh():
    """The innermost mesh entered via :func:`set_mesh` (old-jax path),
    or ``None``."""
    return _MESH_STACK[-1] if _MESH_STACK else None


@contextlib.contextmanager
def set_mesh(mesh):
    """Ambient-mesh context that works on every supported jax."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
        return
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def shard_map(f, *, in_specs, out_specs, axis_names=None, check_vma=True,
              mesh=None):
    """``jax.shard_map`` with a fallback to the experimental API.

    ``axis_names`` is the set of *manual* axes (new-jax meaning); on old
    jax the remaining mesh axes are passed as ``auto``.  On old jax a
    mesh must be resolvable — pass ``mesh=`` or enter :func:`set_mesh`.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map
    m = mesh if mesh is not None else current_mesh()
    if m is None:
        raise ValueError(
            "this jax has no ambient-mesh support; pass mesh= or wrap the "
            "call in repro.distrib.compat.set_mesh(mesh)")
    # Old jax's partial-auto shard_map (auto=...) trips an XLA
    # IsManualSubgroup CHECK on this pattern, so fall back to the mature
    # fully-manual form: axes outside ``axis_names`` become manual but
    # unpartitioned (specs never mention them), i.e. the body computes
    # replicated over them instead of XLA auto-sharding it.  Semantics
    # match; only intra-body compute layout differs.
    return _shard_map(f, mesh=m, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
