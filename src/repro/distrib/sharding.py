"""Named-sharding rules for params, optimizer state, batches and caches.

Axes: ``pod`` (inter-pod DCN — the HierTrain "WAN"), ``data`` (intra-pod
DP/FSDP), ``model`` (intra-pod TP).  Rules are shape-driven with
divisibility fallbacks so every assigned architecture lowers on the
16x16 and 2x16x16 meshes without per-arch special cases:

* weights (ndim >= 2): last dim -> ``model`` (TP), second-to-last ->
  ``data`` (FSDP / ZeRO-3: params gathered on use, grads reduce-
  scattered by XLA's SPMD partitioner).  Layer-stacked leaves
  ``[L, in, out]`` shard ``in``/``out`` the same way; the stack dim
  stays unsharded (it is scanned over).
* batches: leading dim over ``(pod, data)`` when divisible, else
  ``data`` only, else replicated (long_500k's global_batch=1).
* KV caches: batch over DP axes; KV-head dim over ``model`` when
  divisible, else the *sequence* dim over ``model`` (MQA/GQA with few
  KV heads — granite's kv=1 — becomes sequence-sharded decode attention;
  the LSE combine falls out of XLA's reduction handling).
* recurrent states: batch over DP; first state dim divisible by
  ``model`` gets TP (zamba's 112 SSD heads, xlstm's 512-wide head dim).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Tree = Any


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    """Leading-dim data-parallel spec with divisibility fallback."""
    axes = dp_axes(mesh)
    prod = int(np.prod([_axis_size(mesh, a) for a in axes]))
    rest = (None,) * (ndim - 1)
    if axes and batch % prod == 0:
        return P(axes, *rest)
    if "data" in axes and batch % _axis_size(mesh, "data") == 0:
        return P("data", *rest)
    return P(*((None,) * ndim))


def batch_shardings(mesh: Mesh, batch_shapes: Tree) -> Tree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, batch_spec(mesh, s.shape[0],
                                                 len(s.shape))),
        batch_shapes)


def param_spec(mesh: Mesh, shape: Tuple[int, ...], fsdp: bool = True) -> P:
    """TP (``model``) on the largest shardable dim, FSDP (``data``) on the
    largest remaining one.  For ``[L, ...]`` layer-stacked leaves the scan
    dim is excluded.  Putting TP on the larger of (in, out) keeps the
    contraction sharding Megatron-shaped for both halves of an MLP
    (w_in: out-dim TP -> sharded activations; w_out: in-dim TP -> one
    psum per block) instead of sharding a contraction over ``data``.

    ``fsdp=False`` replicates params over ``data`` (TP-only): for models
    whose per-device state fits HBM this removes the per-microbatch
    weight re-gather entirely (§Perf iteration 1)."""
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")
    ndim = len(shape)
    if ndim < 2:
        return P()
    spec: list = [None] * ndim
    start = 1 if ndim >= 3 else 0          # skip the layer-stack dim
    dims = sorted(range(start, ndim), key=lambda i: -shape[i])
    for i in dims:
        if "model" in mesh.axis_names and shape[i] % model == 0 and \
                shape[i] >= model:
            spec[i] = "model"
            dims.remove(i)
            break
    if fsdp:
        for i in dims:
            if "data" in mesh.axis_names and shape[i] % data == 0 and \
                    shape[i] >= data:
                spec[i] = "data"
                break
    return P(*spec)


def fsdp_needed(mesh: Mesh, total_params: int, opt_bytes_per_param: int,
                budget_bytes: float = 8e9) -> bool:
    """TP-only state = (2 + opt) bytes/param over the model axis; use
    FSDP only when that exceeds the per-device budget."""
    model = _axis_size(mesh, "model")
    per_dev = total_params * (2 + opt_bytes_per_param) / model
    return per_dev > budget_bytes


# Megatron column/row assignment by leaf name: column-parallel weights
# shard their OUTPUT dim (no communication on use — the producer's input
# is replicated), row-parallel weights shard their INPUT dim (one psum of
# the block output).  Shape-only rules put TP on wk/wv's contraction dim,
# which costs a psum per use (measured 2304 all-reduces/step on
# qwen2.5-3b train_4k — §Perf iteration 2).
_COLUMN_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "up_proj",
                    "in_proj", "w_in", "b_up", "bq", "bk", "bv", "lm_head",
                    "r", "w_gates", "router", "conv_w", "conv_b"}
_ROW_PARALLEL = {"wo", "w_down", "down_proj", "out_proj"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def param_spec_named(mesh: Mesh, name: str, shape: Tuple[int, ...],
                     fsdp: bool = True) -> P:
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")
    ndim = len(shape)
    if ndim < 2:
        return P()
    tp_dim = None
    if name in _COLUMN_PARALLEL and shape[-1] % model == 0 and \
            shape[-1] >= model:
        tp_dim = ndim - 1
    elif name in _ROW_PARALLEL and shape[-2] % model == 0 and \
            shape[-2] >= model:
        tp_dim = ndim - 2
    if tp_dim is None:
        return param_spec(mesh, shape, fsdp)
    spec: list = [None] * ndim
    if "model" in mesh.axis_names:
        spec[tp_dim] = "model"
    if fsdp and "data" in mesh.axis_names:
        start = 1 if ndim >= 3 else 0
        for i in sorted(range(start, ndim), key=lambda i: -shape[i]):
            if i != tp_dim and shape[i] % data == 0 and shape[i] >= data:
                spec[i] = "data"
                break
    return P(*spec)


def param_shardings(mesh: Mesh, param_shapes: Tree,
                    fsdp: bool = True) -> Tree:
    return jax.tree_util.tree_map_with_path(
        lambda path, s: NamedSharding(
            mesh, param_spec_named(mesh, _leaf_name(path), s.shape, fsdp)),
        param_shapes)


def opt_state_shardings(mesh: Mesh, state_shapes: Tree,
                        fsdp: bool = True) -> Tree:
    """Optimizer state mirrors parameter sharding leaf-for-leaf (scalars —
    the step counter — stay replicated)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, s: NamedSharding(
            mesh, param_spec_named(mesh, _leaf_name(path), s.shape, fsdp)),
        state_shapes)


def cache_spec(mesh: Mesh, shape: Tuple[int, ...], batch: int) -> P:
    """Decode-state sharding.  Layout conventions from the model zoo:
    ``[L, B, S, KV, hd]`` attention caches, ``[L, B, ...state]``
    recurrent states, ``[L, B, K-1, C]`` conv states."""
    model = _axis_size(mesh, "model")
    ndim = len(shape)
    spec: list = [None] * ndim
    if ndim < 2:
        return P()
    # axis 1 is batch for every cache in the zoo.
    bspec = batch_spec(mesh, shape[1], 1)
    spec[1] = bspec[0] if len(bspec) else None
    if "model" in mesh.axis_names and ndim >= 3:
        if ndim == 5 and shape[3] % model == 0 and shape[3] >= model:
            spec[3] = "model"          # KV heads / SSD heads
        elif ndim == 5 and shape[2] % model == 0:
            spec[2] = "model"          # sequence-sharded KV (MQA)
        else:
            # first divisible trailing dim gets TP
            for ax in range(ndim - 1, 1, -1):
                if shape[ax] % model == 0 and shape[ax] >= model:
                    spec[ax] = "model"
                    break
    return P(*spec)


def cache_shardings(mesh: Mesh, cache_shapes: Tree, batch: int) -> Tree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, cache_spec(mesh, s.shape, batch)),
        cache_shapes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
