"""HierTrain tiered gradient synchronization over the pod axis.

This is the paper's core insight mapped to TPU fleets (DESIGN.md §3):
the inter-pod DCN link plays the WAN; "frontend" parameter tiers are
synchronized at full width every step (the layers all workers co-train),
while "backend" tiers — the parameter-heavy leaves the paper centralizes
on one worker — cross the slow link *compressed* (int8 stochastic
rounding, the TPU analogue of the JALAD 8-bit baseline the paper
compares against, here made unbiased so synchronous-SGD semantics hold
in expectation).

Tier assignment is cost-model-driven, reusing the paper's scheduling
idea at leaf granularity: given the DCN budget, greedily demote the
largest leaves to the compressed tier until the predicted sync time fits
``max_sync_fraction`` of the compute time (Algorithm-1-style napkin
math, solved exactly since the greedy is optimal for a knapsack with
uniform value density).

Wire-format accounting (per step, per parameter byte tier):

    frontend: ring all-reduce, 2 (P-1)/P * 4 B/param (f32)
    backend:  all-gather of int8 + per-row scales,
              (P-1)/P * (elems + 4 * rows) B/leaf

so the backend tier moves ~8x fewer DCN bytes.  The per-leaf byte count
is single-sourced from :func:`repro.core.wire.int8_leaf_bytes` — the
same formula the wire cost model charges at activation crossings — so
the predicted sync time and the bytes :func:`_compressed_mean` actually
ships can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wire import int8_leaf_bytes
from repro.kernels import ops as kops

Tree = Any


@dataclasses.dataclass
class TierAssignment:
    quantized: Tree                  # pytree of bool, True = backend tier
    front_bytes: int
    back_bytes: int                  # f32 bytes of the demoted leaves
    back_wire_bytes: float           # their int8 payload + row scales
    sync_seconds: float              # predicted DCN time per step

    @property
    def total_bytes(self) -> int:
        return self.front_bytes + self.back_bytes

    def describe(self) -> str:
        return (f"front={self.front_bytes/1e9:.2f}GB "
                f"back(int8)={self.back_wire_bytes/1e9:.2f}GB wire "
                f"predicted sync={self.sync_seconds*1e3:.1f}ms")


def _leaf_bytes(shape) -> int:
    return int(np.prod(shape)) * 4          # grads sync in f32


def choose_tiers(param_shapes: Tree, *, n_pods: int,
                 dcn_bytes_per_s: float = 25e9,
                 compute_seconds: float = 1.0,
                 max_sync_fraction: float = 0.25) -> TierAssignment:
    """Greedy Algorithm-1-style tier choice: demote largest leaves to the
    int8 tier until predicted DCN sync fits the budget."""
    leaves, treedef = jax.tree.flatten(param_shapes)
    sizes = [_leaf_bytes(l.shape) for l in leaves]
    wire_sizes = [int8_leaf_bytes(l.shape) for l in leaves]
    order = np.argsort(sizes)[::-1]
    ring = 2.0 * (n_pods - 1) / n_pods
    gather = 1.0 * (n_pods - 1) / n_pods

    quant = [False] * len(leaves)

    def sync_time():
        f = sum(s for s, q in zip(sizes, quant) if not q)
        b = sum(w for w, q in zip(wire_sizes, quant) if q)
        return (f * ring + b * gather) / dcn_bytes_per_s

    budget = max_sync_fraction * compute_seconds
    for i in order:
        if sync_time() <= budget:
            break
        quant[i] = True
    fb = sum(s for s, q in zip(sizes, quant) if not q)
    bb = sum(s for s, q in zip(sizes, quant) if q)
    bw = sum(w for w, q in zip(wire_sizes, quant) if q)
    return TierAssignment(
        quantized=jax.tree.unflatten(treedef, quant),
        front_bytes=fb, back_bytes=bb, back_wire_bytes=bw,
        sync_seconds=sync_time())


def _as_2d(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    shape = x.shape
    if x.ndim >= 2:
        return x.reshape(-1, shape[-1]), shape
    return x.reshape(1, -1), shape


def _compressed_mean(g: jax.Array, key: jax.Array, axis: str) -> jax.Array:
    """Unbiased int8 all-gather mean over ``axis`` (manual shard_map axis)."""
    g2, shape = _as_2d(g.astype(jnp.float32))
    q, scale = kops.quantize_int8(g2, key)
    qs = jax.lax.all_gather(q, axis)             # [P, rows, cols] int8
    ss = jax.lax.all_gather(scale, axis)         # [P, rows]
    deq = qs.astype(jnp.float32) * ss[..., None]
    return jnp.mean(deq, axis=0).reshape(shape).astype(g.dtype)


def tiered_grad_sync(grads: Tree, tiers: Optional[TierAssignment],
                     key: jax.Array, axis: str = "pod") -> Tree:
    """Cross-pod gradient mean with per-tier transports.  Must run inside
    ``jax.shard_map`` with ``axis`` manual.  ``tiers=None`` => plain pmean
    (the paper-faithful all-sync baseline)."""
    if tiers is None:
        return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
    leaves, treedef = jax.tree.flatten(grads)
    qflags = jax.tree.leaves(tiers.quantized)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, q, k in zip(leaves, qflags, keys):
        if q:
            out.append(_compressed_mean(leaf, k, axis))
        else:
            out.append(jax.lax.pmean(leaf, axis))
    return jax.tree.unflatten(treedef, out)


def dcn_bytes_per_step(tiers: TierAssignment, n_pods: int) -> float:
    """Wire bytes per step per pod link (diagnostics for EXPERIMENTS.md).

    Backend leaves charge their exact int8 wire size (payload + per-row
    f32 scales, :func:`repro.core.wire.int8_leaf_bytes`) — the same
    accounting :func:`choose_tiers` optimized against."""
    ring = 2.0 * (n_pods - 1) / n_pods
    gather = 1.0 * (n_pods - 1) / n_pods
    return tiers.front_bytes * ring + tiers.back_wire_bytes * gather
