"""Flash-attention forward kernel (Pallas TPU).

Online-softmax attention with explicit VMEM tiling.  Grid is
``(B*H, T/bq, S/bk)``; the last grid axis is the TPU's sequential minor
axis, so the running max / denominator / accumulator live in VMEM scratch
across the K sweep and the output block is written once at the final K
step.  GQA is handled in the BlockSpec ``index_map`` (query head ``h``
reads KV head ``h // rep`` — no materialized K/V repeat).

The kernel also emits the per-query log-sum-exp, which the pure-jnp
chunked backward in ``ops.py`` consumes (standard flash backward without
re-doing the online softmax).

Block sizes default to 512x512 (f32 working set per step:
``3 * 512 * hd + 512 * 512`` ~ 2.3 MB for hd=128, comfortably inside the
~16 MB v5e VMEM).  The MXU sees ``[bq, hd] @ [hd, bk]`` and
``[bq, bk] @ [bk, hd]`` contractions — all dims multiples of 128 for the
shapes this repo runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *,
                causal: bool, window: int, scale: float, nk: int):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale           # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                   # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]

    bq, bk = s.shape
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]                              # [bq, 1]
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # [bq, bk]
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)                   # [bk, hd]
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _emit():
        l = l_scr[:, :1]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)
        lse = m_scr[:, :1] + jnp.log(safe)             # [bq, 1]
        lse_ref[0] = jnp.broadcast_to(lse, lse_ref.shape[1:])


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """q: [BH, T, hd] (head-major); k/v: [BKV, S, hd]; rep = BH//BKV heads
    per KV head.  Returns (o [BH, T, hd], lse [BH, T])."""
    BH, T, hd = q.shape
    BKV, S, _ = k.shape
    assert BH % BKV == 0
    rep = BH // BKV
    bq = min(block_q, T)
    bk = min(block_k, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    nq, nk = T // bq, S // bk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_fwd_kernel, causal=causal, window=window,
                               scale=scale, nk=nk)
    o, lse = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // rep, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // rep, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, LANES), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, T, LANES), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse[:, :, 0]
