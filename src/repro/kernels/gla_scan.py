"""Chunked gated-linear-recurrence kernel (Pallas TPU) — the SSD/mLSTM
primitive shared by Mamba2 and xLSTM.

Contract (matches ``repro.models.lm.gla.chunked_gla``)::

    S_t = exp(a_t) S_{t-1} + k_t^T v_t
    n_t = exp(a_t) n_{t-1} + k_t
    y_t = q_t S_t  [/ max(|q_t n_t|, 1)]

Grid is ``(B*H, T/W)`` — the chunk axis is the TPU's sequential minor
grid axis, so the running ``[dk, dv]`` state and ``[1, dk]`` normalizer
live in VMEM scratch across chunks.  Within a chunk everything is a
``W x W`` / ``W x dk`` / ``W x dv`` matmul (MXU-shaped); the recurrence
only crosses chunks, which is exactly the paper-recommended TPU
adaptation of a GPU sequential-scan kernel: quadratic *inside* the VMEM
tile, linear *across* tiles.

VMEM working set per step (f32): ``W*dk*2 + W*dv*2 + 3*W*W + dk*dv``
— for W=128, dk=dv=128 that is ~0.5 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel(q_ref, k_ref, v_ref, a_ref, y_ref, s_out_ref, n_out_ref,
                S_scr, n_scr, *, normalize: bool, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        S_scr[...] = jnp.zeros_like(S_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    q = q_ref[0].astype(jnp.float32)          # [W, dk]
    k = k_ref[0].astype(jnp.float32)          # [W, dk]
    v = v_ref[0].astype(jnp.float32)          # [W, dv]
    a = a_ref[0].astype(jnp.float32)          # [W, LANES] (col 0 real)

    ca = jnp.cumsum(a[:, :1], axis=0)         # [W, 1] inclusive cumsum
    tot = ca[-1:, :]                          # [1, 1]
    W = q.shape[0]

    # --- intra-chunk quadratic term -----------------------------------
    rel = ca - ca.T                           # [W, W] = ca_i - ca_j
    causal = jax.lax.broadcasted_iota(jnp.int32, (W, W), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (W, W), 1)
    D = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * D
    y = jax.lax.dot(scores, v)                # [W, dv]

    # --- cross-chunk term via carried state ----------------------------
    S_in = S_scr[...]                         # [dk, dv]
    n_in = n_scr[...]                         # [1, dk] (first row real)
    q_dec = q * jnp.exp(ca)                   # [W, dk]
    y = y + jax.lax.dot(q_dec, S_in)

    if normalize:
        denom = jax.lax.dot(scores, jnp.ones((W, 1), jnp.float32))
        denom = denom + jax.lax.dot_general(
            q_dec, n_in, (((1,), (1,)), ((), ())))      # [W, 1]
        y = y / jnp.maximum(jnp.abs(denom), 1.0)

    y_ref[0] = y.astype(y_ref.dtype)

    # --- state update ---------------------------------------------------
    kd = k * jnp.exp(tot - ca)                # [W, dk]
    S_new = jnp.exp(tot) * S_in + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())))      # [dk, dv]
    n_new = jnp.exp(tot) * n_in + jnp.sum(kd, axis=0, keepdims=True)
    S_scr[...] = S_new
    n_scr[...] = n_new

    @pl.when(ci == nc - 1)
    def _emit():
        s_out_ref[0] = S_new
        n_out_ref[0] = jnp.broadcast_to(n_new, n_out_ref.shape[1:])


def gla_scan_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                 log_decay: jax.Array, *, chunk: int = 128,
                 normalize: bool = False, interpret: bool = False):
    """q/k: [BH, T, dk]; v: [BH, T, dv]; log_decay: [BH, T] (f32, <= 0).

    Returns (y [BH, T, dv], S [BH, dk, dv], n [BH, dk]).
    Initial state is zero (callers with a nonzero initial state use the
    jnp reference — prefill/decode paths never hit the kernel).
    """
    BH, T, dk = q.shape
    dv = v.shape[-1]
    W = min(chunk, T)
    assert T % W == 0, (T, W)
    nc = T // W
    LANES = 128
    a = jnp.broadcast_to(log_decay[..., None], (BH, T, LANES))

    kernel = functools.partial(_gla_kernel, normalize=normalize, nc=nc)
    y, S, n = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, W, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, W, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, W, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, W, LANES), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, W, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, 8, dk), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, dv), v.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((BH, 8, dk), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, dk), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, a)
    return y, S, n[:, 0, :]
