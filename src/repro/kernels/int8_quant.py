"""Int8 stochastic-rounding quantizer kernel (Pallas TPU).

Used by the HierTrain tiered gradient sync: "backend" (parameter-heavy)
gradient tiers cross the inter-pod DCN link int8-quantized — the TPU
analogue of JALAD's 8-bit edge-cloud compression, applied to the
paper's insight that bulk parameters should not cross the slow link at
full width.

Per-row absmax scaling over a ``[bm, n]`` VMEM tile::

    scale_i = max_j |x_ij| / 127
    q_ij    = clip(floor(x_ij / scale_i + u_ij), -127, 127)   u ~ U[0,1)

Stochastic rounding keeps the quantizer unbiased (E[q*scale] = x), so
the compressed all-reduce is an unbiased gradient estimator — the
property the tiered-sync equivalence tests check.  The uniform noise is
an explicit kernel input (generated with jax.random outside), keeping
runs reproducible and the kernel portable to interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, u_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                 # [bm, n]
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-30) / 127.0         # [bm, 1]
    u = u_ref[...].astype(jnp.float32)
    q = jnp.floor(x / scale + u)
    q = jnp.clip(q, -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = jnp.broadcast_to(scale, scale_ref.shape)


def quantize_int8(x: jax.Array, noise: jax.Array, *, block_rows: int = 256,
                  interpret: bool = False):
    """x, noise: [M, N] (noise uniform in [0,1)).  Returns
    (q int8 [M, N], scale f32 [M])."""
    M, N = x.shape
    bm = min(block_rows, M)
    while M % bm:                      # largest divisor <= block_rows
        bm -= 1
    LANES = 128

    q, scale = pl.pallas_call(
        _quant_kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm, N), lambda i: (i, 0)),
            pl.BlockSpec((bm, N), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, N), lambda i: (i, 0)),
            pl.BlockSpec((bm, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int8),
            jax.ShapeDtypeStruct((M, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(x, noise)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse map (pure jnp — a single multiply needs no kernel)."""
    return q.astype(jnp.float32) * scale[:, None]
