"""Jit'd public wrappers around the Pallas kernels.

* :func:`flash_attention` — model-layout GQA flash attention with a
  memory-O(T * block) chunked backward (consumes the kernel's LSE).
* :func:`gla_scan` — chunked gated linear recurrence; backward via the
  linear-memory jnp reference.
* :func:`quantize_int8` / :func:`dequantize_int8` — unbiased int8
  compression for the tiered gradient sync.

On non-TPU backends the kernels run in ``interpret=True`` mode (the
kernel body executes as traced JAX ops) — numerically identical, which
is what the oracle tests rely on.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import gla_scan as gs
from repro.kernels import int8_quant as iq
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (prefers multiples of 128)."""
    b = min(target, n)
    while n % b:
        b -= 1
    return b


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, window: int, block_q: int, block_k: int,
                interpret: bool):
    @jax.custom_vjp
    def f(q, k, v):
        o, _ = fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=interpret)
        return o

    def fwd(q, k, v):
        o, lse = fa.flash_attention_fwd(q, k, v, causal=causal,
                                        window=window, block_q=block_q,
                                        block_k=block_k,
                                        interpret=interpret)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        BH, T, hd = q.shape
        BKV, S, _ = k.shape
        rep = BH // BKV
        bk = _pick_block(S, block_k)
        scale = 1.0 / (hd ** 0.5)

        qf = q.astype(jnp.float32).reshape(BKV, rep, T, hd)
        dof = do.astype(jnp.float32).reshape(BKV, rep, T, hd)
        of = o.astype(jnp.float32).reshape(BKV, rep, T, hd)
        lsef = lse.reshape(BKV, rep, T)
        delta = jnp.sum(dof * of, axis=-1)             # [BKV, rep, T]
        kb = k.astype(jnp.float32).reshape(BKV, S // bk, bk, hd)
        vb = v.astype(jnp.float32).reshape(BKV, S // bk, bk, hd)
        qpos = jnp.arange(T)

        def step(dq, xs):
            kj, vj, j = xs                             # [BKV, bk, hd]
            kpos = j * bk + jnp.arange(bk)
            s = jnp.einsum("brth,bkh->brtk", qf, kj) * scale
            mask = jnp.ones((T, bk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None], s, ref.NEG_INF)
            p = jnp.exp(s - lsef[..., None])           # [BKV, rep, T, bk]
            dv_j = jnp.einsum("brtk,brth->bkh", p, dof)
            dp = jnp.einsum("brth,bkh->brtk", dof, vj)
            ds = p * (dp - delta[..., None])
            dq = dq + scale * jnp.einsum("brtk,bkh->brth", ds, kj)
            dk_j = scale * jnp.einsum("brtk,brth->bkh", ds, qf)
            return dq, (dk_j, dv_j)

        dq0 = jnp.zeros_like(qf)
        dq, (dk, dv) = jax.lax.scan(
            step, dq0, (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
                        jnp.arange(S // bk)))
        dk = dk.swapaxes(0, 1).reshape(BKV, S, hd)
        dv = dv.swapaxes(0, 1).reshape(BKV, S, hd)
        return (dq.reshape(BH, T, hd).astype(q.dtype),
                dk.astype(k.dtype), dv.astype(v.dtype))

    f.defvjp(fwd, bwd)
    return f


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Model layout: q [B, T, H, hd]; k/v [B, S, KV, hd] -> [B, T, H, hd]."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    interp = _interpret() if interpret is None else interpret
    bq = _pick_block(T, block_q)
    bk = _pick_block(S, block_k)
    f = _make_flash(causal, int(window), bq, bk, interp)
    qh = q.swapaxes(1, 2).reshape(B * H, T, hd)
    kh = k.swapaxes(1, 2).reshape(B * KV, S, hd)
    vh = v.swapaxes(1, 2).reshape(B * KV, S, hd)
    o = f(qh, kh, vh)
    return o.reshape(B, H, T, hd).swapaxes(1, 2)


# ---------------------------------------------------------------------------
# GLA scan
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_gla(chunk: int, normalize: bool, interpret: bool):
    @jax.custom_vjp
    def f(q, k, v, a):
        y, S, n = gs.gla_scan_fwd(q, k, v, a, chunk=chunk,
                                  normalize=normalize, interpret=interpret)
        return y, S, n

    def fwd(q, k, v, a):
        out = gs.gla_scan_fwd(q, k, v, a, chunk=chunk, normalize=normalize,
                              interpret=interpret)
        return out, (q, k, v, a)

    def bwd(res, cts):
        q, k, v, a = res
        _, vjp = jax.vjp(
            lambda q, k, v, a: ref.ref_gla(q, k, v, a, normalize=normalize),
            q, k, v, a)
        return vjp(cts)

    f.defvjp(fwd, bwd)
    return f


def gla_scan(q: jax.Array, k: jax.Array, v: jax.Array,
             log_decay: jax.Array, *, chunk: int = 128,
             normalize: bool = False, initial_state=None,
             interpret: Optional[bool] = None):
    """Model layout: q/k [B, T, H, dk]; v [B, T, H, dv];
    log_decay [B, T, H].  Contract matches chunked_gla."""
    if initial_state is not None:
        # decode/chained-prefill path: stay on the jnp reference.
        from repro.models.lm.gla import chunked_gla
        return chunked_gla(q, k, v, log_decay, chunk=chunk,
                           normalize=normalize, initial_state=initial_state,
                           use_kernel=False)
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    interp = _interpret() if interpret is None else interpret
    f = _make_gla(min(chunk, T), normalize, interp)
    qh = q.swapaxes(1, 2).reshape(B * H, T, dk)
    kh = k.swapaxes(1, 2).reshape(B * H, T, dk)
    vh = v.swapaxes(1, 2).reshape(B * H, T, dv)
    ah = log_decay.astype(jnp.float32).swapaxes(1, 2).reshape(B * H, T)
    y, S, n = f(qh, kh, vh, ah)
    return (y.reshape(B, H, T, dv).swapaxes(1, 2),
            (S.reshape(B, H, dk, dv), n.reshape(B, H, dk)))


# ---------------------------------------------------------------------------
# Int8 compression
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array, key: jax.Array, *,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Flattens to 2D rows of <= 2**14 lanes, quantizes with stochastic
    rounding.  Returns (q int8, scale f32 per row) in the 2D layout plus
    enough info to invert (see :func:`dequantize_int8`)."""
    interp = _interpret() if interpret is None else interpret
    noise = jax.random.uniform(key, x.shape, jnp.float32)
    return iq.quantize_int8(x, noise, interpret=interp)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return iq.dequantize_int8(q, scale)


def wire_qdq_int8(x: jax.Array, *,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Deterministic int8 wire round trip: per-*sample* rows (leading
    axis), absmax scaling, round-to-nearest (the stochastic-rounding
    noise pinned at 0.5, keeping compiled hybrid steps pure).  Returns
    the dequantized tensor in ``x``'s shape and dtype — exactly what the
    receiving worker reconstructs from ``elems + 4`` wire bytes/sample
    (see :mod:`repro.core.wire`)."""
    interp = _interpret() if interpret is None else interpret
    b = x.shape[0]
    flat = x.reshape(b, -1)
    noise = jnp.full(flat.shape, 0.5, jnp.float32)
    q, scale = iq.quantize_int8(flat, noise, interpret=interp)
    return iq.dequantize_int8(q, scale).reshape(x.shape).astype(x.dtype)
