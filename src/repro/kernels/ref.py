"""Pure-jnp oracles for the Pallas kernels (kernel-native layouts).

Each function is the simplest correct implementation of the kernel
contract — tests assert the kernels match these to tight tolerances
across shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: int = 0
                        ) -> Tuple[jax.Array, jax.Array]:
    """q: [BH, T, hd]; k/v: [BKV, S, hd].  Returns (o, lse [BH, T])."""
    BH, T, hd = q.shape
    BKV, S, _ = k.shape
    rep = BH // BKV
    scale = 1.0 / (hd ** 0.5)
    qf = q.astype(jnp.float32).reshape(BKV, rep, T, hd) * scale
    kf = k.astype(jnp.float32)
    s = jnp.einsum("brth,bsh->brts", qf, kf)
    qpos = jnp.arange(T)
    kpos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    lse = jax.nn.logsumexp(s, axis=-1)
    p = jnp.exp(s - lse[..., None])
    o = jnp.einsum("brts,bsh->brth", p, v.astype(jnp.float32))
    return (o.reshape(BH, T, hd).astype(q.dtype),
            lse.reshape(BH, T))


def ref_gla(q: jax.Array, k: jax.Array, v: jax.Array, log_decay: jax.Array,
            *, normalize: bool = False
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Step-by-step recurrence (the definition).  q/k: [BH, T, dk];
    v: [BH, T, dv]; log_decay: [BH, T].  Returns (y, S_final, n_final)."""
    BH, T, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    af = log_decay.astype(jnp.float32)

    def step(carry, xs):
        S, n = carry
        qt, kt, vt, at = xs                      # [BH, dk] ... [BH]
        g = jnp.exp(at)[:, None]
        S = g[..., None] * S + kt[..., :, None] * vt[..., None, :]
        n = g * n + kt
        y = jnp.einsum("bk,bkv->bv", qt, S)
        if normalize:
            den = jnp.abs(jnp.einsum("bk,bk->b", qt, n))
            y = y / jnp.maximum(den, 1.0)[:, None]
        return (S, n), y

    S0 = jnp.zeros((BH, dk, dv), jnp.float32)
    n0 = jnp.zeros((BH, dk), jnp.float32)
    (S, n), ys = jax.lax.scan(
        step, (S0, n0),
        (qf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
         af.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(v.dtype), S, n


def ref_quantize_int8(x: jax.Array, noise: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Row-wise absmax int8 quantization with supplied uniform noise."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.floor(xf / scale + noise.astype(jnp.float32)),
                 -127.0, 127.0)
    return q.astype(jnp.int8), scale[:, 0]
