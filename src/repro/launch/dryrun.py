import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Everything above this line runs before ANY other import: jax locks the
# device count at first initialization, and the production meshes below
# need 512 placeholder host devices.
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch, input_specs  # noqa: E402
from repro.distrib import (batch_shardings, cache_shardings,  # noqa: E402
                           choose_tiers, opt_state_shardings,
                           param_shardings)
from repro.distrib.sharding import fsdp_needed  # noqa: E402
from repro.launch.hlo_analysis import (Roofline, collective_bytes,  # noqa: E402
                                       loop_aware_cost)
from repro.launch.mesh import V5E, make_production_mesh, mesh_chips  # noqa: E402
from repro.models.lm.model import build_model  # noqa: E402
from repro.optim import get_optimizer  # noqa: E402
from repro.serve.engine import make_decode_step, make_prefill_step  # noqa: E402
from repro.distrib import compat
from repro.train.step import make_train_step  # noqa: E402

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * memory_analysis()  — per-device argument/output/temp bytes (fits HBM?)
  * cost_analysis()    — per-device HLO FLOPs / bytes accessed
  * collective traffic — parsed from the post-SPMD HLO text
  * the three roofline terms (EXPERIMENTS.md §Roofline reads this JSON)

Usage::

    python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
    python -m repro.launch.dryrun --mesh both --out dryrun_results.json
    python -m repro.launch.dryrun --hier --arch grok-1-314b  # tiered sync
"""


def _tokens_per_step(cfg, shape) -> float:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch * 1.0            # decode: one token


def _model_flops(cfg, shape, n_params_active: int) -> float:
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    return mult * n_params_active * _tokens_per_step(cfg, shape)


def _active_params(cfg, param_shapes) -> int:
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(param_shapes))
    if cfg.family == "moe" and cfg.moe is not None:
        expert = 0
        moe_leaves = param_shapes["layers"]["moe"]
        for name in ("w_gate", "w_up", "w_down"):
            expert += int(np.prod(moe_leaves[name].shape))
        total = total - expert + int(expert * cfg.moe.top_k
                                     / cfg.moe.n_experts)
    return total


def lower_cell(arch_id: str, shape_name: str, mesh, *,
               hier: bool = False, use_flash: Optional[bool] = None,
               microbatches: Optional[int] = None,
               remat_policy: Optional[str] = None,
               fsdp: Optional[bool] = None):
    """Lower one cell.  Returns (lowered, meta) — compile separately."""
    spec = get_arch(arch_id)
    shape = SHAPES[shape_name]
    cfg = spec.lm
    if use_flash is not None:
        cfg = cfg.variant(use_flash=use_flash)
    if remat_policy is not None:
        cfg = cfg.variant(remat_policy=remat_policy)
    # §Perf iteration 4: Megatron-SP residual only when the layer-scan's
    # saved residual stack would not fit; always for 32k prefill (no
    # gradient stacks, and the attention resharding replaces psums).
    mb = microbatches if microbatches is not None else spec.microbatches
    if shape.kind == "prefill":
        cfg = cfg.variant(seq_parallel=True)
    elif shape.kind == "train":
        dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                          if a in mesh.axis_names]))
        stack_gb = (cfg.n_layers * (shape.global_batch / dp / mb)
                    * shape.seq_len * cfg.d_model * 6) / 1e9
        if stack_gb > 4.0:
            cfg = cfg.variant(seq_parallel=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(model.init, key)
    total_params = sum(int(np.prod(s.shape))
                       for s in jax.tree.leaves(param_shapes))
    if fsdp is None:
        # §Perf iteration 1: FSDP only when TP-only state would not fit —
        # otherwise the per-microbatch weight re-gather dominates the
        # collective roofline term for nothing.
        opt_bpp = 4 if spec.optimizer == "sgdm" else 8
        fsdp = (shape.kind == "train" and
                fsdp_needed(mesh, total_params, opt_bpp))
    pshard = param_shardings(mesh, param_shapes, fsdp=fsdp)
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    repl = NamedSharding(mesh, P())

    meta: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name, "kind": shape.kind,
        "mesh": dict(mesh.shape), "hier": hier, "fsdp": fsdp,
        "seq_parallel": cfg.seq_parallel, "microbatches": mb,
        "active_params": _active_params(cfg, param_shapes),
        "total_params": total_params,
    }

    if shape.kind == "train":
        opt = get_optimizer(spec.optimizer)
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        state_shapes = {"params": param_shapes, "opt": opt_shapes}
        sshard = {"params": pshard,
                  "opt": opt_state_shardings(mesh, opt_shapes, fsdp=fsdp)}
        batch_struct = input_specs(cfg, shape)
        bshard = batch_shardings(mesh, batch_struct)
        tiers = None
        if hier:
            n_pods = mesh.shape.get("pod", 1)
            est_compute = (_model_flops(cfg, shape,
                                        meta["active_params"])
                           / (mesh_chips(mesh) * V5E.peak_flops * 0.4))
            tiers = choose_tiers(param_shapes, n_pods=n_pods,
                                 dcn_bytes_per_s=V5E.dcn_bw,
                                 compute_seconds=est_compute)
            meta["tiers"] = tiers.describe()
        step = make_train_step(model, opt, microbatches=mb,
                               hier_sync=hier, tiers=tiers)
        jitted = jax.jit(step, in_shardings=(sshard, bshard, repl),
                         out_shardings=(sshard, None),
                         donate_argnums=(0,))
        with compat.set_mesh(mesh):
            lowered = jitted.lower(state_shapes, batch_struct, key_struct)
        return lowered, meta

    if shape.kind == "prefill":
        step = make_prefill_step(model, max_len=shape.seq_len)
        batch_struct = input_specs(cfg, shape)
        bshard = batch_shardings(mesh, batch_struct)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        with compat.set_mesh(mesh):
            lowered = jitted.lower(param_shapes, batch_struct)
        return lowered, meta

    # decode: one new token against a seq_len cache
    B, S = shape.global_batch, shape.seq_len
    kw = {"enc_len": S} if cfg.family == "encdec" else {}
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S, **kw))
    cshard = cache_shardings(mesh, cache_shapes, B)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tshard = batch_shardings(mesh, {"t": tok})["t"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_decode_step(model)
    jitted = jax.jit(step, in_shardings=(pshard, tshard, cshard, repl),
                     out_shardings=(None, cshard), donate_argnums=(2,))
    with compat.set_mesh(mesh):
        lowered = jitted.lower(param_shapes, tok, cache_shapes, pos)
    return lowered, meta


def analyse(lowered, meta, hw=V5E) -> Dict[str, Any]:
    t0 = time.perf_counter()
    compiled = lowered.compile()
    meta["compile_s"] = round(time.perf_counter() - t0, 1)
    chips = int(np.prod(list(meta["mesh"].values())))

    ma = compiled.memory_analysis()
    meta["memory"] = {
        "argument_gb": ma.argument_size_in_bytes / 1e9,
        "output_gb": ma.output_size_in_bytes / 1e9,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "alias_gb": ma.alias_size_in_bytes / 1e9,
        "peak_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 1e9,
        "fits_16gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes +
                      ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        < hw.hbm_bytes,
    }
    ca = compiled.cost_analysis()
    # XLA counts while bodies once; the loop-aware walk corrects by trip
    # count (both are recorded; the roofline uses the corrected numbers).
    meta["xla_cost"] = {"flops_per_dev": float(ca.get("flops", 0.0)),
                        "bytes_per_dev": float(ca.get("bytes accessed",
                                                      0.0))}
    hlo_text = compiled.as_text()
    flops_dev, bytes_dev, coll_dev = loop_aware_cost(hlo_text)

    stats = collective_bytes(hlo_text)
    meta["collectives"] = {"by_kind_gb": {k: v / 1e9 for k, v in
                                          stats.bytes_by_kind.items()},
                           "counts": stats.count_by_kind,
                           "static_total_gb": stats.total_bytes / 1e9,
                           "loop_aware_gb": coll_dev / 1e9}

    cfg = get_arch(meta["arch"]).lm
    shape = SHAPES[meta["shape"]]
    roof = Roofline(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_dev,
        chips=chips, peak_flops=hw.peak_flops, hbm_bw=hw.hbm_bw,
        link_bw=hw.ici_bw,
        model_flops=_model_flops(cfg, shape, meta["active_params"]))
    meta["roofline"] = {k: (round(v, 6) if isinstance(v, float) else v)
                        for k, v in roof.row().items()}
    return meta


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, *,
             hier: bool = False, **kw) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, meta = lower_cell(arch_id, shape_name, mesh, hier=hier, **kw)
    return analyse(lowered, meta)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--hier", action="store_true",
                    help="use HierTrain tiered gradient sync (train cells)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--use-flash", action="store_true", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = []
    failures = 0
    for arch_id in archs:
        spec = get_arch(arch_id)
        shapes = (list(spec.shapes) + sorted(spec.skips)
                  if args.shape == "all" else args.shape.split(","))
        for shape_name in shapes:
            if shape_name in spec.skips:
                results.append({"arch": arch_id, "shape": shape_name,
                                "status": "SKIP",
                                "reason": spec.skips[shape_name]})
                print(f"[SKIP] {arch_id} x {shape_name}")
                continue
            for multi in meshes:
                tag = f"{arch_id} x {shape_name} x " \
                      f"{'2x16x16' if multi else '16x16'}" \
                      + (" [hier]" if args.hier else "")
                try:
                    t0 = time.perf_counter()
                    meta = run_cell(arch_id, shape_name, multi,
                                    hier=args.hier,
                                    use_flash=args.use_flash,
                                    microbatches=args.microbatches)
                    meta["status"] = "OK"
                    dt = time.perf_counter() - t0
                    r = meta["roofline"]
                    print(f"[OK]  {tag}: compile={meta['compile_s']}s "
                          f"peak={meta['memory']['peak_gb']:.2f}GB/dev "
                          f"dominant={r['dominant']} "
                          f"terms(c/m/n)={r['compute_s']:.4f}/"
                          f"{r['memory_s']:.4f}/{r['collective_s']:.4f}s "
                          f"useful={r['useful_ratio']:.2f} "
                          f"({dt:.0f}s)")
                    results.append(meta)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc()
                    results.append({"arch": arch_id, "shape": shape_name,
                                    "multi_pod": multi, "status": "FAIL",
                                    "error": f"{type(e).__name__}: {e}"})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out} ({len(results)} cells)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
