"""Post-optimization HLO analysis: collective-traffic accounting and the
three-term roofline.

``collective_bytes`` parses ``compiled.as_text()``: every def line
provides a name -> (dtype, shape) map; every ``all-gather`` /
``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction contributes the byte size of its
*operands* (the data handed to the transport), summed over the module.
The text is the per-partition SPMD module, so totals are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

_ELEM_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# def line:   %name = bf16[1,2,3]{...} op-name(...)  /  name.1 = (tuple...)
# tuple types may contain one level of nesting and per-element layouts.
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^()]*\))*\))|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_SIGIL_NAME_RE = re.compile(r"%([\w.\-]+)")
_BARE_OPERAND_RE = re.compile(
    r"(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?\s+)?%?([\w.\-]+)")


def _operand_names(blob: str) -> list:
    """Instruction names referenced in an operand list ``blob``.

    Splitting the blob on commas breaks inside shape dims
    (``f32[128,64]`` -> ``f32[128``), so prefer the ``%``-sigil form
    every known dump uses; fall back to comma tokens for sigil-free
    dumps (whose shapes then contain no commas to trip on).
    """
    names = _SIGIL_NAME_RE.findall(blob)
    if names:
        return names
    out = []
    for tok in blob.split(","):
        nm = _BARE_OPERAND_RE.match(tok.strip())
        if nm:
            out.append(nm.group(1))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _ELEM_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _ELEM_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def describe(self) -> str:
        parts = [f"{k}: n={self.count_by_kind[k]} "
                 f"{self.bytes_by_kind[k]/1e9:.3f}GB"
                 for k in sorted(self.bytes_by_kind)]
        return "; ".join(parts) if parts else "none"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    sizes: Dict[str, int] = {}
    bytes_by: Dict[str, int] = {}
    count_by: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str = m.group(1), m.group(2)
        sizes[name.lstrip("%")] = _shape_bytes(type_str)
        # match the op kind after the '=' and type
        rest = line[m.end():]
        opm = re.match(r"\s*([\w\-]+)", rest)
        if not opm:
            continue
        kind = opm.group(1)
        base = None
        for c in _COLLECTIVES:
            if kind == c or kind.startswith(c + "-"):  # e.g. all-reduce-start
                base = c
                break
        if base is None or kind.endswith("-done"):
            continue
        # operand bytes: names inside the first (...) after the op kind
        pm = _OPERAND_RE.search(rest)
        nbytes = 0
        if pm:
            for nm in _operand_names(pm.group(1)):
                if nm in sizes:
                    nbytes += sizes[nm]
        if nbytes == 0:
            nbytes = sizes.get(name.lstrip("%"), 0)
        bytes_by[base] = bytes_by.get(base, 0) + nbytes
        count_by[base] = count_by.get(base, 0) + 1
    return CollectiveStats(bytes_by, count_by)


# ---------------------------------------------------------------------------
# Loop-aware HLO cost walk
# ---------------------------------------------------------------------------
#
# XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` surfaces) counts
# a ``while`` body ONCE, so a scanned 64-layer model reports ~1/64th of its
# real FLOPs.  The walker below parses the post-optimization module text,
# builds the computation call graph, extracts loop trip counts from the
# loop-condition constants, and accumulates dot FLOPs and operand/result
# bytes with bodies multiplied by their trip counts.

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*"
                           r"(?:->\s*[^{]*)?\{\s*$")
_CALLEE_SINGLE_RE = re.compile(
    r"(to_apply|body|condition|calls)=%?([\w.\-]+)")
_CALLEE_MULTI_RE = re.compile(
    r"(branch_computations|called_computations)=\{([^}]*)\}")
_DOT_DNUMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"=\s*[su](?:8|16|32|64)\[\]\s*constant\((\d+)\)")


@dataclasses.dataclass
class _Instr:
    kind: str
    result_bytes: int
    result_dims: Tuple[int, ...]
    operand_names: Tuple[str, ...]
    callees: Tuple[str, ...]          # non-condition callees
    cond: Optional[str]               # while-condition computation
    flops: float                      # own flops (dot/conv only)


def _parse_dims(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d.strip())


def _dot_flops(line: str, result_dims, operand_dims) -> float:
    m = _DOT_DNUMS_RE.search(line)
    if not m or not operand_dims:
        return 0.0
    contract = [int(i) for i in m.group(1).split(",") if i.strip()]
    k = 1
    for i in contract:
        if i < len(operand_dims):
            k *= operand_dims[i]
    n = 1
    for d in result_dims:
        n *= d
    return 2.0 * n * k


class HloCostWalk:
    """Parse + walk one HLO module text."""

    def __init__(self, hlo_text: str):
        self.comps: Dict[str, list] = {}
        self.shapes: Dict[str, Tuple[str, Tuple[int, ...], int]] = {}
        self._memo: Dict[str, Tuple[float, float, float]] = {}
        self.trip_counts: Dict[str, int] = {}
        self._parse(hlo_text)

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and line.endswith("{") and \
                    not line.startswith("HloModule"):
                head = line.strip()
                if head.startswith("ENTRY "):
                    head = head[len("ENTRY "):]
                cur = head.split()[0].split("(")[0].lstrip("%")
                self.comps[cur] = []
                continue
            if line.strip() == "}":
                continue
            if cur is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name = m.group(1).lstrip("%")
            type_str = m.group(2)
            dims = _parse_dims(type_str)
            nbytes = _shape_bytes(type_str)
            rest = line[m.end():]
            opm = re.match(r"\s*([\w\-]+)", rest)
            kind = opm.group(1) if opm else "?"
            pm = _OPERAND_RE.search(rest)
            operands = _operand_names(pm.group(1)) if pm else []
            callees = []
            cond = None
            for key, val in _CALLEE_SINGLE_RE.findall(rest):
                if key == "condition":
                    cond = val
                else:
                    callees.append(val)
            for _, val in _CALLEE_MULTI_RE.findall(rest):
                callees.extend(c.strip().lstrip("%")
                               for c in val.split(",") if c.strip())
            flops = 0.0
            if kind == "dot":
                op_dims = (self.shapes.get(operands[0], ("", (), 0))[1]
                           if operands else ())
                flops = _dot_flops(rest, dims, op_dims)
            self.shapes[name] = (kind, dims, nbytes)
            self.comps[cur].append(_Instr(
                kind=kind, result_bytes=nbytes, result_dims=dims,
                operand_names=tuple(operands), callees=tuple(callees),
                cond=cond, flops=flops))
            # remember per-computation constants for trip-count extraction
            cc = _CONST_RE.search(line)
            if cc:
                self.trip_counts[cur] = max(
                    self.trip_counts.get(cur, 0), int(cc.group(1)))

    def _entry(self) -> str:
        for name in self.comps:
            if "main" in name:
                return name
        return next(iter(self.comps))

    def _root_kind(self, ins: "_Instr") -> str:
        for c in ins.callees:
            body = self.comps.get(c)
            if body:
                return body[-1].kind
        return ""

    def _contains_kind(self, ins: "_Instr", kind: str) -> bool:
        for c in ins.callees:
            for sub in self.comps.get(c, ()):
                if sub.kind == kind:
                    return True
        return False

    def cost(self, comp: Optional[str] = None
             ) -> Tuple[float, float, float]:
        """Returns (flops, hbm_bytes, collective_bytes), while bodies
        multiplied by their trip counts.

        Bytes model: every *top-level* instruction of a computation reads
        its operands and writes its result once (fusion internals are free
        — that is what fusion means); parameters/constants are free.
        Collective bytes = operand bytes of every collective op.
        """
        comp = comp or self._entry()
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = (0.0, 0.0, 0.0)    # cycle guard
        flops = 0.0
        nbytes = 0.0
        cbytes = 0.0
        for ins in self.comps.get(comp, ()):
            if ins.kind in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast"):
                continue
            flops += ins.flops
            op_sizes = [self.shapes.get(op, ("", (), 0))[2]
                        for op in ins.operand_names]
            op_bytes = sum(op_sizes)
            big = max(op_sizes) if op_sizes else 0
            if ins.kind == "while":
                pass        # carried tuple is aliased in place, not moved
            elif ins.kind == "dynamic-slice":
                nbytes += 2 * ins.result_bytes
            elif ins.kind == "dynamic-update-slice" or (
                    ins.kind == "fusion" and self._contains_kind(
                        ins, "dynamic-update-slice")):
                # in-place update: the big aliased buffer is neither fully
                # read nor fully rewritten — only the update slice moves.
                nbytes += 2 * max(op_bytes - big, 0)
            elif ins.kind == "fusion" and big > 4 * ins.result_bytes and \
                    self._contains_kind(ins, "dynamic-slice"):
                # sliced read of a loop-carried stack: only the slice moves.
                nbytes += 2 * ins.result_bytes + (op_bytes - big)
            else:
                nbytes += ins.result_bytes + op_bytes
            if any(ins.kind == c or ins.kind.startswith(c + "-")
                   for c in _COLLECTIVES) and not ins.kind.endswith("-done"):
                cbytes += op_bytes if op_bytes else ins.result_bytes
            if ins.kind == "while":
                # trip count = the comparison constant in the condition
                trip = self.trip_counts.get(ins.cond, 1) if ins.cond else 1
                for c in ins.callees:
                    f, b, cb = self.cost(c)
                    flops += f * trip
                    nbytes += b * trip
                    cbytes += cb * trip
            elif ins.kind == "fusion":
                # fused internals: flops real, intermediate bytes free
                for c in ins.callees:
                    f, _, cb = self.cost(c)
                    flops += f
                    cbytes += cb
            elif ins.callees:
                for c in ins.callees:
                    f, b, cb = self.cost(c)
                    flops += f
                    nbytes += b
                    cbytes += cb
        self._memo[comp] = (flops, nbytes, cbytes)
        return self._memo[comp]


def loop_aware_cost(hlo_text: str) -> Tuple[float, float, float]:
    """(flops, approx hbm bytes, collective bytes) per device,
    loop-corrected."""
    walk = HloCostWalk(hlo_text)
    return walk.cost()


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    peak_flops: float
    hbm_bw: float
    link_bw: float
    model_flops: float = 0.0          # 6*N*D (or 6*N_active*D)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / self.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step would achieve if it runs
        exactly at the dominant-term bound: useful FLOPs / (bound_s * chips
        * peak)."""
        denom = self.bound_s * self.chips * self.peak_flops
        return self.model_flops / denom if denom else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.flops_per_device * self.chips,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
