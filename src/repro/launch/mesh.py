"""Production mesh + target-hardware constants (TPU v5e).

``make_production_mesh`` is a function (not a module constant) so that
importing this module never touches jax device state — the dry-run
driver must set ``XLA_FLAGS`` before *any* jax initialization.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class Hardware:
    """TPU v5e per-chip numbers used by the roofline analysis."""
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link
    dcn_bw: float = 25e9              # bytes/s per pod (inter-pod axis)
    hbm_bytes: float = 16e9


V5E = Hardware()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
