"""Layered CNNs from the paper's evaluation (LeNet-5, AlexNet) in pure JAX.

A model is an ordered list of :class:`LayerSpec`.  Each layer carries the
metadata the HierTrain profiling stage needs (``MP_i`` parameter bytes,
``MO_i`` per-sample output bytes, forward FLOPs) and the pieces the hybrid
execution engine needs (segment-wise ``apply``).

Shapes are NHWC.  Convs are followed by ReLU and optional max-pool; the first
Dense after a Conv flattens implicitly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    out_ch: int
    kernel: int
    stride: int = 1
    padding: str = "SAME"
    pool: int = 1  # max-pool window == stride applied after ReLU (1 = none)


@dataclasses.dataclass(frozen=True)
class DenseSpec:
    name: str
    out: int
    relu: bool = True


LayerSpec = Any  # ConvSpec | DenseSpec


@dataclasses.dataclass
class LayerMeta:
    name: str
    param_count: int
    out_elems: int      # per sample
    flops_fwd: int      # per sample
    out_shape: Tuple[int, ...]  # per sample

    @property
    def param_bytes(self) -> int:
        return 4 * self.param_count

    @property
    def out_bytes(self) -> int:
        return 4 * self.out_elems


@dataclasses.dataclass
class LayeredModel:
    """A sequential model with per-layer params and segment execution."""
    name: str
    specs: Tuple[LayerSpec, ...]
    input_shape: Tuple[int, ...]  # per-sample, e.g. (32, 32, 3)
    num_classes: int

    # ---- init ----------------------------------------------------------
    def init(self, key: jax.Array) -> List[Dict[str, jax.Array]]:
        params: List[Dict[str, jax.Array]] = []
        shape = self.input_shape
        for spec in self.specs:
            key, sub = jax.random.split(key)
            if isinstance(spec, ConvSpec):
                fan_in = spec.kernel * spec.kernel * shape[-1]
                w = jax.random.normal(
                    sub, (spec.kernel, spec.kernel, shape[-1], spec.out_ch),
                    jnp.float32) * math.sqrt(2.0 / fan_in)
                b = jnp.zeros((spec.out_ch,), jnp.float32)
                params.append({"w": w, "b": b})
                shape = _conv_out_shape(shape, spec)
            else:
                fan_in = int(np.prod(shape))
                w = jax.random.normal(sub, (fan_in, spec.out),
                                      jnp.float32) * math.sqrt(2.0 / fan_in)
                b = jnp.zeros((spec.out,), jnp.float32)
                params.append({"w": w, "b": b})
                shape = (spec.out,)
        return params

    # ---- metadata (the profiling stage's MP_i / MO_i / FLOPs) ----------
    def layer_meta(self) -> List[LayerMeta]:
        metas: List[LayerMeta] = []
        shape = self.input_shape
        for spec in self.specs:
            if isinstance(spec, ConvSpec):
                out_shape = _conv_out_shape(shape, spec)
                # conv output spatial size *before* pooling:
                pre = _conv_out_shape(shape, dataclasses.replace(spec, pool=1))
                flops = 2 * spec.kernel * spec.kernel * shape[-1] * \
                    spec.out_ch * pre[0] * pre[1]
                pcount = spec.kernel * spec.kernel * shape[-1] * spec.out_ch \
                    + spec.out_ch
            else:
                fan_in = int(np.prod(shape))
                out_shape = (spec.out,)
                flops = 2 * fan_in * spec.out
                pcount = fan_in * spec.out + spec.out
            metas.append(LayerMeta(spec.name, pcount,
                                   int(np.prod(out_shape)), int(flops),
                                   out_shape))
            shape = out_shape
        return metas

    @property
    def num_layers(self) -> int:
        return len(self.specs)

    # ---- execution ------------------------------------------------------
    def apply_segment(self, params: Sequence[Dict[str, jax.Array]],
                      x: jax.Array, start: int, stop: int) -> jax.Array:
        """Run layers ``start..stop-1`` (0-indexed) on batch ``x``."""
        for i in range(start, stop):
            x = self.apply_layer(params[i], x, i)
        return x

    def apply_layer(self, p: Dict[str, jax.Array], x: jax.Array,
                    i: int) -> jax.Array:
        spec = self.specs[i]
        if isinstance(spec, ConvSpec):
            y = jax.lax.conv_general_dilated(
                x, p["w"], window_strides=(spec.stride, spec.stride),
                padding=spec.padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            y = jax.nn.relu(y + p["b"])
            if spec.pool > 1:
                y = jax.lax.reduce_window(
                    y, -jnp.inf, jax.lax.max,
                    (1, spec.pool, spec.pool, 1),
                    (1, spec.pool, spec.pool, 1), "VALID")
            return y
        # Dense: flatten if needed.
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        y = x @ p["w"] + p["b"]
        return jax.nn.relu(y) if spec.relu else y

    def apply(self, params: Sequence[Dict[str, jax.Array]],
              x: jax.Array) -> jax.Array:
        return self.apply_segment(params, x, 0, self.num_layers)

    def loss(self, params: Sequence[Dict[str, jax.Array]], x: jax.Array,
             labels: jax.Array, weights: jax.Array | None = None
             ) -> jax.Array:
        """Mean softmax cross-entropy; ``weights`` masks padded samples."""
        logits = self.apply(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        if weights is None:
            return jnp.mean(nll)
        return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def _conv_out_shape(shape: Tuple[int, ...], spec: ConvSpec
                    ) -> Tuple[int, ...]:
    h, w, _ = shape
    if spec.padding == "SAME":
        oh = -(-h // spec.stride)
        ow = -(-w // spec.stride)
    else:  # VALID
        oh = (h - spec.kernel) // spec.stride + 1
        ow = (w - spec.kernel) // spec.stride + 1
    if spec.pool > 1:
        oh //= spec.pool
        ow //= spec.pool
    return (oh, ow, spec.out_ch)


# ---------------------------------------------------------------------------
# The two CNNs from §VI-A.
# ---------------------------------------------------------------------------

def lenet5(num_classes: int = 10) -> LayeredModel:
    """LeNet-5 on CIFAR-10 (32x32x3), 5 trainable layers."""
    return LayeredModel(
        name="lenet5",
        specs=(
            ConvSpec("conv1", 6, 5, padding="VALID", pool=2),
            ConvSpec("conv2", 16, 5, padding="VALID", pool=2),
            DenseSpec("fc1", 120),
            DenseSpec("fc2", 84),
            DenseSpec("fc3", num_classes, relu=False),
        ),
        input_shape=(32, 32, 3),
        num_classes=num_classes,
    )


def alexnet(num_classes: int = 200) -> LayeredModel:
    """AlexNet (classic 224x224 geometry, tiny-ImageNet classes upscaled
    to the canonical input size, as the paper's Chainer reference does),
    8 trainable layers."""
    return LayeredModel(
        name="alexnet",
        specs=(
            ConvSpec("conv1", 64, 11, stride=4, padding="SAME", pool=2),
            ConvSpec("conv2", 192, 5, padding="SAME", pool=2),
            ConvSpec("conv3", 384, 3, padding="SAME"),
            ConvSpec("conv4", 256, 3, padding="SAME"),
            ConvSpec("conv5", 256, 3, padding="SAME", pool=2),
            DenseSpec("fc6", 4096),
            DenseSpec("fc7", 4096),
            DenseSpec("fc8", num_classes, relu=False),
        ),
        input_shape=(224, 224, 3),
        num_classes=num_classes,
    )


def alexnet_tiny(num_classes: int = 200) -> LayeredModel:
    """AlexNet on native 64x64 tiny-ImageNet (used by the smoke tests —
    the 224x224 version is too slow for per-test JAX execution on CPU)."""
    m = alexnet(num_classes)
    return LayeredModel(name="alexnet_tiny", specs=m.specs,
                        input_shape=(64, 64, 3),
                        num_classes=num_classes)


MODELS: Dict[str, Callable[[], LayeredModel]] = {
    "lenet5": lenet5,
    "alexnet": alexnet,
}
