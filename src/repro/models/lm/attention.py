"""Grouped-query attention with RoPE, sliding windows, cross-attention and
single-token decode against a KV cache.

The quadratic reference path lives here (and doubles as the oracle for the
Pallas flash kernel in ``repro/kernels``).  ``use_flash`` switches the train/
prefill path to the kernel.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.common import (Params, ambient_abstract_mesh,
                                    apply_rope, shard_hint,
                                    truncated_normal_init)


def _qkv_hints(q, k, v):
    """Megatron-style activation sharding: heads over ``model`` where
    divisible; K/V with few KV heads replicate over ``model`` (cheap —
    they are 1/rep the size) so the score contraction is never sharded
    (a sharded-hd contraction would psum O(T*S) score tensors).

    When the *query* head count does not divide the model axis (phi3's
    40 heads on a 16-way axis) fall back to CONTEXT PARALLELISM: shard
    the query sequence dim over ``model`` instead — each shard computes
    its query rows against the full K/V, so attention compute/score
    memory still split model_size-ways (without this the whole attention
    runs replicated: measured 16x redundant FLOPs on phi3 prefill_32k)."""
    mesh = ambient_abstract_mesh()
    model = mesh.shape.get("model", 1) if mesh is not None else 1
    heads_shardable = q.shape[2] % model == 0 and q.shape[2] >= model
    if heads_shardable or q.shape[1] == 1:
        q = shard_hint(q, ("pod", "data"), None, "model", None)
    else:
        q = shard_hint(q, ("pod", "data"), "model", None, None)
    k = shard_hint(k, ("pod", "data"), None, "model", None)
    v = shard_hint(v, ("pod", "data"), None, "model", None)
    return q, k, v


def init_attention(key: jax.Array, d_model: int, n_heads: int,
                   n_kv_heads: int, head_dim: int, dtype,
                   qkv_bias: bool = False, kv_dim: Optional[int] = None
                   ) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    kv_dim = kv_dim or d_model
    p = {
        "wq": truncated_normal_init(kq, (d_model, n_heads * head_dim), 1.0,
                                    dtype),
        "wk": truncated_normal_init(kk, (kv_dim, n_kv_heads * head_dim),
                                    1.0, dtype),
        "wv": truncated_normal_init(kv, (kv_dim, n_kv_heads * head_dim),
                                    1.0, dtype),
        "wo": truncated_normal_init(ko, (n_heads * head_dim, d_model), 1.0,
                                    dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(p: Params, x: jax.Array, kv_src: jax.Array, n_heads: int,
                 n_kv_heads: int, head_dim: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, T = x.shape[:2]
    S = kv_src.shape[1]
    q = x @ p["wq"]
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, T, n_heads, head_dim),
            k.reshape(B, S, n_kv_heads, head_dim),
            v.reshape(B, S, n_kv_heads, head_dim))


def mha(q: jax.Array, k: jax.Array, v: jax.Array,
        causal: bool, window: int = 0,
        q_offset: int | jax.Array = 0, block_q: int = 0) -> jax.Array:
    """Reference attention.  q: [B,T,H,hd]; k/v: [B,S,KV,hd].

    ``window > 0`` = sliding-window (each query sees the previous ``window``
    keys inclusive).  ``q_offset`` is the absolute position of q[.,0] minus
    that of k[.,0] (for decode: S_cache).  ``block_q > 0`` switches to the
    memory-bounded blocked evaluation (scan over query blocks, rematerialized
    in backward) — required for the 4k/32k shape cells where the full
    ``[B, KV, T, rep, S]`` score tensor would not fit any memory.
    """
    if block_q and q.shape[1] > block_q and q.shape[1] % block_q == 0:
        return _mha_blocked(q, k, v, causal=causal, window=window,
                            block_q=block_q)
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qf = (q.astype(jnp.float32) / jnp.sqrt(hd).astype(jnp.float32)
          ).reshape(B, T, KV, rep, hd)
    kf = k.astype(jnp.float32)
    # grouped einsum: no materialized head-repeat of K/V
    logits = jnp.einsum("btkrh,bskh->bktrs", qf, kf)
    qpos = jnp.arange(T) + q_offset
    kpos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bktrs,bskh->btkrh", probs, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def _mha_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                 window: int, block_q: int) -> jax.Array:
    """Scan over query blocks; each block takes a full softmax row against
    all of K/V (no online accumulation needed).  The block body is
    checkpointed so backward recomputes scores instead of storing them."""
    B, T, H, hd = q.shape
    nb = T // block_q
    qb = q.reshape(B, nb, block_q, H, hd).swapaxes(0, 1)

    @jax.checkpoint
    def body(_, xs):
        qi, i = xs
        # re-hint inside the scan body: the outer T-sharding dies when the
        # scan slices its block axis, so context parallelism must shard
        # the *within-block* query rows.
        qi, k2, v2 = _qkv_hints(qi, k, v)
        out = mha(qi, k2, v2, causal=causal, window=window,
                  q_offset=i * block_q)
        return None, out

    _, out = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    return out.swapaxes(0, 1).reshape(B, T, H, hd)


def self_attention(p: Params, x: jax.Array, *, n_heads: int,
                   n_kv_heads: int, head_dim: int, causal: bool,
                   rope_theta: float = 0.0, window: int = 0,
                   positions: Optional[jax.Array] = None,
                   use_flash: bool = False, block_q: int = 0) -> jax.Array:
    B, T, _ = x.shape
    q, k, v = _project_qkv(p, x, x, n_heads, n_kv_heads, head_dim)
    q, k, v = _qkv_hints(q, k, v)
    if rope_theta > 0:
        pos = positions if positions is not None else jnp.arange(T)
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    if use_flash:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = mha(q, k, v, causal=causal, window=window, block_q=block_q)
    return out.reshape(B, T, n_heads * head_dim) @ p["wo"]


def cross_attention(p: Params, x: jax.Array, enc_out: jax.Array, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    block_q: int = 0) -> jax.Array:
    B, T, _ = x.shape
    q, k, v = _project_qkv(p, x, enc_out, n_heads, n_kv_heads, head_dim)
    q, k, v = _qkv_hints(q, k, v)
    out = mha(q, k, v, causal=False, block_q=block_q)
    return out.reshape(B, T, n_heads * head_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# Decode path (single new token against a KV cache)
# ---------------------------------------------------------------------------

def decode_self_attention(p: Params, x: jax.Array, cache_k: jax.Array,
                          cache_v: jax.Array, pos: jax.Array, *,
                          n_heads: int, n_kv_heads: int, head_dim: int,
                          rope_theta: float = 0.0, window: int = 0
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B, 1, D]; cache_k/v: [B, S, KV, hd]; pos: scalar int32 (the
    absolute position being written).  Returns (out, new_k, new_v)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, x, n_heads, n_kv_heads, head_dim)
    if rope_theta > 0:
        posv = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, posv, rope_theta)
        k = apply_rope(k, posv, rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1)
    S, KV = cache_k.shape[1], cache_k.shape[2]
    rep = n_heads // KV
    qf = (q.astype(jnp.float32) / jnp.sqrt(head_dim).astype(jnp.float32)
          ).reshape(B, 1, KV, rep, head_dim)
    logits = jnp.einsum("btkrh,bskh->bktrs", qf,
                        cache_k.astype(jnp.float32))
    kpos = jnp.arange(S)
    valid = kpos <= pos
    if window > 0:
        valid &= kpos > pos - window
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bktrs,bskh->btkrh", probs,
                     cache_v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, n_heads * head_dim)
    return out @ p["wo"], cache_k, cache_v
