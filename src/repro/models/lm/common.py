"""Shared building blocks for the LM model zoo (pure JAX, functional).

Conventions:
* params are nested dicts of jnp arrays; layer stacks store params with a
  leading ``[L, ...]`` axis and run under ``lax.scan``.
* activations are ``[B, T, D]``; compute dtype is configurable (bf16 target),
  softmax/normalization statistics are always f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def ambient_abstract_mesh():
    """The mesh currently in scope, or ``None``.

    ``jax.sharding.get_abstract_mesh`` only exists in newer jax releases;
    older ones keep it in ``jax._src.mesh`` (where the empty sentinel is not
    always an ``AbstractMesh``).  Normalize every "no usable mesh" shape to
    ``None`` so callers need a single check.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except AttributeError:
        try:
            from jax._src import mesh as _mesh_lib
            mesh = _mesh_lib.get_abstract_mesh()
        except (ImportError, AttributeError):
            return None
    if mesh is None or getattr(mesh, "empty", False) or \
            not getattr(mesh, "axis_names", None):
        return None
    return mesh


def shard_hint(x: jax.Array, *axes) -> jax.Array:
    """``with_sharding_constraint`` that degrades to a no-op when no mesh
    is in scope (CPU smoke tests) or when an axis name is absent from the
    ambient mesh (single-pod vs multi-pod).  ``axes``: one entry per dim,
    each a mesh-axis name, a tuple of names, or None."""
    mesh = ambient_abstract_mesh()
    if mesh is None:
        return x
    from jax.sharding import PartitionSpec

    # axes in Manual mode (inside shard_map) cannot appear in constraints
    auto = {n for n, t in zip(mesh.axis_names, mesh.axis_types)
            if str(t) == "Auto"}

    def reduce(a, dim):
        """Keep the subset of axis names present in the mesh (and not
        manual); drop the entry if the product no longer divides ``dim``."""
        if a is None:
            return None
        names = tuple(n for n in (a if isinstance(a, tuple) else (a,))
                      if n in auto)
        if not names:
            return None
        prod = 1
        for n in names:
            prod *= mesh.shape[n]
        if dim % prod != 0 or dim < prod:
            return None
        return names if len(names) > 1 else names[0]

    spec = tuple(reduce(a, x.shape[i]) for i, a in enumerate(axes))
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def truncated_normal_init(key: jax.Array, shape: Tuple[int, ...],
                          scale: float, dtype=jnp.bfloat16) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6
             ) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) +
            bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: [B, T, H, hd]; positions: [T] or [B, T] (absolute)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    if positions.ndim == 1:
        angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
        angles = angles[None, :, None, :]  # [1, T, 1, hd/2]
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs
        angles = angles[:, :, None, :]     # [B, T, 1, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings [length, dim] (f32)."""
    pos = np.arange(length)[:, None]
    inv = np.exp(-np.log(10000.0) * np.arange(0, dim, 2) / dim)[None, :]
    emb = np.zeros((length, dim), np.float32)
    emb[:, 0::2] = np.sin(pos * inv)
    emb[:, 1::2] = np.cos(pos * inv)
    return jnp.asarray(emb)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key: jax.Array, d_model: int, d_ff: int, dtype
                ) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": truncated_normal_init(k1, (d_model, d_ff), 1.0, dtype),
        "w_up": truncated_normal_init(k2, (d_model, d_ff), 1.0, dtype),
        "w_down": truncated_normal_init(k3, (d_ff, d_model), 1.0, dtype),
    }


def apply_swiglu(p: Params, x: jax.Array) -> jax.Array:
    g = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    return (g * (x @ p["w_up"])) @ p["w_down"]


def apply_geglu(p: Params, x: jax.Array) -> jax.Array:
    """Gated-GELU MLP (gemma-style); same param layout as SwiGLU."""
    g = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32),
                    approximate=True).astype(x.dtype)
    return (g * (x @ p["w_up"])) @ p["w_down"]


def sinusoidal_position_at(pos: jax.Array, dim: int) -> jax.Array:
    """Single-position sinusoidal embedding [dim] (f32), traced-pos safe."""
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, dim, 2,
                                                 dtype=jnp.float32) / dim)
    ang = pos.astype(jnp.float32) * inv
    emb = jnp.zeros((dim,), jnp.float32)
    emb = emb.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
    return emb


def init_gelu_mlp(key: jax.Array, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w_up": truncated_normal_init(k1, (d_model, d_ff), 1.0, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": truncated_normal_init(k2, (d_ff, d_model), 1.0, dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def apply_gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu((x @ p["w_up"] + p["b_up"]).astype(jnp.float32),
                    approximate=True).astype(x.dtype)
    return h @ p["w_down"] + p["b_down"]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def chunked_softmax_xent(hidden: jax.Array, lm_head: jax.Array,
                         labels: jax.Array, mask: Optional[jax.Array] = None,
                         chunk: int = 512) -> jax.Array:
    """Mean next-token cross-entropy without materializing [B, T, V] at once.

    hidden: [B, T, D] (already final-normed), lm_head: [D, V],
    labels: [B, T] int32, mask: [B, T] (1 = count).
    """
    B, T, D = hidden.shape
    if mask is None:
        mask = jnp.ones((B, T), jnp.float32)
    n_chunks = max(T // chunk, 1)
    cs = T // n_chunks
    h = hidden.reshape(B, n_chunks, cs, D).swapaxes(0, 1)
    y = labels.reshape(B, n_chunks, cs).swapaxes(0, 1)
    m = mask.reshape(B, n_chunks, cs).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        hc, yc, mc = xs
        logits = (hc @ lm_head).astype(jnp.float32)
        # keep the [B, chunk, V] chunk sharded: batch over DP, vocab TP.
        logits = shard_hint(logits, ("pod", "data"), None, "model")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None],
                                   axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (h, y, m))
    return tot / jnp.maximum(cnt, 1.0)
