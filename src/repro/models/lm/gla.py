"""Chunked gated linear recurrence ("GLA/SSD" primitive).

One primitive covers both Mamba2's SSD and xLSTM's mLSTM:

    S_t = exp(a_t) * S_{t-1} + k_t^T v_t          (state  [dk, dv])
    n_t = exp(a_t) * n_{t-1} + k_t                (normalizer, optional)
    y_t = q_t @ S_t  [ / max(|q_t @ n_t|, 1) ]

with ``a_t <= 0`` log-decay.  Input gates are folded into ``k`` by the
caller.  The chunked evaluation is linear in sequence length: quadratic
*within* a chunk (MXU-friendly ``W x W`` matmuls), recurrent *across*
chunks (lax.scan).  This file is the pure-jnp reference; the Pallas kernel
in ``repro/kernels/gla_scan.py`` implements the same contract.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def chunked_gla(q: jax.Array, k: jax.Array, v: jax.Array,
                log_decay: jax.Array, *, chunk: int = 128,
                normalize: bool = False,
                initial_state: Optional[Tuple[jax.Array, jax.Array]] = None,
                use_kernel: bool = False
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """q,k: [B,T,H,dk]; v: [B,T,H,dv]; log_decay: [B,T,H] (<= 0, f32).

    Returns y: [B,T,H,dv] (dtype of v) and final (S: [B,H,dk,dv],
    n: [B,H,dk]).
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.gla_scan(q, k, v, log_decay, chunk=chunk,
                             normalize=normalize,
                             initial_state=initial_state)
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    W = min(chunk, T)
    if T % W:
        # pad to a chunk multiple with zero k/v and zero log-decay: padded
        # steps leave the state untouched and their outputs are dropped.
        pad = W - T % W
        padt = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) *
                                 (a.ndim - 2))
        y, state = chunked_gla(
            padt(q), padt(k), padt(v), padt(log_decay), chunk=W,
            normalize=normalize, initial_state=initial_state)
        return y[:, :T], state
    nc = T // W

    qf = q.astype(jnp.float32).reshape(B, nc, W, H, dk)
    kf = k.astype(jnp.float32).reshape(B, nc, W, H, dk)
    vf = v.astype(jnp.float32).reshape(B, nc, W, H, dv)
    af = log_decay.astype(jnp.float32).reshape(B, nc, W, H)
    ca = jnp.cumsum(af, axis=2)                      # [B,nc,W,H]
    tot = ca[:, :, -1, :]                            # [B,nc,H]

    # Intra-chunk quadratic term (per chunk, all chunks at once).
    # decay matrix D[i,j] = exp(ca_i - ca_j) for j <= i else 0.
    rel = ca[:, :, :, None, :] - ca[:, :, None, :, :]     # [B,nc,W,W,H]
    causal = jnp.tril(jnp.ones((W, W), bool))
    D = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bcihd,bcjhd->bcijh", qf, kf) * D  # [B,nc,W,W,H]
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", scores, vf)

    # Per-chunk summaries for the cross-chunk recurrence.
    kd = kf * jnp.exp(tot[:, :, None, :, None] - ca[..., None])
    chunk_S = jnp.einsum("bcihk,bcihv->bchkv", kd, vf)    # [B,nc,H,dk,dv]
    chunk_n = jnp.einsum("bcihk->bchk", kd)               # [B,nc,H,dk]

    if initial_state is None:
        S0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
    else:
        S0 = initial_state[0].astype(jnp.float32)
        n0 = initial_state[1].astype(jnp.float32)

    def body(carry, xs):
        S, n = carry
        cS, cn, decay_tot = xs              # [B,H,dk,dv],[B,H,dk],[B,H]
        newS = jnp.exp(decay_tot)[:, :, None, None] * S + cS
        newn = jnp.exp(decay_tot)[:, :, None] * n + cn
        return (newS, newn), (S, n)         # emit state *entering* chunk

    (Sf, nf), (S_in, n_in) = jax.lax.scan(
        body, (S0, n0),
        (chunk_S.swapaxes(0, 1), chunk_n.swapaxes(0, 1),
         tot.swapaxes(0, 1)))
    S_in = S_in.swapaxes(0, 1)              # [B,nc,H,dk,dv]
    n_in = n_in.swapaxes(0, 1)              # [B,nc,H,dk]

    q_dec = qf * jnp.exp(ca)[..., None]
    y_inter = jnp.einsum("bcihk,bchkv->bcihv", q_dec, S_in)
    y = y_intra + y_inter
    if normalize:
        denom_intra = jnp.einsum("bcijh,bcjhd->bcihd", scores,
                                 jnp.ones_like(vf[..., :1]))[..., 0]
        denom_inter = jnp.einsum("bcihk,bchk->bcih", q_dec, n_in)
        denom = jnp.abs(denom_intra + denom_inter)
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return (y.reshape(B, T, H, dv).astype(v.dtype),
            (Sf, nf))


def gla_decode_step(q: jax.Array, k: jax.Array, v: jax.Array,
                    log_decay: jax.Array, state: Tuple[jax.Array, jax.Array],
                    *, normalize: bool = False
                    ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token recurrent step.  q,k: [B,H,dk]; v: [B,H,dv];
    log_decay: [B,H]; state: (S [B,H,dk,dv], n [B,H,dk])."""
    S, n = state
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    a = jnp.exp(log_decay.astype(jnp.float32))
    S = a[..., None, None] * S + kf[..., :, None] * vf[..., None, :]
    n = a[..., None] * n + kf
    y = jnp.einsum("bhk,bhkv->bhv", qf, S)
    if normalize:
        denom = jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return y.astype(v.dtype), (S, n)
