"""LayerStack adapter for the LM model zoo (DESIGN.md §8).

Exposes the transformer / GLA / MoE / xLSTM block stacks of
:mod:`repro.models.lm.model` to the HierTrain core — profiler, Algorithm-1
scheduler, hybrid execution engine, DES and train loops — as an ordered
chain of cut-points:

    [embed]  [block_1 ... block_K]  [head]

Cut-point granularity
---------------------
* ``embed`` pins naturally to the *stream start* (token ids are tiny —
  8 bytes/token sample wire cost — but the embedding table is huge, so a
  cut at 1 ships ``T x D`` activations instead of re-hosting the table).
* every block is one cut-point with analytically derived meta
  (``flops_fwd/flops_bwd/param_count/param_bytes/act_bytes/grad_bytes``),
  cross-checkable against the compiled HLO via
  :func:`hlo_crosscheck_flops` (``launch/hlo_analysis.loop_aware_cost``).
* ``head`` pins to the *stream end*: its output is the ``T x V`` logit
  tensor, which is why optimal schedules never cut after it.

Families (``block family`` labels used by benchmarks/tests):

* ``attention`` — ``dense`` decoder blocks (GQA + SwiGLU, local/global
  window pattern preserved per layer).
* ``moe``       — dense skeleton with routed-MoE MLPs.
* ``gla``       — ``zamba``-style Mamba2 (SSD) blocks built on the chunked
  GLA primitive, with an attention block after every
  ``shared_attn_every``-th Mamba layer.  The cut-point protocol requires
  *disjoint per-cut params* (frontend copies are sliced as ``params[:m]``
  and their gradients aggregated per cut), so the recurring attention
  block is **untied** here — each occurrence is its own cut-point with its
  own weights.  The adapter is therefore its own reference model: the
  hybrid-vs-reference exactness suite runs both paths through this stack.
* ``xlstm``     — mLSTM blocks (GLA primitive) with an sLSTM block every
  ``slstm_every``-th position.

Unsupported: ``encdec`` (needs a second input stream) and VLM prefix
embeddings (``n_frontend_tokens > 0``) — the cut-point chain is strictly
linear.

Wire sizes: activations cross cuts in the model dtype (bf16 by default),
but gradients are exchanged in f32 (the weight-update phase of §IV-C
aggregates in full precision), so ``grad_bytes != act_bytes`` whenever the
compute dtype is narrower than f32 — the first profile family to exercise
the explicit ``MG`` channel of the cost model.

MoE caveat: ``apply_moe`` groups tokens (``group_size``); a sub-batch of
``b`` samples dispatches ``b*T`` tokens, which must be divisible by
``min(group_size, b*T)``.  Schedules used for *execution* (not just
scoring) should keep ``group_size >= B*T`` or a divisor relationship.
Capacity-dropping also makes routed MoE only *approximately* decomposable
across the hybrid batch split (which tokens drop depends on the group
composition); the hybrid step is exactly batch-B SGD whenever capacity is
lossless (``capacity_factor >= n_experts / 1``, i.e. no token ever
dropped) and within routing-drop noise otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.layerstack import CutMeta, LayerStack
from repro.models.lm import ssm as ssm_mod
from repro.models.lm import xlstm as xlstm_mod
from repro.models.lm.common import truncated_normal_init
from repro.models.lm.model import (LMConfig, _apply_block, _apply_norm,
                                   _group_layout, _init_block, _init_norm,
                                   _resid_hint)

Params = List[Any]

SUPPORTED_FAMILIES = ("dense", "moe", "zamba", "xlstm")

# cfg.family -> the block-family label used in benchmarks/docs.
FAMILY_LABELS = {"dense": "attention", "moe": "moe", "zamba": "gla",
                 "xlstm": "xlstm"}


@dataclasses.dataclass(frozen=True)
class _BlockSpec:
    kind: str          # embed | attn | moe | mamba2 | mlstm | slstm | head
    window: int = 0    # attention window (0 = full) — attn blocks only


def _block_plan(cfg: LMConfig) -> List[_BlockSpec]:
    """The linear cut-point chain of one LM config."""
    if cfg.family not in SUPPORTED_FAMILIES:
        raise ValueError(
            f"family {cfg.family!r} has no LayerStack adapter "
            f"(supported: {SUPPORTED_FAMILIES})")
    if cfg.n_frontend_tokens > 0:
        raise ValueError("prefix-embedding (VLM/audio) configs are not "
                         "cut-point schedulable")
    plan = [_BlockSpec("embed")]
    if cfg.family in ("dense", "moe"):
        kind = "moe" if cfg.family == "moe" else "attn"
        ng, g, _ = _group_layout(cfg)
        for i in range(cfg.n_layers):
            # gemma3-style pattern: each group is (g-1) local + 1 global.
            is_global = ng > 0 and i < ng * g and i % g == g - 1
            plan.append(_BlockSpec(kind,
                                   0 if is_global else cfg.sliding_window))
    elif cfg.family == "zamba":
        assert cfg.ssm is not None and cfg.shared_attn_every > 0
        g = cfg.shared_attn_every
        for i in range(cfg.n_layers):
            plan.append(_BlockSpec("mamba2"))
            if (i + 1) % g == 0:
                plan.append(_BlockSpec("attn", cfg.sliding_window))
    else:  # xlstm
        assert cfg.xlstm is not None
        g = cfg.xlstm.slstm_every
        for i in range(cfg.n_layers):
            if g > 0 and i % g == g - 1:
                plan.append(_BlockSpec("slstm"))
            else:
                plan.append(_BlockSpec("mlstm"))
    plan.append(_BlockSpec("head"))
    return plan


# ---------------------------------------------------------------------------
# Analytic per-block meta (matmul FLOPs only — what the HLO dot-walker
# counts; elementwise ops ride along free at these arithmetic intensities).
# ---------------------------------------------------------------------------


def _norm_params(cfg: LMConfig) -> int:
    return 2 * cfg.d_model if cfg.norm == "layer" else cfg.d_model


def _attn_meta(cfg: LMConfig, T: int) -> Tuple[int, float]:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    params = D * H * hd + 2 * D * KV * hd + H * hd * D
    if cfg.qkv_bias:
        params += H * hd + 2 * KV * hd
    # qkv + wo projections, then the dense (masked) T x T score/AV matmuls.
    flops = 2 * T * D * (H * hd) + 4 * T * D * (KV * hd) \
        + 4 * T * T * H * hd + 2 * T * (H * hd) * D
    return params, float(flops)


def _mlp_meta(cfg: LMConfig, T: int) -> Tuple[int, float]:
    D, dff = cfg.d_model, cfg.d_ff
    if cfg.mlp == "gelu":
        return 2 * D * dff + dff + D, float(4 * T * D * dff)
    return 3 * D * dff, float(6 * T * D * dff)


def _moe_meta(cfg: LMConfig, T: int) -> Tuple[int, float]:
    moe = cfg.moe
    assert moe is not None
    D = cfg.d_model
    E, K, F = moe.n_experts, moe.top_k, moe.d_ff_expert
    G = min(moe.group_size, T)          # nominal single-sample grouping
    C = max(int(G * K * moe.capacity_factor / E), 1)
    params = D * E + 3 * E * D * F
    # router + dispatch/combine einsums + expert SwiGLU + one-hot builds.
    per_tok = 2 * D * E + 4 * E * C * D + 6 * E * C * D * F / G \
        + 4 * K * E * C
    if moe.n_shared > 0:
        width = moe.d_ff_shared or moe.n_shared * F
        params += 3 * D * width
        per_tok += 6 * D * width
    return params, float(T * per_tok)


def _gla_flops(nh: int, dk: int, dv: int, W: int, T: int) -> float:
    """Chunked-GLA matmul FLOPs for T tokens: intra-chunk quadratic scores
    (2*W*dk) + intra AV (2*W*dv) + chunk-state build and query (4*dk*dv),
    per token per head."""
    return float(T * nh * (2 * W * (dk + dv) + 4 * dk * dv))


def _mamba2_meta(cfg: LMConfig, T: int) -> Tuple[int, float]:
    sc = cfg.ssm
    assert sc is not None
    D = cfg.d_model
    di = ssm_mod.d_inner(D, sc)
    nh = ssm_mod.n_ssm_heads(D, sc)
    conv_ch = di + 2 * sc.d_state
    params = D * (2 * di + 2 * sc.d_state + nh) + sc.d_conv * conv_ch \
        + conv_ch + 3 * nh + di + di * D + _norm_params(cfg)
    W = min(sc.chunk, T)
    flops = 2 * T * D * (2 * di + 2 * sc.d_state + nh) \
        + 2 * T * sc.d_conv * conv_ch \
        + _gla_flops(nh, sc.d_state, sc.head_dim, W, T) \
        + 2 * T * di * D
    return params, float(flops)


def _mlstm_meta(cfg: LMConfig, T: int) -> Tuple[int, float]:
    xc = cfg.xlstm
    assert xc is not None
    D = cfg.d_model
    di = xc.expand * D
    hd = di // xc.n_heads
    params = D * 2 * di + xc.d_conv * di + di + 3 * di * di \
        + di * 2 * xc.n_heads + 2 * xc.n_heads + di + di * D \
        + _norm_params(cfg)
    W = min(xc.chunk, T)
    flops = 2 * T * D * 2 * di + 2 * T * xc.d_conv * di \
        + 6 * T * di * di + 2 * T * di * 2 * xc.n_heads \
        + _gla_flops(xc.n_heads, hd, hd, W, T) \
        + 2 * T * di * D
    return params, float(flops)


def _slstm_meta(cfg: LMConfig, T: int) -> Tuple[int, float]:
    xc = cfg.xlstm
    assert xc is not None
    D = cfg.d_model
    hd = D // xc.n_heads
    params = D * 4 * D + xc.n_heads * hd * 4 * hd + 4 * D + D + D * D \
        + _norm_params(cfg)
    # input projection + per-step recurrent matmul + output projection.
    flops = 2 * T * D * 4 * D + 8 * T * D * hd + 2 * T * D * D
    return params, float(flops)


# ---------------------------------------------------------------------------
# The adapter.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LMLayerStack(LayerStack):
    """An LM config's block stack behind the :class:`LayerStack` protocol.

    ``seq_len`` fixes the per-*sample* meta: one sample is one sequence of
    ``seq_len`` tokens (tokens + targets = ``8 * seq_len`` wire bytes), so
    the HierTrain batch axis is the sequence axis and every schedule's
    ``b_*`` counts sequences.
    """
    cfg: LMConfig
    seq_len: int
    backend: str = "ref"

    def __post_init__(self) -> None:
        if self.backend not in ("ref", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}; pick "
                             f"'ref' or 'pallas'")
        if self.backend == "pallas":
            # Route apply_segment's attention blocks onto the Pallas
            # flash-attention kernel and the mamba2/mLSTM blocks onto the
            # chunked GLA scan (kernels/ops.py; interpret mode off-TPU).
            # Analytic cut meta is backend-independent, so profiles and
            # schedules are identical — only kernel numerics differ,
            # within the oracle suite's pinned tolerance.
            self.cfg = self.cfg.variant(use_flash=True, use_gla_kernel=True)
        self._plan = _block_plan(self.cfg)

    @property
    def name(self) -> str:                        # type: ignore[override]
        return f"{self.cfg.name}@T{self.seq_len}"

    @property
    def family(self) -> str:
        return FAMILY_LABELS[self.cfg.family]

    @property
    def num_layers(self) -> int:
        return len(self._plan)

    # ---- metadata ------------------------------------------------------

    def cut_meta(self) -> List[CutMeta]:
        cfg, T = self.cfg, self.seq_len
        act_elem = jnp.dtype(cfg.dtype).itemsize
        hid_elems = float(T * cfg.d_model)
        hid_act = hid_elems * act_elem
        hid_grad = hid_elems * 4                       # f32 gradient wire
        metas: List[CutMeta] = []
        counts = {k: 0 for k in ("attn", "moe", "mamba2", "mlstm", "slstm")}
        for spec in self._plan:
            if spec.kind == "embed":
                metas.append(CutMeta(
                    name="embed", param_count=cfg.vocab * cfg.d_model,
                    flops_fwd=0.0, flops_bwd=0.0,
                    act_bytes=hid_act, grad_bytes=hid_grad,
                    act_elems=hid_elems, grad_elems=hid_elems,
                    param_bytes=float(cfg.vocab * cfg.d_model * act_elem)))
                continue
            if spec.kind == "head":
                p = cfg.d_model * cfg.vocab + _norm_params(cfg)
                flops = float(2 * T * cfg.d_model * cfg.vocab)
                metas.append(CutMeta(
                    name="head", param_count=p, flops_fwd=flops,
                    flops_bwd=2.0 * flops,
                    act_bytes=float(T * cfg.vocab * act_elem),
                    grad_bytes=float(T * cfg.vocab * 4),
                    act_elems=float(T * cfg.vocab),
                    grad_elems=float(T * cfg.vocab),
                    param_bytes=float(p * act_elem)))
                continue
            if spec.kind == "attn":
                pa, fa = _attn_meta(cfg, T)
                pm, fm = _mlp_meta(cfg, T)
                p, flops = pa + pm + 2 * _norm_params(cfg), fa + fm
            elif spec.kind == "moe":
                pa, fa = _attn_meta(cfg, T)
                pm, fm = _moe_meta(cfg, T)
                p, flops = pa + pm + 2 * _norm_params(cfg), fa + fm
            elif spec.kind == "mamba2":
                p, flops = _mamba2_meta(cfg, T)
            elif spec.kind == "mlstm":
                p, flops = _mlstm_meta(cfg, T)
            else:
                p, flops = _slstm_meta(cfg, T)
            counts[spec.kind] += 1
            metas.append(CutMeta(
                name=f"{spec.kind}{counts[spec.kind]}", param_count=p,
                flops_fwd=flops, flops_bwd=2.0 * flops,
                act_bytes=hid_act, grad_bytes=hid_grad,
                act_elems=hid_elems, grad_elems=hid_elems,
                param_bytes=float(p * act_elem)))
        return metas

    def default_sample_bytes(self) -> float:
        return 8.0 * self.seq_len        # int32 tokens + int32 targets

    # ---- params --------------------------------------------------------

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, len(self._plan))
        params: Params = []
        for spec, k in zip(self._plan, keys):
            if spec.kind == "embed":
                params.append({"embed": truncated_normal_init(
                    k, (cfg.vocab, cfg.d_model), 1.0, cfg.dtype)})
            elif spec.kind == "head":
                params.append({
                    "final_norm": _init_norm(cfg),
                    "lm_head": truncated_normal_init(
                        k, (cfg.d_model, cfg.vocab), 1.0, cfg.dtype)})
            elif spec.kind in ("attn", "moe"):
                params.append(_init_block(k, cfg))
            elif spec.kind == "mamba2":
                params.append({"pre": _init_norm(cfg),
                               "m": ssm_mod.init_mamba2(
                                   k, cfg.d_model, cfg.ssm, cfg.dtype)})
            elif spec.kind == "mlstm":
                params.append({"pre": _init_norm(cfg),
                               "m": xlstm_mod.init_mlstm(
                                   k, cfg.d_model, cfg.xlstm, cfg.dtype)})
            else:
                params.append({"pre": _init_norm(cfg),
                               "s": xlstm_mod.init_slstm(
                                   k, cfg.d_model, cfg.xlstm, cfg.dtype)})
        return params

    # ---- execution -----------------------------------------------------

    def apply_segment(self, params: Params, x: jax.Array, start: int,
                      stop: int) -> jax.Array:
        cfg = self.cfg
        for i in range(start, stop):
            spec, p = self._plan[i], params[i]
            if spec.kind == "embed":
                x = jnp.take(p["embed"], x, axis=0)
            elif spec.kind == "head":
                x = _apply_norm(cfg, p["final_norm"], x) @ p["lm_head"]
            elif spec.kind in ("attn", "moe"):
                x = _apply_block(cfg, p, x, spec.window)
            elif spec.kind == "mamba2":
                h = _resid_hint(cfg, x)
                hn = _apply_norm(cfg, p["pre"], h)
                x = h + ssm_mod.apply_mamba2(p["m"], hn, cfg.ssm,
                                             use_kernel=cfg.use_gla_kernel)
            elif spec.kind == "mlstm":
                h = _resid_hint(cfg, x)
                hn = _apply_norm(cfg, p["pre"], h)
                x = h + xlstm_mod.apply_mlstm(p["m"], hn, cfg.xlstm,
                                              use_kernel=cfg.use_gla_kernel)
            else:
                h = _resid_hint(cfg, x)
                hn = _apply_norm(cfg, p["pre"], h)
                x = h + xlstm_mod.apply_slstm(p["s"], hn, cfg.xlstm)
        return x

    def sum_loss(self, logits: jax.Array, labels: jax.Array) -> jax.Array:
        """Per-sequence-sum token cross-entropy (f32)."""
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return jnp.sum(nll)

    def dummy_batch(self, key: jax.Array, batch: int
                    ) -> Tuple[jax.Array, jax.Array]:
        kx, ky = jax.random.split(key)
        x = jax.random.randint(kx, (batch, self.seq_len), 0, self.cfg.vocab)
        y = jax.random.randint(ky, (batch, self.seq_len), 0, self.cfg.vocab)
        return x, y


def lm_layerstack(cfg: LMConfig, seq_len: int,
                  backend: str = "ref") -> LMLayerStack:
    """Build the LayerStack adapter over ``cfg``'s block stack.

    ``backend="pallas"`` routes attention blocks onto
    ``kernels/flash_attention.py`` and GLA-family blocks (mamba2/mLSTM)
    onto ``kernels/gla_scan.py``; ``"ref"`` (default) keeps the pure-jnp
    reference path that ``kernels/ref.py``-style oracles pin.  Profiles
    and schedules are backend-independent."""
    return LMLayerStack(cfg=cfg, seq_len=seq_len, backend=backend)


# ---------------------------------------------------------------------------
# HLO cross-check: compile one cut-point's forward segment and count its
# dot FLOPs with the loop-aware HLO walker — the guard that keeps the
# analytic meta honest as block implementations evolve.
# ---------------------------------------------------------------------------


def hlo_block_flops(stack: LMLayerStack, cut: int, batch: int = 1) -> float:
    """Measured per-sample matmul FLOPs of cut-point ``cut`` (compiled)."""
    from repro.launch.hlo_analysis import loop_aware_cost
    params = stack.init(jax.random.PRNGKey(0))
    x, _ = stack.dummy_batch(jax.random.PRNGKey(1), batch)
    # repro-lint: disable-next=RA102 one-shot HLO probe, compiled once per crosscheck
    xi = x if cut == 0 else jax.jit(
        lambda p, v: stack.apply_segment(p, v, 0, cut))(params, x)
    fn = jax.jit(lambda p, v: stack.apply_segment(p, v, cut, cut + 1))
    hlo = fn.lower(params, xi).compile().as_text()
    flops, _, _ = loop_aware_cost(hlo)
    return float(flops) / batch


def hlo_crosscheck_flops(stack: LMLayerStack, cut: int, batch: int = 1
                         ) -> Tuple[float, float]:
    """(analytic, hlo-measured) per-sample forward FLOPs of one cut."""
    analytic = stack.cut_meta()[cut].flops_fwd
    return analytic, hlo_block_flops(stack, cut, batch)
