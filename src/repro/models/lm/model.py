"""LM model zoo: one config dataclass, one builder, five families.

Families
--------
* ``dense``  — pre-norm decoder (GQA + SwiGLU), optional sliding/global
  attention pattern (gemma3), optional VLM/audio prefix embeddings (pixtral).
* ``moe``    — dense skeleton with the MLP replaced by a routed MoE
  (grok-1, qwen2-moe incl. shared experts).
* ``zamba``  — Mamba2 backbone with a single *shared* attention+MLP block
  applied every ``shared_attn_every`` layers (zamba2).
* ``xlstm``  — mLSTM blocks with an sLSTM block every ``slstm_every``
  (xlstm).
* ``encdec`` — whisper-style encoder-decoder with cross-attention; the audio
  conv frontend is a stub (precomputed frame embeddings are model inputs).

All stacks scan over layers (stacked params) so compiled HLO stays small for
the 512-device dry-runs.  Mixed attention patterns (gemma3's 5 local : 1
global) are realized as *grouped* scans so the window size stays a static
Python int in every sub-scan (a requirement for the Pallas flash kernel and
for cheap masks).  Every family exposes::

    init(key)                          -> params
    loss_fn(params, batch)             -> scalar  (train objective)
    prefill(params, batch, max_len)    -> (last_logits, cache)
    decode_step(params, tok, cache, pos) -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import attention as attn
from repro.models.lm import moe as moe_mod
from repro.models.lm import ssm as ssm_mod
from repro.models.lm import xlstm as xlstm_mod
from repro.models.lm.common import (Params, apply_geglu, apply_gelu_mlp,
                                    apply_swiglu, chunked_softmax_xent,
                                    init_gelu_mlp, init_swiglu, layer_norm,
                                    rms_norm, shard_hint,
                                    sinusoidal_position_at,
                                    sinusoidal_positions,
                                    truncated_normal_init)
from repro.models.lm.moe import MoEConfig
from repro.models.lm.ssm import SSMConfig
from repro.models.lm.xlstm import XLSTMConfig


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                     # dense | moe | zamba | xlstm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0         # local attention width (0 = full)
    global_every: int = 0           # gemma3: every k-th layer is global
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    shared_attn_every: int = 0      # zamba
    encoder_layers: int = 0
    n_frontend_tokens: int = 0      # stub prefix length (frames / patches)
    norm: str = "rms"               # rms | layer
    mlp: str = "swiglu"             # swiglu | geglu | gelu
    dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "none"      # none | dots
    loss_chunk: int = 512
    attn_block_q: int = 512         # blocked-attention q tile (0 = off)
    seq_parallel: bool = False      # Megatron-SP residual (T over model)
    use_flash: bool = False
    use_gla_kernel: bool = False
    sub_quadratic: bool = False     # True => long_500k decode is eligible

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def variant(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# norm / mlp dispatch
# ---------------------------------------------------------------------------

def _resid_hint(cfg: LMConfig, x: jax.Array) -> jax.Array:
    """Residual-stream sharding: batch over DP; with ``seq_parallel``
    also T over `model` (Megatron-SP) — shrinks the layer-scan's saved
    residual stack model_size-fold at the cost of per-layer attention
    reshards, so the launcher enables it only when the stack would
    otherwise blow the HBM budget (measured: grok-1 12.9 GB -> 0.8 GB,
    but qwen2.5's collective term grows 29% for a stack that already
    fits)."""
    return shard_hint(x, ("pod", "data"),
                      "model" if cfg.seq_parallel else None, None)


def _init_norm(cfg: LMConfig) -> Params:
    if cfg.norm == "layer":
        return {"w": jnp.ones((cfg.d_model,), cfg.dtype),
                "b": jnp.zeros((cfg.d_model,), cfg.dtype)}
    return {"w": jnp.zeros((cfg.d_model,), cfg.dtype)}


def _apply_norm(cfg: LMConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "layer":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"])


def _init_mlp(key, cfg: LMConfig) -> Params:
    if cfg.mlp == "gelu":
        return init_gelu_mlp(key, cfg.d_model, cfg.d_ff, cfg.dtype)
    return init_swiglu(key, cfg.d_model, cfg.d_ff, cfg.dtype)


def _apply_mlp(cfg: LMConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.mlp == "gelu":
        return apply_gelu_mlp(p, x)
    if cfg.mlp == "geglu":
        return apply_geglu(p, x)
    return apply_swiglu(p, x)


# ---------------------------------------------------------------------------
# transformer block (dense / moe families; also zamba's shared block)
# ---------------------------------------------------------------------------

def _init_block(key, cfg: LMConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": _init_norm(cfg),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, cfg.dtype,
                                    cfg.qkv_bias),
        "ln2": _init_norm(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(k2, cfg.d_model, cfg.moe, cfg.dtype)
    else:
        p["mlp"] = _init_mlp(k3, cfg)
    return p


def _apply_block(cfg: LMConfig, p: Params, x: jax.Array, window: int,
                 positions: Optional[jax.Array] = None,
                 causal: bool = True) -> jax.Array:
    x = _resid_hint(cfg, x)
    h = _apply_norm(cfg, p["ln1"], x)
    h = attn.self_attention(
        p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, causal=causal, rope_theta=cfg.rope_theta,
        window=window, positions=positions, use_flash=cfg.use_flash,
        block_q=cfg.attn_block_q)
    x = x + h
    h = _apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        h = moe_mod.apply_moe(p["moe"], h, cfg.moe)
    else:
        h = _apply_mlp(cfg, p["mlp"], h)
    return x + h


def _prefill_block(cfg: LMConfig, p: Params, x: jax.Array, max_len: int,
                   window: int) -> Tuple[jax.Array, Params]:
    """Transformer block forward that also emits its (padded) KV cache."""
    B, T, _ = x.shape
    x = _resid_hint(cfg, x)
    h = _apply_norm(cfg, p["ln1"], x)
    q, k, v = attn._project_qkv(p["attn"], h, h, cfg.n_heads,
                                cfg.n_kv_heads, cfg.hd)
    q, k, v = attn._qkv_hints(q, k, v)
    pos = jnp.arange(T)
    if cfg.rope_theta > 0:
        q = attn.apply_rope(q, pos, cfg.rope_theta)
        k = attn.apply_rope(k, pos, cfg.rope_theta)
    if cfg.use_flash:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=True, window=window)
    else:
        o = attn.mha(q, k, v, causal=True, window=window,
                     block_q=cfg.attn_block_q)
    x = x + o.reshape(B, T, -1) @ p["attn"]["wo"]
    h = _apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        h = moe_mod.apply_moe(p["moe"], h, cfg.moe)
    else:
        h = _apply_mlp(cfg, p["mlp"], h)
    pad = jnp.zeros((B, max_len - T) + k.shape[2:], cfg.dtype)
    cache = {"k": jnp.concatenate([k.astype(cfg.dtype), pad], axis=1),
             "v": jnp.concatenate([v.astype(cfg.dtype), pad], axis=1)}
    return x + h, cache


def _decode_block(cfg: LMConfig, p: Params, x: jax.Array, cache: Params,
                  pos: jax.Array, window: int) -> Tuple[jax.Array, Params]:
    h = _apply_norm(cfg, p["ln1"], x)
    h, ck, cv = attn.decode_self_attention(
        p["attn"], h, cache["k"], cache["v"], pos, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, window=window)
    x = x + h
    h = _apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        h = moe_mod.apply_moe(p["moe"], h, cfg.moe)
    else:
        h = _apply_mlp(cfg, p["mlp"], h)
    return x + h, {"k": ck, "v": cv}


def _maybe_remat(cfg: LMConfig, fn: Callable) -> Callable:
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Model build — per family
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: LMConfig
    init: Callable[[jax.Array], Params]
    hidden_fn: Callable[[Params, Dict[str, jax.Array]], jax.Array]
    loss_fn: Callable[[Params, Dict[str, jax.Array]], jax.Array]
    prefill: Callable[..., Tuple[jax.Array, Params]]
    decode_step: Callable[..., Tuple[jax.Array, Params]]
    init_cache: Callable[..., Params]   # (batch, max_len, **kw) -> cache


def build_model(cfg: LMConfig) -> Model:
    if cfg.family in ("dense", "moe"):
        return _build_decoder(cfg)
    if cfg.family == "zamba":
        return _build_zamba(cfg)
    if cfg.family == "xlstm":
        return _build_xlstm(cfg)
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    raise ValueError(f"unknown family {cfg.family}")


# --- shared head/embedding helpers ----------------------------------------

def _init_head(key, cfg: LMConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "embed": truncated_normal_init(k1, (cfg.vocab, cfg.d_model), 1.0,
                                       cfg.dtype),
        "final_norm": _init_norm(cfg),
        "lm_head": truncated_normal_init(k2, (cfg.d_model, cfg.vocab), 1.0,
                                         cfg.dtype),
    }


def _embed_tokens(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def _prefix_embeds(params: Params, batch: Dict[str, jax.Array],
                   cfg: LMConfig) -> jax.Array:
    """token embeddings, with optional frontend-stub prefix concatenated."""
    x = _embed_tokens(params, batch["tokens"])
    if "embeds" in batch:
        x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
    return x


def _loss_from_hidden(cfg: LMConfig, params: Params, hidden: jax.Array,
                      batch: Dict[str, jax.Array]) -> jax.Array:
    hidden = _apply_norm(cfg, params["final_norm"], hidden)
    if "embeds" in batch:  # prefix positions carry no LM loss
        hidden = hidden[:, batch["embeds"].shape[1]:]
    mask = batch.get("mask")
    return chunked_softmax_xent(hidden, params["lm_head"],
                                batch["targets"], mask,
                                chunk=cfg.loss_chunk)


def _last_logits(cfg: LMConfig, params: Params, x: jax.Array) -> jax.Array:
    x = _apply_norm(cfg, params["final_norm"], x[:, -1:])
    return (x @ params["lm_head"]).astype(jnp.float32)[:, 0]


def _group_layout(cfg: LMConfig) -> Tuple[int, int, int]:
    """(n_groups, group_size, n_rest) of the local/global layer pattern.

    group_size == 0 means "uniform window" (single scan, no grouping).
    """
    if cfg.sliding_window and cfg.global_every:
        g = cfg.global_every
        return cfg.n_layers // g, g, cfg.n_layers % g
    return 0, 0, cfg.n_layers


def _split_groups(stacked: Params, n_groups: int, g: int
                  ) -> Tuple[Params, Params, Params]:
    """Split [L, ...] stacked params into (local [ng, g-1, ...],
    global [ng, ...], rest [n_rest, ...])."""
    def take_local(a):
        return a[:n_groups * g].reshape((n_groups, g) + a.shape[1:])[:, :-1]

    def take_global(a):
        return a[:n_groups * g].reshape((n_groups, g) + a.shape[1:])[:, -1]

    local = jax.tree.map(take_local, stacked)
    glob = jax.tree.map(take_global, stacked)
    rest = jax.tree.map(lambda a: a[n_groups * g:], stacked)
    return local, glob, rest


# --- dense / moe decoder ----------------------------------------------------

def _build_decoder(cfg: LMConfig) -> Model:
    ng, g, n_rest = _group_layout(cfg)
    sw = cfg.sliding_window

    def init(key: jax.Array) -> Params:
        kh, kl = jax.random.split(key)
        layer_keys = jax.random.split(kl, cfg.n_layers)
        layers = jax.vmap(lambda k: _init_block(k, cfg))(layer_keys)
        p = _init_head(kh, cfg)
        p["layers"] = layers
        return p

    def _stack_apply(x: jax.Array, stacked: Params, window: int,
                     positions=None) -> jax.Array:
        def body(x, lp):
            return _apply_block(cfg, lp, x, window, positions), None
        x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, stacked)
        return x

    def hidden_fn(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = _prefix_embeds(params, batch, cfg)
        if ng == 0:  # uniform window
            return _stack_apply(x, params["layers"], sw)
        local, glob, rest = _split_groups(params["layers"], ng, g)

        def group_body(x, gp):
            lp, gp_glob = gp
            x = _stack_apply(x, lp, sw)
            x = _maybe_remat(cfg, lambda x, p: _apply_block(
                cfg, p, x, 0))(x, gp_glob)
            return x, None

        x, _ = jax.lax.scan(group_body, x, (local, glob))
        if n_rest:
            x = _stack_apply(x, rest, sw)
        return x

    def loss_fn(params, batch):
        return _loss_from_hidden(cfg, params, hidden_fn(params, batch),
                                 batch)

    def init_cache(batch: int, max_len: int) -> Params:
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.hd), cfg.dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.hd), cfg.dtype),
        }

    def _stack_prefill(x, stacked, max_len, window):
        def body(x, lp):
            return _prefill_block(cfg, lp, x, max_len, window)
        return jax.lax.scan(_maybe_remat(cfg, body), x, stacked)

    def prefill(params: Params, batch: Dict[str, jax.Array], max_len: int
                ) -> Tuple[jax.Array, Params]:
        """Run the full prompt, return (last-position logits, filled cache)."""
        x = _prefix_embeds(params, batch, cfg)
        if ng == 0:
            x, cache = _stack_prefill(x, params["layers"], max_len, sw)
            return _last_logits(cfg, params, x), cache
        local, glob, rest = _split_groups(params["layers"], ng, g)

        def group_body(x, gp):
            lp, gp_glob = gp
            x, c_local = _stack_prefill(x, lp, max_len, sw)
            x, c_glob = _prefill_block(cfg, gp_glob, x, max_len, 0)
            return x, (c_local, c_glob)

        x, (c_local, c_glob) = jax.lax.scan(group_body, x, (local, glob))
        caches = [(c_local, c_glob)]
        if n_rest:
            x, c_rest = _stack_prefill(x, rest, max_len, sw)
            caches.append(c_rest)
        cache = _merge_group_caches(caches, ng, g, n_rest)
        return _last_logits(cfg, params, x), cache

    def _merge_group_caches(caches, ng, g, n_rest):
        (c_local, c_glob) = caches[0]
        def merge(loc, glo):
            # loc: [ng, g-1, B, ...]; glo: [ng, B, ...] -> [ng*g, B, ...]
            return jnp.concatenate([loc, glo[:, None]], axis=1).reshape(
                (ng * g,) + loc.shape[2:])
        full = jax.tree.map(merge, c_local, c_glob)
        if n_rest:
            full = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                full, caches[1])
        return full

    def decode_step(params: Params, tok: jax.Array, cache: Params,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        x = _embed_tokens(params, tok)          # [B, 1, D]
        if ng == 0:
            def body(x, xs):
                lp, lc = xs
                return _decode_block(cfg, lp, x, lc, pos, sw)
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
            return _last_logits(cfg, params, x), new_cache

        local, glob, rest = _split_groups(params["layers"], ng, g)
        cl, cg, cr = _split_groups(cache, ng, g)

        def group_body(x, xs):
            lp, gp_glob, lc, gc = xs

            def body(x, ys):
                p, c = ys
                return _decode_block(cfg, p, x, c, pos, sw)
            x, nc_local = jax.lax.scan(body, x, (lp, lc))
            x, nc_glob = _decode_block(cfg, gp_glob, x, gc, pos, 0)
            return x, (nc_local, nc_glob)

        x, (ncl, ncg) = jax.lax.scan(group_body, x, (local, glob, cl, cg))
        caches = [(ncl, ncg)]
        if n_rest:
            def body(x, ys):
                p, c = ys
                return _decode_block(cfg, p, x, c, pos, sw)
            x, ncr = jax.lax.scan(body, x, (rest, cr))
            caches.append(ncr)
        new_cache = _merge_group_caches(caches, ng, g, n_rest)
        return _last_logits(cfg, params, x), new_cache

    return Model(cfg, init, hidden_fn, loss_fn, prefill, decode_step,
                 init_cache)


# --- zamba: mamba2 backbone + shared attention block ------------------------

def _build_zamba(cfg: LMConfig) -> Model:
    assert cfg.ssm is not None and cfg.shared_attn_every > 0
    g = cfg.shared_attn_every
    ng = cfg.n_layers // g                      # groups ending in shared blk
    n_rest = cfg.n_layers - ng * g

    def init(key: jax.Array) -> Params:
        kh, km, ks = jax.random.split(key, 3)
        layer_keys = jax.random.split(km, cfg.n_layers)

        def init_layer(k):
            return {"pre": _init_norm(cfg),
                    "m": ssm_mod.init_mamba2(k, cfg.d_model, cfg.ssm,
                                             cfg.dtype)}
        p = _init_head(kh, cfg)
        p["mamba"] = jax.vmap(init_layer)(layer_keys)
        p["shared"] = _init_block(ks, cfg)
        return p

    def _grouped(stacked):
        first = jax.tree.map(
            lambda a: a[:ng * g].reshape((ng, g) + a.shape[1:]), stacked)
        rest = jax.tree.map(lambda a: a[ng * g:], stacked)
        return first, rest

    def _mamba_body(x, lp):
        x = _resid_hint(cfg, x)
        h = _apply_norm(cfg, lp["pre"], x)
        return x + ssm_mod.apply_mamba2(lp["m"], h, cfg.ssm,
                                        use_kernel=cfg.use_gla_kernel), None

    def _mamba_stack(x, stacked):
        x, _ = jax.lax.scan(_maybe_remat(cfg, _mamba_body), x, stacked)
        return x

    def hidden_fn(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = _embed_tokens(params, batch["tokens"])
        first, rest = _grouped(params["mamba"])
        shared = params["shared"]

        def group_body(x, gp):
            x = _mamba_stack(x, gp)
            x = _maybe_remat(cfg, lambda x, p: _apply_block(
                cfg, p, x, cfg.sliding_window))(x, shared)
            return x, None

        x, _ = jax.lax.scan(group_body, x, first)
        if n_rest:
            x = _mamba_stack(x, rest)
        return x

    def loss_fn(params, batch):
        return _loss_from_hidden(cfg, params, hidden_fn(params, batch),
                                 batch)

    def init_cache(batch: int, max_len: int) -> Params:
        m = ssm_mod.init_mamba2_cache(batch, cfg.d_model, cfg.ssm, cfg.dtype)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), m),
            "attn": {
                "k": jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, cfg.hd),
                               cfg.dtype),
                "v": jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, cfg.hd),
                               cfg.dtype),
            },
        }

    def prefill(params: Params, batch: Dict[str, jax.Array], max_len: int
                ) -> Tuple[jax.Array, Params]:
        x = _embed_tokens(params, batch["tokens"])
        first, rest = _grouped(params["mamba"])
        shared = params["shared"]

        def m_body(x, lp):
            h = _apply_norm(cfg, lp["pre"], x)
            y, c = ssm_mod.prefill_mamba2(lp["m"], h, cfg.ssm,
                                          use_kernel=cfg.use_gla_kernel)
            return x + y, c

        def group_body(x, gp):
            x, mc = jax.lax.scan(_maybe_remat(cfg, m_body), x, gp)
            x, ac = _prefill_block(cfg, shared, x, max_len,
                                   cfg.sliding_window)
            return x, (mc, ac)

        x, (mc_first, ac) = jax.lax.scan(group_body, x, first)
        # mc_first: [ng, g, ...] -> flatten to [ng*g, ...]
        mcache = jax.tree.map(
            lambda a: a.reshape((ng * g,) + a.shape[2:]), mc_first)
        if n_rest:
            x, mc_rest = jax.lax.scan(_maybe_remat(cfg, m_body), x, rest)
            mcache = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), mcache, mc_rest)
        return (_last_logits(cfg, params, x),
                {"mamba": mcache, "attn": ac})

    def decode_step(params: Params, tok: jax.Array, cache: Params,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        x = _embed_tokens(params, tok)
        first, rest = _grouped(params["mamba"])
        mc_first, mc_rest = _grouped(cache["mamba"])
        shared = params["shared"]

        def m_body(x, xs):
            lp, lc = xs
            h = _apply_norm(cfg, lp["pre"], x)
            y, nc = ssm_mod.decode_mamba2(lp["m"], h, lc, cfg.ssm)
            return x + y, nc

        def group_body(x, xs):
            gp, mc, ac = xs
            x, nmc = jax.lax.scan(m_body, x, (gp, mc))
            x, nac = _decode_block(cfg, shared, x, ac, pos,
                                   cfg.sliding_window)
            return x, (nmc, nac)

        x, (nmc_first, nac) = jax.lax.scan(
            group_body, x, (first, mc_first, cache["attn"]))
        mcache = jax.tree.map(
            lambda a: a.reshape((ng * g,) + a.shape[2:]), nmc_first)
        if n_rest:
            x, nmc_rest = jax.lax.scan(m_body, x, (rest, mc_rest))
            mcache = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), mcache, nmc_rest)
        return (_last_logits(cfg, params, x),
                {"mamba": mcache, "attn": nac})

    return Model(cfg, init, hidden_fn, loss_fn, prefill, decode_step,
                 init_cache)


# --- xlstm -------------------------------------------------------------------

def _build_xlstm(cfg: LMConfig) -> Model:
    assert cfg.xlstm is not None
    xc = cfg.xlstm
    g = xc.slstm_every
    if g > 0:
        assert cfg.n_layers % g == 0, "n_layers must divide slstm_every"
        ng = cfg.n_layers // g      # groups of (g-1) mLSTM + 1 sLSTM
        n_m_per_group = g - 1
    else:
        ng, n_m_per_group = 0, 0

    def init(key: jax.Array) -> Params:
        kh, km, ks = jax.random.split(key, 3)

        def init_m(k):
            return {"pre": _init_norm(cfg),
                    "m": xlstm_mod.init_mlstm(k, cfg.d_model, xc, cfg.dtype)}

        def init_s(k):
            return {"pre": _init_norm(cfg),
                    "s": xlstm_mod.init_slstm(k, cfg.d_model, xc, cfg.dtype)}

        p = _init_head(kh, cfg)
        if ng:
            mkeys = jax.random.split(km, ng * n_m_per_group)
            p["mlstm"] = jax.tree.map(
                lambda a: a.reshape((ng, n_m_per_group) + a.shape[1:]),
                jax.vmap(init_m)(mkeys))
            p["slstm"] = jax.vmap(init_s)(jax.random.split(ks, ng))
        else:
            p["mlstm"] = jax.vmap(init_m)(
                jax.random.split(km, cfg.n_layers))
        return p

    def _m_body(x, lp):
        x = _resid_hint(cfg, x)
        h = _apply_norm(cfg, lp["pre"], x)
        return x + xlstm_mod.apply_mlstm(lp["m"], h, xc,
                                         use_kernel=cfg.use_gla_kernel), None

    def _s_apply(x, lp):
        x = _resid_hint(cfg, x)
        h = _apply_norm(cfg, lp["pre"], x)
        return x + xlstm_mod.apply_slstm(lp["s"], h, xc)

    def hidden_fn(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = _embed_tokens(params, batch["tokens"])
        if not ng:
            x, _ = jax.lax.scan(_maybe_remat(cfg, _m_body), x,
                                params["mlstm"])
            return x

        def group_body(x, gp):
            mp, sp = gp
            x, _ = jax.lax.scan(_maybe_remat(cfg, _m_body), x, mp)
            x = _maybe_remat(cfg, _s_apply)(x, sp)
            return x, None

        x, _ = jax.lax.scan(group_body, x, (params["mlstm"],
                                            params["slstm"]))
        return x

    def loss_fn(params, batch):
        return _loss_from_hidden(cfg, params, hidden_fn(params, batch),
                                 batch)

    def init_cache(batch: int, max_len: int = 0) -> Params:
        mc = xlstm_mod.init_mlstm_cache(batch, cfg.d_model, xc, cfg.dtype)
        n_m = ng * n_m_per_group if ng else cfg.n_layers
        cache = {"mlstm": jax.tree.map(
            lambda a: jnp.zeros((n_m,) + a.shape, a.dtype), mc)}
        if ng:
            sc = xlstm_mod.init_slstm_cache(batch, cfg.d_model, xc)
            cache["slstm"] = jax.tree.map(
                lambda a: jnp.zeros((ng,) + a.shape, a.dtype), sc)
        return cache

    def _regroup(tree):     # [ng*m, ...] <- [ng, m, ...]
        return jax.tree.map(
            lambda a: a.reshape((ng * n_m_per_group,) + a.shape[2:]), tree)

    def prefill(params: Params, batch: Dict[str, jax.Array], max_len: int
                ) -> Tuple[jax.Array, Params]:
        x = _embed_tokens(params, batch["tokens"])

        def m_body(x, lp):
            h = _apply_norm(cfg, lp["pre"], x)
            y, c = xlstm_mod.prefill_mlstm(lp["m"], h, xc,
                                           use_kernel=cfg.use_gla_kernel)
            return x + y, c

        if not ng:
            x, mc = jax.lax.scan(_maybe_remat(cfg, m_body), x,
                                 params["mlstm"])
            return _last_logits(cfg, params, x), {"mlstm": mc}

        def group_body(x, gp):
            mp, sp = gp
            x, mc = jax.lax.scan(_maybe_remat(cfg, m_body), x, mp)
            h = _apply_norm(cfg, sp["pre"], x)
            y, sc = xlstm_mod.prefill_slstm(sp["s"], h, xc)
            return x + y, (mc, sc)

        x, (mc, sc) = jax.lax.scan(group_body, x,
                                   (params["mlstm"], params["slstm"]))
        return (_last_logits(cfg, params, x),
                {"mlstm": _regroup(mc), "slstm": sc})

    def decode_step(params: Params, tok: jax.Array, cache: Params,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        x = _embed_tokens(params, tok)

        def m_body(x, xs):
            lp, lc = xs
            h = _apply_norm(cfg, lp["pre"], x)
            y, nc = xlstm_mod.decode_mlstm(lp["m"], h, lc, xc)
            return x + y, nc

        if not ng:
            x, nmc = jax.lax.scan(m_body, x,
                                  (params["mlstm"], cache["mlstm"]))
            return _last_logits(cfg, params, x), {"mlstm": nmc}

        mc_g = jax.tree.map(
            lambda a: a.reshape((ng, n_m_per_group) + a.shape[1:]),
            cache["mlstm"])

        def group_body(x, xs):
            mp, sp, mc, sc = xs
            x, nmc = jax.lax.scan(m_body, x, (mp, mc))
            h = _apply_norm(cfg, sp["pre"], x)
            y, nsc = xlstm_mod.decode_slstm(sp["s"], h, sc, xc)
            return x + y, (nmc, nsc)

        x, (nmc, nsc) = jax.lax.scan(
            group_body, x, (params["mlstm"], params["slstm"], mc_g,
                            cache["slstm"]))
        return (_last_logits(cfg, params, x),
                {"mlstm": _regroup(nmc), "slstm": nsc})

    return Model(cfg, init, hidden_fn, loss_fn, prefill, decode_step,
                 init_cache)


# --- encdec (whisper) --------------------------------------------------------

def _build_encdec(cfg: LMConfig) -> Model:
    assert cfg.encoder_layers > 0

    def _init_dec_block(key) -> Params:
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": _init_norm(cfg),
            "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd, cfg.dtype),
            "lnx": _init_norm(cfg),
            "xattn": attn.init_attention(k2, cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.hd, cfg.dtype),
            "ln2": _init_norm(cfg),
            "mlp": _init_mlp(k3, cfg),
        }

    def init(key: jax.Array) -> Params:
        kh, ke, kd = jax.random.split(key, 3)
        p = _init_head(kh, cfg)
        p["enc_layers"] = jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(ke, cfg.encoder_layers))
        p["enc_norm"] = _init_norm(cfg)
        p["dec_layers"] = jax.vmap(_init_dec_block)(
            jax.random.split(kd, cfg.n_layers))
        return p

    def encode(params: Params, frames: jax.Array) -> jax.Array:
        """frames: [B, T_enc, D] precomputed embeddings (conv-frontend stub)."""
        T = frames.shape[1]
        x = frames.astype(cfg.dtype) + sinusoidal_positions(
            T, cfg.d_model).astype(cfg.dtype)[None]

        def body(x, lp):
            return _apply_block(cfg, lp, x, 0, causal=False), None

        x, _ = jax.lax.scan(_maybe_remat(cfg, body), x,
                            params["enc_layers"])
        return _apply_norm(cfg, params["enc_norm"], x)

    def _dec_block(p: Params, x: jax.Array, enc_out: jax.Array) -> jax.Array:
        x = _resid_hint(cfg, x)
        h = _apply_norm(cfg, p["ln1"], x)
        h = attn.self_attention(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, causal=True, rope_theta=cfg.rope_theta,
            use_flash=cfg.use_flash, block_q=cfg.attn_block_q)
        x = x + h
        h = _apply_norm(cfg, p["lnx"], x)
        h = attn.cross_attention(p["xattn"], h, enc_out,
                                 n_heads=cfg.n_heads,
                                 n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                                 block_q=cfg.attn_block_q)
        x = x + h
        h = _apply_norm(cfg, p["ln2"], x)
        return x + _apply_mlp(cfg, p["mlp"], h)

    def hidden_fn(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        enc_out = encode(params, batch["frames"])
        x = _embed_tokens(params, batch["tokens"])
        T = x.shape[1]
        x = x + sinusoidal_positions(T, cfg.d_model).astype(x.dtype)[None]

        def body(x, lp):
            return _dec_block(lp, x, enc_out), None

        x, _ = jax.lax.scan(_maybe_remat(cfg, body), x,
                            params["dec_layers"])
        return x

    def loss_fn(params, batch):
        return _loss_from_hidden(cfg, params, hidden_fn(params, batch),
                                 batch)

    def init_cache(batch: int, max_len: int, enc_len: int = 0) -> Params:
        c = {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.hd), cfg.dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.hd), cfg.dtype),
        }
        if enc_len:
            c["xk"] = jnp.zeros((cfg.n_layers, batch, enc_len,
                                 cfg.n_kv_heads, cfg.hd), cfg.dtype)
            c["xv"] = jnp.zeros_like(c["xk"])
        return c

    def prefill(params: Params, batch: Dict[str, jax.Array], max_len: int
                ) -> Tuple[jax.Array, Params]:
        enc_out = encode(params, batch["frames"])
        x = _embed_tokens(params, batch["tokens"])
        B, T = x.shape[:2]
        x = x + sinusoidal_positions(T, cfg.d_model).astype(x.dtype)[None]

        def body(x, lp):
            h = _apply_norm(cfg, lp["ln1"], x)
            q, k, v = attn._project_qkv(lp["attn"], h, h, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd)
            if cfg.rope_theta > 0:
                pos = jnp.arange(T)
                q = attn.apply_rope(q, pos, cfg.rope_theta)
                k = attn.apply_rope(k, pos, cfg.rope_theta)
            o = attn.mha(q, k, v, causal=True, block_q=cfg.attn_block_q)
            x = x + o.reshape(B, T, -1) @ lp["attn"]["wo"]
            h = _apply_norm(cfg, lp["lnx"], x)
            h = attn.cross_attention(lp["xattn"], h, enc_out,
                                     n_heads=cfg.n_heads,
                                     n_kv_heads=cfg.n_kv_heads,
                                     head_dim=cfg.hd,
                                     block_q=cfg.attn_block_q)
            x = x + h
            h = _apply_norm(cfg, lp["ln2"], x)
            x = x + _apply_mlp(cfg, lp["mlp"], h)
            # cross-attention K/V are static per request: cache them.
            _, xk, xv = attn._project_qkv(lp["xattn"], h, enc_out,
                                          cfg.n_heads, cfg.n_kv_heads,
                                          cfg.hd)
            pad = jnp.zeros((B, max_len - T) + k.shape[2:], cfg.dtype)
            return x, {"k": jnp.concatenate([k.astype(cfg.dtype), pad], 1),
                       "v": jnp.concatenate([v.astype(cfg.dtype), pad], 1),
                       "xk": xk.astype(cfg.dtype),
                       "xv": xv.astype(cfg.dtype)}

        x, cache = jax.lax.scan(_maybe_remat(cfg, body), x,
                                params["dec_layers"])
        return _last_logits(cfg, params, x), cache

    def decode_step(params: Params, tok: jax.Array, cache: Params,
                    pos: jax.Array) -> Tuple[jax.Array, Params]:
        x = _embed_tokens(params, tok)          # [B, 1, D]
        B = x.shape[0]
        x = x + sinusoidal_position_at(pos, cfg.d_model).astype(x.dtype)[
            None, None]

        def body(x, xs):
            lp, lc = xs
            h = _apply_norm(cfg, lp["ln1"], x)
            h, ck, cv = attn.decode_self_attention(
                lp["attn"], h, lc["k"], lc["v"], pos, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta)
            x = x + h
            h = _apply_norm(cfg, lp["lnx"], x)
            q = (h @ lp["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
            o = attn.mha(q, lc["xk"], lc["xv"], causal=False)
            x = x + o.reshape(B, 1, -1) @ lp["xattn"]["wo"]
            h = _apply_norm(cfg, lp["ln2"], x)
            x = x + _apply_mlp(cfg, lp["mlp"], h)
            return x, {"k": ck, "v": cv, "xk": lc["xk"], "xv": lc["xv"]}

        x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
        return _last_logits(cfg, params, x), new_cache

    return Model(cfg, init, hidden_fn, loss_fn, prefill, decode_step,
                 init_cache)


# ---------------------------------------------------------------------------
# Parameter accounting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def param_count(params: Params) -> int:
    return sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))


def active_param_count(cfg: LMConfig, params: Params) -> int:
    """Parameters touched per token (MoE: top_k of n_experts)."""
    total = param_count(params)
    if cfg.family != "moe" or cfg.moe is None:
        return total
    expert_leaves = 0
    layers = params["layers"]
    for name in ("w_gate", "w_up", "w_down"):
        expert_leaves += int(np.prod(layers["moe"][name].shape))
    active = expert_leaves * cfg.moe.top_k / cfg.moe.n_experts
    return int(total - expert_leaves + active)
