"""Mixture-of-Experts layer: top-k routing, grouped dense dispatch.

TPU-native formulation: tokens are processed in groups; dispatch/combine are
one-hot einsums (Switch/Mesh-TF style), so under pjit with the expert dim
sharded the compiler emits all-to-all style collectives instead of gathers.
Capacity-dropping semantics with renormalized top-k gates; optional shared
experts (Qwen-MoE) are a plain SwiGLU applied to every token.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.lm.common import (Params, apply_swiglu, init_swiglu,
                                    truncated_normal_init)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared experts (always-on)
    d_ff_shared: int = 0         # total shared ff width
    capacity_factor: float = 1.25
    group_size: int = 1024       # tokens per dispatch group


def init_moe(key: jax.Array, d_model: int, cfg: MoEConfig, dtype) -> Params:
    kg, ke1, ke2, ke3, ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff_expert
    p: Params = {
        "router": truncated_normal_init(kg, (d_model, E), 1.0, jnp.float32),
        "w_gate": truncated_normal_init(ke1, (E, d_model, F), 1.0, dtype),
        "w_up": truncated_normal_init(ke2, (E, d_model, F), 1.0, dtype),
        "w_down": truncated_normal_init(ke3, (E, F, d_model), 1.0, dtype),
    }
    if cfg.n_shared > 0:
        width = cfg.d_ff_shared or cfg.n_shared * F
        p["shared"] = init_swiglu(ks, d_model, width, dtype)
    return p


def apply_moe(p: Params, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """x: [B, T, D] -> [B, T, D]."""
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    G = min(cfg.group_size, B * T)
    tokens = x.reshape(-1, D)
    n_tok = tokens.shape[0]
    assert n_tok % G == 0, f"tokens {n_tok} % group {G} != 0"
    ng = n_tok // G
    xg = tokens.reshape(ng, G, D)

    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)               # [ng, G, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # [ng, G, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    capacity = max(int(G * K * cfg.capacity_factor / E), 1)
    # one-hot over experts for each of the K choices: [ng, G, K, E]
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    # position of each (token, choice) within its expert's buffer
    flat = onehot.reshape(ng, G * K, E)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0            # [ng, G*K, E]
    pos = pos.reshape(ng, G, K, E)
    keep = (pos >= 0) & (pos < capacity)
    pos = jnp.clip(pos, 0, capacity - 1).astype(jnp.int32)
    pos_onehot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32) * \
        keep[..., None].astype(jnp.float32)
    # dispatch tensor [ng, G, E, C]
    dispatch = jnp.einsum("gske,gskec->gsec", onehot, pos_onehot)
    combine = jnp.einsum("gsk,gske,gskec->gsec", gate_vals, onehot,
                         pos_onehot)

    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch.astype(xg.dtype), xg)
    h_gate = jax.nn.silu(jnp.einsum(
        "gecd,edf->gecf", expert_in, p["w_gate"]).astype(jnp.float32)
    ).astype(xg.dtype)
    h_up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    h = jnp.einsum("gecf,efd->gecd", h_gate * h_up, p["w_down"])
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(xg.dtype), h)

    if "shared" in p:
        out = out + apply_swiglu(p["shared"], xg)
    return out.reshape(B, T, D)


def router_aux_loss(p: Params, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean over groups)."""
    B, T, D = x.shape
    logits = (x.reshape(-1, D) @ p["router"].astype(x.dtype)
              ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.top_k)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], cfg.n_experts, dtype=jnp.float32),
        axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
