"""Mamba2 (SSD) block on top of the chunked GLA primitive.

Structure per block (pre-norm residual):
  in_proj -> [z | xBC | dt];  depthwise causal conv4 + silu on xBC;
  SSD recurrence (q=C, k=dt*B, v=x heads, decay=exp(-exp(A_log)*dt));
  skip D*x; gate y*silu(z); RMSNorm; out_proj.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.common import (Params, rms_norm,
                                    truncated_normal_init)
from repro.models.lm.gla import chunked_gla, gla_decode_step


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128


def d_inner(d_model: int, cfg: SSMConfig) -> int:
    return cfg.expand * d_model


def n_ssm_heads(d_model: int, cfg: SSMConfig) -> int:
    return d_inner(d_model, cfg) // cfg.head_dim


def init_mamba2(key: jax.Array, d_model: int, cfg: SSMConfig, dtype
                ) -> Params:
    di = d_inner(d_model, cfg)
    nh = n_ssm_heads(d_model, cfg)
    conv_ch = di + 2 * cfg.d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": truncated_normal_init(
            k1, (d_model, 2 * di + 2 * cfg.d_state + nh), 1.0, dtype),
        "conv_w": truncated_normal_init(k2, (cfg.d_conv, conv_ch), 1.0,
                                        dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(A_log) = -1
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.zeros((di,), dtype),
        "out_proj": truncated_normal_init(k4, (di, d_model), 1.0, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along T.  x: [B,T,C]; w: [K,C]; prev: [B,K-1,C]
    carried state.  Returns (y [B,T,C], new_state [B,K-1,C])."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)           # [B, T+K-1, C]
    # depthwise conv as a sum of shifted scalings (K is tiny, e.g. 4)
    T = x.shape[1]
    y = sum(xp[:, i:i + T, :] * w[i][None, None, :] for i in range(K))
    return y + b, xp[:, -(K - 1):, :] if K > 1 else \
        jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)


def _mamba2_forward(p: Params, x: jax.Array, cfg: SSMConfig,
                    conv_prev: Optional[jax.Array] = None,
                    use_kernel: bool = False
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared fwd path.  Returns (y, new_conv_state, final_S)."""
    B, T, D = x.shape
    di = d_inner(D, cfg)
    nh = di // cfg.head_dim
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * cfg.d_state]
    dt_pre = zxbcdt[..., -nh:].astype(jnp.float32)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                 prev=conv_prev)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xin = xBC[..., :di]
    Bmat = xBC[..., di:di + cfg.d_state]
    Cmat = xBC[..., di + cfg.d_state:]
    dt = jax.nn.softplus(dt_pre + p["dt_bias"])               # [B,T,nh]
    log_decay = -jnp.exp(p["A_log"])[None, None, :] * dt      # [B,T,nh]

    v = xin.reshape(B, T, nh, cfg.head_dim)
    k = (Bmat[:, :, None, :] * dt[..., None]).astype(x.dtype)
    k = jnp.broadcast_to(k, (B, T, nh, cfg.d_state))
    q = jnp.broadcast_to(Cmat[:, :, None, :].astype(x.dtype),
                         (B, T, nh, cfg.d_state))
    y, (S_fin, _) = chunked_gla(q, k, v, log_decay, chunk=cfg.chunk,
                                use_kernel=use_kernel)
    y = y + v * p["D_skip"][None, None, :, None].astype(v.dtype)
    y = y.reshape(B, T, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["norm_w"])
    return y @ p["out_proj"], new_conv, S_fin


def apply_mamba2(p: Params, x: jax.Array, cfg: SSMConfig,
                 use_kernel: bool = False) -> jax.Array:
    """x: [B, T, D] -> [B, T, D] (training path)."""
    y, _, _ = _mamba2_forward(p, x, cfg, use_kernel=use_kernel)
    return y


def prefill_mamba2(p: Params, x: jax.Array, cfg: SSMConfig,
                   use_kernel: bool = False) -> Tuple[jax.Array, Params]:
    """Prefill path: also return the recurrent cache for decode."""
    y, conv, S = _mamba2_forward(p, x, cfg, use_kernel=use_kernel)
    return y, {"conv": conv, "S": S}


def init_mamba2_cache(batch: int, d_model: int, cfg: SSMConfig, dtype
                      ) -> Params:
    di = d_inner(d_model, cfg)
    nh = di // cfg.head_dim
    conv_ch = di + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_ch), dtype),
        "S": jnp.zeros((batch, nh, cfg.d_state, cfg.head_dim),
                       jnp.float32),
    }


def decode_mamba2(p: Params, x: jax.Array, cache: Params, cfg: SSMConfig
                  ) -> Tuple[jax.Array, Params]:
    """x: [B, 1, D] single-token step with recurrent state."""
    B, _, D = x.shape
    di = d_inner(D, cfg)
    nh = di // cfg.head_dim
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * cfg.d_state]
    dt_pre = zxbcdt[..., -nh:].astype(jnp.float32)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                 prev=cache["conv"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xin = xBC[..., :di]
    Bmat = xBC[..., di:di + cfg.d_state]
    Cmat = xBC[..., di + cfg.d_state:]
    dt = jax.nn.softplus(dt_pre + p["dt_bias"])[:, 0]          # [B,nh]
    log_decay = -jnp.exp(p["A_log"])[None, :] * dt

    v = xin.reshape(B, nh, cfg.head_dim)
    k = (Bmat[:, 0, None, :] * dt[..., None]).astype(x.dtype)
    k = jnp.broadcast_to(k, (B, nh, cfg.d_state))
    q = jnp.broadcast_to(Cmat[:, 0, None, :].astype(x.dtype),
                         (B, nh, cfg.d_state))
    n_dummy = jnp.zeros((B, nh, cfg.d_state), jnp.float32)
    y, (S_new, _) = gla_decode_step(q, k, v, log_decay,
                                    (cache["S"], n_dummy))
    y = y + v * p["D_skip"][None, :, None].astype(v.dtype)
    y = y.reshape(B, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["norm_w"])
    return y @ p["out_proj"], {"conv": new_conv, "S": S_new}
