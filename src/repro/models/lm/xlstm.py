"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel via GLA) and sLSTM
(scalar memory, strictly recurrent over time).

Simplifications vs arXiv:2405.04517, recorded in DESIGN.md: the mLSTM input
gate is clamped to [-8, 8] instead of carrying the running max-stabilizer
``m_t`` (the GLA normalizer bounds the output); the sLSTM keeps the standard
log-space stabilizer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.common import (Params, rms_norm,
                                    truncated_normal_init)
from repro.models.lm.gla import chunked_gla, gla_decode_step
from repro.models.lm.ssm import _causal_conv


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    n_heads: int = 4
    expand: int = 2          # mLSTM inner expansion
    d_conv: int = 4
    slstm_every: int = 6     # every k-th block is an sLSTM (0 = never)
    chunk: int = 128


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key: jax.Array, d_model: int, cfg: XLSTMConfig, dtype
               ) -> Params:
    di = cfg.expand * d_model
    hd = di // cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "up_proj": truncated_normal_init(ks[0], (d_model, 2 * di), 1.0,
                                         dtype),
        "conv_w": truncated_normal_init(ks[1], (cfg.d_conv, di), 1.0, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": truncated_normal_init(ks[2], (di, di), 1.0, dtype),
        "wk": truncated_normal_init(ks[3], (di, di), 1.0, dtype),
        "wv": truncated_normal_init(ks[4], (di, di), 1.0, dtype),
        "w_gates": truncated_normal_init(ks[5], (di, 2 * cfg.n_heads), 1.0,
                                         jnp.float32),
        "b_igate": jnp.zeros((cfg.n_heads,), jnp.float32),
        "b_fgate": jnp.full((cfg.n_heads,), 3.0, jnp.float32),
        "norm_w": jnp.zeros((di,), dtype),
        "down_proj": truncated_normal_init(ks[6], (di, d_model), 1.0,
                                           dtype),
    }


def _mlstm_qkv_gates(p: Params, x: jax.Array, cfg: XLSTMConfig,
                     conv_state: Optional[jax.Array] = None):
    B, T, D = x.shape
    di = cfg.expand * D
    hd = di // cfg.n_heads
    up = x @ p["up_proj"]
    xin, z = up[..., :di], up[..., di:]
    cx, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"],
                                prev=conv_state)
    cx = jax.nn.silu(cx.astype(jnp.float32)).astype(x.dtype)
    q = (cx @ p["wq"]).reshape(B, T, cfg.n_heads, hd)
    k = (cx @ p["wk"]).reshape(B, T, cfg.n_heads, hd) / jnp.sqrt(hd).astype(
        x.dtype)
    v = (xin @ p["wv"]).reshape(B, T, cfg.n_heads, hd)
    gates = (xin.astype(jnp.float32) @ p["w_gates"])      # [B,T,2H]
    ig = jnp.clip(gates[..., :cfg.n_heads] + p["b_igate"], -8.0, 8.0)
    fg = gates[..., cfg.n_heads:] + p["b_fgate"]
    log_decay = jax.nn.log_sigmoid(fg)
    k = k * jnp.exp(ig).astype(k.dtype)[..., None]
    return q, k, v, log_decay, z, new_conv


def apply_mlstm(p: Params, x: jax.Array, cfg: XLSTMConfig,
                use_kernel: bool = False) -> jax.Array:
    B, T, D = x.shape
    di = cfg.expand * D
    q, k, v, log_decay, z, _ = _mlstm_qkv_gates(p, x, cfg)
    y, _ = chunked_gla(q, k, v, log_decay, chunk=cfg.chunk, normalize=True,
                       use_kernel=use_kernel)
    y = y.reshape(B, T, di)
    y = rms_norm(y, p["norm_w"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return y @ p["down_proj"]


def prefill_mlstm(p: Params, x: jax.Array, cfg: XLSTMConfig,
                  use_kernel: bool = False) -> Tuple[jax.Array, Params]:
    """Prefill: also return the recurrent cache for decode."""
    B, T, D = x.shape
    di = cfg.expand * D
    q, k, v, log_decay, z, new_conv = _mlstm_qkv_gates(p, x, cfg)
    y, (S, n) = chunked_gla(q, k, v, log_decay, chunk=cfg.chunk,
                            normalize=True, use_kernel=use_kernel)
    y = y.reshape(B, T, di)
    y = rms_norm(y, p["norm_w"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return y @ p["down_proj"], {"conv": new_conv, "S": S, "n": n}


def init_mlstm_cache(batch: int, d_model: int, cfg: XLSTMConfig, dtype
                     ) -> Params:
    di = cfg.expand * d_model
    hd = di // cfg.n_heads
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        "S": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
    }


def decode_mlstm(p: Params, x: jax.Array, cache: Params, cfg: XLSTMConfig
                 ) -> Tuple[jax.Array, Params]:
    B, _, D = x.shape
    di = cfg.expand * D
    q, k, v, log_decay, z, new_conv = _mlstm_qkv_gates(
        p, x, cfg, conv_state=cache["conv"])
    y, (S, n) = gla_decode_step(q[:, 0], k[:, 0], v[:, 0], log_decay[:, 0],
                                (cache["S"], cache["n"]), normalize=True)
    y = y.reshape(B, 1, di)
    y = rms_norm(y, p["norm_w"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return y @ p["down_proj"], {"conv": new_conv, "S": S, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key: jax.Array, d_model: int, cfg: XLSTMConfig, dtype
               ) -> Params:
    hd = d_model // cfg.n_heads
    ks = jax.random.split(key, 3)
    return {
        "w_in": truncated_normal_init(ks[0], (d_model, 4 * d_model), 1.0,
                                      jnp.float32),
        "r": truncated_normal_init(ks[1], (cfg.n_heads, hd, 4 * hd), 1.0,
                                   jnp.float32),
        "b": jnp.concatenate([
            jnp.zeros((d_model,), jnp.float32),        # i
            jnp.full((d_model,), 3.0, jnp.float32),    # f
            jnp.zeros((2 * d_model,), jnp.float32),    # z, o
        ]),
        "norm_w": jnp.zeros((d_model,), dtype),
        "out_proj": truncated_normal_init(ks[2], (d_model, d_model), 1.0,
                                          dtype),
    }


def _slstm_cell(carry, gates_x, nh: int, hd: int, r: jax.Array):
    """One time step.  carry = (c, n, h, m) each [B, nh, hd] f32."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,hdg->bhg", h, r)              # [B, nh, 4hd]
    pre = gates_x + rec
    it, ft, zt, ot = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(ft + m, it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(ft + m - m_new)
    c_new = f * c + i * jnp.tanh(zt)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_forward(p: Params, x: jax.Array, cfg: XLSTMConfig,
                   carry: Optional[Tuple] = None):
    """Strictly sequential over T (lax.scan).  Returns (y, final_carry)."""
    B, T, D = x.shape
    nh = cfg.n_heads
    hd = D // nh
    gx = (x.astype(jnp.float32) @ p["w_in"] + p["b"])    # [B,T,4D]
    # regroup gate layout from [4*D] to per-head [nh, 4*hd]
    gx = gx.reshape(B, T, 4, nh, hd).transpose(0, 1, 3, 2, 4).reshape(
        B, T, nh, 4 * hd)
    if carry is None:
        zeros = jnp.zeros((B, nh, hd), jnp.float32)
        carry = (zeros, zeros, zeros, zeros)
    carry, hs = jax.lax.scan(
        lambda carry, g: _slstm_cell(carry, g, nh, hd, p["r"]),
        carry, gx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, T, D).astype(x.dtype)
    y = rms_norm(y, p["norm_w"])
    return y @ p["out_proj"], carry


def apply_slstm(p: Params, x: jax.Array, cfg: XLSTMConfig) -> jax.Array:
    y, _ = _slstm_forward(p, x, cfg)
    return y


def prefill_slstm(p: Params, x: jax.Array, cfg: XLSTMConfig
                  ) -> Tuple[jax.Array, Params]:
    y, (c, n, h, m) = _slstm_forward(p, x, cfg)
    return y, {"c": c, "n": n, "h": h, "m": m}


def init_slstm_cache(batch: int, d_model: int, cfg: XLSTMConfig) -> Params:
    hd = d_model // cfg.n_heads
    z = jnp.zeros((batch, cfg.n_heads, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def decode_slstm(p: Params, x: jax.Array, cache: Params, cfg: XLSTMConfig
                 ) -> Tuple[jax.Array, Params]:
    B, _, D = x.shape
    nh = cfg.n_heads
    hd = D // nh
    gx = (x[:, 0].astype(jnp.float32) @ p["w_in"] + p["b"])
    gx = gx.reshape(B, 4, nh, hd).transpose(0, 2, 1, 3).reshape(
        B, nh, 4 * hd)
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h, m), h_out = _slstm_cell(carry, gx, nh, hd, p["r"])
    y = h_out.reshape(B, 1, D).astype(x.dtype)
    y = rms_norm(y, p["norm_w"])
    return y @ p["out_proj"], {"c": c, "n": n, "h": h, "m": m}
