from repro.optim.optimizers import (AdamW, Optimizer, OptState, SGDMomentum,
                                    get_optimizer, global_norm)

__all__ = ["AdamW", "Optimizer", "OptState", "SGDMomentum",
           "get_optimizer", "global_norm"]
