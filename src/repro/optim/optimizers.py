"""Sharding-preserving optimizers, built from scratch (no optax).

State is a pytree with the *same tree structure and per-leaf shapes* as
the parameters, so whatever NamedSharding the parameters carry applies
leaf-for-leaf to the optimizer state (the launch layer relies on this:
``state_shardings = jax.tree.map(lambda s: s, param_shardings)``).

* :class:`SGDMomentum` — f32 momentum, direct bf16 param update.  4
  bytes/param of state: the choice for 100B+ models (grok-1) where AdamW
  f32 state would blow the per-chip HBM budget.
* :class:`AdamW` — f32 first/second moments, decoupled weight decay,
  bias correction by step count.

Both support global-norm clipping; updates happen in f32 and are cast
back to the parameter dtype (bf16 master-less training — the f32
momentum acts as the error accumulator).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Dict[str, Any]


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def _clipped(grads: Params, clip: float) -> Tuple[Params, jax.Array]:
    gnorm = global_norm(grads)
    if clip <= 0:
        return grads, gnorm
    scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


@dataclasses.dataclass(frozen=True)
class SGDMomentum:
    lr: float = 1e-2
    momentum: float = 0.9
    clip_norm: float = 1.0
    weight_decay: float = 0.0

    def init(self, params: Params) -> OptState:
        return {
            "m": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, params: Params, grads: Params, state: OptState,
               lr_scale: jax.Array | float = 1.0
               ) -> Tuple[Params, OptState, jax.Array]:
        grads, gnorm = _clipped(grads, self.clip_norm)

        def upd(p, g, m):
            g = g.astype(jnp.float32)
            if self.weight_decay:
                g = g + self.weight_decay * p.astype(jnp.float32)
            m = self.momentum * m + g
            new_p = p.astype(jnp.float32) - self.lr * lr_scale * m
            return new_p.astype(p.dtype), m

        flat = jax.tree.map(upd, params, grads, state["m"])
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "step": state["step"] + 1}, gnorm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Params) -> OptState:
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, params: Params, grads: Params, state: OptState,
               lr_scale: jax.Array | float = 1.0
               ) -> Tuple[Params, OptState, jax.Array]:
        grads, gnorm = _clipped(grads, self.clip_norm)
        step = state["step"] + 1
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / c1
            vhat = v / c2
            pf = p.astype(jnp.float32)
            new_p = pf - self.lr * lr_scale * (
                mhat / (jnp.sqrt(vhat) + self.eps)
                + self.weight_decay * pf)
            return new_p.astype(p.dtype), m, v

        flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
        pick = lambda i: jax.tree.map(
            lambda t: t[i], flat, is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "step": step}, gnorm


Optimizer = SGDMomentum | AdamW


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgdm":
        return SGDMomentum(**kw)
    if name == "adamw":
        return AdamW(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
