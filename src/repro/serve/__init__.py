from repro.serve.engine import (GenerationResult, clear_decode_cache,
                                generate, make_decode_step,
                                make_prefill_step, sample_token)

__all__ = ["GenerationResult", "clear_decode_cache", "generate",
           "make_decode_step", "make_prefill_step", "sample_token"]
