from repro.serve.engine import (GenerationResult, generate,
                                make_decode_step, make_prefill_step,
                                sample_token)

__all__ = ["GenerationResult", "generate", "make_decode_step",
           "make_prefill_step", "sample_token"]
