"""Serving runtime: prefill + decode step builders and a batched
generation driver.

The decode step is the unit the dry-run lowers for the ``decode_32k`` /
``long_500k`` cells: one new token against a KV cache (attention archs)
or recurrent state (SSM/xLSTM), batch sharded over ``(pod, data)``, the
cache sharded per ``repro.distrib.cache_spec`` (KV heads over ``model``
when divisible, else sequence-sharded with the LSE combine emerging
from XLA's sharded-softmax handling).

Surface note (DESIGN.md §9): serving is *inference* and sits outside the
``Fleet``/``Plan`` training facade — this module is the serving front
door (``generate`` + the step builders in ``__all__``), and it consumes
``build_model(LMConfig)`` models directly rather than layer stacks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hybrid_step import _JitStepCache

__all__ = ["GenerationResult", "clear_decode_cache", "generate",
           "make_decode_step", "make_prefill_step", "sample_token"]

Tree = Any

# Compiled decode steps, one per model, in a bounded id-keyed LRU (the
# entry pins the model, making the id key sound — see _JitStepCache).
# The seed called jax.jit(make_decode_step(model)) inside generate(),
# recompiling the decode step on every generate() invocation.
_DECODE_CACHE = _JitStepCache()


def _decode_step_for(model) -> Callable:
    key = ("decode", id(model))
    fn = _DECODE_CACHE.get(key)
    if fn is None:
        fn = jax.jit(make_decode_step(model))
        _DECODE_CACHE.put(key, fn, model)
    return fn


def clear_decode_cache() -> None:
    """Drop every cached compiled decode step (releases pinned models)."""
    _DECODE_CACHE.clear()


def make_prefill_step(model, max_len: int) -> Callable:
    def prefill_step(params: Tree, batch: Dict[str, jax.Array]):
        return model.prefill(params, batch, max_len)
    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params: Tree, tok: jax.Array, cache: Tree,
                    pos: jax.Array):
        return model.decode_step(params, tok, cache, pos)
    return decode_step


def sample_token(logits: jax.Array, key: jax.Array,
                 temperature: float = 0.0) -> jax.Array:
    """logits [B, V] -> token [B, 1] (greedy when temperature == 0)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array          # [B, n_new]
    prefill_logits: jax.Array


def generate(model, params: Tree, batch: Dict[str, jax.Array], *,
             max_len: int, n_new: int, key: Optional[jax.Array] = None,
             temperature: float = 0.0) -> GenerationResult:
    """Batched prefill-then-decode driver (the serving example path)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    prompt_len = batch["tokens"].shape[1]
    if "embeds" in batch:
        prompt_len += batch["embeds"].shape[1]
    logits, cache = model.prefill(params, batch, max_len)
    decode = _decode_step_for(model)

    toks = []
    tok = sample_token(logits, key, temperature)
    for i in range(n_new):
        toks.append(tok)
        step_logits, cache = decode(params, tok,
                                    cache, jnp.int32(prompt_len + i))
        tok = sample_token(step_logits, jax.random.fold_in(key, i),
                           temperature)
    return GenerationResult(tokens=jnp.concatenate(toks, axis=1),
                            prefill_logits=logits)
