"""Planner-as-a-service: cross-fleet batched scheduling with a
fingerprinted plan cache (DESIGN.md §13).

The paper's Algorithm 1 plans one fleet at a time; the planner turns
scheduling into a service that answers *populations* — "millions of
users each bringing their own device profile" — at high throughput:

* :func:`Planner.plan_many` resolves a batch of :class:`PlanRequest`\\ s
  through a **plan cache** keyed by quantized
  ``(profile, network, B, objective, wire)`` fingerprints; misses are
  grouped into shape buckets ``(kind, n_layers, M, E)`` and solved in
  shared tableau stacks by :func:`repro.core.scheduler.solve_many`
  (bit-identical per fleet to the per-fleet engines).
* :meth:`Planner.submit` / :meth:`Planner.drain` form the admission
  loop: queued requests drain in size-bucketed batches of at most
  ``max_batch``, so padding waste inside each stacked simplex call stays
  near zero (and is logged via :class:`SolveManyStats`).

Fingerprint grid (documented contract, tested by ``tests/test_planner``):
every float entering the key — per-layer seconds, wire bytes,
bandwidths, ``sample_bytes`` — is quantized to **relative log buckets**
of width ``Q_REL = 1e-3``: ``bucket(x) = sign(x) * (1 +
rint(ln|x| / ln(1 + Q_REL)))`` with ``bucket(0) = 0``.  Two profiles
whose every entry agrees within ~0.05 % share a bucket (and may share a
plan); any entry perturbed past the grid separates the keys.  Because
``T_total`` and the period are positively-weighted max/sum compositions
of those entries, serving fleet A a plan cached from fleet B inside one
bucket mis-prices it by at most ``(1 + Q_REL)^2 - 1`` ≈ 2e-3 relative
before re-scoring — and the planner *re-scores* every cache hit on the
requester's own exact profile/network, so the returned
``t_total``/``t_period``/breakdown are always exact for the schedule
served (only the argmin, not the pricing, is shared).

Structural fields — topology kind, worker names, layer count, ``B``,
objective, wire mode, tree ``edge_of`` — enter the key exactly, so a
cache hit always carries a schedule that is *valid* for the requester
(same workers, same cut range); the quantization grid only ever blurs
profile magnitudes, never shapes.

Telemetry: ``hits`` / ``misses`` / ``evictions`` counters, ``hit_rate``,
and the solver-side :class:`SolveManyStats` (lanes, stacked calls,
padding waste) live on the planner object; the cache is a bounded LRU
like ``hybrid_step._JitStepCache``.

``python -m repro.serve.planner --bench`` runs a synthetic-population
smoke benchmark (see :mod:`repro.serve.population`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import logging
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import pipeline as pipeline_mod
from repro.core.cost_model import (HierProfile, MultiProfile, Network,
                                   StarNetwork, TreeNetwork, TreeProfile,
                                   _t_total, _t_total_multi)
from repro.core.fleet import Fleet
from repro.core.scheduler import (MultiSchedulerResult, SolveManyStats,
                                  SolveRequest, solve_many)

__all__ = ["PLAN_CACHE_SIZE", "Q_REL", "PlanRequest", "Planner",
           "clear_plan_cache", "fingerprint", "plan_many", "quantize"]

_log = logging.getLogger(__name__)

#: Relative width of one fingerprint bucket.  1e-3 keeps false sharing
#: (two distinct fleets landing in one bucket) mis-priced by at most
#: ~2e-3 relative *before* the exact per-request re-score — see the
#: module docstring and the pinned bound in tests/test_planner.py.
Q_REL = 1e-3

#: Default plan-cache capacity (schedules are tiny; this is ~a few MB).
PLAN_CACHE_SIZE = 4096

_LN_STEP = float(np.log1p(Q_REL))


def quantize(x) -> np.ndarray:
    """Map values onto the relative log-bucket grid (int64 bucket ids).

    ``bucket(x) = sign(x) * (1 + rint(ln|x| / ln(1+Q_REL)))`` and
    ``bucket(0) = 0`` — the ``+1`` keeps tiny magnitudes from colliding
    with exact zero.  Pure float64 ops with round-half-even, so the same
    bytes hash to the same key in any process on IEEE-754 hardware.
    """
    a = np.atleast_1d(np.asarray(x, np.float64))
    mag = np.zeros(a.shape, np.int64)
    nz = a != 0.0
    mag[nz] = np.rint(np.log(np.abs(a[nz])) / _LN_STEP).astype(np.int64) + 1
    return np.where(a < 0.0, -mag, mag)


def _profile_kind(profile) -> str:
    if isinstance(profile, TreeProfile):
        return "tree"
    if isinstance(profile, MultiProfile):
        return "star"
    return "triple"


def fingerprint(profile: Union[HierProfile, MultiProfile],
                net: Union[Network, StarNetwork, TreeNetwork],
                B: int, objective: str = "latency",
                wire: str = "none", *, exact: bool = False) -> str:
    """Quantized cache key of one scheduling problem (sha256 hex).

    Structural fields enter exactly; float fields enter through
    :func:`quantize`.  The profile passed here is the *wire-adjusted*
    one (``api._prepare`` output), so ``wire`` is part of both the
    structure tag and the quantized ``MO``/``MG`` columns.

    ``exact=True`` hashes the raw float64 bytes instead of the bucket
    ids — the *exact* problem identity, used to memoize deterministic
    re-scoring (two requests share an exact digest only when every
    input bit matches, so the memo can never blur anything).
    """
    h = hashlib.sha256()

    def put(tag: str, payload: bytes) -> None:
        h.update(tag.encode())
        h.update(b"\x00")
        h.update(payload)
        h.update(b"\x01")

    def put_q(tag: str, arr) -> None:
        if exact:
            put(tag, np.ascontiguousarray(
                np.asarray(arr, np.float64)).tobytes())
        else:
            put(tag, quantize(arr).tobytes())

    kind = _profile_kind(profile)
    workers = profile.worker_names if isinstance(profile, MultiProfile) \
        else ("device", "edge", "cloud")
    put("kind", kind.encode())
    put("workers", "|".join(workers).encode())
    put("layers", "|".join(profile.layer_names).encode())
    put("B", int(B).to_bytes(8, "little", signed=True))
    put("objective", objective.encode())
    put("wire", wire.encode())
    put_q("L_f", profile.L_f)
    put_q("L_b", profile.L_b)
    put_q("L_u", profile.L_u)
    put_q("MP", profile.MP)
    put_q("MO", profile.MO)
    put_q("MG", profile.MG)
    put_q("Q", profile.sample_bytes)
    if isinstance(profile, TreeProfile):
        put("n_edges", int(profile.n_edges).to_bytes(4, "little"))
        put_q("cloud_speedup", profile.cloud_speedup)
    if isinstance(net, TreeNetwork):
        put("edge_of", np.asarray(net.edge_of, np.int64).tobytes())
        put_q("bw_de", net.bw_de)
        put_q("bw_ec", net.bw_ec)
    elif isinstance(net, StarNetwork):
        put_q("bw_de", net.bw_de)
        put_q("bw_ec", net.bw_ec)
    else:
        put_q("bw_de", net.bw_de)
        put_q("bw_ec", net.bw_ec)
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """One client's planning request, as accepted by :func:`plan_many`.

    Mirrors the :func:`repro.api.plan` signature: ``fleet`` may be a
    pinned-profile fleet (``model=None``) or a spec fleet plus a model;
    ``tag`` is an opaque client label echoed nowhere but useful for
    correlating requests in logs/tests.
    """
    fleet: Fleet
    B: int
    objective: str = "latency"
    model: Any = None
    wire: Optional[str] = None
    pipeline_depth: int = 1
    tag: str = ""


@dataclasses.dataclass
class _Prepared:
    """A request after facade prep: solver inputs + cache key + bucket."""
    request: PlanRequest
    stack: Any
    profile: Union[HierProfile, MultiProfile]
    net: Union[Network, StarNetwork, TreeNetwork]
    wire: str
    fp: str
    xfp: str
    bucket: Tuple


class Planner:
    """Cross-fleet batch planner with a fingerprinted LRU plan cache.

    ``plan_many`` is the front door; ``submit``/``drain`` add a queued
    admission loop that caps each stacked solve at ``max_batch``
    requests per shape bucket.  Counters (``hits``, ``misses``,
    ``evictions``, ``hit_rate``) and solver telemetry
    (:attr:`solver_stats`) accumulate across calls; :meth:`clear`
    resets everything.
    """

    def __init__(self, cache_size: int = PLAN_CACHE_SIZE,
                 max_batch: int = 256) -> None:
        assert cache_size >= 1 and max_batch >= 1
        self.cache_size = cache_size
        self.max_batch = max_batch
        self._cache: "OrderedDict[str, Any]" = OrderedDict()
        # Memo of exact re-scoring: (exact problem digest, schedule) ->
        # rescored result.  Keys collide only for bit-identical pricing
        # problems, so this never blurs a price — it only deduplicates
        # the max-plus t_period recurrences across same-class clients.
        self._rescore_cache: "OrderedDict[Tuple[str, str], Any]" = \
            OrderedDict()
        self._queue: List[PlanRequest] = []
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.solver_stats = SolveManyStats()

    # ---- cache ----------------------------------------------------------

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._cache)

    def __contains__(self, fp: str) -> bool:
        return fp in self._cache

    def clear(self) -> None:
        """Drop the cache, the queue, and every counter."""
        self._cache.clear()
        self._rescore_cache.clear()
        self._queue.clear()
        self.hits = self.misses = self.evictions = 0
        self.solver_stats = SolveManyStats()

    def stats(self) -> Dict[str, Any]:
        s = self.solver_stats
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate,
                "cached": len(self._cache), "cache_size": self.cache_size,
                "solved_fleets": s.n_fleets, "lanes": s.lanes,
                "lp_calls": s.lp_calls, "refine_rounds": s.refine_rounds,
                "pad_waste": s.pad_waste}

    def _cache_get(self, fp: str):
        res = self._cache.get(fp)
        if res is not None:
            self._cache.move_to_end(fp)
        return res

    def _cache_put(self, fp: str, res) -> None:
        self._cache[fp] = res
        self._cache.move_to_end(fp)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
            self.evictions += 1

    # ---- planning -------------------------------------------------------

    def _prepare(self, r: PlanRequest) -> _Prepared:
        from repro import api
        stack, profile, net, wire = api._prepare(r.model, r.fleet, r.wire)
        fp = fingerprint(profile, net, r.B, r.objective, wire)
        xfp = fingerprint(profile, net, r.B, r.objective, wire, exact=True)
        bucket = (_profile_kind(profile), profile.num_layers,
                  getattr(profile, "num_streams", 1),
                  getattr(net, "num_edges", 1))
        return _Prepared(request=r, stack=stack, profile=profile, net=net,
                         wire=wire, fp=fp, xfp=xfp, bucket=bucket)

    def _rescore(self, res, profile, net):
        """The cached schedule priced *exactly* on this request's own
        profile/network (cache hits share the argmin, never the price).
        ``search_log`` is dropped: it belongs to the solving request."""
        if isinstance(res, MultiSchedulerResult):
            bd = _t_total_multi(profile, net, res.schedule)
            tp = pipeline_mod.t_period_multi(profile, net, res.schedule)
        else:
            bd = _t_total(profile, net, res.schedule, "device")
            tp = pipeline_mod.t_period(profile, net, res.schedule, "device")
        return dataclasses.replace(res, breakdown=bd, t_total=bd.total,
                                   t_period=tp, search_log=[])

    def _rescore_cached(self, p: _Prepared, res):
        """:meth:`_rescore` memoized on ``(exact digest, schedule)``.

        The key is the *unquantized* problem identity plus the schedule
        being priced, so two requests share a memo entry only when every
        float of their profile/network matches bit for bit — identical
        inputs give identical prices, and the documented exact-re-scoring
        contract is preserved while same-class clients pay the max-plus
        ``t_period`` recurrence once instead of once each."""
        key = (p.xfp, res.schedule.describe())
        scored = self._rescore_cache.get(key)
        if scored is None:
            scored = self._rescore(res, p.profile, p.net)
            self._rescore_cache[key] = scored
            while len(self._rescore_cache) > self.cache_size:
                self._rescore_cache.popitem(last=False)
        else:
            self._rescore_cache.move_to_end(key)
        return scored

    def plan_many(self, requests: Sequence[PlanRequest]) -> List[Any]:
        """Plan a batch of requests; returns ``repro.api.Plan`` objects in
        request order.

        Resolution per request: cache hit → re-scored cached schedule;
        first miss of a fingerprint → solved; further requests with the
        same fingerprint in the same batch ride the in-flight solve and
        count as hits.  Misses are grouped by shape bucket and solved in
        chunks of at most ``max_batch`` through ``solve_many`` (one
        stacked simplex per chunk; equal shapes inside a bucket keep
        padding waste ~0).
        """
        from repro import api
        prepared = [self._prepare(r) for r in requests]

        to_solve: "OrderedDict[str, _Prepared]" = OrderedDict()
        for p in prepared:
            if p.fp in self._cache:
                self.hits += 1
            elif p.fp in to_solve:
                self.hits += 1          # alias of an in-flight solve
            else:
                to_solve[p.fp] = p
                self.misses += 1

        buckets: "OrderedDict[Tuple, List[_Prepared]]" = OrderedDict()
        for p in to_solve.values():
            buckets.setdefault(p.bucket, []).append(p)
        for bucket, items in buckets.items():
            for lo in range(0, len(items), self.max_batch):
                chunk = items[lo:lo + self.max_batch]
                sreqs = [SolveRequest(p.profile, p.net, p.request.B,
                                      p.request.objective) for p in chunk]
                waste0 = (self.solver_stats.cells_native,
                          self.solver_stats.cells_padded)
                outs = solve_many(sreqs, stats=self.solver_stats)
                dn = self.solver_stats.cells_native - waste0[0]
                dp = self.solver_stats.cells_padded - waste0[1]
                _log.debug("planner bucket %s: %d fleets, pad waste %.4f",
                           bucket, len(chunk),
                           1.0 - dn / dp if dp else 0.0)
                for p, res in zip(chunk, outs):
                    self._cache_put(p.fp, res)

        plans = []
        for p in prepared:
            res = self._cache_get(p.fp)
            assert res is not None, "planner cache lost an in-flight plan"
            r = p.request
            plans.append(api.Plan(
                fleet=r.fleet, B=r.B, objective=r.objective,
                pipeline_depth=r.pipeline_depth, backend="batched",
                profile=p.profile, network=p.net,
                result=self._rescore_cached(p, res),
                wire=p.wire, model=p.stack))
        return plans

    # ---- admission loop -------------------------------------------------

    def submit(self, request: PlanRequest) -> None:
        """Queue one request for the next :meth:`drain`."""
        self._queue.append(request)

    def drain(self) -> List[Any]:
        """Plan every queued request (in submit order) and empty the
        queue.  Bucketing/chunking happens inside :meth:`plan_many`."""
        queue, self._queue = self._queue, []
        if not queue:
            return []
        return self.plan_many(queue)


# ---------------------------------------------------------------------------
# Module-level default planner (the `repro.api.plan_many` backend).
# ---------------------------------------------------------------------------

_DEFAULT_PLANNER = Planner()


def plan_many(requests: Sequence[PlanRequest], *,
              planner: Optional[Planner] = None) -> List[Any]:
    """Plan many fleets through the shared default :class:`Planner`
    (or an explicit one)."""
    return (planner if planner is not None else _DEFAULT_PLANNER
            ).plan_many(requests)


def clear_plan_cache() -> None:
    """Reset the default planner's cache and counters."""
    _DEFAULT_PLANNER.clear()


# ---------------------------------------------------------------------------
# CLI: python -m repro.serve.planner --bench
# ---------------------------------------------------------------------------

def _bench(n: int, seed: int, assert_hit_rate: Optional[float]) -> int:
    import time

    from repro.serve.population import synthetic_population

    reqs = synthetic_population(n=n, seed=seed)
    pl = Planner()
    t0 = time.perf_counter()
    plans = pl.plan_many(reqs)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    pl.plan_many(reqs)
    warm_s = time.perf_counter() - t0
    st = pl.stats()
    print(f"planner bench: n={len(plans)} fleets, seed={seed}")
    print(f"  cold: {cold_s:.3f}s ({len(plans) / cold_s:.1f} plans/s), "
          f"hit rate {st['hit_rate']:.3f} "
          f"({st['hits']} hits / {st['misses']} misses)")
    print(f"  warm replay: {warm_s:.3f}s "
          f"({len(plans) / warm_s:.1f} plans/s)")
    print(f"  solver: {st['solved_fleets']} fleets solved, "
          f"{st['lanes']} lanes, {st['lp_calls']} stacked calls, "
          f"pad waste {st['pad_waste']:.4f}")
    if assert_hit_rate is not None and st["hit_rate"] <= assert_hit_rate:
        print(f"FAIL: hit rate {st['hit_rate']:.3f} <= {assert_hit_rate}")
        return 1
    return 0


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="cross-fleet planner benchmark / smoke test")
    ap.add_argument("--bench", action="store_true",
                    help="run the synthetic-population benchmark")
    ap.add_argument("--n", type=int, default=256,
                    help="population size (default 256)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--assert-hit-rate", type=float, nargs="?",
                    const=0.0, default=None, metavar="R",
                    help="exit 1 unless the cold hit rate exceeds R "
                         "(default 0 when given without a value)")
    args = ap.parse_args(argv)
    if not args.bench:
        ap.error("nothing to do: pass --bench")
    return _bench(args.n, args.seed, args.assert_hit_rate)


if __name__ == "__main__":
    raise SystemExit(main())
