"""Deterministic synthetic fleet populations for the planner benchmark.

Models the serving regime the planner targets: a large client population
drawn from a *finite catalog of device classes* (phone models ×
firmware throttles × radio plans), where many clients share a class —
exactly the structure that makes a fingerprinted plan cache pay off —
but classes themselves are heterogeneous in compute, uplink and
backhaul.

Four families, mixing the paper's Table-II CNN testbeds with the LM
fleet (DESIGN.md §8):

========  ========  ====  ====================================
family    topology   M    base profile
========  ========  ====  ====================================
lenet5    triple     1    ``Fleet.from_table2("lenet5")``
alexnet   triple     1    ``Fleet.from_table2("alexnet")``
lm-m2     star       2    dense LM, ``Fleet.lm_default(2)``
lm-m3     star       3    dense LM, ``Fleet.lm_default(3)``
========  ========  ====  ====================================

Each family gets ``count // 8`` device classes (min 1); per class the
device compute rows, uplink bandwidths and the backhaul are scaled by
factors drawn from ``np.random.default_rng(seed)``, and every client
fleet is pinned (``Fleet.from_profile``) so requests are fully
self-describing.  Everything is a pure function of ``(n, seed)`` —
float64 ops only — so the same population (same fingerprints) is
reproduced in any process.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.cost_model import (HierProfile, MultiProfile, Network,
                                   StarNetwork)
from repro.core.fleet import Fleet
from repro.serve.planner import PlanRequest

__all__ = ["FAMILIES", "synthetic_population"]

#: (family, weight numerator / 32, batch size).  LM counts are kept
#: smaller because one M=3 stage-A grid is ~20x a lenet5 grid.
FAMILIES: Tuple[Tuple[str, int, int], ...] = (
    ("lenet5", 12, 128),
    ("alexnet", 10, 64),
    ("lm-m2", 7, 64),
    ("lm-m3", 3, 64),
)

#: LM clients carry down-sampled ~200 kB training samples (the 2 MB raw
#: default would pin every schedule to TASK-O on the slowest radio and
#: make the population's schedule diversity trivial).
_LM_SAMPLE_BYTES = 2e5


def _lm_stack():
    from repro.models.lm.layerstack import lm_layerstack
    from repro.models.lm.model import LMConfig
    cfg = LMConfig(name="pop-lm", family="dense", n_layers=6,
                   d_model=256, n_heads=4, n_kv_heads=2, d_ff=768,
                   vocab=32_000)
    return lm_layerstack(cfg, seq_len=256)


def _base(family: str):
    """(base profile, base network) of one family — built once."""
    if family in ("lenet5", "alexnet"):
        from repro.models.cnn import alexnet, lenet5
        model = {"lenet5": lenet5, "alexnet": alexnet}[family]()
        fleet = Fleet.from_table2(family, m=1, topology="triple")
        return fleet.profile_for(model), fleet.network()
    m = {"lm-m2": 2, "lm-m3": 3}[family]
    fleet = Fleet.lm_default(m=m, sample_bytes=_LM_SAMPLE_BYTES)
    return fleet.profile_for(_lm_stack()), fleet.network()


def _perturb_triple(prof: HierProfile, net: Network, comp: float,
                    up: float, bh: float) -> Tuple[HierProfile, Network]:
    L_f, L_b, L_u = prof.L_f.copy(), prof.L_b.copy(), prof.L_u.copy()
    L_f[0] *= comp
    L_b[0] *= comp
    L_u[0] *= comp
    return (HierProfile(prof.layer_names, L_f, L_b, L_u, prof.MP.copy(),
                        prof.MO.copy(), prof.sample_bytes, prof.MG.copy()),
            Network(bw_de=net.bw_de * up, bw_ec=net.bw_ec * bh))


def _perturb_star(prof: MultiProfile, net: StarNetwork,
                  comp: np.ndarray, up: np.ndarray, bh: float
                  ) -> Tuple[MultiProfile, StarNetwork]:
    M = prof.num_devices
    L_f, L_b, L_u = prof.L_f.copy(), prof.L_b.copy(), prof.L_u.copy()
    L_f[:M] *= comp[:, None]
    L_b[:M] *= comp[:, None]
    L_u[:M] *= comp[:, None]
    return (MultiProfile(prof.layer_names, prof.worker_names, L_f, L_b,
                         L_u, prof.MP.copy(), prof.MO.copy(),
                         prof.sample_bytes, prof.MG.copy()),
            StarNetwork(bw_de=net.bw_de * up, bw_ec=net.bw_ec * bh))


def family_counts(n: int) -> List[Tuple[str, int, int]]:
    """Deterministic ``(family, count, B)`` split of an ``n``-client
    population (weights from :data:`FAMILIES`; remainder to the first)."""
    total_w = sum(w for _, w, _ in FAMILIES)
    counts = [(fam, n * w // total_w, B) for fam, w, B in FAMILIES]
    short = n - sum(c for _, c, _ in counts)
    fam0, c0, b0 = counts[0]
    counts[0] = (fam0, c0 + short, b0)
    return counts


def synthetic_population(n: int = 1024, seed: int = 0,
                         classes_per: int = 8) -> List[PlanRequest]:
    """``n`` pinned-fleet :class:`PlanRequest`\\ s over the four families.

    Each family draws ``count // classes_per`` device classes (min 1);
    clients are assigned classes uniformly, and two clients of one class
    are *identical* fleets (same fingerprint).  Fully deterministic in
    ``(n, seed, classes_per)``.
    """
    rng = np.random.default_rng(seed)
    reqs: List[PlanRequest] = []
    for family, count, B in family_counts(n):
        if count <= 0:
            continue
        prof, net = _base(family)
        n_classes = max(1, count // classes_per)
        if isinstance(prof, MultiProfile):
            M = prof.num_devices
            comp = rng.uniform(0.7, 1.4, size=(n_classes, M))
            up = rng.uniform(0.7, 1.4, size=(n_classes, M))
        else:
            comp = rng.uniform(0.7, 1.4, size=(n_classes, 1))
            up = rng.uniform(0.7, 1.4, size=(n_classes, 1))
        bh = rng.uniform(0.85, 1.25, size=n_classes)
        assign = rng.integers(0, n_classes, size=count)
        for i in range(count):
            k = int(assign[i])
            if isinstance(prof, MultiProfile):
                p, nw = _perturb_star(prof, net, comp[k], up[k],
                                      float(bh[k]))
            else:
                p, nw = _perturb_triple(prof, net, float(comp[k, 0]),
                                        float(up[k, 0]), float(bh[k]))
            reqs.append(PlanRequest(fleet=Fleet.from_profile(p, nw), B=B,
                                    tag=f"{family}/c{k}/{i}"))
    return reqs
