from repro.train.loop import (HierLoopConfig, InjectedFailure, LoopConfig,
                              run_hier_loop, run_train_loop)
from repro.train.step import TrainState, init_state, make_train_step

__all__ = ["HierLoopConfig", "InjectedFailure", "LoopConfig",
           "run_hier_loop", "run_train_loop", "TrainState", "init_state",
           "make_train_step"]
