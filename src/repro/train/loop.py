"""Fault-tolerant training loop.

Failure model and mitigations (single-host container, 1000-node
protocol):

* **Checkpoint/restart** — atomic keep-N checkpoints every
  ``ckpt_every`` steps; on start the loop restores the latest and
  resumes at the recorded step.
* **Deterministic skip-ahead** — the data pipeline is stateless
  (batch k is pure in (seed, k)), so resume needs no pipeline replay.
* **Failure injection** — ``fail_at`` raises mid-run (after the
  gradient step, before the checkpoint) to exercise the recovery path;
  the integration test restarts the loop and asserts bit-identical
  convergence with an uninterrupted run.
* **Straggler mitigation** (HierTrain-native) — for the hierarchical
  CNN trainer, measured per-step worker times feed an EMA profile and
  the Algorithm-1 scheduler re-solves every ``resched_every`` steps:
  a slowed worker automatically sheds samples/layers.  This is the
  paper's profiling stage run *online*.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointManager

Tree = Any


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    log_every: int = 10
    fail_at: Optional[int] = None     # raise after completing this step
    seed: int = 0


def run_train_loop(cfg: LoopConfig, state: Tree, train_step: Callable,
                   batch_fn: Callable[[int], Tree],
                   shardings: Optional[Tree] = None,
                   log: Optional[Callable[[str], None]] = print
                   ) -> Dict[str, Any]:
    """Run (or resume) training.  Returns {state, history, resumed_from}."""
    manager = CheckpointManager(cfg.ckpt_dir, cfg.keep) if cfg.ckpt_dir \
        else None
    start = 0
    resumed_from = None
    if manager is not None:
        step, restored = manager.restore_latest(state, shardings)
        if restored is not None:
            state, start, resumed_from = restored, step, step

    key = jax.random.PRNGKey(cfg.seed)
    history: List[Dict[str, float]] = []
    t_last = time.perf_counter()
    for step in range(start, cfg.total_steps):
        batch = jax.tree.map(jax.numpy.asarray, batch_fn(step))
        state, metrics = train_step(state, batch,
                                    jax.random.fold_in(key, step))
        if cfg.log_every and (step + 1) % cfg.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            now = time.perf_counter()
            m["steps_per_s"] = cfg.log_every / (now - t_last)
            t_last = now
            m["at"] = step + 1
            history.append(m)
            if log:
                log(f"step {step+1}: loss={m['loss']:.4f} "
                    f"gnorm={m.get('grad_norm', float('nan')):.3f} "
                    f"({m['steps_per_s']:.2f} it/s)")
        if manager is not None and (step + 1) % cfg.ckpt_every == 0:
            manager.save(step + 1, state, extra={"seed": cfg.seed})
        if cfg.fail_at is not None and step + 1 == cfg.fail_at:
            raise InjectedFailure(f"injected failure after step {step+1}")
    return {"state": state, "history": history,
            "resumed_from": resumed_from}


# ---------------------------------------------------------------------------
# Hierarchical (mobile-edge-cloud) CNN training with online re-scheduling
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HierLoopConfig:
    total_steps: int
    batch: int
    lr: float = 0.05
    resched_every: int = 20           # straggler mitigation cadence
    ema: float = 0.3
    seed: int = 0
    pipeline_depth: int = 1           # K minibatches in flight (§7); 1 =
    #                                   barrier-per-iteration execution
    objective: str = "latency"        # scheduler objective (§7)


def _ema_profile_update(prof, baseline, slow: Dict[str, float],
                        worker_names, ema: float) -> None:
    """EMA every worker toward its *currently observed* speed.

    Workers absent from ``slow`` decay toward the baseline profile
    (factor 1.0) — this is what lets a healed straggler recover: the old
    code only touched workers the monitor still reported, so a worker
    that stopped straggling kept its degraded profile forever.
    """
    for i, w in enumerate(worker_names):
        factor = slow.get(w, 1.0)
        for name in ("L_f", "L_b", "L_u"):
            cur = getattr(prof, name)
            target = getattr(baseline, name)[i] * factor
            cur[i] = (1 - ema) * cur[i] + ema * target
    if hasattr(prof, "_prefix"):
        del prof._prefix


def _loop_ops(topology: str, model, profile, net, cfg: "HierLoopConfig"):
    """Topology-native function bundle for :func:`_run_loop`.

    The triple and star loops were line-for-line duplicates differing
    only in which half of the forked surface they called; this bundle is
    the collapse point (DESIGN.md §9).  History formats are preserved
    per topology: the triple records scalar ``m_s`` and a 3-tuple ``b``,
    the star records the ``m_s`` tuple and an (M+2)-tuple ``b``.
    """
    if topology == "triple":
        from repro.core import scheduler
        from repro.core.cost_model import WORKERS, _t_total
        from repro.core.hybrid_step import jitted_hybrid_step, split_batch
        from repro.core.pipeline import t_period

        return dict(
            names=WORKERS,
            widx={w: i for i, w in enumerate(WORKERS)},
            solve=lambda p: scheduler._solve_3w(p, net, cfg.batch,
                                                objective=cfg.objective),
            fill=lambda p, s: _t_total(p, net, s).total,
            period=lambda p, s: t_period(p, net, s),
            step_fn=lambda s: jitted_hybrid_step(model, s.m_s, s.m_l,
                                                 cfg.lr),
            split=split_batch,
            hist=lambda s: {"m_s": s.m_s, "m_l": s.m_l,
                            "b": (s.b_o, s.b_s, s.b_l)},
            tag="hier",
        )
    assert topology == "star", topology
    from repro.core import scheduler
    from repro.core.cost_model import _t_total_multi
    from repro.core.hybrid_step import (jitted_multi_hybrid_step,
                                        multi_split_batch)
    from repro.core.pipeline import t_period_multi

    return dict(
        names=profile.worker_names,
        widx=profile.widx,
        solve=lambda p: scheduler._solve_multi(p, net, cfg.batch,
                                               objective=cfg.objective),
        fill=lambda p, s: _t_total_multi(p, net, s).total,
        period=lambda p, s: t_period_multi(p, net, s),
        step_fn=lambda s: jitted_multi_hybrid_step(model, s.m_s, s.m_l,
                                                   cfg.lr),
        split=multi_split_batch,
        hist=lambda s: {"m_s": s.m_s, "m_l": s.m_l,
                        "b": (s.b_o, *s.b_s, s.b_l)},
        tag="multi-hier",
    )


def _run_loop(cfg: HierLoopConfig, model, profile, net, data,
              worker_slowdown: Optional[Callable[[int], Dict[str, float]]]
              = None, log: Optional[Callable[[str], None]] = None, *,
              topology: str, initial_schedule=None) -> Dict[str, Any]:
    """Train any layer stack under the HierTrain schedule, re-solving the
    schedule online as (simulated) worker speeds drift — the engine
    behind :meth:`repro.api.Plan.train` for both topologies.

    ``model`` is anything :func:`repro.core.layerstack.as_layerstack`
    accepts — a layered CNN or an LM model-zoo adapter
    (:mod:`repro.models.lm.layerstack`); ``data.batch(step)`` must return
    ``{"x", "labels"}`` arrays whose leading axis is the sample axis.

    ``worker_slowdown(step)`` returns per-worker-name slowdown factors —
    the straggler injection used by tests/benchmarks.  Execution is
    simulated with the calibrated cost model for timing and with the
    *real* hybrid JAX step for the numerics.

    Re-scheduling is gated on cadence alone (every ``resched_every``
    steps): each tick EMAs *every* worker toward its observed speed — so
    a straggler that heals decays back to the baseline profile and the
    loop returns to the pre-straggle schedule.

    With ``cfg.pipeline_depth = K > 1`` the wall clock models pipelined
    steady-state execution (DESIGN.md §7): the first step of each
    K-window pays the Eq.-12 fill latency and the remaining ``K - 1``
    pay one ``t_period`` each — and a re-schedule that actually changes
    the schedule breaks the pipe, so the fill is re-paid at that step
    regardless of window position.
    """
    import copy

    ops = _loop_ops(topology, model, profile, net, cfg)
    widx = ops["widx"]
    prof = copy.deepcopy(profile)
    # The solver is a pure function of the profile values, so a caller
    # that already planned this exact (profile, net, B, objective) —
    # Plan.train — can seed the loop and skip the duplicate solve.
    sched = initial_schedule if initial_schedule is not None \
        else ops["solve"](prof).schedule
    params = model.init(jax.random.PRNGKey(cfg.seed))
    wall = 0.0
    history = []
    losses = []
    for step in range(cfg.total_steps):
        prev_sched = sched
        slow = worker_slowdown(step) if worker_slowdown else {}
        if worker_slowdown is not None and step > 0 and \
                step % cfg.resched_every == 0:
            _ema_profile_update(prof, profile, slow, ops["names"], cfg.ema)
            sched = ops["solve"](prof).schedule
        # timing from the cost model under the *actual* current speeds
        true_prof = copy.deepcopy(profile)
        for w, factor in (slow or {}).items():
            i = widx[w]
            true_prof.L_f[i] *= factor
            true_prof.L_b[i] *= factor
            true_prof.L_u[i] *= factor
        if hasattr(true_prof, "_prefix"):   # deepcopy carries the cache
            del true_prof._prefix
        if cfg.pipeline_depth > 1 and step % cfg.pipeline_depth != 0 \
                and sched == prev_sched:
            wall += ops["period"](true_prof, sched)
        else:   # window head or pipe broken by a re-schedule: pay fill
            wall += ops["fill"](true_prof, sched)
        b = data.batch(step)
        # Cached compiled step: static (m_s, m_l, lr), donated params — a
        # reschedule that keeps the cuts reuses the same executable.
        step_fn = ops["step_fn"](sched)
        params, loss = step_fn(params, ops["split"](
            jax.numpy.asarray(b["x"]), jax.numpy.asarray(b["labels"]),
            sched))
        losses.append(float(loss))
        if log and (step + 1) % 10 == 0:
            log(f"{ops['tag']} step {step+1}: loss={losses[-1]:.4f} "
                f"sched=({sched.describe()}) wall={wall:.2f}s")
        history.append({"step": step + 1, "loss": losses[-1],
                        "wall": wall, **ops["hist"](sched),
                        "sched": sched})
    return {"params": params, "history": history, "wall": wall,
            "final_schedule": sched}


def run_hier_loop(cfg: HierLoopConfig, model, profile, net, data,
                  worker_slowdown: Optional[Callable[[int], Dict[str, float]]]
                  = None, log: Optional[Callable[[str], None]] = None
                  ) -> Dict[str, Any]:
    """Deprecated shim over the facade: ``repro.api.plan(model,
    Fleet.from_profile(profile, net), B).train(data, ...)``.  Results —
    trained params, history, wall clock — are bit-identical to the
    historical three-worker loop."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated(
        "repro.train.loop.run_hier_loop()",
        "repro.api.plan(model, Fleet.from_profile(profile, net), "
        "B).train(data, steps=...)")
    from repro import api
    p = api.plan(model, api.Fleet.from_profile(profile, net), cfg.batch,
                 objective=cfg.objective,
                 pipeline_depth=cfg.pipeline_depth)
    return p.train(data, steps=cfg.total_steps, lr=cfg.lr,
                   resched_every=cfg.resched_every, ema=cfg.ema,
                   seed=cfg.seed, worker_slowdown=worker_slowdown, log=log)


def run_multi_hier_loop(cfg: HierLoopConfig, model, profile, net, data,
                        worker_slowdown: Optional[
                            Callable[[int], Dict[str, float]]] = None,
                        log: Optional[Callable[[str], None]] = None
                        ) -> Dict[str, Any]:
    """Deprecated shim over the facade (M-device variant): see
    :func:`run_hier_loop`."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated(
        "repro.train.loop.run_multi_hier_loop()",
        "repro.api.plan(model, Fleet.from_profile(profile, net), "
        "B).train(data, steps=...)")
    from repro import api
    p = api.plan(model, api.Fleet.from_profile(profile, net), cfg.batch,
                 objective=cfg.objective,
                 pipeline_depth=cfg.pipeline_depth)
    return p.train(data, steps=cfg.total_steps, lr=cfg.lr,
                   resched_every=cfg.resched_every, ema=cfg.ema,
                   seed=cfg.seed, worker_slowdown=worker_slowdown, log=log)
