"""Fault-tolerant training loop.

Failure model and mitigations (single-host container, 1000-node
protocol):

* **Checkpoint/restart** — atomic keep-N checkpoints every
  ``ckpt_every`` steps; on start the loop restores the latest and
  resumes at the recorded step.
* **Deterministic skip-ahead** — the data pipeline is stateless
  (batch k is pure in (seed, k)), so resume needs no pipeline replay.
* **Failure injection** — ``fail_at`` raises mid-run (after the
  gradient step, before the checkpoint) to exercise the recovery path;
  the integration test restarts the loop and asserts bit-identical
  convergence with an uninterrupted run.
* **Straggler mitigation** (HierTrain-native) — for the hierarchical
  CNN trainer, measured per-step worker times feed an EMA profile and
  the Algorithm-1 scheduler re-solves every ``resched_every`` steps:
  a slowed worker automatically sheds samples/layers.  This is the
  paper's profiling stage run *online*.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointManager

Tree = Any


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    log_every: int = 10
    fail_at: Optional[int] = None     # raise after completing this step
    seed: int = 0


def run_train_loop(cfg: LoopConfig, state: Tree, train_step: Callable,
                   batch_fn: Callable[[int], Tree],
                   shardings: Optional[Tree] = None,
                   log: Optional[Callable[[str], None]] = print
                   ) -> Dict[str, Any]:
    """Run (or resume) training.  Returns {state, history, resumed_from}."""
    manager = CheckpointManager(cfg.ckpt_dir, cfg.keep) if cfg.ckpt_dir \
        else None
    start = 0
    resumed_from = None
    if manager is not None:
        step, restored = manager.restore_latest(state, shardings)
        if restored is not None:
            state, start, resumed_from = restored, step, step

    key = jax.random.PRNGKey(cfg.seed)
    history: List[Dict[str, float]] = []
    t_last = time.perf_counter()
    for step in range(start, cfg.total_steps):
        batch = jax.tree.map(jax.numpy.asarray, batch_fn(step))
        state, metrics = train_step(state, batch,
                                    jax.random.fold_in(key, step))
        if cfg.log_every and (step + 1) % cfg.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            now = time.perf_counter()
            m["steps_per_s"] = cfg.log_every / (now - t_last)
            t_last = now
            m["at"] = step + 1
            history.append(m)
            if log:
                log(f"step {step+1}: loss={m['loss']:.4f} "
                    f"gnorm={m.get('grad_norm', float('nan')):.3f} "
                    f"({m['steps_per_s']:.2f} it/s)")
        if manager is not None and (step + 1) % cfg.ckpt_every == 0:
            manager.save(step + 1, state, extra={"seed": cfg.seed})
        if cfg.fail_at is not None and step + 1 == cfg.fail_at:
            raise InjectedFailure(f"injected failure after step {step+1}")
    return {"state": state, "history": history,
            "resumed_from": resumed_from}


# ---------------------------------------------------------------------------
# Hierarchical (mobile-edge-cloud) CNN training with online re-scheduling
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HierLoopConfig:
    total_steps: int
    batch: int
    lr: float = 0.05
    resched_every: int = 20           # straggler mitigation cadence
    ema: float = 0.3
    seed: int = 0
    pipeline_depth: int = 1           # K minibatches in flight (§7); 1 =
    #                                   barrier-per-iteration execution
    objective: str = "latency"        # scheduler objective (§7)
    wire: str = "none"                # cut-point transfer codec (§11);
    #                                   the caller's profile must carry
    #                                   matching (compressed) MO/MG
    ckpt_dir: Optional[str] = None    # crash-safe resume (DESIGN.md §10)
    ckpt_every: int = 50
    keep: int = 3
    fail_at: Optional[int] = None     # raise after completing this step


def _sched_to_json(s) -> Dict[str, Any]:
    """JSON form of a (Multi)Schedule — ints and strings only, so the
    round-trip through the checkpoint manifest is exact."""
    from repro.core.cost_model import MultiSchedule
    if isinstance(s, MultiSchedule):
        return {"kind": "star", "worker_o": s.worker_o,
                "worker_l": s.worker_l, "s_workers": list(s.s_workers),
                "m_s": list(s.m_s), "m_l": s.m_l, "b_o": s.b_o,
                "b_s": list(s.b_s), "b_l": s.b_l}
    return {"kind": "triple", "worker_o": s.worker_o,
            "worker_s": s.worker_s, "worker_l": s.worker_l, "m_s": s.m_s,
            "m_l": s.m_l, "b_o": s.b_o, "b_s": s.b_s, "b_l": s.b_l}


def _sched_from_json(d: Dict[str, Any]):
    from repro.core.cost_model import MultiSchedule, Schedule
    if d["kind"] == "star":
        return MultiSchedule(
            worker_o=d["worker_o"], worker_l=d["worker_l"],
            s_workers=tuple(d["s_workers"]), m_s=tuple(d["m_s"]),
            m_l=d["m_l"], b_o=d["b_o"], b_s=tuple(d["b_s"]), b_l=d["b_l"])
    return Schedule(d["worker_o"], d["worker_s"], d["worker_l"], d["m_s"],
                    d["m_l"], d["b_o"], d["b_s"], d["b_l"])


def _prof_arrays(p) -> Dict[str, np.ndarray]:
    return {"L_f": np.asarray(p.L_f), "L_b": np.asarray(p.L_b),
            "L_u": np.asarray(p.L_u)}


def _profile_from_arrays(template, worker_names, arrays):
    """Rebuild a profile from checkpointed timing rows.  The per-layer
    columns (MP/MO/MG/sample_bytes) are hardware-membership invariant, so
    they come from the caller's template; the per-worker rows and (for a
    star) the membership come from the checkpoint."""
    from repro.core.cost_model import (HierProfile, MultiProfile,
                                       TreeProfile)
    if worker_names is None:
        return HierProfile(
            layer_names=template.layer_names, L_f=arrays["L_f"],
            L_b=arrays["L_b"], L_u=arrays["L_u"], MP=template.MP,
            MO=template.MO, sample_bytes=template.sample_bytes,
            MG=template.MG)
    common = dict(
        layer_names=template.layer_names, worker_names=tuple(worker_names),
        L_f=arrays["L_f"], L_b=arrays["L_b"], L_u=arrays["L_u"],
        MP=template.MP, MO=template.MO,
        sample_bytes=template.sample_bytes, MG=template.MG)
    if isinstance(template, TreeProfile):
        return TreeProfile(n_edges=template.n_edges,
                           cloud_speedup=template.cloud_speedup, **common)
    return MultiProfile(**common)


def _ema_profile_update(prof, baseline, slow: Dict[str, float],
                        worker_names, ema: float) -> None:
    """EMA every worker toward its *currently observed* speed.

    Workers absent from ``slow`` decay toward the baseline profile
    (factor 1.0) — this is what lets a healed straggler recover: the old
    code only touched workers the monitor still reported, so a worker
    that stopped straggling kept its degraded profile forever.
    """
    for i, w in enumerate(worker_names):
        factor = slow.get(w, 1.0)
        for name in ("L_f", "L_b", "L_u"):
            cur = getattr(prof, name)
            target = getattr(baseline, name)[i] * factor
            cur[i] = (1 - ema) * cur[i] + ema * target
    if hasattr(prof, "_prefix"):
        del prof._prefix


def _loop_ops(topology: str, model, profile, net, cfg: "HierLoopConfig"):
    """Topology-native function bundle for :func:`_run_loop`.

    The triple and star loops were line-for-line duplicates differing
    only in which half of the forked surface they called; this bundle is
    the collapse point (DESIGN.md §9).  History formats are preserved
    per topology: the triple records scalar ``m_s`` and a 3-tuple ``b``,
    the star records the ``m_s`` tuple and an (M+2)-tuple ``b``.
    """
    if topology == "triple":
        from repro.core import scheduler
        from repro.core.cost_model import WORKERS, _t_total
        from repro.core.hybrid_step import jitted_hybrid_step, split_batch
        from repro.core.pipeline import t_period

        return dict(
            names=WORKERS,
            widx={w: i for i, w in enumerate(WORKERS)},
            solve=lambda p, warm=None: scheduler._solve_3w(
                p, net, cfg.batch, objective=cfg.objective,
                warm_start=warm),
            fill=lambda p, s: _t_total(p, net, s).total,
            period=lambda p, s: t_period(p, net, s),
            step_fn=lambda s: jitted_hybrid_step(model, s.m_s, s.m_l,
                                                 cfg.lr, wire=cfg.wire),
            split=split_batch,
            hist=lambda s: {"m_s": s.m_s, "m_l": s.m_l,
                            "b": (s.b_o, s.b_s, s.b_l)},
            tag="hier",
        )
    assert topology in ("star", "tree"), topology
    from repro.core import scheduler
    from repro.core.cost_model import _t_total_multi
    from repro.core.hybrid_step import (jitted_multi_hybrid_step,
                                        jitted_tree_hybrid_step,
                                        multi_split_batch,
                                        tree_stream_edges)
    from repro.core.pipeline import t_period_multi

    if topology == "tree":
        # The tree step pre-merges each edge's same-cut streams; the
        # stream→edge map depends on the live schedule, so it is
        # re-derived per solve.  Straggler EMAs are already per-edge:
        # every edge server is its own row of ``worker_names``.
        step_fn = lambda s: jitted_tree_hybrid_step(  # noqa: E731
            model, s.m_s, s.m_l, cfg.lr, wire=cfg.wire,
            stream_edge=tree_stream_edges(profile, net, s))
        tag = "tree-hier"
    else:
        step_fn = lambda s: jitted_multi_hybrid_step(  # noqa: E731
            model, s.m_s, s.m_l, cfg.lr, wire=cfg.wire)
        tag = "multi-hier"

    return dict(
        names=profile.worker_names,
        widx=profile.widx,
        solve=lambda p, warm=None: scheduler._solve_multi(
            p, net, cfg.batch, objective=cfg.objective, warm_start=warm),
        fill=lambda p, s: _t_total_multi(p, net, s).total,
        period=lambda p, s: t_period_multi(p, net, s),
        step_fn=step_fn,
        split=multi_split_batch,
        hist=lambda s: {"m_s": s.m_s, "m_l": s.m_l,
                        "b": (s.b_o, *s.b_s, s.b_l)},
        tag=tag,
    )


def _run_loop(cfg: HierLoopConfig, model, profile, net, data,
              worker_slowdown: Optional[Callable[[int], Dict[str, float]]]
              = None, log: Optional[Callable[[str], None]] = None, *,
              topology: str, initial_schedule=None,
              churn=None) -> Dict[str, Any]:
    """Train any layer stack under the HierTrain schedule, re-solving the
    schedule online as (simulated) worker speeds drift — the engine
    behind :meth:`repro.api.Plan.train` for both topologies.

    ``model`` is anything :func:`repro.core.layerstack.as_layerstack`
    accepts — a layered CNN or an LM model-zoo adapter
    (:mod:`repro.models.lm.layerstack`); ``data.batch(step)`` must return
    ``{"x", "labels"}`` arrays whose leading axis is the sample axis.

    ``worker_slowdown(step)`` returns per-worker-name slowdown factors —
    the straggler injection used by tests/benchmarks.  Execution is
    simulated with the calibrated cost model for timing and with the
    *real* hybrid JAX step for the numerics.

    Re-scheduling is gated on cadence alone (every ``resched_every``
    steps): each tick EMAs *every* worker toward its observed speed — so
    a straggler that heals decays back to the baseline profile and the
    loop returns to the pre-straggle schedule.

    With ``cfg.pipeline_depth = K > 1`` the wall clock models pipelined
    steady-state execution (DESIGN.md §7): the first step of each
    K-window pays the Eq.-12 fill latency and the remaining ``K - 1``
    pay one ``t_period`` each — and a re-schedule that actually changes
    the schedule breaks the pipe, so the fill is re-paid at that step
    regardless of window position.

    **Elastic fleets** (DESIGN.md §10): ``churn`` is a
    :class:`~repro.core.churn.ChurnTrace` (star topology only).  Events
    pinned to step ``s`` are applied at the top of step ``s``; a
    membership change remaps the live schedule onto the survivors and
    re-solves with it as a warm incumbent (bit-identical to a cold
    solve on the survivor fleet, by the ``_warm_ok`` certificate), a
    crash additionally charges the lost in-flight fill as recovery
    time, and a join seeds the newcomer's profile rows from the fleet's
    reference tier for the EMA to refine.  Measured solver seconds land
    only in the returned ``churn_log`` — the simulated ``wall`` stays a
    pure function of (cost model, trace, seed) so resume is
    bit-reproducible.

    **Crash-safe resume**: with ``cfg.ckpt_dir`` set, every
    ``cfg.ckpt_every`` steps the loop atomically checkpoints params,
    the EMA'd and baseline profiles, the reference rows, the schedule,
    the network, the simulated wall clock, and the step.  On start the
    loop restores the newest readable checkpoint and continues; a
    resumed run is bitwise equal to an uninterrupted one from the
    resume step onward (``history`` then covers only the resumed tail;
    ``resumed_from`` records the step).  ``cfg.fail_at`` injects a
    failure after that step completes (post-checkpoint) to exercise
    the path.
    """
    import copy

    if churn is not None and topology != "star":
        raise ValueError(
            "churn is native to the star topology: membership is a "
            "property of the M-device fleet; the paper's fixed "
            "three-worker triple has no notion of join/leave "
            "(use Fleet.from_table2() or topology='star')")
    if churn is not None:
        from repro.core.churn import (DeviceCrash, apply_event,
                                      reference_rows, remap_schedule)

    ops = _loop_ops(topology, model, profile, net, cfg)
    prof = copy.deepcopy(profile)
    # Baseline for the straggler EMA and the simulated "true" speeds.
    # Static fleets: a value-identical copy of ``profile`` (arithmetic
    # unchanged).  Elastic fleets: membership-edited alongside ``prof``
    # so it always describes the *current* fleet at nominal speed.
    base_prof = copy.deepcopy(profile)
    ref = reference_rows(base_prof) if churn is not None else None
    # The solver is a pure function of the profile values, so a caller
    # that already planned this exact (profile, net, B, objective) —
    # Plan.train — can seed the loop and skip the duplicate solve.
    sched = initial_schedule if initial_schedule is not None \
        else ops["solve"](prof).schedule
    params = model.init(jax.random.PRNGKey(cfg.seed))
    wall = 0.0
    start = 0
    resumed_from = None
    churn_log: List[Dict[str, Any]] = []

    manager = CheckpointManager(cfg.ckpt_dir, cfg.keep) \
        if cfg.ckpt_dir and cfg.ckpt_every else None
    if manager is not None:
        is_star = topology == "star"
        is_tree = topology == "tree"

        def _like(ckpt_step, extra):
            if extra.get("seed") != cfg.seed:
                raise ValueError(
                    f"checkpoint seed {extra.get('seed')} does not "
                    f"match cfg.seed {cfg.seed}: refusing to resume a "
                    "different run")
            names = extra["worker_names"] if is_star else None
            rows = len(names) if is_star \
                else np.asarray(profile.L_f).shape[0]
            cols = np.asarray(profile.L_f).shape[1]

            def grid():
                return {k: np.zeros((rows, cols))
                        for k in ("L_f", "L_b", "L_u")}

            like = {"params": model.init(jax.random.PRNGKey(cfg.seed)),
                    "prof": grid()}
            if is_star:
                like["base"] = grid()
                like["ref"] = {k: np.zeros(cols)
                               for k in ("L_f", "L_b", "L_u")}
            return like

        ckpt_step, tree, extra = manager.restore_latest_with(_like)
        if ckpt_step is not None:
            start = resumed_from = ckpt_step
            params = tree["params"]
            wall = float(extra["wall"])
            sched = _sched_from_json(extra["sched"])
            # Star membership may have churned, so names come from the
            # checkpoint; tree/triple fleets have fixed membership and
            # rebuild from the caller's template.
            names = tuple(extra["worker_names"]) if is_star else \
                (profile.worker_names if is_tree else None)
            prof = _profile_from_arrays(profile, names, tree["prof"])
            if is_star:
                from repro.core.cost_model import StarNetwork
                base_prof = _profile_from_arrays(profile, names,
                                                 tree["base"])
                net = StarNetwork(
                    bw_de=np.asarray(extra["bw_de"], dtype=np.float64),
                    bw_ec=float(extra["bw_ec"]))
                ref = (np.asarray(tree["ref"]["L_f"]),
                       np.asarray(tree["ref"]["L_b"]),
                       np.asarray(tree["ref"]["L_u"]))
            ops = _loop_ops(topology, model, prof, net, cfg)

    history = []
    losses = []
    for step in range(start, cfg.total_steps):
        prev_sched = sched
        events = churn.events_at(step) if churn is not None else ()
        if events:
            # A crash kills the in-flight attempt: survivors discover it
            # at the barrier after ~one fill of the pre-crash schedule
            # at baseline speeds, then re-run the step on the new fleet.
            lost = ops["fill"](base_prof, sched) \
                if any(isinstance(e, DeviceCrash) for e in events) \
                else 0.0
            wall += lost
            for ev in events:
                prof, base_prof, net, _ = apply_event(prof, base_prof,
                                                      net, ref, ev)
            # ops closures capture (membership, net) — rebuild on churn
            ops = _loop_ops(topology, model, prof, net, cfg)
            warm = remap_schedule(sched, prof)
            t0 = time.perf_counter()
            res = ops["solve"](prof, warm)
            resolve_s = time.perf_counter() - t0
            sched = res.schedule
            churn_log.append({
                "step": step,
                "events": [f"{type(e).__name__}:{e.name}"
                           for e in events],
                "m": len(ops["names"]) - 2,
                "warm": warm is not None, "lost_s": lost,
                "resolve_s": resolve_s, "n_pruned": res.n_pruned,
                "n_candidates": res.n_candidates})
        slow = worker_slowdown(step) if worker_slowdown else {}
        if worker_slowdown is not None and step > 0 and \
                step % cfg.resched_every == 0:
            _ema_profile_update(prof, base_prof, slow, ops["names"],
                                cfg.ema)
            sched = ops["solve"](prof, sched).schedule
        # timing from the cost model under the *actual* current speeds
        true_prof = copy.deepcopy(base_prof)
        widx = ops["widx"]
        for w, factor in (slow or {}).items():
            if w not in widx:   # straggler report for a departed device
                continue
            i = widx[w]
            true_prof.L_f[i] *= factor
            true_prof.L_b[i] *= factor
            true_prof.L_u[i] *= factor
        if hasattr(true_prof, "_prefix"):   # deepcopy carries the cache
            del true_prof._prefix
        if cfg.pipeline_depth > 1 and step % cfg.pipeline_depth != 0 \
                and sched == prev_sched:
            wall += ops["period"](true_prof, sched)
        else:   # window head or pipe broken by a re-schedule: pay fill
            wall += ops["fill"](true_prof, sched)
        b = data.batch(step)
        # Cached compiled step: static (m_s, m_l, lr), donated params — a
        # reschedule that keeps the cuts reuses the same executable.
        step_fn = ops["step_fn"](sched)
        params, loss = step_fn(params, ops["split"](
            jax.numpy.asarray(b["x"]), jax.numpy.asarray(b["labels"]),
            sched))
        losses.append(float(loss))
        if log and (step + 1) % 10 == 0:
            log(f"{ops['tag']} step {step+1}: loss={losses[-1]:.4f} "
                f"sched=({sched.describe()}) wall={wall:.2f}s")
        history.append({"step": step + 1, "loss": losses[-1],
                        "wall": wall, **ops["hist"](sched),
                        "sched": sched})
        if manager is not None and (step + 1) % cfg.ckpt_every == 0:
            tree = {"params": params, "prof": _prof_arrays(prof)}
            extra = {"step": step + 1, "wall": wall, "seed": cfg.seed,
                     "topology": topology,
                     "sched": _sched_to_json(sched)}
            if topology == "star":
                rows = ref if ref is not None else (
                    np.asarray(base_prof.L_f[0]),
                    np.asarray(base_prof.L_b[0]),
                    np.asarray(base_prof.L_u[0]))
                tree["base"] = _prof_arrays(base_prof)
                tree["ref"] = {"L_f": np.asarray(rows[0]),
                               "L_b": np.asarray(rows[1]),
                               "L_u": np.asarray(rows[2])}
                extra["worker_names"] = list(prof.worker_names)
                extra["bw_de"] = [float(x)
                                  for x in np.asarray(net.bw_de)]
                extra["bw_ec"] = float(net.bw_ec)
            manager.save(step + 1, tree, extra=extra)
        if cfg.fail_at is not None and step + 1 == cfg.fail_at:
            raise InjectedFailure(
                f"injected failure after step {step+1}")
    return {"params": params, "history": history, "wall": wall,
            "final_schedule": sched, "resumed_from": resumed_from,
            "churn_log": churn_log}


def run_hier_loop(cfg: HierLoopConfig, model, profile, net, data,
                  worker_slowdown: Optional[Callable[[int], Dict[str, float]]]
                  = None, log: Optional[Callable[[str], None]] = None
                  ) -> Dict[str, Any]:
    """Deprecated shim over the facade: ``repro.api.plan(model,
    Fleet.from_profile(profile, net), B).train(data, ...)``.  Results —
    trained params, history, wall clock — are bit-identical to the
    historical three-worker loop."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated(
        "repro.train.loop.run_hier_loop()",
        "repro.api.plan(model, Fleet.from_profile(profile, net), "
        "B).train(data, steps=...)")
    from repro import api
    p = api.plan(model, api.Fleet.from_profile(profile, net), cfg.batch,
                 objective=cfg.objective,
                 pipeline_depth=cfg.pipeline_depth)
    return p.train(data, steps=cfg.total_steps, lr=cfg.lr,
                   resched_every=cfg.resched_every, ema=cfg.ema,
                   seed=cfg.seed, worker_slowdown=worker_slowdown, log=log)


def run_multi_hier_loop(cfg: HierLoopConfig, model, profile, net, data,
                        worker_slowdown: Optional[
                            Callable[[int], Dict[str, float]]] = None,
                        log: Optional[Callable[[str], None]] = None
                        ) -> Dict[str, Any]:
    """Deprecated shim over the facade (M-device variant): see
    :func:`run_hier_loop`."""
    from repro.core._deprecation import warn_deprecated
    warn_deprecated(
        "repro.train.loop.run_multi_hier_loop()",
        "repro.api.plan(model, Fleet.from_profile(profile, net), "
        "B).train(data, steps=...)")
    from repro import api
    p = api.plan(model, api.Fleet.from_profile(profile, net), cfg.batch,
                 objective=cfg.objective,
                 pipeline_depth=cfg.pipeline_depth)
    return p.train(data, steps=cfg.total_steps, lr=cfg.lr,
                   resched_every=cfg.resched_every, ema=cfg.ema,
                   seed=cfg.seed, worker_slowdown=worker_slowdown, log=log)
