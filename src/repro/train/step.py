"""Train-step builders for the LM runtime.

Two gradient-sync modes:

* ``hier_sync=False`` — classic synchronous data parallelism: the batch
  is sharded over ``(pod, data)`` and XLA's SPMD partitioner emits the
  full cross-replica all-reduce (this is the paper's "horizontal
  training" baseline, Fig. 1a, at pod scale).
* ``hier_sync=True`` — HierTrain hybrid parallelism over the pod axis:
  ``jax.shard_map`` keeps ``pod`` manual (each pod computes gradients on
  its own batch shard, auto-sharded over ``data``/``model`` inside), and
  the cross-pod reduction is the *tiered* sync — frontend tiers pmean at
  full width over the DCN, backend (parameter-heavy) tiers cross int8-
  quantized.  Intra-pod ICI reductions stay automatic, exactly the
  paper's cheap-WLAN assumption.

Microbatching (gradient accumulation) reshapes the batch to
``[k, B/k, ...]`` and lax.scans the grad computation with an f32
accumulator — per-chip activation memory drops k-fold while the HLO
stays one fused loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distrib import compat
from repro.distrib.tiered_sync import TierAssignment, tiered_grad_sync
from repro.optim.optimizers import Optimizer

Tree = Any
TrainState = Dict[str, Tree]        # {"params": ..., "opt": ...}


def init_state(model, optimizer: Optimizer, key: jax.Array) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params)}


def _microbatched_grads(loss_fn: Callable, params: Tree, batch: Tree,
                        microbatches: int) -> Tuple[jax.Array, Tree]:
    if microbatches <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    from repro.models.lm.common import shard_hint

    def resh(x):
        x = x.reshape((microbatches, x.shape[0] // microbatches)
                      + x.shape[1:])
        # keep the per-microbatch batch dim on the DP axes — without this
        # XLA is free to re-shard onto the sequence dim and store
        # full-batch residuals (measured 8x per-device activation memory).
        return shard_hint(x, None, ("pod", "data"),
                          *([None] * (x.ndim - 2)))

    mb = jax.tree.map(resh, batch)

    def body(carry, b):
        loss_acc, grad_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, b)
        grad_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
        return (loss_acc + loss, grad_acc), None

    # init the accumulator *from* the params so it inherits their sharding
    # (a bare zeros() would let XLA replicate ~GBs of f32 per device).
    zeros = jax.tree.map(
        lambda p: (p * 0).astype(jnp.float32), params)
    carry0 = (jnp.zeros((), jnp.float32), zeros)
    (loss, grads), _ = jax.lax.scan(body, carry0, mb)
    inv = 1.0 / microbatches
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def make_train_step(model, optimizer: Optimizer, *,
                    microbatches: int = 1,
                    hier_sync: bool = False,
                    tiers: Optional[TierAssignment] = None,
                    donate: bool = True) -> Callable:
    """Returns ``train_step(state, batch, key) -> (state, metrics)``.

    ``hier_sync`` requires a mesh with a ``pod`` axis in scope at lower
    time; ``tiers=None`` under hier_sync is the paper-faithful variant
    (all tiers full-width over the pod axis — still manual, so the DCN
    traffic is explicit in the HLO rather than fused into one global
    all-reduce).
    """
    loss_fn = model.loss_fn

    def _grads(params, batch):
        return _microbatched_grads(loss_fn, params, batch, microbatches)

    def plain_step(state: TrainState, batch: Tree, key: jax.Array):
        loss, grads = _grads(state["params"], batch)
        params, opt, gnorm = optimizer.update(state["params"], grads,
                                              state["opt"])
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt["step"]}
        return {"params": params, "opt": opt}, metrics

    def hier_step(state: TrainState, batch: Tree, key: jax.Array):
        def per_pod(params, b, k):
            k = jax.random.fold_in(k, jax.lax.axis_index("pod"))
            loss, grads = _grads(params, b)
            grads = tiered_grad_sync(grads, tiers, k, axis="pod")
            return jax.lax.pmean(loss, "pod"), grads

        # check_vma=False: the model body is full of scans whose carries
        # start as unvarying constants (loss chunks, GLA states, grad
        # accumulators) — strict varying-manual-axis typing would need a
        # pcast at every one of them.
        loss, grads = compat.shard_map(
            per_pod,
            in_specs=(P(), P("pod"), P()),
            out_specs=(P(), P()),
            axis_names={"pod"},
            check_vma=False,
        )(state["params"], batch, key)
        params, opt, gnorm = optimizer.update(state["params"], grads,
                                              state["opt"])
        metrics = {"loss": loss, "grad_norm": gnorm, "step": opt["step"]}
        return {"params": params, "opt": opt}, metrics

    return hier_step if hier_sync else plain_step
