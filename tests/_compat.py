"""Optional-import shim for :mod:`hypothesis`.

The property tests are written against the real hypothesis API, but the
library is not part of the baked container image.  Importing from here
instead of from ``hypothesis`` keeps the suite collectable everywhere:

* hypothesis installed  -> re-export the real ``given``/``settings``/``st``.
* hypothesis missing    -> a minimal deterministic fallback that draws
  ``max_examples`` pseudo-random examples per test from a fixed seed.  It
  covers exactly the strategy surface the suite uses (``integers``,
  ``floats``, ``sampled_from``, ``lists`` and ``.map``) — no shrinking, no
  database, but the invariants still get exercised on a clean environment.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # fallback mode
    import random
    from typing import Any, Callable, List

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function plus hypothesis' ``.map`` combinator."""

        def __init__(self, draw: Callable[[random.Random], Any]) -> None:
            self._draw = draw

        def draw(self, rng: random.Random) -> Any:
            return self._draw(rng)

        def map(self, fn: Callable[[Any], Any]) -> "_Strategy":
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class st:  # noqa: N801 - mimics ``hypothesis.strategies`` module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            pool = list(elements)
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng: random.Random) -> List[Any]:
                size = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(size)]
            return _Strategy(draw)

    def settings(max_examples: int = 20, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
        def deco(fn):
            # No functools.wraps: pytest must see a 0-arg signature, not the
            # strategy parameters (it would look for fixtures named like
            # them).  Real hypothesis strips them the same way.
            def wrapper():
                n = getattr(wrapper, "_compat_max_examples", 20)
                for example in range(n):
                    rng = random.Random(0x5EED + 7919 * example)
                    drawn = [s.draw(rng) for s in arg_strategies]
                    kdrawn = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    fn(*drawn, **kdrawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
