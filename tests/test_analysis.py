"""Static-analysis suite (ISSUE 10): per-checker true-positive and
clean-negative fixtures, disable-comment semantics, the baseline
ratchet, the repo self-lint smoke, and the ``benchmarks.run --section``
error path.

Fixtures are inline source snippets linted through
``repro.analysis.lint.lint_file`` — the same entry point the runner
uses — so every test exercises the real scoping-independent checker
path.  The self-lint tests are the acceptance criterion: the committed
tree plus ``analysis/baseline.json`` must be exactly clean, and
deleting any committed suppression must flip the gate red (proven here
by re-linting repo files with their disables stripped).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.base import CODES, Finding, SourceFile
from repro.analysis.lint import (BaselineError, apply_baseline,
                                 lint_file, load_baseline, run)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def codes_of(source: str, path: str = "src/repro/core/wire.py"):
    """Active finding codes of an inline fixture.  The default path
    puts the snippet in every checker's scope (units included)."""
    active, _ = lint_file(SourceFile(path, textwrap.dedent(source)))
    return [f.code for f in active]


def kernel_codes(source: str):
    return codes_of(source, path="src/repro/kernels/fixture.py")


# ---------------------------------------------------------------------------
# RA1xx — jit hygiene
# ---------------------------------------------------------------------------

class TestJitHygiene:
    def test_jit_in_loop_flags(self):
        src = """
            import jax
            def f(xs):
                for x in xs:
                    g = jax.jit(lambda v: v + 1)
                    g(x)
        """
        assert "RA101" in codes_of(src)

    def test_jit_in_while_flags(self):
        src = """
            import jax
            def f(x):
                while x < 3:
                    x = jax.jit(lambda v: v + 1)(x)
        """
        assert "RA101" in codes_of(src)

    def test_jit_hoisted_clean(self):
        src = """
            import jax
            g = jax.jit(lambda v: v + 1)
            def f(xs):
                for x in xs:
                    g(x)
        """
        assert codes_of(src) == []

    def test_closure_factory_in_loop_clean(self):
        # the sanctioned hybrid_step shape: jit lives in a nested make()
        # whose *definition* sits in a loop — each call is a fresh frame.
        src = """
            import jax
            def outer(models):
                steps = []
                for m in models:
                    def make(m=m):
                        return jax.jit(lambda p: p)
                    steps.append(make)
                return steps
        """
        assert codes_of(src) == []

    def test_immediate_call_flags(self):
        src = """
            import jax
            def f(params, x):
                return jax.jit(lambda p, v: p @ v)(params, x)
        """
        assert "RA102" in codes_of(src)

    def test_immediate_call_module_level_clean(self):
        # module-level immediate call runs once at import: not RA102.
        src = """
            import jax
            Y = jax.jit(lambda v: v + 1)(0.0)
        """
        assert codes_of(src) == []

    def test_id_keyed_plain_dict_flags(self):
        src = """
            _CACHE = {}
            def get(model):
                _CACHE[id(model)] = model
        """
        assert "RA103" in codes_of(src)

    def test_id_keyed_dict_call_flags(self):
        src = """
            _CACHE = dict()
            def get(model, fn):
                _CACHE[("step", id(model))] = fn
        """
        assert "RA103" in codes_of(src)

    def test_bounded_cache_object_clean(self):
        # stores via a method (the _JitStepCache pattern) don't match.
        src = """
            from repro.core.hybrid_step import _JitStepCache
            _CACHE = _JitStepCache()
            def get(model, fn):
                _CACHE.put(("step", id(model)), model, fn)
        """
        assert codes_of(src) == []

    def test_non_id_dict_clean(self):
        src = """
            _BY_NAME = {}
            def put(name, fn):
                _BY_NAME[name] = fn
        """
        assert codes_of(src) == []

    def test_nondet_in_jitted_flags(self):
        src = """
            import jax, time
            def step(x):
                return x + time.perf_counter()
            step = jax.jit(step)
        """
        assert "RA104" in codes_of(src)

    def test_nondet_transitive_flags(self):
        src = """
            import jax, random
            def noise():
                return random.random()
            @jax.jit
            def step(x):
                return x + noise()
        """
        assert "RA104" in codes_of(src)

    def test_set_iteration_in_jitted_flags(self):
        src = """
            import jax
            @jax.jit
            def step(x):
                for k in {"a", "b"}:
                    x = x + 1
                return x
        """
        assert "RA104" in codes_of(src)

    def test_nondet_outside_jit_clean(self):
        src = """
            import time
            def wall_clock():
                return time.perf_counter()
        """
        assert codes_of(src) == []

    def test_sorted_iteration_in_jitted_clean(self):
        src = """
            import jax
            @jax.jit
            def step(x):
                for k in sorted({"a", "b"}):
                    x = x + 1
                return x
        """
        assert codes_of(src) == []

    def test_unhashable_static_arg_flags(self):
        src = """
            import jax
            f = jax.jit(lambda x, opts: x, static_argnums=1)
            def g(x):
                return f(x, [1, 2])
        """
        assert "RA105" in codes_of(src)

    def test_unhashable_static_argname_flags(self):
        src = """
            import jax
            f = jax.jit(lambda x, opts=None: x, static_argnames="opts")
            def g(x):
                return f(x, opts={"a": 1})
        """
        assert "RA105" in codes_of(src)

    def test_tuple_static_arg_clean(self):
        src = """
            import jax
            f = jax.jit(lambda x, opts: x, static_argnums=1)
            def g(x):
                return f(x, (1, 2))
        """
        assert codes_of(src) == []

    def test_unhashable_dynamic_arg_clean(self):
        src = """
            import jax
            f = jax.jit(lambda x, y: x)
            def g(x):
                return f(x, [1, 2])
        """
        assert codes_of(src) == []


# ---------------------------------------------------------------------------
# RA201 — donation safety
# ---------------------------------------------------------------------------

class TestDonation:
    def test_read_after_donation_flags(self):
        src = """
            import jax
            step = jax.jit(lambda p, x: (p, 0.0), donate_argnums=0)
            def train(params, x):
                new_params, loss = step(params, x)
                return params
        """
        assert "RA201" in codes_of(src)

    def test_read_after_decorated_donation_flags(self):
        src = """
            import jax
            from functools import partial
            @partial(jax.jit, donate_argnums=(0,))
            def step(p, x):
                return p, 0.0
            def train(params, x):
                out = step(params, x)
                print(params)
        """
        assert "RA201" in codes_of(src)

    def test_rebind_clears_taint(self):
        # the canonical quickstart loop: params is rebound by the call.
        src = """
            import jax
            step = jax.jit(lambda p, x: (p, 0.0), donate_argnums=0)
            def train(params, xs):
                for x in xs:
                    params, loss = step(params, x)
                return params
        """
        assert codes_of(src) == []

    def test_copy_before_donation_clean(self):
        src = """
            import jax
            import jax.numpy as jnp
            step = jax.jit(lambda p, x: (p, 0.0), donate_argnums=0)
            def train(params, x):
                ref = jax.tree.map(jnp.array, params)
                out, loss = step(params, x)
                return ref
        """
        assert codes_of(src) == []

    def test_no_donation_clean(self):
        src = """
            import jax
            step = jax.jit(lambda p, x: (p, 0.0))
            def train(params, x):
                out = step(params, x)
                return params
        """
        assert codes_of(src) == []


# ---------------------------------------------------------------------------
# RA3xx — units lint
# ---------------------------------------------------------------------------

class TestUnits:
    def test_bytes_plus_elems_flags(self):
        assert "RA301" in codes_of("""
            def f(act_bytes, act_elems):
                return act_bytes + act_elems
        """)

    def test_mb_vs_bytes_compare_flags(self):
        assert "RA301" in codes_of("""
            def f(limit_mb, used_bytes):
                return used_bytes > limit_mb
        """)

    def test_division_is_conversion_clean(self):
        assert codes_of("""
            def t_up(act_mb, uplink_mbps):
                return act_mb / uplink_mbps
        """) == []

    def test_conversion_call_boundary_clean(self):
        # callee suffix wins: int8_wire_bytes(elems) IS bytes.
        assert codes_of("""
            def int8_wire_bytes(elems):
                return elems / 1.0 + 4.0
            def f(act_elems, hdr_bytes):
                return int8_wire_bytes(act_elems) + hdr_bytes
        """) == []

    def test_pr7_regression_shape_kwarg_flags(self):
        # the PR 7 bug shape: a byte count handed to an elems parameter.
        assert "RA302" in codes_of("""
            def resolve(act_elems):
                return act_elems
            def f(meta_bytes):
                return resolve(act_elems=meta_bytes)
        """)

    def test_pr7_regression_positional_flags(self):
        assert "RA302" in codes_of("""
            def resolve(act_elems, ratio):
                return act_elems * ratio
            def f(meta_bytes):
                return resolve(meta_bytes, 0.5)
        """)

    def test_assignment_mix_flags(self):
        assert "RA302" in codes_of("""
            def f(act_bytes):
                act_elems = act_bytes
                return act_elems
        """)

    def test_return_mismatch_flags(self):
        assert "RA302" in codes_of("""
            def leaf_bytes(act_elems):
                return act_elems
        """)

    def test_same_family_clean(self):
        assert codes_of("""
            def f(act_bytes, grad_bytes):
                total_bytes = act_bytes + grad_bytes
                return total_bytes
        """) == []

    def test_per_names_are_rates_clean(self):
        assert codes_of("""
            def f(bytes_per_elem, act_elems):
                act_bytes = bytes_per_elem * act_elems
                return act_bytes
        """) == []

    def test_out_of_scope_path_not_linted(self):
        src = """
            def f(act_bytes, act_elems):
                return act_bytes + act_elems
        """
        assert codes_of(src, path="src/repro/launch/other.py") == []


# ---------------------------------------------------------------------------
# RA401 — static deprecation firewall
# ---------------------------------------------------------------------------

class TestShimFirewall:
    def test_from_import_flags(self):
        assert "RA401" in codes_of("""
            from repro.core.scheduler import solve
        """)

    def test_attribute_call_flags(self):
        assert "RA401" in codes_of("""
            from repro.core import cost_model
            def f(profile, sched):
                return cost_model.t_total(profile, sched)
        """)

    def test_full_path_call_flags(self):
        assert "RA401" in codes_of("""
            import repro.core.simulator
            def f(plan):
                return repro.core.simulator.simulate_iteration(plan)
        """)

    def test_canonical_api_clean(self):
        assert codes_of("""
            from repro.api import plan
            def f(profile):
                return plan(profile)
        """) == []

    def test_same_name_other_module_clean(self):
        # `solve` from anywhere else is not the shim.
        assert codes_of("""
            from scipy.optimize import linprog as solve
            def f(c):
                return solve(c)
        """) == []

    def test_tests_out_of_scope(self):
        src = """
            from repro.core.scheduler import solve
        """
        assert codes_of(src, path="tests/test_scheduler_round.py") == []


# ---------------------------------------------------------------------------
# RA5xx — Pallas kernel checks
# ---------------------------------------------------------------------------

class TestPallas:
    def test_grid_arity_mismatch_flags(self):
        assert "RA501" in kernel_codes("""
            import jax
            from jax.experimental import pallas as pl
            def _k_kernel(x_ref, o_ref):
                o_ref[...] = x_ref[...]
            def call(x):
                return pl.pallas_call(
                    _k_kernel,
                    grid=(4, 4),
                    in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                    out_shape=jax.ShapeDtypeStruct((32, 32), x.dtype),
                )(x)
        """)

    def test_gridspec_host_flags(self):
        # grid/specs nested under grid_spec=pl.GridSpec are still seen.
        assert "RA501" in kernel_codes("""
            import jax
            from jax.experimental import pallas as pl
            def _k_kernel(x_ref, o_ref):
                o_ref[...] = x_ref[...]
            def call(x):
                return pl.pallas_call(
                    _k_kernel,
                    grid_spec=pl.GridSpec(
                        grid=(4,),
                        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
                        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
                    ),
                    out_shape=jax.ShapeDtypeStruct((32, 32), x.dtype),
                )(x)
        """)

    def test_block_rank_vs_return_arity_flags(self):
        assert "RA502" in kernel_codes("""
            import jax
            from jax.experimental import pallas as pl
            def _k_kernel(x_ref, o_ref):
                o_ref[...] = x_ref[...]
            def call(x):
                return pl.pallas_call(
                    _k_kernel,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 8), lambda i: (i,)),
                    out_shape=jax.ShapeDtypeStruct((32, 32), x.dtype),
                )(x)
        """)

    def test_block_not_dividing_array_flags(self):
        # 48 % 20 != 0, both resolvable through the tile constant.
        assert "RA502" in kernel_codes("""
            import jax
            from jax.experimental import pallas as pl
            BLOCK = 20
            def _k_kernel(x_ref, o_ref):
                o_ref[...] = x_ref[...]
            def call(x):
                return pl.pallas_call(
                    _k_kernel,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((BLOCK, 8), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((BLOCK, 8), lambda i: (i, 0)),
                    out_shape=jax.ShapeDtypeStruct((48, 8), x.dtype),
                )(x)
        """)

    def test_consistent_call_clean(self):
        assert kernel_codes("""
            import jax
            from jax.experimental import pallas as pl
            BLOCK = 8
            def _k_kernel(x_ref, o_ref):
                o_ref[...] = x_ref[...].astype(jax.numpy.float32)
            def call(x):
                return pl.pallas_call(
                    _k_kernel,
                    grid=(4, 4),
                    in_specs=[pl.BlockSpec((BLOCK, BLOCK),
                                           lambda i, j: (i, j))],
                    out_specs=pl.BlockSpec((BLOCK, BLOCK),
                                           lambda i, j: (i, j)),
                    out_shape=jax.ShapeDtypeStruct((32, 32), x.dtype),
                )(x)
        """) == []

    def test_unresolvable_dims_not_guessed(self):
        # runtime-shaped dims: the divisibility check must stay silent.
        assert kernel_codes("""
            import jax
            from jax.experimental import pallas as pl
            def _k_kernel(x_ref, o_ref):
                o_ref[...] = x_ref[...]
            def call(x, bm):
                return pl.pallas_call(
                    _k_kernel,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((bm, 8), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((bm, 8), lambda i: (i, 0)),
                    out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                )(x)
        """) == []

    def test_raw_ref_matmul_flags(self):
        assert "RA503" in kernel_codes("""
            import jax.numpy as jnp
            from jax.experimental import pallas as pl
            def _mm_kernel(a_ref, b_ref, o_ref):
                o_ref[...] = jnp.dot(a_ref[...], b_ref[...])
        """)

    def test_bf16_cast_matmul_flags(self):
        assert "RA503" in kernel_codes("""
            import jax.numpy as jnp
            from jax.experimental import pallas as pl
            def _mm_kernel(a_ref, b_ref, o_ref):
                a = a_ref[...].astype(jnp.bfloat16)
                o_ref[...] = a @ b_ref[...].astype(jnp.float32)
        """)

    def test_f32_cast_matmul_clean(self):
        assert kernel_codes("""
            import jax.numpy as jnp
            from jax.experimental import pallas as pl
            def _mm_kernel(a_ref, b_ref, o_ref):
                a = a_ref[...].astype(jnp.float32)
                b = b_ref[...].astype(jnp.float32)
                o_ref[...] = jnp.dot(a, b)
        """) == []

    def test_preferred_element_type_clean(self):
        assert kernel_codes("""
            import jax.numpy as jnp
            from jax.experimental import pallas as pl
            def _mm_kernel(a_ref, b_ref, o_ref):
                o_ref[...] = jax.lax.dot_general(
                    a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
        """) == []

    def test_repo_kernels_lint_clean(self):
        # the three real kernels must pass their own structural checks.
        for name in ("flash_attention", "gla_scan", "int8_quant"):
            path = f"src/repro/kernels/{name}.py"
            with open(os.path.join(ROOT, path), encoding="utf-8") as f:
                active, _ = lint_file(SourceFile(path, f.read()))
            assert active == [], f"{path}: {[f.format() for f in active]}"


# ---------------------------------------------------------------------------
# Disable comments, baseline, ratchet
# ---------------------------------------------------------------------------

class TestSuppression:
    def test_disable_with_reason_suppresses(self):
        src = """
            def f(act_bytes, act_elems):
                return act_bytes + act_elems  # repro-lint: disable=RA301 codec boundary
        """
        assert codes_of(src) == []

    def test_disable_next_suppresses(self):
        src = """
            def f(act_bytes, act_elems):
                # repro-lint: disable-next=RA301 codec boundary
                return act_bytes + act_elems
        """
        assert codes_of(src) == []

    def test_disable_without_reason_is_finding(self):
        src = """
            def f(act_bytes, act_elems):
                return act_bytes + act_elems  # repro-lint: disable=RA301
        """
        assert "RA001" in codes_of(src)

    def test_disable_unknown_code_is_finding(self):
        src = """
            x = 1  # repro-lint: disable=RA999 because
        """
        assert "RA001" in codes_of(src)

    def test_disable_wrong_code_does_not_suppress(self):
        src = """
            def f(act_bytes, act_elems):
                return act_bytes + act_elems  # repro-lint: disable=RA302 wrong code
        """
        assert "RA301" in codes_of(src)

    def test_disable_in_string_literal_ignored(self):
        # only real COMMENT tokens disable; strings can't fake it.
        src = '''
            MSG = "repro-lint: disable=RA301 not a comment"
            def f(act_bytes, act_elems):
                return act_bytes + act_elems
        '''
        assert "RA301" in codes_of(src)

    def test_syntax_error_is_ra000(self):
        assert codes_of("def f(:\n") == ["RA000"]

    def test_baseline_requires_reason(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"entries": [
            {"code": "RA301", "path": "x.py", "message": "m"}]}))
        with pytest.raises(BaselineError):
            load_baseline(str(p))

    def test_baseline_absorbs_and_ratchets(self):
        f1 = Finding("RA301", "a.py", 3, 0, "mix one")
        entries = [
            {"code": "RA301", "path": "a.py", "message": "mix one",
             "reason": "r", "count": 1},
            {"code": "RA301", "path": "b.py", "message": "gone",
             "reason": "r", "count": 1},
        ]
        new, baselined, stale = apply_baseline([f1], entries)
        assert new == [] and baselined == [f1]
        assert [e["path"] for e in stale] == ["b.py"]

    def test_baseline_count_budget(self):
        fs = [Finding("RA301", "a.py", i, 0, "mix") for i in (1, 2, 3)]
        entries = [{"code": "RA301", "path": "a.py", "message": "mix",
                    "reason": "r", "count": 2}]
        new, baselined, stale = apply_baseline(fs, entries)
        assert len(baselined) == 2 and len(new) == 1 and not stale


# ---------------------------------------------------------------------------
# Self-lint smoke + the committed-suppression acceptance criterion
# ---------------------------------------------------------------------------

class TestSelfLint:
    def test_repo_is_clean_under_baseline(self):
        report = run(ROOT, baseline_path="analysis/baseline.json",
                     check_baseline=True)
        assert report["ok"], json.dumps(report["new"]
                                        + report["stale_baseline"],
                                        indent=2)
        # the triage left real accepted findings — the gate is live,
        # not vacuously green.
        assert report["summary"]["disabled"] >= 1
        assert report["summary"]["baselined"] >= 1

    def test_analysis_package_lints_itself(self):
        report = run(ROOT, paths=[os.path.join(
            ROOT, "src/repro/analysis")], baseline_path=None)
        assert report["new"] == [], json.dumps(report["new"], indent=2)

    @pytest.mark.parametrize("rel", [
        "src/repro/core/profiler.py",
        "src/repro/core/wire.py",
        "src/repro/models/lm/layerstack.py",
    ])
    def test_deleting_a_committed_disable_turns_red(self, rel):
        # strip the inline disables from the committed file: the
        # finding each one suppresses must come back.
        with open(os.path.join(ROOT, rel), encoding="utf-8") as f:
            text = f.read()
        assert "repro-lint: disable" in text, f"{rel} lost its disables"
        stripped = "\n".join(
            line.split("# repro-lint:")[0].rstrip()
            if "# repro-lint:" in line else line
            for line in text.splitlines())
        active, _ = lint_file(SourceFile(rel, stripped))
        assert active, f"{rel}: stripping disables found nothing"

    def test_cli_check_baseline_exits_zero(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis.lint",
             "--check-baseline", "--json", "-"],
            cwd=ROOT, env=env, capture_output=True, text=True,
            timeout=120)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        report = json.loads(proc.stdout)
        assert report["ok"] and report["summary"]["new"] == 0

    def test_list_checks_catalog(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis.lint",
             "--list-checks"], cwd=ROOT, env=env, capture_output=True,
            text=True, timeout=60)
        assert proc.returncode == 0
        for code in CODES:
            assert code in proc.stdout


# ---------------------------------------------------------------------------
# benchmarks.run --section error path (satellite bugfix)
# ---------------------------------------------------------------------------

class TestSectionValidation:
    def test_programmatic_unknown_section_lists_names(self):
        from benchmarks.run import _SECTIONS, run_sections
        with pytest.raises(ValueError) as ei:
            run_sections("not_a_section")
        msg = str(ei.value)
        for name in _SECTIONS:
            assert name in msg

    def test_json_keys_validates_too(self):
        from benchmarks.run import _json_keys
        with pytest.raises(ValueError, match="valid sections"):
            _json_keys("nope")

    def test_cli_unknown_section_lists_names(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        env.setdefault("JAX_PLATFORMS", "cpu")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--section",
             "not_a_section"], cwd=ROOT, env=env, capture_output=True,
            text=True, timeout=120)
        assert proc.returncode == 2
        assert "valid sections" in proc.stderr
        assert "wire" in proc.stderr and "table2" in proc.stderr

    def test_known_section_still_accepted(self):
        from benchmarks.run import validate_section
        assert validate_section("wire") == "wire"
