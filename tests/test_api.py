"""Facade equivalence suite (DESIGN.md §9): the ``Fleet``/``Plan`` front
door vs the legacy forked surfaces.

Four claim families:

* **Old vs new, bit-identical** — every deprecated entry point
  (``solve``/``solve_multi``, ``t_total*``, ``simulate_iteration*``,
  ``run_*_hier_loop``) returns exactly what the facade returns, and the
  facade returns exactly what the retained topology-native oracles
  return — schedules, costs, periods, DES traces and *trained params* —
  at M = 1 and M >= 2, across the Table II profiles and one LM family.
* **Cross-topology M=1** — a star-native plan at M = 1 is bit-identical
  to the triple-native plan for the latency objective (the deep DESIGN.md
  §6 invariant, now asserted *through the facade*).
* **Deprecation contract** — each shim emits one DeprecationWarning
  naming the exact ``repro.api`` replacement; the facade itself emits
  none (the ``pytest.ini`` filter turns in-repo uses into errors).
* **Surface** — ``repro`` / ``repro.core`` export exactly
  ``Fleet``/``Plan``/``plan``/``as_layerstack``; ``Plan.explain()`` is
  snapshot-stable; the ``python -m repro.api --explain`` CLI runs.
"""
import warnings

import numpy as np
import pytest

import repro
import repro.core
from repro.api import Fleet, Plan, plan
from repro.core import cost_model, pipeline, scheduler, simulator
from repro.core.cost_model import (HierProfile, MultiProfile, MultiSchedule,
                                   Network, Schedule, StarNetwork, WIDX)
from repro.core.fleet import (FLEET_SLOWDOWNS, FLEET_UPLINK_MBPS,
                              LM_FLEET_SLOWDOWNS, LM_FLEET_UPLINK_MBPS)

MBPS = 1e6 / 8.0

TABLE2_LAYERS = {"lenet5": 5, "alexnet": 8, "vgg16": 16}


def synthetic_profile(n: int) -> HierProfile:
    rng = np.random.default_rng(0)
    speed = np.array([[1.0], [0.12], [0.01]])
    base = rng.uniform(5e-3, 5e-2, (1, n))
    return HierProfile(
        layer_names=tuple(f"l{i}" for i in range(n)),
        L_f=base * speed, L_b=2 * base * speed, L_u=0.5 * base * speed,
        MP=rng.uniform(1e5, 5e7, n), MO=rng.uniform(1e4, 2e6, n),
        sample_bytes=3073.0)


def triple_fleet(n: int, ec_mbps: float = 3.0) -> Fleet:
    return Fleet.from_profile(
        synthetic_profile(n), Network(bw_de=5.0 * MBPS,
                                      bw_ec=ec_mbps * MBPS))


def star_fleet_m1(n: int, ec_mbps: float = 3.0) -> Fleet:
    return Fleet.from_profile(
        MultiProfile.from_hier(synthetic_profile(n), (1.0,)),
        StarNetwork.from_network(Network(bw_de=5.0 * MBPS,
                                         bw_ec=ec_mbps * MBPS), 1))


def star_fleet(n: int, scales, seed: int = 0) -> Fleet:
    rng = np.random.default_rng(seed)
    m = len(scales)
    return Fleet.from_profile(
        MultiProfile.from_hier(synthetic_profile(n), scales),
        StarNetwork(bw_de=rng.uniform(2.0, 5.0, m) * MBPS,
                    bw_ec=3.0 * MBPS))


def _tiny_mlp():
    from repro.models.cnn import DenseSpec, LayeredModel
    specs = tuple(DenseSpec(f"fc{i}", 16) for i in range(4)) + \
        (DenseSpec("out", 5, relu=False),)
    return LayeredModel("tiny_mlp", specs, (8,), 5)


# ---------------------------------------------------------------------------
# Schedules and costs: facade == topology-native oracles, both topologies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,n", sorted(TABLE2_LAYERS.items()))
@pytest.mark.parametrize("backend", ["batched", "reference"])
def test_plan_bit_identical_to_oracles_table2(name, n, backend):
    """plan() on a triple fleet IS the 3-worker engine, and on a star
    fleet at M=1 it is bit-identical to it — through the facade."""
    prof = synthetic_profile(n)
    net = Network(bw_de=5.0 * MBPS, bw_ec=3.0 * MBPS)
    oracle = scheduler._solve_3w(prof, net, 64, backend=backend)
    p3 = plan(None, triple_fleet(n), 64, backend=backend)
    assert isinstance(p3.schedule, Schedule)
    assert p3.schedule == oracle.schedule
    assert p3.t_total == oracle.t_total
    assert p3.result.n_candidates == oracle.n_candidates
    assert p3.result.n_pruned == oracle.n_pruned
    ps = plan(None, star_fleet_m1(n), 64, backend=backend)
    assert isinstance(ps.schedule, MultiSchedule)
    assert ps.schedule.to_schedule() == oracle.schedule
    assert ps.t_total == oracle.t_total
    assert ps.result.n_candidates == oracle.n_candidates
    if backend == "batched":   # the scalar 3-worker oracle never prunes
        assert ps.result.n_pruned == oracle.n_pruned
    # the unified view and the describe strings collapse too
    assert p3.multi_schedule == ps.schedule
    assert p3.schedule.describe() == ps.schedule.describe()


def test_solve_shims_bit_identical_and_warn():
    prof = synthetic_profile(6)
    net = Network(bw_de=5.0 * MBPS, bw_ec=3.0 * MBPS)
    p = plan(None, triple_fleet(6), 48)
    with pytest.warns(DeprecationWarning, match=r"repro\.api\.plan"):
        old = scheduler.solve(prof, net, 48)
    assert isinstance(old.schedule, Schedule)
    assert old.schedule == p.schedule and old.t_total == p.t_total
    mprof = MultiProfile.from_hier(prof, (1.0, 1.7))
    mnet = StarNetwork(bw_de=np.array([4.0, 3.0]) * MBPS, bw_ec=3.0 * MBPS)
    pm = plan(None, Fleet.from_profile(mprof, mnet), 48)
    with pytest.warns(DeprecationWarning, match=r"repro\.api\.plan"):
        old_m = scheduler.solve_multi(mprof, mnet, 48)
    assert old_m.schedule == pm.schedule
    assert old_m.t_total == pm.t_total
    assert old_m.n_lp_refine == pm.result.n_lp_refine


def test_solve_shim_exotic_args_keep_working():
    """origin/workers corners the facade does not model fall back to the
    retained 3-worker engine (bit-identical to the pre-facade code)."""
    prof = synthetic_profile(4)
    net = Network(bw_de=5.0 * MBPS, bw_ec=3.0 * MBPS)
    with pytest.warns(DeprecationWarning):
        r = scheduler.solve(prof, net, 16, origin="edge")
    assert r.schedule == scheduler._solve_3w(prof, net, 16,
                                             origin="edge").schedule
    with pytest.raises(ValueError):
        with pytest.warns(DeprecationWarning):
            scheduler.solve(prof, net, 8, backend="cplex")


def test_t_total_shims_collapse_onto_multi_bitwise():
    """The deprecated 3-worker cost entry points now evaluate the star
    model — bit-identical to the retained 3-worker oracle on every
    mapping/cut (the §6 invariant exercised through the shims)."""
    import itertools
    prof = synthetic_profile(5)
    net = Network(bw_de=5.0 * MBPS, bw_ec=3.0 * MBPS)
    rng = np.random.default_rng(3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for wo, ws, wl in itertools.permutations(
                ("device", "edge", "cloud"), 3):
            ms, ml = sorted(rng.integers(0, 6, 2))
            b = rng.multinomial(32, [1 / 3] * 3)
            bo, bs, bl = (int(v) for v in b)
            if ms == 0:
                bo, bs = bo + bs, 0
            if ml == 0:
                bo, bl = bo + bl, 0
            sched = Schedule(wo, ws, wl, int(ms), int(ml), bo, bs, bl)
            ref = cost_model._t_total(prof, net, sched)
            got = cost_model.t_total(prof, net, sched)
            assert got.total == ref.total
            assert got.t_f1 == ref.t_f1 and got.t_update == ref.t_update
            tb = cost_model.t_total_batch(
                prof, net, np.array([WIDX[wo]]), np.array([WIDX[ws]]),
                np.array([WIDX[wl]]), np.array([int(ms)]),
                np.array([int(ml)]), np.array([[bo, bs, bl]]))
            assert tb[0] == ref.total
        # degenerate all-on-one schedules fall back to the 3-worker body
        degen = Schedule("edge", "edge", "edge", 0, 0, 16, 0, 0)
        assert cost_model.t_total(prof, net, degen).total == \
            cost_model._t_total(prof, net, degen).total
        # t_total_multi shim == retained engine
        mprof = MultiProfile.from_hier(prof, (1.0, 1.5))
        mnet = StarNetwork(bw_de=np.array([4.0, 3.0]) * MBPS,
                           bw_ec=2.0 * MBPS)
        msched = MultiSchedule("edge", "cloud",
                               mprof.device_names, (1, 2), 3, 10, (8, 6), 8)
        assert cost_model.t_total_multi(mprof, mnet, msched).total == \
            cost_model._t_total_multi(mprof, mnet, msched).total


# ---------------------------------------------------------------------------
# Simulated traces and periods
# ---------------------------------------------------------------------------

def test_simulate_matches_native_des_and_shims():
    p3 = plan(None, triple_fleet(5), 64)
    want3 = simulator._simulate_iteration(p3.profile, p3.network,
                                          p3.schedule)
    assert p3.simulate() == want3
    assert p3.simulate(K=4) == simulator.simulate_pipeline(
        p3.profile, p3.network, p3.schedule, 4)
    with pytest.warns(DeprecationWarning, match=r"Plan\.simulate|simulate"):
        assert simulator.simulate_iteration(
            p3.profile, p3.network, p3.schedule) == want3

    pm = plan(None, star_fleet(5, (1.0, 1.6)), 48)
    want_m = simulator._simulate_iteration_multi(pm.profile, pm.network,
                                                 pm.schedule)
    assert pm.simulate() == want_m
    assert pm.simulate(K=3) == simulator.simulate_pipeline(
        pm.profile, pm.network, pm.schedule, 3)
    with pytest.warns(DeprecationWarning):
        assert simulator.simulate_iteration_multi(
            pm.profile, pm.network, pm.schedule) == want_m


def test_t_period_and_pipeline_time_native():
    p3 = plan(None, triple_fleet(5), 64, pipeline_depth=8)
    assert p3.t_period == pipeline.t_period(p3.profile, p3.network,
                                            p3.schedule)
    assert p3.pipeline_time() == pipeline.t_pipeline(
        p3.profile, p3.network, p3.schedule, 8)
    pm = plan(None, star_fleet(5, (1.0, 1.3)), 32)
    assert pm.t_period == pipeline.t_period_multi(pm.profile, pm.network,
                                                  pm.schedule)


def test_throughput_objective_through_facade():
    thr = plan(None, triple_fleet(6), 48, objective="throughput")
    want = scheduler._solve_3w(synthetic_profile(6),
                               Network(bw_de=5.0 * MBPS, bw_ec=3.0 * MBPS),
                               48, objective="throughput")
    assert thr.schedule == want.schedule
    assert thr.t_period == want.t_period
    lat = plan(None, triple_fleet(6), 48)
    assert thr.t_period <= lat.t_period


# ---------------------------------------------------------------------------
# Execution: step_fn and train — trained params bit-identical
# ---------------------------------------------------------------------------

def _img_data(model, B):
    from repro.data.pipeline import SyntheticImages
    return SyntheticImages(model.input_shape, model.num_classes, B, seed=0)


def _cnn_fleet(model, m=1, topology="auto"):
    from repro.core.profiler import analytic_profile, multi_analytic_profile
    if topology == "triple":
        return Fleet.from_profile(analytic_profile(model),
                                  Network(bw_de=4.0 * MBPS,
                                          bw_ec=2.0 * MBPS))
    prof = multi_analytic_profile(
        model, device_slowdowns=tuple(1.0 + 0.2 * i for i in range(m)))
    net = StarNetwork(bw_de=np.full(m, 4.0) * MBPS, bw_ec=2.0 * MBPS)
    return Fleet.from_profile(prof, net)


def test_step_fn_bit_identical_to_legacy_jitted_step():
    import jax
    import jax.numpy as jnp
    from repro.core.hybrid_step import jitted_hybrid_step, split_batch
    model = _tiny_mlp()
    p = plan(model, _cnn_fleet(model, topology="triple"), 16)
    sched = p.schedule
    data = _img_data(model, 16)
    b = data.batch(0)
    x, y = jnp.asarray(b["x"]), jnp.asarray(b["labels"])
    params = model.init(jax.random.PRNGKey(0))
    copy = lambda t: jax.tree.map(jnp.array, t)  # donated args need copies
    legacy = jitted_hybrid_step(model, sched.m_s, sched.m_l, 0.05)
    new_p, new_l = p.step_fn(lr=0.05)(copy(params), x, y)
    old_p, old_l = legacy(copy(params), split_batch(x, y, sched))
    assert float(new_l) == float(old_l)
    for a, b2 in zip(jax.tree.leaves(new_p), jax.tree.leaves(old_p)):
        assert (np.asarray(a) == np.asarray(b2)).all()


def test_trained_params_bit_identical_triple_vs_star_m1():
    """Plan.train at M=1 is bit-identical across topology engines —
    schedules, wall clock, losses AND trained parameters — including
    through a straggle-and-heal window that exercises the online
    re-scheduler."""
    import jax
    model = _tiny_mlp()

    def slowdown(step):
        return {"edge": 20.0} if 3 <= step < 6 else {}

    outs = []
    for topology in ("triple", "star"):
        out = plan(model, _cnn_fleet(model, topology=topology), 24).train(
            _img_data(model, 24), steps=8, lr=0.05, resched_every=3,
            worker_slowdown=slowdown)
        outs.append(out)
    a, b = outs
    assert a["wall"] == b["wall"]
    assert [h["loss"] for h in a["history"]] == \
        [h["loss"] for h in b["history"]]
    assert b["final_schedule"].to_schedule() == a["final_schedule"]
    for x, y in zip(jax.tree.leaves(a["params"]),
                    jax.tree.leaves(b["params"])):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_run_hier_loop_shims_route_through_facade():
    import jax
    model = _tiny_mlp()
    fleet = _cnn_fleet(model, topology="triple")
    want = plan(model, fleet, 16).train(_img_data(model, 16), steps=4,
                                        lr=0.05)
    from repro.train.loop import HierLoopConfig, run_hier_loop
    cfg = HierLoopConfig(total_steps=4, batch=16, lr=0.05)
    with pytest.warns(DeprecationWarning, match=r"\.train\(data"):
        old = run_hier_loop(cfg, model, fleet.profile_for(model),
                            fleet.network(), _img_data(model, 16))
    assert old["wall"] == want["wall"]
    assert [h["loss"] for h in old["history"]] == \
        [h["loss"] for h in want["history"]]
    assert isinstance(old["history"][0]["m_s"], int)  # triple history shape
    for x, y in zip(jax.tree.leaves(old["params"]),
                    jax.tree.leaves(want["params"])):
        assert (np.asarray(x) == np.asarray(y)).all()

    fleet2 = _cnn_fleet(model, m=2, topology="star")
    want2 = plan(model, fleet2, 18).train(_img_data(model, 18), steps=3,
                                          lr=0.05)
    from repro.train.loop import run_multi_hier_loop
    cfg2 = HierLoopConfig(total_steps=3, batch=18, lr=0.05)
    with pytest.warns(DeprecationWarning):
        old2 = run_multi_hier_loop(cfg2, model, fleet2.profile_for(model),
                                   fleet2.network(), _img_data(model, 18))
    assert [h["loss"] for h in old2["history"]] == \
        [h["loss"] for h in want2["history"]]
    assert old2["final_schedule"] == want2["final_schedule"]


# ---------------------------------------------------------------------------
# LM family through the facade
# ---------------------------------------------------------------------------

def test_lm_family_plans_and_steps_through_facade():
    import jax
    from repro.models.lm.layerstack import lm_layerstack
    from repro.models.lm.model import LMConfig
    cfg = LMConfig("api-test", "dense", n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=1, d_ff=64, vocab=64)
    stack = lm_layerstack(cfg, seq_len=16)
    fleet = Fleet.lm_default(m=2)
    p = plan(stack, fleet, 8)
    with pytest.warns(DeprecationWarning):
        old = scheduler.solve_multi(p.profile, p.network, 8)
    assert old.schedule == p.schedule and old.t_total == p.t_total
    # the plan executes: one exact hybrid-SGD step on the LM stack
    params = p.init_params(jax.random.PRNGKey(0))
    x, y = stack.dummy_batch(jax.random.PRNGKey(1), 8)
    params, loss = p.step_fn(lr=0.01)(params, x, y)
    assert np.isfinite(float(loss))
    assert p.simulate() > 0


# ---------------------------------------------------------------------------
# Constructors: from_table2 / lm_default match the shared hardware tables
# ---------------------------------------------------------------------------

def test_from_table2_matches_direct_construction():
    from repro.core.profiler import PAPER_TESTBED, analytic_profile
    from repro.models.cnn import lenet5
    model = lenet5()
    fleet = Fleet.from_table2(model="lenet5", m=3, edge_cloud_mbps=3.0,
                              topology="star")
    prof = fleet.profile_for(model)
    want = MultiProfile.from_hier(analytic_profile(model, PAPER_TESTBED),
                                  FLEET_SLOWDOWNS[:3])
    assert (prof.L_f == want.L_f).all() and (prof.L_u == want.L_u).all()
    assert prof.worker_names == want.worker_names
    net = fleet.network()
    assert (net.bw_de == np.array(FLEET_UPLINK_MBPS[:3]) * MBPS).all()
    # M=1 auto-resolves to the paper's exact triple
    f1 = Fleet.from_table2(model="lenet5")
    assert f1.topology == "triple"
    assert isinstance(f1.network(), Network)
    assert f1.network().bw_de == 5.0 * MBPS


def test_lm_default_matches_shared_tables():
    fleet = Fleet.lm_default(m=2)
    assert fleet.topology == "star"
    assert fleet.device_slowdowns == LM_FLEET_SLOWDOWNS[:2]
    net = fleet.network()
    assert (net.bw_de == np.array(LM_FLEET_UPLINK_MBPS[:2]) * MBPS).all()
    assert fleet.sample_bytes == 2e6


def test_benchmark_fleet_helpers_unchanged():
    """benchmarks.common now delegates to Fleet — same arrays as ever."""
    from benchmarks.common import fleet_profile, star_network
    from repro.core.profiler import PAPER_TESTBED, analytic_profile
    from repro.models.cnn import lenet5
    prof = fleet_profile("lenet5", 2)
    want = MultiProfile.from_hier(analytic_profile(lenet5(),
                                                   PAPER_TESTBED),
                                  FLEET_SLOWDOWNS[:2])
    assert (prof.L_f == want.L_f).all()
    net = star_network(2, 3.0)
    assert (net.bw_de == np.array(FLEET_UPLINK_MBPS[:2]) * MBPS).all()
    assert net.bw_ec == 3.0 * MBPS


# ---------------------------------------------------------------------------
# Public surface, warnings hygiene, explain snapshot, CLI
# ---------------------------------------------------------------------------

def test_public_surface_exports():
    assert repro.__all__ == ["Fleet", "Plan", "plan", "plan_many",
                             "as_layerstack"]
    assert repro.core.__all__ == ["Fleet", "Plan", "plan", "plan_many",
                                  "as_layerstack"]
    assert repro.Fleet is Fleet and repro.core.Fleet is Fleet
    assert repro.plan is plan and repro.core.plan is plan
    from repro.api import plan_many
    assert repro.plan_many is plan_many
    assert repro.core.plan_many is plan_many
    assert repro.Plan is Plan
    from repro.core.layerstack import as_layerstack
    assert repro.as_layerstack is as_layerstack
    assert repro.core.as_layerstack is as_layerstack
    with pytest.raises(AttributeError):
        repro.nonexistent_name


def test_facade_emits_no_deprecation_warnings():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p = plan(None, triple_fleet(5), 32)
        p.simulate()
        p.simulate(K=2)
        p.baseline("edge")
        p.explain()
        pm = plan(None, star_fleet(5, (1.0, 1.4)), 32)
        pm.simulate()
        pm.baseline("cloud")
        pm.explain()
    ours = [x for x in w if issubclass(x.category, DeprecationWarning)
            and str(x.message).startswith("repro.")]
    assert ours == []


def test_every_shim_warns_with_exact_replacement():
    prof = synthetic_profile(4)
    net = Network(bw_de=5.0 * MBPS, bw_ec=3.0 * MBPS)
    mprof = MultiProfile.from_hier(prof, (1.0,))
    mnet = StarNetwork.from_network(net, 1)
    sched = scheduler._solve_3w(prof, net, 16).schedule
    msched = MultiSchedule.from_schedule(sched)
    calls = [
        lambda: scheduler.solve(prof, net, 16),
        lambda: scheduler.solve_multi(mprof, mnet, 16),
        lambda: cost_model.t_total(prof, net, sched),
        lambda: cost_model.t_total_multi(mprof, mnet, msched),
        lambda: cost_model.t_total_batch(
            prof, net, np.array([0]), np.array([1]), np.array([2]),
            np.array([0]), np.array([0]), np.array([[16, 0, 0]])),
        lambda: cost_model.t_total_multi_batch(
            mprof, mnet, np.array([0]), np.array([[1]]), np.array([2]),
            np.array([[0]]), np.array([0]), np.array([[16, 0, 0]])),
        lambda: simulator.simulate_iteration(prof, net, sched),
        lambda: simulator.simulate_iteration_multi(mprof, mnet, msched),
    ]
    for call in calls:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            call()
        ours = [x for x in w if str(x.message).startswith("repro.")]
        assert len(ours) == 1, [str(x.message) for x in w]
        assert "repro.api" in str(ours[0].message)
        assert issubclass(ours[0].category, DeprecationWarning)


def test_plan_argument_errors():
    with pytest.raises(ValueError, match="pipeline_depth"):
        plan(None, triple_fleet(4), 8, pipeline_depth=0)
    with pytest.raises(ValueError, match="unknown scheduler objective"):
        plan(None, triple_fleet(4), 8, objective="goodput")
    p = plan(None, triple_fleet(4), 8)
    with pytest.raises(ValueError, match="without a model"):
        p.step_fn()
    with pytest.raises(ValueError, match="pass a model"):
        Fleet.from_table2().profile_for(None)
    with pytest.raises(ValueError, match="topology"):
        Fleet(topology="ring")
    with pytest.raises(ValueError, match="exactly one device"):
        Fleet.from_table2(m=2, topology="triple")
    prof = synthetic_profile(3)
    net = Network(bw_de=5.0 * MBPS, bw_ec=3.0 * MBPS)
    with pytest.raises(ValueError, match="triple-native"):
        Fleet.from_profile(prof, net, topology="star")
    with pytest.raises(ValueError, match="star-native"):
        Fleet.from_profile(MultiProfile.from_hier(prof, (1.0,)),
                           StarNetwork.from_network(net, 1),
                           topology="triple")


EXPLAIN_SNAPSHOT = """\
HierTrain plan — model=lenet5  fleet[M=1 (triple; uplinks 5 Mbps, \
backhaul 3 Mbps)]
  batch B=32  objective=latency  backend=batched  wire=none
  schedule: o=device(b=32) s=edge(m=0,b=0) l=cloud(m=0,b=0)
  cuts: m_s=0  m_l=0  of N=5 layers
  predicted: T_total=0.0951891s  T_period=0.0951891s
  phases (s): f1=0 b1=0 f2=0 b2=0 f3=0.03686 b3=0.05771 update=0.000624
  comm (s): input=0 activation=0 weight-sync=0
  baselines: all-edge=0.16701s (1.75x)  all-cloud=0.422228s (4.44x)
  search: 126 candidates, 0 pruned, 126 LPs"""


def test_explain_snapshot():
    from repro.models.cnn import lenet5
    p = plan(lenet5(), Fleet.from_table2(model="lenet5", m=1,
                                         edge_cloud_mbps=3.0), 32)
    assert p.explain() == EXPLAIN_SNAPSHOT


def test_cli_explain_smoke(capsys):
    from repro import api
    assert api.main(["--explain", "lenet5", "--batch", "16"]) == 0
    out = capsys.readouterr().out
    assert "HierTrain plan" in out and "simulated (DES)" in out
    with pytest.raises(SystemExit):
        api.main(["--explain", "resnet"])
