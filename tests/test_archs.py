"""Per-architecture smoke tests: reduced same-family config, one forward
/ train step / prefill+decode on CPU; output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.lm.model import build_model

KEY = jax.random.PRNGKey(0)


def _smoke_batch(spec, T, B):
    cfg = spec.smoke
    ks = jax.random.split(KEY, 3)
    toks = jax.random.randint(ks[0], (B, T), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[1], (B, T, cfg.d_model),
                                            jnp.float32)
    elif cfg.n_frontend_tokens > 0:
        P = cfg.n_frontend_tokens
        batch["tokens"] = batch["tokens"][:, :T - P]
        batch["targets"] = batch["targets"][:, :T - P]
        batch["embeds"] = jax.random.normal(ks[2], (B, P, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_smoke_train_step(arch_id):
    spec = get_arch(arch_id)
    model = build_model(spec.smoke)
    B, T = spec.smoke_batch, spec.smoke_seq
    batch = _smoke_batch(spec, T, B)
    params = model.init(KEY)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), arch_id
    gnorm = jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum()
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch_id


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_smoke_prefill_decode(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.smoke
    model = build_model(cfg)
    B, T = spec.smoke_batch, spec.smoke_seq
    batch = _smoke_batch(spec, T, B)
    params = model.init(KEY)
    max_len = T + 8
    logits, cache = model.prefill(params, batch, max_len)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch_id
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.int32(T if cfg.family != "dense" or "embeds" not in batch
                    else T)
    logits2, cache = model.decode_step(params, tok, cache, pos)
    assert logits2.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch_id


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_full_config_matches_assignment(arch_id):
    """The FULL configs carry the exact published dimensions."""
    want = {
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 0, 151936),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    }[arch_id]
    c = get_arch(arch_id).lm
    got = (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab)
    assert got == want, (arch_id, got, want)


def test_moe_expert_counts():
    g = get_arch("grok-1-314b").lm.moe
    assert (g.n_experts, g.top_k) == (8, 2)
    q = get_arch("qwen2-moe-a2.7b").lm.moe
    assert (q.n_experts, q.top_k, q.n_shared) == (60, 4, 4)


def test_long500k_only_for_subquadratic():
    for arch_id, spec in ARCHS.items():
        runs_long = "long_500k" in spec.shapes
        assert runs_long == spec.lm.sub_quadratic, arch_id
        if not runs_long:
            assert "long_500k" in spec.skips
    assert ARCHS["zamba2-7b"].lm.sub_quadratic
    assert ARCHS["xlstm-350m"].lm.sub_quadratic


def test_param_counts_near_published():
    """Total parameter counts are within tolerance of the model names."""
    import jax
    from repro.models.lm.model import param_count
    # eval_shape the FULL init — no allocation.
    checks = {"grok-1-314b": (314e9, 0.12), "pixtral-12b": (12e9, 0.15),
              "phi3-medium-14b": (14e9, 0.15), "gemma3-12b": (12e9, 0.20),
              "qwen2.5-3b": (3e9, 0.25), "granite-20b": (20e9, 0.15),
              "zamba2-7b": (7e9, 0.25),
              # our mLSTM keeps full-width q/k/v and untied embeddings,
              # which lands ~0.52B against the published 350M name.
              "xlstm-350m": (350e6, 0.55)}
    for arch_id, (want, tol) in checks.items():
        cfg = get_arch(arch_id).lm
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(shapes))
        assert abs(n - want) / want < tol, (arch_id, n, want)
