"""Equivalence suite: batched simplex / batched scheduler vs the scalar
reference oracle, across randomized profiles, networks, origins and sizes."""
import numpy as np
import pytest

from tests._compat import given, settings, st

from repro.core import batched_lp, scheduler
from repro.core import lp as lp_mod
from repro.core.cost_model import HierProfile, Network, t_total


def random_profile(n_layers, seed, sample_bytes=2000.0):
    rng = np.random.default_rng(seed)
    return HierProfile(
        layer_names=tuple(f"l{i}" for i in range(n_layers)),
        L_f=rng.uniform(1e-4, 1e-2, (3, n_layers)),
        L_b=rng.uniform(1e-4, 2e-2, (3, n_layers)),
        L_u=rng.uniform(1e-5, 1e-3, (3, n_layers)),
        MP=rng.uniform(1e3, 1e6, n_layers),
        MO=rng.uniform(1e2, 1e5, n_layers),
        sample_bytes=sample_bytes,
    )


def random_network(seed):
    rng = np.random.default_rng(seed ^ 0xBEEF)
    return Network(bw_de=rng.uniform(1e5, 1e7),
                   bw_ec=rng.uniform(1e5, 1e7))


# ---------------------------------------------------------------------------
# LP layer: linprog_batch vs a loop of scalar linprog calls.
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_linprog_batch_matches_scalar_on_random_stacks(seed):
    rng = np.random.default_rng(seed)
    K, n = 20, 5
    A_ub = np.zeros((K, 6, n))
    b_ub = np.zeros((K, 6))
    for k in range(K):
        for r in range(6):
            A_ub[k, r, rng.integers(0, 3)] = rng.uniform(0.0, 2.0)
            A_ub[k, r, 3 + r % 2] = -1.0
        # a couple of box constraints with random (possibly tight) rhs
        b_ub[k, rng.integers(0, 6)] = rng.uniform(-0.5, 4.0)
    A_eq = np.zeros((K, 1, n))
    A_eq[:, 0, :3] = 1.0
    b_eq = np.full((K, 1), 8.0)
    c = np.array([0.0, 0.0, 0.0, 1.0, 1.0])

    ref = lp_mod.solve_many(c, A_ub, b_ub, A_eq, b_eq)
    bat = batched_lp.linprog_batch(c, A_ub, b_ub, A_eq, b_eq)
    for k, r in enumerate(ref):
        assert bool(bat.success[k]) == r.success, (k, r.status)
        if r.success:
            assert bat.fun[k] == pytest.approx(r.fun, rel=1e-9, abs=1e-9)
            np.testing.assert_allclose(bat.x[k], r.x, atol=1e-9)


def test_linprog_batch_mixed_statuses():
    """Infeasible / optimal / degenerate lanes in one stack."""
    A_ub = np.zeros((3, 2, 2))
    b_ub = np.zeros((3, 2))
    A_eq = np.zeros((3, 1, 2))
    b_eq = np.zeros((3, 1))
    # lane 0: x0 <= -1 with x >= 0 -> infeasible
    A_ub[0, 0] = [1, 0]; b_ub[0, 0] = -1.0
    A_eq[0, 0] = [0, 1]; b_eq[0, 0] = 1.0
    # lane 1: min x+y s.t. x+y = 3
    A_eq[1, 0] = [1, 1]; b_eq[1, 0] = 3.0
    # lane 2: fully degenerate at the origin
    A_ub[2, 0] = [1, 0]; A_ub[2, 1] = [0, 1]
    A_eq[2, 0] = [1, 1]
    res = batched_lp.linprog_batch(np.array([1.0, 1.0]),
                                   A_ub, b_ub, A_eq, b_eq)
    assert list(res.success) == [False, True, True]
    assert res.status[0] == batched_lp.INFEASIBLE
    assert res.fun[1] == pytest.approx(3.0, abs=1e-9)
    assert res.fun[2] == pytest.approx(0.0, abs=1e-9)


def test_linprog_batch_frozen_lanes_stay_intact():
    """A lane that converges in 1 pivot must not be perturbed while a
    slower lane keeps iterating (converged-batch freezing)."""
    # lane 0 converges immediately (objective already optimal at slack
    # basis); lane 1 needs several pivots.
    A_ub = np.zeros((2, 3, 3))
    b_ub = np.ones((2, 3))
    A_eq = np.zeros((2, 0, 3))
    b_eq = np.zeros((2, 0))
    A_ub[0] = np.eye(3)
    A_ub[1] = [[1, 1, 0], [0, 1, 1], [1, 0, 1]]
    b_ub[1] = [4.0, 6.0, 5.0]
    c = np.array([[1.0, 1.0, 1.0], [-1.0, -2.0, -3.0]])
    res = batched_lp.linprog_batch(c, A_ub, b_ub, A_eq, b_eq)
    ref0 = lp_mod.linprog(c[0], A_ub[0], b_ub[0])
    ref1 = lp_mod.linprog(c[1], A_ub[1], b_ub[1])
    assert res.success.all()
    assert res.fun[0] == pytest.approx(ref0.fun, abs=1e-9)
    assert res.fun[1] == pytest.approx(ref1.fun, abs=1e-9)
    np.testing.assert_allclose(res.x[0], ref0.x, atol=1e-9)
    np.testing.assert_allclose(res.x[1], ref1.x, atol=1e-9)


# ---------------------------------------------------------------------------
# Scheduler layer: batched backend == reference backend.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_layers", [3, 8, 16])
def test_backends_equivalent_across_profiles(n_layers):
    """Identical t_total (and the same schedule) on randomized profiles,
    networks, batch sizes and data origins."""
    n_cases = 6 if n_layers < 16 else 3
    for seed in range(n_cases):
        prof = random_profile(n_layers, seed=seed)
        net = random_network(seed)
        B = int(np.random.default_rng(seed).integers(8, 65))
        origin = ("device", "edge", "cloud")[seed % 3]
        ref = scheduler.solve(prof, net, B, origin=origin,
                              backend="reference", keep_log=True)
        bat = scheduler.solve(prof, net, B, origin=origin, keep_log=True)
        assert bat.t_total == ref.t_total, (n_layers, seed, origin)
        assert bat.schedule == ref.schedule, (n_layers, seed, origin)
        # LP optima agree to tolerance on every candidate both solved
        ref_log = {(s.worker_o, s.worker_s, s.worker_l, s.m_s, s.m_l): v
                   for s, v in ref.search_log}
        for s, v in bat.search_log:
            key = (s.worker_o, s.worker_s, s.worker_l, s.m_s, s.m_l)
            assert v == pytest.approx(ref_log[key], rel=1e-9, abs=1e-12)


def test_pruning_never_changes_the_answer():
    for seed in range(5):
        prof = random_profile(8, seed=seed + 100)
        net = random_network(seed + 100)
        full = scheduler.solve(prof, net, 32, prune=False)
        pruned = scheduler.solve(prof, net, 32, prune=True)
        assert pruned.t_total == full.t_total
        assert pruned.schedule == full.schedule
        assert pruned.n_lp_solved <= full.n_lp_solved


def test_batched_result_metadata():
    prof = random_profile(5, seed=7)
    res = scheduler.solve(prof, random_network(7), 16)
    K = 6 * (5 + 1) * (5 + 2) // 2
    assert res.n_candidates == K
    assert res.n_lp_solved + res.n_pruned == K
    s = res.schedule
    assert s.b_o + s.b_s + s.b_l == 16
    assert t_total(prof, random_network(7), s).total == res.t_total


def test_unknown_backend_rejected():
    prof = random_profile(3, seed=0)
    with pytest.raises(ValueError):
        scheduler.solve(prof, random_network(0), 8, backend="cplex")
