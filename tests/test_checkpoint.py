"""Checkpoint store: roundtrip (incl. bf16), atomicity, keep-N GC,
corruption detection, structure mismatch, restore-latest."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    load_checkpoint, save_checkpoint)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (8, 16), jnp.float32),
                   "b": jax.random.normal(k2, (16,)).astype(jnp.bfloat16)},
        "opt": {"m": jnp.zeros((8, 16), jnp.float32),
                "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 3, tree, extra={"note": "x"})
    out = load_checkpoint(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_keep_n(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    assert latest_step(str(tmp_path)) == 4


def test_atomic_no_partial(tmp_path):
    """A stray .tmp dir (simulated crash) is never picked up."""
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_corruption_detected(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    path = save_checkpoint(str(tmp_path), 1, tree)
    payload = os.path.join(path, "arrays.npz")
    with open(payload, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="corrupt"):
        load_checkpoint(str(tmp_path), 1, tree)


def test_structure_mismatch(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, tree)
    other = {"params": {"w": tree["params"]["w"]}}
    with pytest.raises(ValueError, match="mismatch"):
        load_checkpoint(str(tmp_path), 1, other)


def test_restore_latest_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    step, restored = mgr.restore_latest({"a": jnp.zeros(3)})
    assert step is None and restored is None


def test_stray_entries_ignored(tmp_path):
    """Strict step_\\d{8} parsing: notes files, torn .tmp dirs, and
    oddly named directories never break listing or GC."""
    tree = _tree(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, tree)
    (tmp_path / "step_notes.txt").write_text("operator scribbles")
    os.makedirs(tmp_path / "step_00000009.tmp")
    os.makedirs(tmp_path / "step_abc")
    assert latest_step(str(tmp_path)) == 1
    mgr.save(2, tree)
    mgr.save(3, tree)     # GC of step 1 must skip the strays
    assert latest_step(str(tmp_path)) == 3
    assert (tmp_path / "step_notes.txt").exists()
    assert (tmp_path / "step_abc").exists()


def test_corrupt_newest_falls_back(tmp_path):
    """restore_latest skips an unreadable newest step with a warning and
    restores the previous one."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    t1, t2 = _tree(k1), _tree(k2)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, t1)
    mgr.save(2, t2)
    payload = tmp_path / "step_00000002" / "arrays.npz"
    with open(payload, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        step, restored = mgr.restore_latest(t1)
    assert step == 1
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_all_corrupt_raises(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, tree)
    with open(tmp_path / "step_00000001" / "arrays.npz", "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        with pytest.raises(IOError):
            mgr.restore_latest(tree)


def test_restore_latest_with_extra(tmp_path):
    """Two-phase restore: like_fn sees the manifest extra before the
    arrays load, so it can rebuild a membership-dependent tree."""
    tree = _tree(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, tree, extra={"fleet": ["a", "b"], "wall": 1.25})
    seen = {}

    def like_fn(step, extra):
        seen["step"], seen["extra"] = step, extra
        return tree

    step, restored, extra = mgr.restore_latest_with(like_fn)
    assert step == 5 and seen["step"] == 5
    assert extra["fleet"] == ["a", "b"] and extra["wall"] == 1.25
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_float64_roundtrip_exact(tmp_path):
    """f64 leaves (hier-loop profile rows) restore bit-exactly even with
    jax x64 disabled — the loader must not let jnp downcast them."""
    rng = np.random.default_rng(0)
    tree = {"L_f": rng.random((3, 5)), "L_b": rng.random((3, 5))}
    save_checkpoint(str(tmp_path), 1, tree)
    out = load_checkpoint(str(tmp_path), 1,
                          {k: np.zeros_like(v) for k, v in tree.items()})
    for k in tree:
        assert np.asarray(out[k]).dtype == np.float64
        np.testing.assert_array_equal(np.asarray(out[k]), tree[k])


def test_reshard_on_load(tmp_path):
    """Elastic restore: load with explicit (single-device) shardings."""
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, tree)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    out = load_checkpoint(str(tmp_path), 1, tree, shardings=shardings)
    assert all(a.sharding == jax.sharding.SingleDeviceSharding(dev)
               for a in jax.tree.leaves(out))
