"""Checkpoint store: roundtrip (incl. bf16), atomicity, keep-N GC,
corruption detection, structure mismatch, restore-latest."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    load_checkpoint, save_checkpoint)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (8, 16), jnp.float32),
                   "b": jax.random.normal(k2, (16,)).astype(jnp.bfloat16)},
        "opt": {"m": jnp.zeros((8, 16), jnp.float32),
                "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 3, tree, extra={"note": "x"})
    out = load_checkpoint(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_keep_n(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    assert latest_step(str(tmp_path)) == 4


def test_atomic_no_partial(tmp_path):
    """A stray .tmp dir (simulated crash) is never picked up."""
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_corruption_detected(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    path = save_checkpoint(str(tmp_path), 1, tree)
    payload = os.path.join(path, "arrays.npz")
    with open(payload, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="corrupt"):
        load_checkpoint(str(tmp_path), 1, tree)


def test_structure_mismatch(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, tree)
    other = {"params": {"w": tree["params"]["w"]}}
    with pytest.raises(ValueError, match="mismatch"):
        load_checkpoint(str(tmp_path), 1, other)


def test_restore_latest_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    step, restored = mgr.restore_latest({"a": jnp.zeros(3)})
    assert step is None and restored is None


def test_reshard_on_load(tmp_path):
    """Elastic restore: load with explicit (single-device) shardings."""
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, tree)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    out = load_checkpoint(str(tmp_path), 1, tree, shardings=shardings)
    assert all(a.sharding == jax.sharding.SingleDeviceSharding(dev)
               for a in jax.tree.leaves(out))
