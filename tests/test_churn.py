"""Elastic fleets (DESIGN.md §10): deterministic churn traces,
membership edits, schedule remapping, warm-started re-solve
bit-identity, and exact-SGD preservation across membership changes."""
import jax
import numpy as np
import pytest

from repro.core.churn import (ChurnTrace, DeviceCrash, DeviceJoin,
                              DeviceLeave, LinkDegrade, apply_event,
                              poisson_trace, reference_rows,
                              remap_schedule)
from repro.core.cost_model import StarNetwork
from repro.core.profiler import multi_analytic_profile
from repro.data.pipeline import SyntheticImages


def _tiny_mlp():
    from repro.models.cnn import DenseSpec, LayeredModel
    specs = tuple(DenseSpec(f"fc{i}", 16) for i in range(4)) + \
        (DenseSpec("out", 5, relu=False),)
    return LayeredModel("tiny_mlp", specs, (8,), 5)


def _star(model, slowdowns=(1.0, 1.2, 1.8)):
    prof = multi_analytic_profile(model, device_slowdowns=slowdowns)
    bw = np.linspace(4.0, 3.0, len(slowdowns)) * 1e6 / 8
    net = StarNetwork(bw_de=bw, bw_ec=2.0 * 1e6 / 8)
    return prof, net


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------

def test_poisson_trace_deterministic():
    devs = ("device_0", "device_1", "device_2")
    a = poisson_trace(devs, 200, seed=7, join_rate=0.1, leave_rate=0.1,
                      crash_rate=0.05, degrade_rate=0.1)
    b = poisson_trace(devs, 200, seed=7, join_rate=0.1, leave_rate=0.1,
                      crash_rate=0.05, degrade_rate=0.1)
    assert a == b                      # pure function of the seed
    c = poisson_trace(devs, 200, seed=8, join_rate=0.1, leave_rate=0.1,
                      crash_rate=0.05, degrade_rate=0.1)
    assert a != c


def test_poisson_trace_respects_bounds():
    devs = ("device_0", "device_1")
    tr = poisson_trace(devs, 500, seed=0, join_rate=0.2, leave_rate=0.3,
                       crash_rate=0.2, min_devices=1, max_devices=3)
    live = set(devs)
    for e in tr.events:
        if isinstance(e, (DeviceLeave, DeviceCrash)):
            live.discard(e.name)
        elif isinstance(e, DeviceJoin):
            assert e.name not in live
            live.add(e.name)
        assert 1 <= len(live) <= 3


def test_trace_ordering_and_since():
    tr = ChurnTrace((DeviceLeave(2, "a"), DeviceJoin(5, "b"),
                     LinkDegrade(5, "b", 0.5)))
    assert tr.events_at(5) == (DeviceJoin(5, "b"),
                               LinkDegrade(5, "b", 0.5))
    assert tr.since(5).events == tr.events_at(5)
    assert tr.max_step == 5
    with pytest.raises(AssertionError):
        ChurnTrace((DeviceJoin(5, "b"), DeviceLeave(2, "a")))


# ---------------------------------------------------------------------------
# membership edits
# ---------------------------------------------------------------------------

def test_apply_events_roundtrip_membership():
    model = _tiny_mlp()
    prof, net = _star(model)
    base = prof
    ref = reference_rows(base)

    prof2, base2, net2, changed = apply_event(
        prof, base, net, ref, DeviceJoin(3, "dev_j0", slowdown=2.0,
                                         uplink_mbps=4.0))
    assert changed
    assert prof2.worker_names[:-2] == ("device_0", "device_1",
                                       "device_2", "dev_j0")
    i = prof2.device_index("dev_j0")
    np.testing.assert_array_equal(prof2.L_f[i], ref[0] * 2.0)
    assert net2.bw_de[i] == 4.0 * 1e6 / 8
    # survivors' rows are byte-identical to pre-churn
    np.testing.assert_array_equal(prof2.L_f[:3], prof.L_f[:3])

    prof3, base3, net3, changed = apply_event(
        prof2, base2, net2, ref, DeviceLeave(4, "device_1"))
    assert changed
    assert "device_1" not in prof3.worker_names
    assert len(net3.bw_de) == 3

    _, _, net4, changed = apply_event(prof3, base3, net3, ref,
                                      LinkDegrade(5, "device_0", 0.5))
    assert not changed
    assert net4.bw_de[0] == net3.bw_de[0] * 0.5

    with pytest.raises(ValueError):
        prof.add_device("device_0", ref[0], ref[1], ref[2])   # duplicate
    with pytest.raises(ValueError):
        prof.drop_device("edge")                              # not a device
    with pytest.raises(ValueError):
        net.scale_uplink(0, 0.0)


def test_drop_last_device_rejected():
    model = _tiny_mlp()
    prof, _ = _star(model, slowdowns=(1.0,))
    with pytest.raises(ValueError):
        prof.drop_device("device_0")


# ---------------------------------------------------------------------------
# schedule remap: exact-SGD semantics (sample set unchanged)
# ---------------------------------------------------------------------------

def test_remap_folds_lost_samples_into_task_o():
    from repro.core.cost_model import MultiSchedule, _validate_multi
    model = _tiny_mlp()
    prof, net = _star(model)
    # hand-built schedule with a loaded TASK-S device so the fold is
    # observable (the solver's optimum may park everything on o/l)
    sched = MultiSchedule(worker_o="cloud", worker_l="edge",
                          s_workers=("device_0", "device_1", "device_2"),
                          m_s=(2, 2, 0), m_l=4, b_o=10, b_s=(8, 6, 0),
                          b_l=0)
    _validate_multi(prof, sched)
    departed, lost = "device_1", 6
    prof2 = prof.drop_device(departed)
    re = remap_schedule(sched, prof2)
    assert re is not None
    _validate_multi(prof2, re)
    assert re.b_o == sched.b_o + lost
    assert re.batch == sched.batch        # same sample set => exact SGD
    assert departed not in re.s_workers

    # a joiner enters idle
    prof3 = prof.add_device("dev_j0", prof.L_f[0], prof.L_b[0],
                            prof.L_u[0])
    re2 = remap_schedule(sched, prof3)
    j = re2.s_workers.index("dev_j0")
    assert re2.m_s[j] == 0 and re2.b_s[j] == 0
    assert re2.batch == sched.batch

    # losing TASK O's owner kills the cut structure
    sched_o = MultiSchedule(worker_o="device_0", worker_l="cloud",
                            s_workers=("device_1", "device_2"),
                            m_s=(2, 0), m_l=4, b_o=18, b_s=(6, 0), b_l=0)
    assert remap_schedule(sched_o, prof.drop_device("device_0")) is None


# ---------------------------------------------------------------------------
# warm-started re-solve: bit-identical to a cold solve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("objective", ["latency", "throughput"])
def test_warm_solve_bit_identical(objective):
    from repro.core.scheduler import _solve_multi
    model = _tiny_mlp()
    prof, net = _star(model, slowdowns=(1.0, 1.3, 1.7, 2.2))
    full = _solve_multi(prof, net, 24, objective=objective).schedule
    survivors = prof.drop_device("device_2")
    net_s = net.drop_device(2)
    warm = remap_schedule(full, survivors)
    assert warm is not None
    cold = _solve_multi(survivors, net_s, 24, objective=objective)
    ws = _solve_multi(survivors, net_s, 24, objective=objective,
                      warm_start=warm)
    assert ws.schedule == cold.schedule           # bit-identical argmin
    assert ws.t_total == cold.t_total
    assert ws.n_pruned >= cold.n_pruned           # never prunes less


def test_warm_solve_wrong_batch_rejected():
    from repro.core.scheduler import _solve_multi
    model = _tiny_mlp()
    prof, net = _star(model)
    sched = _solve_multi(prof, net, 24).schedule
    with pytest.raises(ValueError):
        _solve_multi(prof, net, 32, warm_start=sched)


# ---------------------------------------------------------------------------
# loop-level: churn == fresh fleet; determinism; triple rejects churn
# ---------------------------------------------------------------------------

def test_churn_at_step0_equals_fresh_survivor_fleet():
    from repro import api
    model = _tiny_mlp()
    prof, net = _star(model)
    data = SyntheticImages(model.input_shape, model.num_classes, 24,
                           seed=0)
    trace = ChurnTrace((DeviceLeave(0, "device_1"),))
    churned = api.plan(model, api.Fleet.from_profile(prof, net), 24) \
        .train(data, steps=5, seed=3, churn=trace)
    fresh = api.plan(
        model, api.Fleet.from_profile(prof.drop_device("device_1"),
                                      net.drop_device(1)), 24) \
        .train(data, steps=5, seed=3)
    for a, b in zip(jax.tree.leaves(churned["params"]),
                    jax.tree.leaves(fresh["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for ha, hb in zip(churned["history"], fresh["history"]):
        assert ha["loss"] == hb["loss"] and ha["sched"] == hb["sched"]


def test_midrun_churn_schedule_matches_cold_solve():
    from repro import api
    from repro.core.scheduler import _solve_multi
    model = _tiny_mlp()
    prof, net = _star(model)
    data = SyntheticImages(model.input_shape, model.num_classes, 24,
                           seed=0)
    trace = ChurnTrace((DeviceLeave(3, "device_2"),))
    out = api.plan(model, api.Fleet.from_profile(prof, net), 24) \
        .train(data, steps=6, seed=3, churn=trace)
    assert len(out["churn_log"]) == 1 and out["churn_log"][0]["warm"]
    cold = _solve_multi(prof.drop_device("device_2"), net.drop_device(2),
                        24).schedule
    assert out["history"][3]["sched"] == cold
    assert out["final_schedule"] == cold


def test_churn_run_deterministic_and_resumable(tmp_path):
    from repro import api
    from repro.train.loop import InjectedFailure
    model = _tiny_mlp()
    prof, net = _star(model)
    fleet = api.Fleet.from_profile(prof, net)
    data = SyntheticImages(model.input_shape, model.num_classes, 24,
                           seed=0)
    trace = poisson_trace(prof.worker_names[:-2], 18, seed=1,
                          join_rate=0.15, leave_rate=0.1,
                          crash_rate=0.08, degrade_rate=0.1)
    assert trace.events, "trace unexpectedly empty; pick another seed"
    kw = dict(steps=18, seed=3, churn=trace)
    ref = api.plan(model, fleet, 24).train(data, **kw)
    again = api.plan(model, fleet, 24).train(data, **kw)
    assert ref["wall"] == again["wall"]           # simulated clock is pure

    with pytest.raises(InjectedFailure):
        api.plan(model, fleet, 24).train(
            data, ckpt_dir=str(tmp_path), ckpt_every=4, fail_at=11, **kw)
    out = api.plan(model, fleet, 24).train(
        data, ckpt_dir=str(tmp_path), ckpt_every=4, **kw)
    assert out["resumed_from"] == 8
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tail = [h for h in ref["history"] if h["step"] > 8]
    assert len(tail) == len(out["history"])
    for ha, hb in zip(tail, out["history"]):
        assert ha["loss"] == hb["loss"]
        assert ha["wall"] == hb["wall"]
        assert ha["sched"] == hb["sched"]
    assert ref["wall"] == out["wall"]


def test_churn_rejected_on_triple():
    from repro import api
    from repro.core.cost_model import Network
    from repro.core.profiler import analytic_profile
    model = _tiny_mlp()
    fleet = api.Fleet.from_profile(analytic_profile(model),
                                   Network(5e6 / 8, 1e6 / 8))
    data = SyntheticImages(model.input_shape, model.num_classes, 16,
                           seed=0)
    with pytest.raises(NotImplementedError, match="triple"):
        api.plan(model, fleet, 16).train(
            data, steps=2, churn=ChurnTrace((DeviceLeave(0, "x"),)))
