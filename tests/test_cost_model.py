"""Cost-model (Eqs 1-12), scheduler (Alg 1), and baseline tests."""
import itertools

import numpy as np
import pytest
from tests._compat import given, settings, st

from repro.core import baselines, scheduler
from repro.core.cost_model import (HierProfile, Network, Schedule, t_total)
from repro.core.profiler import PAPER_TESTBED, analytic_profile
from repro.models.cnn import alexnet, lenet5


def tiny_profile(n_layers=3, seed=0, sample_bytes=1000.0):
    rng = np.random.default_rng(seed)
    return HierProfile(
        layer_names=tuple(f"l{i}" for i in range(n_layers)),
        L_f=rng.uniform(1e-4, 1e-2, (3, n_layers)),
        L_b=rng.uniform(1e-4, 2e-2, (3, n_layers)),
        L_u=rng.uniform(1e-5, 1e-3, (3, n_layers)),
        MP=rng.uniform(1e3, 1e6, n_layers),
        MO=rng.uniform(1e2, 1e5, n_layers),
        sample_bytes=sample_bytes,
    )


NET = Network(bw_de=5e6 / 8, bw_ec=3e6 / 8)  # 5 / 3 Mbps in bytes/s


def test_hand_computed_all_on_device():
    """Everything on the device: T = B*(F+Bk) over all layers + update."""
    prof = tiny_profile(2)
    sched = Schedule("device", "device", "device", 0, 0, 8, 0, 0)
    bd = t_total(prof, NET, sched)
    expect = 8 * (prof.L_f[0].sum() + prof.L_b[0].sum()) + prof.L_u[0].sum()
    assert bd.total == pytest.approx(expect, rel=1e-12)
    assert bd.comm_input == 0.0


def test_hand_computed_all_cloud_includes_input_transfer():
    prof = tiny_profile(2)
    B = 8
    sched = Schedule("cloud", "cloud", "cloud", 0, 0, B, 0, 0)
    bd = t_total(prof, NET, sched)
    series = 1.0 / (1.0 / NET.bw_de + 1.0 / NET.bw_ec)
    expect = B * prof.sample_bytes / series + \
        B * (prof.L_f[2].sum() + prof.L_b[2].sum()) + prof.L_u[2].sum()
    assert bd.total == pytest.approx(expect, rel=1e-12)


def test_three_worker_schedule_phases():
    """Hand-check Eq. (5)-(11) on a 3-layer net with m_s=1, m_l=2."""
    prof = tiny_profile(3)
    B, bo, bs, bl = 10, 4, 3, 3
    sched = Schedule("cloud", "device", "edge", 1, 2, bo, bs, bl)
    bd = t_total(prof, NET, sched)
    series = 1.0 / (1.0 / NET.bw_de + 1.0 / NET.bw_ec)
    Q = prof.sample_bytes
    bw_os = series            # cloud-device
    bw_ol = NET.bw_ec         # cloud-edge
    t_in_o = bo * Q / series  # data starts at device, worker_o is cloud
    t_in_s = 0.0              # worker_s IS the device
    t_in_l = bl * Q / NET.bw_de
    t_s_out = bs * prof.MO[0] / bw_os
    t_l_out = bl * prof.MO[1] / bw_ol
    f1 = max(t_in_o + bo * prof.L_f[2, 0],
             t_in_s + bs * prof.L_f[0, 0] + t_s_out,
             t_in_l + bl * prof.L_f[1, 0])
    assert bd.t_f1 == pytest.approx(f1, rel=1e-12)
    f2 = max((bo + bs) * prof.L_f[2, 1], bl * prof.L_f[1, 1] + t_l_out)
    assert bd.t_f2 == pytest.approx(f2, rel=1e-12)
    f3 = B * prof.L_f[2, 2]
    assert bd.t_f3 == pytest.approx(f3, rel=1e-12)
    upd = max(prof.L_u[2].sum(), prof.L_u[0, 0], prof.L_u[1, :2].sum()) + \
        max(2 * prof.MP[0] / bw_os, 2 * prof.MP[:2].sum() / bw_ol)
    assert bd.t_update == pytest.approx(upd, rel=1e-12)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_beats_brute_force_within_rounding(seed):
    """Scheduler (LP + rounding) vs exhaustive integer search, small case."""
    prof = tiny_profile(3, seed=seed)
    B = 6
    res = scheduler.solve(prof, NET, B)
    # exhaustive integer optimum
    best = np.inf
    for wo, ws, wl in itertools.permutations(("device", "edge", "cloud")):
        for m_s in range(4):
            for m_l in range(m_s, 4):
                for bo in range(B + 1):
                    for bs in range(B + 1 - bo):
                        bl = B - bo - bs
                        if (m_s == 0 and bs > 0) or (m_l == 0 and bl > 0):
                            continue
                        sc = Schedule(wo, ws, wl, m_s, m_l, bo, bs, bl)
                        best = min(best, t_total(prof, NET, sc).total)
    assert res.t_total >= best - 1e-12  # can't beat the true optimum
    assert res.t_total <= best * 1.25 + 1e-9  # rounding gap stays small


def test_scheduler_never_worse_than_naive_baselines():
    """All-Edge / All-Cloud are degenerate points of the search space."""
    for model in (lenet5(), alexnet()):
        prof = analytic_profile(model)
        for bw_ec in (1.5e6 / 8, 3e6 / 8, 5e6 / 8):
            net = Network(bw_de=5e6 / 8, bw_ec=bw_ec)
            res = scheduler.solve(prof, net, B=32)
            base = baselines.run_all(prof, net, B=32)
            assert res.t_total <= base["all-edge"].t_total + 1e-9
            assert res.t_total <= base["all-cloud"].t_total + 1e-9


def test_constraints_14_15_enforced():
    prof = tiny_profile(3)
    with pytest.raises(AssertionError):
        t_total(prof, NET, Schedule("cloud", "device", "edge", 0, 2, 4, 2, 2))
    with pytest.raises(AssertionError):
        t_total(prof, NET, Schedule("cloud", "device", "edge", 0, 0, 4, 0, 2))


def test_batch_conservation_in_scheduler():
    prof = analytic_profile(lenet5())
    res = scheduler.solve(prof, NET, B=17)
    s = res.schedule
    assert s.b_o + s.b_s + s.b_l == 17
    assert s.b_o >= 0 and s.b_s >= 0 and s.b_l >= 0
    assert 0 <= s.m_s <= s.m_l <= prof.num_layers


def test_jalad_compression_helps_at_low_bandwidth():
    prof = analytic_profile(alexnet())
    low = Network(bw_de=5e6 / 8, bw_ec=1.5e6 / 8)
    j = baselines.jalad(prof, low, B=32)
    nocomp = baselines.jalad(prof, low, B=32, compress_bits=32)
    assert j.t_total <= nocomp.t_total + 1e-9
