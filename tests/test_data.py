"""Data pipeline invariants (hypothesis where it matters): determinism,
shard-count invariance (elastic rescaling preserves the global batch),
stateless skip-ahead."""
import numpy as np
from tests._compat import given, settings, st

from repro.configs.base import ShapeSpec
from repro.data.pipeline import (SyntheticImages, SyntheticTokens,
                                 make_lm_batch_fn)


def test_deterministic():
    s = SyntheticTokens(vocab=100, seq_len=32, global_batch=8, seed=3)
    a = s.batch(5)
    b = s.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 1000),
       num_shards=st.sampled_from([1, 2, 4, 8]))
def test_shard_invariance(step, num_shards):
    """Concatenating shard batches reproduces the 1-shard global batch —
    the property that makes rescaling data-transparent."""
    s = SyntheticTokens(vocab=64, seq_len=16, global_batch=8, seed=0)
    whole = s.batch(step)["tokens"]
    parts = [s.batch(step, shard, num_shards)["tokens"]
             for shard in range(num_shards)]
    np.testing.assert_array_equal(whole, np.concatenate(parts, axis=0))


def test_targets_are_shifted_tokens():
    s = SyntheticTokens(vocab=50, seq_len=16, global_batch=2, seed=1)
    b = s.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_images_learnable_structure():
    s = SyntheticImages((8, 8, 3), num_classes=4, global_batch=64, seed=0)
    b = s.batch(0)
    protos = s._prototypes()
    # same-class samples are closer to their prototype than to others
    d_own, d_other = [], []
    for i in range(64):
        x, y = b["x"][i], b["labels"][i]
        d = np.linalg.norm((protos - x).reshape(4, -1), axis=1)
        d_own.append(d[y])
        d_other.append(np.delete(d, y).min())
    assert np.mean(d_own) < np.mean(d_other)


def test_lm_batch_fn_families():
    cfgs = []
    from repro.configs import get_arch
    shape = ShapeSpec("t", 32, 4, "train")
    for arch in ("whisper-base", "pixtral-12b", "qwen2.5-3b"):
        cfg = get_arch(arch).smoke
        fn = make_lm_batch_fn(cfg, shape, seed=0)
        b = fn(0)
        assert b["tokens"].shape[0] == 4
        if cfg.family == "encdec":
            assert b["frames"].shape == (4, 32, cfg.d_model)
        if cfg.n_frontend_tokens:
            assert "embeds" in b
